"""Training substrate: optimizer, schedules, grad utils, trainer restart."""

import math
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_reduced_config
from repro.data import make_pipeline
from repro.models import build_model
from repro.optim import adamw, clip_by_global_norm, cosine, global_norm, wsd
from repro.optim.adamw import AdamWConfig, dequantize_moment, quantize_moment
from repro.optim.grad_utils import accumulate_grads
from repro.training import Trainer, TrainerConfig


# ---------------- schedules -------------------------------------------------
def test_wsd_shape():
    f = wsd(1e-3, total_steps=100, warmup_steps=10)
    lrs = [float(f(jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= lrs[10] * 1.01          # warmup rises
    assert abs(lrs[50] - 1e-3) < 1e-9                 # stable plateau
    assert lrs[-1] < 1e-4                             # decayed at the end
    assert max(lrs) <= 1e-3 + 1e-9


def test_cosine_monotone_decay_after_warmup():
    f = cosine(1e-2, total_steps=50, warmup_steps=5)
    lrs = [float(f(jnp.asarray(s))) for s in range(5, 50)]
    assert all(a >= b - 1e-12 for a, b in zip(lrs, lrs[1:]))


# ---------------- adamw -----------------------------------------------------
def test_adamw_reduces_quadratic_loss():
    opt = adamw(0.1, AdamWConfig(weight_decay=0.0))
    p = {"w": jnp.asarray([3.0, -2.0])}
    st = opt.init(p)
    for _ in range(60):
        g = {"w": 2 * p["w"]}
        p, st = opt.update(p, g, st)
    assert float(jnp.abs(p["w"]).max()) < 0.15


def test_quantized_adamw_tracks_exact():
    key = jax.random.PRNGKey(0)
    p0 = {"w": jax.random.normal(key, (32, 256))}
    exact, quant = adamw(1e-2), adamw(1e-2, AdamWConfig(quantized_state=True))
    se, sq = exact.init(p0), quant.init(p0)
    pe, pq = p0, p0
    for i in range(10):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (32, 256))}
        pe, se = exact.update(pe, g, se)
        pq, sq = quant.update(pq, g, sq)
    drift = float(jnp.max(jnp.abs(pe["w"] - pq["w"])))
    assert drift < 0.03, drift


def test_quantize_moment_roundtrip_shapes():
    for shape in [(7,), (3, 5), (4, 512), (2, 3, 394)]:
        x = jax.random.normal(jax.random.PRNGKey(0), shape)
        qm = quantize_moment(x)
        assert qm.q.shape == shape
        y = dequantize_moment(qm, shape)
        assert y.shape == shape
        rel = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
        assert rel < 0.02


# ---------------- grad utils ------------------------------------------------
def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((2, 2)) * 4.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_accumulate_grads_matches_full_batch():
    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {}

    key = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(key, (8, 1), jnp.float32)}
    batch = {"x": jax.random.normal(key, (16, 8), jnp.float32),
             "y": jax.random.normal(key, (16, 1), jnp.float32)}
    l1, _, g1 = accumulate_grads(loss_fn, p, batch, 1)
    l4, _, g4 = accumulate_grads(loss_fn, p, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]),
                               rtol=1e-4, atol=1e-6)


# ---------------- trainer: bit-exact restart --------------------------------
@pytest.mark.slow
def test_trainer_restart_bit_exact():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    shape = ShapeConfig("t", 32, 4, "train")
    pipe = make_pipeline(cfg, shape)
    opt = adamw(cosine(3e-3, 10, 2))

    ref_tr = Trainer(model, opt, pipe, TrainerConfig(
        total_steps=8, checkpoint_every=100, log_every=100),
        log_fn=lambda *_: None)
    _, ref = ref_tr.run()

    with tempfile.TemporaryDirectory() as d:
        t1 = Trainer(model, opt, pipe, TrainerConfig(
            total_steps=5, checkpoint_every=5, checkpoint_dir=d,
            log_every=100), log_fn=lambda *_: None)
        t1.run()
        t2 = Trainer(model, opt, pipe, TrainerConfig(
            total_steps=8, checkpoint_every=5, checkpoint_dir=d,
            log_every=100), log_fn=lambda *_: None)
        _, resumed = t2.run()
    assert math.isclose(ref["loss"], resumed["loss"], rel_tol=0, abs_tol=0), \
        (ref["loss"], resumed["loss"])
