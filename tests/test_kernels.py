"""Pallas kernel validation: interpret-mode sweeps vs the jnp oracles.

Every kernel is swept over shapes/dtypes and asserted allclose against the
pure-jnp reference (ref.py).  f32 planar complex arithmetic bounds accuracy
to ~1e-5 relative for these reduction lengths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import CodedFFT, mds
from repro.kernels import (
    fft_fourstep,
    make_kernel_worker_fn,
    mds_apply,
    recombine_fused,
    split_factor,
)
from repro.kernels import ref
from repro.kernels.fourstep_fft import fourstep_fused, fourstep_stage1, fourstep_stage2
from repro.kernels.cmatmul import cmatmul
from repro.kernels.recombine import recombine_twiddle_dft

pytestmark = pytest.mark.kernels

RTOL = 2e-4  # f32 planar complex, reductions up to 4096
ATOL = 1e-3


def _randc(shape, seed=0, dtype=jnp.complex64):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.normal(size=shape) + 1j * rng.normal(size=shape), dtype=dtype
    )


def _relerr(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-12)


# ---------------------------------------------------------------- four-step
@pytest.mark.parametrize("ell", [64, 256, 1024, 4096])
@pytest.mark.parametrize("batch", [1, 3])
def test_fourstep_fft_matches_fft(ell, batch):
    x = _randc((batch, ell), seed=ell + batch)
    got = fft_fourstep(x, interpret=True)
    want = np.fft.fft(np.asarray(x, dtype=np.complex128), axis=-1)
    assert _relerr(got, want) < RTOL


@pytest.mark.parametrize("ell", [384, 1536])  # non-power-of-two, composite
def test_fourstep_fft_composite_lengths(ell, batch=2):
    x = _randc((batch, ell), seed=ell)
    got = fft_fourstep(x, interpret=True)
    want = np.fft.fft(np.asarray(x, dtype=np.complex128), axis=-1)
    assert _relerr(got, want) < RTOL


def test_fourstep_two_pass_matches_fused():
    """stage1+stage2 (large-size path) == fused kernel result."""
    batch, a, b = 2, 16, 64
    x = _randc((batch, a * b), seed=7)
    xr, xi = ref.planar(x)
    xr = xr.reshape(batch, a, b)
    xi = xi.reshape(batch, a, b)
    from repro.kernels.ops import _dft_planes, _twiddle_planes

    far, fai = _dft_planes(a)
    fbr, fbi = _dft_planes(b)
    wr, wi = _twiddle_planes(a, b)
    fr, fi2 = fourstep_fused(xr, xi, far, fai, wr, wi, fbr, fbi, interpret=True)
    t1r, t1i = fourstep_stage1(xr, xi, far, fai, wr, wi, block_b=32, interpret=True)
    sr, si = fourstep_stage2(t1r, t1i, fbr, fbi, block_a=8, interpret=True)
    np.testing.assert_allclose(np.asarray(sr), np.asarray(fr), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(si), np.asarray(fi2), rtol=1e-5, atol=1e-5)


def test_split_factor():
    assert split_factor(4096) == (64, 64)
    assert split_factor(2048) == (32, 64)
    assert split_factor(384) in [(16, 24), (12, 32)] or np.prod(split_factor(384)) == 384
    a, b = split_factor(1)
    assert a * b == 1


def test_fourstep_1d_input_promotion():
    x = _randc((256,), seed=3)
    got = fft_fourstep(x, interpret=True)
    assert got.shape == (256,)
    want = np.fft.fft(np.asarray(x, dtype=np.complex128))
    assert _relerr(got, want) < RTOL


# ---------------------------------------------------------------- cmatmul
@pytest.mark.parametrize("m,k,ell", [(8, 4, 64), (16, 16, 512), (4, 4, 1000), (64, 32, 2048)])
def test_cmatmul_sweep(m, k, ell):
    a = _randc((m, k), seed=m)
    b = _randc((k, ell), seed=ell)
    ar, ai = ref.planar(a)
    br, bi = ref.planar(b)
    cr, ci = cmatmul(ar, ai, br, bi, interpret=True)
    wr, wi = ref.cmatmul_ref(ar, ai, br, bi)
    np.testing.assert_allclose(np.asarray(cr), np.asarray(wr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ci), np.asarray(wi), rtol=1e-4, atol=1e-4)


def test_mds_apply_matches_core_encode():
    g = mds.rs_generator(8, 4, jnp.complex64)
    c = _randc((4, 32, 8), seed=5)  # payload with extra dims
    got = mds_apply(g, c, interpret=True)
    want = mds.encode(g, c)
    assert _relerr(got, want) < RTOL


# ---------------------------------------------------------------- recombine
@pytest.mark.parametrize("m,ell", [(2, 64), (4, 256), (8, 1024), (16, 128)])
def test_recombine_kernel_sweep(m, ell):
    s = m * ell
    c_hat = _randc((m, ell), seed=s)
    got = recombine_fused(c_hat, s, interpret=True)
    from repro.core import recombine as core_recombine

    want = core_recombine(c_hat.astype(jnp.complex128), s)
    assert _relerr(got, want) < RTOL


# ------------------------------------------------------- end-to-end kernel path
def test_coded_fft_with_kernel_worker():
    """Full coded-FFT pipeline with the Pallas worker FFT plugged in."""
    s, m, n = 4096, 4, 6
    x = _randc((s,), seed=11)
    strat = CodedFFT(
        s=s, m=m, n_workers=n, dtype=jnp.complex64,
        worker_fn=make_kernel_worker_fn(interpret=True),
    )
    b = strat.worker_compute(strat.encode(x))
    got = strat.decode(b, subset=jnp.asarray([5, 1, 3, 0]))
    want = np.fft.fft(np.asarray(x, dtype=np.complex128))
    assert _relerr(got, want) < 5e-4


@settings(max_examples=10, deadline=None)
@given(
    log_ell=st.integers(6, 12),
    batch=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_fourstep_random(log_ell, batch, seed):
    ell = 2**log_ell
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.normal(size=(batch, ell)) + 1j * rng.normal(size=(batch, ell)),
        dtype=jnp.complex64,
    )
    got = fft_fourstep(x, interpret=True)
    want = np.fft.fft(np.asarray(x, dtype=np.complex128), axis=-1)
    assert _relerr(got, want) < RTOL
