"""Device-resident Lagrange decode + async bucket pipeline (DESIGN.md §8).

Covers the structured decode stack end to end: closed-form
``mds.lagrange_inverse`` parity against the host ``linalg.inv`` over
adversarial byte-pattern masks at m in {4, 16, 64}, the
``m > LAGRANGE_MAX_M`` host-LRU fallback boundary (pinned by jaxpr
inspection: in-trace weight construction present on one side, absent on
the other), the pipelined service scheduler (mixed kinds in one call, one
device->host transfer per submit_batch, dispatch/sync stats split), and
the wire-scaled straggler arrivals of the real kinds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mds
from repro.distributed.straggler import StragglerModel
from repro.kernels import ops
from repro.serving import FFTService, FFTServiceConfig
from repro.serving.decode_cache import DecodeMatrixCache

pytestmark = pytest.mark.kernels


def _adversarial_masks(n: int, m: int) -> np.ndarray:
    """Byte-pattern adversarial mask set for an (n, m) code.

    Stresses the KEYING/PLUMBING corners, not just numerics: masks equal
    as first-m subsets but different as byte patterns (aliasing tails),
    block stragglers at head and tail, alternating and rotated spreads,
    and random >= m-alive draws.
    """
    rng = np.random.default_rng(0)
    masks = [np.ones(n, bool)]                       # everyone responded
    first = np.zeros(n, bool)
    first[:m] = True
    masks.append(first)                              # exactly the first m
    tail = first.copy()
    tail[-1] = True
    masks.append(tail)                               # same subset, new bytes
    masks.append(~first if (~first).sum() >= m
                 else np.ones(n, bool))              # head block straggles
    alt = np.arange(n) % 2 == 0
    masks.append(alt)                                # alternating spread
    masks.append(np.roll(alt, 1))                    # ... rotated
    for _ in range(2):                               # random >= m alive
        r = rng.random(n) < 0.75
        while r.sum() < m:
            r[rng.integers(n)] = True
        masks.append(r)
    for _ in range(2):                               # spread w/ random swaps
        r = alt.copy()                               # (stays conditioned at
        sw = rng.integers(0, n // 2, size=max(2, n // 16))  # any m)
        r[2 * sw] = False
        r[2 * sw + 1] = True
        while r.sum() < m:
            r[rng.integers(n)] = True
        masks.append(r)
    return np.stack(masks)


# --------------------------------------------------- closed-form inversion
@pytest.mark.parametrize("m", [4, 16, 64])
def test_lagrange_inverse_matches_host_inverse(m):
    """``lagrange_inverse`` == ``np.linalg.inv`` of the subset generator to
    within the subset's own interpolation conditioning, for every
    adversarial byte pattern.  Subsets whose conditioning exceeds what
    float64 itself can carry are excluded -- BOTH implementations return
    conditioning-limited garbage there, which is exactly why
    ``LAGRANGE_MAX_M`` (and the m=64 host fallback) exists.
    """
    n = 2 * m
    g = np.asarray(mds.rs_generator(n, m, jnp.complex128))
    checked = 0
    for mask in _adversarial_masks(n, m):
        subset = DecodeMatrixCache.subset_of(mask, m)
        v = g[subset]
        cond = np.linalg.cond(v)
        if cond > 1e12:
            continue
        want = np.linalg.inv(v)
        got = np.asarray(mds.lagrange_inverse(
            jnp.asarray(subset), n, jnp.complex128))
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < max(1e-9, cond * 1e-12), (m, cond, rel)
        checked += 1
    assert checked >= 4  # spread/random patterns stay well-conditioned


def test_lagrange_decode_matrices_match_cache_exhaustively():
    """Scatter matrices from the device path == the host LRU's, for EVERY
    decodable mask of the (8, 4) service-default code (163 patterns)."""
    n, m = 8, 4
    g = np.asarray(mds.rs_generator(n, m, jnp.complex128))
    cache = DecodeMatrixCache(g, maxsize=256)
    masks = np.stack([
        np.array([(k >> i) & 1 for i in range(n)], bool)
        for k in range(2 ** n)
        if bin(k).count("1") >= m])
    want = cache.matrices(masks)                      # complex64 host path
    got = np.asarray(mds.lagrange_decode_matrices(
        jnp.asarray(masks), m, jnp.complex128))
    assert np.abs(got - want).max() < 1e-5
    # and the f32-plane form the kernels consume agrees
    subsets = ops.mask_subsets(jnp.asarray(masks), m)
    dr, di = ops.lagrange_scatter_planes(subsets, n)
    planes = np.asarray(dr) + 1j * np.asarray(di)
    assert np.abs(planes - want).max() < 1e-4


def test_lagrange_inverse_jit_vmap_composable():
    """The construction must be jit/vmap-safe (it runs inside the bucket
    executor): one fused trace over a batch of masks, no host callbacks."""
    n, m = 8, 4
    masks = jnp.asarray(_adversarial_masks(n, m))

    @jax.jit
    def build(mk):
        return mds.lagrange_decode_matrices(mk, m)

    d = build(masks)
    assert d.shape == (masks.shape[0], m, n)
    g = np.asarray(mds.rs_generator(n, m, jnp.complex64))
    # D @ G == I on every request: the defining decode property
    eye = np.asarray(d) @ g
    assert np.abs(eye - np.eye(m)[None]).max() < 1e-4


# -------------------------------------------- masked Pallas bucket kernels
@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (768, 4, 6), (96, 3, 7)])
def test_coded_bucket_masked_kernel_parity(s, m, n):
    """The masked whole-bucket kernel (decode matrices built IN the kernel
    body from responder subsets) == numpy.fft through the real Pallas
    machinery (interpret=True) AND the direct body -- guards the 15-input
    BlockSpec wiring the CPU service path never executes."""
    from repro.kernels import ref

    g = mds.rs_generator(n, m, jnp.complex64)
    gr, gi = ref.planar(g)
    masks = _adversarial_masks(n, m)[:5]
    rng = np.random.default_rng(s + m)
    xb = (rng.normal(size=(len(masks), s))
          + 1j * rng.normal(size=(len(masks), s))).astype(np.complex64)
    xr, xi = ref.planar(jnp.asarray(xb))
    want = np.fft.fft(xb.astype(np.complex128), axis=-1)
    for itp in (True, None):
        yr, yi = ops.coded_bucket_masked(xr, xi, jnp.asarray(masks), gr, gi,
                                         s, interpret=itp)
        got = np.asarray(ref.unplanar(yr, yi))
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < 3e-4, (itp, rel)


@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (768, 4, 6)])
def test_coded_rbucket_masked_kernel_parity(s, m, n):
    """r2c twin of the masked-kernel parity pin: real requests -> half
    spectra with in-VMEM Lagrange weights, interpret + direct modes."""
    from repro.kernels import ref

    g = mds.rs_generator(n, m, jnp.complex64)
    gr, gi = ref.planar(g)
    masks = _adversarial_masks(n, m)[:5]
    rng = np.random.default_rng(s * m)
    xb = rng.normal(size=(len(masks), s)).astype(np.float32)
    want = np.fft.rfft(xb.astype(np.float64), axis=-1)
    for itp in (True, None):
        yr, yi = ops.coded_rbucket_masked(jnp.asarray(xb), jnp.asarray(masks),
                                          gr, gi, s, interpret=itp)
        got = np.asarray(ref.unplanar(yr, yi))
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < 3e-4, (itp, rel)


# ------------------------------------------------- fallback boundary (§8)
def _runner_jaxpr(svc: FFTService, bucket: int = 2) -> str:
    """The jaxpr of the service's compiled bucket executor at its default
    (s, c2c) key, traced over the exact argument layout the scheduler
    feeds it."""
    cfg = svc.cfg
    runner = svc._runner_for(cfg.s, bucket, "c2c")
    xb = svc._bucket_buffer(cfg.s, bucket, "c2c")
    masks = np.ones((bucket, cfg.n_workers), bool)
    args = svc._bucket_args(cfg.s, "c2c", xb, masks)
    return str(jax.make_jaxpr(lambda *a: runner(*a))(*args))


def test_device_decode_below_boundary_builds_weights_in_trace():
    """m == LAGRANGE_MAX_M must run the device path: the executor takes the
    raw masks and its jaxpr contains the in-trace weight construction
    (trig node powers + the responder argsort) -- and the service never
    touches the host LRU."""
    m = mds.LAGRANGE_MAX_M
    svc = FFTService(FFTServiceConfig(s=64 * m, m=m, n_workers=2 * m))
    assert svc._device_decode()
    jaxpr = _runner_jaxpr(svc)
    assert "cos" in jaxpr and "sort" in jaxpr
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=64 * m).astype(np.complex64))
    svc.submit(x)
    assert not svc._decode_caches  # no capacity ever instantiated a host LRU
    assert svc.stats.decode_cache_misses == 0


def test_above_boundary_falls_back_to_host_lru():
    """m > LAGRANGE_MAX_M flips to the host complex128 LRU: the executor
    jaxpr carries NO in-trace weight construction (matrices arrive as
    inputs), and novel masks pay host inversions (cache misses)."""
    m = 64
    assert m > mds.LAGRANGE_MAX_M
    svc = FFTService(FFTServiceConfig(s=32 * m, m=m, n_workers=2 * m))
    assert not svc._device_decode()
    jaxpr = _runner_jaxpr(svc)
    assert "cos" not in jaxpr
    x = jnp.asarray(np.random.default_rng(0)
                    .normal(size=32 * m).astype(np.complex64))
    svc.submit(x)
    assert svc.stats.decode_cache_misses > 0


def test_device_and_host_paths_serve_identical_results():
    """Same seed (hence same simulated straggler masks): the device-decode
    service and the host-LRU fallback service must agree request for
    request -- and both must match numpy."""
    rng = np.random.default_rng(7)
    xs = [jnp.asarray((rng.normal(size=512) + 1j * rng.normal(size=512))
                      .astype(np.complex64)) for _ in range(9)]
    common = dict(s=512, m=4, n_workers=8, seed=21)
    dev = FFTService(FFTServiceConfig(**common))
    host = FFTService(FFTServiceConfig(**common, device_decode=False))
    out_d = dev.submit_batch(xs)
    out_h = host.submit_batch(xs)
    for x, yd, yh in zip(xs, out_d, out_h):
        want = np.fft.fft(np.asarray(x, np.complex128))
        assert np.abs(yd - want).max() < 1e-2
        assert np.abs(yd - yh).max() < 1e-3
    assert dev.stats.decode_cache_misses == 0
    assert host.stats.decode_cache_misses > 0


# ----------------------------------------------- async pipelined scheduler
def test_one_host_transfer_per_submit_batch():
    """The pipelined scheduler syncs ONCE per submit_batch call, however
    many (s, kind) buckets the call spans, and accounts dispatch vs sync
    wall time separately."""
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=2,
                                      max_batch=4))
    rng = np.random.default_rng(3)
    xs = [jnp.asarray((rng.normal(size=s) + 1j * rng.normal(size=s))
                      .astype(np.complex64))
          for s in (256, 256, 256, 256, 256, 128, 128)]
    svc.submit_batch(xs)                  # 2 s=256 buckets + 1 s=128 bucket
    st = svc.stats.summary()
    assert st["batches"] == 3
    assert st["host_transfers"] == 1
    assert st["dispatch_s"] > 0.0 and st["sync_s"] > 0.0
    svc.submit_batch(xs[:2])
    assert svc.stats.host_transfers == 2


def test_mixed_kinds_bucket_in_one_call():
    """submit_batch accepts per-request kinds: one call carrying c2c + r2c
    + c2r traffic buckets by (s, kind) and returns every result in
    submission order."""
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=5))
    rng = np.random.default_rng(4)
    xc = [jnp.asarray((rng.normal(size=256) + 1j * rng.normal(size=256))
                      .astype(np.complex64)) for _ in range(2)]
    xr = [jnp.asarray(rng.normal(size=256).astype(np.float32))
          for _ in range(2)]
    yh = [jnp.asarray(np.fft.rfft(np.asarray(x)).astype(np.complex64))
          for x in xr]
    reqs = [xc[0], xr[0], yh[0], xc[1], xr[1], yh[1]]
    kinds = ["c2c", "r2c", "c2r"] * 2
    outs = svc.submit_batch(reqs, kind=kinds)
    for i, x in enumerate(xc):
        assert np.abs(outs[3 * i] - np.fft.fft(np.asarray(x))).max() < 1e-2
    for i, x in enumerate(xr):
        assert np.abs(outs[3 * i + 1]
                      - np.fft.rfft(np.asarray(x))).max() < 1e-2
        assert np.abs(outs[3 * i + 2] - np.asarray(x)).max() < 1e-2
    assert svc.stats.batches == 3          # one bucket per kind
    assert svc.stats.host_transfers == 1   # still one sync
    with pytest.raises(ValueError):
        svc.submit_batch(reqs, kind=["c2c"])           # length mismatch
    with pytest.raises(ValueError):
        svc.submit_batch(reqs[:1], kind=["c2x"])       # unknown kind


def test_warmup_keys_executables_once():
    """After warmup, steady-state traffic adds no new executables (and no
    compiles) for the covered (s, kind, bucket) keys."""
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=1,
                                      max_batch=8))
    compiled = svc.warmup()
    assert compiled == 4                   # buckets 1, 2, 4, 8
    n_runners = len(svc._runners)
    rng = np.random.default_rng(6)
    for batch in (1, 3, 8):
        xs = [jnp.asarray((rng.normal(size=256) + 1j
                           * rng.normal(size=256)).astype(np.complex64))
              for _ in range(batch)]
        svc.submit_batch(xs)
    assert len(svc._runners) == n_runners


# --------------------------------------------- wire-scaled straggler model
def test_wire_frac_scales_only_the_wire_share():
    model = StragglerModel(t0=2.0, mu=1.0, wire_frac=0.5)
    rng = np.random.default_rng(0)
    full = model.sample((20000,), 1.0, rng, payload_scale=1.0)
    rng = np.random.default_rng(0)
    half = model.sample((20000,), 1.0, rng, payload_scale=0.5)
    # same tail draws, deterministic part shrinks by wire_frac * (1-scale)
    np.testing.assert_allclose(full - half, 2.0 * 0.5 * 0.5, atol=1e-12)
    # payload_scale=1 reduces to the literature model whatever wire_frac is
    assert model.expected_kth(8, 4, 1.0) == pytest.approx(
        StragglerModel(t0=2.0, mu=1.0, wire_frac=0.0).expected_kth(8, 4, 1.0))
    assert (model.expected_kth(8, 4, 1.0, payload_scale=0.5)
            < model.expected_kth(8, 4, 1.0))


def test_service_charges_real_kinds_half_wire_time():
    """r2c/c2r buckets simulate arrivals at payload_scale=0.5: with a
    wire-heavy model their coded latency must run measurably below c2c's
    on the same seed."""
    model = StragglerModel(t0=1.0, mu=4.0, wire_frac=0.8)
    mk = lambda: FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8, straggler=model, seed=17))
    lat_c, _ = mk()._simulate_arrivals(4000, "c2c")
    lat_r, _ = mk()._simulate_arrivals(4000, "r2c")
    lat_i, _ = mk()._simulate_arrivals(4000, "c2r")
    assert lat_r.mean() < lat_c.mean()
    assert lat_i.mean() < lat_c.mean()
    # exactly the wire share: same rng stream, deterministic offset
    np.testing.assert_allclose(
        (lat_c - lat_r).mean(), (1.0 / 4) * 1.0 * 0.8 * 0.5, atol=1e-9)
