"""The documented real-kind ``2m | s`` ValueError, per kind and entry point.

Every real kind (r2c, c2r, rfftn, irfftn) pair-packs its interleave
shards along the halved axis, so the shard length there must be even.
The contract (README "supported kinds", DESIGN.md §9): an odd-shard
config raises a ``ValueError`` whose message contains the literal
constraint string ``"2m | s"`` -- at PLAN construction, at the kernel
packing op, and from the SERVICE entry points -- never an opaque reshape
error deeper in the pipeline.

The irfftn service entry is the one place the error is unreachable BY
CONSTRUCTION: a c2r bucket's last axis is ``2*(h-1)`` (always even) and
``plan_factors(..., even_last_shard=True)`` only returns factors with
``2*f | s`` -- so that entry gets a structural-guarantee test instead.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodedIRFFT, CodedIRFFTN, CodedRFFT, CodedRFFTN
from repro.core.coded_fft import plan_factors
from repro.core.rfft import require_even_shards
from repro.kernels import ops
from repro.serving.fft_service import FFTService, FFTServiceConfig

# s = 18, m = 2: m | s holds (shards of 9) but 2m = 4 does not -- the
# exact gap the named check exists for (a plain m | s validation would
# accept it).  m must be EVEN to exhibit the gap at an even s, which the
# c2r entry point needs (its bucket length 2*(h-1) is always even).
ODD_S, ODD_M = 18, 2


def test_require_even_shards_is_the_named_contract():
    require_even_shards(24, 3)           # 2m | s: fine
    assert ODD_S % ODD_M == 0            # the gap: m | s ...
    assert ODD_S % (2 * ODD_M) != 0      # ... but 2m does not
    with pytest.raises(ValueError, match=r"2m \| s"):
        require_even_shards(ODD_S, ODD_M)
    with pytest.raises(ValueError, match=r"axis 1"):
        require_even_shards(ODD_S, ODD_M, axis=1)
    with pytest.raises(ValueError, match=r"2m \| s"):
        require_even_shards(0, 1)        # s must be positive too


@pytest.mark.parametrize("cls", [CodedRFFT, CodedIRFFT])
def test_1d_real_plans_raise_named_error(cls):
    with pytest.raises(ValueError, match=r"2m \| s"):
        cls(s=ODD_S, m=ODD_M, n_workers=6)


@pytest.mark.parametrize("cls", [CodedRFFTN, CodedIRFFTN])
def test_nd_real_plans_raise_named_error(cls):
    # the halved (last) axis carries the odd shard: 18 / 2 = 9
    with pytest.raises(ValueError, match=r"2m \| s"):
        cls(shape=(4, ODD_S), factors=(1, ODD_M), n_workers=6)


def test_plan_factors_even_last_requires_even_axis():
    # even_last_shard placement serves any shape with a valid real-kind
    # factorization -- but an ODD last axis can never pack
    with pytest.raises(ValueError, match=r"2m \| s"):
        plan_factors((4, 27), 3, even_last_shard=True)


def test_kernel_pack_real_planes_raises_named_error():
    xb = jnp.zeros((2, ODD_S), jnp.float32)
    with pytest.raises(ValueError, match=r"2m \| s"):
        ops.pack_real_planes(xb, ODD_M)


@pytest.mark.parametrize("kind", ["r2c", "c2r", "rfftn"])
def test_service_submit_raises_named_error(kind):
    """Each reachable real-kind service entry point surfaces the
    constraint (the bucket plan construction runs inside submit)."""
    svc = FFTService(FFTServiceConfig(s=48, m=ODD_M, n_workers=6,
                                      use_reference=True))
    if kind == "r2c":
        bad = np.zeros(ODD_S, np.float32)
        call = lambda: svc.submit_rfft(bad)
    elif kind == "c2r":
        # a c2r request of h bins lands in the s = 2*(h-1) bucket;
        # h = 10 -> s = 18, odd shards at m = 2
        bad = np.zeros(ODD_S // 2 + 1, np.complex64)
        call = lambda: svc.submit_irfft(bad)
    else:
        # odd LAST axis: no even_last_shard placement can exist
        bad = np.zeros((4, 27), np.float32)
        call = lambda: svc.submit_rfftn(bad)
    with pytest.raises(ValueError, match=r"2m \| s"):
        call()


def test_irfftn_entry_is_structurally_even():
    """The irfftn bucket's last axis is 2*(h-1) -- always even -- and
    even_last_shard factor placement guarantees ``2*f | s``: the shape
    whose LAST axis would trap a naive placement (18 = 2*9, so the
    factor 2 must land on axis 0) still serves, matching numpy."""
    svc = FFTService(FFTServiceConfig(s=48, m=ODD_M, n_workers=6,
                                      use_reference=True))
    assert plan_factors((4, ODD_S), ODD_M, even_last_shard=True) == (2, 1)
    rng = np.random.default_rng(7)
    t = rng.standard_normal((4, ODD_S)).astype(np.float32)
    yn = np.fft.rfftn(t).astype(np.complex64)
    np.testing.assert_allclose(svc.submit_irfftn(yn),
                               np.fft.irfftn(yn, s=(4, ODD_S), axes=(0, 1)),
                               rtol=2e-3, atol=2e-3)


def test_even_config_still_serves_every_real_kind():
    """The guard rejects exactly the odd-shard configs: the even twin of
    the same (s, m) serves all four kinds."""
    svc = FFTService(FFTServiceConfig(s=48, m=ODD_M, n_workers=6,
                                      use_reference=True))
    rng = np.random.default_rng(0)
    x = rng.standard_normal(48).astype(np.float32)
    np.testing.assert_allclose(svc.submit_rfft(x), np.fft.rfft(x),
                               rtol=2e-4, atol=2e-4)
    y = np.fft.rfft(x).astype(np.complex64)
    np.testing.assert_allclose(svc.submit_irfft(y), np.fft.irfft(y, n=48),
                               rtol=2e-4, atol=2e-4)
    t = rng.standard_normal((4, 48)).astype(np.float32)
    np.testing.assert_allclose(svc.submit_rfftn(t), np.fft.rfftn(t),
                               rtol=2e-3, atol=2e-3)
    yn = np.fft.rfftn(t).astype(np.complex64)
    np.testing.assert_allclose(svc.submit_irfftn(yn),
                               np.fft.irfftn(yn, s=(4, 48), axes=(0, 1)),
                               rtol=2e-3, atol=2e-3)
