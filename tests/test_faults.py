"""Fault-injected worker runtime (DESIGN.md §12): seeded fault plans,
health tracking + deadline-derived masks, retry/degraded decode with typed
reasons, Byzantine verification in the service path, elastic membership,
and the measured thread-per-worker runtime."""

import numpy as np
import pytest

from repro.core import mds
from repro.core.coded_fft import CodedFFT
from repro.core.fault_tolerance import correct_errors, robust_decode
from repro.distributed import (
    ElasticWorkerPool,
    FaultInjector,
    FaultPlan,
    MeasuredWorkerRuntime,
    StragglerModel,
    WorkerHealthTracker,
)
from repro.serving import (
    FAILURE_REASONS,
    DegradedResult,
    FFTService,
    FFTServiceConfig,
    ServiceError,
)

import jax.numpy as jnp


def _cfg(**kw):
    kw.setdefault("s", 256)
    kw.setdefault("m", 4)
    kw.setdefault("n_workers", 8)
    kw.setdefault("seed", 0)
    kw.setdefault("autotune", False)
    return FFTServiceConfig(**kw)


def _x(s=256, seed=0, dtype=np.complex64):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=s) + 1j * rng.normal(size=s)).astype(dtype)


# A near-deterministic straggler model: every worker completes in ~t0 *
# workload, so deadline-derived masks admit the whole fleet and k > m
# surplus (the Byzantine verifier's precondition) holds by construction.
_TIGHT = StragglerModel(t0=1.0, mu=1e6)


# ------------------------------------------------------------- fault plans
def test_fault_plan_builders_and_projection():
    plan = (FaultPlan(seed=5)
            .kill(0, start_round=2, rounds=3)
            .delay(3, 0.25, rounds=2)
            .corrupt(1, start_round=1, rounds=10))
    r0 = plan.faults_for(0)
    assert r0.killed == frozenset() and dict(r0.delays) == {3: 0.25}
    r2 = plan.faults_for(2)
    assert r2.killed == {0} and r2.corrupt == {1} and not r2.delays
    assert plan.faults_for(99).any is False
    assert plan.horizon == 11
    # immutability: builders return NEW plans
    assert len(FaultPlan().faults) == 0


def test_fault_plan_random_is_seeded_and_rate_scaled():
    a = FaultPlan.random(8, 1 / 8, horizon=64, seed=3)
    b = FaultPlan.random(8, 1 / 8, horizon=64, seed=3)
    assert a == b                               # bit-identical schedules
    assert FaultPlan.random(8, 0.0, seed=1).faults == ()
    dense = FaultPlan.random(8, 1.0, horizon=4, kinds=("kill",), seed=0)
    assert len(dense.faults) == 32              # every (round, worker) hit
    # rate=1/N means ~one faulty worker per round on average
    avg = len(a.faults) / 64
    assert 0.3 <= avg <= 2.5


def test_injector_corruption_is_seeded_and_axis_aware():
    inj = FaultInjector(FaultPlan(seed=9).corrupt(2))
    b = (np.arange(2 * 8 * 4) + 1j).reshape(2, 8, 4).astype(np.complex128)
    c1 = inj.corrupt_array(b, [2], 0, worker_axis=1)
    c2 = inj.corrupt_array(b, [2], 0, worker_axis=1)
    np.testing.assert_array_equal(c1, c2)       # keyed by (seed, round, w)
    c3 = inj.corrupt_array(b, [2], 1, worker_axis=1)
    assert not np.array_equal(c1[:, 2], c3[:, 2])   # distinct per round
    # only the targeted worker row changes, and changes BIG (Byzantine,
    # not noise)
    clean = np.delete(c1, 2, axis=1)
    np.testing.assert_array_equal(clean, np.delete(b, 2, axis=1))
    assert np.abs(c1[:, 2] - b[:, 2]).max() > np.abs(b).max()
    # the caller's buffer is never corrupted in place
    assert b[0, 2, 0] == np.arange(2 * 8 * 4).reshape(2, 8, 4)[0, 2, 0] + 1j


def test_injector_latency_perturbation():
    inj = FaultInjector(FaultPlan().kill(1).delay(4, 0.5))
    lat = np.full((3, 8), 1.0)
    out = inj.perturb_latencies(lat, 0)
    assert np.isinf(out[:, 1]).all()
    np.testing.assert_allclose(out[:, 4], 1.5)
    np.testing.assert_allclose(out[:, 0], 1.0)
    # no active faults -> identity (same object allowed)
    np.testing.assert_array_equal(inj.perturb_latencies(lat, 50), lat)


# ------------------------------------------------------- health + deadlines
def test_health_tracker_deadline_and_dead_worker_estimates():
    h = WorkerHealthTracker(4, slack_frac=0.5)
    h.observe_round([0.1, 0.2, 0.3, np.inf])
    h.observe_round([0.1, 0.2, 0.3, np.inf])
    est = h.estimates()
    np.testing.assert_allclose(est[:3], [0.1, 0.2, 0.3])
    # a slot that has only ever missed must NOT keep the fast prior: it
    # would drag the deadline below what live workers can meet
    assert np.isinf(est[3])
    assert h.deadline(2) == pytest.approx(0.2 * 1.5)
    assert h.deadline(4) == np.inf              # 4th fastest is the dead one
    assert np.isinf(h.deadline(2, alive=np.array([True, False, False, False])))
    mask = h.mask_from_times(np.array([0.1, 0.4, np.inf, np.nan]), 0.31)
    np.testing.assert_array_equal(mask, [True, False, False, False])


def test_health_tracker_calibration_recovers_straggler_model():
    true = StragglerModel(t0=0.8, mu=2.5)
    rng = np.random.default_rng(0)
    h = WorkerHealthTracker(8)
    w = 0.25
    for _ in range(400):
        h.observe_round(true.sample(8, w, rng))
    fit = h.calibrate(workload=w)
    assert fit.t0 == pytest.approx(true.t0, rel=0.05)
    assert fit.mu == pytest.approx(true.mu, rel=0.2)
    with pytest.raises(ValueError):
        WorkerHealthTracker(2).calibrate()


def test_health_tracker_byzantine_flags_and_grow():
    h = WorkerHealthTracker(4)
    h.observe_round([0.1, 0.2, 0.3, 0.4])
    h.flag_byzantine(2)
    assert h.byzantine.tolist() == [False, False, True, False]
    h.grow(6)
    assert h.n_workers == 6 and h.byzantine.shape == (6,)
    np.testing.assert_allclose(h.estimates()[:4], [0.1, 0.2, 0.3, 0.4])
    h.clear_byzantine(2)
    assert not h.byzantine.any()


# ---------------------------------------------------- robust decode satellite
def test_correct_errors_returns_indices_single_prony_pass():
    plan = CodedFFT(s=64, m=4, n_workers=8, dtype=np.complex128,
                    backend="reference")
    x = _x(64, 3, np.complex128)
    b = np.asarray(plan.worker_compute(plan.encode(jnp.asarray(x))),
                   np.complex128)
    nodes = np.asarray(mds.rs_nodes(8, jnp.complex128))
    bad = b.copy()
    bad[5] += 11.0 - 3j
    out = correct_errors(nodes, bad, 4)
    assert out is not None
    corrected, idx = out
    assert idx.tolist() == [5]
    np.testing.assert_allclose(corrected, b, atol=1e-8)
    # clean rows: empty index vector, rows returned as-is
    _, idx0 = correct_errors(nodes, b, 4)
    assert idx0.shape == (0,)


def test_robust_decode_nd_shards_and_bit_consistency():
    """robust_decode accepts (N, *shard) rows and its corrected output is
    BIT-IDENTICAL to the clean decode over the same clean subset (the
    corrupted rows never enter the final decode)."""
    plan = CodedFFT(s=64, m=4, n_workers=8, dtype=np.complex128,
                    backend="reference")
    x = _x(64, 4, np.complex128)
    b = np.asarray(plan.worker_compute(plan.encode(jnp.asarray(x))),
                   np.complex128)
    inj = FaultInjector(FaultPlan(seed=1).corrupt(1).corrupt(6))
    bad = inj.corrupt_array(b[None], [1, 6], 0, worker_axis=1)[0]
    recv = np.arange(8)                         # k=8: correct up to 2
    res = robust_decode(plan, bad, recv)
    assert res.ok and res.n_errors_corrected == 2
    assert sorted(res.error_worker_indices.tolist()) == [1, 6]
    clean_subset = jnp.asarray([0, 2, 3, 4])    # first m clean rows
    want = np.asarray(plan.decode(jnp.asarray(b), subset=clean_subset))
    np.testing.assert_array_equal(res.output, want)   # bitwise
    # 3 corrupt > floor((8-4)/2): uncorrectable, typed not-ok
    bad3 = inj.corrupt_array(b[None], [1, 3, 6], 0, worker_axis=1)[0]
    bad3[3] += 17.0
    assert not robust_decode(plan, bad3, recv).ok


# ------------------------------------------------------- service fault path
def test_service_deadline_masks_serve_correctly_without_faults():
    svc = FFTService(_cfg(health=True))
    x = _x()
    for seed in range(4):
        xi = _x(seed=seed)
        y = svc.submit(xi)
        assert np.abs(y - np.fft.fft(xi)).max() < 1e-2
    assert svc.stats.requests == 4 and svc.stats.degraded == 0
    assert svc.health.rounds == 4
    # measured-timings calibration is reachable from the service tracker
    fit = svc.health.calibrate(workload=1 / 4)
    assert fit.t0 > 0 and fit.mu > 0


def test_service_kill_faults_recover_with_retry_and_redispatch():
    plan = FaultPlan().kill(0, rounds=999).kill(1, rounds=999)
    svc = FFTService(_cfg(faults=plan, on_failure="degrade"))
    for seed in range(10):
        xi = _x(seed=seed)
        y = svc.submit(xi)
        assert isinstance(y, np.ndarray)
        assert np.abs(y - np.fft.fft(xi)).max() < 1e-2
    assert svc.stats.degraded == 0
    s = svc.stats.summary()
    assert s["retries"] >= 0 and s["redispatched_shards"] >= 0


def test_service_insufficient_workers_typed_error_and_degrade():
    pool = ElasticWorkerPool(8, 4)
    for w in range(5):
        pool.leave(w)
    svc = FFTService(_cfg(on_failure="degrade"), pool=pool)
    r = svc.submit(_x())
    assert isinstance(r, DegradedResult)
    assert r.reason == "insufficient_workers" and not r.ok
    assert svc.stats.degraded == 1
    # on_failure="raise" surfaces the same reason as an exception
    svc2 = FFTService(_cfg(), pool=pool)
    with pytest.raises(ServiceError) as ei:
        svc2.submit(_x())
    assert ei.value.reason == "insufficient_workers"
    assert ei.value.reason in FAILURE_REASONS


def test_service_retries_exhausted_typed_error():
    plan = FaultPlan()
    for w in range(5):
        plan = plan.kill(w, rounds=999)
    svc = FFTService(_cfg(faults=plan, max_retries=0, on_failure="degrade"))
    r = svc.submit(_x())
    assert isinstance(r, DegradedResult) and r.reason == "retries_exhausted"


def test_service_verify_detect_catches_corruption():
    plan = FaultPlan(seed=2).corrupt(3, rounds=999)
    svc = FFTService(_cfg(straggler=_TIGHT, faults=plan, verify="detect",
                          on_failure="degrade"))
    r = svc.submit(_x())
    assert isinstance(r, DegradedResult)
    assert r.reason == "corrupt_uncorrectable"
    assert svc.stats.detected >= 1 and svc.stats.corrected == 0


def test_service_verify_off_corruption_poisons_output():
    """The negative control: without verification a Byzantine worker's
    rows reach the decode and the output is visibly wrong."""
    plan = FaultPlan(seed=2).corrupt(0, rounds=999)   # worker 0: always in
    #                                                   the first-m subset
    svc = FFTService(_cfg(straggler=_TIGHT, faults=plan, verify="off",
                          on_failure="degrade", dtype=np.complex128,
                          use_reference=True))
    x = _x(dtype=np.complex128)
    y = svc.submit(x)
    assert np.abs(y - np.fft.fft(x)).max() > 1.0


def test_service_verify_correct_bit_consistent_at_capacity():
    """verify="correct" recovers the transform with floor((k - m)/2) = 2
    corrupt workers out of k = 8 responders, over ADVERSARIAL patterns:
    the corrupt pair rotates every round.  (Bit-consistency with the
    same-subset clean decode is asserted at the robust_decode level.)"""
    plan = FaultPlan(seed=4)
    pairs = [(0, 1), (2, 5), (6, 7), (3, 4)]
    for r, (a, b) in enumerate(pairs):
        plan = plan.corrupt(a, start_round=r).corrupt(b, start_round=r)
    svc = FFTService(_cfg(straggler=_TIGHT, faults=plan, verify="correct",
                          dtype=np.complex128, use_reference=True))
    for r in range(len(pairs)):
        x = _x(seed=10 + r, dtype=np.complex128)
        y = svc.submit(x)
        np.testing.assert_allclose(y, np.fft.fft(x), atol=1e-8)
    assert svc.stats.corrected == 2 * len(pairs)
    assert svc.stats.detected == svc.stats.corrected
    assert svc.stats.degraded == 0
    # offenders are flagged into the health tracker
    assert set(svc.health.summary()["byzantine"]) == {0, 1, 2, 3, 4, 5, 6, 7}


def test_service_verify_correct_overwhelmed_fails_typed():
    plan = FaultPlan(seed=6)
    for w in (1, 4, 7):                          # 3 > floor((8-4)/2)
        plan = plan.corrupt(w, rounds=999)
    svc = FFTService(_cfg(straggler=_TIGHT, faults=plan, verify="correct",
                          on_failure="degrade", dtype=np.complex128,
                          use_reference=True))
    r = svc.submit(_x(dtype=np.complex128))
    assert isinstance(r, DegradedResult)
    assert r.reason == "corrupt_uncorrectable"


# ----------------------------------------------------------- elastic pool
def test_elastic_pool_membership_invariants():
    pool = ElasticWorkerPool(8, m=4)
    assert pool.capacity == 8 and pool.n_live == 8 and pool.can_decode()
    pool.leave(3)
    pool.leave(3)                                # idempotent
    assert pool.n_live == 7 and pool.version == 1
    assert not pool.is_live(3) and pool.capacity == 8
    # join refills the LOWEST departed slot: same RS node, same capacity
    pool.leave(1)
    assert pool.join() == 1
    assert pool.capacity == 8
    # no departed slot left after refilling 3: join GROWS the code
    assert pool.join() == 3
    assert pool.join() == 8 and pool.capacity == 9
    assert pool.summary()["n_live"] == 9
    with pytest.raises(ValueError):
        ElasticWorkerPool(3, m=4)
    with pytest.raises(IndexError):
        pool.leave(99)


def test_service_elastic_membership_live_changes():
    """Workers leave/join between rounds while m stays fixed: departures
    mask rows, slot refills reuse the cached plan, capacity growth keys a
    NEW plan (roots-of-unity codes are capacity-specific)."""
    pool = ElasticWorkerPool(8, m=4)
    svc = FFTService(_cfg(on_failure="degrade"), pool=pool)
    x = _x()
    assert np.abs(svc.submit(x) - np.fft.fft(x)).max() < 1e-2
    pool.leave(2)
    pool.leave(5)
    assert np.abs(svc.submit(x) - np.fft.fft(x)).max() < 1e-2
    n_plans = len(svc._plans)
    pool.join()                                  # refill slot 2: cache hit
    assert len(svc._plans) == n_plans
    assert np.abs(svc.submit(x) - np.fft.fft(x)).max() < 1e-2
    pool.join()                                  # refill slot 5
    grown = pool.join()                          # growth: capacity 9
    assert grown == 8 and svc._n_workers() == 9
    assert np.abs(svc.submit(x) - np.fft.fft(x)).max() < 1e-2
    assert len(svc._plans) > n_plans             # new capacity, new code
    assert svc.health.n_workers == 9             # tracker grew with it


# ------------------------------------------------------- measured runtime
def test_measured_runtime_round_completes_and_decodes():
    plan = CodedFFT(s=64, m=4, n_workers=8, dtype=np.complex128,
                    backend="reference")
    h = WorkerHealthTracker(8)
    x = np.stack([_x(64, s, np.complex128) for s in range(3)])
    with MeasuredWorkerRuntime(plan, h) as rt:
        res = rt.round(x, 0)
    assert res.ok and res.mask.sum() >= 4
    assert np.isfinite(res.t_met) and res.t_met <= res.t_last
    for i in range(3):
        y = np.asarray(plan.decode(jnp.asarray(res.b[i]),
                                   mask=jnp.asarray(res.mask)))
        np.testing.assert_allclose(y, np.fft.fft(x[i]), atol=1e-8)
    assert h.rounds == 1                          # deadlines learn from it


def test_measured_runtime_kill_faults_and_insufficient():
    plan = CodedFFT(s=64, m=4, n_workers=8, dtype=np.complex128,
                    backend="reference")
    h = WorkerHealthTracker(8)
    inj = FaultInjector(FaultPlan().kill(0, rounds=999).kill(7, rounds=999))
    x = _x(64, 1, np.complex128)[None]
    with MeasuredWorkerRuntime(plan, h, injector=inj) as rt:
        warm = rt.round(x, 0)                    # learn live-worker times
        assert warm.ok and not warm.mask[0]
        res = rt.round(x, 1)
        assert res.ok
        y = np.asarray(plan.decode(jnp.asarray(res.b[0]),
                                   mask=jnp.asarray(res.mask)))
        np.testing.assert_allclose(y, np.fft.fft(x[0]), atol=1e-8)
        # fewer than m live workers: typed failure, not a hang
        alive = np.zeros(8, bool)
        alive[:3] = True
        bad = rt.round(x, 2, alive=alive)
        assert not bad.ok and bad.reason == "insufficient_workers"


def test_measured_service_corrects_byzantine_workers():
    """End-to-end measured path: worker THREADS inject the corruption and
    verify="correct" still recovers the exact transform (quorum k = m + 4
    corrects 2 liars)."""
    plan = FaultPlan(seed=8).corrupt(2, rounds=999).corrupt(5, rounds=999)
    svc = FFTService(_cfg(s=64, measured=True, faults=plan,
                          verify="correct", verify_quorum=4,
                          dtype=np.complex128, use_reference=True))
    x = _x(64, 2, np.complex128)
    y = svc.submit(x)
    np.testing.assert_allclose(y, np.fft.fft(x), atol=1e-8)
    assert svc.stats.corrected >= 2
    assert set(svc.health.summary()["byzantine"]) == {2, 5}


def test_measured_uncoded_baseline_requires_every_worker():
    """require_all=True is the uncoded baseline: one killed worker forces
    the full retry ladder (an uncoded partition has no slack)."""
    plan = FaultPlan().kill(3, rounds=999)
    svc = FFTService(_cfg(s=64, measured=True, require_all=True,
                          faults=plan, max_retries=0, on_failure="degrade",
                          dtype=np.complex128, use_reference=True))
    r = svc.submit(_x(64, 0, np.complex128))
    assert isinstance(r, DegradedResult) and r.reason == "retries_exhausted"
    # the coded service under the SAME fault plan just ... works
    svc2 = FFTService(_cfg(s=64, measured=True, faults=plan,
                           dtype=np.complex128, use_reference=True))
    x = _x(64, 0, np.complex128)
    np.testing.assert_allclose(svc2.submit(x), np.fft.fft(x), atol=1e-8)
    assert svc2.stats.degraded == 0
