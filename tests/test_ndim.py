"""n-dimensional coded FFT (Theorems 3/4) against jnp.fft.fftn."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import CodedFFTND, interleave_nd, deinterleave_nd, plan_factors

C128 = jnp.complex128


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) + 1j * rng.normal(size=shape))


def test_interleave_nd_roundtrip():
    t = _rand((8, 12, 6))
    factors = (2, 3, 2)
    c = interleave_nd(t, factors)
    assert c.shape == (12, 4, 4, 3)
    back = deinterleave_nd(c, factors, t.shape)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(t))


def test_interleave_nd_layout():
    """c_{(i)}[j] = t[i_k + j_k * m_k] — the fixed version of paper eq. 28."""
    t = jnp.arange(24.0).reshape(4, 6)
    c = interleave_nd(t, (2, 3))
    for i0 in range(2):
        for i1 in range(3):
            shard = c[i0 * 3 + i1]
            for j0 in range(2):
                for j1 in range(2):
                    assert float(shard[j0, j1]) == float(t[i0 + j0 * 2, i1 + j1 * 3])


@pytest.mark.parametrize(
    "shape,factors,n",
    [
        ((8, 8), (2, 2), 6),
        ((4, 6), (2, 3), 8),
        ((8, 4, 4), (2, 1, 2), 5),
        ((16,), (4,), 6),
    ],
)
def test_ndim_matches_fftn(shape, factors, n):
    t = _rand(shape, seed=sum(shape))
    strat = CodedFFTND(shape=shape, factors=factors, n_workers=n, dtype=C128)
    got = strat.run(t)
    np.testing.assert_allclose(np.asarray(got), np.fft.fftn(np.asarray(t)), atol=1e-8)


def test_ndim_every_subset():
    shape, factors, n = (4, 4), (2, 2), 6
    t = _rand(shape, seed=9)
    strat = CodedFFTND(shape=shape, factors=factors, n_workers=n, dtype=C128)
    b = strat.worker_compute(strat.encode(t))
    want = np.fft.fftn(np.asarray(t))
    for sub in itertools.combinations(range(n), strat.m):
        got = strat.decode(b, subset=jnp.asarray(sub))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-7)


def test_ndim_mask_decode():
    shape, factors = (8, 8), (2, 2)
    t = _rand(shape, seed=10)
    strat = CodedFFTND(shape=shape, factors=factors, n_workers=7, dtype=C128)
    b = strat.worker_compute(strat.encode(t))
    mask = np.ones(7, bool)
    mask[[1, 4, 6]] = False
    got = strat.decode(b, mask=jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.fft.fftn(np.asarray(t)), atol=1e-8)


def test_plan_factors():
    assert plan_factors((8, 8), 4) in [(2, 2), (4, 1), (1, 4)]
    f = plan_factors((6, 4, 10), 12)
    assert np.prod(f) == 12
    for fk, sk in zip(f, (6, 4, 10)):
        assert sk % fk == 0
    with pytest.raises(ValueError):
        plan_factors((3, 3), 4)  # 4 has no factorization over odd dims


@settings(max_examples=20, deadline=None)
@given(
    d0=st.sampled_from([4, 6, 8]),
    d1=st.sampled_from([4, 6, 8]),
    m0=st.sampled_from([1, 2]),
    m1=st.sampled_from([1, 2]),
    extra=st.integers(0, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_2d(d0, d1, m0, m1, extra, seed):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.normal(size=(d0, d1)) + 1j * rng.normal(size=(d0, d1)))
    m = m0 * m1
    strat = CodedFFTND(shape=(d0, d1), factors=(m0, m1), n_workers=m + extra, dtype=C128)
    b = strat.worker_compute(strat.encode(t))
    sub = jnp.asarray(rng.choice(m + extra, size=m, replace=False))
    got = strat.decode(b, subset=sub)
    np.testing.assert_allclose(np.asarray(got), np.fft.fftn(np.asarray(t)), atol=1e-6)
