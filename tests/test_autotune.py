"""Autotuner cache tests — cold search, JSON persistence, warm skip.

The tuner (kernels/autotune.py) measures candidate four-step variants and
bucket block_q tilings once per (shape, mode, backend), records the winner
in an in-memory table, and persists it to a backend-keyed JSON file so the
NEXT process skips the search.  Dispatch (`ops._tuned_block_q`,
`fourstep_planar(variant=None)`) treats the table as a pure dict read.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops

pytestmark = pytest.mark.kernels


@pytest.fixture()
def fresh_cache(tmp_path, monkeypatch):
    """A private cache dir with empty in-memory tables; restores the
    session tables afterwards so other tests keep their entries."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    saved_tables = dict(autotune._TABLES)
    saved_loaded = set(autotune._LOADED)
    autotune._TABLES.clear()
    autotune._LOADED.clear()
    yield tmp_path
    autotune._TABLES.clear()
    autotune._TABLES.update(saved_tables)
    autotune._LOADED.clear()
    autotune._LOADED.update(saved_loaded)


def test_key_is_order_insensitive():
    assert autotune.key_of("bucket", s=64, m=2, n=4) == \
        autotune.key_of("bucket", n=4, m=2, s=64)


def test_candidate_factor_plans_cover_radix_splits():
    plans = autotune.candidate_factor_plans(4096)
    assert [64, 64] in plans
    assert [16, 16, 16] in plans
    for p in plans:
        assert int(np.prod(p)) == 4096


def test_cold_search_persists_and_warm_skips(fresh_cache):
    """The round-trip: cold search -> JSON on disk -> a fresh in-memory
    state (a new process) reloads the table and skips the search."""
    before = autotune.searches_run()
    ent = autotune.ensure_fourstep(64, batch=2, mode="direct", reps=1)
    assert autotune.searches_run() == before + 1
    assert ent["variant"] in ("fused", "two_pass", "xla")

    path = autotune.cache_path()
    assert path.exists()
    data = json.loads(path.read_text())
    assert data["version"] == autotune.SCHEMA_VERSION
    assert any(k.startswith("fourstep|") for k in data["entries"])

    # same process, same key: pure lookup, no new search
    again = autotune.ensure_fourstep(64, batch=2, mode="direct", reps=1)
    assert again == ent
    assert autotune.searches_run() == before + 1

    # simulate a new process: drop memory, keep disk
    autotune.clear(memory_only=True)
    warm = autotune.ensure_fourstep(64, batch=2, mode="direct", reps=1)
    assert warm["variant"] == ent["variant"]
    assert autotune.searches_run() == before + 1


def test_bucket_search_records_block_q_and_dispatch_uses_it(fresh_cache):
    """tune_bucket times real masked-dispatcher calls and the recorded
    block_q flows back through ops._tuned_block_q on the next dispatch."""
    ent = autotune.tune_bucket("bucket", 64, 2, 4, q=4, mode="direct",
                               reps=1)
    assert ent["block_q"] in (1, 2, 4)
    got = ops._tuned_block_q("bucket", 4, 10**9, "direct", s=64, m=2, n=4)
    assert got == ent["block_q"]
    # a miss falls back to the VMEM heuristic (bounded by batch)
    miss = ops._tuned_block_q("bucket", 4, 2, "interpret", s=999, m=2, n=4)
    assert 1 <= miss <= 4


def test_corrupt_cache_file_tolerated(fresh_cache):
    path = autotune.cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text("{not json")
    autotune.clear(memory_only=True)
    assert autotune.lookup("fourstep", L=64, mode="direct") is None
    # and recording over it heals the file
    autotune.record("fourstep", {"variant": "fused", "ms": 1.0},
                    L=64, mode="direct")
    assert json.loads(path.read_text())["entries"]


def test_fourstep_dispatch_honors_recorded_variant(fresh_cache):
    """fourstep_planar(variant=None) consults the table: pin an 'xla'
    entry and the jaxpr shows the platform FFT, no pallas_call."""
    import jax

    autotune.record("fourstep", {"variant": "xla", "ms": 0.1},
                    L=64, mode="direct")
    x = jnp.zeros((2, 64), jnp.float32)
    jaxpr = str(jax.make_jaxpr(
        lambda a, b: ops.fourstep_planar(a, b, interpret=None))(x, x))
    assert "fft" in jaxpr
    assert "pallas_call" not in jaxpr

    autotune.record("fourstep", {"variant": "fused",
                                 "factors": [4, 4, 4], "ms": 0.1},
                    L=64, mode="compiled")
    jaxpr = str(jax.make_jaxpr(
        lambda a, b: ops.fourstep_planar(a, b, interpret=False))(x, x))
    assert "fourstep_fft_multistep" in jaxpr


def test_tuned_streaming_blocks_flow_into_bucket_launch(fresh_cache):
    """A recorded streaming tiling is what the dispatcher launches with."""
    s, m, n = 1 << 17, 2, 4
    autotune.record("bucket", {"block_q": 2, "block_a": 128, "block_b": 64,
                               "ms": 1.0},
                    s=s, m=m, n=n, mode="compiled")
    bq, ba, bb = ops._streaming_blocks("bucket", "compiled", s=s, m=m, n=n)
    assert (bq, ba, bb) == (2, 128, 64)


def test_service_warmup_runs_search_once(fresh_cache):
    """FFTService.warmup() populates the table; a second service (same
    cache) performs zero additional searches."""
    from repro.serving.fft_service import FFTService, FFTServiceConfig

    cfg = FFTServiceConfig(s=64, m=2, n_workers=4, max_batch=4,
                           autotune_reps=1)
    FFTService(cfg).warmup(kinds=("c2c",))
    after_first = autotune.searches_run()
    assert after_first > 0
    FFTService(cfg).warmup(kinds=("c2c",))
    assert autotune.searches_run() == after_first
