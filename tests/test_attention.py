"""Chunked attention vs a dense reference, across mask variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    QuantKV,
    chunked_attention,
    dequantize_kv,
    quantize_kv,
    ring_positions,
)


def _dense_reference(q, k, v, *, causal=True, window=None, prefix_len=None,
                     q_positions=None, kv_positions=None, scale=None):
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    if scale is None:
        scale = d ** -0.5
    if q_positions is None:
        q_positions = np.arange(sq)
    if kv_positions is None:
        kv_positions = np.arange(skv)
    kr = np.repeat(np.asarray(k, np.float64), g, axis=2)
    vr = np.repeat(np.asarray(v, np.float64), g, axis=2)
    qn = np.asarray(q, np.float64)
    scores = np.einsum("bshd,bthd->bhst", qn, kr) * scale
    allowed = (kv_positions[None, :] >= 0)
    if causal:
        allowed = allowed & (kv_positions[None, :] <= q_positions[:, None])
    if window is not None:
        allowed = allowed & (kv_positions[None, :] > q_positions[:, None] - window)
    if prefix_len is not None:
        allowed = allowed | ((kv_positions[None, :] < prefix_len) & (kv_positions[None, :] >= 0))
    scores = np.where(allowed[None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bhst,bthd->bshd", p, vr)
    return out


def _rand_qkv(b=2, sq=16, skv=16, h=4, kh=2, d=8, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, skv, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, kh, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_causal_matches_dense(chunk):
    q, k, v = _rand_qkv()
    got = chunked_attention(q, k, v, causal=True, chunk=chunk)
    want = _dense_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_bidirectional():
    q, k, v = _rand_qkv(seed=1)
    got = chunked_attention(q, k, v, causal=False, chunk=8)
    want = _dense_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_sliding_window():
    q, k, v = _rand_qkv(sq=32, skv=32, seed=2)
    got = chunked_attention(q, k, v, causal=True, window=8, chunk=8)
    want = _dense_reference(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_prefix_lm():
    q, k, v = _rand_qkv(sq=24, skv=24, seed=3)
    got = chunked_attention(q, k, v, causal=True, prefix_len=jnp.asarray(8), chunk=8)
    want = _dense_reference(q, k, v, causal=True, prefix_len=8)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_mqa_grouping():
    q, k, v = _rand_qkv(h=8, kh=1, seed=4)
    got = chunked_attention(q, k, v, chunk=8)
    want = _dense_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_non_divisible_chunk_padding():
    q, k, v = _rand_qkv(sq=10, skv=10, seed=5)
    got = chunked_attention(q, k, v, chunk=4)  # 10 % 4 != 0 -> padded
    want = _dense_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_decode_one_token_against_prefill():
    """Decode (Sq=1 vs cache) must equal the last row of full prefill."""
    b, s, h, kh, d = 2, 12, 4, 2, 8
    q, k, v = _rand_qkv(b=b, sq=s, skv=s, h=h, kh=kh, d=d, seed=6)
    full = chunked_attention(q, k, v, causal=True, chunk=4)
    last = chunked_attention(
        q[:, -1:], k, v, causal=True, chunk=4,
        q_positions=jnp.asarray([s - 1]),
    )
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5
    )


def test_ring_buffer_positions():
    # window 4, after 6 writes slots hold positions [4, 5, 2, 3]
    got = np.asarray(ring_positions(jnp.asarray(6), 4))
    np.testing.assert_array_equal(got, [4, 5, 2, 3])
    # before any write: all invalid
    got0 = np.asarray(ring_positions(jnp.asarray(0), 4))
    np.testing.assert_array_equal(got0, [-1, -1, -1, -1])


def test_ring_buffer_decode_matches_linear_cache():
    """Windowed decode with a ring cache == decode with the full cache."""
    b, h, kh, d, w = 1, 2, 1, 8, 4
    t = 7  # current step: positions 0..6 written
    rng = np.random.default_rng(7)
    kfull = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    vfull = jnp.asarray(rng.normal(size=(b, t, kh, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    want = chunked_attention(
        q, kfull, vfull, causal=True, window=w, chunk=4,
        q_positions=jnp.asarray([t - 1]),
    )
    # build the ring cache: slot i holds latest position == i (mod w)
    kring = np.zeros((b, w, kh, d), np.float32)
    vring = np.zeros((b, w, kh, d), np.float32)
    for pos in range(t):
        kring[:, pos % w] = np.asarray(kfull[:, pos])
        vring[:, pos % w] = np.asarray(vfull[:, pos])
    got = chunked_attention(
        q, jnp.asarray(kring), jnp.asarray(vring), causal=True, window=w, chunk=4,
        q_positions=jnp.asarray([t - 1]), kv_positions=ring_positions(jnp.asarray(t), w),
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_int8_kv_quantization_roundtrip():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(2, 16, 2, 32)), jnp.float32)
    qx = quantize_kv(x)
    assert qx.q.dtype == jnp.int8
    back = dequantize_kv(qx, jnp.float32)
    rel = np.abs(np.asarray(back) - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 0.02


def test_int8_kv_attention_close_to_fp():
    q, k, v = _rand_qkv(sq=8, skv=32, seed=9)
    want = chunked_attention(q, k, v, causal=False, chunk=8)
    got = chunked_attention(q, quantize_kv(k), quantize_kv(v), causal=False, chunk=8)
    err = np.abs(np.asarray(got) - np.asarray(want)).max()
    assert err < 0.05


def test_no_nan_with_fully_masked_rows():
    """Query rows with zero visible keys must return 0, not NaN."""
    q, k, v = _rand_qkv(sq=4, skv=8, seed=10)
    got = chunked_attention(
        q, k, v, causal=True, chunk=4,
        q_positions=jnp.asarray([-1, -1, -1, -1]),  # nothing visible
    )
    assert not bool(jnp.isnan(got).any())
    np.testing.assert_allclose(np.asarray(got), 0.0, atol=1e-6)
