"""Serving: generation engine determinism/caching + FFT service stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.distributed.straggler import StragglerModel
from repro.models import build_model
from repro.serving import (
    EngineConfig,
    FFTService,
    FFTServiceConfig,
    GenerationEngine,
    sample_token,
)


@pytest.fixture(scope="module")
def engine():
    cfg = get_reduced_config("gemma-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return GenerationEngine(model, params, EngineConfig(
        batch_size=3, prompt_len=16, max_new_tokens=8, cache_len=64)), cfg


def test_greedy_generation_deterministic(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, 10)) for _ in range(3)]
    out1 = eng.generate(prompts)
    out2 = eng.generate(prompts)
    assert out1 == out2
    assert all(len(o) == 8 for o in out1)


def test_generate_eos_truncation_with_overlapped_fetch(engine):
    """The one-step-behind token fetch (decode t+1 launches before token t
    reaches the host) must not change WHAT is generated: EOS still
    truncates each row at its first occurrence, and rows without an EOS
    are untouched.  The speculative decode launched past an EOS is
    discarded on the host, never emitted."""
    eng, cfg = engine
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(1, cfg.vocab_size, 10)) for _ in range(3)]
    base = eng.generate(prompts)
    eos = base[0][1]                 # force EOS at row 0's second token
    old = eng.ecfg.eos_id
    eng.ecfg.eos_id = eos
    try:
        out = eng.generate(prompts)
    finally:
        eng.ecfg.eos_id = old
    for got, want in zip(out, base):
        expect = want[:want.index(eos) + 1] if eos in want else want
        assert got == expect


def test_prefill_decode_consistency(engine):
    """Greedy decode continuation must match teacher-forced prefill logits."""
    eng, cfg = engine
    model = eng.model
    params = eng.params
    toks = np.asarray([[5, 9, 2, 7, 1, 3, 8, 4]], np.int32)

    cache = model.init_cache(1, 32)
    logits_a, cache = model.prefill(params, {"tokens": jnp.asarray(toks)}, cache)
    nxt_a = int(jnp.argmax(logits_a[0, -1]))

    # same prefix via prefill of all but last + one decode step
    cache2 = model.init_cache(1, 32)
    _, cache2 = model.prefill(params, {"tokens": jnp.asarray(toks[:, :-1])}, cache2)
    logits_b, _ = model.decode_step(
        params, cache2, {"tokens": jnp.asarray(toks[:, -1:])},
        jnp.asarray(toks.shape[1] - 1, jnp.int32))
    nxt_b = int(jnp.argmax(logits_b[0, -1]))
    assert nxt_a == nxt_b


def test_sample_token_temperature_zero_is_argmax():
    logits = jnp.asarray([[[0.1, 3.0, -1.0]]])
    t = sample_token(logits, jax.random.PRNGKey(0), temperature=0.0)
    assert int(t[0, 0]) == 1


def test_fft_service_tolerates_and_accounts():
    svc = FFTService(FFTServiceConfig(
        s=512, m=4, n_workers=8, straggler=StragglerModel(t0=1.0, mu=1.0),
        seed=3))
    x = (jax.random.normal(jax.random.PRNGKey(0), (512,)) + 0j).astype(jnp.complex64)
    for _ in range(5):
        y = svc.submit(x)
    err = float(jnp.max(jnp.abs(y - jnp.fft.fft(x))))
    assert err < 1e-2
    st = svc.stats.summary()
    assert st["requests"] == 5
    assert st["mean_coded_latency"] < st["mean_uncoded_latency"]
    assert st["stragglers_tolerated"] == 5 * 4  # waits for m=4 of N=8 always
