"""Byzantine fault detection & correction (paper Remark 3)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import CodedFFT, RobustCodedFFT, robust_decode
from repro.core import mds
from repro.core.fault_tolerance import detect_errors, locate_errors, syndromes

C128 = jnp.complex128


def _rand(s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=s) + 1j * rng.normal(size=s))


def _setup(s=64, m=4, n=12, seed=0):
    strat = CodedFFT(s=s, m=m, n_workers=n, dtype=C128)
    x = _rand(s, seed)
    b = strat.worker_compute(strat.encode(x))
    return strat, x, np.asarray(b)


def test_syndromes_vanish_for_clean_codeword():
    strat, x, b = _setup()
    recv = np.arange(10)
    nodes = np.asarray(mds.rs_nodes(strat.n_workers, jnp.complex128))[recv]
    s = syndromes(nodes, b[recv], strat.m)
    assert np.abs(s).max() < 1e-9 * max(1.0, np.abs(b).max())


def test_detect_single_error():
    strat, x, b = _setup()
    recv = np.arange(10)
    nodes = np.asarray(mds.rs_nodes(strat.n_workers, jnp.complex128))[recv]
    assert not detect_errors(nodes, b[recv], strat.m)
    bad = b[recv].copy()
    bad[3] += 10.0
    assert detect_errors(nodes, bad, strat.m)


def test_detect_max_errors():
    """Up to k - m arbitrary errors are always detected."""
    strat, x, b = _setup(m=4, n=12)
    recv = np.arange(9)  # k = 9, detect up to 5
    nodes = np.asarray(mds.rs_nodes(strat.n_workers, jnp.complex128))[recv]
    rng = np.random.default_rng(1)
    bad = b[recv].copy()
    for i in rng.choice(9, 5, replace=False):
        bad[i] += rng.normal() * 5 + 1j
    assert detect_errors(nodes, bad, strat.m)


def test_locate_single_error():
    strat, x, b = _setup()
    recv = np.arange(10)
    nodes = np.asarray(mds.rs_nodes(strat.n_workers, jnp.complex128))[recv]
    bad = b[recv].copy()
    bad[7] += 3.0 - 2.0j
    idx = locate_errors(nodes, bad, strat.m)
    np.testing.assert_array_equal(idx, [7])


@pytest.mark.parametrize("n_err", [0, 1, 2, 3])
def test_correct_up_to_floor_half(n_err):
    """k=12 received, m=4 -> correct up to (12-4)/2 = 4 errors; test 0..3."""
    strat, x, b = _setup(s=64, m=4, n=12, seed=n_err)
    recv = np.arange(12)
    bj = jnp.asarray(b)
    rng = np.random.default_rng(n_err + 100)
    err_pos = rng.choice(12, n_err, replace=False)
    corrupted = b.copy()
    for p in err_pos:
        corrupted[p] += rng.normal(size=b.shape[1]) * 2 + 1j * rng.normal(size=b.shape[1])
    res = robust_decode(strat, jnp.asarray(corrupted), recv)
    assert res.ok
    assert res.n_errors_corrected == n_err
    np.testing.assert_array_equal(np.sort(res.error_worker_indices), np.sort(err_pos))
    np.testing.assert_allclose(res.output, np.fft.fft(np.asarray(x)), atol=1e-6)


def test_robust_wrapper_bounds():
    strat = CodedFFT(s=64, m=4, n_workers=12, dtype=C128)
    rob = RobustCodedFFT(strat)
    assert rob.max_correctable(12) == 4
    assert rob.max_detectable(12) == 8
    assert rob.max_correctable(4) == 0  # at threshold: no redundancy left


def test_robust_end_to_end_with_partial_receipt():
    """Stragglers AND Byzantine workers simultaneously."""
    strat = CodedFFT(s=128, m=4, n_workers=16, dtype=C128)
    x = _rand(128, seed=42)
    b = np.array(strat.worker_compute(strat.encode(x)))
    recv = np.asarray([0, 2, 3, 5, 7, 8, 11, 13])  # k = 8 of 16 arrived
    b[5] = 99.0 + 0j     # Byzantine
    b[11] -= 7.3j        # Byzantine
    res = robust_decode(strat, jnp.asarray(b), recv)
    assert res.ok and res.n_errors_corrected == 2
    np.testing.assert_array_equal(np.sort(res.error_worker_indices), [5, 11])
    np.testing.assert_allclose(res.output, np.fft.fft(np.asarray(x)), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n_err=st.integers(0, 2), seed=st.integers(0, 10_000))
def test_property_correction(n_err, seed):
    strat = CodedFFT(s=48, m=3, n_workers=9, dtype=C128)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=48) + 1j * rng.normal(size=48))
    b = np.array(strat.worker_compute(strat.encode(x)))
    recv = np.sort(rng.choice(9, 3 + 2 * n_err + 1, replace=False))
    err_pos = rng.choice(recv, n_err, replace=False)
    for p in err_pos:
        b[p] += (rng.normal() + 1j * rng.normal()) * 3
    res = robust_decode(strat, jnp.asarray(b), recv)
    assert res.ok
    np.testing.assert_allclose(res.output, np.fft.fft(np.asarray(x)), atol=1e-5)
