"""Streaming front-end (DESIGN.md §11): deadline-aware bucket formation,
typed admission control, kind isolation, the double-buffered staging
pipeline, and the latency histogram it reports through ServiceStats."""

import time

import numpy as np
import pytest

from repro.serving import (
    AdmissionError,
    FFTService,
    FFTServiceConfig,
    LatencyHistogram,
    StreamConfig,
    StreamingFFTService,
)


def _cfg(**kw):
    kw.setdefault("s", 256)
    kw.setdefault("m", 4)
    kw.setdefault("n_workers", 8)
    kw.setdefault("seed", 0)
    kw.setdefault("max_batch", 4)
    kw.setdefault("autotune", False)
    return FFTServiceConfig(**kw)


def _reqs(n, s=256, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.normal(size=s)
             + 1j * rng.normal(size=s)).astype(np.complex64)
            for _ in range(n)]


def test_fill_dispatch_and_results():
    """Full buckets dispatch on the fill rule alone (huge slack), and the
    futures resolve to the true transforms with latency attached."""
    svc = FFTService(_cfg())
    with StreamingFFTService(svc, StreamConfig(slack_s=30.0)) as stream:
        xs = _reqs(8)
        futs = [stream.submit(x) for x in xs]
        for x, f in zip(xs, futs):
            assert np.abs(f.result(timeout=120) - np.fft.fft(x)).max() < 1e-2
            assert f.latency_s > 0.0
    st = svc.stats.summary()
    assert st["fill_dispatches"] == 2            # 8 requests / max_batch 4
    assert st["deadline_dispatches"] == 0
    assert st["batches"] == 2
    assert st["host_transfers"] == 2             # one fetch per bucket
    assert st["latency"]["count"] == 8
    assert st["queue_peak"] >= 1


def test_partial_bucket_dispatches_at_slack_expiry():
    """A partial bucket holds while its slack lasts, then dispatches on
    the DEADLINE rule -- never early, never waiting for a fill that is
    not coming."""
    svc = FFTService(_cfg())
    slack = 1.0
    with StreamingFFTService(svc, StreamConfig(slack_s=slack)) as stream:
        futs = [stream.submit(x) for x in _reqs(2, seed=1)]
        time.sleep(slack * 0.3)
        # well before expiry: the 2-of-4 bucket must still be queued
        assert not any(f.done() for f in futs)
        for f in futs:
            f.result(timeout=120)
    st = svc.stats.summary()
    assert st["deadline_dispatches"] == 1 and st["fill_dispatches"] == 0
    assert st["batches"] == 1                    # both rode ONE bucket
    # dispatched at expiry, not before: arrival->result spans the slack
    assert all(f.latency_s >= slack * 0.9 for f in futs)


def test_admission_control_rejects_with_typed_reason():
    """Over max_queue, submit fails fast with a machine-readable reason;
    accepted requests still complete on close(), and a closed service
    rejects with its own reason."""
    svc = FFTService(_cfg())
    stream = StreamingFFTService(
        svc, StreamConfig(fill_only=True, pipelined=False, max_queue=2))
    xs = _reqs(3, seed=2)
    f0 = stream.submit(xs[0])
    f1 = stream.submit(xs[1])                    # fill_only: both just queue
    with pytest.raises(AdmissionError) as ei:
        stream.submit(xs[2])
    assert ei.value.reason == "queue_full"
    assert svc.stats.rejected == 1
    stream.close()                               # drain flushes the partial
    assert np.abs(f0.result() - np.fft.fft(xs[0])).max() < 1e-2
    assert f1.done()
    assert svc.stats.drain_dispatches == 1
    with pytest.raises(AdmissionError) as ei:
        stream.submit(xs[2])
    assert ei.value.reason == "closed"


def test_mixed_kinds_never_share_a_bucket():
    """c2c / r2c / c2r arrivals at the same length land in three separate
    buckets -- kinds never mix inside one dispatch."""
    svc = FFTService(_cfg(max_batch=8))
    rng = np.random.default_rng(3)
    xc = [(rng.normal(size=256)
           + 1j * rng.normal(size=256)).astype(np.complex64)
          for _ in range(2)]
    xr = [rng.normal(size=256).astype(np.float32) for _ in range(2)]
    yh = [np.fft.rfft(x).astype(np.complex64) for x in xr]
    with StreamingFFTService(svc, StreamConfig(slack_s=30.0)) as stream:
        futs = ([stream.submit(x) for x in xc]
                + [stream.submit(x, kind="r2c") for x in xr]
                + [stream.submit(y, kind="c2r") for y in yh])
        assert stream.drain(timeout=240)
    st = svc.stats.summary()
    assert st["batches"] == 3                    # one bucket per (s, kind)
    assert st["drain_dispatches"] == 3
    for f, x in zip(futs[:2], xc):
        assert np.abs(f.result() - np.fft.fft(x)).max() < 1e-2
    for f, x in zip(futs[2:4], xr):
        assert np.abs(f.result() - np.fft.rfft(x)).max() < 1e-2
    for f, x in zip(futs[4:6], xr):
        assert np.abs(f.result() - x).max() < 1e-2


def test_pipeline_one_transfer_per_bucket_and_overlap_accounting():
    """The staged pipeline keeps the one-fetch-per-bucket invariant and
    accounts staging overlap without losing a single request."""
    svc = FFTService(_cfg())
    scfg = StreamConfig(slack_s=30.0, stage_depth=4)
    with StreamingFFTService(svc, scfg) as stream:
        xs = _reqs(16, seed=4)
        futs = [stream.submit(x) for x in xs]
        for f, x in zip(futs, xs):
            assert np.abs(f.result(timeout=120) - np.fft.fft(x)).max() < 1e-2
    st = svc.stats.summary()
    assert st["requests"] == 16
    assert st["batches"] == 4                    # 16 / max_batch 4, all fills
    assert st["host_transfers"] == 4
    assert st["staging_overlap_s"] >= 0.0
    assert st["latency"]["count"] == 16
    hist = st["latency"]
    assert hist["p50_s"] <= hist["p99_s"] <= hist["max_s"] * 1.1


def test_stage_error_propagates_to_futures():
    """A request that blows up at staging time (here: a length the plan
    cannot shard) resolves its future with the exception instead of
    wedging the pipeline."""
    svc = FFTService(_cfg())
    with StreamingFFTService(svc, StreamConfig(slack_s=0.05)) as stream:
        bad = stream.submit(_reqs(1, s=6, seed=5)[0])   # m=4 does not divide 6
        good = stream.submit(_reqs(1, seed=6)[0])
        with pytest.raises(Exception):
            bad.result(timeout=120)
        good.result(timeout=120)                 # pipeline still alive
    assert svc.stats.latency.n == 2


def test_submit_validates_kind_synchronously():
    svc = FFTService(_cfg())
    with StreamingFFTService(svc) as stream:
        with pytest.raises(ValueError):
            stream.submit(_reqs(1)[0], kind="c2x")
        with pytest.raises(ValueError):
            stream.submit(np.zeros(1, np.complex64), kind="c2r")


def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for v in [0.001] * 90 + [1.0] * 10:
        h.record(v)
    s = h.summary()
    assert s["count"] == 100
    assert 0.0008 <= s["p50_s"] <= 0.00125       # within one log bin
    assert 0.9 <= s["p99_s"] <= 1.3
    assert s["max_s"] == 1.0
    assert np.isnan(LatencyHistogram().percentile(50))
    h.record(0.0)                                # clamps to the low edge
    h.record(1e9)                                # ... and the high edge
    assert h.n == 102
