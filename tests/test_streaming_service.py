"""Streaming front-end (DESIGN.md §11): multi-tier EDF bucket formation,
adaptive slack, typed admission control, kind isolation, the
double-buffered staging pipeline, the latency histograms it reports
through ServiceStats, and the scheduler-lifecycle regressions (EDF
order, flush scoping, cancellation safety, overlap accounting)."""

import threading
import time

import numpy as np
import pytest

from repro.serving import (
    AdmissionError,
    FFTService,
    FFTServiceConfig,
    LatencyHistogram,
    StreamConfig,
    StreamingFFTService,
)


def _cfg(**kw):
    kw.setdefault("s", 256)
    kw.setdefault("m", 4)
    kw.setdefault("n_workers", 8)
    kw.setdefault("seed", 0)
    kw.setdefault("max_batch", 4)
    kw.setdefault("autotune", False)
    return FFTServiceConfig(**kw)


def _reqs(n, s=256, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.normal(size=s)
             + 1j * rng.normal(size=s)).astype(np.complex64)
            for _ in range(n)]


def test_fill_dispatch_and_results():
    """Full buckets dispatch on the fill rule alone (huge slack), and the
    futures resolve to the true transforms with latency attached."""
    svc = FFTService(_cfg())
    with StreamingFFTService(svc, StreamConfig(slack_s=30.0)) as stream:
        xs = _reqs(8)
        futs = [stream.submit(x) for x in xs]
        for x, f in zip(xs, futs):
            assert np.abs(f.result(timeout=120) - np.fft.fft(x)).max() < 1e-2
            assert f.latency_s > 0.0
    st = svc.stats.summary()
    assert st["fill_dispatches"] == 2            # 8 requests / max_batch 4
    assert st["deadline_dispatches"] == 0
    assert st["batches"] == 2
    assert st["host_transfers"] == 2             # one fetch per bucket
    assert st["latency"]["count"] == 8
    assert st["queue_peak"] >= 1


def test_partial_bucket_dispatches_at_slack_expiry():
    """A partial bucket holds while its slack lasts, then dispatches on
    the DEADLINE rule -- never early, never waiting for a fill that is
    not coming."""
    svc = FFTService(_cfg())
    slack = 1.0
    with StreamingFFTService(svc, StreamConfig(slack_s=slack)) as stream:
        futs = [stream.submit(x) for x in _reqs(2, seed=1)]
        time.sleep(slack * 0.3)
        # well before expiry: the 2-of-4 bucket must still be queued
        assert not any(f.done() for f in futs)
        for f in futs:
            f.result(timeout=120)
    st = svc.stats.summary()
    assert st["deadline_dispatches"] == 1 and st["fill_dispatches"] == 0
    assert st["batches"] == 1                    # both rode ONE bucket
    # dispatched at expiry, not before: arrival->result spans the slack
    assert all(f.latency_s >= slack * 0.9 for f in futs)


def test_admission_control_rejects_with_typed_reason():
    """Over max_queue, submit fails fast with a machine-readable reason;
    accepted requests still complete on close(), and a closed service
    rejects with its own reason."""
    svc = FFTService(_cfg())
    stream = StreamingFFTService(
        svc, StreamConfig(fill_only=True, pipelined=False, max_queue=2))
    xs = _reqs(3, seed=2)
    f0 = stream.submit(xs[0])
    f1 = stream.submit(xs[1])                    # fill_only: both just queue
    with pytest.raises(AdmissionError) as ei:
        stream.submit(xs[2])
    assert ei.value.reason == "queue_full"
    assert svc.stats.rejected == 1
    stream.close()                               # drain flushes the partial
    assert np.abs(f0.result() - np.fft.fft(xs[0])).max() < 1e-2
    assert f1.done()
    assert svc.stats.drain_dispatches == 1
    with pytest.raises(AdmissionError) as ei:
        stream.submit(xs[2])
    assert ei.value.reason == "closed"


def test_mixed_kinds_never_share_a_bucket():
    """c2c / r2c / c2r arrivals at the same length land in three separate
    buckets -- kinds never mix inside one dispatch."""
    svc = FFTService(_cfg(max_batch=8))
    rng = np.random.default_rng(3)
    xc = [(rng.normal(size=256)
           + 1j * rng.normal(size=256)).astype(np.complex64)
          for _ in range(2)]
    xr = [rng.normal(size=256).astype(np.float32) for _ in range(2)]
    yh = [np.fft.rfft(x).astype(np.complex64) for x in xr]
    with StreamingFFTService(svc, StreamConfig(slack_s=30.0)) as stream:
        futs = ([stream.submit(x) for x in xc]
                + [stream.submit(x, kind="r2c") for x in xr]
                + [stream.submit(y, kind="c2r") for y in yh])
        assert stream.drain(timeout=240)
    st = svc.stats.summary()
    assert st["batches"] == 3                    # one bucket per (s, kind)
    assert st["drain_dispatches"] == 3
    for f, x in zip(futs[:2], xc):
        assert np.abs(f.result() - np.fft.fft(x)).max() < 1e-2
    for f, x in zip(futs[2:4], xr):
        assert np.abs(f.result() - np.fft.rfft(x)).max() < 1e-2
    for f, x in zip(futs[4:6], xr):
        assert np.abs(f.result() - x).max() < 1e-2


def test_pipeline_one_transfer_per_bucket_and_overlap_accounting():
    """The staged pipeline keeps the one-fetch-per-bucket invariant and
    accounts staging overlap without losing a single request."""
    svc = FFTService(_cfg())
    scfg = StreamConfig(slack_s=30.0, stage_depth=4)
    with StreamingFFTService(svc, scfg) as stream:
        xs = _reqs(16, seed=4)
        futs = [stream.submit(x) for x in xs]
        for f, x in zip(futs, xs):
            assert np.abs(f.result(timeout=120) - np.fft.fft(x)).max() < 1e-2
    st = svc.stats.summary()
    assert st["requests"] == 16
    assert st["batches"] == 4                    # 16 / max_batch 4, all fills
    assert st["host_transfers"] == 4
    assert st["staging_overlap_s"] >= 0.0
    assert st["latency"]["count"] == 16
    hist = st["latency"]
    assert hist["p50_s"] <= hist["p99_s"] <= hist["max_s"] * 1.1


def test_stage_error_propagates_to_futures():
    """A request that blows up at staging time (here: a length the plan
    cannot shard) resolves its future with the exception instead of
    wedging the pipeline."""
    svc = FFTService(_cfg())
    with StreamingFFTService(svc, StreamConfig(slack_s=0.05)) as stream:
        bad = stream.submit(_reqs(1, s=6, seed=5)[0])   # m=4 does not divide 6
        good = stream.submit(_reqs(1, seed=6)[0])
        with pytest.raises(Exception):
            bad.result(timeout=120)
        good.result(timeout=120)                 # pipeline still alive
    assert svc.stats.latency.n == 2


def test_submit_validates_kind_synchronously():
    svc = FFTService(_cfg())
    with StreamingFFTService(svc) as stream:
        with pytest.raises(ValueError):
            stream.submit(_reqs(1)[0], kind="c2x")
        with pytest.raises(ValueError):
            stream.submit(np.zeros(1, np.complex64), kind="c2r")


def _slow_first_stage(svc, delay):
    """Monkey-patch ``svc.stage_bucket`` so its FIRST call sleeps
    ``delay`` seconds -- deterministically holds the scheduler (or the
    stager) busy while more traffic arrives."""
    orig = svc.stage_bucket
    fired = []

    def slow(*a, **kw):
        if not fired:
            fired.append(True)
            time.sleep(delay)
        return orig(*a, **kw)

    svc.stage_bucket = slow


def test_edf_earlier_deadline_bucket_dispatches_first():
    """Regression (dispatch-ordering bug): bucket A is created first,
    bucket B later with a SHORTER slack; when the scheduler next looks,
    both heads have expired and B -- the earlier deadline -- must
    dispatch first.  Insertion-order iteration served A first."""
    svc = FFTService(_cfg())
    _slow_first_stage(svc, 0.6)
    order = []
    scfg = StreamConfig(pipelined=False, adaptive=False)
    with StreamingFFTService(svc, scfg) as stream:
        # blocker: expires immediately, then stages for 0.6 s, so the
        # scheduler is away while A and B queue up and BOTH expire
        fblk = stream.submit(_reqs(1, s=128, seed=7)[0], slack_s=0.0)
        time.sleep(0.1)
        fa = stream.submit(_reqs(1, s=256, seed=8)[0], slack_s=0.30)
        fb = stream.submit(_reqs(1, s=512, seed=9)[0], slack_s=0.10)
        fa.add_done_callback(lambda f: order.append("A"))
        fb.add_done_callback(lambda f: order.append("B"))
        fblk.result(timeout=120)
        fa.result(timeout=120)
        fb.result(timeout=120)
    assert order.index("B") < order.index("A"), order
    assert svc.stats.deadline_dispatches == 3


def test_edf_orders_rows_within_a_bucket():
    """Ties WITHIN a bucket are EDF too: when a full bucket takes only
    ``cap`` of the queued rows, it takes the EARLIEST DEADLINES, not the
    first arrivals."""
    svc = FFTService(_cfg(max_batch=2))
    _slow_first_stage(svc, 0.4)
    xs = _reqs(3, seed=10)
    scfg = StreamConfig(pipelined=False, adaptive=False)
    with StreamingFFTService(svc, scfg) as stream:
        # blocker holds the scheduler while all three same-bucket
        # requests queue up past cap=2
        fblk = stream.submit(_reqs(1, s=128, seed=20)[0], slack_s=0.0)
        time.sleep(0.1)
        fa = stream.submit(xs[0], slack_s=5.0)   # FIFO would take fa, fb
        fb = stream.submit(xs[1], slack_s=5.0)
        fu = stream.submit(xs[2], slack_s=0.05)  # EDF takes fu, fa
        fblk.result(timeout=120)
        assert np.abs(fu.result(timeout=120)
                      - np.fft.fft(xs[2])).max() < 1e-2
        assert fa.done() and not fb.done()
        stream.flush()
        assert np.abs(fb.result(timeout=120)
                      - np.fft.fft(xs[1])).max() < 1e-2
        assert np.abs(fa.result(timeout=120)
                      - np.fft.fft(xs[0])).max() < 1e-2
    assert svc.stats.latency.n == 4


def test_cancelled_future_does_not_kill_the_pipeline():
    """Regression (Future race): a caller cancelling a pending future
    made set_result raise InvalidStateError and killed the syncer; now
    the resolution claims the future first, counts the cancellation,
    and every subsequent request still completes."""
    svc = FFTService(_cfg())
    with StreamingFFTService(svc, StreamConfig(slack_s=0.2)) as stream:
        xs = _reqs(3, seed=11)
        f0 = stream.submit(xs[0])
        assert f0.cancel()                       # pending -> cancellable
        f1 = stream.submit(xs[1])
        assert np.abs(f1.result(timeout=120) - np.fft.fft(xs[1])).max() < 1e-2
        f2 = stream.submit(xs[2])                # pipeline must be alive
        assert np.abs(f2.result(timeout=120) - np.fft.fft(xs[2])).max() < 1e-2
        assert f0.cancelled()
    assert svc.stats.cancelled == 1
    assert svc.stats.latency.n == 3              # cancelled rows computed


def test_flush_scope_excludes_later_submits():
    """Regression (sticky flush): requests submitted AFTER flush()
    returns must NOT be swept into drain buckets.  The old flag stayed
    armed until the queue emptied, so a request arriving while the
    flushed bucket staged was dispatched immediately as a partial
    "drain" bucket."""
    svc = FFTService(_cfg())
    _slow_first_stage(svc, 0.5)
    scfg = StreamConfig(slack_s=30.0, pipelined=False, adaptive=False)
    stream = StreamingFFTService(svc, scfg)
    f1 = stream.submit(_reqs(1, seed=12)[0])
    stream.flush()                               # drains f1 (gen 0)
    time.sleep(0.1)                              # scheduler is staging f1
    f2 = stream.submit(_reqs(1, seed=13)[0])     # gen 1: NOT in scope
    f1.result(timeout=120)
    time.sleep(0.3)                              # old code drained f2 here
    assert not f2.done()
    assert svc.stats.drain_dispatches == 1
    stream.flush()                               # new scope covers f2
    f2.result(timeout=120)
    stream.close()
    assert svc.stats.drain_dispatches == 2


def test_overlap_accounts_subinterval_not_whole_stage():
    """Regression (overlap race): the stager used to classify its WHOLE
    staging interval as overlapped from one unlocked peek at
    sync_q.unfinished_tasks.  Now an in-flight clock under the lock
    measures the actual overlapped sub-interval: a long stage that only
    briefly coexists with a downstream fetch must not be counted
    wholesale."""
    svc = FFTService(_cfg())
    orig = svc.stage_bucket
    calls = []

    def slow_second(*a, **kw):
        calls.append(True)
        if len(calls) == 2:
            time.sleep(0.4)      # bucket 2 stages long AFTER bucket 1's
        return orig(*a, **kw)    # (fast) fetch has already completed

    svc.stage_bucket = slow_second
    with StreamingFFTService(svc, StreamConfig(slack_s=30.0)) as stream:
        xs = _reqs(8, seed=14)
        futs = [stream.submit(x) for x in xs]    # two fill buckets of 4
        for f in futs:
            f.result(timeout=120)
    st = svc.stats.summary()
    assert st["batches"] == 2
    # the 0.4 s stage of bucket 2 overlapped bucket 1's in-flight window
    # only for the few ms that fetch actually took
    assert st["staging_overlap_s"] <= 0.2
    assert 0.0 <= st["staging_overlap_s"] <= st["dispatch_s"]


def test_rejections_counted_for_both_reasons():
    """Both admission reject reasons -- queue_full and closed -- count
    into stats.rejected."""
    svc = FFTService(_cfg())
    stream = StreamingFFTService(
        svc, StreamConfig(fill_only=True, pipelined=False, max_queue=1))
    xs = _reqs(2, seed=15)
    f0 = stream.submit(xs[0])
    with pytest.raises(AdmissionError) as ei:
        stream.submit(xs[1])
    assert ei.value.reason == "queue_full"
    assert svc.stats.rejected == 1
    stream.close()
    f0.result(timeout=120)
    with pytest.raises(AdmissionError) as ei:
        stream.submit(xs[1])
    assert ei.value.reason == "closed"
    assert svc.stats.rejected == 2


# ---------------------------------------------------------------- tiers
def test_tiers_map_to_slack_and_histograms():
    """submit(tier=...) picks the tier's slack for the deadline and the
    per-tier histogram for the accounting; unknown tiers fail fast."""
    svc = FFTService(_cfg())
    scfg = StreamConfig(
        tiers={"interactive": 0.05, "batch": 5.0},
        default_tier="interactive", adaptive=False)
    with StreamingFFTService(svc, scfg) as stream:
        with pytest.raises(ValueError):
            stream.submit(_reqs(1)[0], tier="bogus")
        xs = _reqs(1, seed=16)
        fi = stream.submit(xs[0], tier="interactive")
        fbat = stream.submit(_reqs(1, s=512, seed=16)[0], tier="batch")
        # the interactive deadline expires long before batch's: it rides
        # its own deadline bucket while the batch bucket stays queued
        assert np.abs(fi.result(timeout=120) - np.fft.fft(xs[0])).max() < 1e-2
        assert not fbat.done()
        stream.flush()
        fbat.result(timeout=120)
    st = svc.stats.summary()
    assert st["tiers"]["interactive"]["count"] == 1
    assert st["tiers"]["batch"]["count"] == 1
    assert st["tiers"]["interactive"]["p99_s"] <= st["tiers"]["batch"]["p99_s"]
    assert st["latency"]["count"] == 2           # global histogram too


def test_default_tier_must_exist():
    svc = FFTService(_cfg())
    with pytest.raises(ValueError):
        StreamingFFTService(
            svc, StreamConfig(tiers={"fast": 0.001}, default_tier="standard"))


def test_adaptive_slack_shrinks_deadline_by_predicted_compute():
    """With a compute EWMA recorded for the bucket shape, the effective
    slack shrinks so the deadline budget covers queueing only: a partial
    bucket dispatches well before its NOMINAL slack."""
    svc = FFTService(_cfg())
    scfg = StreamConfig(slack_s=5.0, min_slack_frac=0.01)
    with StreamingFFTService(svc, scfg) as stream:
        with stream._lock:                       # predicted compute: 4.9 s
            stream._ewma[(256, "c2c")] = 4.9
        t0 = time.perf_counter()
        f = stream.submit(_reqs(1, seed=17)[0])
        f.result(timeout=120)
        waited = time.perf_counter() - t0
    # effective slack = 5.0 - 4.9 = 0.1 s, not the nominal 5 s
    assert waited < 3.0
    assert svc.stats.deadline_dispatches == 1


def test_adaptive_slack_floor_and_ewma_updates():
    """The effective slack never drops below min_slack_frac of nominal,
    and real dispatches feed the per-shape EWMA."""
    svc = FFTService(_cfg())
    scfg = StreamConfig(slack_s=0.4, min_slack_frac=0.25)
    with StreamingFFTService(svc, scfg) as stream:
        with stream._lock:                       # absurd prediction
            stream._ewma[(256, "c2c")] = 100.0
        t0 = time.perf_counter()
        f = stream.submit(_reqs(1, seed=18)[0])
        f.result(timeout=120)
        waited = time.perf_counter() - t0
        assert waited >= 0.4 * 0.25 * 0.9        # floored, not immediate
        assert (256, "c2c") in stream.compute_ewma
        assert stream.compute_ewma[(256, "c2c")] < 100.0  # EWMA moved


# ------------------------------------------------------- lifecycle stress
def test_scheduler_stress_random_cancels_and_flushes():
    """Hundreds of tiny submits with random cancels and mid-stream
    flushes: nothing lost, nothing deadlocked, every pipeline thread
    exits -- all under an explicit wall-clock guard (a wedged scheduler
    fails the drain timeout instead of hanging the suite)."""
    t_start = time.perf_counter()
    svc = FFTService(_cfg(s=64, max_batch=4))
    scfg = StreamConfig(
        tiers={"interactive": 0.002, "standard": 0.01, "batch": 0.05},
        max_queue=10_000)
    rng = np.random.default_rng(19)
    xs = _reqs(8, s=64, seed=19)
    stream = StreamingFFTService(svc, scfg)
    futs, cancelled = [], 0
    for i in range(300):
        tier = ("interactive", "standard", "batch")[int(rng.integers(3))]
        f = stream.submit(xs[i % len(xs)], tier=tier)
        futs.append(f)
        if rng.random() < 0.25 and f.cancel():
            cancelled += 1
        if i % 37 == 36:
            stream.flush()
    assert stream.drain(timeout=60.0), "scheduler deadlocked"
    stream.close()
    assert all(f.done() for f in futs)
    ok = sum(1 for f in futs if not f.cancelled())
    assert ok == 300 - cancelled
    for f in futs:
        if not f.cancelled():
            f.result(timeout=1)                  # no stray exceptions
    st = svc.stats.summary()
    assert st["cancelled"] == cancelled
    assert st["latency"]["count"] == 300         # cancelled rows computed too
    assert sum(t["count"] for t in st["tiers"].values()) == 300
    assert not any(t.is_alive() for t in stream._threads)
    assert time.perf_counter() - t_start < 60.0, "wall-clock guard"


# ------------------------------------------------- fault-injected streaming
def test_streaming_kill_fault_recovers_transparently():
    """One persistently dead worker is a latency event, not a failure:
    re-dispatch fills the missing shard rows and every future resolves to
    the true transform."""
    from repro.distributed import FaultPlan

    svc = FFTService(_cfg(faults=FaultPlan().kill(2, rounds=999)))
    with StreamingFFTService(svc, StreamConfig(slack_s=30.0)) as stream:
        xs = _reqs(8, seed=21)
        futs = [stream.submit(x) for x in xs]
        for x, f in zip(xs, futs):
            assert np.abs(f.result(timeout=120) - np.fft.fft(x)).max() < 1e-2
    assert svc.stats.degraded == 0
    assert not any(t.is_alive() for t in stream._threads)


def test_streaming_fault_failures_are_typed_future_exceptions():
    """An unservable round (5 dead workers, zero retries) surfaces as a
    typed ServiceError on EACH future -- and the scheduler/stager/syncer
    threads survive to serve the next submission."""
    from repro.distributed import FaultPlan
    from repro.serving import ServiceError

    plan = FaultPlan()
    for w in range(5):
        plan = plan.kill(w, rounds=999)
    svc = FFTService(_cfg(faults=plan, max_retries=0))
    with StreamingFFTService(svc, StreamConfig(slack_s=30.0)) as stream:
        futs = [stream.submit(x) for x in _reqs(4, seed=22)]
        for f in futs:
            with pytest.raises(ServiceError) as ei:
                f.result(timeout=120)
            assert ei.value.reason == "retries_exhausted"
        # the pipeline is still alive: a second wave gets the same
        # typed answer instead of a hang or a dead-thread timeout
        assert all(t.is_alive() for t in stream._threads)
        f2 = stream.submit(_reqs(1, seed=23)[0])
        with pytest.raises(ServiceError):
            f2.result(timeout=120)
    assert svc.stats.degraded >= 5
    assert not any(t.is_alive() for t in stream._threads)


def test_streaming_corrupt_fault_detected_as_future_exception():
    """A Byzantine worker under verify="detect": the syndrome check turns
    silent corruption into a typed corrupt_uncorrectable Future exception
    (and under verify="off" it would have been silently wrong)."""
    from repro.distributed import FaultPlan, StragglerModel
    from repro.serving import ServiceError

    tight = StragglerModel(t0=1.0, mu=1e6)  # all workers arrive -> k = 8
    svc = FFTService(_cfg(straggler=tight,
                          faults=FaultPlan(seed=3).corrupt(1, rounds=999),
                          verify="detect"))
    with StreamingFFTService(svc, StreamConfig(slack_s=30.0)) as stream:
        f = stream.submit(_reqs(1, seed=24)[0])
        with pytest.raises(ServiceError) as ei:
            f.result(timeout=120)
        assert ei.value.reason == "corrupt_uncorrectable"
    assert svc.stats.detected >= 1
    assert not any(t.is_alive() for t in stream._threads)


def test_scheduler_stress_with_fault_injection():
    """The PR-8 lifecycle stress under a random kill/delay/corrupt storm
    with Byzantine correction on: every non-cancelled future either holds
    the true transform or raises a TYPED ServiceError -- no untyped
    exceptions, no lost futures, no dead pipeline threads."""
    from repro.distributed import FaultPlan, StragglerModel
    from repro.serving import FAILURE_REASONS, ServiceError

    t_start = time.perf_counter()
    plan = FaultPlan.random(8, rate=0.25, horizon=256, seed=20)
    svc = FFTService(_cfg(s=64, max_batch=4, faults=plan, verify="correct",
                          straggler=StragglerModel(t0=1.0, mu=50.0)))
    scfg = StreamConfig(
        tiers={"interactive": 0.002, "standard": 0.01, "batch": 0.05},
        max_queue=10_000)
    rng = np.random.default_rng(25)
    xs = _reqs(8, s=64, seed=25)
    stream = StreamingFFTService(svc, scfg)
    futs, cancelled = [], 0
    for i in range(200):
        tier = ("interactive", "standard", "batch")[int(rng.integers(3))]
        f = stream.submit(xs[i % len(xs)], tier=tier)
        futs.append((xs[i % len(xs)], f))
        if rng.random() < 0.2 and f.cancel():
            cancelled += 1
        if i % 41 == 40:
            stream.flush()
    assert stream.drain(timeout=90.0), "scheduler deadlocked under faults"
    stream.close()
    assert all(f.done() for _, f in futs)
    served = failed = 0
    for x, f in futs:
        if f.cancelled():
            continue
        try:
            y = f.result(timeout=1)
        except ServiceError as e:
            assert e.reason in FAILURE_REASONS    # typed, never raw
            failed += 1
        else:
            assert np.abs(y - np.fft.fft(x)).max() < 1e-2
            served += 1
    assert served + failed == 200 - cancelled
    assert served > 0                             # the storm never won outright
    st = svc.stats.summary()
    assert st["cancelled"] == cancelled
    assert st["degraded"] >= failed               # cancelled rows still ride
    #                                               the bucket and may degrade
    # the fault machinery demonstrably engaged
    assert (st["retries"] + st["redispatched_shards"]
            + st["detected"] + st["corrected"]) > 0
    assert not any(t.is_alive() for t in stream._threads)
    assert time.perf_counter() - t_start < 90.0, "wall-clock guard"


def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for v in [0.001] * 90 + [1.0] * 10:
        h.record(v)
    s = h.summary()
    assert s["count"] == 100
    assert 0.0008 <= s["p50_s"] <= 0.00125       # within one log bin
    assert 0.9 <= s["p99_s"] <= 1.3
    assert s["max_s"] == 1.0
    assert np.isnan(LatencyHistogram().percentile(50))
    h.record(0.0)                                # clamps to the low edge
    h.record(1e9)                                # ... and the high edge
    assert h.n == 102
