"""Optional-dependency shim for ``hypothesis``.

The property tests use a small slice of the hypothesis API (``@given`` /
``@settings`` with ``integers`` / ``floats`` / ``sampled_from`` /
``booleans``, plus the ``prop_settings`` helper that disables the
per-example deadline for jit-heavy properties).  When the
real package is installed (the ``test`` extra in pyproject.toml) it is used
unchanged; otherwise this module provides a deterministic fallback sampler
so the suite still runs green instead of erroring at collection.

The fallback draws ``max_examples`` pseudo-random examples per test from a
seed fixed by the test name, so failures reproduce across runs.  It does
NOT shrink or persist a failure database -- install hypothesis for that.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True

    def prop_settings(max_examples: int = 20):
        """Property-suite settings: jit/compile time breaks hypothesis's
        per-example deadline and too_slow health check, so both are
        disabled; the CI property job pins ``--hypothesis-seed`` instead
        (tests/test_properties.py, DESIGN.md §7)."""
        return settings(
            max_examples=max_examples,
            deadline=None,
            suppress_health_check=[HealthCheck.too_slow],
        )

except ImportError:
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: "np.random.Generator"):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=None):
            if max_value is None:
                min_value, max_value = 0, min_value
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _Strategies()

    class HealthCheck:  # noqa: D401 - API-shape stand-in
        """Placeholder mirroring hypothesis.HealthCheck attribute access."""

        too_slow = data_too_large = filter_too_much = None

    def prop_settings(max_examples: int = 20):
        """Fallback twin of the real-hypothesis ``prop_settings`` above."""
        return settings(max_examples=max_examples)

    def settings(*, max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            def runner():
                n = getattr(runner, "_shim_max_examples", 20)
                rng = np.random.default_rng(
                    zlib.adler32(fn.__qualname__.encode()))
                for _ in range(n):
                    kwargs = {k: s.example(rng) for k, s in strategies.items()}
                    try:
                        fn(**kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"property falsified on example {kwargs!r}"
                        ) from exc

            # NOT functools.wraps: pytest must see the zero-arg signature,
            # so __wrapped__ (whose params look like fixtures) stays unset.
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco


__all__ = ["given", "settings", "prop_settings", "st", "HealthCheck",
           "HAVE_HYPOTHESIS"]
