"""Wire-model regression: payload_scale charging across strategies.

The StragglerModel splits ``t0`` into compute and wire shares
(``wire_frac``), and every per-draw ``payload_scale`` scales only the
wire share: ``t0_eff = t0 * (1 - wire_frac + wire_frac * ps)``.  The
service must charge each bucket family its TRUE per-shard payload:

* c2c mds shards ship the full s/m payload      -> payload_scale 1
* r2c/c2r pair-packed shards ship half          -> payload_scale 0.5
* comm_efficient folded shards ship 1/q         -> payload_scale 1/q
* partial fragments reship the full shard total -> payload_scale 1

and the modeled round times must show the Jeong et al. (1805.09891)
trade: the folded payload WINS when the wire dominates and LOSES when
compute dominates (the m*q-th order statistic costs more than the m-th).
"""

import numpy as np
import pytest

from repro.distributed.straggler import StragglerModel
from repro.serving.fft_service import FFTService, FFTServiceConfig

S, M, N, Q = 256, 2, 8, 2


def _svc(strategy, wire_frac=0.5, **kw):
    return FFTService(FFTServiceConfig(
        s=S, m=M, n_workers=N, strategy=strategy, use_reference=True,
        straggler=StragglerModel(t0=1.0, mu=1.0, wire_frac=wire_frac), **kw))


def test_t0_eff_payload_scaling():
    """payload_scale scales ONLY the wire share of t0."""
    sm = StragglerModel(t0=2.0, mu=1.0, wire_frac=0.25)
    assert sm._t0_eff(1.0) == pytest.approx(2.0)        # inert at ps=1
    assert sm._t0_eff(0.5) == pytest.approx(2.0 * (0.75 + 0.25 * 0.5))
    assert sm._t0_eff(0.0) == pytest.approx(1.5)        # wire share gone
    # no wire split -> payload_scale is inert entirely
    assert StragglerModel(t0=2.0, mu=1.0, wire_frac=0.0)._t0_eff(0.1) == 2.0


def test_service_charges_per_strategy_payload():
    """The service's wire scale per bucket family (DESIGN.md §13)."""
    mds = _svc("mds")
    assert mds._wire_scale("c2c") == 1.0
    assert mds._wire_scale("r2c") == 0.5      # pair-packed half payload
    assert mds._wire_scale("c2r") == 0.5
    assert mds._wire_scale("rfftn") == 0.5
    assert _svc("comm_efficient")._wire_scale("c2c") == pytest.approx(1 / Q)
    assert _svc("comm_efficient", strategy_param=4)._wire_scale("c2c") \
        == pytest.approx(0.25)
    assert _svc("partial")._wire_scale("c2c") == 1.0


def test_sampled_latencies_shift_by_wire_share():
    """Same seed => identical exponential noise, so the drawn latencies
    differ between payload scales by EXACTLY the deterministic wire-share
    shift ``workload * t0 * wire_frac * (1 - ps)``."""
    wf = 0.6
    sm = StragglerModel(t0=1.0, mu=1.0, wire_frac=wf)
    full = sm.sample((4, N), 1.0 / M, np.random.default_rng(3))
    half = sm.sample((4, N), 1.0 / M, np.random.default_rng(3),
                     payload_scale=0.5)
    fold = sm.sample((4, N), 1.0 / M, np.random.default_rng(3),
                     payload_scale=1.0 / Q)
    np.testing.assert_allclose(full - half, (1.0 / M) * wf * 0.5, rtol=1e-12)
    np.testing.assert_allclose(full - fold, (1.0 / M) * wf * (1 - 1.0 / Q),
                               rtol=1e-12)


def test_simulate_arrivals_use_strategy_payload():
    """End-to-end: two same-seed services draw the same noise; the
    comm_efficient one's latencies sit EXACTLY the folded wire share
    below the mds one's."""
    wf = 0.8
    mds = _svc("mds", wire_frac=wf, seed=11)
    ce = _svc("comm_efficient", wire_frac=wf, seed=11)
    lat_mds, _ = mds._simulate_arrivals(5, "c2c")
    lat_ce, _ = ce._simulate_arrivals(5, "c2c")
    np.testing.assert_allclose(
        lat_mds - lat_ce, (1.0 / M) * wf * (1 - 1.0 / Q), rtol=1e-12)


def test_modeled_rounds_show_comm_efficient_crossover():
    """Modeled expected round times (harmonic closed form): the folded
    payload beats plain MDS when the wire dominates and loses when
    compute does -- the trade the bench race demonstrates empirically."""
    def round_time(wire_frac, strategy):
        sm = StragglerModel(t0=1.0, mu=4.0, wire_frac=wire_frac)
        if strategy == "mds":
            return sm.expected_kth(N, M, 1.0 / M)
        return sm.expected_kth(N, M * Q, 1.0 / M, payload_scale=1.0 / Q)

    assert round_time(0.8, "comm_efficient") < round_time(0.8, "mds")
    assert round_time(0.0, "comm_efficient") > round_time(0.0, "mds")
    # threshold m*q must fit in N or the round never completes
    sm = StragglerModel(t0=1.0, mu=1.0)
    assert sm.expected_kth(M * Q - 1, M * Q, 1.0 / M) == float("inf")


def test_partial_coverage_beats_mds_with_slow_but_alive_fleet():
    """The partial-work win (Wang 1804.09791): with some workers slowed
    (but alive), the m*r-th FRAGMENT arrives before the m-th full shard
    -- prefixes from the slow workers count."""
    rng = np.random.default_rng(5)
    sm = StragglerModel(t0=1.0, mu=1.0, wire_frac=0.0)
    r, rounds = 4, 300
    slow = np.ones(N)
    slow[: N // 2] = 3.0     # half the fleet 3x slow -- but ALIVE
    frac = np.arange(1, r + 1) / r
    t_mds = t_part = 0.0
    for _ in range(rounds):
        lat = sm.sample(N, 1.0 / M, rng) * slow
        t_mds += np.sort(lat)[M - 1]
        ft = np.sort((lat[:, None] * frac).ravel())
        t_part += ft[M * r - 1]
    assert t_part < t_mds
