"""Streaming (double-buffered DMA) kernel tests — DESIGN.md §10.

The streaming four-step and streaming bucket kernels keep only
(batch-block, shard-block) tiles VMEM-resident and stage tile k+1 while
tile k computes, so shapes past the fused VMEM budget stay ONE launch.
CPU CI cannot execute compiled Mosaic, so correctness is pinned two ways:
interpret-mode parity on shapes that genuinely exceed the budget (forcing
multi-tile grids through the real DMA machinery), and jaxpr launch-count
pins on the TPU-like dispatch (tracing never executes the kernel).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mds
from repro.kernels import ops, ref
from repro.kernels.coded_pipeline import (
    coded_fft_bucket_streaming,
    coded_fft_bucket_streaming_masked,
    subsets_from_masks_body,
)
from repro.kernels.fourstep_fft import fourstep_streaming, multistep_fused

pytestmark = pytest.mark.kernels


def _relerr(got, want):
    got = np.asarray(got)
    want = np.asarray(want)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-30)


def _planes(x):
    return (jnp.asarray(x.real.astype(np.float32)),
            jnp.asarray(x.imag.astype(np.float32)))


# ------------------------------------------------- streaming four-step
@pytest.mark.parametrize("a,b,batch,bq,ba,bb", [
    (8, 16, 3, 2, 4, 4),     # multi-tile both phases, ragged batch
    (16, 16, 5, 2, 16, 16),  # single tile per phase (degenerate grid)
    (32, 8, 4, 4, 8, 2),     # tall A, narrow B tiles
])
def test_fourstep_streaming_parity(a, b, batch, bq, ba, bb):
    """Interpret-mode parity vs numpy on forced multi-tile grids: the
    double-buffered copy/compute interleave must be bit-equivalent to the
    monolithic four-step at every tiling."""
    ell = a * b
    rng = np.random.default_rng(ell + batch)
    x = rng.standard_normal((batch, ell)) + 1j * rng.standard_normal(
        (batch, ell))
    xr, xi = _planes(x)
    far, fai = ops._dft_planes(a)
    fbr, fbi = ops._dft_planes(b)
    wr, wi = ops._twiddle_planes(a, b)
    outr, outi = fourstep_streaming(
        xr.reshape(batch, a, b), xi.reshape(batch, a, b),
        far, fai, wr, wi, fbr, fbi,
        block_q=bq, block_a=ba, block_b=bb, interpret=True)
    got = (np.asarray(outr) + 1j * np.asarray(outi)).reshape(batch, ell)
    assert _relerr(got, np.fft.fft(x, axis=-1)) < 1e-5


def test_fourstep_streaming_over_vmem_budget():
    """A shape whose fused (A, B) working set exceeds _FUSED_MAX_ELEMS:
    the exact population the streaming grid exists for."""
    a = b = 1024                      # a*b = 1M > 512*512 budget
    ell = a * b
    assert a * b > ops._FUSED_MAX_ELEMS
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1, ell)) + 1j * rng.standard_normal((1, ell))
    xr, xi = _planes(x)
    far, fai = ops._dft_planes(a)
    fbr, fbi = ops._dft_planes(b)
    wr, wi = ops._twiddle_planes(a, b)
    outr, outi = fourstep_streaming(
        xr.reshape(1, a, b), xi.reshape(1, a, b),
        far, fai, wr, wi, fbr, fbi,
        block_q=1, block_a=256, block_b=256, interpret=True)
    got = (np.asarray(outr) + 1j * np.asarray(outi)).reshape(1, ell)
    assert _relerr(got, np.fft.fft(x, axis=-1)) < 1e-4


def test_fourstep_streaming_one_launch_jaxpr():
    """TPU-like dispatch: variant="streaming" lowers to exactly ONE
    pallas_call -- both compute phases and every DMA live inside it."""
    batch, ell = 4, 4096

    def run(xr, xi):
        return ops.fourstep_planar(xr, xi, interpret=False,
                                   variant="streaming")

    args = [jax.ShapeDtypeStruct((batch, ell), jnp.float32)] * 2
    jaxpr = str(jax.make_jaxpr(run)(*args))
    assert jaxpr.count("fourstep_fft_streaming") == 1


# ------------------------------------------------- multistep (mixed radix)
@pytest.mark.parametrize("factors", [(4, 8), (4, 4, 4), (2, 4, 8), (8, 8, 8)])
def test_multistep_fused_parity(factors):
    """The mixed-radix fused kernel == numpy at every radix plan, through
    the ops dispatcher (which owns the digit-reversal unscramble)."""
    ell = int(np.prod(factors))
    rng = np.random.default_rng(ell)
    x = rng.standard_normal((3, ell)) + 1j * rng.standard_normal((3, ell))
    xr, xi = _planes(x)
    for interpret in (None, True):
        outr, outi = ops.fourstep_planar(
            xr, xi, interpret=interpret, variant="fused", factors=factors)
        got = np.asarray(outr) + 1j * np.asarray(outi)
        assert _relerr(got, np.fft.fft(x, axis=-1)) < 1e-5, (factors,
                                                             interpret)


def test_multistep_enables_over_budget_fused():
    """A three-factor plan keeps L = 2^18 on the fused kernel path even
    though its balanced 2-split (512, 512) busts the two-factor budget."""
    ell = 1 << 18
    factors = (64, 64, 64)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((1, ell)) + 1j * rng.standard_normal((1, ell))
    xr, xi = _planes(x)
    outr, outi = ops.fourstep_planar(xr, xi, interpret=None,
                                     variant="fused", factors=factors)
    got = np.asarray(outr) + 1j * np.asarray(outi)
    assert _relerr(got, np.fft.fft(x, axis=-1)) < 1e-4


# ------------------------------------------------- in-kernel first_available
def test_subsets_from_masks_matches_argsort_exhaustively():
    """The Mosaic-safe rank/one-hot subset selection == ops.mask_subsets
    (stable argsort) for EVERY mask pattern -- including short rows, whose
    filler slots must keep the Lagrange nodes distinct."""
    for n, m in [(6, 4), (8, 4), (7, 3), (5, 2)]:
        masks = np.stack([
            np.array([(k >> i) & 1 for i in range(n)], bool)
            for k in range(2 ** n)])
        want = np.asarray(ops.mask_subsets(jnp.asarray(masks), m))
        got = np.asarray(subsets_from_masks_body(
            jnp.asarray(masks).astype(jnp.float32), m))
        assert np.array_equal(want, got), (n, m)


# ------------------------------------------------- streaming bucket kernel
def _bucket_case(s, m, n, q, seed=0):
    rng = np.random.default_rng(seed)
    g = mds.rs_generator(n, m, jnp.complex64)
    gr, gi = ref.planar(g)
    x = rng.standard_normal((q, s)) + 1j * rng.standard_normal((q, s))
    xr, xi = _planes(x)
    masks = np.zeros((q, n), bool)
    for r in range(q):
        masks[r, rng.choice(n, size=min(n, m + 1), replace=False)] = True
    return g, gr, gi, x, xr, xi, masks


@pytest.mark.parametrize("s,m,n,q,bq,ba,bb", [
    (512, 4, 6, 3, 2, 4, 4),    # small shape, forced multi-tile grid
    (256, 2, 4, 5, 2, 2, 8),
])
def test_streaming_bucket_forced_multi_tile_parity(s, m, n, q, bq, ba, bb):
    """Direct kernel-level parity with tiny tiles: many grid steps per
    phase, ragged batch padding, masked and unmasked variants."""
    g, gr, gi, x, xr, xi, masks = _bucket_case(s, m, n, q)
    ell = s // m
    a, b = ops.split_factor(ell)
    planes = (*ops._dft_planes(a), *ops._twiddle_planes(a, b),
              *ops._dft_planes(b),
              *ops._recombine_planes_scrambled(s, m, a, b))
    want = np.fft.fft(x, axis=-1)

    yr, yi = coded_fft_bucket_streaming_masked(
        xr, xi, jnp.asarray(masks), gr, gi, *planes,
        block_q=bq, block_a=ba, block_b=bb, interpret=True)
    assert _relerr(np.asarray(yr) + 1j * np.asarray(yi), want) < 1e-4

    subsets = ops.mask_subsets(jnp.asarray(masks), m)
    dr, di = ops.lagrange_scatter_planes(subsets, n)
    yr, yi = coded_fft_bucket_streaming(
        xr, xi, dr, di, gr, gi, *planes,
        block_q=bq, block_a=ba, block_b=bb, interpret=True)
    assert _relerr(np.asarray(yr) + 1j * np.asarray(yi), want) < 1e-4


def test_streaming_bucket_over_vmem_parity():
    """The acceptance shape class: a bucket whose working set exceeds the
    fused VMEM gate runs the ONE-launch streaming path (dispatcher-routed)
    and still matches numpy through interpret mode."""
    s, m, n, q = 1 << 17, 2, 4, 2
    assert not ops.coded_bucket_fusable(s, m, n)
    assert ops.coded_bucket_streamable(s, m, n)
    g, gr, gi, x, xr, xi, masks = _bucket_case(s, m, n, q, seed=3)
    yr, yi = ops.coded_bucket_masked(xr, xi, jnp.asarray(masks), gr, gi, s,
                                     interpret=True)
    assert _relerr(np.asarray(yr) + 1j * np.asarray(yi),
                   np.fft.fft(x, axis=-1)) < 1e-3


def test_streaming_bucket_one_launch_jaxpr(monkeypatch):
    """Jaxpr pin (the acceptance criterion): on TPU-like dispatch an
    over-VMEM bucket lowers to exactly ONE pallas_call -- the streaming
    kernel -- with no stage-path fallback and no extra launches."""
    monkeypatch.setattr(ops, "default_interpret", lambda: False)
    s, m, n, q = 1 << 17, 2, 4, 2
    assert not ops.coded_bucket_fusable(s, m, n)
    g = mds.rs_generator(n, m, jnp.complex64)
    gr, gi = ref.planar(g)

    def run(xr, xi, masks):
        return ops.coded_bucket_masked(xr, xi, masks, gr, gi, s)

    args = [jax.ShapeDtypeStruct((q, s), jnp.float32)] * 2 + [
        jax.ShapeDtypeStruct((q, n), jnp.bool_)]
    jaxpr = str(jax.make_jaxpr(run)(*args))
    assert jaxpr.count("coded_fft_bucket_streaming_masked") == 1
    assert "coded_fft_bucket_masked" not in jaxpr.replace(
        "coded_fft_bucket_streaming_masked", "")


def test_service_routes_over_vmem_bucket_to_streaming(monkeypatch):
    """The serving layer inherits the routing: an over-VMEM c2c bucket's
    device-decode runner traces to the streaming kernel launch."""
    from repro.serving.fft_service import FFTService, FFTServiceConfig

    monkeypatch.setattr(ops, "default_interpret", lambda: False)
    s, m, n = 1 << 17, 2, 4
    svc = FFTService(FFTServiceConfig(s=s, m=m, n_workers=n, autotune=False))
    runner = svc._runner_for(s, 2, "c2c")
    xb = jax.ShapeDtypeStruct((2, s), jnp.complex64)
    masks = jax.ShapeDtypeStruct((2, n), jnp.bool_)
    jaxpr = str(jax.make_jaxpr(runner)(xb, masks))
    assert jaxpr.count("coded_fft_bucket_streaming_masked") == 1


def test_masked_bucket_ships_raw_masks(monkeypatch):
    """Zero decode metadata: the fused masked kernel's jaxpr consumes the
    (q, N) boolean masks directly -- no argsort, no host subsets."""
    monkeypatch.setattr(ops, "default_interpret", lambda: False)
    s, m, n, q = 256, 4, 8, 4
    g = mds.rs_generator(n, m, jnp.complex64)
    gr, gi = ref.planar(g)

    def run(xr, xi, masks):
        return ops.coded_bucket_masked(xr, xi, masks, gr, gi, s)

    args = [jax.ShapeDtypeStruct((q, s), jnp.float32)] * 2 + [
        jax.ShapeDtypeStruct((q, n), jnp.bool_)]
    jaxpr = str(jax.make_jaxpr(run)(*args))
    assert "coded_fft_bucket_masked" in jaxpr
    assert "argsort" not in jaxpr
