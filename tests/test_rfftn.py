"""n-D real coded transforms (DESIGN.md §9): CodedRFFTN / CodedIRFFTN
against numpy.fft.rfftn/irfftn, the documented even-shard ValueError, the
FFTService rfftn/irfftn kinds, and the shard_map mesh path.

The drawn-config property sweep lives in tests/test_properties.py; this
module pins shapes, protocol details, adjoint structure, and the service
plumbing.
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CodedIRFFTN,
    CodedRFFTN,
    adjoint_fold_nd,
    pack_half_nd,
    require_even_shards,
    split_packed_nd,
)
from repro.core.rfft import CodedIRFFT, CodedRFFT
from repro.serving import FFTService, FFTServiceConfig

C64 = jnp.complex64
C128 = jnp.complex128


def _relerr(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-12)


# ------------------------------------------------------------- plan parity
@pytest.mark.parametrize("shape,factors,n", [
    ((8, 8), (2, 2), 6),
    ((16, 4), (4, 1), 5),
    ((12, 6), (2, 3), 8),
    ((8, 4, 4), (2, 1, 2), 5),
    ((16,), (4,), 6),          # 1-D degenerate: must agree with CodedRFFT
])
def test_rfftn_irfftn_roundtrip_matches_numpy(shape, factors, n):
    rng = np.random.default_rng(sum(shape))
    t = rng.normal(size=shape)
    plan = CodedRFFTN(shape=shape, factors=factors, n_workers=n, dtype=C128,
                      backend="reference")
    got = plan.run(jnp.asarray(t))
    want = np.fft.rfftn(t)
    assert got.shape == want.shape
    assert _relerr(got, want) < 1e-8

    iplan = CodedIRFFTN(shape=shape, factors=factors, n_workers=n,
                        dtype=C128, backend="reference")
    back = iplan.run(jnp.asarray(np.asarray(got)))
    assert back.shape == t.shape
    assert np.abs(np.asarray(back) - t).max() < 1e-8


def test_rfftn_every_subset_with_nan_stragglers():
    """Any m-subset decodes; straggler rows are NaN-poisoned to prove the
    decode never reads them (the acceptance semantics)."""
    shape, factors, n = (8, 8), (2, 2), 6
    rng = np.random.default_rng(3)
    t = rng.normal(size=shape)
    plan = CodedRFFTN(shape=shape, factors=factors, n_workers=n, dtype=C128,
                      backend="reference")
    b = plan.worker_compute(plan.encode(jnp.asarray(t)))
    want = np.fft.rfftn(t)
    for sub in itertools.combinations(range(n), plan.m):
        mask = np.zeros(n, bool)
        mask[list(sub)] = True
        poisoned = jnp.where(
            jnp.asarray(mask)[:, None, None], b, jnp.nan)
        got = np.asarray(plan.decode(poisoned, mask=jnp.asarray(mask)))
        assert not np.isnan(got).any()
        assert _relerr(got, want) < 1e-7, sub


def test_rfftn_kernel_backend_batched():
    """Default (kernel) backend, batched: per-axis four-step worker sweep
    over half-size shards still matches numpy."""
    plan = CodedRFFTN(shape=(16, 16), factors=(2, 2), n_workers=6)
    assert plan.resolved_backend == "kernel"
    rng = np.random.default_rng(7)
    tb = rng.normal(size=(3, 16, 16)).astype(np.float32)
    got = plan.run(jnp.asarray(tb))
    want = np.fft.rfftn(tb.astype(np.float64), axes=(-2, -1))
    assert _relerr(got, want) < 5e-3


def test_irfftn_inconsistent_endpoints_match_numpy_exactly():
    """Non-Hermitian-consistent endpoint bins: the spectral symmetrization
    of the message stage reproduces numpy.fft.irfftn exactly (endpoint
    anti-Hermitian parts discarded AFTER the rest-axis transforms)."""
    shape, factors = (8, 8), (2, 2)
    rng = np.random.default_rng(11)
    h = shape[-1] // 2 + 1
    y = rng.normal(size=shape[:-1] + (h,)) + 1j * rng.normal(
        size=shape[:-1] + (h,))
    plan = CodedIRFFTN(shape=shape, factors=factors, n_workers=6,
                       dtype=C128, backend="reference")
    got = plan.run(jnp.asarray(y))
    want = np.fft.irfftn(y, s=shape, axes=tuple(range(len(shape))))
    assert np.abs(np.asarray(got) - want).max() < 1e-8


def test_rfftn_reduces_to_rfft_in_1d():
    """shape=(s,) CodedRFFTN/CodedIRFFTN computes the same transform as
    the 1-D CodedRFFT/CodedIRFFT plans (same code, same shard payload)."""
    s, m, n = 64, 4, 8
    rng = np.random.default_rng(5)
    x = rng.normal(size=s)
    p1 = CodedRFFT(s=s, m=m, n_workers=n, dtype=C128, backend="reference")
    pn = CodedRFFTN(shape=(s,), factors=(m,), n_workers=n, dtype=C128,
                    backend="reference")
    assert pn.worker_shard_shape == p1.worker_shard_shape
    np.testing.assert_allclose(np.asarray(pn.run(jnp.asarray(x))),
                               np.asarray(p1.run(jnp.asarray(x))), atol=1e-9)
    y = np.fft.rfft(x)
    i1 = CodedIRFFT(s=s, m=m, n_workers=n, dtype=C128, backend="reference")
    in_ = CodedIRFFTN(shape=(s,), factors=(m,), n_workers=n, dtype=C128,
                      backend="reference")
    np.testing.assert_allclose(np.asarray(in_.run(jnp.asarray(y))),
                               np.asarray(i1.run(jnp.asarray(y))), atol=1e-9)


def test_rfftn_payload_is_half_of_c2c_nd():
    """The communication claim in n-D: rfftn worker shards carry HALF the
    elements of the c2c n-D plan at the same (shape, m)."""
    from repro.core import CodedFFTND

    shape, factors, n = (16, 16), (2, 2), 8
    c2c = CodedFFTND(shape=shape, factors=factors, n_workers=n)
    r2c = CodedRFFTN(shape=shape, factors=factors, n_workers=n)
    assert (2 * np.prod(r2c.worker_shard_shape)
            == np.prod(c2c.worker_shard_shape))
    a = r2c.encode(jnp.zeros(shape, jnp.float32))
    assert a.shape == (n,) + r2c.worker_shard_shape


def test_adjoint_pack_split_inverses():
    """pack_half_nd inverts split_packed_nd on jointly-Hermitian spectra,
    and adjoint_fold_nd's folded shards ifftn to the interleave (the §9
    structural identities, independent of any plan)."""
    rng = np.random.default_rng(2)
    c = rng.normal(size=(3, 4, 8))                    # real shards
    zh = np.fft.fftn(c[..., ::2] + 1j * c[..., 1::2], axes=(1, 2))
    half = split_packed_nd(jnp.asarray(zh), 8, rest_axes=(1,))
    full = np.fft.fftn(c, axes=(1, 2))
    np.testing.assert_allclose(np.asarray(half), full[..., :5], atol=1e-10)
    packed = pack_half_nd(jnp.asarray(full), 8, rest_axes=(1,))
    np.testing.assert_allclose(np.asarray(packed), zh, atol=1e-10)

    shape, factors = (8, 8), (2, 4)
    t = rng.normal(size=shape)
    folded = adjoint_fold_nd(jnp.asarray(np.fft.fftn(t)), shape, factors,
                             C128)
    from repro.core import interleave_nd

    shards = np.asarray(interleave_nd(jnp.asarray(t), factors))
    got = np.fft.ifftn(np.asarray(folded), axes=(1, 2)) / np.prod(factors)
    np.testing.assert_allclose(got.real, shards, atol=1e-9)
    np.testing.assert_allclose(got.imag, 0, atol=1e-9)


# -------------------------------------------------- even-shard ValueError
def test_even_shard_value_error_is_documented_and_raised():
    """The real-kind packing constraint fails LOUDLY with the documented
    '2m | s' message (README / DESIGN.md §9) -- 1-D plans, n-D plans, and
    the shared helper -- never as a downstream reshape error."""
    with pytest.raises(ValueError, match=r"2m \| s"):
        require_even_shards(30, 6)                 # L = 5, odd
    require_even_shards(60, 6)                     # L = 10: fine
    with pytest.raises(ValueError, match=r"2m \| s"):
        CodedRFFT(s=30, m=6, n_workers=8)          # 30 % 12 != 0
    with pytest.raises(ValueError, match=r"2m \| s"):
        CodedIRFFT(s=30, m=6, n_workers=8)
    with pytest.raises(ValueError, match=r"2m \| s"):
        CodedRFFTN(shape=(8, 6), factors=(2, 2), n_workers=8)  # L_last = 3
    with pytest.raises(ValueError, match=r"2m \| s"):
        CodedIRFFTN(shape=(8, 6), factors=(2, 2), n_workers=8)


def test_even_shard_error_reaches_service_clients():
    """A service request whose length breaks 2m | s surfaces the same
    documented error instead of an opaque shape failure."""
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8))
    with pytest.raises(ValueError, match=r"2m \| s"):
        svc.submit_rfft(jnp.zeros(252, jnp.float32))   # 252 % 8 != 0
    with pytest.raises(ValueError, match=r"2m \| s"):
        # odd last axis: no pair packing exists at any factorization
        svc.submit_rfftn(jnp.zeros((4, 7), jnp.float32))
    # but a shape that only a real-kind-aware factor placement can serve
    # IS served (plan_factors even_last_shard keeps the last shard even)
    y = svc.submit_rfftn(jnp.zeros((4, 6), jnp.float32))
    assert y.shape == (4, 4)


# ------------------------------------------------------------ the service
def test_service_rfftn_and_irfftn_kinds():
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=3))
    rng = np.random.default_rng(1)
    ts = [rng.normal(size=(16, 16)).astype(np.float32) for _ in range(5)]
    for t, y in zip(ts, svc.submit_batch(
            [jnp.asarray(t) for t in ts], kind="rfftn")):
        want = np.fft.rfftn(t.astype(np.float64))
        assert y.shape == want.shape
        assert _relerr(y, want) < 1e-2
    ys = [np.fft.rfftn(t).astype(np.complex64) for t in ts]
    for t, z in zip(ts, svc.submit_batch(
            [jnp.asarray(y) for y in ys], kind="irfftn")):
        assert z.shape == t.shape
        assert np.abs(z - t).max() < 1e-2
    # single-request conveniences
    y = svc.submit_rfftn(jnp.asarray(ts[0]))
    assert _relerr(y, np.fft.rfftn(ts[0].astype(np.float64))) < 1e-2
    z = svc.submit_irfftn(jnp.asarray(ys[0]))
    assert np.abs(z - ts[0]).max() < 1e-2
    # n-D kinds never take the 1-D planar kernel executors
    assert not svc._kernel_path((16, 16), "rfftn")
    assert not svc._kernel_path((16, 16), "irfftn")


def test_service_mixed_kinds_with_nd():
    """One submit_batch call mixing all five kinds buckets correctly and
    returns every result in submission order."""
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=9))
    rng = np.random.default_rng(2)
    t = rng.normal(size=(16, 16)).astype(np.float32)
    x1 = (rng.normal(size=256) + 1j * rng.normal(size=256)).astype(
        np.complex64)
    xr = rng.normal(size=256).astype(np.float32)
    yh = np.fft.rfft(xr).astype(np.complex64)
    yn = np.fft.rfftn(t).astype(np.complex64)
    outs = svc.submit_batch(
        [jnp.asarray(x1), jnp.asarray(t), jnp.asarray(xr),
         jnp.asarray(yh), jnp.asarray(yn)],
        kind=["c2c", "rfftn", "r2c", "c2r", "irfftn"])
    assert _relerr(outs[0], np.fft.fft(x1.astype(np.complex128))) < 1e-2
    assert _relerr(outs[1], np.fft.rfftn(t.astype(np.float64))) < 1e-2
    assert _relerr(outs[2], np.fft.rfft(xr.astype(np.float64))) < 1e-2
    assert np.abs(outs[3] - xr).max() < 1e-2
    assert np.abs(outs[4] - t).max() < 1e-2
    # five kinds -> five buckets, each charged its own arrival draw
    assert svc.stats.batches == 5
    assert svc.stats.requests == 5


def test_service_rfftn_warmup_and_wire_scale():
    """warmup() accepts shape tuples for the n-D kinds, and the straggler
    model charges rfftn/irfftn buckets the halved real-kind wire share."""
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=0))
    assert svc.warmup(lengths=[(16, 16)], kinds=("rfftn", "irfftn"),
                      buckets=(1, 2)) == 4
    lat_r, _ = svc._simulate_arrivals(4096, kind="rfftn")
    svc2 = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=0))
    lat_c, _ = svc2._simulate_arrivals(4096, kind="c2c")
    # same seed, same draws: real-kind arrivals are never slower and
    # strictly faster on average (wire share halved)
    assert lat_r.mean() < lat_c.mean()


# ---------------------------------------------------------------- the mesh
def test_rfftn_under_mesh_shard_map():
    """DistributedCodedPlan runs the n-D real plans UNCHANGED: half-size
    packed shard shapes and per-request masks thread through both
    shard_map stages (1-wide axis keeps it single-device; the 8-device
    run lives in test_coded_runtime's subprocess)."""
    from repro.distributed import DistributedCodedPlan, test_mesh

    mesh = test_mesh((1,), ("workers",))
    rng = np.random.default_rng(0)
    t = rng.normal(size=(3, 16, 16)).astype(np.float32)
    masks = np.stack([np.roll(np.arange(8) % 2 == 0, i) for i in range(3)])
    plan = CodedRFFTN(shape=(16, 16), factors=(2, 2), n_workers=8)
    d = DistributedCodedPlan(plan, mesh, masked_fill=float("nan"))
    out = np.asarray(d.run(jnp.asarray(t), jnp.asarray(masks)))
    want = np.fft.rfftn(t.astype(np.float64), axes=(-2, -1))
    assert not np.isnan(out).any()
    assert _relerr(out, want) < 1e-2

    iplan = CodedIRFFTN(shape=(16, 16), factors=(2, 2), n_workers=8)
    di = DistributedCodedPlan(iplan, mesh, masked_fill=float("nan"))
    y = np.fft.rfftn(t, axes=(-2, -1)).astype(np.complex64)
    back = np.asarray(di.run(jnp.asarray(y), jnp.asarray(masks)))
    assert np.abs(back - t).max() < 1e-2
