"""Elastic resharding: device-continuity round-trips across mesh resizes.

The elastic.py contract (DESIGN.md §12): ``reshard``/``reshard_like`` move
a pytree through global shapes, so an 8-device -> 4-device -> 8-device
migration is BIT-EXACT, including PartitionSpecs that name axes the
shrunken mesh no longer has (pod removal).  The mesh tests force 8 host
devices in a subprocess (the main process keeps its default 1-CPU world);
pure membership logic for ElasticWorkerPool lives in tests/test_faults.py.
"""

import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.elastic import _resolve

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed import reshard, reshard_like, test_mesh

m8 = test_mesh((8,), ("d",))
m4 = test_mesh((4,), ("d",))
m2x4 = test_mesh((2, 4), ("pod", "d"))

# mixed pytree: sharded f32 matrix, replicated complex vector, int leaf
tree = {
    "w": jnp.arange(64.0, dtype=jnp.float32).reshape(8, 8),
    "tw": jnp.exp(2j * jnp.pi * jnp.arange(16) / 16).astype(jnp.complex64),
    "step": jnp.asarray(7, jnp.int32),
}
specs = {"w": P("d", None), "tw": P(), "step": P()}

# 8 -> 4 -> 8: bit-exact round trip for every leaf
t8 = reshard(tree, m8, specs)
t4 = reshard(t8, m4, specs)
t8b = reshard(t4, m8, specs)
for k in tree:
    np.testing.assert_array_equal(np.asarray(t8b[k]), np.asarray(tree[k]))
    np.testing.assert_array_equal(np.asarray(t4[k]), np.asarray(tree[k]))

# landing shardings are the requested ones (equivalence, not spec
# identity: a dropped axis leaves P(None) which equals P() only logically)
assert t8b["w"].sharding.is_equivalent_to(NamedSharding(m8, P("d", None)), 2)
assert t4["w"].sharding.is_equivalent_to(NamedSharding(m4, P("d", None)), 2)

# pspecs naming DROPPED axes: a ("pod", "d") layout reshards onto a mesh
# with no "pod" axis -- the missing name is silently dropped, values exact
pod_specs = {"w": P(("pod", "d"), None), "tw": P("pod"), "step": P()}
tp = reshard(tree, m2x4, pod_specs)
tdown = reshard(tp, m8, pod_specs)
for k in tree:
    np.testing.assert_array_equal(np.asarray(tdown[k]), np.asarray(tree[k]))
assert tdown["w"].sharding.is_equivalent_to(NamedSharding(m8, P("d", None)), 2)
assert tdown["tw"].sharding.is_equivalent_to(NamedSharding(m8, P()), 1)

# reshard_like: mesh swap keeps each leaf's CURRENT spec without the
# caller restating it; dropped-axis specs resolve the same way
tl = reshard_like(tp, m4)
for k in tree:
    np.testing.assert_array_equal(np.asarray(tl[k]), np.asarray(tree[k]))
assert tl["w"].sharding.is_equivalent_to(NamedSharding(m4, P("d", None)), 2)

# host numpy leaves ride along (device_put places them fresh)
host = {"w": np.ones((8, 4), np.float32)}
hp = reshard(host, m4, {"w": P("d", None)})
np.testing.assert_array_equal(np.asarray(hp["w"]), host["w"])
print("SUBPROC_OK")
"""


@pytest.mark.slow
def test_elastic_reshard_roundtrip_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=os.getcwd(),
                       capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SUBPROC_OK" in r.stdout


def test_resolve_drops_missing_axes_single_device():
    """Spec-resolution logic is pure; exercise it without a mesh resize:
    names absent from the target mesh drop to None, tuples keep only the
    axes that exist, and non-P leaves resolve to replicated."""
    from repro.distributed.mesh import test_mesh

    mesh = test_mesh((1,), ("d",))
    assert _resolve(P("pod", None), mesh).spec == P(None, None)
    assert _resolve(P(("pod", "d"), None), mesh).spec == P(("d",), None)
    assert _resolve(P(("pod", "host")), mesh).spec == P(None)
    assert _resolve(None, mesh).spec == P()
    assert _resolve(P("d"), mesh).spec == P("d")
