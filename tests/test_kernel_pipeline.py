"""Kernel-first hot path: fused pipeline parity, backend dispatch rules,
and decode-matrix LRU correctness (DESIGN.md §6).

Parity tests pin ``interpret=True`` so the fused kernels are exercised
through the real Pallas machinery on CPU in every PR (the CI
kernels-interpret job runs this module); dispatch tests cover the
``interpret=None`` default (direct kernel-body evaluation off-TPU) and
the plan/service backend-selection rules.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodedFFT, CodedFFTND, mds
from repro.core.coded_fft import _default_fft
from repro.kernels import ops, ref
from repro.serving import FFTService, FFTServiceConfig
from repro.serving.decode_cache import DecodeMatrixCache

pytestmark = pytest.mark.kernels

RTOL = 3e-4


def _randc(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        (rng.normal(size=shape) + 1j * rng.normal(size=shape))
        .astype(np.complex64))


def _relerr(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-12)


# --------------------------------------------- fused encode+worker parity
@pytest.mark.parametrize("m,n,ell", [
    (4, 8, 512),     # the service default shape (pow2)
    (4, 6, 384),     # non-power-of-two composite L
    (4, 6, 189),     # odd composite L (split_factor -> 9 x 21)
    (2, 5, 127),     # prime L: split_factor falls back to (1, L)
    (3, 7, 96),      # odd m
])
@pytest.mark.parametrize("fused", [True, False])
def test_encode_worker_parity_interpret(m, n, ell, fused):
    """Fused encode+worker == encode_dft + fft oracle, through Pallas
    interpret mode, for non-power-of-two and odd L (split_factor
    fallbacks) in both the fused and the two-pass (separate) paths."""
    c = _randc((3, m, ell), seed=ell + m)
    g = mds.rs_generator(n, m, jnp.complex64)
    cr, ci = ref.planar(c)
    gr, gi = ref.planar(g)
    br, bi = ops.encode_worker(cr, ci, gr, gi, interpret=True, fused=fused)
    wr, wi = ref.encode_worker_ref(cr, ci, g)
    assert _relerr(ref.unplanar(br, bi), ref.unplanar(wr, wi)) < RTOL
    # and the default dispatch (direct path off-TPU) is the same math
    # (not bit-identical: XLA may reassociate the f32 accumulations)
    br2, bi2 = ops.encode_worker(cr, ci, gr, gi, fused=fused)
    assert _relerr(ref.unplanar(br2, bi2), ref.unplanar(br, bi)) < 1e-5


def test_split_factor_prime_fallback():
    assert ops.split_factor(127) == (1, 127)
    a, b = ops.split_factor(189)
    assert a * b == 189 and 1 < a <= b


def test_degenerate_factorization_falls_back_to_platform_fft():
    """A large prime shard length must NOT build a dense (L, L) DFT matrix
    (regression: the default kernel worker at L=10007 would have allocated
    ~800 MB of DFT planes and run O(L^2) flops); fourstep_planar falls
    back to the platform FFT past the (B, B) budget and stays exact."""
    ell = 10007  # prime
    a, b = ops.split_factor(ell)
    assert b * b > ops._FUSED_MAX_ELEMS
    x = _randc((2, ell), seed=13)
    xr, xi = ref.planar(x)
    got = ref.unplanar(*ops.fourstep_planar(xr, xi))
    want = np.fft.fft(np.asarray(x, np.complex128), axis=-1)
    assert _relerr(got, want) < 1e-3
    # end-to-end through the default plan (s = m * L)
    plan = CodedFFT(s=4 * ell, m=4, n_workers=8)
    xs = _randc((4 * ell,), seed=14)
    y = plan.run(xs)
    assert _relerr(y, np.fft.fft(np.asarray(xs, np.complex128))) < 1e-3


# --------------------------------------------------- whole-bucket pipeline
@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (756, 4, 6), (254, 2, 5)])
def test_coded_bucket_kernel_parity(s, m, n):
    """One-launch bucket pipeline (interleave -> encode -> worker ->
    decode -> recombine) == jnp.fft, via Pallas interpret, including odd
    and prime shard lengths."""
    assert ops.coded_bucket_fusable(s, m, n)
    q = 3
    xb = _randc((q, s), seed=s)
    g = mds.rs_generator(n, m, jnp.complex64)
    rng = np.random.default_rng(s)
    masks = np.zeros((q, n), bool)
    for row in masks:
        row[rng.choice(n, size=m, replace=False)] = True
    cache = DecodeMatrixCache(np.asarray(g))
    dmats = cache.matrices(masks)
    xr, xi = ref.planar(xb)
    gr, gi = ref.planar(g)
    dr = jnp.asarray(dmats.real.astype(np.float32))
    di = jnp.asarray(dmats.imag.astype(np.float32))
    yr, yi = ops.coded_bucket(xr, xi, dr, di, gr, gi, s, interpret=True)
    want = np.fft.fft(np.asarray(xb, np.complex128), axis=-1)
    assert _relerr(ref.unplanar(yr, yi), want) < 1e-3
    # direct path (off-TPU default) computes the identical body
    # (not bit-identical: XLA may reassociate the f32 accumulations)
    yr2, yi2 = ops.coded_bucket(xr, xi, dr, di, gr, gi, s)
    assert _relerr(ref.unplanar(yr2, yi2), ref.unplanar(yr, yi)) < 1e-5


@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (756, 4, 6)])
def test_coded_bucket_direct_matches_pallas_bucket(s, m, n):
    """The off-TPU direct executor (platform-FFT worker stage, gathered
    compact decode) == the Pallas bucket kernel == jnp.fft."""
    q = 3
    xb = _randc((q, s), seed=s + 1)
    g = mds.rs_generator(n, m, jnp.complex64)
    rng = np.random.default_rng(s)
    masks = np.zeros((q, n), bool)
    for row in masks:
        row[rng.choice(n, size=m, replace=False)] = True
    cache = DecodeMatrixCache(np.asarray(g))
    invs, subsets = cache.compact(masks)
    dmats = cache.matrices(masks)
    xr, xi = ref.planar(xb)
    gr, gi = ref.planar(g)
    yr, yi = ops.coded_bucket_direct(
        xr, xi, jnp.asarray(invs.real.astype(np.float32)),
        jnp.asarray(invs.imag.astype(np.float32)),
        jnp.asarray(subsets), gr, gi, s)
    want = np.fft.fft(np.asarray(xb, np.complex128), axis=-1)
    assert _relerr(ref.unplanar(yr, yi), want) < 1e-3
    kr, ki = ops.coded_bucket(
        xr, xi, jnp.asarray(dmats.real.astype(np.float32)),
        jnp.asarray(dmats.imag.astype(np.float32)), gr, gi, s,
        interpret=True)
    assert _relerr(ref.unplanar(yr, yi), ref.unplanar(kr, ki)) < 1e-4


def test_bcmatmul_and_batched_recombine_parity():
    q, m, n, ell = 5, 4, 8, 96
    a = _randc((q, m, n), seed=1)
    b = _randc((q, n, ell), seed=2)
    from repro.kernels.cmatmul import bcmatmul
    from repro.kernels.recombine import recombine_twiddle_dft_batched

    ar, ai = ref.planar(a)
    br, bi = ref.planar(b)
    cr, ci = bcmatmul(ar, ai, br, bi, block_q=2, block_l=32, interpret=True)
    wr, wi = ref.bcmatmul_ref(ar, ai, br, bi)
    np.testing.assert_allclose(np.asarray(cr), np.asarray(wr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(ci), np.asarray(wi), rtol=1e-4,
                               atol=1e-4)

    c = _randc((q, m, ell), seed=3)
    s = m * ell
    cr, ci = ref.planar(c)
    twr, twi, fr, fi = ops._recombine_planes(s, m)
    got = recombine_twiddle_dft_batched(
        cr, ci, twr, twi, fr, fi, block_q=2, block_l=32, interpret=True)
    want = ref.recombine_batched_ref(cr, ci, twr, twi, fr, fi)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------- backend dispatch rules
def test_backend_dispatch_rules():
    # c64 + default backend -> kernel engine
    plan = CodedFFT(s=256, m=4, n_workers=6)
    assert plan.backend == "kernel" and plan.resolved_backend == "kernel"
    # explicit reference backend wins
    assert CodedFFT(s=256, m=4, n_workers=6,
                    backend="reference").resolved_backend == "reference"
    # complex128 (numerics tier) always resolves to the jnp oracle
    p128 = CodedFFT(s=256, m=4, n_workers=6, dtype=jnp.complex128)
    assert p128.resolved_backend == "reference"
    # explicit worker_fn plug-in overrides the backend worker
    p = CodedFFT(s=256, m=4, n_workers=6, worker_fn=_default_fft)
    assert p.resolved_worker_fn is _default_fft


def test_kernel_backend_plan_run_matches_fft():
    """Default (kernel-backend) plan.run == jnp.fft, batched and unbatched,
    including NaN-poisoned stragglers under a mask."""
    plan = CodedFFT(s=756, m=4, n_workers=6)  # odd L = 189
    xb = _randc((3, 756), seed=5)
    out = plan.run(xb)
    want = np.fft.fft(np.asarray(xb, np.complex128), axis=-1)
    assert _relerr(out, want) < 1e-3
    b = plan.worker_compute(plan.encode(xb[0]))
    b = b.at[jnp.asarray([1, 4])].set(jnp.nan)
    mask = jnp.asarray([True, False, True, True, False, True])
    got = plan.decode(b, mask=mask)
    assert _relerr(got, want[0]) < 1e-3


def test_kernel_backend_nd_plan():
    plan = CodedFFTND(shape=(16, 12), factors=(2, 2), n_workers=6)
    assert plan.resolved_backend == "kernel"
    t = _randc((16, 12), seed=9)
    got = plan.run(t)
    want = np.fft.fft2(np.asarray(t, np.complex128))
    assert _relerr(got, want) < 1e-3


# ------------------------------------------------------- decode-matrix LRU
def test_decode_cache_hit_miss_and_eviction():
    g = np.asarray(mds.rs_generator(8, 4, jnp.complex64))
    cache = DecodeMatrixCache(g, maxsize=2)
    m1 = np.array([1, 1, 1, 1, 0, 0, 0, 0], bool)
    m2 = np.array([0, 1, 1, 1, 1, 0, 0, 0], bool)
    m3 = np.array([1, 0, 1, 0, 1, 0, 1, 0], bool)
    d1 = cache.matrix(m1)
    assert (cache.hits, cache.misses) == (0, 1)
    assert np.array_equal(cache.matrix(m1), d1)
    assert (cache.hits, cache.misses) == (1, 1)
    cache.matrix(m2)
    cache.matrix(m1)            # refresh m1 -> m2 is now LRU
    cache.matrix(m3)            # evicts m2
    assert len(cache) == 2
    assert (cache.hits, cache.misses) == (2, 3)
    cache.matrix(m2)            # recomputed after eviction, same value
    assert cache.misses == 4
    # matrices are the true scatter inverses regardless of cache churn
    for mask in (m1, m2, m3):
        d, inv, sub = cache._compute(mask)
        np.testing.assert_array_equal(sub, DecodeMatrixCache.subset_of(mask, 4))
        np.testing.assert_allclose(
            d[:, sub] @ g[sub, :].astype(np.complex128), np.eye(4),
            atol=1e-5)
        np.testing.assert_array_equal(d[:, sub], inv)
        assert np.all(d[:, [k for k in range(8) if k not in sub]] == 0)


def test_decode_cache_rejects_undecodable_mask():
    g = np.asarray(mds.rs_generator(8, 4, jnp.complex64))
    cache = DecodeMatrixCache(g)
    with pytest.raises(ValueError, match="responders"):
        cache.matrix(np.array([1, 1, 1, 0, 0, 0, 0, 0], bool))


def test_service_lru_churn_stays_correct():
    """With a tiny decode cache, straggler-mask churn forces constant
    evictions; every request must still decode exactly.  Pins the host-LRU
    FALLBACK path (``device_decode=False``) -- the default path builds
    decode matrices in-jit and is covered by test_lagrange_decode.py."""
    svc = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8, seed=11, decode_cache_size=2,
        device_decode=False))
    rng = np.random.default_rng(0)
    worst = 0.0
    for _ in range(6):
        xs = [jnp.asarray((rng.normal(size=256) + 1j * rng.normal(size=256))
                          .astype(np.complex64)) for _ in range(8)]
        for x, y in zip(xs, svc.submit_batch(xs)):
            worst = max(worst, float(np.max(np.abs(y - np.fft.fft(x)))))
    assert worst < 1e-2, worst
    st = svc.stats.summary()
    # churn proof: far more misses than the cache can hold
    assert st["decode_cache_misses"] > 2
    assert st["requests"] == 48


# ----------------------------------------- real-input (r2c/c2r) kernel path
@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (768, 4, 6), (240, 2, 5),
                                   (96, 3, 7)])
def test_coded_rfft_bucket_kernel_parity(s, m, n):
    """One-launch r2c bucket (pack -> encode -> half-length worker ->
    decode -> symmetry butterfly) == numpy.rfft via Pallas interpret,
    including odd shard lengths and odd m; direct path same math."""
    assert ops.coded_rbucket_fusable(s, m, n)
    q = 3
    rng = np.random.default_rng(s + m)
    xb = jnp.asarray(rng.normal(size=(q, s)).astype(np.float32))
    g = mds.rs_generator(n, m, jnp.complex64)
    masks = np.zeros((q, n), bool)
    for row in masks:
        row[rng.choice(n, size=m, replace=False)] = True
    cache = DecodeMatrixCache(np.asarray(g))
    dmats = cache.matrices(masks)
    gr, gi = ref.planar(g)
    dr = jnp.asarray(dmats.real.astype(np.float32))
    di = jnp.asarray(dmats.imag.astype(np.float32))
    want = np.fft.rfft(np.asarray(xb, np.float64), axis=-1)
    yr, yi = ops.coded_rbucket(xb, dr, di, gr, gi, s, interpret=True)
    assert _relerr(ref.unplanar(yr, yi), want) < 1e-3
    yr2, yi2 = ops.coded_rbucket(xb, dr, di, gr, gi, s)
    assert _relerr(ref.unplanar(yr2, yi2), ref.unplanar(yr, yi)) < 1e-5
    # gathered-compact direct executor (the off-TPU service path)
    invs, subsets = cache.compact(masks)
    yr3, yi3 = ops.coded_rbucket_direct(
        xb, jnp.asarray(invs.real.astype(np.float32)),
        jnp.asarray(invs.imag.astype(np.float32)),
        jnp.asarray(subsets), gr, gi, s)
    assert _relerr(ref.unplanar(yr3, yi3), want) < 1e-3


@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (240, 2, 5), (96, 3, 7)])
def test_coded_irbucket_direct_matches_numpy(s, m, n):
    """c2r direct bucket executor (adjoint message stage, packed ifft
    worker, compact decode, relabel unpack) == numpy.irfft."""
    q = 3
    rng = np.random.default_rng(s)
    xs = rng.normal(size=(q, s))
    yb = jnp.asarray(np.fft.rfft(xs, axis=-1).astype(np.complex64))
    g = mds.rs_generator(n, m, jnp.complex64)
    masks = np.zeros((q, n), bool)
    for row in masks:
        row[rng.choice(n, size=m, replace=False)] = True
    cache = DecodeMatrixCache(np.asarray(g))
    invs, subsets = cache.compact(masks)
    gr, gi = ref.planar(g)
    yr, yi = ref.planar(yb)
    out = ops.coded_irbucket_direct(
        yr, yi, jnp.asarray(invs.real.astype(np.float32)),
        jnp.asarray(invs.imag.astype(np.float32)),
        jnp.asarray(subsets), gr, gi, s)
    assert _relerr(out, xs) < 1e-3


@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (768, 4, 6), (240, 2, 5),
                                   (96, 3, 7)])
def test_coded_irfft_bucket_kernel_parity(s, m, n):
    """One-launch fused c2r bucket (adjoint message butterfly -> fused
    encode + half-length ifft worker -> decode -> pair unpack) ==
    numpy.irfft via Pallas interpret, over ADVERSARIAL byte-pattern masks
    -- pairs that select the same first-m responder subset but differ as
    byte patterns, plus exact-threshold scatters -- including odd shard
    lengths and odd m; direct path same math (DESIGN.md §9)."""
    assert ops.coded_irbucket_fusable(s, m, n)
    rng = np.random.default_rng(s + m)
    # adversarial mask family: same-subset-different-bytes pairs + the
    # all-alive row + exact-threshold random scatters
    masks = [np.zeros(n, bool) for _ in range(2)]
    masks[0][:m] = True                       # contiguous first-m ...
    masks[1][:m] = True
    masks[1][n - 1] = True                    # ... same subset, extra byte
    masks.append(np.ones(n, bool))
    for _ in range(2):
        row = np.zeros(n, bool)
        row[rng.choice(n, size=m, replace=False)] = True
        masks.append(row)
    masks = np.stack(masks)
    q = masks.shape[0]
    xs = rng.normal(size=(q, s))
    yb = jnp.asarray(np.fft.rfft(xs, axis=-1).astype(np.complex64))
    g = mds.rs_generator(n, m, jnp.complex64)
    cache = DecodeMatrixCache(np.asarray(g))
    dmats = cache.matrices(masks)
    gr, gi = ref.planar(g)
    dr = jnp.asarray(dmats.real.astype(np.float32))
    di = jnp.asarray(dmats.imag.astype(np.float32))
    yr, yi = ref.planar(yb)
    out = ops.coded_irbucket(yr, yi, dr, di, gr, gi, s, interpret=True)
    assert _relerr(out, xs) < 1e-3
    # direct path (off-TPU default) computes the identical body
    out2 = ops.coded_irbucket(yr, yi, dr, di, gr, gi, s)
    assert _relerr(out2, np.asarray(out)) < 1e-5
    # masked variant: raw masks in, subset selection + decode matrices
    # built in-kernel
    out3 = ops.coded_irbucket_masked(yr, yi, jnp.asarray(masks), gr, gi, s,
                                     interpret=True)
    assert _relerr(out3, xs) < 1e-3
    out4 = ops.coded_irbucket_masked(yr, yi, jnp.asarray(masks), gr, gi, s)
    assert _relerr(out4, xs) < 1e-3
    # and the reference plan agrees (the acceptance cross-check)
    from repro.core import CodedIRFFT

    plan = CodedIRFFT(s=s, m=m, n_workers=n, dtype=jnp.complex64,
                      backend="reference")
    want_plan = plan.run(yb[0], mask=jnp.asarray(masks[0]))
    assert _relerr(np.asarray(out)[0], np.asarray(want_plan)) < 1e-3


def test_submit_irfft_routes_through_fused_c2r_kernel(monkeypatch):
    """Dispatch pin (the acceptance criterion): on the kernel backend with
    a non-interpret (TPU-like) dispatch, the c2r bucket runner lowers to
    the ONE-LAUNCH fused kernel -- the jaxpr carries the
    coded_irfft_bucket pallas_call, not the stage-path composition.  CI
    runs on CPU, so the TPU dispatch is pinned by patching
    ops.default_interpret; tracing never executes the kernel."""
    monkeypatch.setattr(ops, "default_interpret", lambda: False)
    s, m, n = 256, 4, 8
    svc = FFTService(FFTServiceConfig(s=s, m=m, n_workers=n))
    assert svc._kernel_path(s, "c2r") and svc._device_decode()
    runner = svc._runner_for(s, 4, "c2r")
    yb = jax.ShapeDtypeStruct((4, s // 2 + 1), jnp.complex64)
    masks = jax.ShapeDtypeStruct((4, n), jnp.bool_)
    jaxpr = str(jax.make_jaxpr(runner)(yb, masks))
    assert "coded_irfft_bucket_masked" in jaxpr
    # the host-LRU fallback runner pins the unmasked fused kernel too
    svc2 = FFTService(FFTServiceConfig(s=s, m=m, n_workers=n,
                                       device_decode=False))
    runner2 = svc2._runner_for(s, 4, "c2r")
    dplanes = jax.ShapeDtypeStruct((2, 4, m, n), jnp.float32)
    jaxpr2 = str(jax.make_jaxpr(runner2)(yb, dplanes))
    assert "coded_irfft_bucket" in jaxpr2


def test_pack_real_planes_odd_shard_raises_documented_error():
    """Odd shard lengths on the real kernel path fail with the documented
    '2m | s' ValueError at trace time, not an opaque reshape error."""
    with pytest.raises(ValueError, match=r"2m \| s"):
        ops.pack_real_planes(jnp.zeros((2, 252), jnp.float32), 4)


@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (768, 4, 6), (240, 2, 5),
                                   (96, 3, 7)])
def test_tpu_stage_path_compositions_match_numpy(s, m, n):
    """Pin the TPU-only stage compositions of _make_kernel_runner, which
    CI's interpret-mode default never executes: the r2c non-fusable
    fallback (pack -> encode_worker -> decode_apply -> rfft_postdecode)
    and the c2r executor's conj-trick ifft (encode_worker on negated
    planes, /n2 rescale).  Run them through the Pallas kernels in
    interpret mode against numpy."""
    q = 2
    n2 = s // m // 2
    rng = np.random.default_rng(s)
    xb = rng.normal(size=(q, s)).astype(np.float32)
    g = mds.rs_generator(n, m, jnp.complex64)
    gr, gi = ref.planar(g)
    masks = np.zeros((q, n), bool)
    for row in masks:
        row[rng.choice(n, size=m, replace=False)] = True
    cache = DecodeMatrixCache(np.asarray(g))
    dmats = cache.matrices(masks)
    dr = jnp.asarray(dmats.real.astype(np.float32))
    di = jnp.asarray(dmats.imag.astype(np.float32))

    # r2c stage path (the whole=False branch)
    zr, zi = ops.pack_real_planes(jnp.asarray(xb), m)
    br, bi = ops.encode_worker(zr, zi, gr, gi, interpret=True)
    hr, hi = ops.decode_apply(dr, di, br, bi, interpret=True)
    yr, yi = ops.rfft_postdecode_planar(hr, hi, s)
    want = np.fft.rfft(xb.astype(np.float64), axis=-1)
    assert _relerr(ref.unplanar(yr, yi), want) < 1e-3

    # c2r executor: ifft(G @ z) = conj(fft(conj(G) @ conj(z))) / n2
    yb = np.fft.rfft(xb, axis=-1).astype(np.complex64)
    yr_, yi_ = ref.planar(jnp.asarray(yb))
    zr2, zi2 = ops.irfft_message_planar(yr_, yi_, s, m)
    br2, bi2 = ops.encode_worker(zr2, -zi2, gr, -gi, interpret=True)
    br2, bi2 = br2 / n2, -bi2 / n2
    hr2, hi2 = ops.decode_apply(dr, di, br2, bi2, interpret=True)
    out = ops.irfft_unpack_planar(hr2, hi2)
    assert _relerr(out, xb) < 1e-3


def test_rfft_payload_is_half_of_c2c():
    """The acceptance geometry: r2c worker shards carry HALF the c2c
    payload elements (the communication-overhead win, DESIGN.md §7)."""
    from repro.core import CodedFFT, CodedRFFT

    s, m, n = 2048, 4, 8
    c2c = CodedFFT(s=s, m=m, n_workers=n)
    r2c = CodedRFFT(s=s, m=m, n_workers=n)
    assert r2c.worker_shard_shape[0] * 2 == c2c.worker_shard_shape[0]
    a = r2c.encode(jnp.zeros((s,), jnp.float32))
    assert a.shape == (n, s // m // 2)


def test_service_rfft_and_irfft_kinds():
    """Service r2c/c2r buckets decode exactly under straggler churn and
    share ONE decode-matrix LRU across kinds (same (N, m) generator).
    Pinned to the host-LRU fallback path, which is what shares the LRU."""
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=3,
                                      device_decode=False))
    rng = np.random.default_rng(1)
    xs = [jnp.asarray(rng.normal(size=256).astype(np.float32))
          for _ in range(6)]
    for x, y in zip(xs, svc.submit_batch(xs, kind="r2c")):
        assert y.shape == (129,)
        assert float(np.abs(y - np.fft.rfft(np.asarray(x))).max()) < 1e-2
    ys = [jnp.asarray(np.fft.rfft(np.asarray(x)).astype(np.complex64))
          for x in xs]
    for x, z in zip(xs, svc.submit_batch(ys, kind="c2r")):
        assert z.shape == (256,)
        assert float(np.abs(z - np.asarray(x)).max()) < 1e-2
    # same-mask repeats across kinds hit the SHARED cache
    assert svc.stats.decode_cache_hits > 0
    assert len(svc._decode_cache_for()) <= svc.stats.decode_cache_misses


# --------------------------------------------- adversarial mask patterns
def test_masks_equal_as_subsets_do_not_collide():
    """Two masks selecting the SAME first-m responder subset but differing
    as byte patterns must occupy distinct cache entries (byte-keying), and
    both must decode correctly -- a subset-keyed cache would alias them,
    a value-keyed comparison would miss the second's tail responders."""
    g = np.asarray(mds.rs_generator(8, 4, jnp.complex64))
    cache = DecodeMatrixCache(g, maxsize=8)
    m1 = np.array([1, 1, 1, 1, 0, 0, 0, 0], bool)
    m2 = np.array([1, 1, 1, 1, 1, 0, 0, 0], bool)  # same first-4 subset
    np.testing.assert_array_equal(
        DecodeMatrixCache.subset_of(m1, 4), DecodeMatrixCache.subset_of(m2, 4))
    d1, d2 = cache.matrix(m1), cache.matrix(m2)
    assert len(cache) == 2                      # no collision
    assert cache.hits == 0 and cache.misses == 2
    np.testing.assert_allclose(d1, d2, atol=0)  # same VALUE, distinct keys
    # and the same byte pattern submitted from another (s, kind) bucket is
    # a pure hit: the service shares one LRU because the generator only
    # depends on (N, m)
    cache.matrix(m1)
    assert cache.hits == 1


def test_service_shares_decode_cache_across_buckets():
    """Identical straggler masks arriving in different (s, kind) buckets
    must hit the one shared LRU, not rebuild per bucket (host-fallback
    path; the default device-decode path has no cache to share)."""
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8, seed=9,
                                      decode_cache_size=512,
                                      device_decode=False))
    rng = np.random.default_rng(2)
    xs256 = [jnp.asarray((rng.normal(size=256) + 1j * rng.normal(size=256))
                         .astype(np.complex64)) for _ in range(4)]
    xs128 = [jnp.asarray((rng.normal(size=128) + 1j * rng.normal(size=128))
                         .astype(np.complex64)) for _ in range(4)]
    svc.submit_batch(xs256)
    misses_after_first = svc.stats.decode_cache_misses
    # same service RNG stream continues, but ANY repeat mask from the 128
    # bucket or the r2c bucket hits the same store; with 70 masks over a
    # small C(8, >=4) pattern space repeats are guaranteed
    for _ in range(4):
        svc.submit_batch(xs128)
        svc.submit_batch([jnp.real(x) for x in xs256], kind="r2c")
    assert svc.stats.decode_cache_hits > 0
    assert len(svc._decode_cache_for()) == svc.stats.decode_cache_misses
    assert svc.stats.decode_cache_misses >= misses_after_first


def test_service_lru_churn_with_real_kinds_stays_correct():
    """LRU eviction under churn across c2c + r2c + c2r buckets keeps
    parity: a tiny cache forces constant evictions; every request of every
    kind must still decode exactly (extends the c2c churn test above)."""
    svc = FFTService(FFTServiceConfig(
        s=128, m=4, n_workers=8, seed=13, decode_cache_size=2,
        device_decode=False))
    rng = np.random.default_rng(5)
    worst = 0.0
    for _ in range(4):
        xr = [jnp.asarray(rng.normal(size=128).astype(np.float32))
              for _ in range(4)]
        for x, y in zip(xr, svc.submit_batch(xr, kind="r2c")):
            worst = max(worst, float(
                np.abs(y - np.fft.rfft(np.asarray(x))).max()))
        ys = [jnp.asarray(np.fft.rfft(np.asarray(x)).astype(np.complex64))
              for x in xr]
        for x, z in zip(xr, svc.submit_batch(ys, kind="c2r")):
            worst = max(worst, float(np.abs(z - np.asarray(x)).max()))
        xc = [jnp.asarray((rng.normal(size=128) + 1j * rng.normal(size=128))
                          .astype(np.complex64)) for _ in range(4)]
        for x, y in zip(xc, svc.submit_batch(xc)):
            worst = max(worst, float(
                np.abs(y - np.fft.fft(np.asarray(x))).max()))
    assert worst < 1e-2, worst
    assert svc.stats.decode_cache_misses > 2  # churn proof


# ----------------------------------------------- service path selection
def test_service_default_uses_kernel_path_with_reference_escape():
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8))
    assert svc._kernel_path(256)
    assert svc.plan.resolved_backend == "kernel"
    ref_svc = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8, use_reference=True))
    assert not ref_svc._kernel_path(256)
    assert ref_svc.plan.resolved_backend == "reference"
    # explicit worker plug-in or pinned decode method -> plan.run executor
    plug = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8,
        worker_fn=ops.make_kernel_worker_fn(interpret=True)))
    assert not plug._kernel_path(256)
    pinned = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8, decode_method="solve"))
    assert not pinned._kernel_path(256)


def test_service_kernel_vs_reference_same_results():
    """Same seed => same straggler draws => kernel and reference executors
    must agree to f32 tolerance on every request."""
    cfgs = [FFTServiceConfig(s=512, m=4, n_workers=8, seed=7,
                             use_reference=flag) for flag in (False, True)]
    rng = np.random.default_rng(2)
    xs = [jnp.asarray((rng.normal(size=512) + 1j * rng.normal(size=512))
                      .astype(np.complex64)) for _ in range(5)]
    outs = [FFTService(c).submit_batch(xs) for c in cfgs]
    for yk, yr in zip(*outs):
        assert float(np.max(np.abs(yk - yr))) < 1e-3
