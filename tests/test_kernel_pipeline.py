"""Kernel-first hot path: fused pipeline parity, backend dispatch rules,
and decode-matrix LRU correctness (DESIGN.md §6).

Parity tests pin ``interpret=True`` so the fused kernels are exercised
through the real Pallas machinery on CPU in every PR (the CI
kernels-interpret job runs this module); dispatch tests cover the
``interpret=None`` default (direct kernel-body evaluation off-TPU) and
the plan/service backend-selection rules.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodedFFT, CodedFFTND, mds
from repro.core.coded_fft import _default_fft
from repro.kernels import ops, ref
from repro.serving import FFTService, FFTServiceConfig
from repro.serving.decode_cache import DecodeMatrixCache

pytestmark = pytest.mark.kernels

RTOL = 3e-4


def _randc(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        (rng.normal(size=shape) + 1j * rng.normal(size=shape))
        .astype(np.complex64))


def _relerr(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return np.abs(got - want).max() / max(np.abs(want).max(), 1e-12)


# --------------------------------------------- fused encode+worker parity
@pytest.mark.parametrize("m,n,ell", [
    (4, 8, 512),     # the service default shape (pow2)
    (4, 6, 384),     # non-power-of-two composite L
    (4, 6, 189),     # odd composite L (split_factor -> 9 x 21)
    (2, 5, 127),     # prime L: split_factor falls back to (1, L)
    (3, 7, 96),      # odd m
])
@pytest.mark.parametrize("fused", [True, False])
def test_encode_worker_parity_interpret(m, n, ell, fused):
    """Fused encode+worker == encode_dft + fft oracle, through Pallas
    interpret mode, for non-power-of-two and odd L (split_factor
    fallbacks) in both the fused and the two-pass (separate) paths."""
    c = _randc((3, m, ell), seed=ell + m)
    g = mds.rs_generator(n, m, jnp.complex64)
    cr, ci = ref.planar(c)
    gr, gi = ref.planar(g)
    br, bi = ops.encode_worker(cr, ci, gr, gi, interpret=True, fused=fused)
    wr, wi = ref.encode_worker_ref(cr, ci, g)
    assert _relerr(ref.unplanar(br, bi), ref.unplanar(wr, wi)) < RTOL
    # and the default dispatch (direct path off-TPU) is the same math
    # (not bit-identical: XLA may reassociate the f32 accumulations)
    br2, bi2 = ops.encode_worker(cr, ci, gr, gi, fused=fused)
    assert _relerr(ref.unplanar(br2, bi2), ref.unplanar(br, bi)) < 1e-5


def test_split_factor_prime_fallback():
    assert ops.split_factor(127) == (1, 127)
    a, b = ops.split_factor(189)
    assert a * b == 189 and 1 < a <= b


def test_degenerate_factorization_falls_back_to_platform_fft():
    """A large prime shard length must NOT build a dense (L, L) DFT matrix
    (regression: the default kernel worker at L=10007 would have allocated
    ~800 MB of DFT planes and run O(L^2) flops); fourstep_planar falls
    back to the platform FFT past the (B, B) budget and stays exact."""
    ell = 10007  # prime
    a, b = ops.split_factor(ell)
    assert b * b > ops._FUSED_MAX_ELEMS
    x = _randc((2, ell), seed=13)
    xr, xi = ref.planar(x)
    got = ref.unplanar(*ops.fourstep_planar(xr, xi))
    want = np.fft.fft(np.asarray(x, np.complex128), axis=-1)
    assert _relerr(got, want) < 1e-3
    # end-to-end through the default plan (s = m * L)
    plan = CodedFFT(s=4 * ell, m=4, n_workers=8)
    xs = _randc((4 * ell,), seed=14)
    y = plan.run(xs)
    assert _relerr(y, np.fft.fft(np.asarray(xs, np.complex128))) < 1e-3


# --------------------------------------------------- whole-bucket pipeline
@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (756, 4, 6), (254, 2, 5)])
def test_coded_bucket_kernel_parity(s, m, n):
    """One-launch bucket pipeline (interleave -> encode -> worker ->
    decode -> recombine) == jnp.fft, via Pallas interpret, including odd
    and prime shard lengths."""
    assert ops.coded_bucket_fusable(s, m, n)
    q = 3
    xb = _randc((q, s), seed=s)
    g = mds.rs_generator(n, m, jnp.complex64)
    rng = np.random.default_rng(s)
    masks = np.zeros((q, n), bool)
    for row in masks:
        row[rng.choice(n, size=m, replace=False)] = True
    cache = DecodeMatrixCache(np.asarray(g))
    dmats = cache.matrices(masks)
    xr, xi = ref.planar(xb)
    gr, gi = ref.planar(g)
    dr = jnp.asarray(dmats.real.astype(np.float32))
    di = jnp.asarray(dmats.imag.astype(np.float32))
    yr, yi = ops.coded_bucket(xr, xi, dr, di, gr, gi, s, interpret=True)
    want = np.fft.fft(np.asarray(xb, np.complex128), axis=-1)
    assert _relerr(ref.unplanar(yr, yi), want) < 1e-3
    # direct path (off-TPU default) computes the identical body
    # (not bit-identical: XLA may reassociate the f32 accumulations)
    yr2, yi2 = ops.coded_bucket(xr, xi, dr, di, gr, gi, s)
    assert _relerr(ref.unplanar(yr2, yi2), ref.unplanar(yr, yi)) < 1e-5


@pytest.mark.parametrize("s,m,n", [(2048, 4, 8), (756, 4, 6)])
def test_coded_bucket_direct_matches_pallas_bucket(s, m, n):
    """The off-TPU direct executor (platform-FFT worker stage, gathered
    compact decode) == the Pallas bucket kernel == jnp.fft."""
    q = 3
    xb = _randc((q, s), seed=s + 1)
    g = mds.rs_generator(n, m, jnp.complex64)
    rng = np.random.default_rng(s)
    masks = np.zeros((q, n), bool)
    for row in masks:
        row[rng.choice(n, size=m, replace=False)] = True
    cache = DecodeMatrixCache(np.asarray(g))
    invs, subsets = cache.compact(masks)
    dmats = cache.matrices(masks)
    xr, xi = ref.planar(xb)
    gr, gi = ref.planar(g)
    yr, yi = ops.coded_bucket_direct(
        xr, xi, jnp.asarray(invs.real.astype(np.float32)),
        jnp.asarray(invs.imag.astype(np.float32)),
        jnp.asarray(subsets), gr, gi, s)
    want = np.fft.fft(np.asarray(xb, np.complex128), axis=-1)
    assert _relerr(ref.unplanar(yr, yi), want) < 1e-3
    kr, ki = ops.coded_bucket(
        xr, xi, jnp.asarray(dmats.real.astype(np.float32)),
        jnp.asarray(dmats.imag.astype(np.float32)), gr, gi, s,
        interpret=True)
    assert _relerr(ref.unplanar(yr, yi), ref.unplanar(kr, ki)) < 1e-4


def test_bcmatmul_and_batched_recombine_parity():
    q, m, n, ell = 5, 4, 8, 96
    a = _randc((q, m, n), seed=1)
    b = _randc((q, n, ell), seed=2)
    from repro.kernels.cmatmul import bcmatmul
    from repro.kernels.recombine import recombine_twiddle_dft_batched

    ar, ai = ref.planar(a)
    br, bi = ref.planar(b)
    cr, ci = bcmatmul(ar, ai, br, bi, block_q=2, block_l=32, interpret=True)
    wr, wi = ref.bcmatmul_ref(ar, ai, br, bi)
    np.testing.assert_allclose(np.asarray(cr), np.asarray(wr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(ci), np.asarray(wi), rtol=1e-4,
                               atol=1e-4)

    c = _randc((q, m, ell), seed=3)
    s = m * ell
    cr, ci = ref.planar(c)
    twr, twi, fr, fi = ops._recombine_planes(s, m)
    got = recombine_twiddle_dft_batched(
        cr, ci, twr, twi, fr, fi, block_q=2, block_l=32, interpret=True)
    want = ref.recombine_batched_ref(cr, ci, twr, twi, fr, fi)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------- backend dispatch rules
def test_backend_dispatch_rules():
    # c64 + default backend -> kernel engine
    plan = CodedFFT(s=256, m=4, n_workers=6)
    assert plan.backend == "kernel" and plan.resolved_backend == "kernel"
    # explicit reference backend wins
    assert CodedFFT(s=256, m=4, n_workers=6,
                    backend="reference").resolved_backend == "reference"
    # complex128 (numerics tier) always resolves to the jnp oracle
    p128 = CodedFFT(s=256, m=4, n_workers=6, dtype=jnp.complex128)
    assert p128.resolved_backend == "reference"
    # explicit worker_fn plug-in overrides the backend worker
    p = CodedFFT(s=256, m=4, n_workers=6, worker_fn=_default_fft)
    assert p.resolved_worker_fn is _default_fft


def test_kernel_backend_plan_run_matches_fft():
    """Default (kernel-backend) plan.run == jnp.fft, batched and unbatched,
    including NaN-poisoned stragglers under a mask."""
    plan = CodedFFT(s=756, m=4, n_workers=6)  # odd L = 189
    xb = _randc((3, 756), seed=5)
    out = plan.run(xb)
    want = np.fft.fft(np.asarray(xb, np.complex128), axis=-1)
    assert _relerr(out, want) < 1e-3
    b = plan.worker_compute(plan.encode(xb[0]))
    b = b.at[jnp.asarray([1, 4])].set(jnp.nan)
    mask = jnp.asarray([True, False, True, True, False, True])
    got = plan.decode(b, mask=mask)
    assert _relerr(got, want[0]) < 1e-3


def test_kernel_backend_nd_plan():
    plan = CodedFFTND(shape=(16, 12), factors=(2, 2), n_workers=6)
    assert plan.resolved_backend == "kernel"
    t = _randc((16, 12), seed=9)
    got = plan.run(t)
    want = np.fft.fft2(np.asarray(t, np.complex128))
    assert _relerr(got, want) < 1e-3


# ------------------------------------------------------- decode-matrix LRU
def test_decode_cache_hit_miss_and_eviction():
    g = np.asarray(mds.rs_generator(8, 4, jnp.complex64))
    cache = DecodeMatrixCache(g, maxsize=2)
    m1 = np.array([1, 1, 1, 1, 0, 0, 0, 0], bool)
    m2 = np.array([0, 1, 1, 1, 1, 0, 0, 0], bool)
    m3 = np.array([1, 0, 1, 0, 1, 0, 1, 0], bool)
    d1 = cache.matrix(m1)
    assert (cache.hits, cache.misses) == (0, 1)
    assert np.array_equal(cache.matrix(m1), d1)
    assert (cache.hits, cache.misses) == (1, 1)
    cache.matrix(m2)
    cache.matrix(m1)            # refresh m1 -> m2 is now LRU
    cache.matrix(m3)            # evicts m2
    assert len(cache) == 2
    assert (cache.hits, cache.misses) == (2, 3)
    cache.matrix(m2)            # recomputed after eviction, same value
    assert cache.misses == 4
    # matrices are the true scatter inverses regardless of cache churn
    for mask in (m1, m2, m3):
        d, inv, sub = cache._compute(mask)
        np.testing.assert_array_equal(sub, DecodeMatrixCache.subset_of(mask, 4))
        np.testing.assert_allclose(
            d[:, sub] @ g[sub, :].astype(np.complex128), np.eye(4),
            atol=1e-5)
        np.testing.assert_array_equal(d[:, sub], inv)
        assert np.all(d[:, [k for k in range(8) if k not in sub]] == 0)


def test_decode_cache_rejects_undecodable_mask():
    g = np.asarray(mds.rs_generator(8, 4, jnp.complex64))
    cache = DecodeMatrixCache(g)
    with pytest.raises(ValueError, match="responders"):
        cache.matrix(np.array([1, 1, 1, 0, 0, 0, 0, 0], bool))


def test_service_lru_churn_stays_correct():
    """With a tiny decode cache, straggler-mask churn forces constant
    evictions; every request must still decode exactly."""
    svc = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8, seed=11, decode_cache_size=2))
    rng = np.random.default_rng(0)
    worst = 0.0
    for _ in range(6):
        xs = [jnp.asarray((rng.normal(size=256) + 1j * rng.normal(size=256))
                          .astype(np.complex64)) for _ in range(8)]
        for x, y in zip(xs, svc.submit_batch(xs)):
            worst = max(worst, float(np.max(np.abs(y - np.fft.fft(x)))))
    assert worst < 1e-2, worst
    st = svc.stats.summary()
    # churn proof: far more misses than the cache can hold
    assert st["decode_cache_misses"] > 2
    assert st["requests"] == 48


# ----------------------------------------------- service path selection
def test_service_default_uses_kernel_path_with_reference_escape():
    svc = FFTService(FFTServiceConfig(s=256, m=4, n_workers=8))
    assert svc._kernel_path(256)
    assert svc.plan.resolved_backend == "kernel"
    ref_svc = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8, use_reference=True))
    assert not ref_svc._kernel_path(256)
    assert ref_svc.plan.resolved_backend == "reference"
    # explicit worker plug-in or pinned decode method -> plan.run executor
    plug = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8,
        worker_fn=ops.make_kernel_worker_fn(interpret=True)))
    assert not plug._kernel_path(256)
    pinned = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8, decode_method="solve"))
    assert not pinned._kernel_path(256)


def test_service_kernel_vs_reference_same_results():
    """Same seed => same straggler draws => kernel and reference executors
    must agree to f32 tolerance on every request."""
    cfgs = [FFTServiceConfig(s=512, m=4, n_workers=8, seed=7,
                             use_reference=flag) for flag in (False, True)]
    rng = np.random.default_rng(2)
    xs = [jnp.asarray((rng.normal(size=512) + 1j * rng.normal(size=512))
                      .astype(np.complex64)) for _ in range(5)]
    outs = [FFTService(c).submit_batch(xs) for c in cfgs]
    for yk, yr in zip(*outs):
        assert float(np.max(np.abs(yk - yr))) < 1e-3
