"""CodedPlan protocol: conformance, batched shapes, fast decode dispatch,
batched service scheduler, and the generalized n-D distributed runtime."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CodedFFT,
    CodedFFTMultiInput,
    CodedFFTND,
    CodedIFFT,
    CodedIRFFT,
    CodedPlan,
    CodedRFFT,
    MDSPlan,
    UncodedRepetitionFFT,
    mds,
)

C128 = jnp.complex128


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) + 1j * rng.normal(size=shape))


def _mds_plans():
    return [
        CodedFFT(s=64, m=4, n_workers=6, dtype=C128),
        CodedFFTND(shape=(8, 8), factors=(2, 2), n_workers=6, dtype=C128),
        CodedFFTMultiInput(q=4, shape=(8,), m_tilde=2, factors=(2,),
                           n_workers=6, dtype=C128),
        CodedRFFT(s=64, m=4, n_workers=6, dtype=C128),
        CodedIFFT(s=64, m=4, n_workers=6, dtype=C128),
        CodedIRFFT(s=64, m=4, n_workers=6, dtype=C128),
    ]


def _plans():
    return _mds_plans() + [
        UncodedRepetitionFFT(s=64, m=2, n_workers=8, dtype=C128),
    ]


def _plan_input(plan, seed):
    """A valid random input for any plan (real for r2c, half-spectrum
    Hermitian-consistent for c2r, complex otherwise)."""
    rng = np.random.default_rng(seed)
    if isinstance(plan, CodedRFFT):
        return jnp.asarray(rng.normal(size=plan.input_shape))
    if isinstance(plan, CodedIRFFT):
        return jnp.asarray(np.fft.rfft(rng.normal(size=plan.s)))
    return _rand(plan.input_shape, seed=seed)


# ---------------- protocol conformance ---------------------------------------
def test_all_strategies_satisfy_coded_plan():
    for plan in _plans():
        assert isinstance(plan, CodedPlan), type(plan).__name__
        assert plan.recovery_threshold >= 1
        assert len(plan.worker_shard_shape) >= 1


def test_mds_plans_expose_message_postdecode():
    for plan in _mds_plans():
        assert isinstance(plan, MDSPlan), type(plan).__name__
        x = _plan_input(plan, seed=1)
        c = plan.message(x)
        assert c.shape == (plan.m,) + tuple(plan.worker_shard_shape)
        # encode == DFT of the message symbols, decode o postdecode inverts
        np.testing.assert_allclose(
            np.asarray(plan.encode(x)),
            np.asarray(mds.encode_dft(c, plan.n_workers)), atol=1e-9)
    # repetition is deliberately NOT an MDS plan
    assert not isinstance(_plans()[-1], MDSPlan)


def test_dense_and_dft_encode_agree():
    for plan in _mds_plans():
        x = _plan_input(plan, seed=2)
        np.testing.assert_allclose(
            np.asarray(plan.encode(x)), np.asarray(plan.encode_dense(x)),
            atol=1e-9)


# ---------------- batched shapes == per-request oracle -----------------------
# (end-to-end parity against numpy under random masks/batches lives in the
# property-based differential suite, tests/test_properties.py -- here we
# only pin the batched SHAPE contract and per-request equivalence)
@pytest.mark.parametrize("plan_idx", range(7))
def test_batched_run_equals_per_request(plan_idx):
    plan = _plans()[plan_idx]
    nb = 3
    x1 = _plan_input(plan, seed=plan_idx)
    xb = jnp.stack([x1, x1 * 0.5, x1 + 1])
    a = plan.encode(xb)
    assert a.shape == (nb, plan.n_workers) + tuple(plan.worker_shard_shape)
    b = plan.worker_compute(a)
    assert b.shape == a.shape
    out = plan.decode(b)
    assert out.shape == (nb,) + tuple(plan.output_shape)
    for i in range(nb):
        one = plan.run(xb[i])
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(one),
                                   atol=1e-8)


# ---------------- decode_ifft == Vandermonde solve ---------------------------
@pytest.mark.parametrize("n,m", [(3, 2), (8, 4), (12, 8), (16, 16), (9, 1)])
def test_decode_ifft_matches_solve_on_contiguous_subsets(n, m):
    g = mds.rs_generator(n, m, C128)
    c = _rand((m, 6), seed=n * m)
    b = mds.encode(g, c)
    for start in range(n):  # every rotation, including mod-n wraparound
        sub = jnp.asarray([(start + j) % n for j in range(m)])
        fast = mds.decode_ifft(b, sub, n)
        dense = mds.decode_from_subset(g, b, sub)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(dense),
                                   atol=1e-8)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(c), atol=1e-8)


def test_decode_auto_dispatch_static_and_traced():
    n, m = 10, 4
    g = mds.rs_generator(n, m, C128)
    c = _rand((m, 5), seed=3)
    b = mds.encode(g, c)
    assert mds.is_contiguous_subset(np.asarray([7, 8, 9, 0]), n)
    assert not mds.is_contiguous_subset(np.asarray([0, 2, 4, 6]), n)
    for sub in ([3, 4, 5, 6], [7, 8, 9, 0], [0, 2, 4, 6], [9, 1, 5, 2]):
        sub = jnp.asarray(sub)
        got = mds.decode_auto(g, b, sub)
        np.testing.assert_allclose(np.asarray(got), np.asarray(c), atol=1e-8)
        # traced subset -> lax.cond dispatch inside jit
        got_j = jax.jit(lambda bb, ss: mds.decode_auto(g, bb, ss))(b, sub)
        np.testing.assert_allclose(np.asarray(got_j), np.asarray(c), atol=1e-8)


def test_decode_ifft_full_set_exact_at_large_m():
    """m == N is the literal inverse zero-padded DFT: stable at any size."""
    for m in (64, 256, 1024):
        g = mds.rs_generator(m, m, C128)
        c = _rand((m, 4), seed=m)
        b = mds.encode_dft(c, m)
        got = mds.decode_ifft(b, jnp.arange(m), m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(c), atol=1e-9)
        # auto routes the full set to the transform decode at any m
        auto = mds.decode_auto(g, b, jnp.arange(m))
        np.testing.assert_allclose(np.asarray(auto), np.asarray(c), atol=1e-9)


def test_decode_auto_gates_large_m_contiguous_to_solve():
    """Contiguous arcs are intrinsically ill-conditioned beyond small m;
    auto must NOT route them to the Lagrange transform decode (regression:
    CodedFFT(s=1024, m=16, n_workers=32).run() silently returned garbage)."""
    n, m = 32, 16
    g = mds.rs_generator(n, m, C128)
    c = _rand((m, 5), seed=42)
    b = mds.encode(g, c)
    sub = jnp.arange(m)  # contiguous, m > IFFT_AUTO_MAX_M
    auto = mds.decode_auto(g, b, sub)
    dense = mds.decode_from_subset(g, b, sub)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(dense), atol=0)
    np.testing.assert_allclose(np.asarray(auto), np.asarray(c), atol=1e-6)
    # end to end: the exact scenario from the regression
    plan = CodedFFT(s=1024, m=16, n_workers=32, dtype=C128)
    x = _rand(1024, seed=9)
    err = float(jnp.max(jnp.abs(plan.run(x) - jnp.fft.fft(x))))
    assert err < 1e-5, err


def test_plan_decode_method_forcing():
    plan = CodedFFT(s=96, m=4, n_workers=8, dtype=C128)
    x = _rand(96, seed=11)
    b = plan.worker_compute(plan.encode(x))
    want = jnp.fft.fft(x)
    for method in ("auto", "ifft", "solve"):
        got = plan.decode(b, subset=jnp.asarray([2, 3, 4, 5]), method=method)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-8)


# ---------------- batched service == oracle ----------------------------------
def test_service_batched_submit_matches_oracle():
    from repro.distributed.straggler import StragglerModel
    from repro.serving import FFTService, FFTServiceConfig

    svc = FFTService(FFTServiceConfig(
        s=256, m=4, n_workers=8,
        straggler=StragglerModel(t0=1.0, mu=1.0), seed=5))
    rng = np.random.default_rng(1)
    sizes = [256, 128, 256, 256, 128, 256, 256]  # two (s, m) buckets
    xs = [jnp.asarray((rng.normal(size=s) + 1j * rng.normal(size=s))
                      .astype(np.complex64)) for s in sizes]
    outs = svc.submit_batch(xs)
    for x, y in zip(xs, outs):
        err = float(jnp.max(jnp.abs(y - jnp.fft.fft(x))))
        assert err < 1e-2, err
    st = svc.stats.summary()
    assert st["requests"] == len(sizes)
    assert st["batches"] == 2  # one jitted call per (s, m) bucket
    assert st["stragglers_tolerated"] == len(sizes) * 4  # waits for m of N
    # batch-of-one path shares the same compiled stack
    y = svc.submit(xs[0])
    assert float(jnp.max(jnp.abs(y - jnp.fft.fft(xs[0])))) < 1e-2


def test_service_bucket_keeps_service_dtype():
    """A real-valued request first in a bucket must not narrow the buffer
    and silently drop a complex request's imaginary part (regression)."""
    from repro.serving import FFTService, FFTServiceConfig

    svc = FFTService(FFTServiceConfig(s=64, m=4, n_workers=8, seed=0))
    rng = np.random.default_rng(0)
    xr = jnp.asarray(rng.normal(size=64).astype(np.float32))
    xc = jnp.asarray((rng.normal(size=64) + 1j * rng.normal(size=64))
                     .astype(np.complex64))
    outs = svc.submit_batch([xr, xc])
    for x, y in zip([xr, xc], outs):
        err = float(jnp.max(jnp.abs(y - jnp.fft.fft(x.astype(jnp.complex64)))))
        assert err < 1e-3, err


# ---------------- generalized distributed runtime (n-D, NaN stragglers) ------
_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, numpy as np
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core import CodedFFTND, CodedFFTMultiInput
from repro.distributed import DistributedCodedPlan, test_mesh

mesh = test_mesh((8,), ("workers",))
rng = np.random.default_rng(0)

# n-D plan under the generalized runtime; stragglers poisoned with NaN to
# prove the decode never reads masked rows
plan = CodedFFTND(shape=(16, 8), factors=(2, 2), n_workers=8, dtype=jnp.complex128)
d = DistributedCodedPlan(plan, mesh, masked_fill=float("nan"))
t = jnp.asarray(rng.normal(size=(16, 8)) + 1j * rng.normal(size=(16, 8)))
mask = jnp.asarray([True, False, True, True, False, True, False, True])
out = d.run(t, mask)
err = float(jnp.max(jnp.abs(out - jnp.fft.fftn(t))))
assert err < 1e-8, f"nd masked decode err {err}"

# batched n-D with per-request masks
tb = jnp.asarray(rng.normal(size=(3, 16, 8)) + 1j * rng.normal(size=(3, 16, 8)))
masks = jnp.asarray([[True]*8,
                     [False, True, False, True, True, False, True, False],
                     [True, True, True, True, False, False, False, False]])
outb = d.run(tb, masks)
errb = float(jnp.max(jnp.abs(outb - jnp.fft.fftn(tb, axes=(-2, -1)))))
assert errb < 1e-8, f"batched nd err {errb}"

# multi-input plan through the same runtime
pmi = CodedFFTMultiInput(q=4, shape=(8,), m_tilde=2, factors=(2,), n_workers=8,
                         dtype=jnp.complex128)
dmi = DistributedCodedPlan(pmi, mesh, masked_fill=float("nan"))
tq = jnp.asarray(rng.normal(size=(4, 8)) + 1j * rng.normal(size=(4, 8)))
got = dmi.run(tq, mask)
want = jnp.stack([jnp.fft.fft(tq[h]) for h in range(4)])
errq = float(jnp.max(jnp.abs(got - want)))
assert errq < 1e-8, f"multi-input err {errq}"

# real-input plan (DESIGN.md §7): half-length packed shard shapes thread
# through the same runtime unchanged, NaN-poisoned stragglers ignored
from repro.core import CodedRFFT
pr = CodedRFFT(s=96, m=4, n_workers=8, dtype=jnp.complex128,
               backend="reference")
dr = DistributedCodedPlan(pr, mesh, masked_fill=float("nan"))
xr = jnp.asarray(rng.normal(size=(3, 96)))
outr = dr.run(xr, masks)
errr = float(jnp.max(jnp.abs(outr - jnp.fft.rfft(xr, axis=-1))))
assert errr < 1e-8, f"rfft mesh err {errr}"
print("SUBPROC_PLAN_OK")
"""


@pytest.mark.slow
def test_generalized_distributed_runtime_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=os.getcwd(),
                       capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SUBPROC_PLAN_OK" in r.stdout
