"""Coded FFT (1-D) correctness: Theorem 1 — any m workers suffice."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import CodedFFT, interleave, deinterleave

C128 = jnp.complex128


def _rand(s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=s) + 1j * rng.normal(size=s))


def test_interleave_roundtrip():
    x = _rand(24)
    for m in (1, 2, 3, 4, 6, 8, 12, 24):
        c = interleave(x, m)
        assert c.shape == (m, 24 // m)
        np.testing.assert_array_equal(np.asarray(deinterleave(c)), np.asarray(x))


def test_interleave_layout_matches_paper_eq20():
    x = jnp.arange(12.0)
    c = interleave(x, 3)
    # c_i[j] = x[i + j*m]
    for i in range(3):
        for j in range(4):
            assert float(c[i, j]) == float(x[i + j * 3])


def test_motivating_example_section_iii_a():
    """The paper's worked example: s=4, m=2, N=3(+1), workers 1,2 respond."""
    x = jnp.asarray([1.0 + 0j, 2.0, 3.0, 4.0])
    strat = CodedFFT(s=4, m=2, n_workers=3, dtype=C128)
    b = strat.worker_compute(strat.encode(x))
    # master receives workers 1 and 2 only (worker 0 straggles)
    got = strat.decode(b, subset=jnp.asarray([1, 2]))
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-10)


def test_no_straggler_baseline_matches_fft():
    x = _rand(64)
    strat = CodedFFT(s=64, m=4, n_workers=6, dtype=C128)
    got = strat.run(x)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-9)


@pytest.mark.parametrize("s,m,n", [(32, 4, 6), (48, 4, 8), (60, 5, 7), (128, 8, 12)])
def test_every_m_subset_decodes(s, m, n):
    """Theorem 1 exhaustively: EVERY m-subset of workers recovers X."""
    x = _rand(s, seed=s)
    strat = CodedFFT(s=s, m=m, n_workers=n, dtype=C128)
    b = strat.worker_compute(strat.encode(x))
    want = np.fft.fft(np.asarray(x))
    for sub in itertools.combinations(range(n), m):
        got = strat.decode(b, subset=jnp.asarray(sub))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-7)


def test_fewer_than_m_workers_insufficient():
    """Theorem 2 (converse, sanity form): m-1 workers give an underdetermined
    system — decoding from a wrong-size subset is rejected."""
    strat = CodedFFT(s=32, m=4, n_workers=8, dtype=C128)
    b = strat.worker_compute(strat.encode(_rand(32)))
    with pytest.raises(ValueError):
        strat.decode(b, subset=jnp.asarray([0, 1, 2]))


def test_masked_decode_picks_first_available():
    x = _rand(64, seed=3)
    strat = CodedFFT(s=64, m=4, n_workers=8, dtype=C128)
    b = strat.worker_compute(strat.encode(x))
    mask = np.ones(8, bool)
    mask[[0, 2, 5]] = False  # three stragglers
    got = strat.decode(b, mask=jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-8)


def test_stragglers_hold_garbage_rows():
    """Rows outside the subset must never influence the decode."""
    x = _rand(64, seed=4)
    strat = CodedFFT(s=64, m=4, n_workers=6, dtype=C128)
    b = strat.worker_compute(strat.encode(x))
    b = b.at[0].set(jnp.nan + 1j * jnp.nan)  # worker 0 returned garbage
    got = strat.decode(b, subset=jnp.asarray([1, 2, 3, 4]))
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-8)


def test_fast_encode_matches_matrix_encode():
    x = _rand(96, seed=5)
    strat = CodedFFT(s=96, m=4, n_workers=8, dtype=C128)
    # encode IS the DFT fast path now; the dense generator matmul is the oracle
    np.testing.assert_allclose(
        np.asarray(strat.encode(x)), np.asarray(strat.encode_dense(x)), atol=1e-9
    )


def test_linearity_of_coded_pipeline():
    """Coding commutes with the DFT (the property Thm 1 rests on)."""
    strat = CodedFFT(s=32, m=4, n_workers=6, dtype=C128)
    x, y = _rand(32, 6), _rand(32, 7)
    bx = strat.worker_compute(strat.encode(x))
    by = strat.worker_compute(strat.encode(y))
    bxy = strat.worker_compute(strat.encode(2.0 * x + 3.0 * y))
    np.testing.assert_allclose(np.asarray(bxy), np.asarray(2.0 * bx + 3.0 * by), atol=1e-8)


@settings(max_examples=25, deadline=None)
@given(
    m_pow=st.integers(0, 4),
    ell_mult=st.integers(1, 6),
    extra=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_random_configs_match_fft(m_pow, ell_mult, extra, seed):
    """Property: for random (s, m, N) and random subsets, coded FFT == FFT."""
    m = 2**m_pow
    s = m * 4 * ell_mult
    n = m + extra
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=s) + 1j * rng.normal(size=s))
    strat = CodedFFT(s=s, m=m, n_workers=n, dtype=C128)
    b = strat.worker_compute(strat.encode(x))
    sub = jnp.asarray(rng.choice(n, size=m, replace=False))
    got = strat.decode(b, subset=sub)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-6)


def test_recovery_threshold_property():
    strat = CodedFFT(s=64, m=4, n_workers=8)
    assert strat.recovery_threshold == 4


def test_jit_end_to_end():
    x = _rand(64, seed=8)
    strat = CodedFFT(s=64, m=4, n_workers=8, dtype=C128)

    @jax.jit
    def run(xv, mask):
        b = strat.worker_compute(strat.encode(xv))
        return strat.decode(b, mask=mask)

    mask = jnp.asarray([False, True, True, False, True, True, True, True])
    got = run(x, mask)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-8)
