"""Data pipeline determinism/sharding + spectral mixer (incl. coded path)
+ wkv chunked-vs-scan exactness (hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.configs import ShapeConfig, get_reduced_config
from repro.core.coded_fft import CodedFFT
from repro.data import make_pipeline
from repro.models.rwkv6 import wkv_chunked, wkv_scan_reference
from repro.models.spectral import (
    decaying_filter_init,
    spectral_apply,
    spectral_apply_coded,
)


# ---------------- data ------------------------------------------------------
def test_pipeline_random_access_deterministic():
    cfg = get_reduced_config("gemma-2b")
    shape = ShapeConfig("t", 64, 8, "train")
    p1 = make_pipeline(cfg, shape, seed=1)
    p2 = make_pipeline(cfg, shape, seed=1)
    b1, b2 = p1.batch(17), p2.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # labels are next-token-shifted
    assert b1["tokens"].shape == (8, 64)


def test_pipeline_host_sharding_partitions_batch():
    cfg = get_reduced_config("gemma-2b")
    shape = ShapeConfig("t", 32, 8, "train")
    full = make_pipeline(cfg, shape).batch(3)
    parts = [make_pipeline(cfg, shape, process_index=i, process_count=4).batch(3)
             for i in range(4)]
    stacked = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(stacked, np.asarray(full["tokens"]))


def test_pipeline_modality_stubs():
    cfgv = get_reduced_config("paligemma-3b")
    sh = ShapeConfig("t", 64, 2, "train")
    b = make_pipeline(cfgv, sh).batch(0)
    assert b["patches"].shape == (2, cfgv.num_prefix_tokens, cfgv.d_model)
    assert b["tokens"].shape[1] == 64 - cfgv.num_prefix_tokens
    cfga = get_reduced_config("whisper-medium")
    b = make_pipeline(cfga, sh).batch(0)
    assert b["frames"].shape == (2, 64, cfga.d_model)


# ---------------- spectral mixer --------------------------------------------
def test_spectral_causality():
    """Output at position t must not depend on inputs after t."""
    key = jax.random.PRNGKey(0)
    p = decaying_filter_init(key, 4, 16)
    x1 = jax.random.normal(jax.random.PRNGKey(1), (1, 40, 4))
    x2 = x1.at[:, 25:].set(9.0)  # perturb the future
    y1 = spectral_apply(p, x1)
    y2 = spectral_apply(p, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :25]), np.asarray(y2[:, :25]),
                               atol=1e-5)


def test_spectral_coded_equals_plain_under_stragglers():
    key = jax.random.PRNGKey(0)
    p = decaying_filter_init(key, 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 48, 8))
    plan = CodedFFT(s=128, m=4, n_workers=6)
    mask = jnp.asarray([False, True, True, False, True, True])
    y1 = spectral_apply(p, x)
    y2 = spectral_apply_coded(p, x, plan, mask=mask)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# ---------------- wkv property test -----------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=70),
    seed=st.integers(min_value=0, max_value=2**16),
    decay_scale=st.floats(min_value=0.05, max_value=6.0),
)
def test_wkv_chunked_matches_scan(t, seed, decay_scale):
    """Chunked parallel wkv == exact per-token recurrence for any length,
    seed, and decay strength within the model's clamped range."""
    b, h, k = 2, 3, 8
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    mk = lambda i: jax.random.normal(keys[i], (b, t, h, k), jnp.float32)
    r, kk, v = mk(0), mk(1), mk(2)
    logw = -jnp.abs(jax.random.normal(keys[3], (b, t, h, k))) * decay_scale
    logw = jnp.maximum(logw, -8.0)
    u = jax.random.normal(keys[4], (h, k))
    state = jax.random.normal(keys[5], (b, h, k, k))
    # f32 streaming: exact vs the per-token recurrence
    o1, s1 = wkv_chunked(r, kk, v, logw, u, state, stream_dtype=jnp.float32)
    o2, s2 = wkv_scan_reference(r, kk, v, logw, u, state)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-3, atol=2e-3)


def test_wkv_bf16_stream_close_to_f32():
    b, t, h, k = 2, 64, 4, 16
    keys = jax.random.split(jax.random.PRNGKey(7), 6)
    mk = lambda i: jax.random.normal(keys[i], (b, t, h, k), jnp.float32)
    r, kk, v = mk(0), mk(1), mk(2)
    logw = jnp.maximum(-jnp.abs(jax.random.normal(keys[3], (b, t, h, k))), -8.0)
    u = jax.random.normal(keys[4], (h, k))
    state = jax.random.normal(keys[5], (b, h, k, k))
    o_bf, s_bf = wkv_chunked(r, kk, v, logw, u, state)  # default bf16 stream
    o_f, s_f = wkv_chunked(r, kk, v, logw, u, state, stream_dtype=jnp.float32)
    # bf16 rounding of r/k/v only: relative error stays at the ~1% level
    scale = float(jnp.max(jnp.abs(o_f)))
    assert float(jnp.max(jnp.abs(o_bf - o_f))) / scale < 0.05
    sscale = float(jnp.max(jnp.abs(s_f)))
    assert float(jnp.max(jnp.abs(s_bf - s_f))) / sscale < 0.05
