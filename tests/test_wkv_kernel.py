"""Pallas WKV kernel vs the per-token recurrence oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv import wkv_pallas
from repro.models.rwkv6 import wkv_scan_reference

pytestmark = pytest.mark.kernels


def _inputs(b, h, t, kd, seed=0, decay=1.0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    mk = lambda i, shape: jax.random.normal(keys[i], shape, jnp.float32)
    r, k, v = (mk(i, (b, t, h, kd)) for i in range(3))
    logw = jnp.maximum(-jnp.abs(mk(3, (b, t, h, kd))) * decay, -8.0)
    u = mk(4, (h, kd))
    s0 = mk(5, (b, h, kd, kd))
    return r, k, v, logw, u, s0


def _flatten_bh(x):  # (B, T, H, K) -> (B*H, T, K)
    b, t, h, kd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, t, kd)


@pytest.mark.parametrize("b,h,t,kd", [(1, 1, 16, 8), (2, 3, 64, 16),
                                      (1, 2, 48, 32), (2, 1, 128, 64)])
def test_wkv_kernel_matches_oracle(b, h, t, kd):
    r, k, v, logw, u, s0 = _inputs(b, h, t, kd, seed=kd + t)
    o_ref, s_ref = wkv_scan_reference(r, k, v, logw, u, s0)

    u_bh = jnp.tile(u, (b, 1))                     # (B*H, K)
    o, sf = wkv_pallas(
        _flatten_bh(r), _flatten_bh(k), _flatten_bh(v), _flatten_bh(logw),
        u_bh, s0.reshape(b * h, kd, kd), interpret=True)

    o_ref_f = _flatten_bh(o_ref)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref_f),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sf),
                               np.asarray(s_ref.reshape(b * h, kd, kd)),
                               rtol=2e-3, atol=2e-3)


def test_wkv_kernel_strong_decay_no_nan():
    r, k, v, logw, u, s0 = _inputs(1, 2, 32, 16, seed=7, decay=12.0)
    o, sf = wkv_pallas(
        _flatten_bh(r), _flatten_bh(k), _flatten_bh(v), _flatten_bh(logw),
        jnp.tile(u, (1, 1)), s0.reshape(2, 16, 16), interpret=True)
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(sf).all())
    o_ref, _ = wkv_scan_reference(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(_flatten_bh(o_ref)),
                               rtol=2e-3, atol=2e-3)
