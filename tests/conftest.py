# NOTE: no XLA_FLAGS device-count override here on purpose — smoke tests and
# benches must see exactly 1 CPU device.  Multi-device tests spawn a
# subprocess that sets --xla_force_host_platform_device_count itself.
import jax

# Double precision is required for the complex-RS decode conditioning tests
# and the Prony error locator; model code is dtype-explicit throughout.
jax.config.update("jax_enable_x64", True)
