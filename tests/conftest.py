# NOTE: no XLA_FLAGS device-count override here on purpose — smoke tests and
# benches must see exactly 1 CPU device.  Multi-device tests spawn a
# subprocess that sets --xla_force_host_platform_device_count itself.
import os

import jax
import pytest

# Double precision is required for the complex-RS decode conditioning tests
# and the Prony error locator; model code is dtype-explicit throughout.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session", autouse=True)
def _isolated_autotune_cache(tmp_path_factory):
    """Point the kernel autotuner's JSON cache at a session tmpdir so tests
    never read or pollute the user-level ~/.cache/coded-fft table (service
    warmup runs the search by default)."""
    old = os.environ.get("REPRO_AUTOTUNE_CACHE")
    os.environ["REPRO_AUTOTUNE_CACHE"] = str(tmp_path_factory.mktemp("autotune"))
    yield
    if old is None:
        os.environ.pop("REPRO_AUTOTUNE_CACHE", None)
    else:
        os.environ["REPRO_AUTOTUNE_CACHE"] = old
