"""Sharding plans + roofline machinery (pure logic, no 512-device mesh)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import useful_flops
from repro.launch.shardings import batch_pspecs, build_rules, cache_pspecs

import numpy as np


def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """Mesh over abstract devices -- build_rules only reads .shape/.axis_names."""

    class _M:
        def __init__(self):
            self.shape = dict(zip(axes, shape))
            self.axis_names = axes

    return _M()


def test_divisibility_fallbacks_qwen():
    cfg = get_config("qwen1.5-32b")  # 40 heads, kv=40, vocab 152064
    rules, fb = build_rules(cfg, SHAPES["train_4k"], _fake_mesh())
    assert rules["heads"] is None          # 40 % 16 != 0
    assert rules["kv_heads"] is None
    assert rules["vocab"] == "model"       # 152064 % 16 == 0
    assert rules["mlp"] == "model"
    assert any("n_heads" in f for f in fb)


def test_kv_seq_context_parallel_enabled_for_decode():
    cfg = get_config("qwen1.5-32b")
    rules, fb = build_rules(cfg, SHAPES["decode_32k"], _fake_mesh())
    assert rules["kv_seq"] == "model"      # kv replicated -> cache seq sharded
    rules_t, _ = build_rules(cfg, SHAPES["train_4k"], _fake_mesh())
    assert rules_t["kv_seq"] is None       # train: no cache


def test_long500k_batch_fallback():
    cfg = get_config("rwkv6-3b")
    rules, fb = build_rules(cfg, SHAPES["long_500k"], _fake_mesh())
    assert rules["batch"] is None          # batch=1 cannot shard
    assert rules["tokens"] is None


def test_multipod_batch_drops_to_data_when_pod_doesnt_divide():
    cfg = get_config("gemma-2b")
    mesh = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    # global_batch=32 for prefill: 32 % 512... batch axes pod*data = 32 -> ok
    rules, _ = build_rules(cfg, SHAPES["prefill_32k"], mesh)
    assert rules["batch"] == ("pod", "data")


def test_cache_pspecs_structure_matches_init_cache():
    from repro.models import build_model

    for arch in ("gemma-2b", "rwkv6-3b", "recurrentgemma-9b", "whisper-medium"):
        cfg = get_config(arch)
        rules, _ = build_rules(cfg, SHAPES["decode_32k"], _fake_mesh())
        model = build_model(cfg)
        cache = jax.eval_shape(lambda m=model: m.init_cache(4, 128))
        specs = cache_pspecs(cfg, rules)
        assert (jax.tree.structure(cache)
                == jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P))), arch


def test_batch_pspecs_cover_inputs():
    from repro.models import build_model

    cfg = get_config("paligemma-3b")
    model = build_model(cfg)
    rules, _ = build_rules(cfg, SHAPES["train_4k"], _fake_mesh())
    batch = model.input_specs(SHAPES["train_4k"])
    specs = batch_pspecs(cfg, SHAPES["train_4k"], rules)
    assert set(batch) == set(specs)


# ---------------- hlo analyzer ----------------------------------------------
def test_analyzer_trip_count_multiplication():
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    hlo = jax.jit(f).lower(x).compile().as_text()
    cost = analyze_hlo(hlo)
    one_matmul = 2 * 64 ** 3
    assert abs(cost.flops - 7 * one_matmul) / (7 * one_matmul) < 0.05


def test_analyzer_collective_accounting():
    import re

    # synthetic HLO exercise: one all-gather inside a trip-4 while
    hlo = """
HloModule m

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %g = f32[8]{0} all-gather(%x), dimensions={0}
  ROOT %t = (s32[], f32[8]) tuple(%i, %g)
}

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %t0 = (s32[], f32[8]) tuple(%a, %a)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %o = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    cost = analyze_hlo(hlo)
    assert cost.collective_counts.get("all-gather") == 4
    assert cost.collective_result_bytes["all-gather"] == 4 * 8 * 4


def test_useful_flops_sane():
    uf = useful_flops("gemma-2b", "train_4k")
    # 6 * ~2.5e9 * (256*4096) within a factor ~2
    assert 1e16 < uf["total"] < 4e16
    ud = useful_flops("gemma-2b", "decode_32k")
    assert ud["linear"] == pytest.approx(2 * ud["linear"] / 2)
    assert ud["total"] < 1e13
