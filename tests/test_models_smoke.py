"""Per-architecture smoke tests on REDUCED configs (CPU, 1 device).

For each of the 10 assigned architectures: instantiate the reduced config,
run one forward loss + one gradient step + prefill/decode, assert output
shapes and the absence of NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import build_model

B, S = 2, 32


def _batch_for(model, seq=S, batch=B):
    cfg = model.cfg
    rng = np.random.default_rng(0)
    tok = lambda *sh: jnp.asarray(rng.integers(0, cfg.vocab_size, sh), jnp.int32)
    if cfg.family == "encdec":
        return {
            "frames": jnp.asarray(rng.normal(size=(batch, seq, cfg.d_model)), jnp.float32),
            "tokens": tok(batch, seq),
            "labels": tok(batch, seq),
        }
    if cfg.family == "vlm":
        text = seq - cfg.num_prefix_tokens
        return {
            "patches": jnp.asarray(
                rng.normal(size=(batch, cfg.num_prefix_tokens, cfg.d_model)), jnp.float32
            ),
            "tokens": tok(batch, text),
            "labels": tok(batch, text),
        }
    return {"tokens": tok(batch, seq), "labels": tok(batch, seq)}


@pytest.fixture(scope="module", params=ARCH_IDS)
def built(request):
    cfg = get_reduced_config(request.param)
    # smoke in f32 for CPU numerics
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_loss_forward_no_nan(built):
    model, params = built
    loss, metrics = jax.jit(model.loss)(params, _batch_for(model))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"loss not finite: {loss}"
    assert float(loss) > 0.0


def test_grad_step_no_nan(built):
    model, params = built

    @jax.jit
    def gstep(p, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        return loss, grads

    loss, grads = gstep(params, _batch_for(model))
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), "NaN/Inf in grads"
    # at least most parameters receive gradient signal
    nonzero = sum(bool(jnp.any(g != 0)) for g in flat)
    assert nonzero >= len(flat) * 0.5


def test_prefill_then_decode_matches_shapes(built):
    model, params = built
    cfg = model.cfg
    cache_len = S + 8
    cache = model.init_cache(B, cache_len)
    batch = _batch_for(model)
    pre_in = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = jax.jit(model.prefill)(params, pre_in, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    dstep = jax.jit(model.decode_step)
    logits2, cache = dstep(params, cache, {"tokens": next_tok}, jnp.asarray(S))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # one more step to exercise cache progression
    logits3, cache = dstep(params, cache, {"tokens": next_tok}, jnp.asarray(S + 1))
    assert bool(jnp.all(jnp.isfinite(logits3)))


def test_param_counts_positive(built):
    model, _ = built
    assert model.n_params > 0
    assert 0 < model.n_active_params <= model.n_params
    if model.cfg.moe is not None:
        assert model.n_active_params < model.n_params
