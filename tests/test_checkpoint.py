"""Checkpoint store: atomicity, bf16 round-trip, GC, async writer."""

import json
import os
import shutil
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16) * 1.5,
                   "c": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_including_bf16():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        save_checkpoint(d, 3, t, metadata={"loss": 1.0})
        step, r = restore_checkpoint(d, t)
        assert step == 3
        assert r["nested"]["b"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]))
        np.testing.assert_array_equal(
            np.asarray(r["nested"]["b"], dtype=np.float32),
            np.asarray(t["nested"]["b"], dtype=np.float32))


def test_latest_step_ignores_torn_writes():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        save_checkpoint(d, 2, _tree())
        # a torn write: directory without manifest
        os.makedirs(os.path.join(d, "step_00000009"))
        assert latest_step(d) == 2


def test_structure_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        with pytest.raises(ValueError, match="structure mismatch"):
            restore_checkpoint(d, {"different": jnp.zeros(3)})


def test_gc_keeps_last_k():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            save_checkpoint(d, s, _tree())
        gc_checkpoints(d, keep_last=2)
        assert latest_step(d) == 4
        kept = sorted(n for n in os.listdir(d) if n.startswith("step_"))
        assert len(kept) == 2


def test_async_checkpointer_surfaces_and_orders():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep_last=None)
        ck.save(1, _tree())
        ck.save(2, _tree())  # implicitly waits for save 1
        ck.wait()
        assert latest_step(d) == 2
        step, _ = restore_checkpoint(d, _tree())
        assert step == 2
