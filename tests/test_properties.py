"""Property-based differential suite: EVERY CodedPlan vs numpy.fft.

One harness, all strategies (1-D, n-D, multi-input, uncoded repetition,
and the real/inverse plans of DESIGN.md §7), drawing

    (config, batch, dtype/backend tier, straggler mask)

and asserting end-to-end parity against the numpy oracle under ANY
``k >= recovery_threshold``-subset of responders, with straggler rows
NaN-poisoned to prove decode never reads them.  This supersedes the
per-plan ad-hoc example parity tests (the remaining example tests pin
shapes, protocol details, and dispatch rules, not parity).

Runs with or without hypothesis installed (tests/_hypothesis_shim.py);
the CI property job pins ``--hypothesis-seed`` and the default example
budget stays small for PR latency -- the ``slow``-marked sweep at the
bottom buys the full budget.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import HAVE_HYPOTHESIS, given, prop_settings, st

from repro.core import (
    REGISTRY,
    CodedFFT,
    CodedFFTMultiInput,
    CodedFFTND,
    CodedIFFT,
    CodedIRFFT,
    CodedIRFFTN,
    CodedPartialFFT,
    CodedRFFT,
    CodedRFFTN,
    UncodedRepetitionFFT,
)

# Example budget: small by default (PR latency); PROP_MAX_EXAMPLES
# overrides for local deep runs, the slow sweep below multiplies it.
MAX_EXAMPLES = int(os.environ.get("PROP_MAX_EXAMPLES", "8"))

# Enumerated valid configs keep the draw space dense in constructible
# plans (m | s, 2m | s for the real kinds, N >= m); drawing raw integers
# would reject almost everything.
CONFIGS_1D = [
    (32, 2, 5),
    (48, 4, 6),
    (64, 4, 8),
    (96, 3, 7),
    (120, 4, 9),
]
CONFIGS_ND = [
    ((8, 8), (2, 2), 6),
    ((16, 4), (4, 1), 5),
    ((12, 6), (2, 3), 8),
]
# n-D real configs additionally need an even LAST shard axis
# (2*factors[-1] | shape[-1], DESIGN.md §9)
CONFIGS_RND = [
    ((8, 8), (2, 2), 6),
    ((16, 4), (4, 1), 5),
    ((12, 8), (3, 2), 8),
    ((6, 4, 8), (3, 1, 2), 7),
    ((24,), (4,), 6),
]
CONFIGS_MI = [
    (4, (8,), 2, (2,), 6),
    (2, (4, 6), 2, (1, 2), 5),
    (6, (8,), 3, (1,), 4),
]
# (backend, dtype, rtol): the kernel tier computes in f32 planes; the
# reference tier is the c128 numerics oracle.
TIERS = [
    ("kernel", jnp.complex64, 5e-3),
    ("reference", jnp.complex64, 5e-3),
    ("reference", jnp.complex128, 1e-8),
]
BATCHES = (0, 1, 3)


def _mask(n: int, k: int, seed: int) -> np.ndarray:
    """A uniformly random availability pattern with exactly k responders."""
    rng = np.random.default_rng(seed)
    mask = np.zeros(n, bool)
    mask[rng.choice(n, size=k, replace=False)] = True
    return mask


def _arc_mask(n: int, k: int, seed: int) -> np.ndarray:
    """A contiguous-mod-n responder arc: the mask family the §4 ifft
    fast-decode dispatch routes to for small m."""
    start = seed % n
    mask = np.zeros(n, bool)
    mask[(start + np.arange(k)) % n] = True
    return mask


def _masks(n: int, threshold: int, batch: int, seed: int,
           contiguous: bool = False) -> np.ndarray:
    rng = np.random.default_rng(seed)
    rows = max(batch, 1)
    ks = rng.integers(threshold, n + 1, size=rows)
    make = _arc_mask if contiguous else _mask
    out = np.stack([make(n, int(k), seed + 17 * r + 1)
                    for r, k in enumerate(ks)])
    return out if batch else out[0]

def _rand(shape, seed, *, dtype):
    rng = np.random.default_rng(seed)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        data = rng.normal(size=shape) + 1j * rng.normal(size=shape)
    else:
        data = rng.normal(size=shape)
    return jnp.asarray(data.astype(dtype))


def _poisoned_run(plan, x, mask, *, fragment_mask=None):
    """encode -> worker -> NaN-poison stragglers -> masked decode.

    With ``fragment_mask`` (partial-work plans, DESIGN.md §13) the poison
    is per-FRAGMENT: an unfinished fragment row holds NaN even when other
    fragments of the same worker are live, proving decode reads exactly
    the claimed coverage set.
    """
    b = plan.worker_compute(plan.encode(x))
    if fragment_mask is not None:
        fm = jnp.asarray(fragment_mask)
        shield = fm.reshape(fm.shape + (1,) * (b.ndim - fm.ndim))
        b = jnp.where(shield, b, jnp.nan)
        return plan.decode(b, fragment_mask=fm)
    mk = jnp.asarray(mask)
    shield = mk.reshape(mk.shape + (1,) * (b.ndim - mk.ndim))
    b = jnp.where(shield, b, jnp.nan)
    return plan.decode(b, mask=mk)


def _check(got, want, rtol, label):
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, (label, got.shape, want.shape)
    err = np.abs(got - want).max() / max(np.abs(want).max(), 1e-12)
    assert err < rtol, (label, err)


# ------------------------------------------------------------ MDS plan kinds
@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_1D), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_coded_fft_matches_numpy(cfg, tier, batch, seed):
    s, m, n = cfg
    backend, dtype, rtol = tier
    plan = CodedFFT(s=s, m=m, n_workers=n, dtype=dtype, backend=backend)
    shape = ((batch, s) if batch else (s,))
    x = _rand(shape, seed, dtype=dtype)
    mask = _masks(n, m, batch, seed)
    _check(_poisoned_run(plan, x, mask),
           np.fft.fft(np.asarray(x, np.complex128), axis=-1), rtol, cfg)


@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_1D), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_coded_rfft_matches_numpy(cfg, tier, batch, seed):
    s, m, n = cfg
    backend, dtype, rtol = tier
    plan = CodedRFFT(s=s, m=m, n_workers=n, dtype=dtype, backend=backend)
    shape = ((batch, s) if batch else (s,))
    x = _rand(shape, seed, dtype=plan.real_dtype)
    mask = _masks(n, m, batch, seed)
    _check(_poisoned_run(plan, x, mask),
           np.fft.rfft(np.asarray(x, np.float64), axis=-1), rtol, cfg)


@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_1D), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_coded_ifft_matches_numpy(cfg, tier, batch, seed):
    s, m, n = cfg
    backend, dtype, rtol = tier
    plan = CodedIFFT(s=s, m=m, n_workers=n, dtype=dtype, backend=backend)
    shape = ((batch, s) if batch else (s,))
    x = _rand(shape, seed, dtype=dtype)
    mask = _masks(n, m, batch, seed)
    _check(_poisoned_run(plan, x, mask),
           np.fft.ifft(np.asarray(x, np.complex128), axis=-1), rtol, cfg)


@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_1D), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_coded_irfft_matches_numpy(cfg, tier, batch, seed):
    s, m, n = cfg
    backend, dtype, rtol = tier
    plan = CodedIRFFT(s=s, m=m, n_workers=n, dtype=dtype, backend=backend)
    # draw the half spectrum of a REAL signal so the request is exactly
    # Hermitian-consistent (numpy drops endpoint imag parts; so do we --
    # pinned separately below)
    shape = ((batch, s) if batch else (s,))
    xt = np.random.default_rng(seed).normal(size=shape)
    y = jnp.asarray(np.fft.rfft(xt, axis=-1).astype(dtype))
    mask = _masks(n, m, batch, seed)
    _check(_poisoned_run(plan, y, mask),
           np.fft.irfft(np.asarray(y, np.complex128), n=s, axis=-1),
           rtol, cfg)


def test_irfft_endpoint_imag_discarded_like_numpy():
    """Non-Hermitian endpoint bins: parity with numpy.fft.irfft exactly."""
    s, m, n = 64, 4, 8
    rng = np.random.default_rng(0)
    y = np.fft.rfft(rng.normal(size=s)).astype(np.complex128)
    y[0] += 0.7j
    y[-1] -= 0.3j
    plan = CodedIRFFT(s=s, m=m, n_workers=n, dtype=jnp.complex128,
                      backend="reference")
    _check(plan.run(jnp.asarray(y)), np.fft.irfft(y, n=s), 1e-8, "endpoints")


@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_ND), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_coded_fft_nd_matches_numpy(cfg, tier, batch, seed):
    shape, factors, n = cfg
    backend, dtype, rtol = tier
    plan = CodedFFTND(shape=shape, factors=factors, n_workers=n,
                      dtype=dtype, backend=backend)
    full = ((batch,) + shape if batch else shape)
    t = _rand(full, seed, dtype=dtype)
    mask = _masks(n, plan.m, batch, seed)
    _check(_poisoned_run(plan, t, mask),
           np.fft.fftn(np.asarray(t, np.complex128),
                       axes=tuple(range(-len(shape), 0))), rtol, cfg)


@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_RND), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_coded_rfftn_matches_numpy(cfg, tier, batch, seed):
    """n-D real forward (DESIGN.md §9): pair-packed half-payload shards,
    per-axis worker sweep, generalized split postdecode == numpy.rfftn
    under NaN-poisoned straggler masks."""
    shape, factors, n = cfg
    backend, dtype, rtol = tier
    plan = CodedRFFTN(shape=shape, factors=factors, n_workers=n,
                      dtype=dtype, backend=backend)
    full = ((batch,) + shape if batch else shape)
    t = _rand(full, seed, dtype=plan.real_dtype)
    mask = _masks(n, plan.m, batch, seed)
    axes = tuple(range(-len(shape), 0))
    _check(_poisoned_run(plan, t, mask),
           np.fft.rfftn(np.asarray(t, np.float64), axes=axes), rtol, cfg)


@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_RND), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_coded_irfftn_matches_numpy(cfg, tier, batch, seed):
    """n-D real inverse: the adjoint pipeline (symmetrize -> per-axis
    fold -> pack -> ifftn workers) == numpy.irfftn on Hermitian-consistent
    draws (the inconsistent-endpoint contract is pinned in
    tests/test_rfftn.py)."""
    shape, factors, n = cfg
    backend, dtype, rtol = tier
    plan = CodedIRFFTN(shape=shape, factors=factors, n_workers=n,
                       dtype=dtype, backend=backend)
    full = ((batch,) + shape if batch else shape)
    axes = tuple(range(-len(shape), 0))
    xt = np.random.default_rng(seed).normal(size=full)
    y = jnp.asarray(np.fft.rfftn(xt, axes=axes).astype(dtype))
    mask = _masks(n, plan.m, batch, seed)
    _check(_poisoned_run(plan, y, mask),
           np.fft.irfftn(np.asarray(y, np.complex128), s=shape, axes=axes),
           rtol, cfg)


@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_MI), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_multi_input_matches_numpy(cfg, tier, batch, seed):
    q, shape, m_tilde, factors, n = cfg
    backend, dtype, rtol = tier
    plan = CodedFFTMultiInput(q=q, shape=shape, m_tilde=m_tilde,
                              factors=factors, n_workers=n, dtype=dtype,
                              backend=backend)
    full = ((batch, q) + shape if batch else (q,) + shape)
    t = _rand(full, seed, dtype=dtype)
    mask = _masks(n, plan.m, batch, seed)
    _check(_poisoned_run(plan, t, mask),
           np.fft.fftn(np.asarray(t, np.complex128),
                       axes=tuple(range(-len(shape), 0))), rtol, cfg)


# ----------------------------------------------------- strategy registry
# Every registered strategy (core.strategies.REGISTRY) runs the SAME
# differential harness: applicability-filtered configs, its OWN recovery
# threshold, NaN-poisoned straggler draws.  A new strategy registered with
# a factory + applicability predicate is verified here with zero new test
# code (DESIGN.md §13).

# extend the 1-D pool so the repetition entry (m^2 | N) draws non-trivial
# configs too
CONFIGS_REGISTRY = CONFIGS_1D + [(32, 2, 8), (64, 2, 4), (48, 2, 12)]


def _fragment_masks(n: int, r: int, need: int, batch: int,
                    seed: int) -> np.ndarray:
    """Random sequential-prefix fragment patterns meeting the coverage
    condition: worker w finished ``p_w`` fragments (0..r), total >= need."""
    rng = np.random.default_rng(seed)
    rows = max(batch, 1)
    out = np.zeros((rows, n, r), bool)
    for b in range(rows):
        prefix = rng.integers(0, r + 1, size=n)
        while prefix.sum() < need:
            w = int(rng.integers(n))
            prefix[w] = min(r, prefix[w] + 1)
        for w, p in enumerate(prefix):
            out[b, w, :p] = True
    return out if batch else out[0]


@prop_settings(max_examples=MAX_EXAMPLES)
@given(name=st.sampled_from(sorted(REGISTRY)),
       cfg=st.sampled_from(CONFIGS_REGISTRY), tier=st.sampled_from(TIERS),
       batch=st.sampled_from(BATCHES), seed=st.integers(0, 10**6))
def test_registry_strategy_matches_numpy(name, cfg, tier, batch, seed):
    """Differential-vs-numpy over the whole strategy registry, worker-mask
    draws at each strategy's own threshold (m for mds/partial, m*q for
    comm_efficient, N - N/m^2 + 1 for repetition)."""
    s, m, n = cfg
    backend, dtype, rtol = tier
    ent = REGISTRY[name]
    if not ent.applicable(s, m, n, None):
        return          # the registry's own applicability filter
    if not ent.kernel_ok:
        backend = "reference"   # the planar kernels are (N, m) MDS layouts
    plan = ent.build(s, m, n, dtype=dtype, backend=backend)
    if name == "repetition" and batch:
        batch = 0       # the baseline's host-side decode is checked 1-D
    shape = ((batch, s) if batch else (s,))
    x = _rand(shape, seed, dtype=dtype)
    mask = _masks(n, int(plan.recovery_threshold), batch, seed)
    _check(_poisoned_run(plan, x, mask),
           np.fft.fft(np.asarray(x, np.complex128), axis=-1), rtol,
           (name, cfg))


@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_REGISTRY), r=st.sampled_from([2, 3]),
       tier=st.sampled_from(TIERS), batch=st.sampled_from(BATCHES),
       seed=st.integers(0, 10**6))
def test_partial_fragment_prefixes_match_numpy(cfg, r, tier, batch, seed):
    """Partial-work decode from RAGGED fragment prefixes: random per-worker
    progress 0..r meeting the m*r coverage condition, unfinished fragment
    rows NaN-poisoned -- stragglers contribute prefixes, not holes."""
    s, m, n = cfg
    backend, dtype, rtol = tier
    if s % (m * r) or m * r > 8:
        return          # keep the decode width inside the tier rtols
    plan = CodedPartialFFT(s=s, m=m, n_workers=n, r=r, dtype=dtype,
                           backend="reference")
    shape = ((batch, s) if batch else (s,))
    x = _rand(shape, seed, dtype=dtype)
    fmask = _fragment_masks(n, r, plan.fragments_needed, batch, seed)
    _check(_poisoned_run(plan, x, None, fragment_mask=fmask),
           np.fft.fft(np.asarray(x, np.complex128), axis=-1), rtol,
           (cfg, r))


# -------------------------------------------------------- non-MDS baseline
@prop_settings(max_examples=MAX_EXAMPLES)
@given(cfg=st.sampled_from([(32, 2, 8), (64, 2, 4), (48, 2, 12)]),
       seed=st.integers(0, 10**6))
def test_uncoded_repetition_matches_numpy(cfg, seed):
    """The repetition baseline decodes from any mask at or above ITS
    (higher, Remark-4) threshold -- same differential harness, non-MDS
    decode."""
    s, m, n = cfg
    plan = UncodedRepetitionFFT(s=s, m=m, n_workers=n, dtype=jnp.complex128)
    x = _rand((s,), seed, dtype=jnp.complex128)
    k = int(np.random.default_rng(seed).integers(
        plan.recovery_threshold, n + 1))
    mask = _mask(n, k, seed + 1)
    got = plan.decode(plan.worker_compute(plan.encode(x)), mask=mask)
    _check(got, np.fft.fft(np.asarray(x, np.complex128)), 1e-8, cfg)


# ------------------------------------------------------------- deep sweep
@pytest.mark.slow
@prop_settings(max_examples=4 * MAX_EXAMPLES)
@given(cfg=st.sampled_from(CONFIGS_1D),
       kind=st.sampled_from(["c2c", "r2c", "c2r", "inv"]),
       tier=st.sampled_from(TIERS), batch=st.sampled_from(BATCHES),
       contiguous=st.booleans(), seed=st.integers(0, 10**6))
def test_full_budget_sweep(cfg, kind, tier, batch, contiguous, seed):
    """The full-budget pass over every 1-D kind (slow marker: deselected
    from the PR-latency CI property job, included in tier-1).  The
    ``contiguous`` draw alternates scattered responder masks with
    contiguous arcs -- the family §4's ifft fast decode dispatches to."""
    s, m, n = cfg
    backend, dtype, rtol = tier
    shape = ((batch, s) if batch else (s,))
    mask = _masks(n, m, batch, seed, contiguous=contiguous)
    if kind == "c2c":
        plan = CodedFFT(s=s, m=m, n_workers=n, dtype=dtype, backend=backend)
        x = _rand(shape, seed, dtype=dtype)
        want = np.fft.fft(np.asarray(x, np.complex128), axis=-1)
    elif kind == "inv":
        plan = CodedIFFT(s=s, m=m, n_workers=n, dtype=dtype, backend=backend)
        x = _rand(shape, seed, dtype=dtype)
        want = np.fft.ifft(np.asarray(x, np.complex128), axis=-1)
    elif kind == "r2c":
        plan = CodedRFFT(s=s, m=m, n_workers=n, dtype=dtype, backend=backend)
        x = _rand(shape, seed, dtype=plan.real_dtype)
        want = np.fft.rfft(np.asarray(x, np.float64), axis=-1)
    else:
        plan = CodedIRFFT(s=s, m=m, n_workers=n, dtype=dtype,
                          backend=backend)
        xt = np.random.default_rng(seed).normal(size=shape)
        x = jnp.asarray(np.fft.rfft(xt, axis=-1).astype(dtype))
        want = np.fft.irfft(np.asarray(x, np.complex128), n=s, axis=-1)
    _check(_poisoned_run(plan, x, mask), want, rtol, (cfg, kind))


def test_shim_mode_reported():
    """Pin that the suite ran (collection smoke) and report which sampler
    backed it -- the deterministic shim or real hypothesis."""
    assert MAX_EXAMPLES >= 1
    assert HAVE_HYPOTHESIS in (True, False)


# --------------------------------------------------- bf16 plane precision
# The opt-in bf16 twiddle/DFT planes (f32 accumulation) must stay inside
# ops.BF16_RTOL of the float64 oracle -- the same budget the service's
# per-(s, m, kind) warmup probe enforces before enabling the mode.
BF16_CONFIGS = [(64, 2, 5), (96, 3, 7), (256, 4, 8), (2048, 4, 8)]


@pytest.mark.parametrize("cfg", BF16_CONFIGS)
def test_bf16_bucket_planes_within_error_budget(cfg):
    from repro.core import mds
    from repro.kernels import ops, ref

    s, m, n = cfg
    q = 3
    rng = np.random.default_rng(s)
    g = mds.rs_generator(n, m, jnp.complex64)
    gr, gi = ref.planar(g)
    x = rng.standard_normal((q, s)) + 1j * rng.standard_normal((q, s))
    xr = jnp.asarray(x.real.astype(np.float32))
    xi = jnp.asarray(x.imag.astype(np.float32))
    masks = np.zeros((q, n), bool)
    for r in range(q):
        masks[r, rng.choice(n, size=m, replace=False)] = True
    want = np.fft.fft(x, axis=-1)
    for itp in (None, True):
        yr, yi = ops.coded_bucket_masked(
            xr, xi, jnp.asarray(masks), gr, gi, s,
            interpret=itp, precision="bf16")
        got = np.asarray(yr) + 1j * np.asarray(yi)
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < ops.BF16_RTOL, (cfg, itp, rel)
        # and bf16 must actually differ from the f32 planes (the knob is
        # live, not silently ignored)
        fr, fi = ops.coded_bucket_masked(
            xr, xi, jnp.asarray(masks), gr, gi, s,
            interpret=itp, precision="f32")
        assert np.abs(np.asarray(fr) - np.asarray(yr)).max() > 0


@pytest.mark.parametrize("ell", [256, 4096])
def test_bf16_fourstep_within_error_budget(ell):
    from repro.kernels import ops

    rng = np.random.default_rng(ell)
    x = rng.standard_normal((2, ell)) + 1j * rng.standard_normal((2, ell))
    xr = jnp.asarray(x.real.astype(np.float32))
    xi = jnp.asarray(x.imag.astype(np.float32))
    want = np.fft.fft(x, axis=-1)
    for variant in ("fused", "two_pass"):
        outr, outi = ops.fourstep_planar(xr, xi, variant=variant,
                                         precision="bf16")
        got = np.asarray(outr) + 1j * np.asarray(outi)
        rel = np.abs(got - want).max() / np.abs(want).max()
        assert rel < ops.BF16_RTOL, (ell, variant, rel)


def test_bf16_probe_auto_disables_per_shape(monkeypatch, tmp_path):
    """cfg.precision="bf16" is gated per (s, m, kind): a failing probe
    records ok=False in the autotune table and the runner stays f32."""
    from repro.kernels import autotune
    from repro.serving.fft_service import FFTService, FFTServiceConfig

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path))
    saved = dict(autotune._TABLES)
    saved_loaded = set(autotune._LOADED)
    autotune._TABLES.clear()
    autotune._LOADED.clear()
    try:
        cfg = FFTServiceConfig(s=64, m=2, n_workers=4, precision="bf16",
                               autotune=False)
        svc = FFTService(cfg)
        monkeypatch.setattr(FFTService, "_probe_bf16",
                            lambda self, s, kind: False)
        assert svc._precision_for(64, "c2c") == "f32"
        ent = autotune.lookup("bf16", s=64, m=2, k="c2c",
                              mode=__import__("repro.kernels.ops",
                                              fromlist=["ops"])._mode(None))
        assert ent == {"ok": False}
        # the verdict is sticky: a healthy probe later still reads f32
        monkeypatch.setattr(FFTService, "_probe_bf16",
                            lambda self, s, kind: True)
        assert svc._precision_for(64, "c2c") == "f32"
    finally:
        autotune._TABLES.clear()
        autotune._TABLES.update(saved)
        autotune._LOADED.clear()
        autotune._LOADED.update(saved_loaded)
