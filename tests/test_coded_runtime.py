"""shard_map coded runtime + elastic resharding + gradient coding +
compression (runs under 8 forced host devices in a subprocess-free way:
conftest does NOT set XLA_FLAGS, so these tests spawn their own devices
via a session-scoped guard only when the flag is already present, else
they exercise the mesh=None code paths and a subprocess for the real one).
"""

import itertools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coded_fft import CodedFFT
from repro.optim.compression import (
    compress,
    compression_ratio,
    decompress,
    init_residual,
)
from repro.optim.gradient_coding import CyclicGradientCode

_HAVE_DEVICES = jax.device_count() >= 8


# ---------------- gradient coding (pure math, single device) ----------------
@pytest.mark.parametrize("n,s", [(4, 0), (5, 1), (6, 2), (8, 3)])
def test_gradient_coding_all_subsets(n, s):
    code = CyclicGradientCode(n_workers=n, n_stragglers=s)
    grads = [{"w": jnp.full((3,), float(i + 1))} for i in range(n)]
    msgs = [code.encode_worker_grad(k, grads) for k in range(n)]
    total = jax.tree.map(lambda *g: sum(g), *grads)
    for subset in itertools.combinations(range(n), n - s):
        dec = code.decode(np.asarray(subset), [msgs[i] for i in subset])
        np.testing.assert_allclose(np.asarray(dec["w"]),
                                   np.asarray(total["w"]), rtol=1e-4)


def test_gradient_coding_support_is_cyclic():
    code = CyclicGradientCode(n_workers=6, n_stragglers=2)
    assert code.worker_partitions(5) == [5, 0, 1]
    assert code.recovery_threshold == 4


# ---------------- error-feedback compression --------------------------------
def test_compression_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g_seq = [jnp.asarray(rng.standard_normal(512), jnp.float32) * 0.01
             for _ in range(50)]
    res = init_residual(g_seq[0])
    acc_comp = jnp.zeros(512)
    for g in g_seq:
        code, res = compress(g, res)
        acc_comp = acc_comp + decompress(code, (512,))
    acc_true = sum(g_seq)
    # with error feedback, accumulated error stays bounded by one step's
    # quantization error rather than growing with T
    err = float(jnp.max(jnp.abs(acc_comp + res - acc_true)))
    assert err < 1e-4
    assert compression_ratio((512,)) > 3.5


# ---------------- distributed runtime (needs 8 host devices) ----------------
_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.core.coded_fft import CodedFFT
from repro.distributed import DistributedCodedFFT, test_mesh, reshard
from jax.sharding import PartitionSpec as P

mesh = test_mesh((8,), ("workers",))
plan = CodedFFT(s=1024, m=4, n_workers=8)
d = DistributedCodedFFT(plan, mesh)
x = (jax.random.normal(jax.random.PRNGKey(0), (1024,))
     + 1j * jax.random.normal(jax.random.PRNGKey(1), (1024,))).astype(jnp.complex64)
ref = jnp.fft.fft(x)
mask = jnp.asarray([False, True, False, True, True, False, True, False])
out = d.run(x, mask)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-2, f"masked decode err {err}"

# collective accounting: exactly one all-gather of s coded symbols
txt = d.lower().compile().as_text()
assert txt.count("all-gather") >= 1

# n-D real plan over the same 8-device mesh (DESIGN.md §9): half-size
# packed shard shapes thread through both shard_map stages unchanged
import numpy as np
from repro.core import CodedRFFTN
rplan = CodedRFFTN(shape=(16, 16), factors=(2, 2), n_workers=8)
dr = DistributedCodedFFT(rplan, mesh)
t = np.random.default_rng(0).normal(size=(16, 16)).astype("float32")
rout = dr.run(jnp.asarray(t), mask)
rerr = float(np.abs(np.asarray(rout) - np.fft.rfftn(t.astype("float64"))).max())
assert rerr < 1e-2, f"rfftn mesh err {rerr}"

# elastic: move a sharded tree 8 -> 4 -> 8 devices bit-exactly
m8 = test_mesh((8,), ("d",))
m4 = test_mesh((4,), ("d",))
tree = {"w": jnp.arange(64.0).reshape(8, 8)}
specs = {"w": P("d", None)}
t8 = reshard(tree, m8, specs)
t4 = reshard(t8, m4, specs)
t8b = reshard(t4, m8, specs)
import numpy as np
np.testing.assert_array_equal(np.asarray(t8b["w"]), np.asarray(tree["w"]))
print("SUBPROC_OK")
"""


@pytest.mark.slow
def test_distributed_runtime_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=os.getcwd(),
                       capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SUBPROC_OK" in r.stdout


# ---------------- batched mask-to-weights decode (DESIGN.md §8) -------------
def test_batched_mesh_decode_uses_lagrange_weights():
    """Batched masked decode under the mesh builds per-request Lagrange
    decode matrices IN-TRACE: the lowered program carries no dense solve
    (the pre-§8 path vmapped ``linalg.solve`` per request), and the
    NaN-poisoned straggler rows provably never reach the output (the
    weights gather responder rows before contracting).  A 1-wide axis
    keeps all 8 coded shards local, so this traces on one device."""
    from repro.distributed import DistributedCodedFFT, test_mesh

    mesh = test_mesh((1,), ("workers",))
    plan = CodedFFT(s=256, m=4, n_workers=8)
    d = DistributedCodedFFT(plan, mesh, masked_fill=float("nan"))
    rng = np.random.default_rng(0)
    x = jnp.asarray((rng.normal(size=(3, 256))
                     + 1j * rng.normal(size=(3, 256))).astype(np.complex64))
    masks = jnp.asarray(np.array([
        [1, 0, 1, 1, 0, 1, 0, 0],
        [1, 1, 1, 1, 1, 1, 1, 1],
        [0, 1, 0, 1, 1, 0, 1, 1],
    ], bool))
    out = np.asarray(d.run(x, masks))
    want = np.fft.fft(np.asarray(x, np.complex128), axis=-1)
    assert not np.isnan(out).any()
    assert np.abs(out - want).max() < 1e-2
    jaxpr = str(jax.make_jaxpr(lambda xx, mk: d.run(xx, mk))(x, masks))
    assert "triangular_solve" not in jaxpr     # no per-request dense solve
    assert "sort" in jaxpr                     # in-trace responder subsets


# ---------------- single-device coded-FFT semantics still hold --------------
def test_plan_run_with_garbage_stragglers_local():
    plan = CodedFFT(s=256, m=4, n_workers=6)
    x = (jax.random.normal(jax.random.PRNGKey(0), (256,)) + 0j).astype(jnp.complex64)
    b = plan.worker_compute(plan.encode(x))
    b = b.at[jnp.asarray([1, 4])].set(jnp.nan)      # stragglers return garbage
    mask = jnp.asarray([True, False, True, True, False, True])
    out = plan.decode(b, mask=mask)
    err = float(jnp.max(jnp.abs(out - jnp.fft.fft(x))))
    assert err < 1e-3
