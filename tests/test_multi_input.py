"""Coded FFT with multiple inputs (Theorems 5/6)."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import CodedFFTMultiInput

C128 = jnp.complex128


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) + 1j * rng.normal(size=shape))


@pytest.mark.parametrize(
    "q,shape,m_tilde,factors,n",
    [
        (4, (8,), 2, (2,), 6),      # m = 4
        (2, (4, 4), 2, (2, 1), 6),  # m = 4, 2-D
        (6, (6,), 3, (1,), 5),      # m = 3, coding purely across inputs
        (2, (8,), 1, (4,), 6),      # m = 4, coding purely across space
    ],
)
def test_multi_input_matches_fftn(q, shape, m_tilde, factors, n):
    t = _rand((q,) + shape, seed=q * 10)
    strat = CodedFFTMultiInput(
        q=q, shape=shape, m_tilde=m_tilde, factors=factors, n_workers=n, dtype=C128
    )
    got = strat.run(t)
    want = np.stack([np.fft.fftn(np.asarray(t[h])) for h in range(q)])
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-8)


def test_multi_input_every_subset():
    q, shape, m_tilde, factors, n = 4, (4,), 2, (2,), 6
    t = _rand((q,) + shape, seed=5)
    strat = CodedFFTMultiInput(
        q=q, shape=shape, m_tilde=m_tilde, factors=factors, n_workers=n, dtype=C128
    )
    b = strat.worker_compute(strat.encode(t))
    want = np.stack([np.fft.fft(np.asarray(t[h])) for h in range(q)])
    for sub in itertools.combinations(range(n), strat.m):
        got = strat.decode(b, subset=jnp.asarray(sub))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-7)


def test_worker_storage_is_qs_over_m():
    """System model check: each worker stores exactly qs/m elements."""
    q, shape, m_tilde, factors, n = 4, (8, 4), 2, (2, 2), 20
    strat = CodedFFTMultiInput(
        q=q, shape=shape, m_tilde=m_tilde, factors=factors, n_workers=n, dtype=C128
    )
    t = _rand((q,) + shape)
    a = strat.encode(t)
    per_worker = int(np.prod(a.shape[1:]))
    assert per_worker == q * np.prod(shape) // strat.m
    assert strat.m == 8
    assert strat.recovery_threshold == strat.m


@settings(max_examples=15, deadline=None)
@given(
    q=st.sampled_from([2, 4]),
    m_tilde=st.sampled_from([1, 2]),
    m0=st.sampled_from([1, 2]),
    extra=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_multi_input(q, m_tilde, m0, extra, seed):
    rng = np.random.default_rng(seed)
    shape = (8,)
    t = jnp.asarray(rng.normal(size=(q,) + shape) + 1j * rng.normal(size=(q,) + shape))
    strat = CodedFFTMultiInput(
        q=q, shape=shape, m_tilde=m_tilde, factors=(m0,),
        n_workers=m_tilde * m0 + extra, dtype=C128,
    )
    b = strat.worker_compute(strat.encode(t))
    sub = jnp.asarray(rng.choice(strat.n_workers, size=strat.m, replace=False))
    got = strat.decode(b, subset=sub)
    want = np.stack([np.fft.fft(np.asarray(t[h])) for h in range(q)])
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-6)
