"""MDS code properties: every m-subset invertible, conditioning, fast encode."""

import itertools

import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import mds


def test_generator_shape_and_nodes():
    g = mds.rs_generator(8, 3, jnp.complex128)
    assert g.shape == (8, 3)
    nodes = np.asarray(mds.rs_nodes(8, jnp.complex128))
    np.testing.assert_allclose(np.abs(nodes), 1.0, atol=1e-12)
    assert len(np.unique(np.round(nodes, 9))) == 8


def test_every_submatrix_invertible_small():
    """The MDS property itself: every m x m submatrix non-singular."""
    n, m = 8, 4
    g = np.asarray(mds.rs_generator(n, m, jnp.complex128))
    for sub in itertools.combinations(range(n), m):
        s = np.linalg.svd(g[list(sub)], compute_uv=False)
        assert s[-1] > 1e-9


def test_subset_conditioning_reasonable():
    """Unit-circle nodes keep subset inverses well conditioned (float safety)."""
    n, m = 16, 8
    g = np.asarray(mds.rs_generator(n, m, jnp.complex128))
    worst = 0.0
    for sub in itertools.combinations(range(n), m):
        worst = max(worst, np.linalg.cond(g[list(sub)]))
    assert worst < 1e7  # decodable in float64 with plenty of headroom


def test_encode_decode_roundtrip_payload():
    n, m, payload = 10, 4, (7, 3)
    g = mds.rs_generator(n, m, jnp.complex128)
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.normal(size=(m,) + payload) + 1j * rng.normal(size=(m,) + payload))
    a = mds.encode(g, c)
    assert a.shape == (n,) + payload
    got = mds.decode_from_subset(g, a, jnp.asarray([9, 2, 5, 0]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(c), atol=1e-9)


def test_encode_dft_equals_matrix_encode():
    n, m = 12, 5
    g = mds.rs_generator(n, m, jnp.complex128)
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.normal(size=(m, 6)) + 1j * rng.normal(size=(m, 6)))
    np.testing.assert_allclose(
        np.asarray(mds.encode_dft(c, n)), np.asarray(mds.encode(g, c)), atol=1e-9
    )


def test_first_available_stable_order():
    mask = jnp.asarray([False, True, False, True, True, False, True])
    idx = np.asarray(mds.first_available(mask, 3))
    np.testing.assert_array_equal(idx, [1, 3, 4])


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 24),
    m_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_random_subset_decode(n, m_frac, seed):
    m = max(1, int(n * m_frac))
    g = mds.rs_generator(n, m, jnp.complex128)
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(m, 4)) + 1j * rng.normal(size=(m, 4)))
    a = mds.encode(g, c)
    sub = jnp.asarray(rng.choice(n, size=m, replace=False))
    got = mds.decode_from_subset(g, a, sub)
    np.testing.assert_allclose(np.asarray(got), np.asarray(c), atol=1e-6)
