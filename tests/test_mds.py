"""MDS code properties: every m-subset invertible, conditioning, fast encode."""

import itertools

import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core import mds


def test_generator_shape_and_nodes():
    g = mds.rs_generator(8, 3, jnp.complex128)
    assert g.shape == (8, 3)
    nodes = np.asarray(mds.rs_nodes(8, jnp.complex128))
    np.testing.assert_allclose(np.abs(nodes), 1.0, atol=1e-12)
    assert len(np.unique(np.round(nodes, 9))) == 8


def test_every_submatrix_invertible_small():
    """The MDS property itself: every m x m submatrix non-singular."""
    n, m = 8, 4
    g = np.asarray(mds.rs_generator(n, m, jnp.complex128))
    for sub in itertools.combinations(range(n), m):
        s = np.linalg.svd(g[list(sub)], compute_uv=False)
        assert s[-1] > 1e-9


def test_subset_conditioning_reasonable():
    """Unit-circle nodes keep subset inverses well conditioned (float safety)."""
    n, m = 16, 8
    g = np.asarray(mds.rs_generator(n, m, jnp.complex128))
    worst = 0.0
    for sub in itertools.combinations(range(n), m):
        worst = max(worst, np.linalg.cond(g[list(sub)]))
    assert worst < 1e7  # decodable in float64 with plenty of headroom


def test_encode_decode_roundtrip_payload():
    n, m, payload = 10, 4, (7, 3)
    g = mds.rs_generator(n, m, jnp.complex128)
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.normal(size=(m,) + payload) + 1j * rng.normal(size=(m,) + payload))
    a = mds.encode(g, c)
    assert a.shape == (n,) + payload
    got = mds.decode_from_subset(g, a, jnp.asarray([9, 2, 5, 0]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(c), atol=1e-9)


def test_encode_dft_equals_matrix_encode():
    n, m = 12, 5
    g = mds.rs_generator(n, m, jnp.complex128)
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.normal(size=(m, 6)) + 1j * rng.normal(size=(m, 6)))
    np.testing.assert_allclose(
        np.asarray(mds.encode_dft(c, n)), np.asarray(mds.encode(g, c)), atol=1e-9
    )


def test_first_available_stable_order():
    mask = jnp.asarray([False, True, False, True, True, False, True])
    idx = np.asarray(mds.first_available(mask, 3))
    np.testing.assert_array_equal(idx, [1, 3, 4])


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 24),
    m_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_random_subset_decode(n, m_frac, seed):
    m = max(1, int(n * m_frac))
    g = mds.rs_generator(n, m, jnp.complex128)
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(m, 4)) + 1j * rng.normal(size=(m, 4)))
    a = mds.encode(g, c)
    sub = jnp.asarray(rng.choice(n, size=m, replace=False))
    got = mds.decode_from_subset(g, a, sub)
    np.testing.assert_allclose(np.asarray(got), np.asarray(c), atol=1e-6)


# -------------------- §4 decode_auto dispatch boundary (regression pins) -----
def _decode_jaxpr(n, m, subset):
    g = mds.rs_generator(n, m, jnp.complex128)
    b = jnp.zeros((n, 6), jnp.complex128)
    # the subset must be CONCRETE before tracing begins: array creation
    # inside the trace is staged to a Tracer, which would flip decode_auto
    # onto its traced lax.cond path and put BOTH branches in the jaxpr
    sub = jnp.asarray(subset)
    import jax

    return str(jax.make_jaxpr(lambda bb: mds.decode_auto(g, bb, sub))(b))


def test_decode_auto_boundary_at_ifft_auto_max_m():
    """Contiguous arcs at m == IFFT_AUTO_MAX_M must still take the O(s log N)
    transform decode: the jaxpr contains fft ops and no dense solve."""
    m = mds.IFFT_AUTO_MAX_M
    jaxpr = _decode_jaxpr(m + 4, m, list(range(3, 3 + m)))
    assert "fft" in jaxpr
    assert "triangular_solve" not in jaxpr


def test_decode_auto_boundary_above_ifft_auto_max_m():
    """One past the boundary (m == IFFT_AUTO_MAX_M + 1) the same contiguous
    arc must flip to the backward-stable Vandermonde solve: no fft ops."""
    m = mds.IFFT_AUTO_MAX_M + 1
    jaxpr = _decode_jaxpr(m + 4, m, list(range(3, 3 + m)))
    assert "fft" not in jaxpr
    assert "triangular_solve" in jaxpr


def test_batched_decode_resolves_auto_to_solve_statically():
    """Per-request masked decode under vmap must resolve auto -> solve at
    TRACE time: a lax.cond would select-execute BOTH decode paths per
    request (plan.py).  Assert the jaxpr carries neither cond nor fft."""
    import jax

    from repro.core import CodedFFT

    plan = CodedFFT(s=48, m=4, n_workers=8, dtype=jnp.complex128)
    b = jnp.zeros((3, 8, 12), jnp.complex128)
    masks = jnp.ones((3, 8), bool)
    jaxpr = str(jax.make_jaxpr(
        lambda bb, mk: plan.decode(bb, mask=mk))(b, masks))
    assert "cond[" not in jaxpr
    assert "triangular_solve" in jaxpr
    assert "fft" not in jaxpr


def test_decode_auto_traced_subset_keeps_cond_unbatched():
    """The UNbatched traced-subset path deliberately keeps the lax.cond
    dispatch (a real branch outside vmap) -- pin it so the static
    resolution above stays a batched-only special case."""
    import jax

    n, m = 10, 4
    g = mds.rs_generator(n, m, jnp.complex128)
    b = jnp.zeros((n, 5), jnp.complex128)
    jaxpr = str(jax.make_jaxpr(
        lambda bb, ss: mds.decode_auto(g, bb, ss))(b, jnp.arange(m)))
    assert "cond[" in jaxpr
