"""Baseline strategies and the Remark-4 recovery-threshold comparison."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CodedFFT,
    UncodedRepetitionFFT,
    coded_fft_threshold,
    repetition_threshold,
    short_dot_threshold,
)

C128 = jnp.complex128


def _rand(s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=s) + 1j * rng.normal(size=s))


def test_threshold_formulas_remark4():
    n, m = 16, 2
    assert coded_fft_threshold(n, m) == 2
    assert repetition_threshold(n, m) == 16 - 4 + 1 == 13
    assert short_dot_threshold(n, m) == 16 - 8 + 2 == 10
    # coded FFT is orderwise better
    assert coded_fft_threshold(n, m) < short_dot_threshold(n, m) < repetition_threshold(n, m)


def test_repetition_computes_fft_when_all_alive():
    x = _rand(32, seed=1)
    strat = UncodedRepetitionFFT(s=32, m=2, n_workers=8, dtype=C128)
    got = strat.run(x)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-8)


def test_repetition_with_stragglers():
    x = _rand(32, seed=2)
    strat = UncodedRepetitionFFT(s=32, m=2, n_workers=8, dtype=C128)
    mask = np.ones(8, bool)
    mask[[0, 5]] = False  # blocks (0,0) and (0,1) still covered by replicas
    got = strat.run(x, mask=mask)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-8)


def test_repetition_threshold_is_exact_empirically():
    """Exhaustive check on small N: threshold is N - N/m^2 + 1, not less."""
    strat = UncodedRepetitionFFT(s=16, m=2, n_workers=8, dtype=C128)
    k_star = strat.worst_case_threshold()
    assert k_star == repetition_threshold(8, 2) == 7
    assert strat.is_k_recoverable(k_star)
    assert not strat.is_k_recoverable(k_star - 1)


def test_repetition_missing_block_fails():
    strat = UncodedRepetitionFFT(s=16, m=2, n_workers=8, dtype=C128)
    x = _rand(16, seed=3)
    mask = np.ones(8, bool)
    mask[[0, 4]] = False  # both replicas of block (0,0) dead
    assert not strat.decodable(mask)
    with pytest.raises(ValueError):
        strat.run(x, mask=mask)


def test_coded_fft_empirical_threshold_beats_baselines():
    """Coded FFT decodes from ANY m workers; repetition provably cannot."""
    s, m, n = 32, 2, 8
    coded = CodedFFT(s=s, m=m, n_workers=n, dtype=C128)
    x = _rand(s, seed=4)
    b = coded.worker_compute(coded.encode(x))
    want = np.fft.fft(np.asarray(x))
    for sub in itertools.combinations(range(n), m):
        got = coded.decode(b, subset=jnp.asarray(sub))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-7)
    # same N, m: repetition needs 7 of 8 in the worst case
    rep = UncodedRepetitionFFT(s=s, m=m, n_workers=n, dtype=C128)
    assert rep.worst_case_threshold() == 7 > coded.recovery_threshold == 2
