"""Baseline strategies and the Remark-4 recovery-threshold comparison."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CodedFFT,
    UncodedRepetitionFFT,
    coded_fft_threshold,
    repetition_threshold,
    short_dot_threshold,
)

C128 = jnp.complex128


def _rand(s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=s) + 1j * rng.normal(size=s))


def test_threshold_formulas_remark4():
    n, m = 16, 2
    assert coded_fft_threshold(n, m) == 2
    assert repetition_threshold(n, m) == 16 - 4 + 1 == 13
    assert short_dot_threshold(n, m) == 16 - 8 + 2 == 10
    # coded FFT is orderwise better
    assert coded_fft_threshold(n, m) < short_dot_threshold(n, m) < repetition_threshold(n, m)


def test_repetition_computes_fft_when_all_alive():
    x = _rand(32, seed=1)
    strat = UncodedRepetitionFFT(s=32, m=2, n_workers=8, dtype=C128)
    got = strat.run(x)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-8)


def test_repetition_with_stragglers():
    x = _rand(32, seed=2)
    strat = UncodedRepetitionFFT(s=32, m=2, n_workers=8, dtype=C128)
    mask = np.ones(8, bool)
    mask[[0, 5]] = False  # blocks (0,0) and (0,1) still covered by replicas
    got = strat.run(x, mask=mask)
    np.testing.assert_allclose(np.asarray(got), np.fft.fft(np.asarray(x)), atol=1e-8)


def test_repetition_threshold_is_exact_empirically():
    """Exhaustive check on small N: threshold is N - N/m^2 + 1, not less."""
    strat = UncodedRepetitionFFT(s=16, m=2, n_workers=8, dtype=C128)
    k_star = strat.worst_case_threshold()
    assert k_star == repetition_threshold(8, 2) == 7
    assert strat.is_k_recoverable(k_star)
    assert not strat.is_k_recoverable(k_star - 1)


def test_repetition_missing_block_fails():
    strat = UncodedRepetitionFFT(s=16, m=2, n_workers=8, dtype=C128)
    x = _rand(16, seed=3)
    mask = np.ones(8, bool)
    mask[[0, 4]] = False  # both replicas of block (0,0) dead
    assert not strat.decodable(mask)
    with pytest.raises(ValueError):
        strat.run(x, mask=mask)


def test_coded_fft_empirical_threshold_beats_baselines():
    """Coded FFT decodes from ANY m workers; repetition provably cannot."""
    s, m, n = 32, 2, 8
    coded = CodedFFT(s=s, m=m, n_workers=n, dtype=C128)
    x = _rand(s, seed=4)
    b = coded.worker_compute(coded.encode(x))
    want = np.fft.fft(np.asarray(x))
    for sub in itertools.combinations(range(n), m):
        got = coded.decode(b, subset=jnp.asarray(sub))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-7)
    # same N, m: repetition needs 7 of 8 in the worst case
    rep = UncodedRepetitionFFT(s=s, m=m, n_workers=n, dtype=C128)
    assert rep.worst_case_threshold() == 7 > coded.recovery_threshold == 2


# -- exhaustive per-strategy threshold verification (DESIGN.md §13) ----------
#
# For every registered strategy at a small (N, m): enumerate EVERY responder
# subset (and, for the partial strategy, every sequential fragment pattern)
# and assert ``decodable()`` holds iff the claimed recovery condition is met
# -- then spot-check that a boundary set actually decodes to numpy's answer.

from repro.core import (  # noqa: E402
    REGISTRY,
    CodedCommEffFFT,
    CodedPartialFFT,
    make_strategy,
)

# per-strategy small configs: (s, m, n_workers, param)
EXHAUSTIVE_CFGS = [
    ("mds", 16, 2, 4, None),
    ("mds", 24, 3, 5, None),
    ("partial", 16, 2, 4, 2),
    ("partial", 24, 2, 3, 3),
    ("comm_efficient", 16, 2, 5, 2),
    ("comm_efficient", 24, 2, 6, 3),
    ("repetition", 16, 2, 8, None),
]


def _subset_mask(n, sub):
    mask = np.zeros(n, bool)
    mask[list(sub)] = True
    return mask


@pytest.mark.parametrize("name,s,m,n,param", EXHAUSTIVE_CFGS)
def test_registry_entries_registered_and_applicable(name, s, m, n, param):
    ent = REGISTRY[name]
    assert ent.applicable(s, m, n, param), (name, s, m, n, param)
    plan = make_strategy(name, s, m, n, dtype=C128, param=param)
    assert plan.recovery_threshold >= 1


@pytest.mark.parametrize("name,s,m,n,param", EXHAUSTIVE_CFGS)
def test_exhaustive_worker_subsets_decodable_iff_threshold(name, s, m, n,
                                                           param):
    """Every one of the 2^N responder subsets: decodable() iff the
    strategy's claimed worker-count condition holds."""
    plan = make_strategy(name, s, m, n, dtype=C128, param=param)
    for size in range(n + 1):
        for sub in itertools.combinations(range(n), size):
            mask = _subset_mask(n, sub)
            if name == "repetition":
                # replication is NOT count-decodable: the claim is only
                # that every subset >= threshold works and SOME smaller
                # subset fails (worst case) -- asserted per-subset here
                want = all(
                    any(plan.block_of_worker(w) == (i, j)
                        for w in sub)
                    for i in range(plan.m) for j in range(plan.m))
            else:
                want = size >= plan.recovery_threshold
            assert plan.decodable(mask) == want, (name, sub)


@pytest.mark.parametrize("name,s,m,n,param", EXHAUSTIVE_CFGS)
def test_boundary_subsets_actually_decode(name, s, m, n, param):
    """Claimed-threshold subsets don't just SAY decodable -- they decode
    to numpy's transform (every exactly-threshold subset)."""
    plan = make_strategy(name, s, m, n, dtype=C128, param=param)
    x = _rand(s, seed=7)
    want = np.fft.fft(np.asarray(x))
    b = plan.worker_compute(plan.encode(x))
    k = int(plan.recovery_threshold)
    for sub in itertools.combinations(range(n), k):
        mask = _subset_mask(n, sub)
        if not plan.decodable(mask):
            continue    # repetition: only block-covering subsets decode
        got = plan.decode(b, mask=jnp.asarray(mask))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-6,
                                   err_msg=f"{name} {sub}")


def test_partial_exhaustive_fragment_patterns():
    """Every sequential fragment pattern at small (N, r): decodable iff
    total finished fragments >= m*r, and decode is exact at the boundary."""
    s, m, n, r = 16, 2, 3, 2
    plan = CodedPartialFFT(s=s, m=m, n_workers=n, r=r, dtype=C128)
    need = plan.fragments_needed
    x = _rand(s, seed=8)
    want = np.fft.fft(np.asarray(x))
    b = plan.worker_compute(plan.encode(x))
    bn = np.asarray(b)
    for prefixes in itertools.product(range(r + 1), repeat=n):
        fmask = np.zeros((n, r), bool)
        for w, p in enumerate(prefixes):
            fmask[w, :p] = True
        want_dec = sum(prefixes) >= need
        assert plan.decodable(fragment_mask=fmask) == want_dec, prefixes
        if want_dec:
            # poison the unfinished fragments: decode must not read them
            poisoned = bn.copy()
            poisoned[~fmask] = np.nan
            got = plan.decode(jnp.asarray(poisoned),
                              fragment_mask=jnp.asarray(fmask))
            np.testing.assert_allclose(np.asarray(got), want, atol=1e-6,
                                       err_msg=str(prefixes))


def test_comm_efficient_payload_is_folded():
    """The comm-efficient worker ships 1/q of the MDS shard -- the wire
    saving the m*q threshold buys (Jeong et al. 1805.09891)."""
    s, m, n, q = 32, 2, 6, 2
    plan = CodedCommEffFFT(s=s, m=m, n_workers=n, q=q, dtype=C128)
    assert plan.worker_shard_shape == (s // m // q,)
    assert plan.stored_shard_shape == (s // m,)
    assert plan.payload_scale == 1.0 / q
    assert plan.recovery_threshold == m * q
    x = _rand(s, seed=9)
    b = plan.worker_compute(plan.encode(x))
    assert b.shape == (n, s // m // q)
    # below-threshold masks refuse
    assert not plan.decodable(np.arange(n) < m * q - 1)
    with pytest.raises(ValueError):
        plan.decode(b, subset=jnp.arange(m * q - 1))
