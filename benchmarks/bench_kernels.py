"""Kernel hot path vs jnp oracle: parity + timing -> BENCH_kernels.json.

Three kernel-vs-oracle comparisons (DESIGN.md §6), each timed on the
DEFAULT dispatch path (compiled Pallas on TPU; the same kernel bodies as
straight XLA off-TPU) with strict parity asserts against the jnp oracle:

* **fourstep** -- the worker DFT: fused single-kernel vs two-pass
  (stage1/stage2) vs ``jnp.fft``;
* **encode_worker** -- fused encode+worker (MDS encode folded into the
  four-step stage-1 matmul; message shards transformed, an N/m flop
  saving) vs the separate encode-then-transform path vs the PR-1 oracle
  (``encode_dft`` + ``jnp.fft``), swept over s in {1k, 16k, 256k} x
  m in {4, 16, 64};
* **decode** -- per-mask scatter decode matrices applied as one batched
  MXU matmul (the service path) vs the dense per-request Vandermonde
  solve, same sweep;
* **cold_decode** -- NOVEL-mask decode-matrix production (DESIGN.md §8):
  the device-resident Lagrange build (cold == warm by construction) vs
  the host-LRU fallback cold (one inversion per miss) and warm;
* **streaming** -- the autotuned four-step dispatch (DESIGN.md §10):
  the tuner-routed default path vs the fixed fused / two-pass variants
  vs ``jnp.fft`` over L in {4k, 16k, 64k, 256k}, plus the bf16-plane
  fused variant.  TWO asserted acceptance claims: the tuned path sits
  within 1.5x of the jnp oracle at L=4096, and it never loses to its
  own two-pass fallback at any benched L (the pre-autotune default DID
  at L=4096 -- fused 0.42ms vs two-pass 0.32ms -- which is exactly the
  regression the tuner exists to catch);
* **rfft** -- the real-input (r2c) bucket vs the c2c bucket fed the same
  real signal as complex, at s in {16k, 256k}: half the worker-shard
  payload bytes and lower wall-clock (DESIGN.md §7);
* **rfftn** -- the n-D real plan (CodedRFFTN) vs the n-D c2c plan fed the
  same real field as complex (DESIGN.md §9): same per-axis code, half the
  worker payload;

plus the acceptance measurement: **batched service throughput** at the
``BENCH_service.json`` config (s=2048, m=4, N=8, 64 requests/bucket),
default (kernel) hot path vs the PR-1 jnp-oracle path
(``use_reference=True``).  Timings alternate A/B per repetition and report
medians -- this container's CPU throttles in bursts, so interleaving is
the only honest protocol.  Wall-clock here is CPU; the analytic v5e
roofline for each kernel shape is included for the TPU story.
"""

from __future__ import annotations

import json
import os
import pathlib
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mds
from repro.kernels import autotune, ops, ref
from repro.serving import FFTService, FFTServiceConfig
from repro.serving.decode_cache import DecodeMatrixCache

# BENCH_SMOKE=1 (the CI bench-smoke job): tiny shapes, few reps, NO JSON
# artifact -- a fast structural check that every perf path still runs and
# its parity asserts hold, so hot-path regressions fail PRs quickly
SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"


def _roofline(flops: float, bytes_: float) -> str:
    ct = flops / 197e12
    mt = bytes_ / 819e9
    dom = "compute" if ct > mt else "memory"
    return (f"flops {flops:.2e}, bytes {bytes_:.2e}, AI "
            f"{flops / bytes_:6.1f} F/B -> {dom}-bound "
            f"(c {ct * 1e6:.1f}us vs m {mt * 1e6:.1f}us)")


def _randc(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        (rng.normal(size=shape) + 1j * rng.normal(size=shape))
        .astype(np.complex64))


def _relerr(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return float(np.abs(got - want).max() / max(np.abs(want).max(), 1e-12))


def _time_interleaved(variants: dict, reps: int = 8) -> dict:
    """Median seconds per call for each jitted variant, A/B-interleaved."""
    for fn, args in variants.values():
        jax.block_until_ready(fn(*args))
    times = {k: [] for k in variants}
    names = list(variants)
    for r in range(reps):
        order = names if r % 2 == 0 else names[::-1]
        for k in order:
            fn, args = variants[k]
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            times[k].append(time.perf_counter() - t0)
    return {k: statistics.median(v) for k, v in times.items()}


# ---------------------------------------------------------------- sections
def bench_fourstep(lines: list) -> list[dict]:
    rows = []
    for ell in ((4096,) if SMOKE else (4096, 16384, 65536)):
        batch = 4
        x = _randc((batch, ell), seed=ell)
        xr, xi = ref.planar(x)
        fused = jax.jit(lambda r, i: ops.fourstep_planar(r, i, fused=True))
        twop = jax.jit(lambda r, i: ops.fourstep_planar(r, i, fused=False))
        oracle = jax.jit(lambda z: jnp.fft.fft(z, axis=-1))
        want = np.fft.fft(np.asarray(x, np.complex128), axis=-1)
        err = _relerr(ref.unplanar(*fused(xr, xi)), want)
        assert err < 1e-3, err
        t = _time_interleaved({
            "fused": (fused, (xr, xi)),
            "two_pass": (twop, (xr, xi)),
            "jnp_oracle": (oracle, (x,)),
        })
        a, b = ops.split_factor(ell)
        flops = batch * 3 * 2 * ell * (a + b)
        bytes_ = batch * ell * 4 * 2 * 3
        rows.append({"L": ell, "batch": batch, "rel_err": err,
                     "fused_ms": t["fused"] * 1e3,
                     "two_pass_ms": t["two_pass"] * 1e3,
                     "jnp_oracle_ms": t["jnp_oracle"] * 1e3})
        lines.append(
            f"  fourstep L={ell} ({a}x{b}) rel err {err:.2e}; fused "
            f"{t['fused']*1e3:.2f}ms two-pass {t['two_pass']*1e3:.2f}ms "
            f"jnp {t['jnp_oracle']*1e3:.2f}ms; "
            + _roofline(float(flops), float(bytes_)))
    return rows


def bench_streaming(lines: list) -> list[dict]:
    """The autotuned four-step story (DESIGN.md §10).

    For each L the tuner measures fused / two-pass / platform-FFT (and,
    off the two-factor grid, multistep plans) once and records the winner;
    the ``tuned`` column is then the DEFAULT dispatch
    (``fourstep_planar(variant=None)``) reading that table.  Two timing
    asserts -- the ONLY timing asserts in this bench, both acceptance
    criteria with wide margins over the observed gap:

    * tuned <= 1.5x the jnp oracle at L=4096 (on CPU the tuner learns the
      platform FFT wins and routes to it, closing the 2.6x fused gap);
    * tuned <= 1.25x two-pass at EVERY benched L (the fused-by-default
      heuristic lost to its own fallback at L=4096; the table cannot, it
      measured both).

    The bf16 column times the fused variant with bfloat16 DFT/twiddle
    planes (f32 accumulation) and reports its error against the f64
    oracle -- the per-shape budget the service probe gates on.
    """
    mode = ops._mode(None)
    rows = []
    for ell in ((4096,) if SMOKE else (4096, 16384, 65536, 262144)):
        batch = 4
        x = _randc((batch, ell), seed=ell)
        xr, xi = ref.planar(x)
        ent = autotune.ensure_fourstep(ell, batch=batch, mode=mode,
                                       reps=2 if SMOKE else 5)
        tuned = jax.jit(lambda r, i: ops.fourstep_planar(r, i))
        fused = jax.jit(
            lambda r, i: ops.fourstep_planar(r, i, variant="fused"))
        twop = jax.jit(
            lambda r, i: ops.fourstep_planar(r, i, variant="two_pass"))
        bf16 = jax.jit(lambda r, i: ops.fourstep_planar(
            r, i, variant="fused", precision="bf16"))
        oracle = jax.jit(lambda z: jnp.fft.fft(z, axis=-1))
        want = np.fft.fft(np.asarray(x, np.complex128), axis=-1)
        err_t = _relerr(ref.unplanar(*tuned(xr, xi)), want)
        err_f = _relerr(ref.unplanar(*fused(xr, xi)), want)
        err_b = _relerr(ref.unplanar(*bf16(xr, xi)), want)
        assert err_t < 1e-3 and err_f < 1e-3, (ell, err_t, err_f)
        assert err_b < ops.BF16_RTOL, (ell, err_b)
        t = _time_interleaved({
            "tuned": (tuned, (xr, xi)),
            "fused": (fused, (xr, xi)),
            "two_pass": (twop, (xr, xi)),
            "bf16_fused": (bf16, (xr, xi)),
            "jnp_oracle": (oracle, (x,)),
        }, reps=4 if SMOKE else 8)
        assert t["tuned"] <= t["two_pass"] * 1.25, (
            f"L={ell}: tuned dispatch {t['tuned']*1e3:.2f}ms lost to its "
            f"own two-pass fallback {t['two_pass']*1e3:.2f}ms -- the "
            f"autotune table routed to a slower variant")
        # SMOKE runs 4 reps -- too few for a ratio this tight (the tuned
        # path is the platform FFT plus the planar<->complex casts, so
        # the margin over 1.5x is real but small); the acceptance claim
        # is about the full-rep artifact, where the median holds it.
        if ell == 4096 and not SMOKE:
            assert t["tuned"] <= t["jnp_oracle"] * 1.5, (
                f"tuned four-step {t['tuned']*1e3:.2f}ms not within 1.5x "
                f"of jnp oracle {t['jnp_oracle']*1e3:.2f}ms at L=4096")
        rows.append({
            "L": ell, "batch": batch, "mode": mode,
            "tuned_entry": ent,
            "rel_err_tuned": err_t, "rel_err_bf16": err_b,
            "tuned_ms": t["tuned"] * 1e3,
            "fused_ms": t["fused"] * 1e3,
            "two_pass_ms": t["two_pass"] * 1e3,
            "bf16_fused_ms": t["bf16_fused"] * 1e3,
            "jnp_oracle_ms": t["jnp_oracle"] * 1e3,
            "tuned_vs_oracle": t["tuned"] / t["jnp_oracle"],
            "fused_regressed_vs_two_pass": t["fused"] > t["two_pass"],
        })
        lines.append(
            f"  streaming L={ell}: tuned[{ent.get('variant')}] "
            f"{t['tuned']*1e3:.2f}ms fused {t['fused']*1e3:.2f}ms "
            f"two-pass {t['two_pass']*1e3:.2f}ms bf16 "
            f"{t['bf16_fused']*1e3:.2f}ms jnp {t['jnp_oracle']*1e3:.2f}ms "
            f"(tuned/oracle {t['tuned']/t['jnp_oracle']:.2f}x, bf16 err "
            f"{err_b:.1e})")
    return rows


def bench_encode_worker(lines: list) -> list[dict]:
    rows = []
    for s in ((1024,) if SMOKE else (1024, 16384, 262144)):
        for m in ((4,) if SMOKE else (4, 16, 64)):
            n = 2 * m
            ell = s // m
            q = 2 if s >= 262144 else 4
            c = _randc((q, m, ell), seed=s + m)
            g = mds.rs_generator(n, m, jnp.complex64)
            cr, ci = ref.planar(c)
            gr, gi = ref.planar(g)
            fused = jax.jit(
                lambda r, i: ops.encode_worker(r, i, gr, gi, fused=True))
            sep = jax.jit(
                lambda r, i: ops.encode_worker(r, i, gr, gi, fused=False))
            oracle = jax.jit(lambda z: jnp.fft.fft(
                jax.vmap(lambda u: mds.encode_dft(u, n))(z), axis=-1))
            wr, wi = ref.encode_worker_ref(cr, ci, g)
            err = _relerr(ref.unplanar(*fused(cr, ci)),
                          np.asarray(ref.unplanar(wr, wi)))
            assert err < 1e-3, (s, m, err)
            t = _time_interleaved({
                "fused": (fused, (cr, ci)),
                "separate": (sep, (cr, ci)),
                "oracle": (oracle, (c,)),
            }, reps=6 if s >= 262144 else 8)
            rows.append({"s": s, "m": m, "n": n, "L": ell, "batch": q,
                         "rel_err": err,
                         "fused_ms": t["fused"] * 1e3,
                         "separate_ms": t["separate"] * 1e3,
                         "oracle_ms": t["oracle"] * 1e3})
            lines.append(
                f"  encode+worker s={s} m={m} N={n}: fused "
                f"{t['fused']*1e3:.2f}ms separate {t['separate']*1e3:.2f}ms "
                f"oracle {t['oracle']*1e3:.2f}ms (rel err {err:.1e})")
    return rows


def bench_decode(lines: list) -> list[dict]:
    rows = []
    for s in ((1024,) if SMOKE else (1024, 16384, 262144)):
        for m in ((4,) if SMOKE else (4, 16, 64)):
            n = 2 * m
            ell = s // m
            q = 2 if s >= 262144 else 8
            b = _randc((q, n, ell), seed=s * m)
            g = mds.rs_generator(n, m, jnp.complex64)
            # per-request masks with uniformly-spread responders (rotated
            # every-other pattern): well-conditioned subsets at any m --
            # arbitrary half-subsets of the circle are intrinsically
            # ill-conditioned past m~16 (DESIGN.md §4), where BOTH decode
            # implementations degrade and a parity check is meaningless
            masks = np.stack([
                np.roll(np.arange(n) % 2 == 0, i) for i in range(q)])
            cache = DecodeMatrixCache(np.asarray(g))
            dmats = cache.matrices(masks)
            dr = jnp.asarray(dmats.real.astype(np.float32))
            di = jnp.asarray(dmats.imag.astype(np.float32))
            br, bi = ref.planar(b)
            subsets = jnp.asarray(np.stack(
                [DecodeMatrixCache.subset_of(row, m) for row in masks]))
            matmul = jax.jit(lambda r, i: ops.decode_apply(dr, di, r, i))
            solve = jax.jit(lambda z: jax.vmap(
                lambda bq, sq: mds.decode_from_subset(g, bq, sq))(z, subsets))
            got = ref.unplanar(*matmul(br, bi))
            want = solve(b)
            err = _relerr(got, np.asarray(want))
            assert err < 1e-3, (s, m, err)
            t = _time_interleaved({
                "matmul": (matmul, (br, bi)),
                "solve": (solve, (b,)),
            }, reps=6 if s >= 262144 else 8)
            rows.append({"s": s, "m": m, "n": n, "batch": q, "rel_err": err,
                         "matmul_ms": t["matmul"] * 1e3,
                         "solve_ms": t["solve"] * 1e3})
            lines.append(
                f"  decode s={s} m={m} N={n}: matmul {t['matmul']*1e3:.2f}ms "
                f"solve {t['solve']*1e3:.2f}ms (rel err {err:.1e})")
    return rows


def bench_rfft(lines: list) -> list[dict]:
    """The r2c acceptance measurement (DESIGN.md §7): real-input coded FFT
    vs the c2c pipeline fed the same real signal as complex, at
    s in {16k, 256k}.  Two wins claimed and asserted: HALF the worker-shard
    payload bytes on the wire, and lower wall-clock (half-length worker
    transforms) on the same bucket executor."""
    rows = []
    for s in ((16384,) if SMOKE else (16384, 262144)):
        m, n = 4, 8
        q = 2 if s >= 262144 else 4
        ell = s // m
        rng = np.random.default_rng(s)
        xb = rng.normal(size=(q, s)).astype(np.float32)
        g = mds.rs_generator(n, m, jnp.complex64)
        gr, gi = ref.planar(g)
        masks = np.stack([
            np.roll(np.arange(n) % 2 == 0, i) for i in range(q)])
        cache = DecodeMatrixCache(np.asarray(g))
        invs, subsets = cache.compact(masks)
        dvr = jnp.asarray(invs.real.astype(np.float32))
        dvi = jnp.asarray(invs.imag.astype(np.float32))
        subs = jnp.asarray(subsets)
        xr = jnp.asarray(xb)
        xi = jnp.zeros_like(xr)

        r2c = jax.jit(lambda a: ops.coded_rbucket_direct(
            a, dvr, dvi, subs, gr, gi, s))
        c2c = jax.jit(lambda a, b: ops.coded_bucket_direct(
            a, b, dvr, dvi, subs, gr, gi, s))

        want_half = np.fft.rfft(xb.astype(np.float64), axis=-1)
        err_r = _relerr(ref.unplanar(*r2c(xr)), want_half)
        assert err_r < 1e-3, err_r
        want_full = np.fft.fft(xb.astype(np.complex128), axis=-1)
        err_c = _relerr(ref.unplanar(*c2c(xr, xi)), want_full)
        assert err_c < 1e-3, err_c

        t = _time_interleaved({
            "r2c": (r2c, (xr,)),
            "c2c_on_real": (c2c, (xr, xi)),
        }, reps=6 if s >= 262144 else 8)
        # worker-shard payload: what ONE worker ships back to the master.
        # The payload claim is structural and asserted; the wall-clock
        # ratio is REPORTED (json + line) but never asserted -- a timing
        # comparison on a noisy shared CI runner would flake, and no other
        # bench assert is a timing check.
        r2c_bytes = (ell // 2) * 8          # L/2 complex64
        c2c_bytes = ell * 8                 # L complex64
        assert r2c_bytes * 2 == c2c_bytes
        rows.append({
            "s": s, "m": m, "n": n, "batch": q,
            "rel_err_r2c": err_r,
            "r2c_ms": t["r2c"] * 1e3,
            "c2c_on_real_ms": t["c2c_on_real"] * 1e3,
            "speedup": t["c2c_on_real"] / t["r2c"],
            "worker_payload_bytes_r2c": r2c_bytes,
            "worker_payload_bytes_c2c": c2c_bytes,
        })
        lines.append(
            f"  rfft s={s} m={m} N={n}: r2c {t['r2c']*1e3:.2f}ms vs "
            f"c2c-on-real {t['c2c_on_real']*1e3:.2f}ms "
            f"({t['c2c_on_real']/t['r2c']:.2f}x), payload "
            f"{r2c_bytes//1024}KiB vs {c2c_bytes//1024}KiB/worker shard "
            f"(rel err {err_r:.1e})")
    return rows


def bench_rfftn_nd(lines: list) -> list[dict]:
    """The n-D real acceptance measurement (DESIGN.md §9): CodedRFFTN vs
    the n-D c2c plan (CodedFFTND) fed the same real field as complex.
    Same (shape, m, N) code, same per-request masks, both through the
    jitted generic executor.  The structural claim -- HALF the worker
    payload elements -- is asserted; wall-clock is reported (same
    no-timing-assert protocol as the 1-D rfft section)."""
    from repro.core import CodedFFTND, CodedRFFTN
    from repro.core.coded_fft import plan_factors

    rows = []
    for shape in (((64, 64),) if SMOKE else ((128, 128), (256, 256))):
        m, n = 4, 8
        factors = plan_factors(shape, m)
        q = 4
        rplan = CodedRFFTN(shape=shape, factors=factors, n_workers=n)
        cplan = CodedFFTND(shape=shape, factors=factors, n_workers=n)
        rng = np.random.default_rng(shape[0])
        tb = jnp.asarray(rng.normal(size=(q,) + shape).astype(np.float32))
        masks = jnp.asarray(np.stack(
            [np.roll(np.arange(n) % 2 == 0, i) for i in range(q)]))
        r2c = jax.jit(lambda a: rplan.run(a, mask=masks))
        c2c = jax.jit(lambda a: cplan.run(a.astype(jnp.complex64),
                                          mask=masks))
        axes = tuple(range(-len(shape), 0))
        want_half = np.fft.rfftn(np.asarray(tb, np.float64), axes=axes)
        err_r = _relerr(r2c(tb), want_half)
        assert err_r < 1e-3, err_r
        err_c = _relerr(c2c(tb), np.fft.fftn(np.asarray(tb, np.complex128),
                                             axes=axes))
        assert err_c < 1e-3, err_c
        t = _time_interleaved({
            "rfftn": (r2c, (tb,)),
            "c2cn_on_real": (c2c, (tb,)),
        }, reps=6)
        r_elems = int(np.prod(rplan.worker_shard_shape))
        c_elems = int(np.prod(cplan.worker_shard_shape))
        assert 2 * r_elems == c_elems       # the communication claim
        rows.append({
            "shape": list(shape), "m": m, "n": n, "batch": q,
            "rel_err_rfftn": err_r,
            "rfftn_ms": t["rfftn"] * 1e3,
            "c2cn_on_real_ms": t["c2cn_on_real"] * 1e3,
            "speedup": t["c2cn_on_real"] / t["rfftn"],
            "worker_payload_bytes_rfftn": r_elems * 8,
            "worker_payload_bytes_c2cn": c_elems * 8,
        })
        lines.append(
            f"  rfftn shape={shape} m={m} N={n}: rfftn "
            f"{t['rfftn']*1e3:.2f}ms vs c2cn-on-real "
            f"{t['c2cn_on_real']*1e3:.2f}ms "
            f"({t['c2cn_on_real']/t['rfftn']:.2f}x), payload "
            f"{r_elems * 8 // 1024}KiB vs {c_elems * 8 // 1024}KiB/worker "
            f"shard (rel err {err_r:.1e})")
    return rows


def bench_service(lines: list) -> dict:
    """The acceptance measurement: default kernel path vs PR-1 oracle path
    on batched service throughput at the BENCH_service.json config."""
    s, m, n, n_req = 2048, 4, 8, (16 if SMOKE else 64)
    cfg = dict(s=s, m=m, n_workers=n, seed=0, max_batch=n_req)
    kernel = FFTService(FFTServiceConfig(**cfg))
    oracle = FFTService(FFTServiceConfig(**cfg, use_reference=True))
    rng = np.random.default_rng(3)
    xs = [(rng.normal(size=s) + 1j * rng.normal(size=s)).astype(np.complex64)
          for _ in range(n_req)]

    worst = max(
        float(np.max(np.abs(y - np.fft.fft(x))))
        for x, y in zip(xs, kernel.submit_batch(xs)))
    assert worst < 1e-2, worst
    # warm compiles (the kernel path needs no mask warm-up any more: decode
    # matrices are built in-jit, a novel mask costs what a repeat does)
    for _ in range(2 if SMOKE else 8):
        kernel.submit_batch(xs)
    oracle.submit_batch(xs)

    tk, to = [], []
    for r in range(6 if SMOKE else 30):
        pair = ((kernel, tk), (oracle, to))
        for svc, acc in (pair if r % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            svc.submit_batch(xs)
            acc.append(time.perf_counter() - t0)
    k_med, o_med = statistics.median(tk), statistics.median(to)
    result = {
        "s": s, "m": m, "n_workers": n, "n_requests": n_req,
        "kernel_ms_med": k_med * 1e3,
        "oracle_ms_med": o_med * 1e3,
        "kernel_rps": n_req / k_med,
        "oracle_rps": n_req / o_med,
        "speedup": o_med / k_med,
        "pairwise_win_rate": sum(a < b for a, b in zip(tk, to)) / len(tk),
        "decode_cache": {
            "hits": kernel.stats.decode_cache_hits,
            "misses": kernel.stats.decode_cache_misses,
        },
        "worst_abs_err": worst,
    }
    lines.append(
        f"  service s={s} m={m} N={n} x{n_req} reqs: kernel "
        f"{result['kernel_rps']:.0f} rps vs oracle {result['oracle_rps']:.0f} "
        f"rps -> {result['speedup']:.2f}x (win rate "
        f"{result['pairwise_win_rate']:.0%}, worst err {worst:.1e})")
    return result


def bench_cold_decode(lines: list) -> dict:
    """Novel-mask decode-matrix cost (the DESIGN.md §8 claim).

    Streams buckets of NEVER-REPEATED straggler masks through the three
    decode-matrix producers: the device-resident Lagrange build (one jitted
    call, masks in -> scatter planes out), the host LRU COLD (every mask a
    miss -> one complex128 inversion each), and the host LRU WARM (same
    masks every call -> pure hits, the pre-§8 steady-state best case).
    The claim: Lagrange pays no novel-mask penalty at all -- cold IS warm
    -- and sits within noise of the warm-LRU path end to end.  N=32 gives
    a mask space big enough that the cold stream never repeats.
    """
    m, n, q = 4, 32, 64
    reps = 4 if SMOKE else 16
    g = mds.rs_generator(n, m, jnp.complex64)
    rng = np.random.default_rng(0)

    def draw(count, rows=q, workers=n):
        out = rng.random((count, rows, workers)) < 0.6
        for b in range(count):
            for r in range(rows):
                while out[b, r].sum() < m:
                    out[b, r, rng.integers(workers)] = True
        return out

    novel = draw(2 * reps)          # distinct masks for every cold call
    fixed = draw(1)[0]              # one bucket reused for the warm path

    dev = jax.jit(lambda mk: ops.lagrange_scatter_planes(
        ops.mask_subsets(mk, m), n))
    # parity first: device planes == host matrices on the warm bucket
    cache = DecodeMatrixCache(np.asarray(g), maxsize=8192)
    want = cache.matrices(fixed)
    dr, di = dev(jnp.asarray(fixed))
    err = _relerr(np.asarray(dr) + 1j * np.asarray(di), want)
    assert err < 1e-3, err

    def host_call(masks):
        dmats = cache.matrices(masks)
        planes = np.stack([dmats.real, dmats.imag]).astype(np.float32)
        return jnp.asarray(planes)

    def dev_call(masks):
        return dev(jnp.asarray(masks))

    jax.block_until_ready(dev_call(fixed))
    host_call(fixed)
    t_dev_cold, t_dev_warm, t_host_cold, t_host_warm = [], [], [], []
    for r in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(dev_call(novel[2 * r]))
        t_dev_cold.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(host_call(novel[2 * r + 1]))   # all misses
        t_host_cold.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(dev_call(fixed))
        t_dev_warm.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(host_call(fixed))              # all hits
        t_host_warm.append(time.perf_counter() - t0)
    med = lambda ts: statistics.median(ts)
    result = {
        "m": m, "n": n, "bucket": q, "rel_err": err,
        "lagrange_novel_ms": med(t_dev_cold) * 1e3,
        "lagrange_warm_ms": med(t_dev_warm) * 1e3,
        "host_lru_cold_ms": med(t_host_cold) * 1e3,
        "host_lru_warm_ms": med(t_host_warm) * 1e3,
        "cold_penalty_lagrange": med(t_dev_cold) / med(t_dev_warm),
        "cold_penalty_host_lru": med(t_host_cold) / med(t_host_warm),
    }
    lines.append(
        f"  cold-mask decode m={m} N={n} x{q}: lagrange novel "
        f"{result['lagrange_novel_ms']:.3f}ms (warm "
        f"{result['lagrange_warm_ms']:.3f}ms) vs host LRU cold "
        f"{result['host_lru_cold_ms']:.3f}ms / warm "
        f"{result['host_lru_warm_ms']:.3f}ms -> novel-mask penalty "
        f"{result['cold_penalty_lagrange']:.2f}x vs "
        f"{result['cold_penalty_host_lru']:.2f}x")

    # -- end to end at the service config: novel-mask DEVICE bucket vs the
    # warm-LRU bucket (matrices all cache hits, the pre-§8 best case).
    # The Lagrange build fuses into the bucket executor, so its marginal
    # cost disappears into the bucket's own compute: novel masks no longer
    # pay a host inversion anywhere.
    s, n8, q8 = 2048, 8, (16 if SMOKE else 64)
    g8 = mds.rs_generator(n8, m, jnp.complex64)
    g8r, g8i = ref.planar(g8)
    xr, xi = ref.planar(_randc((q8, s), seed=1))

    @jax.jit
    def dev_bucket(xr_, xi_, mk):
        sub = ops.mask_subsets(mk, m)
        ivr, ivi = ops.lagrange_compact_planes(sub, n8)
        return ops.coded_bucket_direct(xr_, xi_, ivr, ivi, sub, g8r, g8i, s)

    @jax.jit
    def warm_bucket(xr_, xi_, dvr, dvi, sub):
        return ops.coded_bucket_direct(xr_, xi_, dvr, dvi, sub, g8r, g8i, s)

    cache8 = DecodeMatrixCache(np.asarray(g8), maxsize=512)
    fixed8 = draw(1, q8, n8)[0]
    novel8 = draw(reps, q8, n8)     # one fresh bucket per timed rep
    cache8.compact(fixed8)          # prime: the warm path is all hits

    def warm_call():
        invs, subs = cache8.compact(fixed8)
        planes = np.stack([invs.real, invs.imag]).astype(np.float32)
        return warm_bucket(xr, xi, jnp.asarray(planes[0]),
                           jnp.asarray(planes[1]), jnp.asarray(subs))

    jax.block_until_ready(dev_bucket(xr, xi, jnp.asarray(novel8[0])))
    jax.block_until_ready(warm_call())
    t_dev_e2e, t_warm_e2e = [], []
    for r in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(dev_bucket(xr, xi, jnp.asarray(novel8[r])))
        t_dev_e2e.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(warm_call())
        t_warm_e2e.append(time.perf_counter() - t0)
    result["bucket_e2e"] = {
        "s": s, "m": m, "n": n8, "bucket": q8,
        "lagrange_novel_ms": med(t_dev_e2e) * 1e3,
        "host_lru_warm_ms": med(t_warm_e2e) * 1e3,
        "novel_vs_warm": med(t_dev_e2e) / med(t_warm_e2e),
    }
    lines.append(
        f"  cold-mask bucket e2e s={s} m={m} N={n8} x{q8}: lagrange novel "
        f"{result['bucket_e2e']['lagrange_novel_ms']:.2f}ms vs warm-LRU "
        f"{result['bucket_e2e']['host_lru_warm_ms']:.2f}ms -> "
        f"{result['bucket_e2e']['novel_vs_warm']:.2f}x")
    return result


def bench_wkv(lines: list) -> None:
    """WKV recurrence kernel parity (unchanged from the seed bench)."""
    from repro.kernels.wkv import wkv_pallas
    from repro.models.rwkv6 import wkv_scan_reference

    b, h, t, kd = 1, 2, 64, 32
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    mk = lambda i, sh: jax.random.normal(ks[i], sh, jnp.float32)
    r, kk, vv = (mk(i, (b, t, h, kd)) for i in range(3))
    lw = jnp.maximum(-jnp.abs(mk(3, (b, t, h, kd))), -8.0)
    u = mk(4, (h, kd))
    s0 = mk(5, (b, h, kd, kd))
    fl = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, kd)
    o, _ = wkv_pallas(fl(r), fl(kk), fl(vv), fl(lw), jnp.tile(u, (b, 1)),
                      s0.reshape(b * h, kd, kd), interpret=True)
    o_ref, _ = wkv_scan_reference(r, kk, vv, lw, u, s0)
    err = float(jnp.max(jnp.abs(o - fl(o_ref))))
    assert err < 5e-3
    lines.append(f"  wkv (BH={b * h}, T={t}, K={kd}) abs err {err:.2e}")


def run() -> list[str]:
    lines = ["bench_kernels: Pallas hot path vs jnp oracle -> BENCH_kernels.json"]
    result = {
        "backend": jax.default_backend(),
        "fourstep": bench_fourstep(lines),
        "streaming": bench_streaming(lines),
        "encode_worker": bench_encode_worker(lines),
        "decode": bench_decode(lines),
        "cold_decode": bench_cold_decode(lines),
        "rfft": bench_rfft(lines),
        "rfftn": bench_rfftn_nd(lines),
        "service_throughput": bench_service(lines),
    }
    bench_wkv(lines)
    if SMOKE:
        lines.append("  [BENCH_SMOKE=1: tiny shapes, artifact not written]")
        return lines
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_kernels.json"
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    lines.append(f"  [written to {out_path}]")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
