"""Pallas kernels vs jnp oracles + v5e roofline estimates.

The kernels run in interpret mode on CPU (this container has no TPU), so
wall-clock here is NOT kernel performance -- correctness is checked
against the pure-jnp oracle and we report the ANALYTIC roofline for the
kernel shapes on v5e (197 TFLOP/s bf16-ish MXU, 819 GB/s HBM): the
four-step worker FFT is intentionally matmul-rich so its arithmetic
intensity lands in the compute-bound regime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.recombine import recombine as recombine_oracle
from repro.kernels import ops


def _roofline(flops: float, bytes_: float) -> str:
    ct = flops / 197e12
    mt = bytes_ / 819e9
    dom = "compute" if ct > mt else "memory"
    return (f"flops {flops:.2e}, bytes {bytes_:.2e}, AI "
            f"{flops / bytes_:6.1f} F/B -> {dom}-bound "
            f"(c {ct * 1e6:.1f}us vs m {mt * 1e6:.1f}us)")


def run() -> list[str]:
    lines = ["bench_kernels: Pallas (interpret) vs jnp oracle + v5e roofline"]
    key = jax.random.PRNGKey(0)

    # four-step worker FFT: L = A x B two-matmul formulation
    for L in (4096, 16384):
        x = (jax.random.normal(key, (8, L)) + 1j * jax.random.normal(key, (8, L))
             ).astype(jnp.complex64)
        got = ops.fft_fourstep(x)
        want = jnp.fft.fft(x, axis=-1)
        err = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
        a, b = ops.split_factor(L)
        # planar complex: 2 matmuls x (3 real matmuls, karatsuba) per row batch
        flops = 8 * 3 * 2 * L * (a + b)
        bytes_ = 8 * L * 4 * 2 * 3  # read+write f32 planes through 3 stages
        lines.append(f"  fourstep L={L} ({a}x{b}) rel err {err:.2e}; "
                     + _roofline(flops * 1.0, bytes_ * 1.0))
        assert err < 1e-3

    # MDS encode/decode apply as complex matmul kernel
    g = jnp.asarray(jax.random.normal(key, (8, 4)) + 1j, jnp.complex64)
    c = (jax.random.normal(key, (4, 2048)) + 0j).astype(jnp.complex64)
    got = ops.mds_apply(g, c)
    want = jnp.einsum("nm,ml->nl", g, c)
    err = float(jnp.max(jnp.abs(got - want)))
    lines.append(f"  cmatmul (8,4)x(4,2048) abs err {err:.2e}; "
                 + _roofline(3 * 2 * 8 * 4 * 2048, (8 * 4 + 4 * 2048 + 8 * 2048) * 8))
    assert err < 1e-3

    # fused recombine (twiddle + length-m DFT)
    m, ell = 4, 2048
    ch = (jax.random.normal(key, (m, ell)) + 1j * jax.random.normal(key, (m, ell))
          ).astype(jnp.complex64)
    got = ops.recombine_fused(ch, m * ell)
    want = recombine_oracle(ch, m * ell)
    err = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    lines.append(f"  recombine m={m} s={m * ell} rel err {err:.2e}; "
                 + _roofline(3 * 2 * m * m * ell + 6 * m * ell,
                             (2 * m * ell + m * ell) * 8))
    assert err < 1e-3

    # WKV recurrence kernel (RWKV-6): state resident in VMEM
    from repro.kernels.wkv import wkv_pallas
    from repro.models.rwkv6 import wkv_scan_reference

    b, h, t, kd = 1, 2, 64, 32
    ks = jax.random.split(key, 6)
    mk = lambda i, sh: jax.random.normal(ks[i], sh, jnp.float32)
    r, kk, vv = (mk(i, (b, t, h, kd)) for i in range(3))
    lw = jnp.maximum(-jnp.abs(mk(3, (b, t, h, kd))), -8.0)
    u = mk(4, (h, kd))
    s0 = mk(5, (b, h, kd, kd))
    fl = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, kd)
    o, _ = wkv_pallas(fl(r), fl(kk), fl(vv), fl(lw), jnp.tile(u, (b, 1)),
                      s0.reshape(b * h, kd, kd), interpret=True)
    o_ref, _ = wkv_scan_reference(r, kk, vv, lw, u, s0)
    err = float(jnp.max(jnp.abs(o - fl(o_ref))))
    # per (bh): dots 2*T*K*K x3-ish; bytes: 4 inputs + 1 output streamed once
    flops = b * h * (3 * 2 * t * kd * kd)
    bytes_ = b * h * 5 * t * kd * 4
    lines.append(f"  wkv (BH={b * h}, T={t}, K={kd}) abs err {err:.2e}; "
                 + _roofline(float(flops), float(bytes_)))
    assert err < 5e-3
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
