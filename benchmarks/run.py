"""Benchmark aggregator: one module per paper claim.

``PYTHONPATH=src python -m benchmarks.run`` runs everything and prints a
single report (tee'd to bench_output.txt by the final deliverable step).
Individual modules run standalone too.
"""

from __future__ import annotations

import time
import traceback


def main() -> int:
    from benchmarks import (
        bench_comm_load,
        bench_decode_scaling,
        bench_fault_tolerance,
        bench_kernels,
        bench_latency,
        bench_ndim,
        bench_recovery,
        bench_service,
    )

    modules = [
        ("recovery thresholds (Thm 1/2, Remark 4)", bench_recovery),
        ("straggler latency (shifted-exp model)", bench_latency),
        ("decode linearity in s (§III-C)", bench_decode_scaling),
        ("communication optimality (Remark 5)", bench_comm_load),
        ("n-D + multi-input (Thm 3/5)", bench_ndim),
        ("Byzantine fault tolerance (Remark 3)", bench_fault_tolerance),
        ("Pallas kernels vs oracle + roofline", bench_kernels),
        ("end-to-end FFT service", bench_service),
    ]
    failures = []
    for title, mod in modules:
        print("=" * 72)
        print(f"== {title}")
        print("=" * 72)
        t0 = time.perf_counter()
        try:
            for line in mod.run():
                print(line)
        except Exception:
            failures.append(title)
            traceback.print_exc()
        print(f"-- {time.perf_counter() - t0:.1f}s")
        print()
    if failures:
        print("FAILED:", failures)
        return 1
    print("all benchmarks complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
