"""Recovery threshold: coded FFT vs repetition vs short-dot (Remark 4).

Paper claim: coded FFT achieves K* = m (optimal, Thm 1/2); uncoded
repetition needs N - N/m^2 + 1 and short-dot N - N/m + m.  We print the
analytic thresholds for a sweep of (N, m) AND verify empirically that the
coded construction decodes from *every* (random) m-subset while repetition
fails on its worst-case subsets of the same size.
"""

from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CodedFFT,
    UncodedRepetitionFFT,
    coded_fft_threshold,
    repetition_threshold,
    short_dot_threshold,
)


def run() -> list[str]:
    lines = ["bench_recovery: thresholds (lower = more straggler-tolerant)"]
    lines.append(f"{'N':>4} {'m':>3} | {'coded (K*=m)':>12} {'repetition':>11} "
                 f"{'short-dot':>9}")
    for n, m in [(16, 2), (16, 4), (64, 4), (64, 8), (256, 8), (256, 16),
                 (512, 16)]:
        lines.append(
            f"{n:>4} {m:>3} | {coded_fft_threshold(n, m):>12} "
            f"{repetition_threshold(n, m):>11} {short_dot_threshold(n, m):>9}")

    # empirical: every random m-subset decodes exactly
    s, m, n = 512, 2, 16
    plan = CodedFFT(s=s, m=m, n_workers=n)
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (s,)) + 1j * jax.random.normal(key, (s,))
         ).astype(jnp.complex64)
    ref = jnp.fft.fft(x)
    b = plan.worker_compute(plan.encode(x))
    worst = 0.0
    n_sub = 0
    for subset in itertools.combinations(range(n), m):
        out = plan.decode(b, subset=jnp.asarray(subset))
        worst = max(worst, float(jnp.max(jnp.abs(out - ref))))
        n_sub += 1
    lines.append(f"coded FFT: all {n_sub} possible {m}-subsets of {n} workers "
                 f"decode; worst abs err {worst:.2e}")

    # repetition: exhibits subsets of the same size that CANNOT decode
    rep = UncodedRepetitionFFT(s=s, m=m, n_workers=n)
    n_fail = 0
    for sub in itertools.combinations(range(n), m):
        mask = np.zeros(n, bool)
        mask[list(sub)] = True
        if not rep.decodable(mask):
            n_fail += 1
    lines.append(f"repetition: {n_fail}/{n_sub} {m}-subsets CANNOT decode "
                 f"(threshold {repetition_threshold(n, m)} > {m})")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
