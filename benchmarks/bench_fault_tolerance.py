"""Fault tolerance: Byzantine correction (Remark 3) + measured stragglers.

Two sections, selectable via ``BENCH_ONLY=byzantine|measured``:

* ``byzantine`` -- inject garbage into worker outputs and verify the
  Prony-style locator detects/corrects within the MDS bounds (detect
  ``k - m``, correct ``floor((k - m)/2)``), including BIT-consistency:
  the corrected output is byte-identical to the clean decode over the
  same clean responder subset (corrupted rows never enter the final
  decode), asserted over adversarial corruption patterns.

* ``measured`` -- the straggler-tolerance claim on MEASURED wall-clock
  time, not the shifted-exponential model: the thread-per-worker
  ``MeasuredWorkerRuntime`` service (N=8, m=4, so N - m = 4 slack) runs
  under seeded kill/delay fault plans at rates {0, 1/N, 2/N}.  Per-round
  time-to-threshold comes from actual thread arrival times against
  deadlines LEARNED by the health tracker.  Acceptance (asserted when not
  BENCH_SMOKE): coded p99 at fault rate 1/N stays within 1.5x the
  no-fault p99 and zero requests degrade -- while the uncoded baseline
  (``require_all=True``: every worker is load-bearing) FAILS rounds under
  the identical fault plan in the same run.

``BENCH_SMOKE=1`` shrinks rounds and skips the artifact; otherwise the
results append to ``BENCH_faults.json`` with the previous runs preserved
under ``history`` (oldest first), version-stamped like BENCH_service.json.
"""

from __future__ import annotations

import json
import os
import pathlib
import platform

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CodedFFT, RobustCodedFFT, robust_decode
from repro.distributed import FaultPlan
from repro.serving import DegradedResult, FFTService, FFTServiceConfig

SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"
ONLY = os.environ.get("BENCH_ONLY", "")


def _want(section: str) -> bool:
    # the aggregator historically ran this module as one section ("faults")
    return not ONLY or ONLY in (section, "faults")


def _versions() -> dict:
    import jaxlib

    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


# ---------------------------------------------------------------- byzantine
def _byzantine_section(lines: list[str]) -> dict:
    lines.append("  -- Byzantine errors (Remark 3) --")
    out: dict = {"cases": []}
    s, m, n = 1024, 4, 12
    plan = CodedFFT(s=s, m=m, n_workers=n, dtype=jnp.complex128)
    robust = RobustCodedFFT(plan, tol=1e-8)
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (s,)) + 1j * jax.random.normal(key, (s,))
         ).astype(jnp.complex128)
    ref = jnp.fft.fft(x)
    rng = np.random.default_rng(0)
    b_clean = np.array(plan.worker_compute(plan.encode(x)))

    # adversarial sweep: every receive size x several corruption patterns
    # (rotating positions, adjacent pairs, the extremes of the subset)
    for k_recv in (8, 10, 12):
        max_corr = robust.max_correctable(k_recv)
        recv = np.sort(rng.choice(n, size=k_recv, replace=False))
        patterns = [rng.choice(recv, size=max_corr, replace=False)
                    for _ in range(3)]
        patterns.append(recv[:max_corr])          # lowest received indices
        patterns.append(recv[-max_corr:])         # highest received indices
        for bad in patterns:
            bad = np.sort(np.asarray(bad))
            b = b_clean.copy()
            b[bad] = rng.standard_normal((max_corr, s // m)) * 100.0
            res = robust_decode(plan, jnp.asarray(b), recv, tol=1e-8)
            err = float(np.max(np.abs(res.output - np.asarray(ref))))
            found = sorted(res.error_worker_indices.tolist())
            assert res.ok and err < 1e-5
            assert set(found) == set(bad.tolist())
            # BIT-consistency: decoding the clean rows over the same
            # subset robust_decode used must match byte-for-byte -- the
            # corrupted rows provably never entered the final decode
            clean = [int(i) for i in recv if i not in set(bad.tolist())]
            subset = jnp.asarray(clean[:m])
            want = np.asarray(plan.decode(jnp.asarray(b_clean),
                                          subset=subset))
            assert np.array_equal(np.asarray(res.output), want), \
                "corrected output not bit-identical to clean-subset decode"
            out["cases"].append({
                "k": int(k_recv), "corrupted": [int(w) for w in bad],
                "located": found, "corrected": int(res.n_errors_corrected),
                "output_err": err, "bit_consistent": True,
            })
        lines.append(
            f"  k={k_recv:>2}: {len(patterns)} adversarial patterns of "
            f"{max_corr} corrupt workers located+corrected, outputs "
            f"bit-consistent with clean-subset decode")
    # one past the bound: floor((k-m)/2)+1 errors must be REFUSED, not
    # silently mis-corrected
    recv = np.arange(8)
    over = rng.choice(recv, size=(8 - m) // 2 + 1, replace=False)
    b = b_clean.copy()
    b[np.sort(over)] = rng.standard_normal((over.shape[0], s // m)) * 100.0
    res = robust_decode(plan, jnp.asarray(b), recv, tol=1e-8)
    assert not res.ok
    out["over_bound_refused"] = True
    lines.append(f"  k= 8: {over.shape[0]} errors (> bound) refused, ok=False")
    lines.append(f"  bound: correct floor((k-m)/2), detect k-m (m={m})")
    return out


# ----------------------------------------------------------------- measured
_MEASURED_S = 65536
_WARMUP = 3          # cold rounds (deadline bootstrap, pool spin-up, jit)
#                      excluded from the latency percentiles


def _measured_service(rate: float, *, require_all: bool,
                      rounds: int, seed: int) -> tuple[FFTService, list]:
    n = 8
    # kill-only for the rate sweep: a killed worker frees its pool thread
    # immediately, so re-dispatch timing measures the PROTOCOL, not thread
    # starvation behind sleeping delay-fault workers (delays are covered
    # by the deadline-mask tests; masks handle them without retries)
    faults = (FaultPlan.random(n, rate, kinds=("kill",),
                               horizon=rounds + 8, seed=seed)
              if rate > 0 else None)
    # s large enough that per-worker FFT compute dominates thread-
    # scheduling jitter -- at tiny s the m-th-of-k order statistic is all
    # scheduler noise and the p99 ratio measures the OS, not the protocol
    s = _MEASURED_S
    svc = FFTService(FFTServiceConfig(
        s=s, m=4, n_workers=n, dtype=jnp.complex128, use_reference=True,
        autotune=False, seed=seed, measured=True, faults=faults,
        require_all=require_all, on_failure="degrade",
        max_retries=0 if require_all else 2))
    rng = np.random.default_rng(seed)
    xs = [(rng.normal(size=s) + 1j * rng.normal(size=s))
          for _ in range(rounds)]
    return svc, xs


def _run_measured(rate: float, *, require_all: bool, rounds: int) -> dict:
    svc, xs = _measured_service(rate, require_all=require_all,
                                rounds=rounds + _WARMUP, seed=7)
    lat, failed = [], 0
    for i, x in enumerate(xs):
        before = svc.stats.coded_latency
        y = svc.submit(jnp.asarray(x))
        # per-round MEASURED time-to-threshold (thread arrival clock),
        # via the stats delta -- not a model draw
        if i >= _WARMUP:
            lat.append(svc.stats.coded_latency - before)
        if isinstance(y, DegradedResult):
            if i >= _WARMUP:
                failed += 1
        else:
            assert np.abs(y - np.fft.fft(x)).max() < 1e-6
    lat_ms = np.sort(np.asarray(lat)) * 1e3
    return {
        "fault_rate": rate,
        "require_all": require_all,
        "rounds": rounds,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "failed_rounds": failed,
        "retries": svc.stats.retries,
        "redispatched_shards": svc.stats.redispatched_shards,
    }


def _measured_section(lines: list[str]) -> dict:
    n = 8
    rounds = 10 if SMOKE else 120
    lines.append(f"  -- measured runtime (thread-per-worker, N={n} m=4, "
                 f"{rounds} rounds/point) --")
    out: dict = {"coded": [], "uncoded": []}
    for rate in (0.0, 1 / n, 2 / n):
        r = _run_measured(rate, require_all=False, rounds=rounds)
        out["coded"].append(r)
        lines.append(
            f"  coded   rate={rate:.3f}: p50 {r['p50_ms']:6.2f} ms, "
            f"p99 {r['p99_ms']:6.2f} ms, failed {r['failed_rounds']}, "
            f"retries {r['retries']}, redispatched {r['redispatched_shards']}")
    for rate in (0.0, 1 / n):
        r = _run_measured(rate, require_all=True, rounds=rounds)
        out["uncoded"].append(r)
        lines.append(
            f"  uncoded rate={rate:.3f}: p50 {r['p50_ms']:6.2f} ms, "
            f"p99 {r['p99_ms']:6.2f} ms, failed {r['failed_rounds']} "
            f"(require_all: every worker load-bearing)")

    p99_0 = out["coded"][0]["p99_ms"]
    p99_1 = out["coded"][1]["p99_ms"]
    ratio = p99_1 / p99_0
    unc_failed = out["uncoded"][1]["failed_rounds"]
    out["p99_ratio_rate_1_over_n"] = ratio
    lines.append(
        f"  coded p99 @ rate 1/N vs no-fault: {ratio:.2f}x "
        f"(acceptance <= 1.5x); uncoded failed {unc_failed}/{rounds} "
        f"rounds under the same plan")
    if not SMOKE:
        assert ratio <= 1.5, (
            f"coded p99 degraded {ratio:.2f}x under fault rate 1/N "
            f"(acceptance: <= 1.5x with N - m = 4 slack)")
        assert out["coded"][1]["failed_rounds"] == 0, \
            "coded path degraded requests at fault rate 1/N"
        assert unc_failed > 0, (
            "uncoded require_all baseline should fail rounds at fault "
            "rate 1/N -- fault plan never fired?")
    return out


def run() -> list[str]:
    with jax.experimental.enable_x64():
        return _run_x64()


def _run_x64() -> list[str]:
    lines = ["bench_fault_tolerance: Byzantine errors + measured stragglers"]
    result: dict = {}
    if _want("byzantine"):
        result["byzantine"] = _byzantine_section(lines)
    if _want("measured"):
        result["measured"] = _measured_section(lines)
    result["versions"] = _versions()
    if SMOKE or ONLY:
        lines.append("  [BENCH_SMOKE/BENCH_ONLY: artifact not written]")
        return lines
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_faults.json"
    history: list = []
    if out_path.exists():
        try:
            prev = json.loads(out_path.read_text())
            history = prev.pop("history", [])
            history.append(prev)
        except (json.JSONDecodeError, AttributeError):
            pass
    result["history"] = history
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    lines.append(f"  [written to {out_path}]")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
