"""Byzantine fault tolerance (paper Remark 3).

With k >= m results received, the MDS structure detects up to k - m
arbitrary errors and corrects up to floor((k - m)/2) -- we inject garbage
into worker outputs and verify detection/correction via the Prony-style
error locator over C.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CodedFFT, RobustCodedFFT, robust_decode


def run() -> list[str]:
    with jax.experimental.enable_x64():
        return _run_x64()


def _run_x64() -> list[str]:
    lines = ["bench_fault_tolerance: Byzantine errors (Remark 3)"]
    s, m, n = 1024, 4, 12
    plan = CodedFFT(s=s, m=m, n_workers=n, dtype=jnp.complex128)
    robust = RobustCodedFFT(plan, tol=1e-8)
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (s,)) + 1j * jax.random.normal(key, (s,))
         ).astype(jnp.complex128)
    ref = jnp.fft.fft(x)
    rng = np.random.default_rng(0)

    for k_recv in (8, 10, 12):
        max_corr = robust.max_correctable(k_recv)
        recv = np.sort(rng.choice(n, size=k_recv, replace=False))
        b = np.array(plan.worker_compute(plan.encode(x)))  # writable copy
        bad = rng.choice(recv, size=max_corr, replace=False)
        b[bad] = rng.standard_normal((max_corr, s // m)) * 100.0  # garbage
        res = robust_decode(plan, jnp.asarray(b), recv, tol=1e-8)
        err = float(np.max(np.abs(res.output - np.asarray(ref))))
        found = sorted(res.error_worker_indices.tolist())
        lines.append(
            f"  k={k_recv:>2} corrupted {sorted(bad.tolist())} -> located "
            f"{found}, corrected {res.n_errors_corrected}"
            f"/{max_corr}, output err {err:.2e}, ok={res.ok}")
        assert res.ok and err < 1e-5
        assert set(found) == set(bad.tolist())
    lines.append(f"  bound: correct floor((k-m)/2), detect k-m (m={m})")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
