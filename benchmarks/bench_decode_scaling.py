"""Decoding complexity linear in s (paper §III-C).

Claim: master decode = (N,m)-MDS decode repeated s/m times + recombine,
total O(s log^2 m loglog m) -- LINEAR in s for fixed (N, m).  We time the
jitted decode for s over two orders of magnitude and report ns/element,
which should be ~flat; we also sweep m at fixed s to show the mild
growth in the per-element cost.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CodedFFT


def _time(fn, *args, iters=5) -> float:
    jax.block_until_ready(fn(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run() -> list[str]:
    lines = ["bench_decode_scaling: decode wall time vs s (fixed N=8, m=4)"]
    m, n = 4, 8
    subset = jnp.asarray([1, 3, 4, 6])
    per_elem = []
    for logs in (12, 14, 16, 18):
        s = 1 << logs
        plan = CodedFFT(s=s, m=m, n_workers=n)
        b = jnp.zeros((n, s // m), jnp.complex64)
        dec = jax.jit(lambda bb: plan.decode(bb, subset=subset))
        dt = _time(dec, b)
        per_elem.append(dt / s * 1e9)
        lines.append(f"  s=2^{logs:<3} decode {dt * 1e3:8.2f} ms   "
                     f"{dt / s * 1e9:7.2f} ns/elem")
    spread = max(per_elem) / min(per_elem)
    lines.append(f"  per-element cost spread {spread:.2f}x over 64x input "
                 f"growth -> linear in s (claim holds)")

    lines.append("decode cost vs m (s=2^16, N=2m):")
    s = 1 << 16
    for m2 in (2, 4, 8, 16):
        plan = CodedFFT(s=s, m=m2, n_workers=2 * m2)
        b = jnp.zeros((2 * m2, s // m2), jnp.complex64)
        sub = jnp.arange(m2)
        dec = jax.jit(lambda bb: plan.decode(bb, subset=sub))
        dt = _time(dec, b)
        lines.append(f"  m={m2:<3} decode {dt * 1e3:8.2f} ms "
                     f"({dt / s * 1e9:6.2f} ns/elem)")

    lines.append("transform decode vs dense solve at the MDS layer "
                 "(s=2^20, full response set -> DESIGN.md §4 fast path):")
    lines.append("  solve cost grows ~linearly in m; the O(s log N) "
                 "transform decode stays flat (and is exact at any m here)")
    from repro.core import mds

    s = 1 << 20
    for m2 in (16, 128, 1024):
        n2 = m2
        b = jnp.zeros((n2, s // m2), jnp.complex64)
        g = mds.rs_generator(n2, m2, jnp.complex64)
        sub = jnp.arange(m2)
        dt_ifft = _time(jax.jit(lambda bb: mds.decode_ifft(bb, sub, n2)), b)
        dt_solve = _time(jax.jit(
            lambda bb: mds.decode_from_subset(g, bb, sub)), b)
        lines.append(f"  m={m2:<5} ifft {dt_ifft * 1e3:8.2f} ms vs "
                     f"solve {dt_solve * 1e3:8.2f} ms "
                     f"({dt_solve / dt_ifft:.2f}x)")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
