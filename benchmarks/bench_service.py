"""End-to-end FFT service under straggler injection (the paper's Fig. 1
story): request latency waiting for the fastest m workers vs waiting for
all N, with decode correctness verified against jnp.fft on every request.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.straggler import StragglerModel
from repro.serving import FFTService, FFTServiceConfig


def run() -> list[str]:
    lines = ["bench_service: coded FFT serving with stragglers"]
    for mu in (2.0, 1.0, 0.5):
        svc = FFTService(FFTServiceConfig(
            s=2048, m=4, n_workers=8,
            straggler=StragglerModel(t0=1.0, mu=mu), seed=0))
        key = jax.random.PRNGKey(0)
        worst = 0.0
        for i in range(30):
            key, k1, k2 = jax.random.split(key, 3)
            x = (jax.random.normal(k1, (2048,))
                 + 1j * jax.random.normal(k2, (2048,))).astype(jnp.complex64)
            y = svc.submit(x)
            worst = max(worst, float(jnp.max(jnp.abs(y - jnp.fft.fft(x)))))
        st = svc.stats.summary()
        lines.append(
            f"  mu={mu:<4} 30 reqs: coded {st['mean_coded_latency']:.3f}s vs "
            f"uncoded {st['mean_uncoded_latency']:.3f}s "
            f"({st['speedup']:.2f}x), {st['stragglers_tolerated']} stragglers "
            f"tolerated, worst err {worst:.1e}")
        assert worst < 1e-2
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
