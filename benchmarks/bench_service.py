"""End-to-end FFT service under straggler injection (the paper's Fig. 1
story): request latency waiting for the fastest m workers vs waiting for
all N, with decode correctness verified against jnp.fft on every request.

Also measures the batched scheduler (DESIGN.md §5): wall-clock throughput
of ``submit_batch`` (one jitted encode/decode per (s, m) bucket) vs the
sequential per-request path, emitted to ``BENCH_service.json`` for the
perf trajectory.

The ``open_loop`` section (DESIGN.md §11) is the SLO story: a Poisson
arrival trace drives the streaming front-end (deadline-aware continuous
batching + double-buffered staging) against the naive fill-only /
synchronous-staging baseline IN THE SAME RUN, reporting p50/p99 latency
vs offered load.  The acceptance claim -- streaming p99 at mid-load at
least 1.3x better than the baseline -- is asserted on every full run.
"""

from __future__ import annotations

import gc
import json
import os
import pathlib
import platform
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.straggler import StragglerModel
from repro.serving import FFTService, FFTServiceConfig, ServiceStats

# BENCH_SMOKE=1 (the CI bench-smoke job): few requests/reps, NO artifact
# write -- structural + correctness signal only, fast enough to gate PRs
SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"
# BENCH_ONLY=<section> runs a single section (stragglers | batched |
# open_loop) for a focused CI signal; implies no artifact write
ONLY = os.environ.get("BENCH_ONLY", "")


def _want(section: str) -> bool:
    return not ONLY or ONLY == section


def _versions() -> dict:
    """Stamp each BENCH_service.json entry so trajectory rows are
    comparable across CI runners (jax/platform drift is the usual
    explanation for a mystery step in the curves)."""
    import jaxlib

    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def _requests(n, s, key):
    xs = []
    for _ in range(n):
        key, k1, k2 = jax.random.split(key, 3)
        xs.append((jax.random.normal(k1, (s,))
                   + 1j * jax.random.normal(k2, (s,))).astype(jnp.complex64))
    return xs, key


def _straggler_section(lines: list[str]) -> None:
    for mu in ((1.0,) if SMOKE else (2.0, 1.0, 0.5)):
        svc = FFTService(FFTServiceConfig(
            s=2048, m=4, n_workers=8,
            straggler=StragglerModel(t0=1.0, mu=mu), seed=0))
        key = jax.random.PRNGKey(0)
        xs, key = _requests(8 if SMOKE else 30, 2048, key)
        worst = 0.0
        for x in xs:
            y = svc.submit(x)
            worst = max(worst, float(jnp.max(jnp.abs(y - jnp.fft.fft(x)))))
        st = svc.stats.summary()
        lines.append(
            f"  mu={mu:<4} {len(xs)} reqs: coded "
            f"{st['mean_coded_latency']:.3f}s vs "
            f"uncoded {st['mean_uncoded_latency']:.3f}s "
            f"({st['speedup']:.2f}x), {st['stragglers_tolerated']} stragglers "
            f"tolerated, worst err {worst:.1e}")
        assert worst < 1e-2


def _batched_sections(result: dict, lines: list[str]) -> None:
    # ---- batched scheduler throughput (DESIGN.md §5/§8) ---------------------
    n_req, s = (16 if SMOKE else 64), 2048
    cfg = FFTServiceConfig(s=s, m=4, n_workers=8,
                           straggler=StragglerModel(t0=1.0, mu=1.0),
                           seed=0, max_batch=64)
    key = jax.random.PRNGKey(1)
    xs, key = _requests(n_req, s, key)

    seq = FFTService(cfg)
    jax.block_until_ready(seq.submit(xs[0]))           # compile warm-up
    seq.stats = ServiceStats()                         # stats = timed run only
    t0 = time.perf_counter()
    outs_seq = [seq.submit(x) for x in xs]
    jax.block_until_ready(outs_seq[-1])
    dt_seq = time.perf_counter() - t0

    bat = FFTService(cfg)
    jax.block_until_ready(bat.submit_batch(xs)[-1])    # compile warm-up
    bat.stats = ServiceStats()                         # stats = timed run only
    t0 = time.perf_counter()
    outs_bat = bat.submit_batch(xs)
    jax.block_until_ready(outs_bat[-1])
    dt_bat = time.perf_counter() - t0

    worst = max(float(jnp.max(jnp.abs(y - jnp.fft.fft(x))))
                for x, y in zip(xs, outs_bat))
    assert worst < 1e-2
    bat_stats = bat.stats.summary()
    result.update({
        "s": s,
        "m": cfg.m,
        "n_workers": cfg.n_workers,
        "n_requests": n_req,
        "sequential_s": dt_seq,
        "batched_s": dt_bat,
        "sequential_rps": n_req / dt_seq,
        "batched_rps": n_req / dt_bat,
        "batch_speedup": dt_seq / dt_bat,
        "batches": bat_stats["batches"],
        # the async-pipeline observables (DESIGN.md §8): dispatch vs sync
        # wall split and ONE device->host transfer per submit_batch call
        "dispatch_s": bat_stats["dispatch_s"],
        "sync_s": bat_stats["sync_s"],
        "host_transfers": bat_stats["host_transfers"],
        "decode_cache_misses": bat_stats["decode_cache_misses"],
    })

    # ---- real-input (r2c) bucket config (DESIGN.md §7) ----------------------
    # same shape, REAL traffic: half-payload worker shards through the
    # r2c executor vs serving the same signals as complex requests
    rng = np.random.default_rng(7)
    xs_real = [jnp.asarray(rng.normal(size=s).astype(np.float32))
               for _ in range(n_req)]
    rsvc = FFTService(cfg)
    outs_r = rsvc.submit_batch(xs_real, kind="r2c")     # compile warm-up
    worst_r = max(
        float(np.abs(y - np.fft.rfft(np.asarray(x))).max())
        for x, y in zip(xs_real, outs_r))
    assert worst_r < 1e-2
    xs_cplx = [x.astype(jnp.complex64) for x in xs_real]
    rsvc.submit_batch(xs_cplx)                          # compile warm-up
    t_r2c, t_c2c = [], []
    for r in range(4 if SMOKE else 10):
        order = ((("r2c",), t_r2c), (("c2c",), t_c2c))
        for (kind,), acc in (order if r % 2 == 0 else order[::-1]):
            t0 = time.perf_counter()
            if kind == "r2c":
                rsvc.submit_batch(xs_real, kind="r2c")
            else:
                rsvc.submit_batch(xs_cplx)
            acc.append(time.perf_counter() - t0)

    r_med, c_med = statistics.median(t_r2c), statistics.median(t_c2c)
    result["rfft"] = {
        "s": s, "m": cfg.m, "n_workers": cfg.n_workers,
        "n_requests": n_req,
        "r2c_rps": n_req / r_med,
        "c2c_on_real_rps": n_req / c_med,
        "speedup_vs_c2c_on_real": c_med / r_med,
        "worker_payload_bytes_r2c": (s // cfg.m // 2) * 8,
        "worker_payload_bytes_c2c": (s // cfg.m) * 8,
        "worst_abs_err": worst_r,
    }
    lines.append(
        f"  rfft bucket: {n_req} real reqs {r_med * 1e3:.1f} ms "
        f"({n_req / r_med:.0f} rps) vs c2c-on-real {c_med * 1e3:.1f} ms "
        f"({n_req / c_med:.0f} rps) -> "
        f"{c_med / r_med:.2f}x, worst err {worst_r:.1e}")

    # ---- n-D real (rfftn) buckets (DESIGN.md §9) ----------------------------
    # 2-D real fields served end-to-end through the rfftn kind: the service
    # buckets by the shape tuple and runs the generic jitted plan executor
    # (half-payload packed shards, per-request straggler masks)
    nd_shape = (32, 32) if SMOKE else (64, 64)
    nd_req = 8 if SMOKE else 32
    tsn = [jnp.asarray(rng.normal(size=nd_shape).astype(np.float32))
           for _ in range(nd_req)]
    ndsvc = FFTService(cfg)
    outs_n = ndsvc.submit_batch(tsn, kind="rfftn")      # compile warm-up
    axes = tuple(range(-len(nd_shape), 0))
    worst_n = max(
        float(np.abs(y - np.fft.rfftn(np.asarray(t, np.float64),
                                      axes=axes)).max())
        for t, y in zip(tsn, outs_n))
    assert worst_n < 1e-2
    ysn = [jnp.asarray(np.fft.rfftn(np.asarray(t)).astype(np.complex64))
           for t in tsn]
    ndsvc.submit_batch(ysn, kind="irfftn")              # compile warm-up
    t_nd = []
    for _ in range(4 if SMOKE else 8):
        t0 = time.perf_counter()
        ndsvc.submit_batch(tsn, kind="rfftn")
        t_nd.append(time.perf_counter() - t0)
    nd_med = statistics.median(t_nd)
    shard_elems = int(np.prod(
        ndsvc._plan_for(nd_shape, "rfftn").worker_shard_shape))
    result["rfftn"] = {
        "shape": list(nd_shape), "m": cfg.m, "n_workers": cfg.n_workers,
        "n_requests": nd_req,
        "rfftn_rps": nd_req / nd_med,
        "worker_payload_bytes_rfftn": shard_elems * 8,
        "worker_payload_bytes_c2cn": shard_elems * 2 * 8,
        "worst_abs_err": worst_n,
    }
    lines.append(
        f"  rfftn bucket: {nd_req} real {nd_shape} reqs "
        f"{nd_med * 1e3:.1f} ms ({nd_req / nd_med:.0f} rps), "
        f"payload {shard_elems * 8 // 1024}KiB vs "
        f"{shard_elems * 2 * 8 // 1024}KiB/worker shard (c2cn), "
        f"worst err {worst_n:.1e}")
    if SMOKE:
        lines.append(
            f"  batched scheduler (smoke): {n_req} reqs in {dt_bat * 1e3:.1f} "
            f"ms [BENCH_SMOKE=1: artifact not written]")
    else:
        lines.append(
            f"  batched scheduler: {n_req} reqs in {dt_bat * 1e3:.1f} ms "
            f"({result['batched_rps']:.0f} rps) vs sequential "
            f"{dt_seq * 1e3:.1f} ms ({result['sequential_rps']:.0f} rps) "
            f"-> {result['batch_speedup']:.2f}x")


def _open_loop_section(lines: list[str]) -> dict:
    """Poisson arrival trace -> p50/p99 latency vs offered load, streaming
    front-end vs the naive (fill-only, synchronous-staging) baseline
    measured in the SAME run (DESIGN.md §11)."""
    from repro.serving.streaming import (
        AdmissionError,
        StreamConfig,
        StreamingFFTService,
    )

    s = 512 if SMOKE else 2048
    cfg = FFTServiceConfig(s=s, m=4, n_workers=8,
                           straggler=StragglerModel(t0=1.0, mu=1.0),
                           seed=0, max_batch=32)
    svc = FFTService(cfg)
    # precompile every power-of-two bucket: a cold compile inside a
    # latency window would swamp the queueing signal being measured
    svc.warmup()
    rng = np.random.default_rng(11)
    pool = [(rng.normal(size=s)
             + 1j * rng.normal(size=s)).astype(np.complex64)
            for _ in range(32)]
    rates = [300] if SMOKE else [500, 1000, 2000]
    n_per = 40 if SMOKE else 600
    slack = 0.005
    modes = {
        "streaming": StreamConfig(slack_s=slack),
        # the before-this-PR story: dispatch only full buckets, stage
        # synchronously -- batch rps is identical, the tail is not
        "naive": StreamConfig(slack_s=slack, fill_only=True,
                              pipelined=False),
    }
    out = {"s": s, "m": cfg.m, "n_workers": cfg.n_workers,
           "max_batch": cfg.max_batch, "slack_ms": slack * 1e3,
           "n_per_rate": n_per, "curves": {}}
    for mode, scfg in modes.items():
        curve = []
        for rate in rates:
            svc.stats = ServiceStats()       # fresh window per drive
            stream = StreamingFFTService(svc, scfg)
            arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_per))
            futs, rejected = [], 0
            t0 = time.perf_counter()
            for i, t_arr in enumerate(arrivals):
                lag = t_arr - (time.perf_counter() - t0)
                if lag > 0:
                    time.sleep(lag)
                try:
                    futs.append((i, stream.submit(pool[i % len(pool)])))
                except AdmissionError:
                    rejected += 1
            stream.drain()
            stream.close()
            lats = np.asarray([f.latency_s for _, f in futs])
            worst = max(
                float(np.abs(f.result()
                             - np.fft.fft(pool[i % len(pool)])).max())
                for i, f in futs[:8])
            assert worst < 1e-2
            st = svc.stats.summary()
            # structural invariants of the streaming path: nothing lost,
            # ONE device->host transfer per dispatched bucket
            assert len(futs) + rejected == n_per
            assert st["host_transfers"] == st["batches"]
            assert st["latency"]["count"] == len(futs)
            curve.append({
                "offered_rps": rate,
                "n_offered": n_per,
                "completed": len(futs),
                "rejected": rejected,
                "p50_ms": float(np.percentile(lats, 50) * 1e3),
                "p99_ms": float(np.percentile(lats, 99) * 1e3),
                "mean_ms": float(lats.mean() * 1e3),
                "buckets": st["batches"],
                "fill_dispatches": st["fill_dispatches"],
                "deadline_dispatches": st["deadline_dispatches"],
                "drain_dispatches": st["drain_dispatches"],
                "queue_peak": st["queue_peak"],
                "staging_overlap_s": st["staging_overlap_s"],
            })
            lines.append(
                f"  open-loop[{mode}] {rate} rps: p50 "
                f"{curve[-1]['p50_ms']:.1f} ms, p99 "
                f"{curve[-1]['p99_ms']:.1f} ms "
                f"({curve[-1]['completed']}/{n_per} ok, "
                f"{rejected} rejected, "
                f"{st['deadline_dispatches']}/{st['fill_dispatches']}"
                f"/{st['drain_dispatches']} ddl/fill/drain)")
        out["curves"][mode] = curve
    mid = len(rates) // 2
    ratio = (out["curves"]["naive"][mid]["p99_ms"]
             / out["curves"]["streaming"][mid]["p99_ms"])
    out["mid_load_rps"] = rates[mid]
    out["p99_naive_over_streaming_mid_load"] = ratio
    lines.append(
        f"  open-loop p99 @ {rates[mid]} rps: naive/streaming = "
        f"{ratio:.2f}x (acceptance floor 1.3x)")
    if not SMOKE:
        assert ratio >= 1.3, (
            f"streaming p99 must beat the fill-only baseline by >=1.3x "
            f"at mid-load; measured {ratio:.2f}x")

    # ---- mixed-tier EDF scheduling (DESIGN.md §11) ----------------------
    # the SAME Poisson trace twice at equal offered load: single-tier
    # (everything at the standard slack) vs multi-tier EDF (interactive /
    # standard / batch classes).  The acceptance claim: the interactive
    # tier's p99 under EDF must not exceed the single-tier baseline p99.
    rate = 300 if SMOKE else 1000
    n_mix = 60 if SMOKE else 600
    tiers = {"interactive": 0.002, "standard": slack, "batch": 0.050}
    tier_names = np.asarray(["interactive", "standard", "batch"])
    draw = rng.choice(3, size=n_mix, p=[0.3, 0.5, 0.2])
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_mix))
    mixed = {"offered_rps": rate, "n_offered": n_mix,
             "tiers_ms": {k: v * 1e3 for k, v in tiers.items()},
             "tier_mix": {str(t): int((draw == i).sum())
                          for i, t in enumerate(tier_names)}}
    for mode in ("single_tier", "multi_tier"):
        svc.stats = ServiceStats()
        stream = StreamingFFTService(
            svc, StreamConfig(slack_s=slack, tiers=tiers))
        futs, rejected = [], 0
        # collector pause != queueing: at a 2 ms interactive slack, one
        # gen-2 GC sweep over the earlier sections' jaxpr graphs shows
        # up as a multi-ms p99 outlier, so sweep NOW and hold the
        # collector off for the (sub-second) timed drive
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        for i, t_arr in enumerate(arrivals):
            lag = t_arr - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            tier = ("standard" if mode == "single_tier"
                    else str(tier_names[draw[i]]))
            try:
                futs.append((tier, stream.submit(pool[i % len(pool)],
                                                 tier=tier)))
            except AdmissionError:
                rejected += 1
        stream.drain()
        stream.close()
        gc.enable()
        st = svc.stats.summary()
        assert len(futs) + rejected == n_mix
        assert st["latency"]["count"] == len(futs)
        lats = {}
        for tier, f in futs:
            lats.setdefault(tier, []).append(f.latency_s)
        per_tier = {
            tier: {"count": len(v),
                   "p50_ms": float(np.percentile(v, 50) * 1e3),
                   "p99_ms": float(np.percentile(v, 99) * 1e3)}
            for tier, v in sorted(lats.items())}
        mixed[mode] = {
            "completed": len(futs), "rejected": rejected,
            "p99_all_ms": float(np.percentile(
                [f.latency_s for _, f in futs], 99) * 1e3),
            "per_tier": per_tier,
            # the histogram-side view (per-tier LatencyHistogram): counts
            # must agree with the exact per-future percentiles above
            "hist_tiers": {k: {"count": v["count"],
                               "p99_ms": v["p99_s"] * 1e3}
                           for k, v in st["tiers"].items()},
        }
        for tier, v in per_tier.items():
            assert st["tiers"][tier]["count"] == v["count"]
        lines.append(
            f"  mixed-tier[{mode}] {rate} rps: "
            + ", ".join(f"{t} p99 {v['p99_ms']:.1f} ms (n={v['count']})"
                        for t, v in per_tier.items()))
    gain = (mixed["single_tier"]["p99_all_ms"]
            / mixed["multi_tier"]["per_tier"]["interactive"]["p99_ms"])
    mixed["interactive_p99_gain_vs_single_tier"] = gain
    lines.append(
        f"  mixed-tier interactive p99 vs single-tier baseline p99 @ "
        f"{rate} rps: {gain:.2f}x (acceptance floor 1.0x)")
    if not SMOKE:
        assert (mixed["multi_tier"]["per_tier"]["interactive"]["p99_ms"]
                <= mixed["single_tier"]["p99_all_ms"]), (
            "interactive-tier p99 under EDF must not exceed the "
            "single-tier baseline p99 at equal offered load")
    out["mixed_tier"] = mixed
    return out


def run() -> list[str]:
    lines = ["bench_service: coded FFT serving with stragglers"]
    result: dict = {}
    if _want("stragglers"):
        _straggler_section(lines)
    if _want("batched"):
        _batched_sections(result, lines)
    if _want("open_loop"):
        result["open_loop"] = _open_loop_section(lines)
    result["versions"] = _versions()
    if SMOKE or ONLY:
        return lines
    # anchor to the repo root so the tracked artifact updates regardless of cwd
    out_path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_service.json"
    # append to the perf trajectory rather than overwrite: the previous runs
    # move into "history" (oldest first), the current run stays top-level
    history: list = []
    if out_path.exists():
        try:
            prev = json.loads(out_path.read_text())
            history = prev.pop("history", [])
            history.append(prev)
        except (json.JSONDecodeError, AttributeError):
            pass
    result["history"] = history
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    lines.append(f"  [written to {out_path}]")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
