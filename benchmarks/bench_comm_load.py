"""Communication load (Remark 5) + the beyond-MDS strategy race.

Two sections, selectable via ``BENCH_ONLY=comm_load|strategies``:

* ``comm_load`` -- the original cut-set-bound check: any scheme must move
  >= s field symbols from workers to master; coded FFT moves EXACTLY s
  (m workers x s/m symbols).  Counted analytically per strategy AND
  verified in the lowered shard_map program (the single all-gather
  carries exactly s complex symbols).

* ``strategies`` -- race the three served CodedPlan families (DESIGN.md
  §13) on the regimes each was built for:

  (a) MODELED round times (harmonic closed form) over a wire_frac grid:
      comm_efficient's folded 1/q payload wins when the wire dominates
      and loses when compute does (Jeong et al. 1805.09891 trade).
  (b) MONTE-CARLO slow-but-alive fleet: the (m*r)-th fragment arrives
      before the m-th full shard because prefixes from slowed workers
      count (Wang et al. 1804.09791).
  (c) SERVICE-MEASURED race through the ``strategy=`` config knob:
      same-seed services, accuracy vs numpy asserted, simulated
      coverage latencies showing both crossovers end to end.

  All three claims are asserted in-bench; results append to
  ``BENCH_strategies.json`` with prior runs preserved under ``history``
  (oldest first).  ``BENCH_SMOKE=1`` shrinks rounds and, like
  ``BENCH_ONLY``, skips the artifact write.
"""

from __future__ import annotations

import json
import os
import pathlib
import platform

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CodedFFT, coded_fft_threshold, repetition_threshold, short_dot_threshold
from repro.core.strategies import REGISTRY, make_strategy
from repro.distributed.straggler import StragglerModel
from repro.serving import FFTService, FFTServiceConfig

SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"
ONLY = os.environ.get("BENCH_ONLY", "")


def _want(section: str) -> bool:
    # the aggregator historically ran this module as one section ("comm_load")
    return not ONLY or ONLY in (section, "comm_load")


def _versions() -> dict:
    import jaxlib

    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


# ---------------------------------------------------------------- comm_load
def _comm_load_section(lines: list[str]) -> None:
    lines.append("  -- worker->master symbols (input length s, cut-set "
                 "bound = s) --")
    lines.append(f"  {'N':>4} {'m':>3} {'s':>7} | {'coded':>8} "
                 f"{'short-dot':>10} {'repetition':>11}")
    for n, m, s in [(16, 4, 1 << 14), (64, 8, 1 << 16), (256, 16, 1 << 20)]:
        coded = coded_fft_threshold(n, m) * (s // m)          # = s exactly
        sd = short_dot_threshold(n, m) * (s // m)
        rep = repetition_threshold(n, m) * (s // m)
        lines.append(f"  {n:>4} {m:>3} {s:>7} | {coded:>8} {sd:>10} {rep:>11}"
                     f"   (coded/s = {coded / s:.2f}, optimal)")

    # verify in the lowered distributed program (needs >= 2 local devices
    # only for mesh construction; with 1 device we lower a 1-axis mesh)
    ndev = jax.device_count()
    if ndev >= 2:
        from repro.distributed import DistributedCodedFFT, test_mesh

        s, m, n = 4096, 4, ndev
        mesh = test_mesh((ndev,), ("workers",))
        plan = CodedFFT(s=s, m=m, n_workers=n)
        d = DistributedCodedFFT(plan, mesh)
        txt = d.lower().compile().as_text()
        import re

        ag = re.findall(r"c64\[([0-9,]+)\][^ ]* all-gather", txt)
        tot = 0
        for dims in ag:
            prod = 1
            for x in dims.split(","):
                prod *= int(x)
            tot += prod
        lines.append(f"  lowered shard_map program: all-gather carries {tot} "
                     f"c64 symbols for s={s} (N x s/N view of the same s "
                     f"coded symbols; bound s={s})")
    else:
        lines.append("  (single device: skipping lowered-collective check; "
                     "see tests/test_coded_runtime.py)")


# --------------------------------------------------------------- strategies
_N, _M, _Q, _R = 8, 2, 2, 4
_MU = 4.0


def _modeled_race(lines: list[str]) -> dict:
    """Closed-form expected round times over the wire_frac grid."""
    lines.append(f"  -- modeled round time (N={_N} m={_M} q={_Q}, "
                 f"harmonic closed form) --")
    out = {"grid": [], "n": _N, "m": _M, "q": _Q, "mu": _MU}
    for wf in (0.0, 0.25, 0.5, 0.8):
        sm = StragglerModel(t0=1.0, mu=_MU, wire_frac=wf)
        t_mds = sm.expected_kth(_N, _M, 1.0 / _M)
        t_ce = sm.expected_kth(_N, _M * _Q, 1.0 / _M, payload_scale=1.0 / _Q)
        out["grid"].append({"wire_frac": wf, "mds": t_mds,
                            "comm_efficient": t_ce})
        win = "comm_efficient" if t_ce < t_mds else "mds"
        lines.append(f"  wire_frac={wf:.2f}: mds {t_mds:.4f}  "
                     f"comm_eff {t_ce:.4f}  -> {win}")
    g = {r["wire_frac"]: r for r in out["grid"]}
    assert g[0.8]["comm_efficient"] < g[0.8]["mds"], \
        "folded payload must win when the wire dominates"
    assert g[0.0]["comm_efficient"] > g[0.0]["mds"], \
        "the m*q-th order statistic must cost more when compute dominates"
    lines.append("  asserted: comm_efficient wins at wire_frac 0.8, loses "
                 "at 0.0")
    return out


def _partial_mc_race(lines: list[str]) -> dict:
    """Slow-but-alive fleet: fragment coverage vs the m-th order stat."""
    rounds = 60 if SMOKE else 400
    lines.append(f"  -- partial-work vs mds, half the fleet 3x slow but "
                 f"ALIVE (r={_R}, {rounds} rounds) --")
    rng = np.random.default_rng(5)
    sm = StragglerModel(t0=1.0, mu=1.0, wire_frac=0.0)
    slow = np.ones(_N)
    slow[: _N // 2] = 3.0
    frac = np.arange(1, _R + 1) / _R
    t_mds = t_part = 0.0
    for _ in range(rounds):
        lat = sm.sample(_N, 1.0 / _M, rng) * slow
        t_mds += float(np.sort(lat)[_M - 1])
        ft = np.sort((lat[:, None] * frac).ravel())
        t_part += float(ft[_M * _R - 1])
    out = {"rounds": rounds, "r": _R, "slow_factor": 3.0,
           "mean_mds": t_mds / rounds, "mean_partial": t_part / rounds,
           "speedup": t_mds / t_part}
    lines.append(f"  mean round: mds {out['mean_mds']:.4f}  partial "
                 f"{out['mean_partial']:.4f}  ({out['speedup']:.2f}x)")
    assert t_part < t_mds, \
        "prefix fragments from slowed workers must beat full-shard waits"
    lines.append("  asserted: partial beats mds with slow-but-alive "
                 "stragglers")
    return out


def _service_race(lines: list[str]) -> dict:
    """End-to-end through the ``strategy=`` knob: accuracy + coverage."""
    s = 4096
    rounds, batch = (2, 4) if SMOKE else (30, 8)
    lines.append(f"  -- service race via strategy= (s={s} N={_N} m={_M}, "
                 f"{rounds} rounds x batch {batch}) --")
    rng = np.random.default_rng(1)
    xs = [(rng.standard_normal((batch, s)) + 1j * rng.standard_normal(
        (batch, s))).astype(np.complex64) for _ in range(rounds)]
    refs = [np.fft.fft(xb, axis=-1) for xb in xs]
    out: dict = {"s": s, "rounds": rounds, "batch": batch, "points": []}
    for wf in (0.8, 0.0):
        row = {"wire_frac": wf}
        for strategy in ("mds", "partial", "comm_efficient"):
            svc = FFTService(FFTServiceConfig(
                s=s, m=_M, n_workers=_N, strategy=strategy,
                use_reference=True, autotune=False, seed=0,
                straggler=StragglerModel(t0=1.0, mu=_MU, wire_frac=wf)))
            err = 0.0
            for xb, ref in zip(xs, refs):
                ys = svc.submit_batch([jnp.asarray(x) for x in xb])
                got = np.stack([np.asarray(y) for y in ys])
                err = max(err, float(np.max(np.abs(got - ref))
                                     / np.max(np.abs(ref))))
            assert err < 5e-4, f"{strategy} service decode error {err:.2e}"
            mean_lat = svc.stats.coded_latency / svc.stats.requests
            row[strategy] = {"mean_latency": mean_lat, "max_rel_err": err,
                             "stragglers_tolerated":
                                 svc.stats.stragglers_tolerated}
            lines.append(f"  wire_frac={wf:.1f} {strategy:>15}: mean "
                         f"coverage {mean_lat:.4f}, max rel err {err:.2e}, "
                         f"tolerated {svc.stats.stragglers_tolerated}")
        out["points"].append(row)
    hi, lo = out["points"][0], out["points"][1]
    assert hi["comm_efficient"]["mean_latency"] < hi["mds"]["mean_latency"], \
        "service: folded payload must win at wire_frac 0.8"
    assert lo["comm_efficient"]["mean_latency"] > lo["mds"]["mean_latency"], \
        "service: m*q-th order statistic must lose at wire_frac 0.0"
    # same-seed draws: partial's (m*r)-th fragment coverage can never
    # trail the m-th full shard (m fully-done workers imply m*r fragments)
    for row in out["points"]:
        assert row["partial"]["mean_latency"] \
            <= row["mds"]["mean_latency"] + 1e-12
    lines.append("  asserted: comm_efficient crossover + partial <= mds "
                 "end to end")
    return out


def _strategies_section(lines: list[str]) -> dict:
    lines.append(f"  registered strategies: {sorted(REGISTRY)}")
    # one differential sanity pass so the race never reports timings for
    # plans that silently decode garbage
    x = (np.random.default_rng(9).standard_normal(256)
         + 0j).astype(np.complex64)
    ref = np.fft.fft(x)
    for name in ("mds", "partial", "comm_efficient"):
        plan = make_strategy(name, 256, _M, _N)
        got = np.asarray(plan.run(jnp.asarray(x)))
        assert np.max(np.abs(got - ref)) / np.max(np.abs(ref)) < 5e-4
    return {
        "modeled": _modeled_race(lines),
        "partial_monte_carlo": _partial_mc_race(lines),
        "service": _service_race(lines),
    }


def run() -> list[str]:
    lines = ["bench_comm_load: communication optimality + strategy race"]
    result: dict = {}
    if _want("comm_load"):
        _comm_load_section(lines)
    if _want("strategies"):
        result["strategies"] = _strategies_section(lines)
    if not result.get("strategies"):
        return lines
    result["versions"] = _versions()
    if SMOKE or ONLY:
        lines.append("  [BENCH_SMOKE/BENCH_ONLY: artifact not written]")
        return lines
    out_path = (pathlib.Path(__file__).resolve().parent.parent
                / "BENCH_strategies.json")
    history: list = []
    if out_path.exists():
        try:
            prev = json.loads(out_path.read_text())
            history = prev.pop("history", [])
            history.append(prev)
        except (json.JSONDecodeError, AttributeError):
            pass
    result["history"] = history
    out_path.write_text(json.dumps(result, indent=2) + "\n")
    lines.append(f"  [written to {out_path}]")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
