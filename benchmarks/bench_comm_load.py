"""Communication load optimality (paper Remark 5).

Claim: any scheme must move >= s field symbols from workers to master
(cut-set bound); coded FFT moves EXACTLY s (m workers x s/m symbols) --
optimal.  We count symbols analytically per strategy AND verify the
distributed runtime's lowering: the single all-gather in the shard_map
program carries exactly s complex symbols.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import CodedFFT, coded_fft_threshold, repetition_threshold, short_dot_threshold


def run() -> list[str]:
    lines = ["bench_comm_load: worker->master symbols (input length s, "
             "cut-set bound = s)"]
    lines.append(f"{'N':>4} {'m':>3} {'s':>7} | {'coded':>8} {'short-dot':>10} "
                 f"{'repetition':>11}")
    for n, m, s in [(16, 4, 1 << 14), (64, 8, 1 << 16), (256, 16, 1 << 20)]:
        coded = coded_fft_threshold(n, m) * (s // m)          # = s exactly
        sd = short_dot_threshold(n, m) * (s // m)
        rep = repetition_threshold(n, m) * (s // m)
        lines.append(f"{n:>4} {m:>3} {s:>7} | {coded:>8} {sd:>10} {rep:>11}"
                     f"   (coded/s = {coded / s:.2f}, optimal)")

    # verify in the lowered distributed program (needs >= 2 local devices
    # only for mesh construction; with 1 device we lower a 1-axis mesh)
    ndev = jax.device_count()
    if ndev >= 2:
        from repro.distributed import DistributedCodedFFT, test_mesh

        s, m, n = 4096, 4, ndev
        mesh = test_mesh((ndev,), ("workers",))
        plan = CodedFFT(s=s, m=m, n_workers=n)
        d = DistributedCodedFFT(plan, mesh)
        txt = d.lower().compile().as_text()
        import re

        ag = re.findall(r"c64\[([0-9,]+)\][^ ]* all-gather", txt)
        tot = 0
        for dims in ag:
            prod = 1
            for x in dims.split(","):
                prod *= int(x)
            tot += prod
        lines.append(f"lowered shard_map program: all-gather carries {tot} "
                     f"c64 symbols for s={s} (N x s/N view of the same s "
                     f"coded symbols; bound s={s})")
    else:
        lines.append("(single device: skipping lowered-collective check; "
                     "see tests/test_coded_runtime.py)")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
