"""n-dimensional + multi-input coded FFT (Theorems 3 & 5).

Verifies K* = m for 2-D/3-D transforms and the q-input bundling strategy,
and times encode/worker/decode stages.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CodedFFTMultiInput, CodedFFTND, plan_factors


def _t(fn, *a):
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*a))
    return time.perf_counter() - t0


def run() -> list[str]:
    lines = ["bench_ndim: n-D and multi-input coded FFT (K* = m)"]
    key = jax.random.PRNGKey(0)

    for shape, m, n in [((64, 64), 4, 8), ((32, 32, 16), 4, 6),
                        ((128, 64), 8, 12)]:
        factors = plan_factors(shape, m)
        plan = CodedFFTND(shape=shape, factors=factors, n_workers=n)
        t = (jax.random.normal(key, shape) + 1j * jax.random.normal(key, shape)
             ).astype(jnp.complex64)
        ref = jnp.fft.fftn(t)
        mask = jnp.arange(n) % 2 == 0  # half the workers straggle...
        mask = mask.at[:m].set(True) if int(mask.sum()) < m else mask
        run_fn = jax.jit(lambda tt: plan.run(tt, mask=mask))
        out = run_fn(t)
        err = float(jnp.max(jnp.abs(out - ref)))
        dt = _t(run_fn, t)
        lines.append(f"  {len(shape)}-D {shape} m={m} (factors {factors}) "
                     f"N={n}: err {err:.2e}, {dt * 1e3:.1f} ms e2e, "
                     f"threshold {plan.recovery_threshold}")

    # multi-input (Thm 5): q inputs, bundled MDS (m = m_tilde * prod(factors))
    q, shape, n = 8, (64, 32), 8
    plan = CodedFFTMultiInput(q=q, shape=shape, m_tilde=2, factors=(2, 1),
                              n_workers=n)
    ts = (jax.random.normal(key, (q,) + shape)
          + 1j * jax.random.normal(key, (q,) + shape)).astype(jnp.complex64)
    refs = jnp.fft.fftn(ts, axes=(1, 2))
    mask = jnp.asarray([True, False, True, True, False, True, False, True])
    out = jax.jit(lambda xx: plan.run(xx, mask=mask))(ts)
    err = float(jnp.max(jnp.abs(out - refs)))
    lines.append(f"  multi-input q={q} {shape} m_tilde=2 factors=(2,1) "
                 f"(m={plan.m}) N={n}: err {err:.2e}, "
                 f"threshold {plan.recovery_threshold}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
