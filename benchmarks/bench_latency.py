"""Straggler latency: expected completion time under the shifted-exponential
model (the paper's motivating metric -- Fig. 1's 'don't wait for worker 1').

Each strategy processes workload w per worker and waits for its recovery
threshold k: completion = k-th order statistic of N shifted-exp finish
times.  Closed form E[T_(k)] = w (t0 + (H_N - H_{N-k}) / mu) plus Monte
Carlo confirmation.
"""

from __future__ import annotations

import numpy as np

from repro.core import coded_fft_threshold, repetition_threshold, short_dot_threshold
from repro.distributed.straggler import StragglerModel, empirical_completion


def run() -> list[str]:
    model = StragglerModel(t0=1.0, mu=1.0)
    rng = np.random.default_rng(0)
    trials = 2000
    lines = ["bench_latency: E[completion] (shifted-exp, t0=1, mu=1); "
             "analytic | monte-carlo x2000"]
    lines.append(f"{'N':>4} {'m':>3} | {'coded':>15} {'short-dot':>15} "
                 f"{'wait-all':>15}")
    for n, m in [(8, 4), (16, 8), (32, 8), (64, 16), (256, 16)]:
        w = 1.0 / m
        specs = {
            "coded": (coded_fft_threshold(n, m), w),
            "short-dot": (short_dot_threshold(n, m), w),
            "wait-all": (n, w),
        }
        cells = []
        for name, (k, wl) in specs.items():
            ana = model.expected_kth(n, k, wl)
            emp = np.mean([
                empirical_completion(model.sample(n, wl, rng), k)
                for _ in range(trials)])
            cells.append(f"{ana:6.3f}|{emp:6.3f}")
        lines.append(f"{n:>4} {m:>3} | " + " ".join(f"{c:>15}" for c in cells))
    lines.append("coded FFT waits for the m fastest only: latency stays flat "
                 "as N grows while wait-all degrades with H_N.")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
