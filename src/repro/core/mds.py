"""Complex Reed-Solomon MDS codes for coded computation.

The paper (§III-B) requires an arbitrary ``(N, m)``-MDS code over a field
with a primitive root of unity.  Working over ``F = C`` we use a Vandermonde
generator evaluated at the ``N``-th roots of unity::

    G[k, i] = alpha_k ** i,   alpha_k = exp(-2j * pi * k / N),   i < m

Properties exploited here:

* every ``m x m`` submatrix of ``G`` is a Vandermonde matrix on distinct
  unit-circle nodes, hence invertible -> the code is MDS and the recovery
  threshold is exactly ``m`` (Theorem 1);
* nodes on the unit circle give the best-conditioned subset inverses among
  Vandermonde choices over C, which matters for float decoding;
* encoding equals evaluating the degree-``(m-1)`` message polynomial at the
  roots of unity, i.e. a zero-padded length-``N`` DFT -- the paper's
  Reed-Solomon suggestion (§III-C) specialised to C.

All functions are jit-compatible and batched over trailing axes: message
``c`` has shape ``(m, *payload)`` and codeword ``a`` has ``(n, *payload)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "rs_nodes",
    "rs_generator",
    "encode",
    "decode_from_subset",
    "subset_decode_matrix",
    "first_available",
    "decode_masked",
    "encode_dft",
]


def rs_nodes(n: int, dtype=jnp.complex64) -> jax.Array:
    """The ``n`` evaluation nodes: ``exp(-2j*pi*k/n)`` for ``k < n``."""
    k = jnp.arange(n)
    return jnp.exp(-2j * jnp.pi * k / n).astype(dtype)


def rs_generator(n: int, m: int, dtype=jnp.complex64) -> jax.Array:
    """``(n, m)`` Vandermonde generator ``G[k, i] = alpha_k**i``."""
    if m > n:
        raise ValueError(f"need n >= m, got n={n} m={m}")
    nodes = rs_nodes(n, dtype)
    powers = jnp.arange(m)
    return (nodes[:, None] ** powers[None, :]).astype(dtype)


def _flatten_payload(c: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    payload = c.shape[1:]
    return c.reshape(c.shape[0], -1), payload


def encode(generator: jax.Array, c: jax.Array) -> jax.Array:
    """Encode ``m`` message shards into ``n`` coded shards: ``a = G @ c``.

    ``c``: ``(m, *payload)`` -> returns ``(n, *payload)``.
    """
    flat, payload = _flatten_payload(c)
    coded = generator.astype(flat.dtype) @ flat
    return coded.reshape((generator.shape[0],) + payload)


def encode_dft(c: jax.Array, n: int) -> jax.Array:
    """Fast encode for the roots-of-unity generator.

    Evaluating the message polynomial at all ``n`` roots of unity is a
    zero-padded length-``n`` DFT along the shard axis:
    ``a_k = sum_i c_i * omega_n^{ki}`` = ``fft(pad(c, n), axis=0)[k]``.
    O(n log n) per payload element instead of O(n*m).
    """
    m = c.shape[0]
    if n < m:
        raise ValueError(f"need n >= m, got n={n} m={m}")
    pad = [(0, n - m)] + [(0, 0)] * (c.ndim - 1)
    return jnp.fft.fft(jnp.pad(c, pad), axis=0)


def subset_decode_matrix(generator: jax.Array, subset: jax.Array) -> jax.Array:
    """Inverse of the ``m x m`` generator submatrix picked by ``subset``."""
    sub = jnp.take(generator, subset, axis=0)
    return jnp.linalg.inv(sub)


def decode_from_subset(
    generator: jax.Array, b: jax.Array, subset: jax.Array
) -> jax.Array:
    """Recover the ``m`` message shards from the coded results in ``subset``.

    ``b``: ``(n, *payload)`` worker results (rows outside ``subset`` are
    ignored, so stragglers may hold garbage).  ``subset``: ``(m,)`` integer
    indices of the workers that responded.  Static-shape, jit-safe.
    """
    m = generator.shape[1]
    if subset.shape[0] != m:
        raise ValueError(f"subset must have exactly m={m} entries")
    flat, payload = _flatten_payload(b)
    rows = jnp.take(flat, subset, axis=0)
    sub = jnp.take(generator, subset, axis=0).astype(flat.dtype)
    decoded = jnp.linalg.solve(sub, rows)
    return decoded.reshape((m,) + payload)


def first_available(mask: jax.Array, m: int) -> jax.Array:
    """Indices of the first ``m`` available workers (stable order).

    ``mask``: boolean ``(n,)``, True = result arrived.  The master waits for
    the *fastest* m workers; inside one SPMD program we model arrival order
    by the mask and pick the first m set entries.  Shapes stay static.
    """
    # argsort of (not mask) is stable: available indices first, in order.
    order = jnp.argsort(jnp.logical_not(mask), stable=True)
    return order[:m]


def decode_masked(generator: jax.Array, b: jax.Array, mask: jax.Array) -> jax.Array:
    """Decode from whichever ``m`` workers are available per ``mask``."""
    m = generator.shape[1]
    subset = first_available(mask, m)
    return decode_from_subset(generator, b, subset)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _condition_numbers(n: int, m: int) -> jax.Array:  # pragma: no cover - util
    """Condition number of every contiguous m-subset (diagnostic helper)."""
    g = rs_generator(n, m, jnp.complex128)
    idx = (jnp.arange(n)[:, None] + jnp.arange(m)[None, :]) % n
    subs = g[idx]  # (n, m, m)
    return jnp.linalg.cond(subs)
