"""Complex Reed-Solomon MDS codes for coded computation.

The paper (§III-B) requires an arbitrary ``(N, m)``-MDS code over a field
with a primitive root of unity.  Working over ``F = C`` we use a Vandermonde
generator evaluated at the ``N``-th roots of unity::

    G[k, i] = alpha_k ** i,   alpha_k = exp(-2j * pi * k / N),   i < m

Properties exploited here:

* every ``m x m`` submatrix of ``G`` is a Vandermonde matrix on distinct
  unit-circle nodes, hence invertible -> the code is MDS and the recovery
  threshold is exactly ``m`` (Theorem 1);
* nodes on the unit circle give the best-conditioned subset inverses among
  Vandermonde choices over C, which matters for float decoding;
* encoding equals evaluating the degree-``(m-1)`` message polynomial at the
  roots of unity, i.e. a zero-padded length-``N`` DFT -- the paper's
  Reed-Solomon suggestion (§III-C) specialised to C.

All functions are jit-compatible and batched over trailing axes: message
``c`` has shape ``(m, *payload)`` and codeword ``a`` has ``(n, *payload)``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rs_nodes",
    "rs_generator",
    "encode",
    "decode_from_subset",
    "subset_decode_matrix",
    "first_available",
    "decode_masked",
    "encode_dft",
    "decode_ifft",
    "decode_auto",
    "is_contiguous_subset",
    "lagrange_decode_coeffs",
    "lagrange_inverse",
    "lagrange_decode_matrix",
    "lagrange_decode_matrices",
    "LAGRANGE_MAX_M",
]


def rs_nodes(n: int, dtype=jnp.complex64) -> jax.Array:
    """The ``n`` evaluation nodes: ``exp(-2j*pi*k/n)`` for ``k < n``."""
    k = jnp.arange(n)
    return jnp.exp(-2j * jnp.pi * k / n).astype(dtype)


def rs_generator(n: int, m: int, dtype=jnp.complex64) -> jax.Array:
    """``(n, m)`` Vandermonde generator ``G[k, i] = alpha_k**i``."""
    if m > n:
        raise ValueError(f"need n >= m, got n={n} m={m}")
    nodes = rs_nodes(n, dtype)
    powers = jnp.arange(m)
    return (nodes[:, None] ** powers[None, :]).astype(dtype)


def _flatten_payload(c: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    payload = c.shape[1:]
    return c.reshape(c.shape[0], -1), payload


def encode(generator: jax.Array, c: jax.Array) -> jax.Array:
    """Encode ``m`` message shards into ``n`` coded shards: ``a = G @ c``.

    ``c``: ``(m, *payload)`` -> returns ``(n, *payload)``.
    """
    flat, payload = _flatten_payload(c)
    coded = generator.astype(flat.dtype) @ flat
    return coded.reshape((generator.shape[0],) + payload)


def encode_dft(c: jax.Array, n: int) -> jax.Array:
    """Fast encode for the roots-of-unity generator.

    Evaluating the message polynomial at all ``n`` roots of unity is a
    zero-padded length-``n`` DFT along the shard axis:
    ``a_k = sum_i c_i * omega_n^{ki}`` = ``fft(pad(c, n), axis=0)[k]``.
    O(n log n) per payload element instead of O(n*m).
    """
    m = c.shape[0]
    if n < m:
        raise ValueError(f"need n >= m, got n={n} m={m}")
    pad = [(0, n - m)] + [(0, 0)] * (c.ndim - 1)
    return jnp.fft.fft(jnp.pad(c, pad), axis=0)


def subset_decode_matrix(generator: jax.Array, subset: jax.Array) -> jax.Array:
    """Inverse of the ``m x m`` generator submatrix picked by ``subset``."""
    sub = jnp.take(generator, subset, axis=0)
    return jnp.linalg.inv(sub)


def decode_from_subset(
    generator: jax.Array, b: jax.Array, subset: jax.Array
) -> jax.Array:
    """Recover the ``m`` message shards from the coded results in ``subset``.

    ``b``: ``(n, *payload)`` worker results (rows outside ``subset`` are
    ignored, so stragglers may hold garbage).  ``subset``: ``(m,)`` integer
    indices of the workers that responded.  Static-shape, jit-safe.
    """
    m = generator.shape[1]
    if subset.shape[0] != m:
        raise ValueError(f"subset must have exactly m={m} entries")
    flat, payload = _flatten_payload(b)
    rows = jnp.take(flat, subset, axis=0)
    sub = jnp.take(generator, subset, axis=0).astype(flat.dtype)
    decoded = jnp.linalg.solve(sub, rows)
    return decoded.reshape((m,) + payload)


def first_available(mask: jax.Array, m: int) -> jax.Array:
    """Indices of the first ``m`` available workers (stable order).

    ``mask``: boolean ``(n,)``, True = result arrived.  The master waits for
    the *fastest* m workers; inside one SPMD program we model arrival order
    by the mask and pick the first m set entries.  Shapes stay static.
    """
    # argsort of (not mask) is stable: available indices first, in order.
    order = jnp.argsort(jnp.logical_not(mask), stable=True)
    return order[:m]


def decode_masked(generator: jax.Array, b: jax.Array, mask: jax.Array) -> jax.Array:
    """Decode from whichever ``m`` workers are available per ``mask``."""
    m = generator.shape[1]
    subset = first_available(mask, m)
    return decode_from_subset(generator, b, subset)


# -- fast decode (§III-C Reed-Solomon mapping) --------------------------------
#
# Worker k's result per payload column is the message polynomial
# ``P(z) = sum_i c_i z^i`` evaluated at the root of unity ``omega^k``
# (encode == zero-padded DFT, see :func:`encode_dft`).  Decoding from a
# subset S of workers is therefore polynomial interpolation at the nodes
# ``{omega^k : k in S}``, which the Lagrange/Forney erasure formula turns
# into transforms instead of a dense solve:
#
#     A(z)   = prod_{k in S} (z - omega^k)           (erasure locator)
#     g_k    = b_k / A'(omega^k)
#     P(z)   = sum_k g_k * A(z) / (z - omega^k)
#
# Collecting coefficients: with ``G_d = sum_{k in S} g_k omega^{kd}`` (a
# length-n DFT of the g's scattered onto the worker grid, d < m) and ``a_t``
# the coefficients of A, ``c_u = sum_{t>u} a_t G_{t-1-u}`` -- a short
# correlation computed by one more length-2m FFT.  Total O(n log n) per
# payload column = O(s log N) per transform, vs O(m^2) per column (plus an
# O(m^3) factor) for the Vandermonde solve.  For S = all n workers the
# formula degenerates to ``c = ifft(b)[:m]`` -- the exact inverse of the
# zero-padded DFT encode.


def lagrange_decode_coeffs(
    subset: jax.Array, n: int, m: int, dtype=jnp.complex128
) -> tuple[jax.Array, jax.Array]:
    """Payload-independent decode precompute for the nodes in ``subset``.

    Returns ``(a, dinv)``: ``a`` (m+1,) ascending coefficients of the
    erasure locator ``A(z) = prod_{k in subset}(z - omega^k)`` and
    ``dinv`` (m,) = ``1 / A'(omega^{subset_j})``.  jit-safe for traced
    subsets (fixed shapes, ``m`` small).
    """
    nodes = jnp.take(rs_nodes(n, dtype), subset)
    diff = nodes[:, None] - nodes[None, :]
    diff = diff.at[jnp.diag_indices(m)].set(1.0)
    dinv = 1.0 / jnp.prod(diff, axis=1)

    # Multiply the linear factors in a shuffled (static) order: building the
    # product in arc order walks monotonically around the circle and the
    # partial-product coefficients blow up before cancelling (catastrophic
    # even for the full circle, whose true locator is just z^n - 1).
    # Balanced order keeps partial products O(1).
    perm = jnp.asarray(np.random.default_rng(0).permutation(m))

    def mul_linear(i, a):
        # a(z) <- a(z) * (z - nodes[perm[i]]); top slot of ``a`` is still 0.
        shifted = jnp.roll(a, 1).at[0].set(0.0)
        return shifted - nodes[perm[i]] * a

    a0 = jnp.zeros((m + 1,), dtype).at[0].set(1.0)
    a = jax.lax.fori_loop(0, m, mul_linear, a0)
    return a, dinv


# -- structured subset inversion (device-resident decode matrices) ------------
#
# ``inv(G[subset])`` has a CLOSED FORM: column j of the inverse holds the
# coefficients of the Lagrange basis polynomial ``L_j(z) = A(z) / ((z -
# x_j) A'(x_j))`` at the subset's nodes (``V[j, i] = x_j^i``, so ``sum_i
# inv[i, j] z^i`` must be 1 at ``x_j`` and 0 at the other nodes).  With the
# locator ``A(z) = prod_k (z - x_k)`` that is O(m^2) of elementwise work and
# small matmuls -- no ``linalg.inv``, no host round-trip, jit/vmap-safe --
# which is what lets the service build per-request decode matrices INSIDE
# the bucket executor (DESIGN.md §8).  Deflation is evaluated in the
# division-free suffix form ``q_i^{(j)} = sum_{d>=0} a_{i+1+d} x_j^d`` so
# every step is a (static-shape) contraction; the node powers are exact
# (``x_j^d = omega^{subset_j * d mod n}``), never a running product.


# Largest m routed to the device-resident Lagrange decode automatically.
# The construction is componentwise-stable (error tracks the subset's own
# interpolation conditioning, like the host inverse); past m ~ 32 the
# f32 planes the kernels decode in are the binding constraint for
# adversarial (contiguous-arc) subsets, so the service falls back to the
# host complex128 LRU there (serving/decode_cache.py).
LAGRANGE_MAX_M = 32


def lagrange_inverse(subset: jax.Array, n: int, dtype=jnp.complex64) -> jax.Array:
    """Closed-form ``inv(rs_generator(n, m)[subset])`` -- O(m^2), jit-safe.

    ``subset``: ``(m,)`` integer worker indices (distinct).  Returns the
    ``(m, m)`` compact decode matrix.  Matches ``jnp.linalg.inv`` of the
    subset generator to within the subset's interpolation conditioning.
    """
    m = subset.shape[0]
    subset = subset.astype(jnp.int32)
    # exact node powers P[j, d] = x_j^d via the root-of-unity closed form
    ang = (subset[:, None] * jnp.arange(m, dtype=jnp.int32)[None, :]) % n
    p = jnp.exp(-2j * jnp.pi * ang / n).astype(dtype)
    nodes = jnp.exp(-2j * jnp.pi * subset / n).astype(dtype)
    # locator A(z) = prod (z - x_j), multiplied in balanced (shuffled static)
    # order -- same stability argument as lagrange_decode_coeffs
    perm = np.random.default_rng(0).permutation(m)
    a = jnp.zeros((m + 1,), dtype).at[0].set(1.0)
    for i in perm:
        shifted = jnp.roll(a, 1).at[0].set(0.0)
        a = shifted - nodes[i] * a
    # deflation, suffix form: T[i, d] = a[i + d + 1] (0 past the end), then
    # q[i, j] = sum_d T[i, d] x_j^d are the coefficients of A(z)/(z - x_j)
    ii, dd = np.indices((m, m))
    hi = ii + dd + 1
    t = jnp.take(a, jnp.asarray(np.minimum(hi, m))) * jnp.asarray(hi <= m)
    q = t @ p.T
    # A'(x_j) = Q_j(x_j) = sum_i q[i, j] x_j^i
    aprime = jnp.einsum("ij,ji->j", q, p)
    return q / aprime[None, :]


def lagrange_decode_matrix(mask: jax.Array, m: int, dtype=jnp.complex64) -> jax.Array:
    """Per-mask ``(m, n)`` SCATTER decode matrix, built on device.

    ``mask``: boolean ``(n,)`` worker availability.  Columns of the first
    ``m`` available workers hold ``inv(G[subset])``; straggler columns are
    zero, so ``c_hat = D @ b`` never reads their (garbage) rows -- the same
    contract as ``DecodeMatrixCache.matrix`` with no host inversion and no
    LRU side channel.
    """
    mask = jnp.asarray(mask)
    n = mask.shape[0]
    subset = first_available(mask, m).astype(jnp.int32)
    inv = lagrange_inverse(subset, n, dtype)
    # scatter as a one-hot contraction (vmap/kernel-friendly: no .at[] write)
    onehot = (subset[:, None] == jnp.arange(n)[None, :]).astype(inv.real.dtype)
    return inv @ onehot.astype(inv.dtype)


def lagrange_decode_matrices(masks: jax.Array, m: int, dtype=jnp.complex64) -> jax.Array:
    """Batched :func:`lagrange_decode_matrix`: ``(B, n)`` -> ``(B, m, n)``."""
    return jax.vmap(lambda mk: lagrange_decode_matrix(mk, m, dtype))(masks)


def decode_ifft(b: jax.Array, subset: jax.Array, n: Optional[int] = None) -> jax.Array:
    """O(s log N) subset decode via the inverse zero-padded DFT mapping.

    ``b``: ``(n, *payload)`` worker results (rows outside ``subset`` are
    never read, so stragglers may hold garbage/NaN); ``subset``: ``(m,)``
    responder indices.  Exact in exact arithmetic for ANY subset (the
    Lagrange erasure formula above); in floats its error tracks the
    subset's intrinsic interpolation conditioning, which for contiguous
    arcs grows exponentially in ``m`` (the dense solve degrades on the
    same arcs, only more gracefully) -- hence :func:`decode_auto` only
    routes here for small ``m`` or the exactly-stable full set.
    """
    n = b.shape[0] if n is None else n
    m = subset.shape[0]
    flat, payload = _flatten_payload(b)
    dtype = flat.dtype
    if m == n:
        # full response set (any subset is a permutation of it): the literal
        # inverse of the zero-padded DFT encode -- exact, stable at any m,
        # one FFT
        c = jnp.fft.ifft(flat.T, axis=-1)[:, :m].T
        return c.reshape((m,) + payload).astype(dtype)
    a, dinv = lagrange_decode_coeffs(subset, n, m, dtype)
    # work in (P, n) layout so both FFTs run along the contiguous last axis
    g = jnp.take(flat, subset, axis=0).T * dinv[None, :]         # (P, m)
    g_grid = jnp.zeros((flat.shape[1], n), dtype).at[:, subset].set(g)
    big = jnp.fft.fft(g_grid, axis=-1)[:, :m]                    # G_d, d < m
    # c_u = sum_t a_t G_{t-1-u} == linear_conv(a, reverse(G))[u + m]
    two_m = 2 * m
    a_hat = jnp.fft.fft(a, n=two_m)
    conv = jnp.fft.ifft(
        a_hat[None, :] * jnp.fft.fft(big[:, ::-1], n=two_m, axis=-1), axis=-1)
    c = conv[:, m:two_m].T
    return c.reshape((m,) + payload).astype(dtype)


def is_contiguous_subset(subset, n: int) -> bool:
    """Static check: does ``subset`` form one contiguous run mod ``n``?"""
    got = np.zeros(n, bool)
    got[np.asarray(subset) % n] = True
    boundaries = int(np.sum(got & ~np.roll(got, -1)))
    return boundaries <= 1


def _contiguous_flag(subset: jax.Array, n: int) -> jax.Array:
    """Traced version of :func:`is_contiguous_subset` (returns a scalar bool)."""
    got = jnp.zeros((n,), bool).at[subset].set(True)
    return jnp.sum(got & ~jnp.roll(got, -1)) <= 1


# Largest m for which the transform decode is routed to automatically on a
# contiguous (non-full) arc: up to here its float error stays within a small
# factor of the dense solve's on the same (intrinsically worsening) arcs.
IFFT_AUTO_MAX_M = 8


def decode_auto(
    generator: jax.Array, b: jax.Array, subset: jax.Array, *, method: str = "auto"
) -> jax.Array:
    """Subset decode with fast-path dispatch (DESIGN.md §4).

    ``method``: ``"solve"`` forces the dense Vandermonde solve, ``"ifft"``
    forces the O(s log N) transform decode, ``"auto"`` picks ``ifft`` when
    it is numerically safe -- the full set (m == N, exact at any size) or a
    contiguous-mod-N subset with ``m <= IFFT_AUTO_MAX_M`` -- and the
    backward-stable ``solve`` otherwise.  With a concrete subset the choice
    is made at trace time; with a traced subset (e.g. ``first_available``
    of a runtime mask) it becomes a ``lax.cond`` (under ``vmap`` that
    select executes both branches -- batched callers resolve ``auto`` to
    ``solve`` instead, see plan.py).
    """
    n, m = generator.shape
    if subset.shape[0] != m:
        raise ValueError(f"subset must have exactly m={m} entries")
    if method == "solve":
        return decode_from_subset(generator, b, subset)
    if method == "ifft":
        return decode_ifft(b, subset, n)
    if method != "auto":
        raise ValueError(f"unknown decode method {method!r}")
    if m == n:
        return decode_ifft(b, subset, n)
    if m > IFFT_AUTO_MAX_M:
        return decode_from_subset(generator, b, subset)
    if not isinstance(subset, jax.core.Tracer):
        if is_contiguous_subset(subset, n):
            return decode_ifft(b, subset, n)
        return decode_from_subset(generator, b, subset)
    return jax.lax.cond(
        _contiguous_flag(subset, n),
        lambda bb, ss: decode_ifft(bb, ss, n),
        lambda bb, ss: decode_from_subset(generator, bb, ss),
        b,
        subset,
    )


@functools.partial(jax.jit, static_argnums=(0, 1))
def _condition_numbers(n: int, m: int) -> jax.Array:  # pragma: no cover - util
    """Condition number of every contiguous m-subset (diagnostic helper)."""
    g = rs_generator(n, m, jnp.complex128)
    idx = (jnp.arange(n)[:, None] + jnp.arange(m)[None, :]) % n
    subs = g[idx]  # (n, m, m)
    return jnp.linalg.cond(subs)
