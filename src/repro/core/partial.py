"""Partial-work coded FFT: stragglers contribute PREFIXES, not holes.

Wang et al. (arXiv 1804.09791) show the MDS construction's blind spot:
a worker that finishes 90% of its shard before the deadline contributes
NOTHING -- the master discards partial work wholesale.  The fix is to make
partial work *sequentially useful*: split each worker's job into ``r``
fragments, each a codeword row of a FINER code, so every finished fragment
is one more decodable symbol.

Construction (the paper's idea specialised to the coded-FFT pipeline):

  1. interleave ``x`` into ``m*r`` message shards of length ``s/(m*r)``
     (the same downsampling map as :class:`~repro.core.coded_fft.CodedFFT`,
     at fragment granularity);
  2. encode with the ``(N*r, m*r)`` complex-RS code on the ``(N*r)``-th
     roots of unity (:func:`repro.core.mds.rs_generator`) -- one zero-padded
     DFT, exactly like the base plan;
  3. worker ``w`` owns coded rows ``{f*N + w : f < r}`` and transforms them
     IN ORDER ``f = 0, 1, ...`` -- a worker cut off at any point has
     produced a prefix of complete fragments;
  4. the master decodes as soon as ANY ``m*r`` fragments (across all
     workers) have arrived -- every subset of distinct roots-of-unity rows
     is a Vandermonde system, so the *coverage condition* is a pure count:
     ``total fragments >= m*r`` (Wang et al.'s bound, here with every
     fragment carrying equal weight 1/r of a shard);
  5. recombine the ``m*r`` decoded message transforms with the standard
     twiddle + DFT stage (:func:`repro.core.recombine.recombine` is
     shard-count generic).

``r = 1`` degenerates to the base MDS plan.  The recovery threshold in
WORKER units stays ``m`` (any ``m`` complete workers give ``m*r``
fragments); the win is that ``m`` *complete* workers are no longer
required -- e.g. ``2m`` workers at half speed also decode.  Per-worker
storage, compute, and total wire payload are unchanged (``payload_scale
= 1``): fragments change the *granularity* of usefulness, not the load.

Decode extends ``mds.decode_auto`` with the fragment-weighted system: the
flat row index of fragment ``f`` of worker ``w`` is ``f*N + w``, fragment
masks ``(N, r)`` flatten to row masks of length ``N*r``, and
``first_available`` + ``decode_auto`` run over the ``(N*r, m*r)``
generator unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mds
from repro.core.interleave import interleave
from repro.core.plan import MDSPlanBase, batch_shape
from repro.core.recombine import recombine

__all__ = ["CodedPartialFFT"]


@dataclasses.dataclass(frozen=True)
class CodedPartialFFT(MDSPlanBase):
    """1-D coded FFT with ``r`` sequentially-useful fragments per worker.

    Args:
      s: transform length.
      m: storage fraction parameter -- each worker stores/processes s/m.
      n_workers: N >= m workers.
      r: fragments per worker; the code is ``(N*r, m*r)`` and the master
        decodes from any ``m*r`` finished fragments.
      dtype: complex dtype of the computation.
      backend: ``"reference"`` (default) or ``"kernel"``.  The fused
        planar bucket kernels are MDS-layout-specific, so this plan runs
        the jnp path by default; ``"kernel"`` still routes the per-fragment
        worker DFT through the Pallas four-step for c64.
    """

    s: int
    m: int
    n_workers: int
    r: int = 2
    dtype: jnp.dtype = jnp.complex64
    backend: str = "reference"

    def __post_init__(self):
        if self.r < 1:
            raise ValueError(f"need r >= 1 fragments, got r={self.r}")
        if self.s % (self.m * self.r) != 0:
            raise ValueError(
                f"m*r={self.m * self.r} must divide s={self.s} "
                f"(fragment shards must tile the input)")
        if self.n_workers < self.m:
            raise ValueError(
                f"need N >= m for recoverability, got N={self.n_workers} "
                f"m={self.m}")

    # -- code geometry -------------------------------------------------------
    @property
    def frag_len(self) -> int:
        """Symbols per fragment: s / (m*r)."""
        return self.s // (self.m * self.r)

    @property
    def shard_len(self) -> int:
        """Symbols per worker (all r fragments): s/m, same as base MDS."""
        return self.s // self.m

    @property
    def fragments(self) -> int:
        return self.r

    @property
    def fragments_needed(self) -> int:
        """The Wang-style coverage condition: decode iff this many
        fragments (across all workers) have arrived."""
        return self.m * self.r

    @property
    def code_rows(self) -> int:
        return self.n_workers * self.r

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return (self.r, self.frag_len)

    @property
    def recovery_threshold(self) -> int:
        """In WORKER units: any m complete workers suffice (their m*r
        fragments meet the coverage condition)."""
        return self.m

    @property
    def payload_scale(self) -> float:
        """Total wire payload matches the base MDS plan (fragments change
        usefulness granularity, not load)."""
        return 1.0

    @property
    def fragment_fractions(self) -> np.ndarray:
        """Fraction of a worker's full shard time at which each fragment
        completes (fragments are equal-cost and sequential): (f+1)/r."""
        return np.arange(1, self.r + 1) / self.r

    @property
    def generator(self) -> jax.Array:
        """The FLAT ``(N*r, m*r)`` fragment-code generator.  Row ``f*N + w``
        is fragment ``f`` of worker ``w`` -- deliberately flat (not the
        MDSPlan ``(N, m)`` shape) because decode operates in row space."""
        return mds.rs_generator(self.code_rows, self.fragments_needed,
                                self.dtype)

    @property
    def decode_generator(self) -> jax.Array:
        return self.generator

    @property
    def worker_encode_tensor(self) -> jax.Array:
        """Per-worker encode rows ``(N, r, m*r)``:
        ``tensor[w, f] = generator[f*N + w]`` -- the distributed runtime's
        per-device encode contraction."""
        return jnp.swapaxes(
            self.generator.reshape(self.r, self.n_workers,
                                   self.fragments_needed), 0, 1)

    # -- stage cores ---------------------------------------------------------
    def _message1(self, x: jax.Array) -> jax.Array:
        return interleave(x.astype(self.dtype), self.fragments_needed)

    def _encode1(self, x: jax.Array) -> jax.Array:
        # one zero-padded DFT over the (N*r)-th roots evaluates all N*r
        # fragment rows; regroup flat rows f*N + w into (N, r) per-worker
        # fragment stacks
        c = self._message1(x)                              # (m*r, L')
        a = mds.encode_dft(c, self.code_rows)              # (N*r, L')
        a = a.reshape(self.r, self.n_workers, self.frag_len)
        return jnp.swapaxes(a, 0, 1).astype(self.dtype)    # (N, r, L')

    def encode(self, x: jax.Array) -> jax.Array:
        # always the DFT encode: MDSPlanBase's kernel branch assumes the
        # (N, m) generator layout, which this plan's flat row code is not
        return self._map_batched(
            self._encode1, x, len(self.input_shape), "plan input")

    def worker_compute(self, a: jax.Array) -> jax.Array:
        """Per-fragment DFT along the last axis; the (r, L') trailing axes
        map each fragment independently, so a worker interrupted after
        fragment f has rows 0..f complete and rows f+1.. garbage."""
        return self._fft1_worker(a)

    def _postdecode1(self, c_hat: jax.Array) -> jax.Array:
        return recombine(c_hat, self.s)                    # m*r shards

    def postdecode(self, c_hat: jax.Array) -> jax.Array:
        return self._map_batched(self._postdecode1, c_hat, 2,
                                 "decoded shards")

    # -- fragment-weighted decode --------------------------------------------
    def _row_mask(self, batch: tuple[int, ...], subset, mask,
                  fragment_mask) -> jax.Array:
        """Resolve subset / worker mask / fragment mask to a flat row mask
        ``(*B, N*r)`` in ``f*N + w`` row order."""
        n, r = self.n_workers, self.r
        if fragment_mask is not None:
            fm = jnp.asarray(fragment_mask)
            fm = jnp.broadcast_to(fm, batch + (n, r))
            return jnp.swapaxes(fm, -1, -2).reshape(batch + (n * r,))
        if mask is not None:
            wm = jnp.broadcast_to(jnp.asarray(mask), batch + (n,))
        elif subset is not None:
            sub = jnp.asarray(subset)
            wm = jnp.zeros((n,), bool).at[sub].set(True)
            wm = jnp.broadcast_to(wm, batch + (n,))
        else:
            wm = jnp.broadcast_to(jnp.arange(n) < self.m, batch + (n,))
        return (jnp.broadcast_to(wm[..., None, :], batch + (r, n))
                .reshape(batch + (n * r,)))

    def _flat_rows(self, b: jax.Array) -> jax.Array:
        """(*B, N, r, L') worker results -> (*B, N*r, L') flat code rows."""
        batch = b.shape[:-3]
        bf = jnp.swapaxes(b, -2, -3)                       # (*B, r, N, L')
        return bf.reshape(batch + (self.code_rows, self.frag_len))

    def decodable(self, mask: Optional[np.ndarray] = None,
                  fragment_mask: Optional[np.ndarray] = None) -> bool:
        """The executable coverage condition: total finished fragments
        >= m*r (a worker mask counts r fragments per live worker)."""
        if fragment_mask is not None:
            return int(np.asarray(fragment_mask).sum()) >= self.fragments_needed
        if mask is None:
            return self.n_workers >= self.m
        return int(np.asarray(mask).sum()) * self.r >= self.fragments_needed

    def decode(self, b: jax.Array, subset=None, mask=None, *,
               fragment_mask=None, method: str = "auto") -> jax.Array:
        """Worker results -> output from any fragment set meeting the
        coverage condition.

        Exactly one of ``subset`` (worker indices), ``mask`` (worker
        availability ``(*B, N)``), or ``fragment_mask`` (per-fragment
        availability ``(*B, N, r)`` -- True means fragment f of worker w
        finished) may be given.  Partial workers hand over their finished
        prefix; unfinished fragment rows are never read (they may hold
        NaN), which the property suite asserts.
        """
        if sum(x is not None for x in (subset, mask, fragment_mask)) > 1:
            raise ValueError(
                "pass at most one of subset / mask / fragment_mask")
        k = self.fragments_needed
        batch = batch_shape(b, 3, "worker results")
        rows_mask = self._row_mask(batch, subset, mask, fragment_mask)
        bf = self._flat_rows(b)
        gen = self.generator

        def decode1(bi, rmk, mth):
            rows = mds.first_available(rmk, k)
            c_hat = mds.decode_auto(gen, bi, rows, method=mth)
            return self._postdecode1(c_hat)

        if not batch:
            return decode1(bf, rows_mask, method)
        flat = bf.reshape((-1,) + bf.shape[len(batch):])
        mflat = rows_mask.reshape(flat.shape[0], -1)
        if flat.shape[0] == 1:
            # batch of one (the service's single-submit bucket): keep
            # decode_auto's dispatch a static choice
            out = decode1(flat[0], mflat[0], method)
            return out.reshape(batch + out.shape)
        # per-request row sets are traced under vmap -- resolve "auto" to
        # the backward-stable solve (same rule as MDSPlanBase.decode)
        mth = "solve" if method == "auto" else method
        out = jax.vmap(lambda bi, mk: decode1(bi, mk, mth))(flat, mflat)
        return out.reshape(batch + out.shape[1:])

    def run(self, x: jax.Array, subset=None, mask=None, *,
            fragment_mask=None, method: str = "auto") -> jax.Array:
        b = self.worker_compute(self.encode(x))
        return self.decode(b, subset=subset, mask=mask,
                           fragment_mask=fragment_mask, method=method)
