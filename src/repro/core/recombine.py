"""Master-side recombination for coded FFT (paper eq. 23/24).

Given the decoded sub-transforms ``C`` with ``C[k] = DFT_{s/m}(c_k)``,
the final output is

    X[i + j*(s/m)] = sum_k C[k, i] * omega_s^{ik} * omega_m^{jk}

i.e. an elementwise *twiddle* ``C[k, i] * omega_s^{ik}`` followed by a batch
of ``s/m`` independent length-``m`` DFTs along the shard axis.  This is the
final butterfly stage of Cooley-Tukey, expressed as a dense length-``m``
DFT so it maps onto an MXU matmul (see kernels/recombine.py).

The same butterfly serves three directions (DESIGN.md §7):

* ``sign=-1`` (default) -- the forward transform;
* ``sign=+1`` with a ``1/m`` scale -- the inverse transform, whose worker
  stage is ``ifft`` (each sub-transform carries its own ``1/L``);
* :func:`recombine_half` -- the real-input forward transform, which only
  materializes the non-redundant half spectrum ``X[0 .. s/2]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["twiddle", "dft_matrix", "recombine", "recombine_half",
           "recombine_nd"]


def dft_matrix(m: int, dtype=jnp.complex64, sign: float = -1.0) -> jax.Array:
    """Dense ``m x m`` DFT matrix ``F[j, k] = exp(sign*2j*pi*j*k/m)``."""
    jk = jnp.outer(jnp.arange(m), jnp.arange(m))
    return jnp.exp(sign * 2j * jnp.pi * jk / m).astype(dtype)


def twiddle(s: int, m: int, dtype=jnp.complex64,
            sign: float = -1.0) -> jax.Array:
    """Twiddle plane ``W[k, i] = omega_s^{ik}``, shape ``(m, s/m)``."""
    ell = s // m
    ki = jnp.outer(jnp.arange(m), jnp.arange(ell))
    return jnp.exp(sign * 2j * jnp.pi * ki / s).astype(dtype)


def recombine(c_hat: jax.Array, s: int, sign: float = -1.0) -> jax.Array:
    """``(m, s/m)`` decoded sub-transforms -> length-``s`` output ``X``.

    ``sign=-1`` recombines forward sub-DFTs; ``sign=+1`` recombines inverse
    sub-DFTs (caller applies the remaining ``1/m`` normalization -- the
    per-shard ``1/L`` already lives in the workers' ``ifft``).
    """
    m = c_hat.shape[0]
    w = twiddle(s, m, c_hat.dtype, sign)
    x_mat = dft_matrix(m, c_hat.dtype, sign) @ (c_hat * w)  # (m, s/m)
    return x_mat.reshape(s)


def recombine_half(c_full: jax.Array, s: int) -> jax.Array:
    """Symmetry-aware butterfly: Hermitian sub-transforms -> ``X[0..s/2]``.

    ``c_full``: ``(m, L)`` decoded sub-transforms of REAL message shards
    (each Hermitian along its length-``L`` axis).  Only the DFT rows
    ``j <= m//2`` are computed -- output index ``u = i + j*L <= s/2`` never
    touches higher rows -- then the flattened block is cut to the
    ``s//2 + 1`` non-redundant bins.  The discarded half is recoverable as
    ``X[s-u] = conj(X[u])``.
    """
    m, ell = c_full.shape
    w = twiddle(s, m, c_full.dtype)
    rows = m // 2 + 1
    f_half = dft_matrix(m, c_full.dtype)[:rows]
    x_mat = f_half @ (c_full * w)  # (m//2 + 1, s/m)
    return x_mat.reshape(rows * ell)[: s // 2 + 1]


def recombine_nd(
    c_hat: jax.Array, shape: tuple[int, ...], factors: tuple[int, ...]
) -> jax.Array:
    """n-D recombination (paper eq. 31).

    ``c_hat``: ``(m, L_0, ..., L_{n-1})`` decoded sub-transforms indexed by
    the row-major shard tuple ``(k_0..k_{n-1})``;  returns the full n-D
    transform ``T`` of shape ``shape``.

    T[..., i_d + j_d*L_d, ...] = sum_{k_0..k} C[(k), (i)] *
        prod_d omega_{s_d}^{i_d k_d} * omega_{m_d}^{j_d k_d}
    """
    n = len(shape)
    ells = tuple(sd // md for sd, md in zip(shape, factors))
    c = c_hat.reshape(tuple(factors) + ells)  # (m_0..m_{n-1}, L_0..L_{n-1})
    for d in range(n):
        md, sd, ld = factors[d], shape[d], ells[d]
        # twiddle along (k_d, i_d): omega_{s_d}^{i_d * k_d}
        tw = jnp.exp(
            -2j * jnp.pi * jnp.outer(jnp.arange(md), jnp.arange(ld)) / sd
        ).astype(c_hat.dtype)
        bshape = [1] * (2 * n)
        bshape[d] = md
        bshape[n + d] = ld
        c = c * tw.reshape(bshape)
        # length-m_d DFT along axis d:  k_d -> j_d
        f = dft_matrix(md, c_hat.dtype)
        c = jnp.tensordot(f, c, axes=([1], [d]))
        c = jnp.moveaxis(c, 0, d)
    # now c[(j_0..j_{n-1}), (i_0..i_{n-1})] holds T[..., i_d + j_d*L_d, ...].
    # That layout is an interleave of T with factors L_d (outer index j in m_d,
    # inner index i in L_d), so invert it with deinterleave_nd(factors=ells).
    from repro.core.interleave import deinterleave_nd

    c = jnp.transpose(c, list(range(n, 2 * n)) + list(range(n)))  # (i.., j..)
    return deinterleave_nd(c.reshape((-1,) + tuple(factors)), ells, shape)
