"""Master-side recombination for coded FFT (paper eq. 23/24).

Given the decoded sub-transforms ``C`` with ``C[k] = DFT_{s/m}(c_k)``,
the final output is

    X[i + j*(s/m)] = sum_k C[k, i] * omega_s^{ik} * omega_m^{jk}

i.e. an elementwise *twiddle* ``C[k, i] * omega_s^{ik}`` followed by a batch
of ``s/m`` independent length-``m`` DFTs along the shard axis.  This is the
final butterfly stage of Cooley-Tukey, expressed as a dense length-``m``
DFT so it maps onto an MXU matmul (see kernels/recombine.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["twiddle", "dft_matrix", "recombine", "recombine_nd"]


def dft_matrix(m: int, dtype=jnp.complex64, sign: float = -1.0) -> jax.Array:
    """Dense ``m x m`` DFT matrix ``F[j, k] = exp(sign*2j*pi*j*k/m)``."""
    jk = jnp.outer(jnp.arange(m), jnp.arange(m))
    return jnp.exp(sign * 2j * jnp.pi * jk / m).astype(dtype)


def twiddle(s: int, m: int, dtype=jnp.complex64) -> jax.Array:
    """Twiddle plane ``W[k, i] = omega_s^{ik}``, shape ``(m, s/m)``."""
    ell = s // m
    ki = jnp.outer(jnp.arange(m), jnp.arange(ell))
    return jnp.exp(-2j * jnp.pi * ki / s).astype(dtype)


def recombine(c_hat: jax.Array, s: int) -> jax.Array:
    """``(m, s/m)`` decoded sub-transforms -> length-``s`` output ``X``."""
    m = c_hat.shape[0]
    w = twiddle(s, m, c_hat.dtype)
    x_mat = dft_matrix(m, c_hat.dtype) @ (c_hat * w)  # (m, s/m)
    return x_mat.reshape(s)


def recombine_nd(
    c_hat: jax.Array, shape: tuple[int, ...], factors: tuple[int, ...]
) -> jax.Array:
    """n-D recombination (paper eq. 31).

    ``c_hat``: ``(m, L_0, ..., L_{n-1})`` decoded sub-transforms indexed by
    the row-major shard tuple ``(k_0..k_{n-1})``;  returns the full n-D
    transform ``T`` of shape ``shape``.

    T[..., i_d + j_d*L_d, ...] = sum_{k_0..k} C[(k), (i)] *
        prod_d omega_{s_d}^{i_d k_d} * omega_{m_d}^{j_d k_d}
    """
    n = len(shape)
    ells = tuple(sd // md for sd, md in zip(shape, factors))
    c = c_hat.reshape(tuple(factors) + ells)  # (m_0..m_{n-1}, L_0..L_{n-1})
    for d in range(n):
        md, sd, ld = factors[d], shape[d], ells[d]
        # twiddle along (k_d, i_d): omega_{s_d}^{i_d * k_d}
        tw = jnp.exp(
            -2j * jnp.pi * jnp.outer(jnp.arange(md), jnp.arange(ld)) / sd
        ).astype(c_hat.dtype)
        bshape = [1] * (2 * n)
        bshape[d] = md
        bshape[n + d] = ld
        c = c * tw.reshape(bshape)
        # length-m_d DFT along axis d:  k_d -> j_d
        f = dft_matrix(md, c_hat.dtype)
        c = jnp.tensordot(f, c, axes=([1], [d]))
        c = jnp.moveaxis(c, 0, d)
    # now c[(j_0..j_{n-1}), (i_0..i_{n-1})] holds T[..., i_d + j_d*L_d, ...].
    # That layout is an interleave of T with factors L_d (outer index j in m_d,
    # inner index i in L_d), so invert it with deinterleave_nd(factors=ells).
    from repro.core.interleave import deinterleave_nd

    c = jnp.transpose(c, list(range(n, 2 * n)) + list(range(n)))  # (i.., j..)
    return deinterleave_nd(c.reshape((-1,) + tuple(factors)), ells, shape)
