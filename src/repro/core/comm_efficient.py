"""Communication-efficient coded FFT: trade recovery threshold for wire.

Jeong et al. (arXiv 1805.09891) observe that in the MDS construction each
worker ships its FULL transformed shard (s/m symbols) even though the
master only needs s total -- when the wire, not the FLOPs, is the
bottleneck, the coded round pays an m-fold communication overhead.  Their
fix: each worker FOLDS its result before shipping, sending ``1/q`` of the
payload, at the price of a higher recovery threshold ``m*q``.

Construction, on top of the standard (N, m) coded-FFT pipeline:

  1. encode exactly as :class:`~repro.core.coded_fft.CodedFFT`: worker
     ``k`` stores coded shard ``a_k = sum_i omega_N^{ki} c_i`` (length
     ``L = s/m``);
  2. worker ``k`` computes the full transform ``b_k = fft(a_k)`` (same
     FLOPs as the base plan), splits it into ``q`` contiguous blocks
     ``b_k^{(t)}`` of length ``L/q``, and ships only the fold

        ``d_k = sum_t omega_N^{k*m*t} b_k^{(t)}``        (L/q symbols);

  3. because ``b_k^{(t)} = sum_i omega_N^{ki} C_i^{(t)}`` with
     ``C_i = fft(c_i)``, the fold's exponents ``{i + m*t}`` sweep
     ``0..m*q-1`` bijectively, so ``d_k`` is row ``k`` of the WIDER
     ``(N, m*q)`` RS code on message ``u_{i+m*t} = C_i^{(t)}``;
  4. the master decodes ``u`` from ANY ``m*q`` responders (every
     ``m*q``-subset of the roots-of-unity Vandermonde is invertible,
     needs ``m*q <= N``), un-permutes ``u -> C`` (a reshape/transpose),
     and recombines as usual.

``q = 1`` degenerates to the base MDS plan.  Per-worker wire payload is
``L/q`` -- ``payload_scale = 1/q`` under :class:`~repro.distributed
.straggler.StragglerModel`'s wire model -- while the threshold rises from
``m`` to ``m*q``: the plan wins exactly when ``wire_frac`` is high and
loses when compute dominates (the master now waits for the ``m*q``-th
order statistic).  ``benchmarks/bench_comm_load.py`` races the crossover.

Decode is inherited wholesale from :class:`~repro.core.plan.MDSPlanBase`
via the ``decode_generator`` / ``decode_width`` hooks -- the fold changed
*which* linear system the responders are rows of, not the shape of the
decode problem.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import mds
from repro.core.interleave import interleave
from repro.core.plan import MDSPlanBase
from repro.core.recombine import recombine

__all__ = ["CodedCommEffFFT"]


@dataclasses.dataclass(frozen=True)
class CodedCommEffFFT(MDSPlanBase):
    """1-D coded FFT shipping a ``1/q`` folded payload per worker.

    Args:
      s: transform length.
      m: storage fraction parameter -- each worker stores/computes s/m.
      n_workers: N >= m*q workers (the widened code needs m*q rows).
      q: fold factor; per-worker wire payload is ``s/(m*q)`` and the
        recovery threshold is ``m*q``.
      dtype: complex dtype of the computation.
      backend: ``"reference"`` (default) or ``"kernel"`` -- the fused
        bucket kernels assume the ship-the-full-shard MDS layout, so this
        plan runs the jnp path by default; ``"kernel"`` still routes the
        worker DFT through the Pallas four-step for c64.
    """

    s: int
    m: int
    n_workers: int
    q: int = 2
    dtype: jnp.dtype = jnp.complex64
    backend: str = "reference"

    def __post_init__(self):
        if self.q < 1:
            raise ValueError(f"need q >= 1, got q={self.q}")
        if self.s % self.m != 0:
            raise ValueError(f"m={self.m} must divide s={self.s}")
        if (self.s // self.m) % self.q != 0:
            raise ValueError(
                f"q={self.q} must divide the shard length "
                f"s/m={self.s // self.m} (the fold splits it into q blocks)")
        if self.n_workers < self.m * self.q:
            raise ValueError(
                f"need N >= m*q for recoverability, got N={self.n_workers} "
                f"m*q={self.m * self.q}")

    # -- code geometry -------------------------------------------------------
    @property
    def shard_len(self) -> int:
        """Symbols each worker stores and transforms: s/m (unchanged)."""
        return self.s // self.m

    @property
    def payload_len(self) -> int:
        """Symbols each worker SHIPS: s/(m*q)."""
        return self.shard_len // self.q

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        """What a worker SHIPS (the folded payload) -- the master-side
        decode shape contract."""
        return (self.payload_len,)

    @property
    def stored_shard_shape(self) -> tuple[int, ...]:
        """What a worker STORES and transforms (the full coded shard) --
        distributed executors size per-device buffers from this."""
        return (self.shard_len,)

    @property
    def recovery_threshold(self) -> int:
        """The traded-away optimum: m*q responders instead of m."""
        return self.m * self.q

    @property
    def payload_scale(self) -> float:
        """The purchased win: 1/q of the MDS wire payload per worker."""
        return 1.0 / self.q

    @property
    def generator(self) -> jax.Array:
        """The ``(N, m)`` ENCODE generator -- worker storage is unchanged
        from the base MDS plan."""
        return mds.rs_generator(self.n_workers, self.m, self.dtype)

    @property
    def decode_generator(self) -> jax.Array:
        """The widened ``(N, m*q)`` system the folded responses are rows
        of (same roots-of-unity nodes, more columns)."""
        return mds.rs_generator(self.n_workers, self.m * self.q, self.dtype)

    @property
    def decode_width(self) -> int:
        return self.m * self.q

    @property
    def worker_encode_tensor(self) -> jax.Array:
        """Per-worker encode rows ``(N, 1, m)`` for the distributed
        runtime's generic contraction (one stored fragment per worker)."""
        return self.generator[:, None, :]

    @functools.cached_property
    def fold_weights(self) -> jax.Array:
        """``(N, q)`` fold coefficients ``omega_N^{k*m*t}`` -- read off the
        decode generator's columns ``m*t`` so the root convention can
        never drift from the system decode solves."""
        return self.decode_generator[:, :: self.m]

    # -- stage cores ---------------------------------------------------------
    def _message1(self, x: jax.Array) -> jax.Array:
        return interleave(x.astype(self.dtype), self.m)

    def _encode1(self, x: jax.Array) -> jax.Array:
        c = self._message1(x)
        return mds.encode_dft(c, self.n_workers).astype(self.dtype)

    def encode(self, x: jax.Array) -> jax.Array:
        """Input -> stored worker shards ``(*B, N, s/m)`` -- always the
        O(N log N) DFT encode (the base kernel branch folds payload
        through the (N, m) generator matmul, which is fine, but its
        output-shape bookkeeping assumes ``worker_shard_shape`` == stored
        shape; this plan ships a different shape than it stores)."""
        return self._map_batched(
            self._encode1, x, len(self.input_shape), "plan input")

    def worker_compute(self, a: jax.Array) -> jax.Array:
        """Full per-shard DFT, then the 1/q fold: ``(*B, N, s/m) ->
        (*B, N, s/(m*q))``.

        Unlike the base plans this is worker-INDEX-aware (the fold weight
        is ``omega^{kmt}``), so the worker axis must be at -2 spanning all
        N workers; use :meth:`worker_compute_rows` for a device holding a
        subset of rows.
        """
        return self.worker_compute_rows(a, jnp.arange(self.n_workers))

    def worker_compute_rows(self, a: jax.Array, rows: jax.Array) -> jax.Array:
        """:meth:`worker_compute` for the workers in ``rows`` only --
        ``a``: ``(n_rows, *B, s/m)`` or ``(*B, n_rows, s/m)`` with the
        row axis at -2; returns the same layout with the last axis folded
        to ``s/(m*q)``."""
        b = self._fft1_worker(a)
        blocks = b.reshape(b.shape[:-1] + (self.q, self.payload_len))
        w = jnp.take(self.fold_weights, rows, axis=0)
        return jnp.einsum("...nql,nq->...nl", blocks,
                          w.astype(blocks.dtype))

    def _postdecode1(self, u: jax.Array) -> jax.Array:
        # u[i + m*t] = C_i^{(t)}: un-permute the widened message back into
        # the m shard transforms, then the standard twiddle recombine
        c_hat = (u.reshape(self.q, self.m, self.payload_len)
                 .transpose(1, 0, 2)
                 .reshape(self.m, self.shard_len))
        return recombine(c_hat, self.s)

    # decode / decodable / run: inherited from MDSPlanBase -- the
    # decode_generator / decode_width hooks point the shared machinery at
    # the widened system, and recovery_threshold drives decodable().
