"""Byzantine fault detection/correction for coded FFT (paper Remark 3).

Because the worker results form an (N, m)-MDS codeword (per payload column),
receiving ``k`` results allows *detecting* up to ``k - m`` arbitrarily wrong
workers and *correcting* up to ``floor((k - m) / 2)`` of them -- the classic
MDS-distance argument, which the paper points out carries over to coded FFT.

Over F = C with Vandermonde/RS codes, error location is done with Prony's
method on the syndrome sequence (the complex-field analogue of
Berlekamp-Massey):

* generalized-RS syndromes at arbitrary distinct nodes ``{a_j}``:
      S_r = sum_j  r_j * u_j * a_j^r ,   r < k - m,
      u_j = 1 / prod_{l != j} (a_j - a_l)
  vanish for every valid codeword (divided-difference identity: the r-th
  syndrome is the leading coefficient of the degree-(k-1) interpolant of
  ``x^r * p(x)``, zero whenever ``deg p < m`` and ``r < k - m``).
* with ``e`` errors the syndromes become a sum of ``e`` exponentials
  ``S_r = sum_t w_t z_t^r`` whose Prony annihilator roots ``z_t`` are the
  error nodes; 2e syndromes determine them, hence ``e <= (k - m)/2``.

Decoding is master-side and tiny (k <= N), so this module is plain
jnp/ndarray code without jit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import mds
from repro.core.coded_fft import CodedFFT

__all__ = [
    "lagrange_weights",
    "syndromes",
    "detect_errors",
    "locate_errors",
    "correct_errors",
    "RobustDecodeResult",
    "robust_decode",
    "RobustCodedFFT",
]


def lagrange_weights(nodes: np.ndarray) -> np.ndarray:
    """u_j = 1 / prod_{l != j}(a_j - a_l) for distinct nodes."""
    diff = nodes[:, None] - nodes[None, :]
    np.fill_diagonal(diff, 1.0)
    return 1.0 / np.prod(diff, axis=1)


def syndromes(nodes: np.ndarray, received: np.ndarray, m: int) -> np.ndarray:
    """Syndrome matrix, shape ``(k - m, L)`` for received values ``(k, L)``."""
    k = nodes.shape[0]
    u = lagrange_weights(nodes)
    powers = np.vander(nodes, N=k - m, increasing=True).T  # (k-m, k)
    return (powers * u[None, :]) @ received


def detect_errors(
    nodes: np.ndarray, received: np.ndarray, m: int, tol: float = 1e-6
) -> bool:
    """True iff the received rows are NOT a valid codeword (some worker lied).

    Detects up to ``k - m`` arbitrary errors (any fewer errors cannot produce
    another codeword, by MDS distance).
    """
    s = syndromes(nodes, received, m)
    scale = max(np.abs(received).max(), 1.0)
    return bool(np.abs(s).max() > tol * scale)


def locate_errors(
    nodes: np.ndarray,
    received: np.ndarray,
    m: int,
    tol: float = 1e-6,
) -> Optional[np.ndarray]:
    """Return indices (into the received subset) of erroneous workers.

    Tries error counts e = 0, 1, ..., floor((k-m)/2) and returns the first
    hypothesis whose corrected word passes the syndrome check; None if no
    consistent hypothesis exists (more errors than correctable).
    """
    k = nodes.shape[0]
    n_syn = k - m
    e_max = n_syn // 2
    syn = syndromes(nodes, received, m)  # (n_syn, L)
    scale = max(np.abs(received).max(), 1.0)
    if np.abs(syn).max() <= tol * scale:
        return np.zeros((0,), dtype=np.int64)
    # random projection across payload columns -> scalar syndrome sequence;
    # error positions are column-independent so a generic projection keeps them.
    rng = np.random.default_rng(0)
    rho = rng.normal(size=syn.shape[1]) + 1j * rng.normal(size=syn.shape[1])
    s = syn @ rho  # (n_syn,)
    for e in range(1, e_max + 1):
        if n_syn < 2 * e:
            break
        # Prony: solve Hankel system for monic annihilator Lambda of degree e
        rows = n_syn - e
        a_mat = np.stack([s[i : i + e] for i in range(rows)])  # (rows, e)
        rhs = -s[e : e + rows]
        coeffs, *_ = np.linalg.lstsq(a_mat, rhs, rcond=None)
        # Lambda(x) = x^e + coeffs[e-1] x^{e-1} + ... + coeffs[0]
        poly = np.concatenate([[1.0 + 0j], coeffs[::-1]])
        roots = np.roots(poly)
        # match roots to nearest received node
        idx = np.unique(np.argmin(np.abs(roots[:, None] - nodes[None, :]), axis=1))
        if idx.shape[0] != e:
            continue
        # hypothesis check: solve error values per column, verify residual
        basis = np.vander(nodes[idx], N=n_syn, increasing=True).T  # (n_syn, e)
        u = lagrange_weights(nodes)
        design = basis * u[idx][None, :]
        vals, *_ = np.linalg.lstsq(design, syn, rcond=None)  # (e, L)
        resid = syn - design @ vals
        if np.abs(resid).max() <= max(tol * scale, 1e-9):
            return idx.astype(np.int64)
    return None


def correct_errors(
    nodes: np.ndarray,
    received: np.ndarray,
    m: int,
    tol: float = 1e-6,
) -> Optional[tuple[np.ndarray, np.ndarray]]:
    """Return ``(corrected rows, error indices)``, or None if uncorrectable.

    The returned indices are the ones ``locate_errors`` found, so callers
    never need a second Prony pass to learn who lied.
    """
    err_idx = locate_errors(nodes, received, m, tol)
    if err_idx is None:
        return None
    if err_idx.shape[0] == 0:
        return received, err_idx
    k = nodes.shape[0]
    n_syn = k - m
    syn = syndromes(nodes, received, m)
    u = lagrange_weights(nodes)
    basis = np.vander(nodes[err_idx], N=n_syn, increasing=True).T
    design = basis * u[err_idx][None, :]
    weighted_err, *_ = np.linalg.lstsq(design, syn, rcond=None)  # (e, L)
    corrected = received.copy()
    corrected[err_idx] -= weighted_err
    return corrected, err_idx


@dataclasses.dataclass
class RobustDecodeResult:
    output: Optional[np.ndarray]
    n_errors_corrected: int
    error_worker_indices: np.ndarray  # global worker ids found erroneous
    ok: bool


def robust_decode(
    strategy: CodedFFT,
    b: jnp.ndarray,
    recv_idx: np.ndarray,
    tol: float = 1e-6,
) -> RobustDecodeResult:
    """Decode coded-FFT worker results with Byzantine workers present.

    ``b``: ``(N, *shard)`` results, of which only rows ``recv_idx`` (k of
    them) arrived; up to floor((k - m)/2) of those may be arbitrarily
    corrupted.  Works for any MDS plan whose evaluation nodes are
    ``mds.rs_nodes(n_workers)`` -- the syndrome math runs on rows flattened
    per payload column, the final decode on the original shard shape.
    """
    recv_idx = np.asarray(recv_idx, dtype=np.int64)
    nodes = np.asarray(mds.rs_nodes(strategy.n_workers, jnp.complex128))[recv_idx]
    b_np = np.asarray(b, dtype=np.complex128)
    received = b_np[recv_idx].reshape(recv_idx.shape[0], -1)  # (k, L_flat)
    result = correct_errors(nodes, received, strategy.m, tol)
    if result is None:
        return RobustDecodeResult(None, 0, np.zeros(0, np.int64), ok=False)
    corrected, err_local = result  # one Prony pass: indices ride along
    n_err = int(err_local.shape[0])
    # decode from the first m *clean* received rows (global indexing)
    err_set = set(err_local.tolist())
    clean_local = [i for i in range(len(recv_idx)) if i not in err_set]
    use_local = np.asarray(clean_local[: strategy.m])
    subset = jnp.asarray(recv_idx[use_local])
    b_full = b_np.copy()
    b_full[recv_idx] = corrected.reshape((recv_idx.shape[0],) + b_np.shape[1:])
    x = strategy.decode(jnp.asarray(b_full).astype(strategy.dtype), subset=subset)
    err_global = recv_idx[err_local] if n_err else np.zeros(0, np.int64)
    return RobustDecodeResult(np.asarray(x), n_err, err_global, ok=True)


@dataclasses.dataclass(frozen=True)
class RobustCodedFFT:
    """Coded FFT with Byzantine-fault correction layered on top (Remark 3)."""

    strategy: CodedFFT
    tol: float = 1e-6

    def max_correctable(self, k_received: int) -> int:
        return (k_received - self.strategy.m) // 2

    def max_detectable(self, k_received: int) -> int:
        return k_received - self.strategy.m

    def run(self, x: jnp.ndarray, recv_idx: np.ndarray) -> RobustDecodeResult:
        b = self.strategy.worker_compute(self.strategy.encode(x))
        return robust_decode(self.strategy, b, recv_idx, self.tol)
