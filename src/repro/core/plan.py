"""The ``CodedPlan`` protocol: one interface for every coded strategy.

The paper's pipeline (interleave -> MDS-encode -> worker DFT -> MDS-decode
-> recombine) is a single coded-linear-transform family; ``CodedFFT``,
``CodedFFTND``, ``CodedFFTMultiInput`` and ``UncodedRepetitionFFT`` are all
instances of it.  This module defines the shared contract (DESIGN.md §2)
plus ``MDSPlanBase``, the batch-aware implementation the three MDS-coded
strategies build on.

Canonical shapes (``B* = any leading batch axes``, usually one):

* ``encode``          : ``(*B, *input_shape)  -> (*B, N, *worker_shard_shape)``
* ``worker_compute``  : ``(*B, N, *shard)     -> (*B, N, *shard)`` -- the
  transform acts on the trailing ``worker_shard_shape`` axes only, so any
  leading layout (batch, worker, or both) maps through unchanged.
* ``decode``          : ``(*B, N, *shard)     -> (*B, *output_shape)`` with
  per-request straggler ``mask`` ``(*B, N)`` / ``subset`` ``(*B, m)``.

MDS plans additionally split the master's two stages so distributed
executors can fuse them per device (DESIGN.md §3):

* ``message``    : input -> the ``m`` uncoded message shards (interleave);
* ``postdecode`` : decoded message shards -> final output (recombine).

``encode = encode_dft(message(x))`` and
``decode = postdecode(mds_subset_decode(b))`` by construction.
"""

from __future__ import annotations

import math
from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mds
from repro.kernels import ops

__all__ = ["CodedPlan", "MDSPlan", "MDSPlanBase", "batch_shape"]


def batch_shape(arr: jax.Array, core_ndim: int, what: str) -> tuple[int, ...]:
    """Leading batch dims of ``arr`` given its core (unbatched) rank."""
    extra = arr.ndim - core_ndim
    if extra < 0:
        raise ValueError(
            f"{what} must have rank >= {core_ndim}, got shape {arr.shape}")
    return arr.shape[:extra]


@runtime_checkable
class CodedPlan(Protocol):
    """Minimal contract every computation strategy satisfies.

    Instances: ``CodedFFT`` / ``CodedFFTND`` / ``CodedFFTMultiInput``
    (complex), ``CodedRFFT`` / ``CodedIFFT`` / ``CodedIRFFT`` (1-D real /
    inverse, DESIGN.md §7), ``CodedRFFTN`` / ``CodedIRFFTN`` (n-D real,
    §9), and ``UncodedRepetitionFFT`` (the non-MDS Remark-4 baseline).
    """

    n_workers: int

    @property
    def recovery_threshold(self) -> int:
        """How many responders the master must wait for (``m`` for every
        MDS plan -- the paper's optimum)."""
        ...

    @property
    def input_shape(self) -> tuple[int, ...]:
        """Core (unbatched) request shape."""
        ...

    @property
    def output_shape(self) -> tuple[int, ...]:
        """Core (unbatched) result shape -- real kinds differ from
        ``input_shape`` (half-spectrum vs time domain)."""
        ...

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        """Per-worker payload shape: what ONE worker stores, transforms,
        and ships (the real kinds' is HALF the complex plans')."""
        ...

    def encode(self, x: jax.Array) -> jax.Array:
        """Input -> coded worker shards ``(*B, N, *worker_shard_shape)``."""
        ...

    def worker_compute(self, a: jax.Array) -> jax.Array:
        """The per-worker transform over the trailing shard axes; any
        leading (batch / worker) axes map through unchanged."""
        ...

    def decode(self, b, subset=None, mask=None):
        """Worker results -> output from any ``recovery_threshold``-subset
        of responders (``subset`` indices or boolean ``mask``)."""
        ...

    def run(self, x, subset=None, mask=None):
        """``decode(worker_compute(encode(x)))`` -- the single-process
        end-to-end reference path."""
        ...


@runtime_checkable
class MDSPlan(CodedPlan, Protocol):
    """A plan whose code is the (N, m) complex-RS MDS code: decodable from
    ANY ``m`` responders, and factorable into per-device encode (generator
    row x message) for mesh execution."""

    @property
    def m(self) -> int:
        """The storage-fraction parameter: each worker holds ``1/m`` of
        the input; also the recovery threshold."""
        ...

    @property
    def generator(self) -> jax.Array:
        """The ``(N, m)`` RS generator ``G[k, i] = omega_N^{ki}`` --
        independent of the transform length and kind, which is why one
        decode-matrix cache serves every service bucket."""
        ...

    def message(self, x: jax.Array) -> jax.Array:
        """Input -> the ``m`` uncoded message shards (interleave; plus
        the pack/fold stages of the real kinds)."""
        ...

    def postdecode(self, c_hat: jax.Array) -> jax.Array:
        """Decoded message-shard transforms -> final output (recombine;
        plus the split/unpack stages of the real kinds)."""
        ...


class MDSPlanBase:
    """Shared batched encode/decode/run for MDS-coded strategies.

    Subclasses provide the dataclass fields (``n_workers``, ``dtype``, ...,
    and ``backend``), the ``m`` / ``generator`` / shape properties, the
    unbatched stage cores ``_message1`` / ``_postdecode1``, and a
    trailing-axes ``worker_compute``.

    Backend dispatch (DESIGN.md §6): plans are constructed with
    ``backend="kernel"`` by default, which routes encode / worker /
    decode-apply through the Pallas kernel stack (interpret mode off-TPU).
    The rules:

    * kernels compute in f32 planes, so only ``complex64`` plans resolve to
      the kernel backend -- ``complex128`` (the numerics/reference tier)
      always resolves to the jnp oracle;
    * ``backend="reference"`` forces the jnp path at any dtype;
    * vmapped per-request decode keeps the jnp solve (the batched service
      decodes through its own decode-matrix cache instead, §6).
    """

    # -- stage cores supplied by the concrete plan ---------------------------
    def _message1(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def _postdecode1(self, c_hat: jax.Array) -> jax.Array:
        raise NotImplementedError

    # -- decode-system hooks (DESIGN.md §13) ---------------------------------
    # For the plain MDS plans the decode system IS the encode system: the
    # (N, m) generator, solvable from any m responders.  Beyond-MDS
    # strategies reuse the whole batched decode machinery below by
    # overriding just these two: the communication-efficient plan's fold
    # makes each worker a row of the WIDER (N, m*q) code, so it decodes
    # against a different generator than it encodes with.
    @property
    def decode_generator(self) -> jax.Array:
        """Generator of the linear system decode solves (default: the
        encode generator)."""
        return self.generator

    @property
    def decode_width(self) -> int:
        """Number of responder rows decode needs -- the column count of
        ``decode_generator`` (default: ``m``)."""
        return self.m

    def decodable(self, mask=None) -> bool:
        """Host-side check: can the master finish from these responders?
        For (any-subset-decodable) MDS-style codes this is a pure count
        against ``recovery_threshold``."""
        if mask is None:
            return self.n_workers >= self.recovery_threshold
        return int(np.asarray(mask).sum()) >= self.recovery_threshold

    # -- backend dispatch ----------------------------------------------------
    @property
    def resolved_backend(self) -> str:
        """The execution engine this plan actually runs on: ``"kernel"``
        only when requested AND the dtype is kernel-eligible (c64)."""
        backend = getattr(self, "backend", "reference")
        if backend == "kernel" and ops.kernel_backend_supported(self.dtype):
            return "kernel"
        return "reference"

    def _fftn_worker(self, a: jax.Array, nd: int) -> jax.Array:
        """Backend-dispatched n-D FFT over the trailing ``nd`` axes --
        the shared worker body of the n-D and multi-input plans."""
        if self.resolved_backend == "kernel":
            return ops.make_kernel_fftn_fn(nd)(a)
        return jnp.fft.fftn(a, axes=tuple(range(-nd, 0)))

    def _ifftn_worker(self, a: jax.Array, nd: int) -> jax.Array:
        """Backend-dispatched n-D inverse FFT over the trailing ``nd``
        axes -- the worker body of the n-D real-output plan (DESIGN.md
        §9).  On the kernel backend it rides the forward four-step sweep
        via ``ifftn(a) = conj(fftn(conj(a))) / prod(L)``: sign flips on
        the imaginary plane, same kernels."""
        if self.resolved_backend == "kernel":
            scale = math.prod(a.shape[-nd:])
            return jnp.conj(
                ops.make_kernel_fftn_fn(nd)(jnp.conj(a))) / scale
        return jnp.fft.ifftn(a, axes=tuple(range(-nd, 0)))

    def _fft1_worker(self, a: jax.Array, inverse: bool = False) -> jax.Array:
        """Backend-dispatched 1-D (i)FFT along the last axis -- the shared
        worker body of the 1-D forward/real/inverse plans (DESIGN.md §7)."""
        if self.resolved_backend == "kernel":
            return ops.make_kernel_worker_fn(inverse=inverse)(a)
        fn = jnp.fft.ifft if inverse else jnp.fft.fft
        return fn(a, axis=-1)

    # -- batch plumbing ------------------------------------------------------
    def _map_batched(self, fn, arr: jax.Array, core_ndim: int, what: str):
        batch = batch_shape(arr, core_ndim, what)
        if not batch:
            return fn(arr)
        flat = arr.reshape((-1,) + arr.shape[len(batch):])
        out = jax.vmap(fn)(flat)
        return out.reshape(batch + out.shape[1:])

    # -- public pipeline -----------------------------------------------------
    def message(self, x: jax.Array) -> jax.Array:
        """Input -> uncoded message shards ``(*B, m, *worker_shard_shape)``."""
        return self._map_batched(
            self._message1, x, len(self.input_shape), "plan input")

    def encode(self, x: jax.Array) -> jax.Array:
        """Input -> coded worker shards.

        Reference backend: the O(N log N) zero-padded DFT encode.  Kernel
        backend: ONE Pallas ``G @ c`` matmul with the whole batch folded
        into the payload columns (no vmap-over-pallas, one launch per
        batch).
        """
        if self.resolved_backend == "kernel":
            c = self.message(x)                       # (*B, m, *shard)
            shard = tuple(self.worker_shard_shape)
            batch = c.shape[:c.ndim - 1 - len(shard)]
            payload = math.prod(shard) if shard else 1
            flat = c.reshape((-1, self.m, payload))
            folded = jnp.swapaxes(flat, 0, 1).reshape(self.m, -1)
            coded = ops.mds_apply(self.generator, folded)
            out = jnp.swapaxes(
                coded.reshape(self.n_workers, flat.shape[0], payload), 0, 1)
            return out.reshape(batch + (self.n_workers,) + shard)
        return self._map_batched(
            self._encode1, x, len(self.input_shape), "plan input")

    def _encode1(self, x: jax.Array) -> jax.Array:
        c = self._message1(x)
        return mds.encode_dft(c, self.n_workers).astype(self.dtype)

    def encode_dense(self, x: jax.Array) -> jax.Array:
        """Reference O(N*m) matrix encode (kept for tests/benchmarks)."""
        return self._map_batched(
            lambda xi: mds.encode(self.generator, self._message1(xi)),
            x, len(self.input_shape), "plan input")

    def postdecode(self, c_hat: jax.Array) -> jax.Array:
        return self._map_batched(
            self._postdecode1, c_hat, 1 + len(self.worker_shard_shape),
            "decoded shards")

    def decode(
        self,
        b: jax.Array,
        subset: Optional[jax.Array] = None,
        mask: Optional[jax.Array] = None,
        *,
        method: str = "auto",
    ) -> jax.Array:
        """Worker results -> output, per-request straggler handling.

        Exactly one of ``subset`` (responder indices, ``(*B, m)`` or shared
        ``(m,)``) or ``mask`` (availability, ``(*B, N)`` or shared ``(N,)``)
        may be given.  ``method`` selects the MDS decode path (DESIGN.md §4).
        """
        if subset is not None and mask is not None:
            raise ValueError("pass at most one of subset / mask")
        m = self.decode_width
        core = 1 + len(self.worker_shard_shape)
        batch = batch_shape(b, core, "worker results")
        use_kernel = self.resolved_backend == "kernel"
        if not batch:
            if subset is None:
                subset = (mds.first_available(jnp.asarray(mask), m)
                          if mask is not None else jnp.arange(m))
            return self._decode1(b, jnp.asarray(subset), method,
                                 use_kernel=use_kernel)

        flat = b.reshape((-1,) + b.shape[len(batch):])
        nb = flat.shape[0]
        if nb == 1:
            # batch of one (the service's single-submit bucket): skip vmap
            # so decode_auto's dispatch stays a real branch/static choice
            if subset is None:
                subset = (mds.first_available(
                    jnp.asarray(mask).reshape(-1)[-self.n_workers:], m)
                    if mask is not None else jnp.arange(m))
            out = self._decode1(flat[0], jnp.asarray(subset).reshape(m),
                                method, use_kernel=use_kernel)
            return out.reshape(batch + out.shape)
        # per-request subsets are traced under vmap, where decode_auto's
        # lax.cond would lower to a select that EXECUTES both decode paths
        # per request -- resolve auto to the backward-stable solve instead
        per_request_method = "solve" if method == "auto" else method
        if mask is not None:
            masks = jnp.broadcast_to(
                jnp.asarray(mask), batch + (self.n_workers,)).reshape(nb, -1)
            subsets = jax.vmap(lambda mk: mds.first_available(mk, m))(masks)
        elif subset is None:
            # shared contiguous default: keep it concrete so the fast-decode
            # dispatch stays static under vmap
            shared = jnp.arange(m)
            out = jax.vmap(lambda bi: self._decode1(bi, shared, method))(flat)
            return out.reshape(batch + out.shape[1:])
        else:
            subset = jnp.asarray(subset)
            if subset.ndim == 1:
                out = jax.vmap(
                    lambda bi: self._decode1(bi, subset, method))(flat)
                return out.reshape(batch + out.shape[1:])
            subsets = subset.reshape(nb, m)
        out = jax.vmap(
            lambda bi, si: self._decode1(bi, si, per_request_method))(
                flat, subsets)
        return out.reshape(batch + out.shape[1:])

    def _decode1(self, b: jax.Array, subset: jax.Array, method: str,
                 *, use_kernel: bool = False) -> jax.Array:
        if use_kernel and method == "auto":
            # kernel backend: decode-apply as an MXU matmul -- invert the
            # subset generator once (payload-independent) and stream the
            # responder rows through the Pallas cmatmul.  Rows outside the
            # subset are never read (straggler garbage stays out).
            rows = jnp.take(b, subset, axis=0)
            dmat = mds.subset_decode_matrix(
                self.decode_generator, subset).astype(self.dtype)
            c_hat = ops.mds_apply(dmat, rows)
            return self._postdecode1(c_hat)
        c_hat = mds.decode_auto(self.decode_generator, b, subset,
                                method=method)
        return self._postdecode1(c_hat)

    def run(
        self,
        x: jax.Array,
        subset: Optional[jax.Array] = None,
        mask: Optional[jax.Array] = None,
        *,
        method: str = "auto",
    ) -> jax.Array:
        b = self.worker_compute(self.encode(x))
        return self.decode(b, subset=subset, mask=mask, method=method)
