"""Baseline computation strategies compared against coded FFT (Remark 4).

The paper's comparison:

* **coded FFT** (this work):          K* = m
* **uncoded repetition**:             K  = N - N/m^2 + 1
* **short-dot / short-MDS [9],[13]**: K  = N - N/m + m

Uncoded repetition is implemented in full: without exploiting the DFT's
recursive structure, the generic approach block-partitions the DFT *matrix*
into an m x m grid -- worker w stores one contiguous input chunk ``x_j``
(1/m of the input) and returns one partial product ``P_ij = F_ij @ x_j``
(s/m outputs).  The master must collect ALL m^2 distinct blocks; with each
block replicated N/m^2 times, an adversary can erase every copy of one
block using only N/m^2 erasures, so the worst-case threshold is
``N - N/m^2 + 1`` exactly.

Short-dot is reported analytically (the sparse-code construction of Dutta
et al. [13]; we cite the threshold rather than re-implement that paper).

``UncodedRepetitionFFT`` implements the :class:`repro.core.plan.CodedPlan`
protocol (shape metadata, leading batch axes through encode/worker/decode)
but NOT ``MDSPlan`` -- its replication code is not subset-decodable, which
is exactly the Remark-4 gap the benchmarks demonstrate.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coded_fft import CodedFFT
from repro.core.comm_efficient import CodedCommEffFFT
from repro.core.partial import CodedPartialFFT
from repro.core.plan import batch_shape

__all__ = [
    "UncodedRepetitionFFT",
    "CodedPartialFFT",
    "CodedCommEffFFT",
    "StrategyEntry",
    "REGISTRY",
    "register_strategy",
    "make_strategy",
    "coded_fft_threshold",
    "repetition_threshold",
    "short_dot_threshold",
]


def coded_fft_threshold(n: int, m: int) -> int:
    """Theorem 1: K* = m."""
    return m


def repetition_threshold(n: int, m: int) -> int:
    """Remark 4: uncoded repetition needs N - N/m^2 + 1 (worst case)."""
    assert n % (m * m) == 0, "repetition baseline needs m^2 | N"
    return n - n // (m * m) + 1


def short_dot_threshold(n: int, m: int) -> int:
    """Remark 4: short-dot / short-MDS [9],[13] needs N - N/m + m."""
    assert n % m == 0
    return n - n // m + m


@dataclasses.dataclass(frozen=True)
class UncodedRepetitionFFT:
    """Generic block-partitioned DFT with replication (no coding).

    N workers, m^2 | N.  Worker ``w`` is assigned block
    ``(i, j) = divmod(w % m^2, m)`` -- it stores input chunk ``x_j``
    (contiguous, length s/m) and computes ``P_ij = F[i-block, j-block] @ x_j``.
    """

    s: int
    m: int
    n_workers: int
    dtype: jnp.dtype = jnp.complex64

    def __post_init__(self):
        if self.s % self.m != 0:
            raise ValueError("m | s required")
        if self.n_workers % (self.m * self.m) != 0:
            raise ValueError("m^2 | N required for the repetition baseline")

    @property
    def shard_len(self) -> int:
        return self.s // self.m

    @property
    def n_blocks(self) -> int:
        return self.m * self.m

    @property
    def replicas(self) -> int:
        return self.n_workers // self.n_blocks

    # -- CodedPlan shape metadata --------------------------------------------
    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return (self.shard_len,)

    @property
    def recovery_threshold(self) -> int:
        """Worst-case threshold (Remark 4) -- contrast with MDS plans' m."""
        return self.worst_case_threshold()

    def block_of_worker(self, w: int) -> tuple[int, int]:
        return divmod(w % self.n_blocks, self.m)

    def _dft_block(self, i: int, j: int) -> jax.Array:
        ell = self.shard_len
        rows = jnp.arange(i * ell, (i + 1) * ell)
        cols = jnp.arange(j * ell, (j + 1) * ell)
        return jnp.exp(-2j * jnp.pi * jnp.outer(rows, cols) / self.s).astype(self.dtype)

    @functools.cached_property
    def _worker_blocks(self) -> jax.Array:
        """Stacked per-worker DFT blocks, shape (N, s/m, s/m)."""
        return jnp.stack(
            [self._dft_block(*self.block_of_worker(w))
             for w in range(self.n_workers)])

    @functools.cached_property
    def _chunk_of_worker(self) -> jax.Array:
        return jnp.asarray(
            [self.block_of_worker(w)[1] for w in range(self.n_workers)])

    def encode(self, x: jax.Array) -> jax.Array:
        """Worker storage ``(*B, N, s/m)`` -- worker w stores chunk x_{j_w}."""
        chunks = x.astype(self.dtype).reshape(
            x.shape[:-1] + (self.m, self.shard_len))
        return jnp.take(chunks, self._chunk_of_worker, axis=-2)

    def worker_compute(self, a: jax.Array) -> jax.Array:
        """Worker w returns F_{i_w, j_w} @ x_{j_w}; leading axes map through."""
        return jnp.einsum("nij,...nj->...ni", self._worker_blocks, a)

    def decodable(self, mask: np.ndarray) -> bool:
        """Master can finish iff every (i, j) block has >= 1 live replica."""
        got = set()
        for w in np.nonzero(np.asarray(mask))[0]:
            got.add(self.block_of_worker(int(w)))
        return len(got) == self.n_blocks

    def decode(self, b: jax.Array, subset: Optional[np.ndarray] = None,
               mask: Optional[np.ndarray] = None) -> jax.Array:
        """Assemble X from one live replica per block (host-side numpy).

        ``b``: ``(*B, N, s/m)`` worker results; ``mask``: ``(N,)`` or
        ``(*B, N)`` availability (``subset`` of responder ids is accepted
        for protocol uniformity and converted to a mask).  Raises if any
        block lost all replicas.
        """
        if subset is not None:
            if mask is not None:
                raise ValueError("pass at most one of subset / mask")
            mask = np.zeros(self.n_workers, bool)
            mask[np.asarray(subset)] = True
        if mask is None:
            mask = np.ones(self.n_workers, bool)
        batch = batch_shape(b, 2, "worker results")
        if batch:
            bf = np.asarray(b).reshape((-1,) + b.shape[len(batch):])
            mf = np.broadcast_to(
                np.asarray(mask), batch + (self.n_workers,)
            ).reshape(bf.shape[0], -1)
            out = np.stack([np.asarray(self._decode1(bi, mi))
                            for bi, mi in zip(bf, mf)])
            return jnp.asarray(out.reshape(batch + (self.s,)))
        return self._decode1(b, np.asarray(mask))

    def _decode1(self, b: jax.Array, mask: np.ndarray) -> jax.Array:
        if not self.decodable(mask):
            raise ValueError("not enough workers responded: some block missing")
        ell = self.shard_len
        x_out = jnp.zeros((self.s,), self.dtype)
        seen = set()
        for w in np.nonzero(mask)[0]:
            i, j = self.block_of_worker(int(w))
            if (i, j) in seen:
                continue
            seen.add((i, j))
            x_out = x_out.at[i * ell : (i + 1) * ell].add(b[..., int(w), :])
        return x_out

    def run(self, x: jax.Array, subset: Optional[np.ndarray] = None,
            mask: Optional[np.ndarray] = None) -> jax.Array:
        return self.decode(self.worker_compute(self.encode(x)),
                           subset=subset, mask=mask)

    # -- empirical threshold verification ------------------------------------
    def worst_case_threshold(self) -> int:
        """Smallest k such that EVERY k-subset is decodable.

        Exact by construction: the adversary kills all replicas of one block
        (N/m^2 workers); with those gone, N - N/m^2 responders still miss a
        block, so threshold = N - N/m^2 + 1.  Verified empirically for small
        N in tests via exhaustive subsets.
        """
        return self.n_workers - self.replicas + 1

    def is_k_recoverable(self, k: int, subsets: Optional[Iterable] = None) -> bool:
        """Check decodability of every k-subset (exhaustive -- small N only)."""
        if subsets is None:
            subsets = itertools.combinations(range(self.n_workers), k)
        for sub in subsets:
            mask = np.zeros(self.n_workers, bool)
            mask[list(sub)] = True
            if not self.decodable(mask):
                return False
        return True


# -- strategy registry (DESIGN.md §13) ----------------------------------------
#
# One name -> (factory, applicability) table for every computation strategy,
# so new plans auto-enroll everywhere a strategy choice exists: the
# registry-parametrized property suite differentially verifies each entry
# against numpy.fft under drawn configs/masks with zero new test code,
# `FFTService(strategy=...)` resolves its bucket plans here, and the
# benchmarks race whatever is registered.


@dataclasses.dataclass(frozen=True)
class StrategyEntry:
    """One computation strategy the runtime can execute.

    ``factory(s, m, n_workers, *, dtype, backend, param)`` builds the plan
    (``param`` is the strategy's own knob -- ``r`` fragments for partial,
    ``q`` fold for comm-efficient -- ``None`` means the entry's default).
    ``applicable(s, m, n_workers, param)`` is the cheap per-(s, m, N)
    predicate the service's bucket selection and the test parametrization
    filter on; the factory's own ValueError stays the authoritative (and
    explanatory) gate.
    """

    name: str
    factory: Callable
    applicable: Callable[[int, int, int, Optional[int]], bool]
    default_param: Optional[int] = None
    kernel_ok: bool = False
    mesh_ok: bool = True
    description: str = ""

    def build(self, s: int, m: int, n_workers: int, *,
              dtype=jnp.complex64, backend: str = "reference",
              param: Optional[int] = None):
        return self.factory(s, m, n_workers, dtype=dtype, backend=backend,
                            param=self.default_param if param is None
                            else param)


REGISTRY: dict[str, StrategyEntry] = {}


def register_strategy(entry: StrategyEntry) -> StrategyEntry:
    if entry.name in REGISTRY:
        raise ValueError(f"strategy {entry.name!r} already registered")
    REGISTRY[entry.name] = entry
    return entry


def make_strategy(name: str, s: int, m: int, n_workers: int, *,
                  dtype=jnp.complex64, backend: str = "reference",
                  param: Optional[int] = None):
    """Build a registered strategy's plan; raises KeyError on unknown
    names and the plan's own ValueError on inapplicable (s, m, N)."""
    if name not in REGISTRY:
        raise KeyError(
            f"unknown strategy {name!r}; registered: {sorted(REGISTRY)}")
    return REGISTRY[name].build(s, m, n_workers, dtype=dtype,
                                backend=backend, param=param)


register_strategy(StrategyEntry(
    name="mds",
    factory=lambda s, m, n, *, dtype, backend, param: CodedFFT(
        s, m, n, dtype=dtype, backend=backend),
    applicable=lambda s, m, n, param: s % m == 0 and n >= m,
    kernel_ok=True,
    mesh_ok=True,
    description="the paper's (N, m) MDS code: threshold m (optimal), "
                "full s/m payload per worker",
))

register_strategy(StrategyEntry(
    name="partial",
    factory=lambda s, m, n, *, dtype, backend, param: CodedPartialFFT(
        s, m, n, r=param, dtype=dtype, backend=backend),
    applicable=lambda s, m, n, param:
        s % (m * (param or 2)) == 0 and n >= m,
    default_param=2,
    kernel_ok=False,
    mesh_ok=True,
    description="Wang et al. 1804.09791: r sequentially-useful fragments "
                "per worker, decode from any m*r fragments -- slow-but-"
                "alive workers contribute prefixes",
))

register_strategy(StrategyEntry(
    name="comm_efficient",
    factory=lambda s, m, n, *, dtype, backend, param: CodedCommEffFFT(
        s, m, n, q=param, dtype=dtype, backend=backend),
    applicable=lambda s, m, n, param:
        s % m == 0 and (s // m) % (param or 2) == 0
        and n >= m * (param or 2),
    default_param=2,
    kernel_ok=False,
    mesh_ok=True,
    description="Jeong et al. 1805.09891: ship a 1/q folded payload "
                "(payload_scale 1/q) at threshold m*q -- wins when the "
                "wire dominates",
))

register_strategy(StrategyEntry(
    name="repetition",
    factory=lambda s, m, n, *, dtype, backend, param: UncodedRepetitionFFT(
        s, m, n, dtype=dtype),
    applicable=lambda s, m, n, param: s % m == 0 and n % (m * m) == 0,
    kernel_ok=False,
    mesh_ok=False,
    description="Remark-4 uncoded baseline: block-partitioned DFT with "
                "replication, worst-case threshold N - N/m^2 + 1",
))
