"""Baseline computation strategies compared against coded FFT (Remark 4).

The paper's comparison:

* **coded FFT** (this work):          K* = m
* **uncoded repetition**:             K  = N - N/m^2 + 1
* **short-dot / short-MDS [9],[13]**: K  = N - N/m + m

Uncoded repetition is implemented in full: without exploiting the DFT's
recursive structure, the generic approach block-partitions the DFT *matrix*
into an m x m grid -- worker w stores one contiguous input chunk ``x_j``
(1/m of the input) and returns one partial product ``P_ij = F_ij @ x_j``
(s/m outputs).  The master must collect ALL m^2 distinct blocks; with each
block replicated N/m^2 times, an adversary can erase every copy of one
block using only N/m^2 erasures, so the worst-case threshold is
``N - N/m^2 + 1`` exactly.

Short-dot is reported analytically (the sparse-code construction of Dutta
et al. [13]; we cite the threshold rather than re-implement that paper).

``UncodedRepetitionFFT`` implements the :class:`repro.core.plan.CodedPlan`
protocol (shape metadata, leading batch axes through encode/worker/decode)
but NOT ``MDSPlan`` -- its replication code is not subset-decodable, which
is exactly the Remark-4 gap the benchmarks demonstrate.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import batch_shape

__all__ = [
    "UncodedRepetitionFFT",
    "coded_fft_threshold",
    "repetition_threshold",
    "short_dot_threshold",
]


def coded_fft_threshold(n: int, m: int) -> int:
    """Theorem 1: K* = m."""
    return m


def repetition_threshold(n: int, m: int) -> int:
    """Remark 4: uncoded repetition needs N - N/m^2 + 1 (worst case)."""
    assert n % (m * m) == 0, "repetition baseline needs m^2 | N"
    return n - n // (m * m) + 1


def short_dot_threshold(n: int, m: int) -> int:
    """Remark 4: short-dot / short-MDS [9],[13] needs N - N/m + m."""
    assert n % m == 0
    return n - n // m + m


@dataclasses.dataclass(frozen=True)
class UncodedRepetitionFFT:
    """Generic block-partitioned DFT with replication (no coding).

    N workers, m^2 | N.  Worker ``w`` is assigned block
    ``(i, j) = divmod(w % m^2, m)`` -- it stores input chunk ``x_j``
    (contiguous, length s/m) and computes ``P_ij = F[i-block, j-block] @ x_j``.
    """

    s: int
    m: int
    n_workers: int
    dtype: jnp.dtype = jnp.complex64

    def __post_init__(self):
        if self.s % self.m != 0:
            raise ValueError("m | s required")
        if self.n_workers % (self.m * self.m) != 0:
            raise ValueError("m^2 | N required for the repetition baseline")

    @property
    def shard_len(self) -> int:
        return self.s // self.m

    @property
    def n_blocks(self) -> int:
        return self.m * self.m

    @property
    def replicas(self) -> int:
        return self.n_workers // self.n_blocks

    # -- CodedPlan shape metadata --------------------------------------------
    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return (self.shard_len,)

    @property
    def recovery_threshold(self) -> int:
        """Worst-case threshold (Remark 4) -- contrast with MDS plans' m."""
        return self.worst_case_threshold()

    def block_of_worker(self, w: int) -> tuple[int, int]:
        return divmod(w % self.n_blocks, self.m)

    def _dft_block(self, i: int, j: int) -> jax.Array:
        ell = self.shard_len
        rows = jnp.arange(i * ell, (i + 1) * ell)
        cols = jnp.arange(j * ell, (j + 1) * ell)
        return jnp.exp(-2j * jnp.pi * jnp.outer(rows, cols) / self.s).astype(self.dtype)

    @functools.cached_property
    def _worker_blocks(self) -> jax.Array:
        """Stacked per-worker DFT blocks, shape (N, s/m, s/m)."""
        return jnp.stack(
            [self._dft_block(*self.block_of_worker(w))
             for w in range(self.n_workers)])

    @functools.cached_property
    def _chunk_of_worker(self) -> jax.Array:
        return jnp.asarray(
            [self.block_of_worker(w)[1] for w in range(self.n_workers)])

    def encode(self, x: jax.Array) -> jax.Array:
        """Worker storage ``(*B, N, s/m)`` -- worker w stores chunk x_{j_w}."""
        chunks = x.astype(self.dtype).reshape(
            x.shape[:-1] + (self.m, self.shard_len))
        return jnp.take(chunks, self._chunk_of_worker, axis=-2)

    def worker_compute(self, a: jax.Array) -> jax.Array:
        """Worker w returns F_{i_w, j_w} @ x_{j_w}; leading axes map through."""
        return jnp.einsum("nij,...nj->...ni", self._worker_blocks, a)

    def decodable(self, mask: np.ndarray) -> bool:
        """Master can finish iff every (i, j) block has >= 1 live replica."""
        got = set()
        for w in np.nonzero(np.asarray(mask))[0]:
            got.add(self.block_of_worker(int(w)))
        return len(got) == self.n_blocks

    def decode(self, b: jax.Array, subset: Optional[np.ndarray] = None,
               mask: Optional[np.ndarray] = None) -> jax.Array:
        """Assemble X from one live replica per block (host-side numpy).

        ``b``: ``(*B, N, s/m)`` worker results; ``mask``: ``(N,)`` or
        ``(*B, N)`` availability (``subset`` of responder ids is accepted
        for protocol uniformity and converted to a mask).  Raises if any
        block lost all replicas.
        """
        if subset is not None:
            if mask is not None:
                raise ValueError("pass at most one of subset / mask")
            mask = np.zeros(self.n_workers, bool)
            mask[np.asarray(subset)] = True
        if mask is None:
            mask = np.ones(self.n_workers, bool)
        batch = batch_shape(b, 2, "worker results")
        if batch:
            bf = np.asarray(b).reshape((-1,) + b.shape[len(batch):])
            mf = np.broadcast_to(
                np.asarray(mask), batch + (self.n_workers,)
            ).reshape(bf.shape[0], -1)
            out = np.stack([np.asarray(self._decode1(bi, mi))
                            for bi, mi in zip(bf, mf)])
            return jnp.asarray(out.reshape(batch + (self.s,)))
        return self._decode1(b, np.asarray(mask))

    def _decode1(self, b: jax.Array, mask: np.ndarray) -> jax.Array:
        if not self.decodable(mask):
            raise ValueError("not enough workers responded: some block missing")
        ell = self.shard_len
        x_out = jnp.zeros((self.s,), self.dtype)
        seen = set()
        for w in np.nonzero(mask)[0]:
            i, j = self.block_of_worker(int(w))
            if (i, j) in seen:
                continue
            seen.add((i, j))
            x_out = x_out.at[i * ell : (i + 1) * ell].add(b[..., int(w), :])
        return x_out

    def run(self, x: jax.Array, subset: Optional[np.ndarray] = None,
            mask: Optional[np.ndarray] = None) -> jax.Array:
        return self.decode(self.worker_compute(self.encode(x)),
                           subset=subset, mask=mask)

    # -- empirical threshold verification ------------------------------------
    def worst_case_threshold(self) -> int:
        """Smallest k such that EVERY k-subset is decodable.

        Exact by construction: the adversary kills all replicas of one block
        (N/m^2 workers); with those gone, N - N/m^2 responders still miss a
        block, so threshold = N - N/m^2 + 1.  Verified empirically for small
        N in tests via exhaustive subsets.
        """
        return self.n_workers - self.replicas + 1

    def is_k_recoverable(self, k: int, subsets: Optional[Iterable] = None) -> bool:
        """Check decodability of every k-subset (exhaustive -- small N only)."""
        if subsets is None:
            subsets = itertools.combinations(range(self.n_workers), k)
        for sub in subsets:
            mask = np.zeros(self.n_workers, bool)
            mask[list(sub)] = True
            if not self.decodable(mask):
                return False
        return True
