"""Interleaving (decimation-in-time) for coded FFT.

1-D (paper eq. 20):   ``c_i[j] = x[i + j*m]``  for ``i < m``, ``j < s/m``.

n-D (paper eq. 28, with the index typo fixed -- the stride along axis ``k``
is ``m_k``, not ``m``):

    c_{(i_0..i_{n-1})}[j_0..j_{n-1}] = t[(i_0 + j_0*m_0), ..., (i_{n-1} + j_{n-1}*m_{n-1})]

The ``prod(m_k) = m`` interleaved tensors are stacked along a leading shard
axis in row-major order of ``(i_0, ..., i_{n-1})``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "interleave",
    "deinterleave",
    "interleave_nd",
    "deinterleave_nd",
]


def interleave(x: jax.Array, m: int) -> jax.Array:
    """Split ``x`` (length ``s``, trailing batch dims allowed *before* the
    transform axis is NOT supported -- transform axis must be axis 0) into
    ``m`` interleaved vectors.  Returns shape ``(m, s // m)``."""
    s = x.shape[0]
    if s % m != 0:
        raise ValueError(f"m={m} must divide s={s}")
    # x[i + j*m] == x.reshape(s//m, m)[j, i]  ->  transpose to (m, s//m)
    return jnp.swapaxes(x.reshape((s // m, m) + x.shape[1:]), 0, 1)


def deinterleave(c: jax.Array) -> jax.Array:
    """Inverse of :func:`interleave`: ``(m, L, *rest) -> (m*L, *rest)``."""
    m, ell = c.shape[0], c.shape[1]
    return jnp.swapaxes(c, 0, 1).reshape((m * ell,) + c.shape[2:])


def interleave_nd(t: jax.Array, factors: tuple[int, ...]) -> jax.Array:
    """Interleave an n-D tensor by ``m_k`` along axis ``k``.

    ``t``: shape ``(s_0, ..., s_{n-1})``; ``factors``: ``(m_0, ..., m_{n-1})``
    with ``m_k | s_k``.  Returns shape ``(m, s_0/m_0, ..., s_{n-1}/m_{n-1})``
    where ``m = prod(m_k)`` and the shard axis enumerates ``(i_0..i_{n-1})``
    in row-major order.
    """
    n = len(factors)
    if t.ndim != n:
        raise ValueError(f"tensor rank {t.ndim} != len(factors) {n}")
    shape = []
    for sk, mk in zip(t.shape, factors):
        if sk % mk != 0:
            raise ValueError(f"factor {mk} must divide dim {sk}")
        shape.extend([sk // mk, mk])
    # reshape to (L_0, m_0, L_1, m_1, ...) then move all m_k axes to front
    r = t.reshape(shape)
    m_axes = [2 * k + 1 for k in range(n)]
    l_axes = [2 * k for k in range(n)]
    r = jnp.transpose(r, m_axes + l_axes)  # (m_0..m_{n-1}, L_0..L_{n-1})
    m = math.prod(factors)
    ells = tuple(sk // mk for sk, mk in zip(t.shape, factors))
    return r.reshape((m,) + ells)


def deinterleave_nd(
    c: jax.Array, factors: tuple[int, ...], out_shape: tuple[int, ...]
) -> jax.Array:
    """Inverse of :func:`interleave_nd`."""
    n = len(factors)
    ells = tuple(sk // mk for sk, mk in zip(out_shape, factors))
    r = c.reshape(tuple(factors) + ells)
    # (m_0..m_{n-1}, L_0..L_{n-1}) -> (L_0, m_0, L_1, m_1, ...)
    perm = []
    for k in range(n):
        perm.extend([n + k, k])
    r = jnp.transpose(r, perm)
    return r.reshape(out_shape)
