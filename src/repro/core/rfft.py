"""Real-input and inverse coded transforms as first-class plans (DESIGN.md §7).

The paper's pipeline is linear in the input, so it applies verbatim to
real signals and to the inverse transform -- what changes is only what the
shards *carry*.  Three plans live here, all :class:`repro.core.plan.MDSPlan`
instances over the SAME ``(N, m)`` complex-RS code, with shape-preserving
worker stages (a plain fft/ifft along the last axis), so the whole encode /
decode / distributed / kernel stack is reused unchanged:

* :class:`CodedRFFT` (r2c) -- real input, half-spectrum output.  The real
  interleave shards ``c_i`` (length ``L = s/m``) are *pair-packed* into
  complex shards ``z_i[j] = c_i[2j] + 1j*c_i[2j+1]`` of length ``L/2``
  before encoding.  Workers transform HALF-length shards (≈½ the flops)
  and ship HALF the payload of the complex plan -- exactly the coded-FFT
  communication overhead (Jeong et al.) that conjugate symmetry removes.
  Decode recovers ``fft(z_i)``; the master's symmetry-aware butterfly
  (:func:`split_packed` + Hermitian extension +
  :func:`repro.core.recombine.recombine_half`) produces ``rfft(x)``.
  The split uses conjugation -- anti-linear, so it CANNOT commute with the
  complex MDS code; it must (and does) run after decode.

* :class:`CodedIFFT` (c2c inverse) -- same interleave/encode, workers run
  ``ifft``, and the recombine butterfly flips its twiddle sign
  (``recombine(c, s, sign=+1) / m``).

* :class:`CodedIRFFT` (c2r) -- the adjoint of :class:`CodedRFFT`: the
  master Hermitian-extends the half spectrum, applies the ADJOINT of the
  recombine butterfly (fold = conj-twiddle + length-``m`` inverse DFT),
  packs the per-shard Hermitian half spectra (:func:`pack_half`), workers
  ``ifft`` the half-length packed shards, and postdecode just unpacks
  real/imag pairs back into the interleave.  Same half-size payloads,
  same decode stack.

``s % (2m) == 0`` is required for the pair packing (``L`` even).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import mds
from repro.core.interleave import deinterleave, interleave
from repro.core.plan import MDSPlanBase
from repro.core.recombine import dft_matrix, recombine, recombine_half, twiddle

__all__ = [
    "CodedRFFT",
    "CodedIFFT",
    "CodedIRFFT",
    "pack_pairs",
    "unpack_pairs",
    "split_packed",
    "pack_half",
    "hermitian_extend",
    "require_even_shards",
]


def require_even_shards(s: int, m: int, axis: int | None = None) -> None:
    """Validate the real-kind packing constraint ``2m | s`` (even shards).

    Every real kind (r2c, c2r, rfftn, irfftn) pair-packs its interleave
    shards along the halved axis, so the shard length ``L = s/m`` there
    must be even: ``s`` must be a positive multiple of ``2m``.  Raises a
    ``ValueError`` whose message always contains the constraint string
    ``"2m | s"`` (the documented, tested contract -- README "supported
    kinds", DESIGN.md §9) instead of letting a reshape fail with an
    opaque shape error deeper in the pipeline.
    """
    if s < 2 * m or s % (2 * m) != 0:
        where = "" if axis is None else f" along axis {axis}"
        raise ValueError(
            f"real packing needs 2m | s (an even shard length s/m){where}: "
            f"got s={s}, m={m}; pad s to a multiple of {2 * m} or lower m")


# ---------------------------------------------------------------- symmetry ops
def pack_pairs(c: jax.Array, dtype=jnp.complex64) -> jax.Array:
    """Real ``(..., L)`` -> packed complex ``(..., L/2)``:
    ``z[j] = c[2j] + 1j*c[2j+1]``."""
    ell = c.shape[-1]
    pairs = c.reshape(c.shape[:-1] + (ell // 2, 2))
    return (pairs[..., 0] + 1j * pairs[..., 1].astype(dtype)).astype(dtype)


def unpack_pairs(z: jax.Array, real_dtype) -> jax.Array:
    """Inverse of :func:`pack_pairs`: ``(..., n)`` complex -> ``(..., 2n)``
    real."""
    n = z.shape[-1]
    pairs = jnp.stack(
        [jnp.real(z).astype(real_dtype), jnp.imag(z).astype(real_dtype)],
        axis=-1)
    return pairs.reshape(z.shape[:-1] + (2 * n,))


def split_packed(z_hat: jax.Array, ell: int) -> jax.Array:
    """Packed spectrum ``fft_{L/2}(z)`` -> half spectrum ``rfft_L(c)``.

    ``z_hat``: ``(..., L/2)`` with ``z = pack_pairs(c)``, ``c`` real of
    length ``ell = L``.  Returns ``(..., L/2 + 1)``.  The even/odd split
    ``E_p = (Z_p + conj(Z_{n-p}))/2``, ``O_p = -j(Z_p - conj(Z_{n-p}))/2``
    recombines as ``C_p = E_p + O_p * omega_L^p``.  Anti-linear (conjugates
    its input): master-side only, never inside the code.
    """
    zext = jnp.concatenate([z_hat, z_hat[..., :1]], axis=-1)
    zrev = jnp.conj(zext[..., ::-1])
    even = 0.5 * (zext + zrev)
    odd = -0.5j * (zext - zrev)
    n = z_hat.shape[-1]
    w = jnp.exp(-2j * jnp.pi * jnp.arange(n + 1) / ell).astype(z_hat.dtype)
    return even + odd * w


def pack_half(c_half: jax.Array, ell: int) -> jax.Array:
    """Inverse of :func:`split_packed`: half spectrum ``(..., L/2 + 1)`` of a
    real length-``ell`` signal -> packed spectrum ``(..., L/2)`` with
    ``ifft_{L/2}(Z)[j] = c[2j] + 1j*c[2j+1]``."""
    n = c_half.shape[-1] - 1
    crev = jnp.conj(c_half[..., ::-1])
    even = 0.5 * (c_half + crev)
    w = jnp.exp(2j * jnp.pi * jnp.arange(n + 1) / ell).astype(c_half.dtype)
    odd = 0.5 * (c_half - crev) * w
    return (even + 1j * odd)[..., :n]


def hermitian_extend(c_half: jax.Array) -> jax.Array:
    """Half spectrum ``(..., L/2 + 1)`` -> full Hermitian ``(..., L)``:
    ``C[L-p] = conj(C[p])``."""
    n = c_half.shape[-1] - 1
    return jnp.concatenate(
        [c_half, jnp.conj(c_half[..., n - 1:0:-1])], axis=-1)


def _real_dtype(dtype) -> jnp.dtype:
    return jnp.float64 if jnp.dtype(dtype) == jnp.complex128 else jnp.float32


# ------------------------------------------------------------------ the plans
@dataclasses.dataclass(frozen=True)
class _RS1DPlanBase(MDSPlanBase):
    """Shared fields/metadata of the 1-D RS-coded transform plans.

    Subclasses set ``_EVEN_SHARDS`` (class attr): the real kinds pair-pack,
    so their shard length ``L = s/m`` must be even (``2m | s``, ``s > 0``).
    """

    s: int
    m: int
    n_workers: int
    dtype: jnp.dtype = jnp.complex64
    backend: str = "kernel"

    _EVEN_SHARDS = False  # class attribute, not a dataclass field

    def __post_init__(self):
        if self._EVEN_SHARDS:
            require_even_shards(self.s, self.m)
        elif self.s % self.m != 0:
            raise ValueError(f"m={self.m} must divide s={self.s}")
        if self.n_workers < self.m:
            raise ValueError(
                f"need N >= m, got N={self.n_workers} m={self.m}")

    @property
    def shard_len(self) -> int:
        """The per-worker TIME-domain shard length ``L`` (real kinds ship
        packed payloads of ``L/2``)."""
        return self.s // self.m

    @property
    def real_dtype(self) -> jnp.dtype:
        return _real_dtype(self.dtype)

    @property
    def recovery_threshold(self) -> int:
        return self.m

    @property
    def generator(self) -> jax.Array:
        return mds.rs_generator(self.n_workers, self.m, self.dtype)


@dataclasses.dataclass(frozen=True)
class CodedRFFT(_RS1DPlanBase):
    """Real-input forward coded FFT: ``(s,)`` real -> ``(s//2+1,)`` complex.

    Worker shards are the pair-packed message spectra: ``L/2`` complex
    values each, vs ``L`` for :class:`~repro.core.coded_fft.CodedFFT` on
    the same ``(s, m)`` -- half the payload bytes on the wire and half the
    per-worker transform length.  The worker stage is an ordinary fft along
    the last axis, so the kernel four-step path, the distributed runtime,
    and the MDS decode stack apply unchanged.
    """

    kind: str = dataclasses.field(default="r2c", init=False)

    _EVEN_SHARDS = True

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.s // 2 + 1,)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return (self.shard_len // 2,)

    def _message1(self, x: jax.Array) -> jax.Array:
        if jnp.iscomplexobj(x):
            x = jnp.real(x)
        c = interleave(x.astype(self.real_dtype), self.m)   # (m, L) real
        return pack_pairs(c, self.dtype)                    # (m, L/2)

    def _postdecode1(self, z_hat: jax.Array) -> jax.Array:
        c_half = split_packed(z_hat, self.shard_len)        # (m, L/2+1)
        return recombine_half(hermitian_extend(c_half), self.s)

    def worker_compute(self, a: jax.Array) -> jax.Array:
        return self._fft1_worker(a)


@dataclasses.dataclass(frozen=True)
class CodedIFFT(_RS1DPlanBase):
    """Inverse coded FFT (c2c): ``(s,)`` spectrum -> ``(s,)`` signal.

    Identical interleave and code; workers run ``ifft`` on their coded
    shards (linearity keeps the code intact) and the recombine butterfly
    conjugates its twiddles, carrying the remaining ``1/m`` of the ``1/s``
    normalization (the workers' ``ifft`` supplies the ``1/L``).
    """

    kind: str = dataclasses.field(default="c2c_inv", init=False)

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return (self.shard_len,)

    def _message1(self, x: jax.Array) -> jax.Array:
        return interleave(x.astype(self.dtype), self.m)

    def _postdecode1(self, c_hat: jax.Array) -> jax.Array:
        return recombine(c_hat, self.s, sign=+1.0) / self.m

    def worker_compute(self, a: jax.Array) -> jax.Array:
        return self._fft1_worker(a, inverse=True)


@dataclasses.dataclass(frozen=True)
class CodedIRFFT(_RS1DPlanBase):
    """Inverse real coded FFT (c2r): ``(s//2+1,)`` half spectrum -> ``(s,)``
    real signal -- the adjoint of :class:`CodedRFFT`.

    Message stage (master, before encode): Hermitian-extend the half
    spectrum, run the ADJOINT recombine butterfly (length-``m`` inverse DFT
    across the fold of the spectrum + conjugate twiddle), and pack each
    resulting per-shard Hermitian half spectrum into ``L/2`` complex
    values.  Workers ``ifft`` the packed coded shards; decode returns the
    packed interleave of the real output, which postdecode just relabels.
    Endpoint bins (``Y[0]``, ``Y[s/2]``) have their imaginary parts
    discarded, matching ``numpy.fft.irfft``.
    """

    kind: str = dataclasses.field(default="c2r", init=False)

    _EVEN_SHARDS = True

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.s // 2 + 1,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return (self.shard_len // 2,)

    def _message1(self, y: jax.Array) -> jax.Array:
        s, m, ell = self.s, self.m, self.shard_len
        y = y.astype(self.dtype)
        head = jnp.real(y[:1]).astype(self.dtype)
        tail = jnp.real(y[-1:]).astype(self.dtype)
        mid = y[1:-1]
        full = jnp.concatenate([head, mid, tail, jnp.conj(mid[::-1])])  # (s,)
        # adjoint recombine: fold_i[t] = sum_r X[t + r*L] * omega_m^{+ir}
        #                                * omega_s^{+it}
        folded = dft_matrix(m, self.dtype, sign=+1.0) @ full.reshape(m, ell)
        folded = folded * jnp.conj(twiddle(s, m, self.dtype))
        return pack_half(folded[:, : ell // 2 + 1], ell)     # (m, L/2)

    def _postdecode1(self, z_hat: jax.Array) -> jax.Array:
        o = unpack_pairs(z_hat, self.real_dtype) / self.m    # (m, L) real
        return deinterleave(o)                               # (s,) real

    def worker_compute(self, a: jax.Array) -> jax.Array:
        return self._fft1_worker(a, inverse=True)
