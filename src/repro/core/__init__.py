"""Coded FFT core library (Yu, Maddah-Ali, Avestimehr 2017).

The paper's primary contribution: straggler-optimal coded computation of
discrete Fourier transforms.  See DESIGN.md §1 for the construction.
"""

from repro.core.coded_fft import CodedFFT, CodedFFTND, plan_factors
from repro.core.fault_tolerance import RobustCodedFFT, robust_decode
from repro.core.interleave import (
    deinterleave,
    deinterleave_nd,
    interleave,
    interleave_nd,
)
from repro.core.mds import (
    decode_auto,
    decode_from_subset,
    decode_ifft,
    decode_masked,
    encode,
    encode_dft,
    first_available,
    is_contiguous_subset,
    rs_generator,
    rs_nodes,
)
from repro.core.plan import CodedPlan, MDSPlan, MDSPlanBase
from repro.core.multi_input import CodedFFTMultiInput
from repro.core.recombine import (
    dft_matrix,
    recombine,
    recombine_half,
    recombine_nd,
    twiddle,
)
from repro.core.rfft import (
    CodedIFFT,
    CodedIRFFT,
    CodedRFFT,
    hermitian_extend,
    pack_half,
    pack_pairs,
    require_even_shards,
    split_packed,
)
from repro.core.rfftn import (
    CodedIRFFTN,
    CodedRFFTN,
    adjoint_fold_nd,
    hermitian_extend_nd,
    neg_freq,
    pack_half_nd,
    split_packed_nd,
)
from repro.core.strategies import (
    REGISTRY,
    CodedCommEffFFT,
    CodedPartialFFT,
    StrategyEntry,
    UncodedRepetitionFFT,
    coded_fft_threshold,
    make_strategy,
    register_strategy,
    repetition_threshold,
    short_dot_threshold,
)

__all__ = [
    "CodedFFT",
    "CodedFFTND",
    "CodedFFTMultiInput",
    "CodedRFFT",
    "CodedIFFT",
    "CodedIRFFT",
    "CodedRFFTN",
    "CodedIRFFTN",
    "pack_pairs",
    "pack_half",
    "split_packed",
    "hermitian_extend",
    "require_even_shards",
    "neg_freq",
    "split_packed_nd",
    "hermitian_extend_nd",
    "pack_half_nd",
    "adjoint_fold_nd",
    "recombine_half",
    "CodedPlan",
    "MDSPlan",
    "MDSPlanBase",
    "RobustCodedFFT",
    "robust_decode",
    "plan_factors",
    "decode_auto",
    "decode_ifft",
    "is_contiguous_subset",
    "interleave",
    "deinterleave",
    "interleave_nd",
    "deinterleave_nd",
    "rs_generator",
    "rs_nodes",
    "encode",
    "encode_dft",
    "decode_from_subset",
    "decode_masked",
    "first_available",
    "recombine",
    "recombine_nd",
    "dft_matrix",
    "twiddle",
    "UncodedRepetitionFFT",
    "CodedPartialFFT",
    "CodedCommEffFFT",
    "StrategyEntry",
    "REGISTRY",
    "register_strategy",
    "make_strategy",
    "coded_fft_threshold",
    "repetition_threshold",
    "short_dot_threshold",
]
