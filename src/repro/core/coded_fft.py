"""Coded FFT -- the paper's optimal computation strategy (Theorem 1).

Pipeline (§III-B):

  1. ``interleave``     : x -> (c_0, ..., c_{m-1}),  c_i[j] = x[i + j*m]
  2. ``encode``         : (N, m)-MDS code over the shards -> a_0..a_{N-1}
  3. ``worker_compute`` : b_k = DFT_{s/m}(a_k)   (linearity => the b_k carry
                          the same MDS code over the C_i = DFT(c_i))
  4. ``decode``         : any m of the b_k -> all C_i  (MDS inversion)
  5. ``recombine``      : twiddle + length-m DFTs -> X  (eq. 23/24)

Recovery threshold is exactly ``m`` -- the master never needs more than the
fastest ``m`` workers, which is information-theoretically optimal (Thm 2).

Both plans here implement the :class:`repro.core.plan.MDSPlan` protocol:
every stage threads leading batch axes, encode is the O(N log N) zero-padded
DFT, and decode dispatches to the O(s log N) transform decode on contiguous
responder subsets (DESIGN.md §2/§4).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.interleave import interleave, interleave_nd
from repro.core.plan import MDSPlanBase
from repro.core import mds
from repro.core.recombine import recombine, recombine_nd
from repro.kernels import ops

__all__ = ["CodedFFT", "CodedFFTND", "plan_factors"]


def _default_fft(a: jax.Array) -> jax.Array:
    """Reference worker computation: length-L FFT along the last axis."""
    return jnp.fft.fft(a, axis=-1)


@dataclasses.dataclass(frozen=True)
class CodedFFT(MDSPlanBase):
    """1-D coded FFT computation strategy.

    Args:
      s: transform length.
      m: storage fraction parameter -- each worker stores/processes s/m.
      n_workers: N >= m workers.
      dtype: complex dtype of the computation.
      worker_fn: explicit per-worker DFT plug-in; must transform the LAST
        axis and map over arbitrary leading axes.  ``None`` (default)
        dispatches on ``backend``: the Pallas four-step kernel for
        complex64 plans, jnp.fft otherwise.
      backend: ``"kernel"`` (default) or ``"reference"`` -- see
        ``MDSPlanBase.resolved_backend`` for the dispatch rules.
    """

    s: int
    m: int
    n_workers: int
    dtype: jnp.dtype = jnp.complex64
    worker_fn: Optional[Callable[[jax.Array], jax.Array]] = None
    backend: str = "kernel"

    def __post_init__(self):
        if self.s % self.m != 0:
            raise ValueError(f"m={self.m} must divide s={self.s}")
        if self.n_workers < self.m:
            raise ValueError(
                f"need N >= m for recoverability, got N={self.n_workers} m={self.m}"
            )

    @property
    def shard_len(self) -> int:
        return self.s // self.m

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.s,)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return (self.shard_len,)

    @property
    def recovery_threshold(self) -> int:
        """Theorem 1: K* = m."""
        return self.m

    @property
    def generator(self) -> jax.Array:
        return mds.rs_generator(self.n_workers, self.m, self.dtype)

    # -- stage cores (see MDSPlanBase for the batched entry points) ----------
    def _message1(self, x: jax.Array) -> jax.Array:
        return interleave(x.astype(self.dtype), self.m)

    def _postdecode1(self, c_hat: jax.Array) -> jax.Array:
        return recombine(c_hat, self.s)

    # back-compat alias: `encode` IS the fast path now
    def encode_fast(self, x: jax.Array) -> jax.Array:
        """O(N log N)-per-column encode (alias of :meth:`encode`)."""
        return self.encode(x)

    # -- stage 3: worker computation -----------------------------------------
    @property
    def resolved_worker_fn(self) -> Callable[[jax.Array], jax.Array]:
        """The active worker: explicit plug-in > kernel backend > jnp."""
        if self.worker_fn is not None:
            return self.worker_fn
        if self.resolved_backend == "kernel":
            return ops.make_kernel_worker_fn()
        return _default_fft

    def worker_compute(self, a: jax.Array) -> jax.Array:
        """Each worker FFTs its own coded shard; any leading axes allowed."""
        return self.resolved_worker_fn(a)


def plan_factors(shape: tuple[int, ...], m: int,
                 even_last_shard: bool = False) -> tuple[int, ...]:
    """Pick per-axis interleave factors with prod(m_k) = m, m_k | s_k.

    Greedy: peel prime factors of m off the largest remaining axis that
    admits them.  Raises if m cannot be factored across the axes.

    ``even_last_shard=True`` (the real n-D kinds, DESIGN.md §9) reserves
    a factor of 2 of slack on the LAST axis so the returned factors
    always satisfy the pair-packing constraint
    ``2 * factors[-1] | shape[-1]`` whenever any valid placement exists
    -- without it, the greedy choice can land a prime on the last axis
    and leave an odd shard that a different placement would have
    avoided.  Requires an even last axis (the documented ``2m | s``
    ValueError otherwise).
    """
    if even_last_shard:
        from repro.core.rfft import require_even_shards

        if shape[-1] % 2 != 0:
            require_even_shards(shape[-1], 1, axis=len(shape) - 1)
        inner = plan_factors(
            tuple(shape[:-1]) + (shape[-1] // 2,), m)
        return inner
    remaining = m
    factors = [1] * len(shape)
    caps = list(shape)
    primes = []
    d, r = 2, remaining
    while d * d <= r:
        while r % d == 0:
            primes.append(d)
            r //= d
        d += 1
    if r > 1:
        primes.append(r)
    for p in sorted(primes, reverse=True):
        # place p on the axis with the largest remaining quotient divisible by p
        best = None
        for k in range(len(shape)):
            if caps[k] % (factors[k] * p) == 0:
                q = caps[k] // (factors[k] * p)
                if best is None or q > best[1]:
                    best = (k, q)
        if best is None:
            raise ValueError(f"cannot split m={m} across shape {shape}")
        factors[best[0]] *= p
    assert math.prod(factors) == m
    return tuple(factors)


@dataclasses.dataclass(frozen=True)
class CodedFFTND(MDSPlanBase):
    """n-D coded FFT (Theorem 3).  ``factors[k]`` divides ``shape[k]`` and
    ``prod(factors) = m``."""

    shape: tuple[int, ...]
    factors: tuple[int, ...]
    n_workers: int
    dtype: jnp.dtype = jnp.complex64
    backend: str = "kernel"

    def __post_init__(self):
        for sk, mk in zip(self.shape, self.factors):
            if sk % mk != 0:
                raise ValueError(f"factor {mk} must divide dim {sk}")
        if self.n_workers < self.m:
            raise ValueError("need N >= m")

    @property
    def m(self) -> int:
        return math.prod(self.factors)

    @property
    def shard_shape(self) -> tuple[int, ...]:
        return tuple(sk // mk for sk, mk in zip(self.shape, self.factors))

    @property
    def input_shape(self) -> tuple[int, ...]:
        return tuple(self.shape)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return tuple(self.shape)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return self.shard_shape

    @property
    def recovery_threshold(self) -> int:
        return self.m

    @property
    def generator(self) -> jax.Array:
        return mds.rs_generator(self.n_workers, self.m, self.dtype)

    def _message1(self, t: jax.Array) -> jax.Array:
        return interleave_nd(t.astype(self.dtype), self.factors)

    def _postdecode1(self, c_hat: jax.Array) -> jax.Array:
        return recombine_nd(c_hat, self.shape, self.factors)

    def worker_compute(self, a: jax.Array) -> jax.Array:
        """n-D FFT of each coded tensor over the trailing shard axes."""
        return self._fftn_worker(a, len(self.shape))
