"""n-D real-input and real-output coded transforms (DESIGN.md §9).

The paper's §V extension covers general n-dimensional transforms; PR 3
added the half-payload REAL kinds in 1-D only.  This module closes the
gap: :class:`CodedRFFTN` and :class:`CodedIRFFTN` are
:class:`repro.core.plan.MDSPlan` instances over the SAME ``(N, m)``
complex-RS code as every other plan, composing the 1-D pair-packing trick
with the existing n-D interleave / recombine machinery -- no new code, no
new decode stack, half-size worker shards.

The composition (forward, r2c):

1. ``interleave_nd`` the real tensor by ``factors`` (paper eq. 28) into
   ``m = prod(factors)`` real shards of shape ``(L_0, ..., L_{n-1})``;
2. pair-pack each shard along its LAST axis:
   ``z[..., j] = c[..., 2j] + 1j*c[..., 2j+1]`` -- workers transform and
   ship shards with a HALVED last axis (``2*factors[-1] | shape[-1]``);
3. workers run the ordinary n-D FFT over the trailing shard axes (the
   per-axis four-step kernel sweep on the kernel backend), so encode /
   decode / the distributed runtime apply unchanged;
4. postdecode runs the GENERALIZED split butterfly: for packed n-D real
   data the 1-D identity ``E_p = (Z_p + conj(Z_{n2-p}))/2`` picks up a
   frequency negation on every OTHER shard axis, because conjugation
   flips the sign of all frequencies jointly
   (``fftn(c)[-q, -p] = conj(fftn(c)[q, p])`` for real ``c``).  The
   same negation appears in the joint Hermitian extension.  Both are
   anti-linear -- master-side only, after decode, never inside the code;
5. ``recombine_nd`` (paper eq. 31) then one slice keeps the
   ``shape[-1]//2 + 1`` non-redundant last-axis bins: exactly
   ``numpy.fft.rfftn``.

:class:`CodedIRFFTN` is the adjoint, generalizing ``CodedIRFFT``: the
master Hermitian-symmetrizes the half-spectrum request (endpoint bins
are averaged with their negated-frequency conjugates, which reproduces
``numpy.fft.irfftn`` EXACTLY even on non-Hermitian-consistent input),
runs the per-axis ADJOINT of the recombine butterfly
(:func:`adjoint_fold_nd`: +sign length-``m_d`` DFT + conjugate twiddle
per axis), packs each per-shard Hermitian spectrum
(:func:`pack_half_nd`), and lets workers ``ifftn`` the packed coded
shards; postdecode unpacks real/imag pairs and de-interleaves.

Both kinds require an EVEN last shard axis (``2*factors[-1]`` must
divide ``shape[-1]``); :func:`repro.core.rfft.require_even_shards`
raises the documented ``ValueError`` otherwise.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import mds
from repro.core.interleave import deinterleave_nd, interleave_nd
from repro.core.plan import MDSPlanBase
from repro.core.recombine import dft_matrix, recombine_nd
from repro.core.rfft import (
    _real_dtype,
    pack_pairs,
    require_even_shards,
    unpack_pairs,
)

__all__ = [
    "CodedRFFTN",
    "CodedIRFFTN",
    "neg_freq",
    "split_packed_nd",
    "hermitian_extend_nd",
    "pack_half_nd",
    "adjoint_fold_nd",
]


# ---------------------------------------------------------------- symmetry ops
def neg_freq(a: jax.Array, axes: tuple[int, ...]) -> jax.Array:
    """Frequency negation ``q -> (-q) mod L`` along each axis in ``axes``.

    The index map conjugation induces on every non-halved axis: for real
    ``c``, ``fftn(c)`` is Hermitian JOINTLY across all axes, so the 1-D
    split/extend identities hold n-D once their conjugated terms are also
    frequency-negated along the remaining axes.
    """
    for ax in axes:
        a = jnp.roll(jnp.flip(a, axis=ax), 1, axis=ax)
    return a


def split_packed_nd(z_hat: jax.Array, ell: int,
                    rest_axes: tuple[int, ...]) -> jax.Array:
    """Generalized split butterfly: packed n-D spectra -> half spectra.

    ``z_hat``: ``(..., R..., L/2)`` with ``z = pack_pairs(c)`` along the
    last axis of real ``c``; ``rest_axes`` index the non-halved transform
    axes of ``z_hat``.  Returns ``(..., R..., L/2 + 1)``: the transform of
    ``c`` restricted to the non-redundant last-axis bins.  Anti-linear
    (conjugates its input): master-side only, never inside the code.
    """
    n2 = z_hat.shape[-1]
    zext = jnp.concatenate([z_hat, z_hat[..., :1]], axis=-1)
    zrev = jnp.conj(neg_freq(zext[..., ::-1], rest_axes))
    even = 0.5 * (zext + zrev)
    odd = -0.5j * (zext - zrev)
    w = jnp.exp(-2j * jnp.pi * jnp.arange(n2 + 1) / ell).astype(z_hat.dtype)
    return even + odd * w


def hermitian_extend_nd(c_half: jax.Array,
                        rest_axes: tuple[int, ...]) -> jax.Array:
    """Joint Hermitian extension ``C[-q, L-p] = conj(C[q, p])`` along the
    last axis: ``(..., L/2 + 1) -> (..., L)``."""
    n2 = c_half.shape[-1] - 1
    tail = jnp.conj(neg_freq(c_half[..., n2 - 1:0:-1], rest_axes))
    return jnp.concatenate([c_half, tail], axis=-1)


def pack_half_nd(c_full: jax.Array, ell: int,
                 rest_axes: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`split_packed_nd`: jointly-Hermitian n-D spectrum
    ``(..., L)`` of a real signal -> packed spectrum ``(..., L/2)`` whose
    ``ifftn`` is the pair-packed real signal."""
    n2 = ell // 2
    ch = c_full[..., : n2 + 1]
    crev = jnp.conj(neg_freq(ch[..., ::-1], rest_axes))
    even = 0.5 * (ch + crev)
    w = jnp.exp(2j * jnp.pi * jnp.arange(n2 + 1) / ell).astype(ch.dtype)
    odd = 0.5 * (ch - crev) * w
    return (even + 1j * odd)[..., :n2]


def adjoint_fold_nd(full: jax.Array, shape: tuple[int, ...],
                    factors: tuple[int, ...], dtype) -> jax.Array:
    """Adjoint of :func:`repro.core.recombine.recombine_nd`.

    ``full``: the full n-D spectrum ``(s_0, ..., s_{n-1})``.  Returns the
    ``(m, L_0, ..., L_{n-1})`` folded shard spectra

        ``folded_k[t] = sum_r full[t_d + r_d L_d]
                        prod_d omega_{m_d}^{+k_d r_d} omega_{s_d}^{+k_d t_d}``

    -- per axis, a +sign length-``m_d`` DFT across the fold plus the
    conjugate recombine twiddle, so that ``ifftn(folded_k)`` is exactly
    the ``k``-th interleave shard of ``ifftn(full) * m``.
    """
    n = len(shape)
    ells = tuple(sd // md for sd, md in zip(shape, factors))
    rs: list[int] = []
    for sd, md in zip(shape, factors):
        rs.extend([md, sd // md])
    c = full.reshape(rs)                      # (m_0, L_0, m_1, L_1, ...)
    c = jnp.transpose(
        c, [2 * k for k in range(n)] + [2 * k + 1 for k in range(n)])
    for d in range(n):
        md, sd, ld = factors[d], shape[d], ells[d]
        f = dft_matrix(md, dtype, sign=+1.0)
        c = jnp.tensordot(f, c, axes=([1], [d]))
        c = jnp.moveaxis(c, 0, d)
        tw = jnp.exp(
            2j * jnp.pi * jnp.outer(jnp.arange(md), jnp.arange(ld)) / sd
        ).astype(dtype)
        bshape = [1] * (2 * n)
        bshape[d] = md
        bshape[n + d] = ld
        c = c * tw.reshape(bshape)
    return c.reshape((math.prod(factors),) + ells)


# ------------------------------------------------------------------ the plans
@dataclasses.dataclass(frozen=True)
class _RSNDRealPlanBase(MDSPlanBase):
    """Shared fields/validation of the n-D real transform plans.

    ``factors[k]`` divides ``shape[k]``, ``prod(factors) = m``, and the
    LAST shard axis must be even (``2*factors[-1] | shape[-1]``) for the
    pair packing -- :func:`repro.core.rfft.require_even_shards` raises
    the documented ``ValueError`` otherwise.
    """

    shape: tuple[int, ...]
    factors: tuple[int, ...]
    n_workers: int
    dtype: jnp.dtype = jnp.complex64
    backend: str = "kernel"

    def __post_init__(self):
        if not self.shape or len(self.shape) != len(self.factors):
            raise ValueError(
                f"factors {self.factors} must match shape {self.shape}")
        for sk, mk in zip(self.shape[:-1], self.factors[:-1]):
            if mk < 1 or sk % mk != 0:
                raise ValueError(f"factor {mk} must divide dim {sk}")
        require_even_shards(self.shape[-1], self.factors[-1],
                            axis=len(self.shape) - 1)
        if self.n_workers < self.m:
            raise ValueError(
                f"need N >= m, got N={self.n_workers} m={self.m}")

    @property
    def m(self) -> int:
        return math.prod(self.factors)

    @property
    def nd(self) -> int:
        return len(self.shape)

    @property
    def shard_shape(self) -> tuple[int, ...]:
        """Per-worker TIME-domain shard shape (the shipped packed payload
        halves the last axis)."""
        return tuple(sk // mk for sk, mk in zip(self.shape, self.factors))

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        ells = self.shard_shape
        return ells[:-1] + (ells[-1] // 2,)

    @property
    def real_dtype(self) -> jnp.dtype:
        return _real_dtype(self.dtype)

    @property
    def recovery_threshold(self) -> int:
        return self.m

    @property
    def generator(self) -> jax.Array:
        return mds.rs_generator(self.n_workers, self.m, self.dtype)

    @property
    def _rest_axes(self) -> tuple[int, ...]:
        """The non-halved shard axes of an ``(m, L_0, ..)`` stack: every
        spatial axis except the packed last one (axis 0 is the shard
        index, untouched by the symmetry ops)."""
        return tuple(range(1, self.nd))


@dataclasses.dataclass(frozen=True)
class CodedRFFTN(_RSNDRealPlanBase):
    """n-D real-input coded FFT: ``shape`` real -> half-spectrum complex
    (`shape[:-1] + (shape[-1]//2 + 1,)`), matching ``numpy.fft.rfftn``.

    Workers transform pair-packed shards with a halved last axis -- half
    the per-worker flops and HALF the wire payload of
    :class:`~repro.core.coded_fft.CodedFFTND` at the same ``(shape, m)``
    -- through the unchanged per-axis four-step kernel sweep, MDS decode
    stack, and distributed runtime.
    """

    kind: str = dataclasses.field(default="rfftn", init=False)

    @property
    def input_shape(self) -> tuple[int, ...]:
        return tuple(self.shape)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return tuple(self.shape[:-1]) + (self.shape[-1] // 2 + 1,)

    def _message1(self, t: jax.Array) -> jax.Array:
        if jnp.iscomplexobj(t):
            t = jnp.real(t)
        c = interleave_nd(t.astype(self.real_dtype), self.factors)
        return pack_pairs(c, self.dtype)        # (m, *ells[:-1], L/2)

    def _postdecode1(self, z_hat: jax.Array) -> jax.Array:
        ells = self.shard_shape
        c_half = split_packed_nd(z_hat, ells[-1], self._rest_axes)
        c_full = hermitian_extend_nd(c_half, self._rest_axes)
        full = recombine_nd(c_full, self.shape, self.factors)
        return full[..., : self.shape[-1] // 2 + 1]

    def worker_compute(self, a: jax.Array) -> jax.Array:
        return self._fftn_worker(a, self.nd)


@dataclasses.dataclass(frozen=True)
class CodedIRFFTN(_RSNDRealPlanBase):
    """n-D inverse real coded FFT: half spectrum
    (`shape[:-1] + (shape[-1]//2 + 1,)`) -> ``shape`` real, matching
    ``numpy.fft.irfftn`` -- the adjoint of :class:`CodedRFFTN`.

    The message stage symmetrizes the request so the endpoint last-axis
    bins are treated exactly as ``numpy.fft.irfftn`` treats them (their
    anti-Hermitian parts are discarded AFTER the other axes' inverse
    transforms -- reproduced here in the spectral domain by averaging
    each endpoint bin with its negated-frequency conjugate), folds with
    the per-axis adjoint recombine butterfly, and pair-packs; workers
    ``ifftn`` half-size shards, and postdecode is a pure relabeling.
    """

    kind: str = dataclasses.field(default="irfftn", init=False)

    @property
    def input_shape(self) -> tuple[int, ...]:
        return tuple(self.shape[:-1]) + (self.shape[-1] // 2 + 1,)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return tuple(self.shape)

    def _message1(self, y: jax.Array) -> jax.Array:
        nd = self.nd
        rest_full = tuple(range(nd - 1))        # no shard axis yet
        y = y.astype(self.dtype)
        head = 0.5 * (y[..., :1] + jnp.conj(neg_freq(y[..., :1], rest_full)))
        last = 0.5 * (y[..., -1:] + jnp.conj(neg_freq(y[..., -1:], rest_full)))
        mid = y[..., 1:-1]
        tail = jnp.conj(neg_freq(mid, rest_full))[..., ::-1]
        full = jnp.concatenate([head, mid, last, tail], axis=-1)
        folded = adjoint_fold_nd(full, self.shape, self.factors, self.dtype)
        return pack_half_nd(folded, self.shard_shape[-1], self._rest_axes)

    def _postdecode1(self, z_hat: jax.Array) -> jax.Array:
        o = unpack_pairs(z_hat, self.real_dtype) / self.m   # (m, *ells) real
        return deinterleave_nd(o, self.factors, self.shape)

    def worker_compute(self, a: jax.Array) -> jax.Array:
        return self._ifftn_worker(a, self.nd)
