"""Coded FFT with multiple inputs (paper §VI, Theorem 5).

``q`` input tensors of shape ``s_0 x ... x s_{n-1}``; each worker stores a
``1/m`` fraction of the *total* ``q*s`` elements, with ``m = m_tilde *
prod(m_k)``, ``m_tilde | q`` and ``m_k | s_k``.

Strategy: bundle the q inputs into ``m_tilde`` disjoint subsets of size
``q/m_tilde``; within a subset, all interleaved tensors sharing an index
tuple ``(i_0..i_{n-1})`` form one message symbol.  The resulting ``m``
symbols are encoded with an (N, m)-MDS code; every worker FFTs all coded
tensors in its symbol.  Any ``m`` responders suffice (K* = m, Thm 5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import mds
from repro.core.interleave import interleave_nd
from repro.core.recombine import recombine_nd

__all__ = ["CodedFFTMultiInput"]


@dataclasses.dataclass(frozen=True)
class CodedFFTMultiInput:
    q: int
    shape: tuple[int, ...]
    m_tilde: int
    factors: tuple[int, ...]
    n_workers: int
    dtype: jnp.dtype = jnp.complex64

    def __post_init__(self):
        if self.q % self.m_tilde != 0:
            raise ValueError("m_tilde must divide q")
        for sk, mk in zip(self.shape, self.factors):
            if sk % mk != 0:
                raise ValueError(f"factor {mk} must divide dim {sk}")
        if self.n_workers < self.m:
            raise ValueError("need N >= m")

    @property
    def m_spatial(self) -> int:
        return math.prod(self.factors)

    @property
    def m(self) -> int:
        return self.m_tilde * self.m_spatial

    @property
    def recovery_threshold(self) -> int:
        return self.m

    @property
    def group_size(self) -> int:
        return self.q // self.m_tilde

    @property
    def shard_shape(self) -> tuple[int, ...]:
        return tuple(sk // mk for sk, mk in zip(self.shape, self.factors))

    @property
    def generator(self) -> jax.Array:
        return mds.rs_generator(self.n_workers, self.m, self.dtype)

    def encode(self, t: jax.Array) -> jax.Array:
        """``t``: (q, *shape) -> coded symbols (N, q/m_tilde, *shard_shape)."""
        if t.shape != (self.q,) + tuple(self.shape):
            raise ValueError(f"expected {(self.q,) + tuple(self.shape)}, got {t.shape}")
        c = jax.vmap(lambda u: interleave_nd(u, self.factors))(t.astype(self.dtype))
        # (q, m_sp, *shard) -> (m_tilde, group, m_sp, *shard)
        c = c.reshape((self.m_tilde, self.group_size, self.m_spatial) + self.shard_shape)
        # symbols axis = (m_tilde, m_sp) row-major -> (m, group, *shard)
        c = jnp.swapaxes(c, 1, 2).reshape(
            (self.m, self.group_size) + self.shard_shape
        )
        return mds.encode(self.generator, c)

    def worker_compute(self, a: jax.Array) -> jax.Array:
        axes = tuple(range(2, 2 + len(self.shape)))
        return jnp.fft.fftn(a, axes=axes)

    def decode(
        self,
        b: jax.Array,
        subset: Optional[jax.Array] = None,
        mask: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Worker results (N, group, *shard) -> output tensors (q, *shape)."""
        if subset is None:
            if mask is not None:
                subset = mds.first_available(mask, self.m)
            else:
                subset = jnp.arange(self.m)
        sym = mds.decode_from_subset(self.generator, b, subset)
        # (m, group, *shard) -> (m_tilde, m_sp, group, *shard) -> (q, m_sp, *shard)
        sym = sym.reshape(
            (self.m_tilde, self.m_spatial, self.group_size) + self.shard_shape
        )
        sym = jnp.swapaxes(sym, 1, 2).reshape(
            (self.q, self.m_spatial) + self.shard_shape
        )
        return jax.vmap(lambda u: recombine_nd(u, self.shape, self.factors))(sym)

    def run(
        self,
        t: jax.Array,
        subset: Optional[jax.Array] = None,
        mask: Optional[jax.Array] = None,
    ) -> jax.Array:
        b = self.worker_compute(self.encode(t))
        return self.decode(b, subset=subset, mask=mask)
