"""Coded FFT with multiple inputs (paper §VI, Theorem 5).

``q`` input tensors of shape ``s_0 x ... x s_{n-1}``; each worker stores a
``1/m`` fraction of the *total* ``q*s`` elements, with ``m = m_tilde *
prod(m_k)``, ``m_tilde | q`` and ``m_k | s_k``.

Strategy: bundle the q inputs into ``m_tilde`` disjoint subsets of size
``q/m_tilde``; within a subset, all interleaved tensors sharing an index
tuple ``(i_0..i_{n-1})`` form one message symbol.  The resulting ``m``
symbols are encoded with an (N, m)-MDS code; every worker FFTs all coded
tensors in its symbol.  Any ``m`` responders suffice (K* = m, Thm 5).

Implements :class:`repro.core.plan.MDSPlan`: batched shapes, DFT fast
encode, and contiguous-subset fast decode come from ``MDSPlanBase``.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import mds
from repro.core.interleave import interleave_nd
from repro.core.plan import MDSPlanBase
from repro.core.recombine import recombine_nd

__all__ = ["CodedFFTMultiInput"]


@dataclasses.dataclass(frozen=True)
class CodedFFTMultiInput(MDSPlanBase):
    q: int
    shape: tuple[int, ...]
    m_tilde: int
    factors: tuple[int, ...]
    n_workers: int
    dtype: jnp.dtype = jnp.complex64
    backend: str = "kernel"

    def __post_init__(self):
        if self.q % self.m_tilde != 0:
            raise ValueError("m_tilde must divide q")
        for sk, mk in zip(self.shape, self.factors):
            if sk % mk != 0:
                raise ValueError(f"factor {mk} must divide dim {sk}")
        if self.n_workers < self.m:
            raise ValueError("need N >= m")

    @property
    def m_spatial(self) -> int:
        return math.prod(self.factors)

    @property
    def m(self) -> int:
        return self.m_tilde * self.m_spatial

    @property
    def recovery_threshold(self) -> int:
        return self.m

    @property
    def group_size(self) -> int:
        return self.q // self.m_tilde

    @property
    def shard_shape(self) -> tuple[int, ...]:
        return tuple(sk // mk for sk, mk in zip(self.shape, self.factors))

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.q,) + tuple(self.shape)

    @property
    def output_shape(self) -> tuple[int, ...]:
        return (self.q,) + tuple(self.shape)

    @property
    def worker_shard_shape(self) -> tuple[int, ...]:
        return (self.group_size,) + self.shard_shape

    @property
    def generator(self) -> jax.Array:
        return mds.rs_generator(self.n_workers, self.m, self.dtype)

    def _message1(self, t: jax.Array) -> jax.Array:
        """``t``: (q, *shape) -> message symbols (m, q/m_tilde, *shard_shape)."""
        if t.shape != self.input_shape:
            raise ValueError(f"expected {self.input_shape}, got {t.shape}")
        c = jax.vmap(lambda u: interleave_nd(u, self.factors))(t.astype(self.dtype))
        # (q, m_sp, *shard) -> (m_tilde, group, m_sp, *shard)
        c = c.reshape((self.m_tilde, self.group_size, self.m_spatial) + self.shard_shape)
        # symbols axis = (m_tilde, m_sp) row-major -> (m, group, *shard)
        return jnp.swapaxes(c, 1, 2).reshape(
            (self.m, self.group_size) + self.shard_shape
        )

    def _postdecode1(self, sym: jax.Array) -> jax.Array:
        """Decoded symbols (m, group, *shard) -> output tensors (q, *shape)."""
        # (m, group, *shard) -> (m_tilde, m_sp, group, *shard) -> (q, m_sp, *shard)
        sym = sym.reshape(
            (self.m_tilde, self.m_spatial, self.group_size) + self.shard_shape
        )
        sym = jnp.swapaxes(sym, 1, 2).reshape(
            (self.q, self.m_spatial) + self.shard_shape
        )
        return jax.vmap(lambda u: recombine_nd(u, self.shape, self.factors))(sym)

    def worker_compute(self, a: jax.Array) -> jax.Array:
        """n-D FFT of every coded tensor over the trailing spatial axes."""
        return self._fftn_worker(a, len(self.shape))
