"""Seeded, deterministic fault injection for the coded worker runtime.

The paper's robustness claims (any ``m`` of ``N`` responses recover the
output; ``k`` responses detect ``k - m`` / correct ``floor((k - m)/2)``
Byzantine workers) are only claims until the runtime is exercised under
actual failures.  This module turns failure modes into data:

* ``WorkerFault`` -- one scheduled fault: ``kill`` (worker never responds
  for ``rounds`` consecutive rounds), ``delay`` (worker responds
  ``delay_s`` seconds late), or ``corrupt`` (worker responds on time with
  arbitrarily wrong rows -- the Byzantine case).
* ``FaultPlan`` -- an immutable schedule of faults plus a seed.  Either
  hand-built (``FaultPlan.single(...)``, chained ``.kill/.delay/.corrupt``)
  or drawn (``FaultPlan.random(...)``) -- both fully deterministic, so a
  failing CI run reproduces from its seed alone.
* ``FaultInjector`` -- the runtime view: ``faults_for(round)`` projects the
  plan onto one round as a ``RoundFaults`` (killed/delayed/corrupt sets),
  ``corrupt_array`` applies seeded, round- and worker-keyed garbage to
  worker output rows, and ``perturb_latencies`` folds kill/delay into a
  vector of (simulated or measured) completion times.

Injection is an *opt-in hook*: ``DistributedCodedPlan.run(faults=...)``
and ``FFTServiceConfig(faults=...)`` thread a plan through; with no plan
every code path is byte-identical to the fault-free build.  DESIGN.md §12.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "WorkerFault",
    "RoundFaults",
    "FaultPlan",
    "FaultInjector",
]

FAULT_KINDS = ("kill", "delay", "corrupt")


@dataclasses.dataclass(frozen=True)
class WorkerFault:
    """One scheduled fault against one worker.

    Active for rounds ``start_round <= r < start_round + rounds``.
    ``delay_s`` only applies to ``kind == "delay"``.
    """

    worker: int
    kind: str
    start_round: int = 0
    rounds: int = 1
    delay_s: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}")
        if self.rounds < 1:
            raise ValueError("fault must span >= 1 round")
        if self.kind == "delay" and self.delay_s < 0:
            raise ValueError("delay_s must be >= 0")

    def active(self, round_idx: int) -> bool:
        return self.start_round <= round_idx < self.start_round + self.rounds


@dataclasses.dataclass(frozen=True)
class RoundFaults:
    """Projection of a FaultPlan onto a single round."""

    killed: FrozenSet[int] = frozenset()
    delays: Tuple[Tuple[int, float], ...] = ()  # (worker, seconds), sorted
    corrupt: FrozenSet[int] = frozenset()

    @property
    def delay_map(self) -> Dict[int, float]:
        return dict(self.delays)

    @property
    def any(self) -> bool:
        return bool(self.killed or self.delays or self.corrupt)


_EMPTY_ROUND = RoundFaults()


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, seeded schedule of worker faults.

    ``seed`` keys the corruption noise (and ``FaultPlan.random`` draws), so
    two runs with the same plan inject bit-identical faults.
    """

    faults: Tuple[WorkerFault, ...] = ()
    seed: int = 0

    # -- builders ---------------------------------------------------------
    def kill(self, worker: int, *, start_round: int = 0, rounds: int = 1) -> "FaultPlan":
        return self._with(WorkerFault(worker, "kill", start_round, rounds))

    def delay(self, worker: int, delay_s: float, *, start_round: int = 0,
              rounds: int = 1) -> "FaultPlan":
        return self._with(WorkerFault(worker, "delay", start_round, rounds, delay_s))

    def corrupt(self, worker: int, *, start_round: int = 0, rounds: int = 1) -> "FaultPlan":
        return self._with(WorkerFault(worker, "corrupt", start_round, rounds))

    def _with(self, fault: WorkerFault) -> "FaultPlan":
        return dataclasses.replace(self, faults=self.faults + (fault,))

    @staticmethod
    def single(worker: int, kind: str, *, delay_s: float = 0.0,
               start_round: int = 0, rounds: int = 1, seed: int = 0) -> "FaultPlan":
        return FaultPlan((WorkerFault(worker, kind, start_round, rounds, delay_s),), seed)

    @staticmethod
    def random(n_workers: int, rate: float, *, kinds: Sequence[str] = FAULT_KINDS,
               rounds: int = 1, horizon: int = 64, delay_s: float = 0.05,
               seed: int = 0) -> "FaultPlan":
        """Draw a seeded schedule: each (round, worker) faults w.p. ``rate``.

        ``rate`` is the per-round per-worker fault probability, so
        ``rate=1/N`` means on average one faulty worker per round (the
        bench's fault-rate axis).  Faults drawn at round ``r`` last
        ``rounds`` rounds.
        """
        if not 0.0 <= rate <= 1.0:
            raise ValueError("rate must be in [0, 1]")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        faults = []
        for r in range(horizon):
            hit = rng.random(n_workers) < rate
            for w in np.flatnonzero(hit):
                kind = kinds[int(rng.integers(len(kinds)))]
                d = float(delay_s * (0.5 + rng.random())) if kind == "delay" else 0.0
                faults.append(WorkerFault(int(w), kind, r, rounds, d))
        return FaultPlan(tuple(faults), seed)

    # -- queries ----------------------------------------------------------
    def faults_for(self, round_idx: int) -> RoundFaults:
        killed, corrupt, delays = set(), set(), {}
        for f in self.faults:
            if not f.active(round_idx):
                continue
            if f.kind == "kill":
                killed.add(f.worker)
            elif f.kind == "corrupt":
                corrupt.add(f.worker)
            else:
                delays[f.worker] = max(delays.get(f.worker, 0.0), f.delay_s)
        if not (killed or corrupt or delays):
            return _EMPTY_ROUND
        return RoundFaults(frozenset(killed), tuple(sorted(delays.items())),
                           frozenset(corrupt))

    @property
    def horizon(self) -> int:
        return max((f.start_round + f.rounds for f in self.faults), default=0)


class FaultInjector:
    """Runtime view of a FaultPlan: per-round fault sets + seeded corruption.

    Stateless with respect to rounds -- every method takes ``round_idx`` so
    replays and retries see identical faults.  Corruption noise is keyed by
    ``(plan.seed, round_idx, worker)``: deterministic, but distinct per
    round and per worker (adversarial patterns in tests rely on this).
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def faults_for(self, round_idx: int) -> RoundFaults:
        return self.plan.faults_for(round_idx)

    def corrupt_array(self, b: np.ndarray, workers: Iterable[int],
                      round_idx: int, *, worker_axis: int = -2) -> np.ndarray:
        """Return ``b`` with ``workers`` rows along ``worker_axis`` garbaged.

        The corruption is large-magnitude seeded noise -- arbitrary
        (Byzantine), not zeroing, so an unverified decode that includes a
        corrupt row produces visibly wrong output rather than small error.
        """
        workers = sorted(set(int(w) for w in workers))
        if not workers:
            return b
        out = np.array(b)  # copy; never corrupt the caller's buffer in place
        mv = np.moveaxis(out, worker_axis, 0)  # view: writes go through
        for w in workers:
            if not 0 <= w < mv.shape[0]:
                continue
            mv[w] = self.corrupt_payload(np.asarray(mv[w]), w, round_idx)
        return out

    def corrupt_payload(self, arr: np.ndarray, worker: int,
                        round_idx: int) -> np.ndarray:
        """The garbage one corrupt worker ships for this round.

        Keyed by ``(seed, round, worker)`` only, so the simulated service
        path and the measured thread runtime inject the same noise."""
        rng = np.random.default_rng((self.plan.seed, round_idx, worker))
        scale = max(float(np.abs(arr).max()), 1.0)
        noise = rng.standard_normal(arr.shape)
        if np.iscomplexobj(arr):
            noise = noise + 1j * rng.standard_normal(arr.shape)
        return (noise * (7.3 * scale)).astype(arr.dtype)

    def corrupt_flags(self, n_workers: int, round_idx: int) -> np.ndarray:
        """Boolean ``(n_workers,)`` corrupt mask for in-trace injection."""
        flags = np.zeros(n_workers, dtype=bool)
        for w in self.faults_for(round_idx).corrupt:
            if w < n_workers:
                flags[w] = True
        return flags

    def perturb_latencies(self, lat: np.ndarray, round_idx: int) -> np.ndarray:
        """Fold kill/delay faults into completion times ``(..., n_workers)``.

        Killed workers never finish (``inf``); delayed workers finish late.
        """
        rf = self.faults_for(round_idx)
        if not rf.any:
            return lat
        out = np.array(lat, dtype=np.float64)
        n = out.shape[-1]
        for w, d in rf.delays:
            if w < n:
                out[..., w] += d
        for w in rf.killed:
            if w < n:
                out[..., w] = np.inf
        return out
