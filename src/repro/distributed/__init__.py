"""Distribution substrate: logical sharding rules, meshes, coded runtime."""

from repro.distributed.coded_runtime import DistributedCodedFFT, DistributedCodedPlan
from repro.distributed.elastic import ElasticWorkerPool, reshard, reshard_like
from repro.distributed.faults import (
    FAULT_KINDS,
    FaultInjector,
    FaultPlan,
    RoundFaults,
    WorkerFault,
)
from repro.distributed.health import WorkerHealthTracker
from repro.distributed.mesh import test_mesh
from repro.distributed.sharding import (
    MULTI_POD_RULES,
    SINGLE_POD_RULES,
    current_mesh,
    logical_spec,
    lshard,
    named_sharding,
    use_rules,
)
from repro.distributed.straggler import StragglerModel, expected_kth_completion
from repro.distributed.worker_runtime import MeasuredRound, MeasuredWorkerRuntime

__all__ = [
    "DistributedCodedFFT",
    "DistributedCodedPlan",
    "ElasticWorkerPool",
    "FAULT_KINDS",
    "FaultInjector",
    "FaultPlan",
    "MULTI_POD_RULES",
    "MeasuredRound",
    "MeasuredWorkerRuntime",
    "RoundFaults",
    "SINGLE_POD_RULES",
    "StragglerModel",
    "WorkerFault",
    "WorkerHealthTracker",
    "current_mesh",
    "expected_kth_completion",
    "logical_spec",
    "lshard",
    "named_sharding",
    "reshard",
    "reshard_like",
    "test_mesh",
    "use_rules",
]
