"""Mesh helpers for tests and small-scale runs.

``launch/mesh.py`` owns the production meshes; this module only provides
CPU-friendly fakes: ``test_mesh(shape, axes)`` builds a mesh over however
many host devices exist (tests set ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` via their own env guard, never globally).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["test_mesh", "device_count_at_least"]


def device_count_at_least(n: int) -> bool:
    return jax.device_count() >= n


def test_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"test mesh {shape} needs {need} devices, have {len(devs)} "
            "(run under XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    arr = np.asarray(devs[:need]).reshape(shape)
    return Mesh(arr, axes)
