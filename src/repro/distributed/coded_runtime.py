"""shard_map execution of any MDS coded plan over a device mesh.

The paper's master/worker topology mapped to SPMD (DESIGN.md §3):

* **encode** -- each device holds the (replicated) message shards, computes
  only ITS coded shards: ``a_k = sum_i G[k,i] c_i`` (no collective; G rows
  are selected by ``axis_index``).  The message is produced host-side by
  ``plan.message`` (interleave), so the runtime works for every
  :class:`repro.core.plan.MDSPlan` -- 1-D, n-D, multi-input.
* **worker compute** -- per-device transform of its own shards, the hot
  loop.  ``plan.worker_compute`` acts on trailing shard axes, so the
  (batch, n_local) leading layout maps through unchanged.  Complex64 plans
  dispatch to the Pallas four-step kernel by default (interpret mode
  off-TPU, DESIGN.md §6); complex128 plans run the jnp oracle.
* **straggler mask** -- an explicit boolean input, per request when the
  input carries a batch axis.  In production the launcher populates it from
  collective timeouts; in tests/benchmarks the straggler simulator does.
  Masked workers' outputs are overwritten with ``masked_fill`` (0 by
  default; NaN in tests to *prove* decode never reads them).
* **decode** -- all-gather the worker results along the axis (the paper's
  fan-in to the master: exactly s coded symbols on the wire, the cut-set
  optimum of Remark 5), then every device runs the same masked MDS decode
  (fast-path dispatch per DESIGN.md §4; batched requests build per-mask
  Lagrange decode matrices IN-TRACE for ``m <= LAGRANGE_MAX_M``,
  DESIGN.md §8) + recombine.  Replicated decode wastes no wall-clock vs a
  physical master because the all-gather is the critical path either way.

``n_local = N // axis_size`` coded shards live on each device, so N need
not equal the device count (e.g. N=8 code on a 4-device axis).

The runtime is plan-generic by construction: every stage touches only
``plan.message`` / ``plan.worker_compute`` / ``plan.postdecode`` and the
``worker_shard_shape`` metadata, so the real-input and inverse plans of
DESIGN.md §7 (``CodedRFFT``/``CodedIFFT``/``CodedIRFFT``) and their n-D
generalizations of §9 (``CodedRFFTN``/``CodedIRFFTN``) run UNCHANGED:
their half-size packed shard shapes and per-request masks thread
through both shard_map stages exactly like the complex plans' (the real
kinds' wire payload per worker is half the c2c plan's at the same
``(s, m)``).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import mds
from repro.core.coded_fft import CodedFFT
from repro.core.plan import batch_shape
from repro.distributed.faults import FaultInjector, FaultPlan

__all__ = ["DistributedCodedPlan", "DistributedCodedFFT"]


@dataclasses.dataclass(frozen=True)
class DistributedCodedPlan:
    """Run any ``MDSPlan`` across a mesh axis with straggler masking.

    ``masked_fill`` is the value written into masked-out workers' result
    rows before they leave the device; the decode provably ignores those
    rows, which tests assert by setting it to NaN.
    """

    plan: object  # any repro.core.plan.MDSPlan
    mesh: Mesh
    axis: str = "workers"
    masked_fill: float = 0.0

    def __post_init__(self):
        size = self.mesh.shape[self.axis]
        if self.plan.n_workers % size != 0:
            raise ValueError(
                f"N={self.plan.n_workers} must be a multiple of axis "
                f"size {size}")

    @property
    def n_local(self) -> int:
        return self.plan.n_workers // self.mesh.shape[self.axis]

    # ------------------------------------------------------------------
    def run(self, x: jax.Array, mask: Optional[jax.Array] = None,
            *, fragment_mask: Optional[jax.Array] = None,
            method: str = "auto",
            faults: Optional[object] = None, round_idx: int = 0
            ) -> jax.Array:
        """End-to-end coded transform of ``x`` under the mesh.

        ``x``: ``(*B, *input_shape)``; ``mask``: bool ``(*B, N)`` or shared
        ``(N,)`` worker availability.  Default: all up.  Returns
        ``(*B, *output_shape)``.

        ``fragment_mask`` (plans with ``fragments > 1``, DESIGN.md §13):
        bool ``(*B, N, F)`` / ``(N, F)`` per-fragment availability -- a
        slow-but-alive worker contributes its finished prefix.  Combines
        with ``mask`` (a masked worker loses all its fragments).

        The strategy hooks (all optional, the base MDS plans use none):
        ``worker_encode_tensor`` ``(N, F, W)`` replaces per-worker
        generator rows, ``stored_shard_shape`` sizes the per-device
        buffer when a plan ships less than it stores, ``worker_compute_
        rows`` is the worker-index-aware compute (the comm-efficient
        fold), and ``decode_generator`` is the (possibly wider) system
        the master solves -- the gathered ``(N, F)`` results flatten to
        its ``N*F`` rows in ``f*N + w`` order.

        ``faults`` (opt-in hook, DESIGN.md §12): a
        :class:`~repro.distributed.faults.FaultPlan` or ``FaultInjector``
        projected onto ``round_idx``.  Kills fold into the availability
        mask host-side (a dead worker IS a masked worker); corrupt workers
        keep their mask bit but their device rows are algebraically
        garbled IN-TRACE before leaving the worker stage, so an unmasked
        decode that reads them yields visibly wrong output (what the
        Byzantine verifier exists to catch).  Delays are a no-op here: the
        all-gather is a synchronous collective that already waits for
        every participant.  With ``faults=None`` the trace is unchanged.
        """
        plan = self.plan
        n = plan.n_workers
        nf = getattr(plan, "fragments", 1)
        out_shard = tuple(plan.worker_shard_shape)
        stored = tuple(getattr(plan, "stored_shard_shape", out_shard))
        # what one decoded row / shipped fragment carries
        post_shard = out_shard[1:] if nf > 1 else out_shard
        payload = math.prod(post_shard)
        enc_t = getattr(plan, "worker_encode_tensor", None)
        if enc_t is None:
            enc_t = plan.generator[:, None, :]                # (N, 1, m)
        width = enc_t.shape[2]
        dec_g = getattr(plan, "decode_generator", None)
        if dec_g is None:
            dec_g = plan.generator
        k = dec_g.shape[1]
        n_rows = n * nf
        wc_rows = getattr(plan, "worker_compute_rows", None)

        batch = batch_shape(x, len(plan.input_shape), "plan input")
        if mask is None:
            mask = jnp.ones(batch + (n,), bool)
        corrupt = jnp.zeros((n,), bool)
        if faults is not None:
            injector = (FaultInjector(faults)
                        if isinstance(faults, FaultPlan) else faults)
            rf = injector.faults_for(round_idx)
            if rf.killed:
                dead = jnp.asarray([w in rf.killed for w in range(n)])
                mask = jnp.asarray(mask) & ~dead
            if rf.corrupt:
                corrupt = jnp.asarray(injector.corrupt_flags(n, round_idx))

        # host-side interleave -> (B, W, payload) flat message symbols
        c = plan.message(x).reshape((-1, width, math.prod(stored) // nf))
        nb = c.shape[0]
        wmask = jnp.broadcast_to(jnp.asarray(mask), batch + (n,)).reshape(nb, n)
        if fragment_mask is None:
            fmask = jnp.broadcast_to(wmask[:, :, None], (nb, n, nf))
        else:
            fmask = jnp.broadcast_to(
                jnp.asarray(fragment_mask), batch + (n, nf)
            ).reshape(nb, n, nf) & wmask[:, :, None]
        fill = jnp.asarray(self.masked_fill, c.dtype)

        # the worker axis stays LEADING through both shard_map stages: the
        # all-gather then tiles axis 0, which XLA:CPU's fft thunk tolerates
        # (gathering a non-leading axis forces a transposed layout onto the
        # worker FFT and trips its dim0-major RET_CHECK)
        @partial(
            shard_map, mesh=self.mesh,
            in_specs=(P(), P(), P()),
            out_specs=P(self.axis, None, None, None),
            check_rep=False,
        )
        def workers(c_rep, fmask_rep, corrupt_rep):
            # per-device fused encode+compute: each device forms only its
            # own coded shards from the replicated message symbols
            idx = jax.lax.axis_index(self.axis)
            rows = idx * self.n_local + jnp.arange(self.n_local)
            g_rows = jnp.take(enc_t, rows, axis=0)        # (n_local, F, W)
            a = jnp.einsum("nfw,bwp->nbfp", g_rows.astype(c_rep.dtype),
                           c_rep)
            a = a.reshape((self.n_local, nb) + stored)
            if wc_rows is not None:
                # worker-index-aware compute (the comm-efficient fold
                # weights depend on k): its contract puts the row axis at
                # -2 over the trailing 1-D shard
                b = jnp.moveaxis(
                    wc_rows(jnp.moveaxis(a, 0, -2), rows), -2, 0)
            else:
                b = plan.worker_compute(a)
            b = b.reshape(self.n_local, nb, nf, payload)
            # Byzantine rows: deterministic in-trace garbage (affine warp
            # of the true values -- "arbitrarily wrong", not just scaled,
            # and jit-stable, unlike a traced RNG draw would be)
            bad = jnp.take(corrupt_rep, rows)                 # (n_local,)
            b = jnp.where(bad[:, None, None, None], b * (-3.7) + 11.3, b)
            alive = jnp.take(fmask_rep, rows, axis=1)     # (nb, n_local, F)
            return jnp.where(
                jnp.moveaxis(alive, 0, 1)[:, :, :, None], b, fill)

        b = workers(c, fmask, corrupt)                    # (N, nb, F, payload)

        @partial(
            shard_map, mesh=self.mesh,
            in_specs=(P(self.axis, None, None, None), P()),
            out_specs=P(),
            check_rep=False,
        )
        def master(b_local, fmask_rep):
            # the paper's fan-in: gather the coded results to the master,
            # then flatten fragments into decode-system row order f*N + w
            b_all = jax.lax.all_gather(b_local, self.axis, tiled=True)
            b_all = jnp.moveaxis(b_all, 0, 2)             # (nb, F, N, p)
            b_all = b_all.reshape(nb, n_rows, payload)
            rmask = jnp.swapaxes(fmask_rep, 1, 2).reshape(nb, n_rows)

            def decode1(bi, mk, mth):
                subset = mds.first_available(mk, k)
                c_hat = mds.decode_auto(dec_g, bi, subset, method=mth)
                return plan.postdecode(c_hat.reshape((k,) + post_shard))

            if nb == 1:
                # single request: decode_auto's lax.cond stays a real branch
                return decode1(b_all[0], rmask[0], method)[None]
            if method == "auto" and k <= mds.LAGRANGE_MAX_M:
                # batched mask-to-weights (DESIGN.md §8): per-request
                # decode matrices from the closed-form Lagrange inversion,
                # built in-trace -- no vmapped linalg.solve, no host work
                # per novel mask.  The k responder rows are GATHERED before
                # the contraction, so the masked_fill rows (NaN in tests)
                # are provably never read.
                subsets = jax.vmap(
                    lambda mk: mds.first_available(mk, k))(rmask)
                inv = jax.vmap(
                    lambda sub: mds.lagrange_inverse(sub, n_rows,
                                                     b_all.dtype)
                )(subsets)
                rows = jnp.take_along_axis(
                    b_all, subsets[:, :, None], axis=1)
                c_hat = inv @ rows                        # (nb, k, payload)
                return jax.vmap(
                    lambda ch: plan.postdecode(
                        ch.reshape((k,) + post_shard))
                )(c_hat)
            # batched, pinned method: under vmap decode_auto's cond would
            # select-execute BOTH decode paths per request -- resolve auto
            # to the solve instead
            mth = "solve" if method == "auto" else method
            return jax.vmap(lambda bi, mk: decode1(bi, mk, mth))(
                b_all, rmask)

        out = master(b, fmask)                                # (nb, *out_shape)
        if not batch:
            return out[0]
        return out.reshape(batch + tuple(plan.output_shape))

    # ------------------------------------------------------------------
    def run_sharded(self, x: jax.Array, mask: Optional[jax.Array] = None,
                    *, method: str = "auto") -> jax.Array:
        """Optimized 1-D pipeline (§Perf cell C): sharded-output decode.

        The baseline ``run`` realizes the paper's master literally: every
        chip all-gathers all N coded results (N/m x s symbols per chip)
        and runs the full decode.  But no consumer needs X replicated --
        so instead each chip receives only its OUTPUT COLUMNS of every
        worker's result via one all-to-all (s symbols total per chip,
        N/m x less wire), decodes the (m, L/P) column block, and
        recombines locally (twiddles depend on the absolute column index,
        taken from ``axis_index``).

        Specific to the 1-D :class:`CodedFFT` layout (column-sharded
        Cooley-Tukey output); other plans raise.  Returns the output
        matrix ``Xmat`` of shape ``(m, s/m)``, column-sharded over the
        worker axis; ``X = Xmat.reshape(s)`` (row-major), since
        ``Xmat[j, i] = X[j*(s/m) + i]``.
        """
        plan = self.plan
        if not isinstance(plan, CodedFFT):
            raise NotImplementedError(
                "run_sharded implements the 1-D Cooley-Tukey output layout; "
                f"got {type(plan).__name__} -- use run()")
        p_sz = self.mesh.shape[self.axis]
        ell = plan.shard_len
        if ell % p_sz != 0:
            raise ValueError(f"s/m={ell} must divide over {p_sz} devices")
        if mask is None:
            mask = jnp.ones((plan.n_workers,), bool)

        from repro.core.recombine import dft_matrix

        @partial(
            shard_map, mesh=self.mesh,
            in_specs=(P(), P()),
            out_specs=P(None, self.axis),
            check_rep=False,
        )
        def pipeline(x_rep, mask_rep):
            # fused interleave+encode: c[i, l] = x[i + l*m] is just the
            # transposed view of x.reshape(L, m), so the coded shard is one
            # strided einsum over x -- the materialized interleave copy
            # (2x s symbols of pure data movement) never exists (§Perf C2)
            idx = jax.lax.axis_index(self.axis)
            rows = idx * self.n_local + jnp.arange(self.n_local)
            g_rows = jnp.take(plan.generator, rows, axis=0)   # (n_local, m)
            xr = x_rep.astype(plan.dtype).reshape(ell, plan.m)
            a_local = jnp.einsum("lm,nm->nl", xr, g_rows.astype(plan.dtype))
            b_local = plan.resolved_worker_fn(a_local)        # (n_local, L)
            alive = jnp.take(mask_rep, rows)
            b_local = jnp.where(alive[:, None], b_local,
                                jnp.asarray(self.masked_fill, plan.dtype))
            # row-shards -> column-shards: THE one collective of the
            # optimized path (s symbols per chip vs N/m x s for all-gather)
            b_cols = jax.lax.all_to_all(
                b_local, self.axis, split_axis=1, concat_axis=0, tiled=True
            )                                                  # (N, L/P)
            subset = mds.first_available(mask_rep, plan.m)
            c_cols = mds.decode_auto(
                plan.generator, b_cols, subset, method=method)
            idx = jax.lax.axis_index(self.axis)
            cols = idx * (ell // p_sz) + jnp.arange(ell // p_sz)
            ki = jnp.outer(jnp.arange(plan.m), cols)
            w = jnp.exp(-2j * jnp.pi * ki / plan.s).astype(c_cols.dtype)
            f_m = dft_matrix(plan.m, c_cols.dtype)
            return f_m @ (c_cols * w)                          # (m, L/P)

        return pipeline(x.astype(plan.dtype), mask)

    # ------------------------------------------------------------------
    def lower(self, s_dtype=jnp.complex64, *, sharded: bool = False):
        """Lower for compile inspection (collective accounting)."""
        x = jax.ShapeDtypeStruct(tuple(self.plan.input_shape), s_dtype)
        mask = jax.ShapeDtypeStruct((self.plan.n_workers,), jnp.bool_)
        fn = self.run_sharded if sharded else self.run
        return jax.jit(fn).lower(x, mask)


# The 1-D name the seed exposed; the class has been generic since the
# CodedPlan refactor, so this is a pure alias.
DistributedCodedFFT = DistributedCodedPlan
