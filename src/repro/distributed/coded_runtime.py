"""shard_map execution of the coded FFT over a device mesh.

The paper's master/worker topology mapped to SPMD (DESIGN.md §3):

* **encode** -- each device holds the (replicated) input block, computes
  only ITS coded shard: ``a_k = sum_i G[k,i] c_i`` (no collective; G row is
  selected by ``axis_index``).
* **worker compute** -- per-device FFT of its own shard, the hot loop.  On
  TPU this is the Pallas four-step kernel; on CPU the jnp oracle.
* **straggler mask** -- an explicit boolean input.  In production the
  launcher populates it from collective timeouts; in tests/benchmarks the
  straggler simulator does.  Masked workers' outputs are *zeroed then
  ignored* by decode (decode reads only the first-m-available rows), so a
  straggler may return garbage without affecting the result (verified in
  tests by feeding NaNs).
* **decode** -- all-gather the worker results along the axis (the paper's
  fan-in to the master: exactly s coded symbols on the wire, the cut-set
  optimum of Remark 5), then every device runs the same masked MDS solve +
  recombine.  Replicated decode wastes no wall-clock vs a physical master
  because the all-gather is the critical path either way.

``n_local = N // axis_size`` coded shards live on each device, so N need
not equal the device count (e.g. N=8 code on a 4-device axis).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import mds
from repro.core.coded_fft import CodedFFT
from repro.core.recombine import recombine

__all__ = ["DistributedCodedFFT"]


@dataclasses.dataclass(frozen=True)
class DistributedCodedFFT:
    """Run a ``CodedFFT`` plan across a mesh axis with straggler masking."""

    plan: CodedFFT
    mesh: Mesh
    axis: str = "workers"

    def __post_init__(self):
        size = self.mesh.shape[self.axis]
        if self.plan.n_workers % size != 0:
            raise ValueError(
                f"N={self.plan.n_workers} must be a multiple of axis "
                f"size {size}")

    @property
    def n_local(self) -> int:
        return self.plan.n_workers // self.mesh.shape[self.axis]

    # ------------------------------------------------------------------
    def _worker_body(self, c: jax.Array, mask: jax.Array) -> jax.Array:
        """Per-device: encode own shards from replicated c, FFT them.

        c: (m, L) replicated message shards; mask: (N,) replicated.
        Returns this device's (n_local, L) results, zeroed if masked out.
        """
        plan = self.plan
        idx = jax.lax.axis_index(self.axis)
        rows = idx * self.n_local + jnp.arange(self.n_local)
        g_rows = jnp.take(plan.generator, rows, axis=0)          # (n_local, m)
        a_local = jnp.einsum("nm,ml->nl", g_rows.astype(c.dtype), c)
        b_local = plan.worker_fn(a_local)                         # (n_local, L)
        alive = jnp.take(mask, rows)                              # (n_local,)
        return jnp.where(alive[:, None], b_local, 0)

    def run(self, x: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
        """End-to-end coded FFT of ``x`` (length s) under the mesh.

        ``mask``: bool (N,) worker availability (>= m True). Default: all up.
        """
        plan = self.plan
        if mask is None:
            mask = jnp.ones((plan.n_workers,), bool)

        from repro.core.interleave import interleave

        c = interleave(x.astype(plan.dtype), plan.m)              # (m, L)

        @partial(
            shard_map, mesh=self.mesh,
            in_specs=(P(), P()),
            out_specs=P(self.axis),
            check_rep=False,
        )
        def workers(c_rep, mask_rep):
            return self._worker_body(c_rep, mask_rep)

        b = workers(c, mask)                                      # (N, L) sharded

        @partial(
            shard_map, mesh=self.mesh,
            in_specs=(P(self.axis), P()),
            out_specs=P(),
            check_rep=False,
        )
        def master(b_local, mask_rep):
            # the paper's fan-in: gather the coded results to the master
            b_all = jax.lax.all_gather(b_local, self.axis, tiled=True)
            subset = mds.first_available(mask_rep, plan.m)
            c_hat = mds.decode_from_subset(plan.generator, b_all, subset)
            return recombine(c_hat, plan.s)

        return master(b, mask)

    # ------------------------------------------------------------------
    def run_sharded(self, x: jax.Array, mask: Optional[jax.Array] = None
                    ) -> jax.Array:
        """Optimized pipeline (§Perf cell C): sharded-output decode.

        The baseline ``run`` realizes the paper's master literally: every
        chip all-gathers all N coded results (N/m x s symbols per chip)
        and runs the full decode.  But no consumer needs X replicated --
        so instead each chip receives only its OUTPUT COLUMNS of every
        worker's result via one all-to-all (s symbols total per chip,
        N/m x less wire), decodes the (m, L/P) column block, and
        recombines locally (twiddles depend on the absolute column index,
        taken from ``axis_index``).

        Returns the Cooley-Tukey output matrix ``Xmat`` of shape
        ``(m, s/m)``, column-sharded over the worker axis;
        ``X = Xmat.reshape(s)`` (row-major), since
        ``Xmat[j, i] = X[j*(s/m) + i]``.
        """
        plan = self.plan
        p_sz = self.mesh.shape[self.axis]
        ell = plan.shard_len
        if ell % p_sz != 0:
            raise ValueError(f"s/m={ell} must divide over {p_sz} devices")
        if mask is None:
            mask = jnp.ones((plan.n_workers,), bool)

        from repro.core.recombine import dft_matrix

        @partial(
            shard_map, mesh=self.mesh,
            in_specs=(P(), P()),
            out_specs=P(None, self.axis),
            check_rep=False,
        )
        def pipeline(x_rep, mask_rep):
            # fused interleave+encode: c[i, l] = x[i + l*m] is just the
            # transposed view of x.reshape(L, m), so the coded shard is one
            # strided einsum over x -- the materialized interleave copy
            # (2x s symbols of pure data movement) never exists (§Perf C2)
            idx = jax.lax.axis_index(self.axis)
            rows = idx * self.n_local + jnp.arange(self.n_local)
            g_rows = jnp.take(plan.generator, rows, axis=0)   # (n_local, m)
            xr = x_rep.astype(plan.dtype).reshape(ell, plan.m)
            a_local = jnp.einsum("lm,nm->nl", xr, g_rows.astype(plan.dtype))
            b_local = plan.worker_fn(a_local)                 # (n_local, L)
            alive = jnp.take(mask_rep, rows)
            b_local = jnp.where(alive[:, None], b_local, 0)
            # row-shards -> column-shards: THE one collective of the
            # optimized path (s symbols per chip vs N/m x s for all-gather)
            b_cols = jax.lax.all_to_all(
                b_local, self.axis, split_axis=1, concat_axis=0, tiled=True
            )                                                  # (N, L/P)
            subset = mds.first_available(mask_rep, plan.m)
            c_cols = mds.decode_from_subset(plan.generator, b_cols, subset)
            idx = jax.lax.axis_index(self.axis)
            cols = idx * (ell // p_sz) + jnp.arange(ell // p_sz)
            ki = jnp.outer(jnp.arange(plan.m), cols)
            w = jnp.exp(-2j * jnp.pi * ki / plan.s).astype(c_cols.dtype)
            f_m = dft_matrix(plan.m, c_cols.dtype)
            return f_m @ (c_cols * w)                          # (m, L/P)

        return pipeline(x.astype(plan.dtype), mask)

    # ------------------------------------------------------------------
    def lower(self, s_dtype=jnp.complex64, *, sharded: bool = False):
        """Lower for compile inspection (collective accounting)."""
        x = jax.ShapeDtypeStruct((self.plan.s,), s_dtype)
        mask = jax.ShapeDtypeStruct((self.plan.n_workers,), jnp.bool_)
        fn = self.run_sharded if sharded else self.run
        return jax.jit(fn).lower(x, mask)
