"""Per-worker health tracking, deadline-derived masks, model calibration.

Before this layer the service *assumed* the straggler distribution: each
round's availability mask was drawn as "the m fastest of a StragglerModel
sample".  A real master cannot do that -- it observes completion times and
must decide, per round, how long to wait.  ``WorkerHealthTracker`` is that
decision state:

* ``observe`` / ``observe_round`` feed measured (or injected-simulation)
  per-worker completion times into per-worker EWMAs plus running min /
  mean / count aggregates.
* ``deadline(m)`` derives the round's wait budget: the m-th fastest
  *estimated* completion time times ``1 + slack_frac``.  The availability
  mask is then simply ``times <= deadline`` (``mask_from_times``) -- a
  mechanism (measured arrival vs deadline) rather than a simulator input.
* Workers whose corrupted output was caught by the Byzantine verifier
  (DESIGN.md §12) are flagged via ``flag_byzantine``; flagged workers are
  excluded from re-dispatch targets and reported in ``summary()``.
* ``calibrate`` closes the ROADMAP "calibrate from measured timings" item:
  it fits the shifted-exponential ``StragglerModel`` (t0, mu) from the
  observed aggregates by moment matching -- for ``T = w*(t0 + Exp(mu))``,
  ``min T -> w*t0`` and ``mean T - min T -> w/mu``.

The tracker is plain numpy and cheap (O(N) per round); the service owns
one per ``FFTService`` and the measured worker runtime shares it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.distributed.straggler import StragglerModel

__all__ = ["WorkerHealthTracker"]


class WorkerHealthTracker:
    """EWMA completion-time state for ``n_workers`` slots.

    ``alpha``: EWMA smoothing factor (weight of the newest sample).
    ``slack_frac``: deadline headroom over the m-th fastest estimate.
    ``default_s``: prior completion-time estimate used for slots with no
    observations yet (also the bootstrap deadline scale of round 0).
    """

    def __init__(self, n_workers: int, *, alpha: float = 0.2,
                 slack_frac: float = 0.5, default_s: float = 1e-3):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        if slack_frac < 0.0:
            raise ValueError("slack_frac must be >= 0")
        self.alpha = float(alpha)
        self.slack_frac = float(slack_frac)
        self.default_s = float(default_s)
        self._ewma = np.full(n_workers, np.nan)
        self._min = np.full(n_workers, np.inf)
        self._sum = np.zeros(n_workers)
        self._count = np.zeros(n_workers, dtype=np.int64)
        self._missed = np.zeros(n_workers, dtype=np.int64)
        self._byzantine = np.zeros(n_workers, dtype=bool)
        self.rounds = 0

    # -- sizing -----------------------------------------------------------
    @property
    def n_workers(self) -> int:
        return int(self._ewma.shape[0])

    def grow(self, n_workers: int) -> None:
        """Extend state to ``n_workers`` slots (elastic joins keep history)."""
        extra = n_workers - self.n_workers
        if extra <= 0:
            return
        self._ewma = np.concatenate([self._ewma, np.full(extra, np.nan)])
        self._min = np.concatenate([self._min, np.full(extra, np.inf)])
        self._sum = np.concatenate([self._sum, np.zeros(extra)])
        self._count = np.concatenate([self._count, np.zeros(extra, np.int64)])
        self._missed = np.concatenate([self._missed, np.zeros(extra, np.int64)])
        self._byzantine = np.concatenate([self._byzantine, np.zeros(extra, bool)])

    # -- observations -----------------------------------------------------
    def observe(self, worker: int, seconds: float) -> None:
        """Record one measured completion time for ``worker``."""
        if not (0 <= worker < self.n_workers):
            raise IndexError(f"worker {worker} out of range")
        if not math.isfinite(seconds) or seconds < 0:
            return
        prev = self._ewma[worker]
        self._ewma[worker] = (seconds if np.isnan(prev)
                              else (1 - self.alpha) * prev + self.alpha * seconds)
        self._min[worker] = min(self._min[worker], seconds)
        self._sum[worker] += seconds
        self._count[worker] += 1

    def observe_round(self, times: Sequence[float]) -> None:
        """Record one round: per-worker times, NaN/inf = did not respond."""
        times = np.asarray(times, dtype=np.float64)
        if times.shape != (self.n_workers,):
            raise ValueError(f"expected ({self.n_workers},) times, got {times.shape}")
        for w in range(self.n_workers):
            t = times[w]
            if math.isfinite(t):
                self.observe(w, float(t))
            else:
                self._missed[w] += 1
        self.rounds += 1

    def flag_byzantine(self, worker: int) -> None:
        self._byzantine[worker] = True

    def clear_byzantine(self, worker: int) -> None:
        self._byzantine[worker] = False

    @property
    def byzantine(self) -> np.ndarray:
        return self._byzantine.copy()

    # -- derived state ----------------------------------------------------
    def estimates(self) -> np.ndarray:
        """Per-worker completion-time estimates (prior where unobserved).

        A slot that has ONLY ever missed is estimated infinitely slow:
        letting the fast default prior stand for a dead worker would drag
        the m-th-fastest deadline below what any live worker can meet.
        """
        est = np.where(np.isnan(self._ewma), self.default_s, self._ewma)
        never = (self._count == 0) & (self._missed > 0)
        return np.where(never, np.inf, est).astype(np.float64)

    def deadline(self, m: int, *, alive: Optional[np.ndarray] = None) -> float:
        """Wait budget for a round needing ``m`` responses.

        The m-th fastest estimated completion among ``alive`` workers,
        stretched by ``1 + slack_frac``.  Monotone in the estimates, so a
        slowing fleet automatically relaxes the deadline while a healthy
        one keeps it tight.
        """
        est = self.estimates()
        if alive is not None:
            alive = np.asarray(alive, dtype=bool)
            est = est[alive[: est.shape[0]]]
        if est.shape[0] < m:
            return float("inf")
        kth = float(np.sort(est)[m - 1])
        return kth * (1.0 + self.slack_frac)

    def mask_from_times(self, times: np.ndarray, deadline: float) -> np.ndarray:
        """Availability mask: measured arrival beat the deadline."""
        times = np.asarray(times, dtype=np.float64)
        with np.errstate(invalid="ignore"):
            return np.where(np.isfinite(times), times <= deadline, False)

    def fragment_mask_from_times(self, times: np.ndarray, deadline: float,
                                 fractions: Sequence[float]) -> np.ndarray:
        """Per-fragment availability for partial-work plans (DESIGN.md §13).

        A partial-work worker emits fragment ``f`` at ``times * fractions
        [f]`` of its full-shard completion (fragments are sequential, so
        ``fractions`` is increasing, e.g. ``(f+1)/r``).  The deadline then
        gates each fragment separately: a worker that misses the round
        deadline overall still lands the prefix of fragments whose scaled
        times beat it -- "missed deadline" becomes per-fragment, not
        per-worker.  ``times``: ``(..., N)`` -> mask ``(..., N, F)``.
        """
        times = np.asarray(times, dtype=np.float64)
        ft = times[..., None] * np.asarray(fractions, dtype=np.float64)
        with np.errstate(invalid="ignore"):
            return np.where(np.isfinite(ft), ft <= deadline, False)

    # -- calibration ------------------------------------------------------
    def calibrate(self, workload: float = 1.0, *,
                  wire_frac: float = 0.0) -> StragglerModel:
        """Fit a StragglerModel (t0, mu) from the observed aggregates.

        Moment matching on the pooled samples of ``T = w*(t0 + Exp(mu))``:
        ``t0_hat = min(T)/w`` and ``mu_hat = w / (mean(T) - min(T))``.
        ``wire_frac`` is pass-through (timing observations cannot split
        compute from wire; callers that know the split provide it).
        """
        seen = self._count > 0
        if not seen.any():
            raise ValueError("no observations to calibrate from")
        total = float(self._sum[seen].sum())
        count = int(self._count[seen].sum())
        t_min = float(self._min[seen].min())
        t_mean = total / count
        t0 = t_min / workload
        tail = max(t_mean - t_min, 1e-12)
        mu = workload / tail
        return StragglerModel(t0=t0, mu=mu, wire_frac=wire_frac)

    def summary(self) -> dict:
        seen = self._count > 0
        return {
            "n_workers": self.n_workers,
            "rounds": self.rounds,
            "observed_workers": int(seen.sum()),
            "ewma_s": [None if np.isnan(v) else float(v) for v in self._ewma],
            "missed": self._missed.tolist(),
            "byzantine": np.flatnonzero(self._byzantine).tolist(),
        }
