"""Measured thread-pool worker runtime: real deadlines, retries, re-dispatch.

Everything else in the repo *simulates* worker timing; this module runs
the paper's master/worker protocol against actual wall-clock time.  Each
worker is a thread computing its coded shard ``b_k = fft(G[k] @ c)`` for
the whole bucket (numpy, so N workers genuinely overlap outside the GIL
inside the FFT); the master

1. dispatches all live workers and waits until ``threshold`` rows have
   ARRIVED or the deadline expires -- the deadline comes from the shared
   :class:`~repro.distributed.health.WorkerHealthTracker` (m-th-fastest
   EWMA estimate + slack), so the wait budget is learned from measured
   rounds, never assumed;
2. on a miss, re-dispatches the missing shard rows to the pool (any
   healthy thread computes a row -- the row is data, not an identity) and
   extends the window by ``retry_backoff``, up to ``max_retries`` times;
3. gives up with a typed reason: ``insufficient_workers`` when no healthy
   worker exists to re-dispatch to, ``retries_exhausted`` when the capped
   windows close without ``m`` rows.

``require_all=True`` is the UNCODED baseline: the master needs every row
(an uncoded partition has no slack), so one killed or delayed worker
stalls the round into the retry machinery -- the measured bench races this
against the coded ``threshold=m`` run under identical fault plans.

Fault injection rides the same :class:`~repro.distributed.faults
.FaultInjector` hook as the simulated path: killed workers never respond,
delayed workers sleep before responding, corrupt workers respond on time
with seeded garbage (caught downstream by ``verify="correct"``).

The runtime covers 1-D c2c plans (the measured-bench workload); the
simulated robust path in ``serving/fft_service.py`` covers every kind.
DESIGN.md §12.
"""

from __future__ import annotations

import queue as queue_mod
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.distributed.faults import FaultInjector, RoundFaults
from repro.distributed.health import WorkerHealthTracker

__all__ = ["MeasuredRound", "MeasuredWorkerRuntime"]


class MeasuredRound:
    """One completed measured round (a plain result record)."""

    def __init__(self, b: np.ndarray, mask: np.ndarray, reason: Optional[str],
                 *, t_met: float, t_last: float, retries: int,
                 redispatched: int, times: np.ndarray):
        self.b = b                    # (q, N, ell) complex; missing rows 0
        self.mask = mask              # (N,) bool: rows that arrived in time
        self.reason = reason          # None | insufficient_workers |
        #                               retries_exhausted
        self.t_met = t_met            # seconds until threshold met (inf if not)
        self.t_last = t_last          # seconds until last arrival seen
        self.retries = retries
        self.redispatched = redispatched
        self.times = times            # (N,) per-worker arrival seconds (inf
        #                               = no response)

    @property
    def ok(self) -> bool:
        return self.reason is None


class MeasuredWorkerRuntime:
    """Thread-per-worker execution of one 1-D coded FFT plan.

    ``plan`` must be a c2c :class:`~repro.core.coded_fft.CodedFFT` (worker
    body = fft along the last axis).  ``health`` is shared with the owning
    service so deadlines learn across rounds.  ``min_deadline_s`` floors
    the wait budget against scheduler jitter at sub-millisecond compute.
    """

    def __init__(self, plan, health: WorkerHealthTracker, *,
                 injector: Optional[FaultInjector] = None,
                 max_retries: int = 2, retry_backoff: float = 2.0,
                 require_all: bool = False, min_deadline_s: float = 2e-3,
                 threshold_extra: int = 0):
        self.plan = plan
        self.health = health
        self.injector = injector
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self.require_all = bool(require_all)
        self.min_deadline_s = float(min_deadline_s)
        # surplus responses to wait for beyond m: the Byzantine verifier
        # needs k > m rows (k = m + q detects q liars, corrects q//2)
        self.threshold_extra = int(threshold_extra)
        self.generator = np.asarray(plan.generator, dtype=np.complex128)
        self.pool = ThreadPoolExecutor(
            max_workers=plan.n_workers, thread_name_prefix="coded-worker")

    def close(self) -> None:
        self.pool.shutdown(wait=False, cancel_futures=True)

    def __enter__(self) -> "MeasuredWorkerRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    def round(self, xb: np.ndarray, round_idx: int,
              alive: Optional[np.ndarray] = None) -> MeasuredRound:
        """Run one bucket ``xb`` (``(q, s)`` complex) as a measured round."""
        plan = self.plan
        n, m = plan.n_workers, plan.m
        q, s = xb.shape
        ell = s // m
        alive = (np.ones(n, bool) if alive is None
                 else np.asarray(alive, bool).copy())
        rf = (self.injector.faults_for(round_idx)
              if self.injector is not None else RoundFaults())
        delay_map = rf.delay_map
        # interleaved message shards c[j] = x[j::m] -> (q, m, ell)
        c = np.ascontiguousarray(
            np.swapaxes(np.asarray(xb, np.complex128).reshape(q, ell, m),
                        -1, -2))
        threshold = (int(alive.sum()) if self.require_all
                     else min(m + self.threshold_extra, int(alive.sum())))
        resq: queue_mod.Queue = queue_mod.Queue()
        t_start = time.perf_counter()

        def compute_row(row: int) -> np.ndarray:
            a = np.tensordot(self.generator[row], c, axes=([0], [1]))  # (q, ell)
            return np.fft.fft(a, axis=-1)

        def worker(k: int) -> None:
            if k in rf.killed:
                return  # dead: never responds this round
            b_k = compute_row(k)
            if k in rf.corrupt and self.injector is not None:
                b_k = self.injector.corrupt_payload(b_k, k, round_idx)
            d = delay_map.get(k)
            if d:
                time.sleep(d)
            resq.put((k, b_k, time.perf_counter() - t_start))

        def redispatch(row: int) -> None:
            # a healthy thread recomputes the missing shard row: no fault
            # applies (the faulty worker is not the one computing it)
            b_k = compute_row(row)
            resq.put((row, b_k, time.perf_counter() - t_start))

        for k in np.flatnonzero(alive):
            self.pool.submit(worker, int(k))

        got: dict[int, np.ndarray] = {}
        times = np.full(n, np.inf)
        t_met = np.inf
        # wait budget for the k-th-fastest response we actually need:
        # m for the coded path, m + quorum under verify, ALL alive rows
        # for the uncoded require_all baseline (else the 8th arrival is
        # judged against an m-th-fastest deadline and always misses)
        deadline = self.health.deadline(max(threshold, 1), alive=alive)
        if not np.isfinite(deadline):
            # too many never-responders for an m-th-fastest deadline:
            # budget off the slowest worker that HAS responded (retries
            # still extend from there), or the floor when nobody has
            est = self.health.estimates()[:n]
            fin = est[np.isfinite(est) & alive]
            deadline = (float(fin.max()) * (1.0 + self.health.slack_frac)
                        if fin.size else 0.0)
        window = max(deadline, self.min_deadline_s)
        retries = redispatched = 0
        healthy = alive & ~np.isin(np.arange(n), sorted(rf.killed))
        if self.health.byzantine.any():
            healthy &= ~self.health.byzantine
        reason: Optional[str] = None

        if int(alive.sum()) < m:
            reason = "insufficient_workers"
        else:
            while True:
                self._collect(resq, got, times, window, t_start, threshold)
                if len(got) >= threshold:
                    break
                if retries >= self.max_retries:
                    reason = "retries_exhausted"
                    break
                if not healthy.any():
                    reason = "insufficient_workers"
                    break
                missing = [k for k in np.flatnonzero(alive) if k not in got]
                for row in missing:
                    self.pool.submit(redispatch, int(row))
                redispatched += len(missing)
                retries += 1
                window *= self.retry_backoff
            if len(got) >= threshold:
                t_met = float(np.sort(times[np.isfinite(times)])[threshold - 1])

        b = np.zeros((q, n, ell), np.complex128)
        mask = np.zeros(n, bool)
        for k, row in got.items():
            b[:, k] = row
            mask[k] = True
        finite = times[np.isfinite(times)]
        t_last = float(finite.max()) if finite.size else np.inf
        self.health.observe_round(np.where(np.isfinite(times), times, np.nan))
        return MeasuredRound(b, mask, reason, t_met=t_met, t_last=t_last,
                             retries=retries, redispatched=redispatched,
                             times=times)

    @staticmethod
    def _collect(resq: queue_mod.Queue, got: dict, times: np.ndarray,
                 window: float, t_start: float, threshold: int) -> None:
        """Drain arrivals until ``threshold`` rows are in or the window
        closes (first arrival per row wins: an original beating its
        re-dispatched copy is kept)."""
        while len(got) < threshold:
            remaining = window - (time.perf_counter() - t_start)
            if remaining <= 0:
                # non-blocking final sweep: arrivals already queued count
                try:
                    while True:
                        k, row, t = resq.get_nowait()
                        if k not in got and t <= window:
                            got[k] = row
                            times[k] = t
                except queue_mod.Empty:
                    return
                continue
            try:
                k, row, t = resq.get(timeout=remaining)
            except queue_mod.Empty:
                continue
            if k not in got:
                got[k] = row
                times[k] = t
