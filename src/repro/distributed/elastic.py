"""Elastic scaling: reshard a live training state between meshes.

When a pod (or slice) drops out or re-joins, the job must continue on a
different device count without losing optimizer state.  ``reshard``
moves an arbitrary pytree from its current sharding onto the equivalent
logical sharding over a new mesh; shapes are global, so the transfer is
exact regardless of either mesh's layout.  Combined with the random-access
data pipeline and deterministic schedules, a resharded run continues
bit-exactly (tests/test_elastic.py proves 8 -> 4 -> 8 device continuity).

On real hardware this pairs with the launcher's slice-membership protocol;
here the mechanism (global-shape transfer through host or ICI) is what we
implement and test.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["reshard", "reshard_like"]


def _resolve(spec_leaf, mesh: Mesh) -> NamedSharding:
    spec = spec_leaf if isinstance(spec_leaf, P) else P()
    # Drop axis names the new mesh doesn't have (e.g. "pod" after shrink).
    cleaned = []
    for entry in spec:
        if entry is None:
            cleaned.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in mesh.axis_names)
            cleaned.append(kept if kept else None)
        else:
            cleaned.append(entry if entry in mesh.axis_names else None)
    return NamedSharding(mesh, P(*cleaned))


def reshard(tree: Any, mesh: Mesh, pspecs: Any) -> Any:
    """Place ``tree`` onto ``mesh`` under the (logical) ``pspecs`` tree.

    ``pspecs`` may be a prefix tree of PartitionSpecs; axes missing from
    the target mesh are silently dropped (pod removal).  Works across
    meshes of different sizes because transfers go through global shapes.
    """
    flat, treedef = jax.tree.flatten(tree)
    spec_flat = treedef.flatten_up_to(pspecs) if pspecs is not None else [P()] * len(flat)
    out = []
    for leaf, spec in zip(flat, spec_flat):
        sh = _resolve(spec, mesh)
        out.append(jax.device_put(leaf, sh))
    return jax.tree.unflatten(treedef, out)


def reshard_like(tree: Any, mesh: Mesh) -> Any:
    """Reshard keeping each leaf's current PartitionSpec (mesh swap only)."""
    def spec_of(x):
        sh = getattr(x, "sharding", None)
        return sh.spec if isinstance(sh, NamedSharding) else P()

    pspecs = jax.tree.map(spec_of, tree)
    return reshard(tree, mesh, pspecs)
