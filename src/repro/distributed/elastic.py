"""Elastic scaling: worker membership + resharding state between meshes.

Two mechanisms live here:

* ``reshard`` / ``reshard_like`` move an arbitrary pytree from its current
  sharding onto the equivalent logical sharding over a new mesh; shapes
  are global, so the transfer is exact regardless of either mesh's layout
  (tests/test_elastic.py proves 8 -> 4 -> 8 device continuity round-trips
  bit-exactly, including pspecs naming dropped axes).
* ``ElasticWorkerPool`` tracks coded-FFT worker membership between rounds:
  workers ``join``/``leave`` live while the recovery threshold ``m`` stays
  fixed.  The paper's MDS property makes departure a *latency event* --
  any ``m`` of the live workers still decode -- so a leave is just a mask
  flip.  Joins first refill departed slots (same RS evaluation node, no
  recompilation); joins beyond capacity grow the code to ``N+1`` nodes,
  which with root-of-unity nodes re-derives the node set, so consumers key
  their plan/generator caches by ``pool.capacity`` (DESIGN.md §12).

On real hardware this pairs with the launcher's slice-membership protocol;
here the mechanism (membership state + global-shape transfer) is what we
implement and test.
"""

from __future__ import annotations

from typing import Any, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ElasticWorkerPool", "reshard", "reshard_like"]


def _resolve(spec_leaf, mesh: Mesh) -> NamedSharding:
    spec = spec_leaf if isinstance(spec_leaf, P) else P()
    # Drop axis names the new mesh doesn't have (e.g. "pod" after shrink).
    cleaned = []
    for entry in spec:
        if entry is None:
            cleaned.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in mesh.axis_names)
            cleaned.append(kept if kept else None)
        else:
            cleaned.append(entry if entry in mesh.axis_names else None)
    return NamedSharding(mesh, P(*cleaned))


def reshard(tree: Any, mesh: Mesh, pspecs: Any) -> Any:
    """Place ``tree`` onto ``mesh`` under the (logical) ``pspecs`` tree.

    ``pspecs`` may be a prefix tree of PartitionSpecs; axes missing from
    the target mesh are silently dropped (pod removal).  Works across
    meshes of different sizes because transfers go through global shapes.
    """
    flat, treedef = jax.tree.flatten(tree)
    spec_flat = treedef.flatten_up_to(pspecs) if pspecs is not None else [P()] * len(flat)
    out = []
    for leaf, spec in zip(flat, spec_flat):
        sh = _resolve(spec, mesh)
        out.append(jax.device_put(leaf, sh))
    return jax.tree.unflatten(treedef, out)


def reshard_like(tree: Any, mesh: Mesh) -> Any:
    """Reshard keeping each leaf's current PartitionSpec (mesh swap only)."""
    def spec_of(x):
        sh = getattr(x, "sharding", None)
        return sh.spec if isinstance(sh, NamedSharding) else P()

    pspecs = jax.tree.map(spec_of, tree)
    return reshard(tree, mesh, pspecs)


class ElasticWorkerPool:
    """Live worker membership for a coded plan with fixed threshold ``m``.

    The pool owns CAPACITY (the code size ``N``: how many RS evaluation
    nodes exist) and LIVENESS (which slots currently have a worker behind
    them).  Invariants, enforced here and tested in tests/test_faults.py:

    * ``m`` never changes: recovery always needs exactly ``m`` responses.
    * ``leave`` only flips liveness; node assignment of every other slot
      is untouched, so in-flight plans stay valid (departed rows masked).
    * ``join`` reuses the lowest departed slot when one exists (same node,
      zero recompilation); otherwise it appends slot ``capacity`` and
      grows the code by one node.  Each capacity value is a distinct code,
      so ``capacity`` is the cache key for plans/generators -- growth
      changes it, refills don't.
    * ``version`` increments on every membership change; consumers snapshot
      ``(capacity, version)`` per round to detect mid-round churn.
    """

    def __init__(self, n_workers: int, m: int):
        if m < 1 or n_workers < m:
            raise ValueError(f"need n_workers >= m >= 1, got N={n_workers} m={m}")
        self.m = int(m)
        self._alive = [True] * int(n_workers)
        self.version = 0
        self.joined = 0
        self.departed = 0

    # -- state ------------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Code size N: number of RS evaluation nodes / worker slots."""
        return len(self._alive)

    @property
    def n_live(self) -> int:
        return sum(self._alive)

    def mask(self) -> np.ndarray:
        """Boolean ``(capacity,)`` liveness mask (copy; safe to keep)."""
        return np.asarray(self._alive, dtype=bool)

    def is_live(self, worker: int) -> bool:
        return bool(self._alive[worker])

    def can_decode(self) -> bool:
        """At least m live workers: a round can still meet the threshold."""
        return self.n_live >= self.m

    # -- membership -------------------------------------------------------
    def leave(self, worker: int) -> None:
        """Remove a worker: mask flip only, node assignments untouched."""
        if not 0 <= worker < self.capacity:
            raise IndexError(f"worker {worker} out of range [0, {self.capacity})")
        if not self._alive[worker]:
            return
        self._alive[worker] = False
        self.departed += 1
        self.version += 1

    def join(self) -> int:
        """Add a worker; returns its slot id.

        Refills the lowest departed slot if any (cheap path), else appends
        a new slot, growing ``capacity`` -- and thus the plan cache key.
        """
        for w, alive in enumerate(self._alive):
            if not alive:
                self._alive[w] = True
                self.joined += 1
                self.version += 1
                return w
        self._alive.append(True)
        self.joined += 1
        self.version += 1
        return self.capacity - 1

    def summary(self) -> dict:
        return {
            "capacity": self.capacity,
            "n_live": self.n_live,
            "m": self.m,
            "version": self.version,
            "joined": self.joined,
            "departed": self.departed,
            "departed_slots": [w for w, a in enumerate(self._alive) if not a],
        }
