"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Model code annotates activations/params with *logical* axis names; a rules
table maps those to physical mesh axes.  Outside a mesh context every
annotation is a no-op, so the same model code runs on 1 CPU device (smoke
tests) and on the 512-chip production mesh (dry-run) unchanged.

Activation axes:
  batch      -> (pod, data)     sequence stays unsharded
  heads/kv_heads/mlp/vocab/experts -> model   (tensor parallelism)
Param axes:
  p_fsdp     -> data            (ZeRO-3: gathered per-layer inside the scan)
  p_heads/p_kv/p_mlp/p_vocab/p_experts -> model
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules",
    "SINGLE_POD_RULES",
    "MULTI_POD_RULES",
    "use_rules",
    "current_rules",
    "current_mesh",
    "logical_spec",
    "lshard",
    "named_sharding",
]

AxisRules = dict[str, Optional[object]]

# Physical axes: ("data", "model") or ("pod", "data", "model").
SINGLE_POD_RULES: AxisRules = {
    "batch": "data",
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": None,   # KV-cache context parallelism (enabled by build_rules
                      # when kv_heads cannot shard the model axis)
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,   # expert-internal ff dim (EP owns "model")
    "expert_cap": None,
    "tokens": "data",     # flattened (batch*seq) token axis in MoE dispatch
    "state": None,
    "layers": None,
    "p_fsdp": "data",
    "p_heads": "model",
    "p_kv": "model",
    "p_mlp": "model",
    "p_vocab": "model",
    "p_experts": "model",
    "p_expert_mlp": None,
    "p_none": None,
    "workers": "data",  # coded-FFT worker axis in the FFT service
}

MULTI_POD_RULES: AxisRules = dict(
    SINGLE_POD_RULES,
    batch=("pod", "data"),
    tokens=("pod", "data"),
)


class _State(threading.local):
    def __init__(self):
        self.rules: Optional[AxisRules] = None
        self.mesh: Optional[Mesh] = None


_STATE = _State()


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[AxisRules] = None):
    """Activate a mesh + logical-rules table for model annotations."""
    if rules is None and mesh is not None:
        rules = MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def current_rules() -> Optional[AxisRules]:
    return _STATE.rules


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def logical_spec(axes: tuple, rules: Optional[AxisRules] = None) -> P:
    """Logical axis names -> PartitionSpec under the active rules."""
    rules = rules if rules is not None else _STATE.rules
    if rules is None:
        return P()
    resolved = []
    for name in axes:
        if name is None:
            resolved.append(None)
        else:
            resolved.append(rules.get(name))
    return P(*resolved)


def named_sharding(axes: tuple, mesh: Optional[Mesh] = None,
                   rules: Optional[AxisRules] = None) -> Optional[NamedSharding]:
    mesh = mesh if mesh is not None else _STATE.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(axes, rules))


def lshard(x: jax.Array, *axes) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical ``axes``.

    No-op when no mesh is active (single-device tests).
    """
    sh = named_sharding(tuple(axes))
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)
