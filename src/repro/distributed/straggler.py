"""Shifted-exponential straggler model + strategy completion times.

Standard model in the coded-computation literature (Lee et al. 2015, and
the model the paper's Remark 4 comparisons presume): a worker processing a
``w`` fraction of the input finishes at

    T_i = w * (t0 + X_i),    X_i ~ Exp(rate mu)   i.i.d.

``t0`` is the deterministic per-unit work, ``1/mu`` the expected tail.  A
strategy that waits for the k-th fastest of N workers completes at the
k-th order statistic; its expectation has the closed form

    E[T_(k)] = w * (t0 + (H_N - H_{N-k}) / mu),   H_n = sum_{i<=n} 1/i.

``t0`` optionally splits into compute and WIRE time: ``wire_frac`` is the
fraction of ``t0`` spent shipping the result shard back to the master
(Jeong et al. 1805.09891 show this master-side communication dominating
coded FFT at scale), and per-draw ``payload_scale`` scales only that
share.  The real-kind shards of DESIGN.md §7 ship half the c2c payload,
so the service charges them ``payload_scale=0.5``:

    T_i = w * (t0 * (1 - wire_frac + wire_frac * payload_scale) + X_i).

With the default ``payload_scale=1`` every formula reduces to the
literature model above, whatever ``wire_frac`` is.

These drive benchmarks/bench_latency.py: coded FFT (k=m, w=1/m) vs
uncoded (k=N partitions, w=1/N) vs repetition / short-dot thresholds.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StragglerModel", "harmonic", "expected_kth_completion",
           "empirical_completion"]


def harmonic(n: int) -> float:
    return float(np.sum(1.0 / np.arange(1, n + 1))) if n > 0 else 0.0


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    t0: float = 1.0         # deterministic seconds per unit workload
    mu: float = 1.0         # exponential rate of the tail
    wire_frac: float = 0.25  # share of t0 that is result-shipping wire
    #                          time, scaled by each draw's payload_scale
    #                          (inert at payload_scale=1, the default)

    def _t0_eff(self, payload_scale: float) -> float:
        return self.t0 * (1.0 - self.wire_frac
                          + self.wire_frac * payload_scale)

    def sample(self, n, workload: float, rng: np.random.Generator,
               *, payload_scale: float = 1.0) -> np.ndarray:
        """Finish times of workers each processing ``workload`` units.

        ``n``: worker count or a shape tuple (e.g. ``(requests, workers)``
        for one vectorized draw per scheduler bucket).  ``payload_scale``
        scales the WIRE share of ``t0`` only (module docstring) -- e.g.
        0.5 for the half-payload real-kind shards.
        """
        return workload * (self._t0_eff(payload_scale)
                           + rng.exponential(1.0 / self.mu, size=n))

    def expected_kth(self, n: int, k: int, workload: float,
                     payload_scale: float = 1.0) -> float:
        return expected_kth_completion(
            self._t0_eff(payload_scale), self.mu, n, k, workload)


def expected_kth_completion(t0: float, mu: float, n: int, k: int,
                            workload: float) -> float:
    """E[k-th order statistic of n shifted-exponential finish times]."""
    if k > n:
        return float("inf")
    return workload * (t0 + (harmonic(n) - harmonic(n - k)) / mu)


def empirical_completion(latencies: np.ndarray, k: int) -> float:
    """Completion time waiting for the k fastest workers."""
    if k > latencies.shape[-1]:
        return float("inf")
    return float(np.sort(latencies, axis=-1)[..., k - 1])
