"""The paper's own end-to-end application: a straggler-tolerant FFT service.

Clients submit transform requests; the service executes them under a coded
computation plan and answers as soon as the fastest ``m`` of ``N`` workers
respond.  The straggler simulator assigns each worker a shifted-exponential
latency per request; the service's reported latency is the m-th order
statistic -- benchmarks compare it against waiting for all N (uncoded) and
against the repetition/short-dot thresholds (paper Remark 4).

The scheduler is batched (DESIGN.md §5): submitted requests are bucketed by
``(s, m)``, stacked along a leading batch axis, padded to a power-of-two
bucket size, and pushed through ONE jitted encode -> worker -> decode call
per bucket with a per-request straggler mask -- master-side work (MDS
encode/decode, recombine) amortizes across the whole bucket instead of
being paid per request.  ``submit`` is the batch-of-one special case.

The default bucket executor is the Pallas kernel pipeline (DESIGN.md §6):
requests are split to f32 real/imag planes ONCE at ingress, interleaved on
planes, pushed through the fused encode+worker kernel (coded shards never
round-trip HBM between encode and the worker DFT), decoded by one batched
MXU matmul against per-request scatter decode matrices from the
:class:`~repro.serving.decode_cache.DecodeMatrixCache` LRU, recombined by
the fused twiddle+DFT kernel, and recombined to complex ONCE at egress.
``use_reference=True`` is the escape hatch back to the jnp-oracle
``plan.run`` executor (as is any config the kernel path does not cover:
a mesh, an explicit ``worker_fn`` plug-in, a pinned ``decode_method``, or
a non-complex64 dtype).

With a mesh, worker compute runs under ``DistributedCodedPlan`` (shard_map,
batch axis threaded through the collectives); without one, it runs on the
local device with identical semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.coded_fft import CodedFFT
from repro.core.strategies import coded_fft_threshold
from repro.distributed.coded_runtime import DistributedCodedPlan
from repro.distributed.straggler import StragglerModel
from repro.kernels import ops, ref
from repro.serving.batching import bucket_size
from repro.serving.decode_cache import DecodeMatrixCache

__all__ = ["FFTServiceConfig", "FFTService", "ServiceStats"]


@dataclasses.dataclass(frozen=True)
class FFTServiceConfig:
    s: int = 4096                 # default transform length
    m: int = 4                    # storage fraction 1/m
    n_workers: int = 8
    dtype: jnp.dtype = jnp.complex64
    straggler: StragglerModel = StragglerModel(t0=1.0, mu=1.0)
    seed: int = 0
    worker_fn: Optional[object] = None   # explicit worker plug-in (overrides
    #                                      the default kernel dispatch)
    use_reference: bool = False   # escape hatch: jnp-oracle hot path
    max_batch: int = 64           # scheduler bucket cap per (s, m)
    decode_method: str = "auto"   # MDS decode dispatch (DESIGN.md §4);
    #                               non-"auto" pins the reference executor
    decode_cache_size: int = 512  # LRU size of per-mask decode matrices
    #                               (past the C(N, k) mask-pattern count for
    #                               small fleets, so steady state is all-hit)


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0               # jitted scheduler invocations
    coded_latency: float = 0.0     # sum of m-th order statistics
    uncoded_latency: float = 0.0   # sum of "wait for everyone" latencies
    stragglers_tolerated: int = 0
    decode_cache_hits: int = 0     # decode-matrix LRU hits (kernel path)
    decode_cache_misses: int = 0   # ... and misses (host inversions paid)

    def summary(self) -> dict:
        n = max(self.requests, 1)
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_coded_latency": self.coded_latency / n,
            "mean_uncoded_latency": self.uncoded_latency / n,
            "speedup": (self.uncoded_latency / self.coded_latency
                        if self.coded_latency > 0 else float("nan")),
            "stragglers_tolerated": self.stragglers_tolerated,
            "decode_cache_hits": self.decode_cache_hits,
            "decode_cache_misses": self.decode_cache_misses,
        }


class FFTService:
    """Batched straggler-tolerant FFT frontend over ``CodedPlan`` execution.

    Requests of any length with ``m | s`` are accepted; each distinct
    ``(s, m)`` gets its own cached plan, decode-matrix LRU, and jitted
    bucket executors.
    """

    def __init__(self, cfg: FFTServiceConfig, mesh: Optional[Mesh] = None,
                 axis: str = "workers"):
        self.cfg = cfg
        self.mesh = mesh
        self.axis = axis
        self.rng = np.random.default_rng(cfg.seed)
        self.stats = ServiceStats()
        self._plans: dict[tuple[int, int], CodedFFT] = {}
        self._runtimes: dict[tuple[int, int], DistributedCodedPlan] = {}
        self._runners: dict[tuple, object] = {}
        self._decode_caches: dict[tuple[int, int], DecodeMatrixCache] = {}
        # default-config plan/runtime, kept as attributes for introspection
        # (and reused by the executor cache for default-length requests)
        self.plan = self._plan_for(cfg.s)
        self.runtime = self._runtime_for(cfg.s) if mesh is not None else None

    # -- plan / compiled-executor caches --------------------------------
    def _plan_for(self, s: int) -> CodedFFT:
        cfg = self.cfg
        key = (s, cfg.m)
        if key not in self._plans:
            kwargs = {}
            if cfg.worker_fn is not None:
                kwargs["worker_fn"] = cfg.worker_fn
            self._plans[key] = CodedFFT(
                s=s, m=cfg.m, n_workers=cfg.n_workers, dtype=cfg.dtype,
                backend="reference" if cfg.use_reference else "kernel",
                **kwargs)
        return self._plans[key]

    def _runtime_for(self, s: int) -> DistributedCodedPlan:
        key = (s, self.cfg.m)
        if key not in self._runtimes:
            self._runtimes[key] = DistributedCodedPlan(
                self._plan_for(s), self.mesh, self.axis)
        return self._runtimes[key]

    def _decode_cache_for(self, s: int) -> DecodeMatrixCache:
        key = (s, self.cfg.m)
        if key not in self._decode_caches:
            self._decode_caches[key] = DecodeMatrixCache(
                np.asarray(self._plan_for(s).generator),
                maxsize=self.cfg.decode_cache_size)
        return self._decode_caches[key]

    def _kernel_path(self, s: int) -> bool:
        """Does this bucket run the fused planar kernel executor?

        The kernel path owns the default local config; anything it does not
        cover -- a mesh (the distributed runtime executes instead), an
        explicit ``worker_fn`` plug-in, a pinned ``decode_method``, a
        reference request, or a non-c64 dtype -- falls back to ``plan.run``.
        """
        cfg = self.cfg
        return (self.mesh is None
                and not cfg.use_reference
                and cfg.worker_fn is None
                and cfg.decode_method == "auto"
                and self._plan_for(s).resolved_backend == "kernel")

    def _runner_for(self, s: int, bucket: int):
        """One jitted batched encode->worker->decode per (s, m, bucket)."""
        kernel = self._kernel_path(s)
        key = (s, self.cfg.m, bucket, kernel)
        if key not in self._runners:
            if kernel:
                self._runners[key] = self._make_kernel_runner(s, bucket)
            else:
                method = self.cfg.decode_method
                if self.mesh is not None:
                    runtime = self._runtime_for(s)
                    fn = lambda xb, masks: runtime.run(xb, masks, method=method)
                else:
                    plan = self._plan_for(s)
                    fn = lambda xb, masks: plan.run(xb, mask=masks, method=method)
                self._runners[key] = jax.jit(fn)
        return self._runners[key]

    def _make_kernel_runner(self, s: int, bucket: int):
        """The fused planar bucket executor (DESIGN.md §6).

        One planar split at ingress, planes threaded end-to-end, one
        complex recombine at egress.  Straggler handling lives entirely in
        the per-request decode matrices (zero columns for non-responders),
        so the jitted function takes no mask.  Bucket shapes that fit the
        VMEM working set run the whole pipeline as ONE Pallas launch
        (``ops.coded_bucket``); larger shapes fall back to the stage
        kernels (fused encode+worker -> decode matmul -> recombine).
        """
        plan = self._plan_for(s)
        m, ell = plan.m, plan.shard_len
        gr, gi = ref.planar(plan.generator)

        if ops.default_interpret():
            # off-TPU: the direct executor (platform-FFT worker stage,
            # gathered compact decode -- DESIGN.md §6)
            def fn(xb: jax.Array, dplanes: jax.Array,
                   subsets: jax.Array) -> jax.Array:
                # dplanes: (2, bucket, m, m) stacked real/imag inverse
                # planes -- ONE transfer per bucket, split for free in-jit
                xr, xi = ref.planar(xb)                  # ingress split
                yr, yi = ops.coded_bucket_direct(
                    xr, xi, dplanes[0], dplanes[1], subsets, gr, gi, s)
                return ref.unplanar(yr, yi)              # egress recombine

            return jax.jit(fn)

        whole = ops.coded_bucket_fusable(s, m, plan.n_workers)

        def fn(xb: jax.Array, dplanes: jax.Array) -> jax.Array:
            # dplanes: (2, bucket, m, N) stacked real/imag scatter decode
            # planes -- ONE host->device transfer, split for free in-jit
            dr, di = dplanes[0], dplanes[1]
            xr, xi = ref.planar(xb)                      # ingress split
            if whole:
                yr, yi = ops.coded_bucket(xr, xi, dr, di, gr, gi, s)
                return ref.unplanar(yr, yi)              # egress recombine
            # interleave on planes: c_i[j] = x[i + j*m]
            cr = jnp.swapaxes(xr.reshape(bucket, ell, m), -1, -2)
            ci = jnp.swapaxes(xi.reshape(bucket, ell, m), -1, -2)
            br, bi = ops.encode_worker(cr, ci, gr, gi)   # fused stage 1+2+3
            hr, hi = ops.decode_apply(dr, di, br, bi)    # batched MXU decode
            yr, yi = ops.recombine_planar(hr, hi, s)     # fused twiddle+DFT
            return ref.unplanar(yr, yi)                  # egress recombine

        return jax.jit(fn)

    # ------------------------------------------------------------------
    def _simulate_arrivals(self, n_requests: int
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Per-request worker latencies + availability masks at decode time.

        One vectorized draw per bucket -- a per-request sampling loop costs
        more host time than the whole decode at service bucket sizes.
        """
        cfg = self.cfg
        k = coded_fft_threshold(cfg.n_workers, cfg.m)
        lat = cfg.straggler.sample(
            (n_requests, cfg.n_workers), 1.0 / cfg.m, self.rng)
        t_done = np.sort(lat, axis=-1)[:, k - 1]
        mask = lat <= t_done[:, None]
        return lat, mask

    def _account(self, lat: np.ndarray, mask: np.ndarray) -> None:
        cfg = self.cfg
        k = coded_fft_threshold(cfg.n_workers, cfg.m)
        lat_sorted = np.sort(lat, axis=-1)
        self.stats.requests += lat.shape[0]
        self.stats.coded_latency += float(lat_sorted[:, k - 1].sum())
        self.stats.uncoded_latency += float(lat_sorted[:, -1].sum())
        self.stats.stragglers_tolerated += int((~mask).sum())

    # ------------------------------------------------------------------
    def submit(self, x: jax.Array) -> np.ndarray:
        """One request: returns F{x}, never waiting for stragglers."""
        return self.submit_batch([x])[0]

    def submit_batch(self, xs: Sequence[jax.Array]) -> list[np.ndarray]:
        """Serve a batch of requests, bucketed by transform length.

        Master-side encode/decode for each bucket runs as ONE jitted call
        over the stacked requests; each request still gets its own
        simulated straggler pattern, and results come back in submission
        order as host arrays (one device->host transfer per bucket).
        """
        cfg = self.cfg
        results: list[Optional[np.ndarray]] = [None] * len(xs)
        by_len: dict[int, list[int]] = {}
        for i, x in enumerate(xs):
            by_len.setdefault(int(x.shape[-1]), []).append(i)

        for s, idxs in by_len.items():
            for start in range(0, len(idxs), cfg.max_batch):
                chunk = idxs[start:start + cfg.max_batch]
                self._run_bucket(s, chunk, xs, results)
        return results  # type: ignore[return-value]

    def _run_bucket(self, s: int, idxs: list[int], xs, results) -> None:
        cfg = self.cfg
        n_live = len(idxs)
        bucket = bucket_size(n_live, cfg.max_batch)
        lat, mask = self._simulate_arrivals(n_live)
        self._account(lat, mask)
        self.stats.batches += 1

        # allocate in the service dtype (NOT the first request's dtype --
        # a real-valued request must not narrow the whole bucket's buffer)
        xb = np.zeros((bucket, s), dtype=np.dtype(self.cfg.dtype))
        for row, i in enumerate(idxs):
            xb[row] = np.asarray(xs[i])
        # padded rows: every worker "responds" so decode stays well-posed
        masks = np.ones((bucket, cfg.n_workers), bool)
        masks[:n_live] = mask

        if self._kernel_path(s):
            # per-request decode matrices from the LRU (host-side: the
            # masks are host data already, and repeats hit the cache)
            cache = self._decode_cache_for(s)
            h0, m0 = cache.hits, cache.misses
            if ops.default_interpret():
                invs, subsets = cache.compact(masks)
                dplanes = np.stack([invs.real, invs.imag]).astype(np.float32)
                args = (jnp.asarray(xb, cfg.dtype), jnp.asarray(dplanes),
                        jnp.asarray(subsets))
            else:
                dmats = cache.matrices(masks)
                dplanes = np.stack([dmats.real, dmats.imag]).astype(np.float32)
                args = (jnp.asarray(xb, cfg.dtype), jnp.asarray(dplanes))
            # deltas, not lifetime cache totals: every other ServiceStats
            # field accumulates, so a stats reset must window these too
            self.stats.decode_cache_hits += cache.hits - h0
            self.stats.decode_cache_misses += cache.misses - m0
            out = self._runner_for(s, bucket)(*args)
        else:
            out = self._runner_for(s, bucket)(
                jnp.asarray(xb, cfg.dtype), jnp.asarray(masks))
        # ONE device->host transfer per bucket: per-request eager jax slices
        # would pay a python lax.slice dispatch per request instead, which
        # dominates the bucket at CPU latencies.  Results are host arrays
        # (views into the bucket transfer); they interop with jnp directly.
        out_rows = np.asarray(out)
        for row, i in enumerate(idxs):
            results[i] = out_rows[row]
