"""The paper's own end-to-end application: a straggler-tolerant FFT service.

Clients submit transform requests; the service executes them under a coded
computation plan and answers as soon as the fastest ``m`` of ``N`` workers
respond.  The straggler simulator assigns each worker a shifted-exponential
latency per request; the service's reported latency is the m-th order
statistic -- benchmarks compare it against waiting for all N (uncoded) and
against the repetition/short-dot thresholds (paper Remark 4).

The scheduler is batched (DESIGN.md §5): submitted requests are bucketed by
``(s, m, kind)`` with ``kind in {c2c, r2c, c2r, rfftn, irfftn}`` (forward
complex, real forward, inverse real -- DESIGN.md §7 -- and the n-D real
pair -- §9), stacked along a leading batch axis, padded to a power-of-two
bucket size, and pushed through ONE jitted encode -> worker -> decode call
per bucket with a per-request straggler mask -- master-side work (MDS
encode/decode, recombine) amortizes across the whole bucket instead of
being paid per request.  ``submit`` is the batch-of-one special case;
``submit_rfft`` / ``submit_irfft`` / ``submit_rfftn`` / ``submit_irfftn``
are the real-kind conveniences.  Real buckets (1-D and n-D) ship HALF the
worker payload (pair-packed shards) and all kinds share one decode-matrix
LRU (the (N, m) generator is length- and kind-independent).  n-D kinds
bucket by the full time-domain shape tuple and run the generic jitted
``plan.run`` executor.

The default bucket executor is the Pallas kernel pipeline (DESIGN.md §6):
requests are split to f32 real/imag planes ONCE at ingress, interleaved on
planes, pushed through the fused encode+worker kernel (coded shards never
round-trip HBM between encode and the worker DFT), decoded by one batched
MXU matmul against per-request decode matrices, recombined by the fused
twiddle+DFT kernel, and recombined to complex ONCE at egress.
``use_reference=True`` is the escape hatch back to the jnp-oracle
``plan.run`` executor (as is any config the kernel path does not cover:
a mesh, an explicit ``worker_fn`` plug-in, a pinned ``decode_method``, or
a non-complex64 dtype).

The submit-to-result path is DEVICE-RESIDENT and ASYNCHRONOUS
(DESIGN.md §8).  Decode matrices are built inside the jitted bucket
executor from each request's straggler mask via the closed-form Lagrange
inversion (``mds.lagrange_inverse``) -- no host ``linalg.inv``, no LRU
side channel, a novel mask costs exactly what a repeated one does.  The
host-side :class:`~repro.serving.decode_cache.DecodeMatrixCache` remains
only as the fallback for ``m > mds.LAGRANGE_MAX_M`` (or
``device_decode=False``).  ``submit_batch`` DISPATCHES every (s, m, kind)
bucket before any host sync -- ingress buffers are donated to XLA
(``donate_argnums``), legal precisely because decode became jittable and
nothing host-side aliases the bucket I/O -- then performs ONE device->host
transfer for the whole call.  ``ServiceStats`` splits dispatch vs sync
wall time and counts host transfers so the async win is observable.

With a mesh, worker compute runs under ``DistributedCodedPlan`` (shard_map,
batch axis threaded through the collectives); without one, it runs on the
local device with identical semantics.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import mds
from repro.core.coded_fft import CodedFFT, plan_factors
from repro.core.fault_tolerance import detect_errors, robust_decode
from repro.core.rfft import CodedIRFFT, CodedRFFT
from repro.core.rfftn import CodedIRFFTN, CodedRFFTN
from repro.core.strategies import REGISTRY, make_strategy
from repro.distributed.coded_runtime import DistributedCodedPlan
from repro.distributed.elastic import ElasticWorkerPool
from repro.distributed.faults import FaultInjector, FaultPlan, RoundFaults
from repro.distributed.health import WorkerHealthTracker
from repro.distributed.straggler import StragglerModel
from repro.distributed.worker_runtime import MeasuredWorkerRuntime
from repro.kernels import autotune, ops, ref
from repro.serving.batching import LatencyHistogram, bucket_size
from repro.serving.decode_cache import DecodeMatrixCache

__all__ = ["DegradedResult", "FAILURE_REASONS", "FFTService",
           "FFTServiceConfig", "ServiceError", "ServiceStats"]

# machine-readable per-request failure reasons (DESIGN.md §12)
FAILURE_REASONS = ("insufficient_workers", "retries_exhausted",
                   "corrupt_uncorrectable")


class ServiceError(RuntimeError):
    """Typed per-request failure from the fault-tolerant service path.

    ``reason`` is one of :data:`FAILURE_REASONS`:

    * ``insufficient_workers`` -- fewer than ``m`` live workers exist (or
      none are healthy enough to re-dispatch to), so the MDS threshold is
      unreachable no matter how long the master waits.
    * ``retries_exhausted`` -- ``m`` responses never arrived inside the
      capped retry windows (``max_retries`` x ``retry_backoff``).
    * ``corrupt_uncorrectable`` -- the Byzantine syndrome check failed and
      correction was impossible (``verify="detect"``, or more than
      ``floor((k - m)/2)`` corrupt responders under ``verify="correct"``).

    Surfaces as a raised exception from ``submit_batch``
    (``on_failure="raise"``), a :class:`DegradedResult` slot
    (``on_failure="degrade"``), and a per-request Future exception on the
    streaming path -- never as a dead scheduler thread.
    """

    def __init__(self, reason: str, detail: str = ""):
        if reason not in FAILURE_REASONS:
            raise ValueError(f"unknown failure reason {reason!r}")
        super().__init__(f"request failed: {reason}"
                         + (f" ({detail})" if detail else ""))
        self.reason = reason
        self.detail = detail


@dataclasses.dataclass(frozen=True)
class DegradedResult:
    """Graceful-degradation slot value (``on_failure="degrade"``).

    Takes the place of the transform result for a request the fault path
    could not serve; ``reason``/``detail`` mirror :class:`ServiceError`.
    """

    reason: str
    detail: str = ""

    @property
    def ok(self) -> bool:
        return False


class _Launched:
    """A launched robust bucket: device/host rows + per-row errors."""

    __slots__ = ("out", "errors")

    def __init__(self, out, errors):
        self.out = out          # device array or host ndarray (verify path)
        self.errors = errors    # per-bucket-row Optional[ServiceError]


def _donate_ingress(fn):
    """Jit ``fn`` with its ingress buffer donated.

    The real-kind bucket I/O changes shape across the call (``f32[b, s]``
    -> ``c64[b, s//2+1]`` and its adjoint), so XLA can never ALIAS the
    donated ingress to the output the way the same-shape c2c path does --
    but donation still releases the buffer after its last use, so the
    encode/worker temporaries reuse its memory instead of growing the
    peak bucket footprint (ROADMAP item 5).  jax warns per-executable
    that no aliasing happened; that is the expected outcome here, not a
    bug signal, so the message is filtered (idempotently, message-scoped)
    when such a runner is built.
    """
    warnings.filterwarnings(
        "ignore", message="Some donated buffers were not usable")
    return jax.jit(fn, donate_argnums=0)


@dataclasses.dataclass(frozen=True)
class FFTServiceConfig:
    s: int = 4096                 # default transform length
    m: int = 4                    # storage fraction 1/m
    n_workers: int = 8
    dtype: jnp.dtype = jnp.complex64
    straggler: StragglerModel = StragglerModel(t0=1.0, mu=1.0)
    seed: int = 0
    worker_fn: Optional[object] = None   # explicit worker plug-in (overrides
    #                                      the default kernel dispatch)
    use_reference: bool = False   # escape hatch: jnp-oracle hot path
    max_batch: int = 64           # scheduler bucket cap per (s, m)
    decode_method: str = "auto"   # MDS decode dispatch (DESIGN.md §4);
    #                               non-"auto" pins the reference executor
    device_decode: bool = True    # build decode matrices IN the jitted
    #                               bucket executor (Lagrange closed form,
    #                               DESIGN.md §8); automatic fallback to the
    #                               host LRU for m > mds.LAGRANGE_MAX_M
    decode_cache_size: int = 512  # LRU size of per-mask decode matrices
    #                               (the m > LAGRANGE_MAX_M / pinned-config
    #                               fallback; past the C(N, k) mask-pattern
    #                               count for small fleets, so steady state
    #                               is all-hit)
    precision: str = "f32"        # kernel plane precision: "bf16" casts the
    #                               DFT/twiddle planes to bfloat16 (f32
    #                               accumulation); a per-(s, m, kind) probe
    #                               against the f32 twin auto-disables any
    #                               shape whose error exceeds ops.BF16_RTOL
    autotune: bool = True         # measure candidate tilings/variants at
    #                               warmup() and persist the winning table
    #                               to the backend-keyed JSON cache
    #                               (kernels/autotune.py); dispatch falls
    #                               back to the static heuristics when off
    autotune_reps: int = 3        # timing repetitions per candidate
    # -- fault-tolerant runtime (opt-in; DESIGN.md §12) -----------------
    faults: Optional[FaultPlan] = None  # seeded kill/delay/corrupt schedule;
    #                               None leaves every code path byte-identical
    #                               to the fault-free build
    health: bool = False          # track per-worker EWMAs and derive each
    #                               round's availability mask from a DEADLINE
    #                               (m-th-fastest estimate + slack) instead of
    #                               a straggler draw's k-th order statistic
    deadline_slack: float = 0.5   # deadline = (1 + slack) * m-th-fastest
    max_retries: int = 2          # re-dispatch rounds for missing shards
    retry_backoff: float = 2.0    # wait-window multiplier per retry
    verify: str = "off"           # Byzantine check on surplus responses when
    #                               k > m arrive: "off" | "detect" | "correct"
    #                               (paper Remark 3: detect k-m, correct
    #                               floor((k-m)/2))
    verify_quorum: int = 2        # measured path only: extra rows beyond m
    #                               the master waits for when verify is on
    #                               (k = m + q detects q, corrects q//2)
    on_failure: str = "raise"     # "raise" ServiceError from submit_batch, or
    #                               "degrade" to a DegradedResult slot
    measured: bool = False        # run buckets on the thread-per-worker
    #                               MeasuredWorkerRuntime (real wall-clock
    #                               deadlines/retries; c2c kinds only)
    require_all: bool = False     # measured path waits for ALL live workers
    #                               (the uncoded baseline for the fault bench)
    # -- computation strategy (DESIGN.md §13) ---------------------------
    strategy: str = "mds"         # registered strategy serving the c2c
    #                               buckets: "mds" (the paper's code),
    #                               "partial" (Wang 1804.09791: r fragments
    #                               per worker, decode from any m*r),
    #                               "comm_efficient" (Jeong 1805.09891:
    #                               1/q payload at threshold m*q), or
    #                               "repetition".  Non-"mds" strategies are
    #                               c2c-only and run the jnp executor.
    strategy_param: Optional[int] = None  # the strategy's own knob (r for
    #                               partial, q for comm_efficient); None
    #                               means the registry entry's default


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0               # jitted scheduler invocations
    coded_latency: float = 0.0     # sum of m-th order statistics
    uncoded_latency: float = 0.0   # sum of "wait for everyone" latencies
    stragglers_tolerated: int = 0
    decode_cache_hits: int = 0     # decode-matrix LRU hits (fallback path)
    decode_cache_misses: int = 0   # ... and misses (host inversions paid);
    #                                both stay 0 on the device-decode path
    dispatch_s: float = 0.0        # wall time staging + launching buckets
    sync_s: float = 0.0            # wall time blocked on device results
    host_transfers: int = 0        # device->host fetches (1 per submit_batch
    #                                call; 1 per bucket on the streaming path)
    # -- open-loop streaming observables (serving/streaming.py, §11) ----
    queue_peak: int = 0            # high-water mark of undispatched requests
    rejected: int = 0              # admission-control rejections (both
    #                                "queue_full" and "closed" reasons)
    cancelled: int = 0             # futures the caller cancelled before
    #                                resolution (the bucket still computed)
    fill_dispatches: int = 0       # buckets dispatched because they filled
    deadline_dispatches: int = 0   # ... because the earliest deadline
    #                                across bucket heads expired (EDF)
    drain_dispatches: int = 0      # ... flushed by drain()/close()
    staging_overlap_s: float = 0.0  # host staging wall time hidden behind
    #                                 a downstream bucket's device compute
    # -- fault-tolerant runtime observables (§12) -----------------------
    retries: int = 0               # retry rounds performed (window extensions)
    redispatched_shards: int = 0   # shard computations re-dispatched to
    #                                healthy workers after a missed deadline
    degraded: int = 0              # requests that failed with a typed reason
    detected: int = 0              # corrupt workers caught by the syndrome
    #                                check (verify="detect"/"correct")
    corrected: int = 0             # ... of those, corrected (verify="correct")
    latency: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)  # per-request arrival->result
    tier_latency: dict = dataclasses.field(default_factory=dict)
    #                              # per-SLO-tier LatencyHistogram, keyed by
    #                                tier name (streaming front-end only)

    def summary(self) -> dict:
        n = max(self.requests, 1)
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_coded_latency": self.coded_latency / n,
            "mean_uncoded_latency": self.uncoded_latency / n,
            "speedup": (self.uncoded_latency / self.coded_latency
                        if self.coded_latency > 0 else float("nan")),
            "stragglers_tolerated": self.stragglers_tolerated,
            "decode_cache_hits": self.decode_cache_hits,
            "decode_cache_misses": self.decode_cache_misses,
            "dispatch_s": self.dispatch_s,
            "sync_s": self.sync_s,
            "host_transfers": self.host_transfers,
            "queue_peak": self.queue_peak,
            "rejected": self.rejected,
            "cancelled": self.cancelled,
            "fill_dispatches": self.fill_dispatches,
            "deadline_dispatches": self.deadline_dispatches,
            "drain_dispatches": self.drain_dispatches,
            "staging_overlap_s": self.staging_overlap_s,
            "retries": self.retries,
            "redispatched_shards": self.redispatched_shards,
            "degraded": self.degraded,
            "detected": self.detected,
            "corrected": self.corrected,
            "latency": self.latency.summary(),
            "tiers": {name: hist.summary()
                      for name, hist in sorted(self.tier_latency.items())},
        }


class FFTService:
    """Batched straggler-tolerant FFT frontend over ``CodedPlan`` execution.

    Requests of any length with ``m | s`` are accepted; each distinct
    ``(s, m)`` gets its own cached plan, decode-matrix LRU, and jitted
    bucket executors.
    """

    KINDS = ("c2c", "r2c", "c2r", "rfftn", "irfftn")
    # half-payload kinds: workers ship pair-packed shards with a halved
    # (last) axis, so their wire time is charged at payload_scale=0.5
    REAL_KINDS = ("r2c", "c2r", "rfftn", "irfftn")
    # n-D kinds bucket by the full TIME-domain shape tuple instead of a
    # scalar length and run the generic jitted ``plan.run`` executor (the
    # fused planar bucket kernels are 1-D layouts)
    ND_KINDS = ("rfftn", "irfftn")

    def __init__(self, cfg: FFTServiceConfig, mesh: Optional[Mesh] = None,
                 axis: str = "workers",
                 pool: Optional[ElasticWorkerPool] = None):
        if cfg.verify not in ("off", "detect", "correct"):
            raise ValueError(
                f'verify must be "off"|"detect"|"correct", got {cfg.verify!r}')
        if cfg.on_failure not in ("raise", "degrade"):
            raise ValueError(
                f'on_failure must be "raise"|"degrade", got {cfg.on_failure!r}')
        if cfg.strategy not in REGISTRY:
            raise ValueError(
                f"unknown strategy {cfg.strategy!r}; "
                f"registered: {sorted(REGISTRY)}")
        if cfg.strategy != "mds":
            # the Byzantine verifier and the measured runtime speak the
            # (N, m) MDS row code; the worker plug-in contract is the MDS
            # c2c worker
            if cfg.verify != "off" or cfg.measured:
                raise ValueError(
                    f"strategy {cfg.strategy!r} does not compose with "
                    f"verify/measured (MDS-row machinery)")
            if cfg.worker_fn is not None:
                raise ValueError(
                    f"worker_fn plug-ins apply to the mds strategy only, "
                    f"got strategy {cfg.strategy!r}")
            if cfg.strategy == "repetition":
                # its replication decode is host-side block assembly, not
                # the jittable masked-subset protocol the bucket executors
                # speak; it stays a Remark-4 benchmark baseline
                raise ValueError(
                    "the repetition baseline is bench-only; the service "
                    "serves subset-decodable strategies")
        if mesh is not None and not REGISTRY[cfg.strategy].mesh_ok:
            raise ValueError(
                f"strategy {cfg.strategy!r} does not compose with a mesh")
        if pool is not None and pool.m != cfg.m:
            raise ValueError(
                f"pool threshold m={pool.m} must match cfg.m={cfg.m}")
        self.cfg = cfg
        self.mesh = mesh
        self.axis = axis
        self.pool = pool
        self.rng = np.random.default_rng(cfg.seed)
        self.stats = ServiceStats()
        # keyed by (s, m, kind, N); s is a scalar length for 1-D kinds and
        # the time-domain shape tuple for the n-D kinds.  N rides in the
        # key because an ElasticWorkerPool can GROW capacity live -- each
        # capacity is a distinct roots-of-unity code (DESIGN.md §12)
        self._plans: dict[tuple, object] = {}
        self._runtimes: dict[tuple, DistributedCodedPlan] = {}
        self._runners: dict[tuple, object] = {}
        # ONE decode-matrix LRU for the whole service: the (N, m) generator
        # -- hence every per-mask decode matrix -- is independent of both
        # the transform length s and the bucket kind, so c2c/r2c/c2r
        # buckets at every length share hits (DESIGN.md §7).  Keyed by N
        # (dict) only because elastic growth changes the generator.
        self._decode_caches: dict[int, DecodeMatrixCache] = {}
        # -- fault-tolerant runtime state (DESIGN.md §12) ---------------
        self._robust = (cfg.faults is not None or cfg.health
                        or cfg.verify != "off" or cfg.measured
                        or pool is not None)
        self.injector = (FaultInjector(cfg.faults)
                         if cfg.faults is not None else None)
        self.health = (WorkerHealthTracker(
            self._n_workers(), slack_frac=cfg.deadline_slack)
            if self._robust else None)
        self._measured: dict[tuple, MeasuredWorkerRuntime] = {}
        self._round = 0                # monotone fault/health round counter
        if self._robust and mesh is not None:
            raise ValueError("the fault-tolerant service path is host-"
                             "orchestrated; it does not compose with a mesh")
        # default-config plan/runtime, kept as attributes for introspection
        # (and reused by the executor cache for default-length requests)
        self.plan = self._plan_for(cfg.s)
        self.runtime = self._runtime_for(cfg.s) if mesh is not None else None

    def _n_workers(self) -> int:
        """Current code size N: pool capacity when elastic, else static."""
        return self.pool.capacity if self.pool is not None else self.cfg.n_workers

    # -- plan / compiled-executor caches --------------------------------
    def _plan_for(self, s, kind: str = "c2c"):
        """The plan serving ``(s, m, kind)`` buckets (DESIGN.md §7/§9).

        ``kind``: ``c2c`` forward complex, ``r2c`` real forward, ``c2r``
        inverse real, ``rfftn``/``irfftn`` the n-D real pair.  ``s`` is
        always the TIME-domain extent: a scalar length for the 1-D kinds,
        the full shape tuple for the n-D kinds (whose interleave factors
        come from :func:`repro.core.coded_fft.plan_factors`).
        """
        if kind not in self.KINDS:
            raise ValueError(f"unknown bucket kind {kind!r}")
        cfg = self.cfg
        n = self._n_workers()
        key = (s, cfg.m, kind, n)
        if key not in self._plans:
            if cfg.strategy != "mds":
                if kind != "c2c":
                    # the real/n-D pipelines (pair packing, Hermitian
                    # recombine) are built on the (N, m) MDS row code
                    raise ValueError(
                        f"strategy {cfg.strategy!r} serves c2c buckets "
                        f"only; got a {kind!r} request")
                ent = REGISTRY[cfg.strategy]
                if not ent.applicable(s, cfg.m, n, cfg.strategy_param):
                    raise ValueError(
                        f"strategy {cfg.strategy!r} is not applicable at "
                        f"(s={s}, m={cfg.m}, N={n}, "
                        f"param={cfg.strategy_param})")
                # always the jnp executor: the fused planar bucket kernels
                # are (N, m) MDS layouts (StrategyEntry.kernel_ok)
                self._plans[key] = make_strategy(
                    cfg.strategy, s, cfg.m, n, dtype=cfg.dtype,
                    backend="reference", param=cfg.strategy_param)
                return self._plans[key]
            if cfg.worker_fn is not None and kind != "c2c":
                # the plug-in contract is the c2c worker (fft along the
                # last axis); silently serving real-kind traffic without
                # it would un-instrument fault-injection setups
                raise ValueError(
                    f"worker_fn plug-ins only apply to c2c buckets; "
                    f"got a {kind!r} request on a worker_fn service")
            backend = "reference" if cfg.use_reference else "kernel"
            if kind in self.ND_KINDS:
                shape = tuple(int(d) for d in s)
                # even_last_shard biases the factor placement so any
                # shape with a valid real-kind factorization is served
                # (a kind-agnostic greedy split can land a factor on the
                # last axis and leave an odd shard spuriously)
                factors = plan_factors(shape, cfg.m, even_last_shard=True)
                cls = CodedRFFTN if kind == "rfftn" else CodedIRFFTN
                self._plans[key] = cls(
                    shape=shape, factors=factors, n_workers=n,
                    dtype=cfg.dtype, backend=backend)
                return self._plans[key]
            common = dict(s=s, m=cfg.m, n_workers=n,
                          dtype=cfg.dtype, backend=backend)
            if kind == "r2c":
                self._plans[key] = CodedRFFT(**common)
            elif kind == "c2r":
                self._plans[key] = CodedIRFFT(**common)
            else:
                kwargs = {}
                if cfg.worker_fn is not None:
                    kwargs["worker_fn"] = cfg.worker_fn
                self._plans[key] = CodedFFT(**common, **kwargs)
        return self._plans[key]

    def _runtime_for(self, s: int, kind: str = "c2c") -> DistributedCodedPlan:
        key = (s, self.cfg.m, kind, self._n_workers())
        if key not in self._runtimes:
            self._runtimes[key] = DistributedCodedPlan(
                self._plan_for(s, kind), self.mesh, self.axis)
        return self._runtimes[key]

    def _decode_cache_for(self) -> DecodeMatrixCache:
        n = self._n_workers()
        if n not in self._decode_caches:
            self._decode_caches[n] = DecodeMatrixCache(
                np.asarray(self._plan_for(self.cfg.s).generator),
                maxsize=self.cfg.decode_cache_size)
        return self._decode_caches[n]

    def _kernel_path(self, s, kind: str = "c2c") -> bool:
        """Does this bucket run the fused planar kernel executor?

        The kernel path owns the default local config; anything it does not
        cover -- a mesh (the distributed runtime executes instead), an
        explicit ``worker_fn`` plug-in, a pinned ``decode_method``, a
        reference request, a non-c64 dtype, or an n-D kind (the planar
        bucket executors are 1-D layouts; rfftn/irfftn run the generic
        jitted ``plan.run``, whose encode/worker stages still dispatch to
        the Pallas kernels) -- falls back to ``plan.run``.
        """
        cfg = self.cfg
        return (kind not in self.ND_KINDS
                and cfg.strategy == "mds"
                and self.mesh is None
                and not cfg.use_reference
                and cfg.worker_fn is None
                and cfg.decode_method == "auto"
                and self._plan_for(s, kind).resolved_backend == "kernel")

    def _device_decode(self) -> bool:
        """Are decode matrices built inside the jitted executor?

        True on the default kernel path for ``m <= mds.LAGRANGE_MAX_M``
        (the closed-form Lagrange inversion, DESIGN.md §8); past that the
        f32 planes cannot carry adversarial-subset conditioning and the
        host complex128 LRU takes over.
        """
        return self.cfg.device_decode and self.cfg.m <= mds.LAGRANGE_MAX_M

    def _precision_for(self, s, kind: str) -> str:
        """Resolved kernel plane precision for one bucket family.

        ``cfg.precision="bf16"`` is a REQUEST, not a guarantee: the first
        bucket of each (s, m, kind) probes the bf16 pipeline against its
        f32 twin and auto-disables the shape (verdict recorded in the
        autotune table, so it persists with the tiling entries) whenever
        the relative error exceeds ``ops.BF16_RTOL`` -- the same budget
        the property suite enforces.
        """
        cfg = self.cfg
        if cfg.precision != "bf16" or kind in self.ND_KINDS or \
                not isinstance(s, int):
            return "f32"
        mode = ops._mode(None)
        ent = autotune.lookup("bf16", s=s, m=cfg.m, k=kind, mode=mode)
        if ent is None:
            ent = autotune.record(
                "bf16", {"ok": bool(self._probe_bf16(s, kind))},
                s=s, m=cfg.m, k=kind, mode=mode)
        return "bf16" if ent.get("ok") else "f32"

    def _probe_bf16(self, s: int, kind: str) -> bool:
        """Does the bf16-plane pipeline stay inside the f32 error budget
        at this (s, m, kind)?  Compares one small bucket against the f32
        run of the SAME masked executor (full-responder masks)."""
        plan = self._plan_for(s, kind)
        m, n = plan.m, plan.n_workers
        gr, gi = ref.planar(plan.generator)
        rng = np.random.default_rng(0)
        q = 2
        masks = jnp.asarray(np.ones((q, n), bool))
        f32 = np.float32
        if kind == "r2c":
            xb = jnp.asarray(rng.standard_normal((q, s)).astype(f32))
            run = lambda p: ops.coded_rbucket_masked(
                xb, masks, gr, gi, s, precision=p)
        elif kind == "c2r":
            yr = jnp.asarray(rng.standard_normal((q, s // 2 + 1)).astype(f32))
            yi = jnp.asarray(rng.standard_normal((q, s // 2 + 1)).astype(f32))
            run = lambda p: ops.coded_irbucket_masked(
                yr, yi, masks, gr, gi, s, precision=p)
        else:
            xr = jnp.asarray(rng.standard_normal((q, s)).astype(f32))
            xi = jnp.asarray(rng.standard_normal((q, s)).astype(f32))
            run = lambda p: ops.coded_bucket_masked(
                xr, xi, masks, gr, gi, s, precision=p)
        try:
            want = run("f32")
            got = run("bf16")
        except Exception:
            return False
        want = want if isinstance(want, tuple) else (want,)
        got = got if isinstance(got, tuple) else (got,)
        scale = max(float(jnp.max(jnp.abs(w))) for w in want) or 1.0
        err = max(float(jnp.max(jnp.abs(g - w)))
                  for g, w in zip(got, want)) / scale
        return err <= ops.BF16_RTOL

    def _runner_for(self, s, bucket: int, kind: str = "c2c"):
        """One jitted batched encode->worker->decode per (s, m, kind,
        bucket).  The executables persist for the service lifetime --
        :meth:`warmup` keys them once so steady state never compiles.
        n-D kinds always take the generic ``plan.run`` branch."""
        kernel = self._kernel_path(s, kind)
        dev = kernel and self._device_decode()
        prec = self._precision_for(s, kind) if kernel else "f32"
        key = (s, self.cfg.m, kind, bucket, kernel, dev, prec,
               self._n_workers())
        if key not in self._runners:
            if dev:
                self._runners[key] = self._make_masked_runner(s, bucket, kind)
            elif kernel:
                self._runners[key] = self._make_kernel_runner(s, bucket, kind)
            else:
                method = self.cfg.decode_method
                nf = int(getattr(self._plan_for(s, kind), "fragments", 1))
                if self.mesh is not None:
                    runtime = self._runtime_for(s, kind)
                    if nf > 1:
                        fn = lambda xb, masks: runtime.run(
                            xb, fragment_mask=masks, method=method)
                    else:
                        fn = lambda xb, masks: runtime.run(
                            xb, masks, method=method)
                else:
                    plan = self._plan_for(s, kind)
                    if nf > 1:
                        # partial-work strategy: the staged masks are
                        # per-fragment (bucket, N, r)
                        fn = lambda xb, masks: plan.run(
                            xb, fragment_mask=masks, method=method)
                    else:
                        fn = lambda xb, masks: plan.run(
                            xb, mask=masks, method=method)
                self._runners[key] = jax.jit(fn)
        return self._runners[key]

    def _make_masked_runner(self, s: int, bucket: int, kind: str = "c2c"):
        """The device-decode bucket executor (DESIGN.md §8).

        Takes ``(requests, masks)`` and nothing else: the whole-bucket
        kernels consume the RAW masks -- subset selection, Lagrange decode
        matrices, worker transform and recombine all happen inside ONE
        jitted call, and on TPU inside one Pallas launch with the decode
        matrices built in VMEM (``ops.coded_bucket_masked``; shapes past
        the VMEM budget stream through the double-buffered grid, §10).
        The c2c ingress buffer is donated: with no host-side decode cache
        aliasing bucket I/O, XLA may reuse the request buffer for the
        same-shape spectrum output.
        """
        plan = self._plan_for(s, kind)
        m, n = plan.m, plan.n_workers
        gr, gi = ref.planar(plan.generator)
        n2 = s // m // 2  # packed shard length of the real kinds
        direct = ops.default_interpret()
        prec = self._precision_for(s, kind)

        if kind == "r2c":
            whole = not direct and ops.coded_rbucket_fusable(s, m, n)

            def fn(xb, masks):
                if direct:
                    subsets = ops.mask_subsets(masks, m)
                    ivr, ivi = ops.lagrange_compact_planes(subsets, n)
                    yr, yi = ops.coded_rbucket_direct(
                        xb, ivr, ivi, subsets, gr, gi, s)
                elif whole:
                    yr, yi = ops.coded_rbucket_masked(xb, masks, gr, gi, s,
                                                      precision=prec)
                else:
                    subsets = ops.mask_subsets(masks, m)
                    dr, di = ops.lagrange_scatter_planes(subsets, n)
                    zr, zi = ops.pack_real_planes(xb, m)
                    br, bi = ops.encode_worker(zr, zi, gr, gi)
                    hr, hi = ops.decode_apply(dr, di, br, bi)
                    yr, yi = ops.rfft_postdecode_planar(hr, hi, s)
                return ref.unplanar(yr, yi)

            # real ingress donated too (ROADMAP item 5): no aliasing (the
            # shape changes), but the f32 request buffer frees early for
            # the encode/worker temporaries
            return _donate_ingress(fn)

        if kind == "c2r":
            whole = not direct and ops.coded_irbucket_fusable(s, m, n)

            def fn(yb, masks):
                yr, yi = ref.planar(yb)
                if direct:
                    subsets = ops.mask_subsets(masks, m)
                    ivr, ivi = ops.lagrange_compact_planes(subsets, n)
                    return ops.coded_irbucket_direct(
                        yr, yi, ivr, ivi, subsets, gr, gi, s)
                if whole:
                    # ONE Pallas launch with in-VMEM decode matrices --
                    # the last kind to get a whole-bucket kernel
                    # (DESIGN.md §9)
                    return ops.coded_irbucket_masked(yr, yi, masks,
                                                     gr, gi, s,
                                                     precision=prec)
                subsets = ops.mask_subsets(masks, m)
                dr, di = ops.lagrange_scatter_planes(subsets, n)
                zr, zi = ops.irfft_message_planar(yr, yi, s, m)
                br, bi = ops.encode_worker(zr, -zi, gr, -gi)
                br, bi = br / n2, -bi / n2
                hr, hi = ops.decode_apply(dr, di, br, bi)
                return ops.irfft_unpack_planar(hr, hi)

            # half-spectrum ingress donated (same early-free rationale)
            return _donate_ingress(fn)

        whole = not direct and (ops.coded_bucket_fusable(s, m, n)
                                or ops.coded_bucket_streamable(s, m, n))
        ell = plan.shard_len

        def fn(xb, masks):
            xr, xi = ref.planar(xb)
            if direct:
                subsets = ops.mask_subsets(masks, m)
                ivr, ivi = ops.lagrange_compact_planes(subsets, n)
                yr, yi = ops.coded_bucket_direct(
                    xr, xi, ivr, ivi, subsets, gr, gi, s)
            elif whole:
                yr, yi = ops.coded_bucket_masked(xr, xi, masks, gr, gi, s,
                                                 precision=prec)
            else:
                subsets = ops.mask_subsets(masks, m)
                dr, di = ops.lagrange_scatter_planes(subsets, n)
                cr = jnp.swapaxes(xr.reshape(bucket, ell, m), -1, -2)
                ci = jnp.swapaxes(xi.reshape(bucket, ell, m), -1, -2)
                br, bi = ops.encode_worker(cr, ci, gr, gi)
                hr, hi = ops.decode_apply(dr, di, br, bi)
                yr, yi = ops.recombine_planar(hr, hi, s)
            return ref.unplanar(yr, yi)

        # c2c donation is a true in-place ALIAS: the (bucket, s) c64
        # output matches the ingress buffer exactly (the real kinds above
        # donate for the early-free only)
        return jax.jit(fn, donate_argnums=0)

    def _make_kernel_runner(self, s: int, bucket: int, kind: str = "c2c"):
        """The fused planar bucket executor (DESIGN.md §6/§7).

        One planar split at ingress, planes threaded end-to-end, one
        complex recombine at egress.  Straggler handling lives entirely in
        the per-request decode matrices (zero columns for non-responders),
        so the jitted function takes no mask.  Bucket shapes that fit the
        VMEM working set run the whole pipeline as ONE Pallas launch
        (``ops.coded_bucket`` / ``ops.coded_rbucket``); larger shapes fall
        back to the stage kernels (fused encode+worker -> decode matmul ->
        recombine).

        ``r2c`` buckets never split at ingress at all -- the real request
        IS its plane -- and ship half-length packed shards; ``c2r`` buckets
        run the adjoint message stage and return a single real plane.
        """
        plan = self._plan_for(s, kind)
        m = plan.m
        gr, gi = ref.planar(plan.generator)
        n2 = s // m // 2  # packed shard length of the real kinds
        prec = self._precision_for(s, kind)

        if kind == "r2c":
            if ops.default_interpret():
                def fn(xb, dplanes, subsets):
                    yr, yi = ops.coded_rbucket_direct(
                        xb, dplanes[0], dplanes[1], subsets, gr, gi, s)
                    return ref.unplanar(yr, yi)

                return jax.jit(fn)

            whole = ops.coded_rbucket_fusable(s, m, plan.n_workers)

            def fn(xb, dplanes):
                dr, di = dplanes[0], dplanes[1]
                if whole:
                    yr, yi = ops.coded_rbucket(xb, dr, di, gr, gi, s,
                                               precision=prec)
                    return ref.unplanar(yr, yi)
                zr, zi = ops.pack_real_planes(xb, m)     # relabel ingress
                br, bi = ops.encode_worker(zr, zi, gr, gi)
                hr, hi = ops.decode_apply(dr, di, br, bi)
                yr, yi = ops.rfft_postdecode_planar(hr, hi, s)
                return ref.unplanar(yr, yi)

            return jax.jit(fn)

        if kind == "c2r":
            if ops.default_interpret():
                def fn(yb, dplanes, subsets):
                    yr, yi = ref.planar(yb)              # ingress split
                    return ops.coded_irbucket_direct(
                        yr, yi, dplanes[0], dplanes[1], subsets, gr, gi, s)

                return jax.jit(fn)

            whole = ops.coded_irbucket_fusable(s, m, plan.n_workers)

            def fn(yb, dplanes):
                dr, di = dplanes[0], dplanes[1]
                yr, yi = ref.planar(yb)
                if whole:
                    return ops.coded_irbucket(yr, yi, dr, di, gr, gi, s,
                                              precision=prec)
                zr, zi = ops.irfft_message_planar(yr, yi, s, m)
                # ifft(G @ z) via the conj trick on planes:
                # conj(fft(conj(G) @ conj(z))) / n2 through the same fused
                # encode+worker kernel
                br, bi = ops.encode_worker(zr, -zi, gr, -gi)
                br, bi = br / n2, -bi / n2
                hr, hi = ops.decode_apply(dr, di, br, bi)
                return ops.irfft_unpack_planar(hr, hi)   # real egress

            return jax.jit(fn)

        ell = plan.shard_len
        if ops.default_interpret():
            # off-TPU: the direct executor (platform-FFT worker stage,
            # gathered compact decode -- DESIGN.md §6)
            def fn(xb: jax.Array, dplanes: jax.Array,
                   subsets: jax.Array) -> jax.Array:
                # dplanes: (2, bucket, m, m) stacked real/imag inverse
                # planes -- ONE transfer per bucket, split for free in-jit
                xr, xi = ref.planar(xb)                  # ingress split
                yr, yi = ops.coded_bucket_direct(
                    xr, xi, dplanes[0], dplanes[1], subsets, gr, gi, s)
                return ref.unplanar(yr, yi)              # egress recombine

            return jax.jit(fn)

        whole = (ops.coded_bucket_fusable(s, m, plan.n_workers)
                 or ops.coded_bucket_streamable(s, m, plan.n_workers))

        def fn(xb: jax.Array, dplanes: jax.Array) -> jax.Array:
            # dplanes: (2, bucket, m, N) stacked real/imag scatter decode
            # planes -- ONE host->device transfer, split for free in-jit
            dr, di = dplanes[0], dplanes[1]
            xr, xi = ref.planar(xb)                      # ingress split
            if whole:
                yr, yi = ops.coded_bucket(xr, xi, dr, di, gr, gi, s,
                                          precision=prec)
                return ref.unplanar(yr, yi)              # egress recombine
            # interleave on planes: c_i[j] = x[i + j*m]
            cr = jnp.swapaxes(xr.reshape(bucket, ell, m), -1, -2)
            ci = jnp.swapaxes(xi.reshape(bucket, ell, m), -1, -2)
            br, bi = ops.encode_worker(cr, ci, gr, gi)   # fused stage 1+2+3
            hr, hi = ops.decode_apply(dr, di, br, bi)    # batched MXU decode
            yr, yi = ops.recombine_planar(hr, hi, s)     # fused twiddle+DFT
            return ref.unplanar(yr, yi)                  # egress recombine

        return jax.jit(fn)

    # ------------------------------------------------------------------
    def _wire_scale(self, kind: str) -> float:
        """Per-shard wire payload relative to the c2c MDS shard.

        Real-kind shards (r2c/c2r, mds-only) ship HALF the c2c payload
        (pair packing, DESIGN.md §7); non-mds strategies charge their own
        ``payload_scale`` (1/q for comm_efficient, 1 for partial)."""
        base = 0.5 if kind in self.REAL_KINDS else 1.0
        return base * float(getattr(self.plan, "payload_scale", 1.0))

    def _simulate_arrivals(self, n_requests: int, kind: str = "c2c"
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Per-request worker latencies + availability masks at decode time.

        One vectorized draw per bucket -- a per-request sampling loop costs
        more host time than the whole decode at service bucket sizes.
        Real-kind shards (r2c/c2r) ship HALF the c2c wire payload
        (DESIGN.md §7), so their wire-time share is charged at
        ``payload_scale=0.5``; the comm_efficient strategy's folded shards
        at 1/q.  The mds/comm_efficient mask admits the k-th-order-statistic
        responders (k = the plan's recovery threshold); the partial strategy
        returns a per-FRAGMENT mask ``(n, N, r)`` admitting fragments until
        the coverage condition (m*r finished fragments) is met.
        """
        cfg = self.cfg
        plan = self.plan
        k = int(getattr(plan, "recovery_threshold", cfg.m))
        lat = cfg.straggler.sample(
            (n_requests, cfg.n_workers), 1.0 / cfg.m, self.rng,
            payload_scale=self._wire_scale(kind))
        if int(getattr(plan, "fragments", 1)) > 1:
            # fragment f of worker w lands at lat * fractions[f]; admit
            # fragments until m*r (across all workers) have arrived
            ft = lat[:, :, None] * np.asarray(plan.fragment_fractions)
            need = int(plan.fragments_needed)
            t_done = np.sort(ft.reshape(n_requests, -1), -1)[:, need - 1]
            return lat, ft <= t_done[:, None, None]
        t_done = np.sort(lat, axis=-1)[:, k - 1]
        mask = lat <= t_done[:, None]
        return lat, mask

    def _account(self, lat: np.ndarray, mask: np.ndarray) -> None:
        cfg = self.cfg
        plan = self.plan
        lat_sorted = np.sort(lat, axis=-1)
        self.stats.requests += lat.shape[0]
        if mask.ndim == 3:
            # partial strategy: coded latency = fragment-coverage time;
            # a tolerated straggler = a worker whose LAST fragment the
            # master did not wait for
            ft = lat[:, :, None] * np.asarray(plan.fragment_fractions)
            need = int(plan.fragments_needed)
            t_cov = np.sort(ft.reshape(lat.shape[0], -1), -1)[:, need - 1]
            self.stats.coded_latency += float(t_cov.sum())
            self.stats.stragglers_tolerated += int((~mask[..., -1]).sum())
        else:
            k = int(getattr(plan, "recovery_threshold", cfg.m))
            self.stats.coded_latency += float(lat_sorted[:, k - 1].sum())
            self.stats.stragglers_tolerated += int((~mask).sum())
        self.stats.uncoded_latency += float(lat_sorted[:, -1].sum())

    # -- fault-tolerant bucket path (opt-in; DESIGN.md §12) --------------
    def _fault_arrivals(self, n_live: int, kind: str):
        """The deadline/retry state machine for one robust bucket.

        Ground truth is still a per-(request, worker) completion-time draw
        (plus injected kill=inf / delay=+d), but the MASK is no longer "the
        m fastest of the draw": the master only admits workers whose time
        beats the LEARNED deadline (m-th-fastest health estimate + slack).
        Requests below the threshold go through capped retry rounds --
        late originals count, missing shards are re-dispatched to healthy
        workers with fresh draws, the window backs off geometrically --
        and requests that still miss get a typed ServiceError.

        Returns ``(masks, errors, t_comp, lat, round_faults, round_idx)``.

        Strategy-generic (DESIGN.md §13): the worker-count threshold and
        wire payload come from the configured plan (``m`` for mds,
        ``m*q`` for comm_efficient), and the partial strategy swaps the
        per-worker masks for per-FRAGMENT masks ``(n_live, N, r)`` --
        the deadline gates each fragment separately
        (:meth:`WorkerHealthTracker.fragment_mask_from_times`), ``met``
        counts fragments against the m*r coverage condition, and a
        re-dispatched shard lands all r fragments at once.
        """
        cfg = self.cfg
        n = self._n_workers()
        plan = self.plan
        need = int(getattr(plan, "recovery_threshold", cfg.m))
        nf = int(getattr(plan, "fragments", 1))
        frac = (np.asarray(plan.fragment_fractions, np.float64)
                if nf > 1 else None)
        # fragments needed for decode; in worker units it is `need`
        need_units = int(getattr(plan, "fragments_needed", need))
        if self.health.n_workers < n:
            self.health.grow(n)       # elastic capacity growth keeps history
        round_idx = self._round
        self._round += 1
        rf = (self.injector.faults_for(round_idx)
              if self.injector is not None else RoundFaults())
        alive = (self.pool.mask() if self.pool is not None
                 else np.ones(n, bool))
        scale = self._wire_scale(kind)
        lat = cfg.straggler.sample((n_live, n), 1.0 / cfg.m, self.rng,
                                   payload_scale=scale)
        if self.injector is not None:
            lat = self.injector.perturb_latencies(lat, round_idx)
        lat = np.where(alive[None, :], lat, np.inf)
        errors: list = [None] * n_live
        mshape = (n_live, n) if nf == 1 else (n_live, n, nf)
        masks = np.zeros(mshape, bool)
        t_comp = np.full(n_live, np.inf)

        def units(mk):
            """Decodable-progress count for ONE request's mask."""
            return int(mk.sum())

        def admit(times, window):
            """Per-worker (or per-fragment) arrivals inside ``window``."""
            if nf > 1:
                return (self.health.fragment_mask_from_times(
                    times, window, frac) & alive[..., :, None])
            return self.health.mask_from_times(times, window) & alive

        def coverage_time(lat_rows):
            """Per-request completion: need-th worker (need_units-th
            fragment for partial) order statistic."""
            if nf > 1:
                ft = np.sort((lat_rows[:, :, None] * frac)
                             .reshape(lat_rows.shape[0], -1), axis=1)
                return ft[:, need_units - 1]
            return np.sort(lat_rows, axis=1)[:, need - 1]

        if int(alive.sum()) < need:
            err = ServiceError(
                "insufficient_workers",
                f"{int(alive.sum())} live workers < threshold {need}")
            errors = [err] * n_live
            self.stats.degraded += n_live
            masks[:] = True   # padding decode stays well-posed; never surfaced
            return masks, errors, t_comp, lat, rf, round_idx

        if self.health.rounds == 0:
            # cold start: no learned estimates yet -- bootstrap from this
            # round's own threshold-order statistics
            kth = coverage_time(lat)
            kth = kth[np.isfinite(kth)]
            deadline = (float(kth.max()) * (1.0 + cfg.deadline_slack)
                        if kth.size else float("inf"))
        else:
            deadline = self.health.deadline(need, alive=alive)
        masks = admit(lat, deadline)
        met = masks.reshape(n_live, -1).sum(axis=1) >= need_units
        t_comp[met] = coverage_time(lat)[met]

        killed = np.zeros(n, bool)
        for w in rf.killed:
            if w < n:
                killed[w] = True
        healthy = alive & ~killed & ~self.health.byzantine[:n]
        window = deadline
        for _ in range(cfg.max_retries):
            if met.all():
                break
            prev = window
            window *= cfg.retry_backoff
            self.stats.retries += 1
            for i in np.flatnonzero(~met):
                # late originals land inside the extended window (for
                # partial: the late worker's finished fragment PREFIX)
                masks[i] |= admit(lat[i], window)
                done = masks[i] if nf == 1 else masks[i].all(axis=-1)
                missing = np.flatnonzero(alive & ~done)
                if missing.size and healthy.any():
                    # re-dispatch the missing shard rows to healthy workers:
                    # fresh work issued when the previous window closed,
                    # racing the extension (a shard row is data, not a
                    # worker identity -- any healthy thread recomputes it)
                    redraw = cfg.straggler.sample(
                        missing.size, 1.0 / cfg.m, self.rng,
                        payload_scale=scale)
                    masks[i][missing[prev + redraw <= window]] = True
                    self.stats.redispatched_shards += int(missing.size)
                if units(masks[i]) >= need_units:
                    met[i] = True
                    t_comp[i] = window   # conservative: met at window close
        for i in np.flatnonzero(~met):
            if not healthy.any():
                reason = "insufficient_workers"
                detail = "no healthy workers to re-dispatch to"
            else:
                unit = "fragments" if nf > 1 else "shards"
                detail = (f"{units(masks[i])}/{need_units} {unit} after "
                          f"{cfg.max_retries} retries")
                reason = "retries_exhausted"
            errors[i] = ServiceError(reason, detail)
            self.stats.degraded += 1
            masks[i] = True
        # feed the tracker: per-worker mean measured time this round
        col = np.where(np.isfinite(lat), lat, np.nan)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", category=RuntimeWarning)
            col_mean = np.nanmean(col, axis=0)
        self.health.observe_round(np.where(np.isnan(col_mean), np.inf,
                                           col_mean))
        return masks, errors, t_comp, lat, rf, round_idx

    def _account_robust(self, t_comp: np.ndarray, lat: np.ndarray,
                        masks: np.ndarray, errors: list) -> None:
        self.stats.requests += int(t_comp.shape[0])
        finite = lat[np.isfinite(lat)]
        cap = float(finite.max()) if finite.size else 0.0
        coded = np.where(np.isfinite(t_comp), t_comp, cap)
        self.stats.coded_latency += float(coded.sum())
        unc = np.where(np.isfinite(lat), lat, cap).max(axis=1)
        self.stats.uncoded_latency += float(unc.sum())
        ok = np.array([e is None for e in errors], bool)
        if ok.any():
            self.stats.stragglers_tolerated += int((~masks[ok]).sum())

    def _robust_launch(self, s, bucket: int, kind: str, xb: np.ndarray,
                       n_live: int) -> "_Launched":
        """Launch one staged bucket through the fault-tolerant path."""
        cfg = self.cfg
        n = self._n_workers()
        if cfg.measured:
            if kind != "c2c":
                raise ValueError(
                    "measured=True serves c2c buckets only "
                    "(MeasuredWorkerRuntime is a 1-D c2c runtime)")
            return self._measured_launch(s, bucket, xb, n_live)
        masks, errors, t_comp, lat, rf, round_idx = \
            self._fault_arrivals(n_live, kind)
        self._account_robust(t_comp, lat, masks, errors)
        full = np.ones((bucket,) + masks.shape[1:], bool)
        full[:n_live] = masks
        errors = errors + [None] * (bucket - n_live)
        live_corrupt = [w for w in sorted(rf.corrupt) if w < n]
        if cfg.verify == "off" and not live_corrupt:
            # fault-free data path: reuse the jitted bucket executor with
            # the deadline-derived masks
            out = self._runner_for(s, bucket, kind)(
                *self._bucket_args(s, kind, xb, full))
            return _Launched(out, errors)
        # instrumented path: corruption must land in real worker rows and
        # verification must see them, so execute host-visibly
        rows, errors = self._verify_execute(s, kind, xb, full, errors,
                                            round_idx, rf, n_live)
        return _Launched(rows, errors)

    def _verify_execute(self, s, kind: str, xb: np.ndarray,
                        masks: np.ndarray, errors: list, round_idx: int,
                        rf: RoundFaults, n_live: int) -> tuple[np.ndarray, list]:
        """Instrumented bucket execution: real worker rows, injected
        corruption, per-request Byzantine verification + decode."""
        plan = self._plan_for(s, kind)
        b = np.asarray(
            plan.worker_compute(plan.encode(jnp.asarray(xb))), np.complex128)
        live_corrupt = [w for w in sorted(rf.corrupt) if w < plan.n_workers]
        if live_corrupt and self.injector is not None:
            b = self.injector.corrupt_array(b, live_corrupt, round_idx,
                                            worker_axis=1)
        return self._decode_collected(s, kind, b, masks, errors, n_live)

    def _decode_collected(self, s, kind: str, b: np.ndarray,
                          masks: np.ndarray, errors: list, n_live: int
                          ) -> tuple[np.ndarray, list]:
        """Per-request decode of collected worker rows ``(bucket, N, ...)``,
        with the configured Byzantine check on surplus responses.

        ``verify="detect"``: k > m responses run the generalized-RS
        syndrome check (catches up to k - m liars); a hit fails the request
        (detection cannot say WHO lied with that budget).
        ``verify="correct"``: Prony error location corrects up to
        floor((k - m)/2) corrupt rows, flags the offenders into the health
        tracker (excluded from future re-dispatch), and decodes from clean
        rows -- bit-identical to the same-subset clean decode.
        """
        cfg = self.cfg
        plan = self._plan_for(s, kind)
        m, n = plan.m, plan.n_workers
        bucket = b.shape[0]
        nodes_all = np.asarray(mds.rs_nodes(n, jnp.complex128))
        rows: list = [None] * bucket
        for i in range(min(bucket, n_live)):   # padding rows never decode
            if errors[i] is not None:
                continue
            recv = np.flatnonzero(masks[i])
            k = int(recv.size)
            if cfg.verify != "off" and k > m:
                if cfg.verify == "detect":
                    flat = b[i][recv].reshape(k, -1)
                    if detect_errors(nodes_all[recv], flat, m):
                        self.stats.detected += 1
                        self.stats.degraded += 1
                        errors[i] = ServiceError(
                            "corrupt_uncorrectable",
                            f"syndrome check failed over {k} responses "
                            f'(verify="detect" cannot correct)')
                        continue
                    y = plan.decode(jnp.asarray(b[i]).astype(plan.dtype),
                                    subset=jnp.asarray(recv[:m]))
                else:
                    res = robust_decode(plan, b[i], recv)
                    if not res.ok:
                        self.stats.detected += 1
                        self.stats.degraded += 1
                        errors[i] = ServiceError(
                            "corrupt_uncorrectable",
                            f"more than {(k - m) // 2} corrupt rows among "
                            f"{k} responses")
                        continue
                    if res.n_errors_corrected:
                        self.stats.detected += res.n_errors_corrected
                        self.stats.corrected += res.n_errors_corrected
                        for w in np.asarray(
                                res.error_worker_indices).tolist():
                            self.health.flag_byzantine(int(w))
                    y = res.output
            elif int(getattr(plan, "fragments", 1)) > 1:
                y = plan.decode(jnp.asarray(b[i]).astype(plan.dtype),
                                fragment_mask=jnp.asarray(masks[i]))
            else:
                y = plan.decode(jnp.asarray(b[i]).astype(plan.dtype),
                                mask=jnp.asarray(masks[i]))
            rows[i] = np.asarray(y)
        zero = self._zero_row(s, kind)
        out = np.stack([zero if r is None else r for r in rows])
        return out, errors

    def _zero_row(self, s, kind: str) -> np.ndarray:
        """All-zeros result row (the slot value under a per-row error)."""
        plan = self._plan_for(s, kind)
        cdt = np.dtype(self.cfg.dtype)
        rdt = np.real(np.zeros(1, cdt)).dtype
        dt = rdt if kind in ("c2r", "irfftn") else cdt
        return np.zeros(tuple(plan.output_shape), dt)

    def _measured_for(self, s: int) -> MeasuredWorkerRuntime:
        cfg = self.cfg
        key = (s, self._n_workers())
        if key not in self._measured:
            self._measured[key] = MeasuredWorkerRuntime(
                self._plan_for(s, "c2c"), self.health,
                injector=self.injector, max_retries=cfg.max_retries,
                retry_backoff=cfg.retry_backoff,
                require_all=cfg.require_all,
                threshold_extra=(0 if cfg.verify == "off"
                                 else cfg.verify_quorum))
        return self._measured[key]

    def _measured_launch(self, s: int, bucket: int, xb: np.ndarray,
                         n_live: int) -> "_Launched":
        """Run one bucket on the thread-per-worker measured runtime."""
        cfg = self.cfg
        n = self._n_workers()
        rt = self._measured_for(s)
        round_idx = self._round
        self._round += 1
        alive = self.pool.mask() if self.pool is not None else None
        res = rt.round(np.asarray(xb, np.complex128), round_idx, alive)
        self.stats.retries += res.retries
        self.stats.redispatched_shards += res.redispatched
        self.stats.requests += n_live
        t_last = res.t_last if np.isfinite(res.t_last) else 0.0
        self.stats.uncoded_latency += t_last * n_live
        errors: list = [None] * bucket
        if not res.ok:
            err = ServiceError(res.reason, f"measured round {round_idx}")
            for i in range(n_live):
                errors[i] = err
            self.stats.degraded += n_live
            self.stats.coded_latency += t_last * n_live
            rows = np.stack([self._zero_row(s, "c2c")] * bucket)
            return _Launched(rows, errors)
        self.stats.coded_latency += float(res.t_met) * n_live
        alive_arr = np.ones(n, bool) if alive is None else alive
        self.stats.stragglers_tolerated += \
            int((alive_arr & ~res.mask).sum()) * n_live
        masks = np.ones((bucket, n), bool)
        masks[:n_live] = res.mask[None, :]
        # corruption was already injected by the worker threads inside
        # res.b, so the shared decode/verify step runs as-is
        return _Launched(*self._decode_collected(s, "c2c", res.b, masks,
                                                 errors, n_live))

    def fetch_bucket(self, out) -> tuple[np.ndarray, Optional[list]]:
        """Host rows + per-row errors for one launched bucket.

        The streaming syncer calls this instead of ``jax.device_get`` so
        the robust path's per-row :class:`ServiceError` objects never go
        through a device transfer (host rows pass straight through)."""
        if isinstance(out, _Launched):
            rows = (out.out if isinstance(out.out, np.ndarray)
                    else jax.device_get(out.out))
            return rows, out.errors
        return jax.device_get(out), None

    # ------------------------------------------------------------------
    def submit(self, x: jax.Array) -> np.ndarray:
        """One request: returns F{x}, never waiting for stragglers."""
        return self.submit_batch([x])[0]

    def submit_rfft(self, x: jax.Array) -> np.ndarray:
        """One REAL request: returns the half spectrum ``rfft(x)``
        (``s//2 + 1`` bins) from half-payload worker shards."""
        return self.submit_batch([x], kind="r2c")[0]

    def submit_irfft(self, y: jax.Array) -> np.ndarray:
        """One half-spectrum request: returns the real ``irfft(y)`` of
        length ``2*(len(y) - 1)``."""
        return self.submit_batch([y], kind="c2r")[0]

    def submit_rfftn(self, t: jax.Array) -> np.ndarray:
        """One n-D REAL request: returns ``numpy.fft.rfftn(t)`` -- the
        half spectrum over the last axis (``t.shape[:-1] + (last//2+1,)``)
        -- from half-payload worker shards (DESIGN.md §9).  The last axis
        must satisfy the real-kind ``2m | s`` constraint after
        ``plan_factors`` splits ``m`` across the axes."""
        return self.submit_batch([t], kind="rfftn")[0]

    def submit_irfftn(self, y: jax.Array) -> np.ndarray:
        """One n-D half-spectrum request: returns the real
        ``numpy.fft.irfftn(y)`` of shape
        ``y.shape[:-1] + (2*(y.shape[-1]-1),)``."""
        return self.submit_batch([y], kind="irfftn")[0]

    def submit_batch(self, xs: Sequence[jax.Array],
                     kind: Union[str, Sequence[str]] = "c2c"
                     ) -> list[np.ndarray]:
        """Serve a batch of requests, bucketed by ``(s, m, kind)``.

        Master-side encode/decode for each bucket runs as ONE jitted call
        over the stacked requests; each request still gets its own
        simulated straggler pattern, and results come back in submission
        order as host arrays.

        ``kind`` selects the transform (DESIGN.md §7/§9): ``"c2c"``
        complex forward (default), ``"r2c"`` real input -> half spectrum,
        ``"c2r"`` half spectrum -> real output, ``"rfftn"`` n-D real
        input -> last-axis half spectrum, ``"irfftn"`` its inverse --
        either ONE kind for the whole call or a PER-REQUEST sequence
        (mixed traffic buckets by (s, kind), so a client no longer splits
        its stream by kind).  Buckets are keyed by the TIME-domain extent
        ``s`` -- a scalar length for 1-D kinds (a c2r request of ``h``
        bins lands in the ``s = 2*(h-1)`` bucket) and the full shape
        tuple for n-D kinds (an irfftn request's last axis is
        ``2*(bins-1)``).

        The call is PIPELINED (DESIGN.md §8): every bucket is dispatched
        before any host sync -- the jitted calls are asynchronous, so
        bucket k+1's host-side staging overlaps bucket k's device compute
        -- then ONE device->host transfer fetches all results.
        """
        kinds = ([kind] * len(xs) if isinstance(kind, str) else list(kind))
        if len(kinds) != len(xs):
            raise ValueError(
                f"per-request kinds: got {len(kinds)} kinds "
                f"for {len(xs)} requests")
        cfg = self.cfg
        results: list[Optional[np.ndarray]] = [None] * len(xs)
        by_bucket: dict[tuple, list[int]] = {}
        for i, (x, k) in enumerate(zip(xs, kinds)):
            by_bucket.setdefault((self.bucket_key(x, k), k), []).append(i)

        # phase 1 -- dispatch: stage + launch every bucket, no host sync
        t0 = time.perf_counter()
        pending: list[tuple[list[int], jax.Array]] = []
        for (s, k), idxs in by_bucket.items():
            for start in range(0, len(idxs), cfg.max_batch):
                chunk = idxs[start:start + cfg.max_batch]
                pending.append((chunk, self._dispatch_bucket(s, chunk, xs, k)))
        self.stats.dispatch_s += time.perf_counter() - t0

        # phase 2 -- sync: ONE device->host transfer for the whole call
        # (robust _Launched buckets contribute their device/host rows;
        # numpy rows pass through device_get unchanged)
        t0 = time.perf_counter()
        fetched = jax.device_get(
            [out.out if isinstance(out, _Launched) else out
             for _, out in pending])
        self.stats.host_transfers += 1
        self.stats.sync_s += time.perf_counter() - t0
        for (chunk, out), rows in zip(pending, fetched):
            errors = out.errors if isinstance(out, _Launched) else None
            for row, i in enumerate(chunk):
                err = errors[row] if errors is not None else None
                if err is not None:
                    if cfg.on_failure == "raise":
                        raise err
                    results[i] = DegradedResult(err.reason, err.detail)
                else:
                    results[i] = rows[row]
        return results  # type: ignore[return-value]

    def warmup(self, lengths: Optional[Sequence[int]] = None,
               kinds: Sequence[str] = ("c2c",),
               buckets: Optional[Sequence[int]] = None) -> int:
        """Precompile the bucket executables so steady state never compiles.

        Keys one persistent executable per (s, kind, bucket-size) --
        default: the config length, c2c, every power-of-two bucket up to
        ``max_batch``.  ``lengths`` entries may be scalar lengths (1-D
        kinds) or shape tuples (``rfftn``/``irfftn``); each entry is
        paired only with the kinds it fits (scalars with 1-D kinds,
        tuples with n-D kinds), so one call can warm mixed traffic.
        Returns the number of executables compiled.  On the fallback
        (host-LRU) path this also primes the all-alive mask entry.

        With ``cfg.autotune`` (the default) this is also when the tiling
        search runs: per warmed (s, kind) on the kernel path the autotuner
        times the candidate four-step variants and bucket block_q tilings
        and persists the winners to the backend-keyed JSON table
        (kernels/autotune.py), so the executables compiled below already
        bake the measured plan in -- and the NEXT process skips the search
        entirely (warm table).
        """
        cfg = self.cfg
        lengths = [cfg.s] if lengths is None else list(lengths)
        if buckets is None:
            buckets, b = [], 1
            while b < cfg.max_batch:
                buckets.append(b)
                b *= 2
            buckets.append(cfg.max_batch)
        if cfg.autotune:
            kind_keys = {"c2c": "bucket", "r2c": "rbucket", "c2r": "irbucket"}
            mode = ops._mode(None)
            qmax = max(buckets)
            for s in lengths:
                for k in kinds:
                    if (isinstance(s, (tuple, list)) or k not in kind_keys
                            or not self._kernel_path(s, k)):
                        continue
                    ell = s // cfg.m if k == "c2c" else s // cfg.m // 2
                    autotune.ensure_fourstep(
                        ell, mode=mode, reps=cfg.autotune_reps)
                    autotune.ensure_bucket(
                        kind_keys[k], s, cfg.m, self._n_workers(), q=qmax,
                        mode=mode, reps=cfg.autotune_reps)
        outs = []
        for s in lengths:
            if isinstance(s, (tuple, list)):
                s = tuple(int(d) for d in s)      # hashable bucket key
            for k in kinds:
                if isinstance(s, tuple) != (k in self.ND_KINDS):
                    continue        # scalar<->1-D, tuple<->n-D only
                for b in sorted(set(buckets)):
                    xb = self._bucket_buffer(s, b, k)
                    masks = self._full_masks(s, k, b)
                    # always the FAST executors: the robust path reuses
                    # them whenever no corruption/verification is in play,
                    # so precompiling here serves both modes
                    outs.append(self._runner_for(s, b, k)(
                        *self._bucket_args(s, k, xb, masks)))
        jax.block_until_ready(outs)
        return len(outs)

    def _bucket_buffer(self, s, bucket: int, kind: str) -> np.ndarray:
        """The request staging buffer for one bucket, in the kind's ingress
        dtype: real requests stay a single real plane end-to-end.  ``s``
        is the scalar time-domain length (1-D kinds) or shape tuple (n-D
        kinds)."""
        cdt = np.dtype(self.cfg.dtype)
        rdt = np.real(np.zeros(1, cdt)).dtype
        if kind == "rfftn":
            return np.zeros((bucket,) + tuple(s), dtype=rdt)
        if kind == "irfftn":
            shape = tuple(s[:-1]) + (s[-1] // 2 + 1,)
            return np.zeros((bucket,) + shape, dtype=cdt)
        if kind == "r2c":
            return np.zeros((bucket, s), dtype=rdt)
        if kind == "c2r":
            return np.zeros((bucket, s // 2 + 1), dtype=cdt)
        # allocate in the service dtype (NOT the first request's dtype --
        # a real-valued request must not narrow the whole bucket's buffer)
        return np.zeros((bucket, s), dtype=cdt)

    def _full_masks(self, s, kind: str, bucket: int) -> np.ndarray:
        """All-responders mask block for one bucket: ``(bucket, N)``, or
        ``(bucket, N, r)`` per-fragment for partial-work strategies."""
        plan = self._plan_for(s, kind)
        nf = int(getattr(plan, "fragments", 1))
        shape = (bucket, self._n_workers()) + ((nf,) if nf > 1 else ())
        return np.ones(shape, bool)

    def _bucket_args(self, s: int, kind: str, xb: np.ndarray,
                     masks: np.ndarray) -> tuple:
        """Device arguments for one bucket invocation.

        Device-decode path: the requests and the raw boolean masks -- two
        int words of decode metadata per request cross the host boundary,
        everything else happens in-jit (DESIGN.md §8).  Fallback kernel
        path (``m > LAGRANGE_MAX_M`` or ``device_decode=False``): per-mask
        matrices from the host LRU, shared across every (s, kind) bucket.
        """
        if self._kernel_path(s, kind) and not self._device_decode():
            cache = self._decode_cache_for()
            h0, m0 = cache.hits, cache.misses
            if ops.default_interpret():
                invs, subsets = cache.compact(masks)
                dplanes = np.stack([invs.real, invs.imag]).astype(np.float32)
                args = (jnp.asarray(xb), jnp.asarray(dplanes),
                        jnp.asarray(subsets))
            else:
                dmats = cache.matrices(masks)
                dplanes = np.stack([dmats.real, dmats.imag]).astype(np.float32)
                args = (jnp.asarray(xb), jnp.asarray(dplanes))
            # deltas, not lifetime cache totals: every other ServiceStats
            # field accumulates, so a stats reset must window these too
            self.stats.decode_cache_hits += cache.hits - h0
            self.stats.decode_cache_misses += cache.misses - m0
            return args
        return (jnp.asarray(xb), jnp.asarray(masks))

    # -- staging seam (shared with serving/streaming.py, DESIGN.md §11) --
    def bucket_key(self, x, kind: str):
        """The bucket extent ``s`` one request lands in: the scalar
        TIME-domain length for 1-D kinds (a c2r request of ``h`` bins maps
        to ``s = 2*(h-1)``), the full time-domain shape tuple for the n-D
        kinds.  Validates the kind and minimal half-spectrum width."""
        if kind not in self.KINDS:
            raise ValueError(f"unknown bucket kind {kind!r}")
        n_last = int(x.shape[-1])
        if kind in ("c2r", "irfftn") and n_last < 2:
            raise ValueError(
                f"{kind} requests need >= 2 half-spectrum bins "
                f"(s = 2*(bins-1) > 0), got {n_last}")
        if kind in self.ND_KINDS:
            # n-D kinds bucket by the full TIME-domain shape tuple
            time_last = 2 * (n_last - 1) if kind == "irfftn" else n_last
            return tuple(int(d) for d in x.shape[:-1]) + (time_last,)
        return 2 * (n_last - 1) if kind == "c2r" else n_last

    def stage_bucket(self, s, kind: str, reqs: Sequence) -> tuple:
        """Host-side staging for one bucket of same-``(s, kind)`` requests.

        Everything that costs host time lives here -- the straggler draw,
        the numpy pack into the padded bucket buffer, and the host->device
        argument conversion -- so the streaming front-end can run it on a
        staging thread while the previous bucket computes (DESIGN.md §11).
        Returns ``(bucket, args)`` for :meth:`launch_bucket`.
        """
        cfg = self.cfg
        n_live = len(reqs)
        bucket = bucket_size(n_live, cfg.max_batch)
        self.stats.batches += 1

        xb = self._bucket_buffer(s, bucket, kind)
        real_in = kind in ("r2c", "rfftn")
        for row, x in enumerate(reqs):
            x = np.asarray(x)
            xb[row] = x.real if real_in and np.iscomplexobj(x) else x
        if self._robust:
            # fault path: masks are derived at LAUNCH time -- the deadline/
            # retry state machine mutates health + round state, which the
            # launch step owns (stager-thread-confined on the streaming
            # path, exactly like the non-robust service internals)
            return bucket, (xb, n_live)
        lat, mask = self._simulate_arrivals(n_live, kind)
        self._account(lat, mask)
        # padded rows: every worker "responds" so decode stays well-posed
        masks = self._full_masks(s, kind, bucket)
        masks[:n_live] = mask
        return bucket, self._bucket_args(s, kind, xb, masks)

    def launch_bucket(self, s, bucket: int, kind: str, args: tuple
                      ) -> jax.Array:
        """Launch one staged bucket; returns the UNSYNCED device result.

        The jitted call returns immediately (async dispatch), so callers
        can launch every bucket before blocking once on all of them.  On
        the fault-tolerant path the return value is a :class:`_Launched`
        (device/host rows + per-row errors); fetch it with
        :meth:`fetch_bucket` rather than ``jax.device_get``.
        """
        if self._robust:
            xb, n_live = args
            return self._robust_launch(s, bucket, kind, xb, n_live)
        return self._runner_for(s, bucket, kind)(*args)

    def _dispatch_bucket(self, s, idxs: list[int], xs,
                         kind: str = "c2c") -> jax.Array:
        """Stage + launch one bucket (the closed-loop submit_batch path)."""
        bucket, args = self.stage_bucket(s, kind, [xs[i] for i in idxs])
        return self.launch_bucket(s, bucket, kind, args)
