"""The paper's own end-to-end application: a straggler-tolerant FFT service.

Clients submit transform requests (1-D vectors, n-D tensors, or multi-input
bundles); the service executes them under a coded computation plan and
answers as soon as the fastest ``m`` of ``N`` workers respond.  The
straggler simulator assigns each worker a shifted-exponential latency per
request; the service's reported latency is the m-th order statistic --
benchmarks compare it against waiting for all N (uncoded) and against the
repetition/short-dot thresholds (paper Remark 4).

With a mesh, worker compute runs under ``DistributedCodedFFT`` (shard_map);
without one, it runs vmapped on the local device with identical semantics.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.coded_fft import CodedFFT
from repro.core.strategies import coded_fft_threshold
from repro.distributed.coded_runtime import DistributedCodedFFT
from repro.distributed.straggler import StragglerModel, empirical_completion

__all__ = ["FFTServiceConfig", "FFTService", "ServiceStats"]


@dataclasses.dataclass(frozen=True)
class FFTServiceConfig:
    s: int = 4096                 # transform length
    m: int = 4                    # storage fraction 1/m
    n_workers: int = 8
    dtype: jnp.dtype = jnp.complex64
    straggler: StragglerModel = StragglerModel(t0=1.0, mu=1.0)
    seed: int = 0
    worker_fn: Optional[object] = None   # kernel plug-in (ops.make_kernel_worker_fn)


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    coded_latency: float = 0.0     # sum of m-th order statistics
    uncoded_latency: float = 0.0   # sum of "wait for everyone" latencies
    stragglers_tolerated: int = 0

    def summary(self) -> dict:
        n = max(self.requests, 1)
        return {
            "requests": self.requests,
            "mean_coded_latency": self.coded_latency / n,
            "mean_uncoded_latency": self.uncoded_latency / n,
            "speedup": (self.uncoded_latency / self.coded_latency
                        if self.coded_latency > 0 else float("nan")),
            "stragglers_tolerated": self.stragglers_tolerated,
        }


class FFTService:
    def __init__(self, cfg: FFTServiceConfig, mesh: Optional[Mesh] = None,
                 axis: str = "workers"):
        kwargs = {}
        if cfg.worker_fn is not None:
            kwargs["worker_fn"] = cfg.worker_fn
        self.plan = CodedFFT(s=cfg.s, m=cfg.m, n_workers=cfg.n_workers,
                             dtype=cfg.dtype, **kwargs)
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.stats = ServiceStats()
        self.runtime = (DistributedCodedFFT(self.plan, mesh, axis)
                        if mesh is not None else None)
        if self.runtime is not None:
            self._run = jax.jit(self.runtime.run)
        else:
            self._run = jax.jit(
                lambda x, mask: self.plan.run(x, mask=mask))

    # ------------------------------------------------------------------
    def _simulate_arrivals(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-worker latencies and the availability mask at decode time."""
        cfg = self.cfg
        lat = cfg.straggler.sample(cfg.n_workers, 1.0 / cfg.m, self.rng)
        t_done = empirical_completion(lat, coded_fft_threshold(cfg.n_workers, cfg.m))
        mask = lat <= t_done
        return lat, mask

    def submit(self, x: jax.Array) -> jax.Array:
        """One request: returns F{x}, never waiting for stragglers."""
        lat, mask = self._simulate_arrivals()
        k = coded_fft_threshold(self.cfg.n_workers, self.cfg.m)
        self.stats.requests += 1
        self.stats.coded_latency += empirical_completion(lat, k)
        self.stats.uncoded_latency += empirical_completion(lat, self.cfg.n_workers)
        self.stats.stragglers_tolerated += int((~mask).sum())
        # straggler rows deliver garbage; decode must ignore them
        mask_j = jnp.asarray(mask)
        return self._run(x.astype(self.cfg.dtype), mask_j)

    def submit_batch(self, xs: Sequence[jax.Array]) -> list[jax.Array]:
        return [self.submit(x) for x in xs]
