"""The paper's own end-to-end application: a straggler-tolerant FFT service.

Clients submit transform requests; the service executes them under a coded
computation plan and answers as soon as the fastest ``m`` of ``N`` workers
respond.  The straggler simulator assigns each worker a shifted-exponential
latency per request; the service's reported latency is the m-th order
statistic -- benchmarks compare it against waiting for all N (uncoded) and
against the repetition/short-dot thresholds (paper Remark 4).

The scheduler is batched (DESIGN.md §5): submitted requests are bucketed by
``(s, m)``, stacked along a leading batch axis, padded to a power-of-two
bucket size, and pushed through ONE jitted encode -> worker -> decode call
per bucket with a per-request straggler mask -- master-side work (MDS
encode/decode, recombine) amortizes across the whole bucket instead of
being paid per request.  ``submit`` is the batch-of-one special case.

With a mesh, worker compute runs under ``DistributedCodedPlan`` (shard_map,
batch axis threaded through the collectives); without one, it runs vmapped
on the local device with identical semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.coded_fft import CodedFFT
from repro.core.strategies import coded_fft_threshold
from repro.distributed.coded_runtime import DistributedCodedPlan
from repro.distributed.straggler import StragglerModel, empirical_completion
from repro.serving.batching import bucket_size

__all__ = ["FFTServiceConfig", "FFTService", "ServiceStats"]


@dataclasses.dataclass(frozen=True)
class FFTServiceConfig:
    s: int = 4096                 # default transform length
    m: int = 4                    # storage fraction 1/m
    n_workers: int = 8
    dtype: jnp.dtype = jnp.complex64
    straggler: StragglerModel = StragglerModel(t0=1.0, mu=1.0)
    seed: int = 0
    worker_fn: Optional[object] = None   # kernel plug-in (ops.make_kernel_worker_fn)
    max_batch: int = 64           # scheduler bucket cap per (s, m)
    decode_method: str = "auto"   # MDS decode dispatch (DESIGN.md §4)


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0               # jitted scheduler invocations
    coded_latency: float = 0.0     # sum of m-th order statistics
    uncoded_latency: float = 0.0   # sum of "wait for everyone" latencies
    stragglers_tolerated: int = 0

    def summary(self) -> dict:
        n = max(self.requests, 1)
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_coded_latency": self.coded_latency / n,
            "mean_uncoded_latency": self.uncoded_latency / n,
            "speedup": (self.uncoded_latency / self.coded_latency
                        if self.coded_latency > 0 else float("nan")),
            "stragglers_tolerated": self.stragglers_tolerated,
        }


class FFTService:
    """Batched straggler-tolerant FFT frontend over ``CodedPlan`` execution.

    Requests of any length with ``m | s`` are accepted; each distinct
    ``(s, m)`` gets its own cached plan and jitted bucket executors.
    """

    def __init__(self, cfg: FFTServiceConfig, mesh: Optional[Mesh] = None,
                 axis: str = "workers"):
        self.cfg = cfg
        self.mesh = mesh
        self.axis = axis
        self.rng = np.random.default_rng(cfg.seed)
        self.stats = ServiceStats()
        self._plans: dict[tuple[int, int], CodedFFT] = {}
        self._runtimes: dict[tuple[int, int], DistributedCodedPlan] = {}
        self._runners: dict[tuple[int, int, int], object] = {}
        # default-config plan/runtime, kept as attributes for introspection
        # (and reused by the executor cache for default-length requests)
        self.plan = self._plan_for(cfg.s)
        self.runtime = self._runtime_for(cfg.s) if mesh is not None else None

    # -- plan / compiled-executor caches --------------------------------
    def _plan_for(self, s: int) -> CodedFFT:
        cfg = self.cfg
        key = (s, cfg.m)
        if key not in self._plans:
            kwargs = {}
            if cfg.worker_fn is not None:
                kwargs["worker_fn"] = cfg.worker_fn
            self._plans[key] = CodedFFT(
                s=s, m=cfg.m, n_workers=cfg.n_workers, dtype=cfg.dtype,
                **kwargs)
        return self._plans[key]

    def _runtime_for(self, s: int) -> DistributedCodedPlan:
        key = (s, self.cfg.m)
        if key not in self._runtimes:
            self._runtimes[key] = DistributedCodedPlan(
                self._plan_for(s), self.mesh, self.axis)
        return self._runtimes[key]

    def _runner_for(self, s: int, bucket: int):
        """One jitted batched encode->worker->decode per (s, m, bucket)."""
        key = (s, self.cfg.m, bucket)
        if key not in self._runners:
            method = self.cfg.decode_method
            if self.mesh is not None:
                runtime = self._runtime_for(s)
                fn = lambda xb, masks: runtime.run(xb, masks, method=method)
            else:
                plan = self._plan_for(s)
                fn = lambda xb, masks: plan.run(xb, mask=masks, method=method)
            self._runners[key] = jax.jit(fn)
        return self._runners[key]

    # ------------------------------------------------------------------
    def _simulate_arrivals(self, n_requests: int
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Per-request worker latencies + availability masks at decode time."""
        cfg = self.cfg
        k = coded_fft_threshold(cfg.n_workers, cfg.m)
        lat = np.stack([
            cfg.straggler.sample(cfg.n_workers, 1.0 / cfg.m, self.rng)
            for _ in range(n_requests)])
        t_done = np.sort(lat, axis=-1)[:, k - 1]
        mask = lat <= t_done[:, None]
        return lat, mask

    def _account(self, lat: np.ndarray, mask: np.ndarray) -> None:
        cfg = self.cfg
        k = coded_fft_threshold(cfg.n_workers, cfg.m)
        for row_lat, row_mask in zip(lat, mask):
            self.stats.requests += 1
            self.stats.coded_latency += empirical_completion(row_lat, k)
            self.stats.uncoded_latency += empirical_completion(
                row_lat, cfg.n_workers)
            self.stats.stragglers_tolerated += int((~row_mask).sum())

    # ------------------------------------------------------------------
    def submit(self, x: jax.Array) -> jax.Array:
        """One request: returns F{x}, never waiting for stragglers."""
        return self.submit_batch([x])[0]

    def submit_batch(self, xs: Sequence[jax.Array]) -> list[jax.Array]:
        """Serve a batch of requests, bucketed by transform length.

        Master-side encode/decode for each bucket runs as ONE jitted call
        over the stacked requests; each request still gets its own
        simulated straggler pattern, and results come back in submission
        order.
        """
        cfg = self.cfg
        results: list[Optional[jax.Array]] = [None] * len(xs)
        by_len: dict[int, list[int]] = {}
        for i, x in enumerate(xs):
            by_len.setdefault(int(x.shape[-1]), []).append(i)

        for s, idxs in by_len.items():
            for start in range(0, len(idxs), cfg.max_batch):
                chunk = idxs[start:start + cfg.max_batch]
                self._run_bucket(s, chunk, xs, results)
        return results  # type: ignore[return-value]

    def _run_bucket(self, s: int, idxs: list[int], xs, results) -> None:
        cfg = self.cfg
        n_live = len(idxs)
        bucket = bucket_size(n_live, cfg.max_batch)
        lat, mask = self._simulate_arrivals(n_live)
        self._account(lat, mask)
        self.stats.batches += 1

        # allocate in the service dtype (NOT the first request's dtype --
        # a real-valued request must not narrow the whole bucket's buffer)
        xb = np.zeros((bucket, s), dtype=np.dtype(self.cfg.dtype))
        for row, i in enumerate(idxs):
            xb[row] = np.asarray(xs[i])
        # padded rows: every worker "responds" so decode stays well-posed
        masks = np.ones((bucket, cfg.n_workers), bool)
        masks[:n_live] = mask

        out = self._runner_for(s, bucket)(
            jnp.asarray(xb, cfg.dtype), jnp.asarray(masks))
        for row, i in enumerate(idxs):
            results[i] = out[row]
