"""The paper's own end-to-end application: a straggler-tolerant FFT service.

Clients submit transform requests; the service executes them under a coded
computation plan and answers as soon as the fastest ``m`` of ``N`` workers
respond.  The straggler simulator assigns each worker a shifted-exponential
latency per request; the service's reported latency is the m-th order
statistic -- benchmarks compare it against waiting for all N (uncoded) and
against the repetition/short-dot thresholds (paper Remark 4).

The scheduler is batched (DESIGN.md §5): submitted requests are bucketed by
``(s, m, kind)`` with ``kind in {c2c, r2c, c2r}`` (forward complex, real
forward, inverse real -- DESIGN.md §7), stacked along a leading batch axis,
padded to a power-of-two bucket size, and pushed through ONE jitted encode
-> worker -> decode call per bucket with a per-request straggler mask --
master-side work (MDS encode/decode, recombine) amortizes across the whole
bucket instead of being paid per request.  ``submit`` is the batch-of-one
special case; ``submit_rfft`` / ``submit_irfft`` are the real-kind
conveniences.  Real buckets ship HALF the worker payload (pair-packed
shards) and all kinds share one decode-matrix LRU (the (N, m) generator is
length- and kind-independent).

The default bucket executor is the Pallas kernel pipeline (DESIGN.md §6):
requests are split to f32 real/imag planes ONCE at ingress, interleaved on
planes, pushed through the fused encode+worker kernel (coded shards never
round-trip HBM between encode and the worker DFT), decoded by one batched
MXU matmul against per-request scatter decode matrices from the
:class:`~repro.serving.decode_cache.DecodeMatrixCache` LRU, recombined by
the fused twiddle+DFT kernel, and recombined to complex ONCE at egress.
``use_reference=True`` is the escape hatch back to the jnp-oracle
``plan.run`` executor (as is any config the kernel path does not cover:
a mesh, an explicit ``worker_fn`` plug-in, a pinned ``decode_method``, or
a non-complex64 dtype).

With a mesh, worker compute runs under ``DistributedCodedPlan`` (shard_map,
batch axis threaded through the collectives); without one, it runs on the
local device with identical semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.coded_fft import CodedFFT
from repro.core.rfft import CodedIRFFT, CodedRFFT
from repro.core.strategies import coded_fft_threshold
from repro.distributed.coded_runtime import DistributedCodedPlan
from repro.distributed.straggler import StragglerModel
from repro.kernels import ops, ref
from repro.serving.batching import bucket_size
from repro.serving.decode_cache import DecodeMatrixCache

__all__ = ["FFTServiceConfig", "FFTService", "ServiceStats"]


@dataclasses.dataclass(frozen=True)
class FFTServiceConfig:
    s: int = 4096                 # default transform length
    m: int = 4                    # storage fraction 1/m
    n_workers: int = 8
    dtype: jnp.dtype = jnp.complex64
    straggler: StragglerModel = StragglerModel(t0=1.0, mu=1.0)
    seed: int = 0
    worker_fn: Optional[object] = None   # explicit worker plug-in (overrides
    #                                      the default kernel dispatch)
    use_reference: bool = False   # escape hatch: jnp-oracle hot path
    max_batch: int = 64           # scheduler bucket cap per (s, m)
    decode_method: str = "auto"   # MDS decode dispatch (DESIGN.md §4);
    #                               non-"auto" pins the reference executor
    decode_cache_size: int = 512  # LRU size of per-mask decode matrices
    #                               (past the C(N, k) mask-pattern count for
    #                               small fleets, so steady state is all-hit)


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0               # jitted scheduler invocations
    coded_latency: float = 0.0     # sum of m-th order statistics
    uncoded_latency: float = 0.0   # sum of "wait for everyone" latencies
    stragglers_tolerated: int = 0
    decode_cache_hits: int = 0     # decode-matrix LRU hits (kernel path)
    decode_cache_misses: int = 0   # ... and misses (host inversions paid)

    def summary(self) -> dict:
        n = max(self.requests, 1)
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_coded_latency": self.coded_latency / n,
            "mean_uncoded_latency": self.uncoded_latency / n,
            "speedup": (self.uncoded_latency / self.coded_latency
                        if self.coded_latency > 0 else float("nan")),
            "stragglers_tolerated": self.stragglers_tolerated,
            "decode_cache_hits": self.decode_cache_hits,
            "decode_cache_misses": self.decode_cache_misses,
        }


class FFTService:
    """Batched straggler-tolerant FFT frontend over ``CodedPlan`` execution.

    Requests of any length with ``m | s`` are accepted; each distinct
    ``(s, m)`` gets its own cached plan, decode-matrix LRU, and jitted
    bucket executors.
    """

    KINDS = ("c2c", "r2c", "c2r")

    def __init__(self, cfg: FFTServiceConfig, mesh: Optional[Mesh] = None,
                 axis: str = "workers"):
        self.cfg = cfg
        self.mesh = mesh
        self.axis = axis
        self.rng = np.random.default_rng(cfg.seed)
        self.stats = ServiceStats()
        self._plans: dict[tuple[int, int, str], object] = {}
        self._runtimes: dict[tuple[int, int, str], DistributedCodedPlan] = {}
        self._runners: dict[tuple, object] = {}
        # ONE decode-matrix LRU for the whole service: the (N, m) generator
        # -- hence every per-mask decode matrix -- is independent of both
        # the transform length s and the bucket kind, so c2c/r2c/c2r
        # buckets at every length share hits (DESIGN.md §7)
        self._decode_cache: Optional[DecodeMatrixCache] = None
        # default-config plan/runtime, kept as attributes for introspection
        # (and reused by the executor cache for default-length requests)
        self.plan = self._plan_for(cfg.s)
        self.runtime = self._runtime_for(cfg.s) if mesh is not None else None

    # -- plan / compiled-executor caches --------------------------------
    def _plan_for(self, s: int, kind: str = "c2c"):
        """The plan serving ``(s, m, kind)`` buckets (kind per DESIGN.md §7:
        ``c2c`` forward complex, ``r2c`` real forward, ``c2r`` inverse
        real).  ``s`` is always the TIME-domain length."""
        if kind not in self.KINDS:
            raise ValueError(f"unknown bucket kind {kind!r}")
        cfg = self.cfg
        key = (s, cfg.m, kind)
        if key not in self._plans:
            if cfg.worker_fn is not None and kind != "c2c":
                # the plug-in contract is the c2c worker (fft along the
                # last axis); silently serving real-kind traffic without
                # it would un-instrument fault-injection setups
                raise ValueError(
                    f"worker_fn plug-ins only apply to c2c buckets; "
                    f"got a {kind!r} request on a worker_fn service")
            backend = "reference" if cfg.use_reference else "kernel"
            common = dict(s=s, m=cfg.m, n_workers=cfg.n_workers,
                          dtype=cfg.dtype, backend=backend)
            if kind == "r2c":
                self._plans[key] = CodedRFFT(**common)
            elif kind == "c2r":
                self._plans[key] = CodedIRFFT(**common)
            else:
                kwargs = {}
                if cfg.worker_fn is not None:
                    kwargs["worker_fn"] = cfg.worker_fn
                self._plans[key] = CodedFFT(**common, **kwargs)
        return self._plans[key]

    def _runtime_for(self, s: int, kind: str = "c2c") -> DistributedCodedPlan:
        key = (s, self.cfg.m, kind)
        if key not in self._runtimes:
            self._runtimes[key] = DistributedCodedPlan(
                self._plan_for(s, kind), self.mesh, self.axis)
        return self._runtimes[key]

    def _decode_cache_for(self) -> DecodeMatrixCache:
        if self._decode_cache is None:
            self._decode_cache = DecodeMatrixCache(
                np.asarray(self._plan_for(self.cfg.s).generator),
                maxsize=self.cfg.decode_cache_size)
        return self._decode_cache

    def _kernel_path(self, s: int, kind: str = "c2c") -> bool:
        """Does this bucket run the fused planar kernel executor?

        The kernel path owns the default local config; anything it does not
        cover -- a mesh (the distributed runtime executes instead), an
        explicit ``worker_fn`` plug-in, a pinned ``decode_method``, a
        reference request, or a non-c64 dtype -- falls back to ``plan.run``.
        """
        cfg = self.cfg
        return (self.mesh is None
                and not cfg.use_reference
                and cfg.worker_fn is None
                and cfg.decode_method == "auto"
                and self._plan_for(s, kind).resolved_backend == "kernel")

    def _runner_for(self, s: int, bucket: int, kind: str = "c2c"):
        """One jitted batched encode->worker->decode per (s, m, kind,
        bucket)."""
        kernel = self._kernel_path(s, kind)
        key = (s, self.cfg.m, kind, bucket, kernel)
        if key not in self._runners:
            if kernel:
                self._runners[key] = self._make_kernel_runner(s, bucket, kind)
            else:
                method = self.cfg.decode_method
                if self.mesh is not None:
                    runtime = self._runtime_for(s, kind)
                    fn = lambda xb, masks: runtime.run(xb, masks, method=method)
                else:
                    plan = self._plan_for(s, kind)
                    fn = lambda xb, masks: plan.run(xb, mask=masks, method=method)
                self._runners[key] = jax.jit(fn)
        return self._runners[key]

    def _make_kernel_runner(self, s: int, bucket: int, kind: str = "c2c"):
        """The fused planar bucket executor (DESIGN.md §6/§7).

        One planar split at ingress, planes threaded end-to-end, one
        complex recombine at egress.  Straggler handling lives entirely in
        the per-request decode matrices (zero columns for non-responders),
        so the jitted function takes no mask.  Bucket shapes that fit the
        VMEM working set run the whole pipeline as ONE Pallas launch
        (``ops.coded_bucket`` / ``ops.coded_rbucket``); larger shapes fall
        back to the stage kernels (fused encode+worker -> decode matmul ->
        recombine).

        ``r2c`` buckets never split at ingress at all -- the real request
        IS its plane -- and ship half-length packed shards; ``c2r`` buckets
        run the adjoint message stage and return a single real plane.
        """
        plan = self._plan_for(s, kind)
        m = plan.m
        gr, gi = ref.planar(plan.generator)
        n2 = s // m // 2  # packed shard length of the real kinds

        if kind == "r2c":
            if ops.default_interpret():
                def fn(xb, dplanes, subsets):
                    yr, yi = ops.coded_rbucket_direct(
                        xb, dplanes[0], dplanes[1], subsets, gr, gi, s)
                    return ref.unplanar(yr, yi)

                return jax.jit(fn)

            whole = ops.coded_rbucket_fusable(s, m, plan.n_workers)

            def fn(xb, dplanes):
                dr, di = dplanes[0], dplanes[1]
                if whole:
                    yr, yi = ops.coded_rbucket(xb, dr, di, gr, gi, s)
                    return ref.unplanar(yr, yi)
                zr, zi = ops.pack_real_planes(xb, m)     # relabel ingress
                br, bi = ops.encode_worker(zr, zi, gr, gi)
                hr, hi = ops.decode_apply(dr, di, br, bi)
                yr, yi = ops.rfft_postdecode_planar(hr, hi, s)
                return ref.unplanar(yr, yi)

            return jax.jit(fn)

        if kind == "c2r":
            if ops.default_interpret():
                def fn(yb, dplanes, subsets):
                    yr, yi = ref.planar(yb)              # ingress split
                    return ops.coded_irbucket_direct(
                        yr, yi, dplanes[0], dplanes[1], subsets, gr, gi, s)

                return jax.jit(fn)

            def fn(yb, dplanes):
                dr, di = dplanes[0], dplanes[1]
                yr, yi = ref.planar(yb)
                zr, zi = ops.irfft_message_planar(yr, yi, s, m)
                # ifft(G @ z) via the conj trick on planes:
                # conj(fft(conj(G) @ conj(z))) / n2 through the same fused
                # encode+worker kernel
                br, bi = ops.encode_worker(zr, -zi, gr, -gi)
                br, bi = br / n2, -bi / n2
                hr, hi = ops.decode_apply(dr, di, br, bi)
                return ops.irfft_unpack_planar(hr, hi)   # real egress

            return jax.jit(fn)

        ell = plan.shard_len
        if ops.default_interpret():
            # off-TPU: the direct executor (platform-FFT worker stage,
            # gathered compact decode -- DESIGN.md §6)
            def fn(xb: jax.Array, dplanes: jax.Array,
                   subsets: jax.Array) -> jax.Array:
                # dplanes: (2, bucket, m, m) stacked real/imag inverse
                # planes -- ONE transfer per bucket, split for free in-jit
                xr, xi = ref.planar(xb)                  # ingress split
                yr, yi = ops.coded_bucket_direct(
                    xr, xi, dplanes[0], dplanes[1], subsets, gr, gi, s)
                return ref.unplanar(yr, yi)              # egress recombine

            return jax.jit(fn)

        whole = ops.coded_bucket_fusable(s, m, plan.n_workers)

        def fn(xb: jax.Array, dplanes: jax.Array) -> jax.Array:
            # dplanes: (2, bucket, m, N) stacked real/imag scatter decode
            # planes -- ONE host->device transfer, split for free in-jit
            dr, di = dplanes[0], dplanes[1]
            xr, xi = ref.planar(xb)                      # ingress split
            if whole:
                yr, yi = ops.coded_bucket(xr, xi, dr, di, gr, gi, s)
                return ref.unplanar(yr, yi)              # egress recombine
            # interleave on planes: c_i[j] = x[i + j*m]
            cr = jnp.swapaxes(xr.reshape(bucket, ell, m), -1, -2)
            ci = jnp.swapaxes(xi.reshape(bucket, ell, m), -1, -2)
            br, bi = ops.encode_worker(cr, ci, gr, gi)   # fused stage 1+2+3
            hr, hi = ops.decode_apply(dr, di, br, bi)    # batched MXU decode
            yr, yi = ops.recombine_planar(hr, hi, s)     # fused twiddle+DFT
            return ref.unplanar(yr, yi)                  # egress recombine

        return jax.jit(fn)

    # ------------------------------------------------------------------
    def _simulate_arrivals(self, n_requests: int
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Per-request worker latencies + availability masks at decode time.

        One vectorized draw per bucket -- a per-request sampling loop costs
        more host time than the whole decode at service bucket sizes.
        """
        cfg = self.cfg
        k = coded_fft_threshold(cfg.n_workers, cfg.m)
        lat = cfg.straggler.sample(
            (n_requests, cfg.n_workers), 1.0 / cfg.m, self.rng)
        t_done = np.sort(lat, axis=-1)[:, k - 1]
        mask = lat <= t_done[:, None]
        return lat, mask

    def _account(self, lat: np.ndarray, mask: np.ndarray) -> None:
        cfg = self.cfg
        k = coded_fft_threshold(cfg.n_workers, cfg.m)
        lat_sorted = np.sort(lat, axis=-1)
        self.stats.requests += lat.shape[0]
        self.stats.coded_latency += float(lat_sorted[:, k - 1].sum())
        self.stats.uncoded_latency += float(lat_sorted[:, -1].sum())
        self.stats.stragglers_tolerated += int((~mask).sum())

    # ------------------------------------------------------------------
    def submit(self, x: jax.Array) -> np.ndarray:
        """One request: returns F{x}, never waiting for stragglers."""
        return self.submit_batch([x])[0]

    def submit_rfft(self, x: jax.Array) -> np.ndarray:
        """One REAL request: returns the half spectrum ``rfft(x)``
        (``s//2 + 1`` bins) from half-payload worker shards."""
        return self.submit_batch([x], kind="r2c")[0]

    def submit_irfft(self, y: jax.Array) -> np.ndarray:
        """One half-spectrum request: returns the real ``irfft(y)`` of
        length ``2*(len(y) - 1)``."""
        return self.submit_batch([y], kind="c2r")[0]

    def submit_batch(self, xs: Sequence[jax.Array],
                     kind: str = "c2c") -> list[np.ndarray]:
        """Serve a batch of requests, bucketed by transform length.

        Master-side encode/decode for each bucket runs as ONE jitted call
        over the stacked requests; each request still gets its own
        simulated straggler pattern, and results come back in submission
        order as host arrays (one device->host transfer per bucket).

        ``kind`` selects the transform (DESIGN.md §7): ``"c2c"`` complex
        forward (default), ``"r2c"`` real input -> half spectrum,
        ``"c2r"`` half spectrum -> real output.  Buckets are keyed by the
        TIME-domain length ``s`` (a c2r request of ``h`` bins lands in the
        ``s = 2*(h-1)`` bucket).
        """
        if kind not in self.KINDS:
            raise ValueError(f"unknown bucket kind {kind!r}")
        cfg = self.cfg
        results: list[Optional[np.ndarray]] = [None] * len(xs)
        by_len: dict[int, list[int]] = {}
        for i, x in enumerate(xs):
            n_last = int(x.shape[-1])
            if kind == "c2r" and n_last < 2:
                raise ValueError(
                    f"c2r requests need >= 2 half-spectrum bins "
                    f"(s = 2*(bins-1) > 0), got {n_last}")
            s = 2 * (n_last - 1) if kind == "c2r" else n_last
            by_len.setdefault(s, []).append(i)

        for s, idxs in by_len.items():
            for start in range(0, len(idxs), cfg.max_batch):
                chunk = idxs[start:start + cfg.max_batch]
                self._run_bucket(s, chunk, xs, results, kind)
        return results  # type: ignore[return-value]

    def _bucket_buffer(self, s: int, bucket: int, kind: str) -> np.ndarray:
        """The request staging buffer for one bucket, in the kind's ingress
        dtype: real requests stay a single f32 plane end-to-end."""
        cdt = np.dtype(self.cfg.dtype)
        if kind == "r2c":
            return np.zeros((bucket, s), dtype=np.real(np.zeros(1, cdt)).dtype)
        if kind == "c2r":
            return np.zeros((bucket, s // 2 + 1), dtype=cdt)
        # allocate in the service dtype (NOT the first request's dtype --
        # a real-valued request must not narrow the whole bucket's buffer)
        return np.zeros((bucket, s), dtype=cdt)

    def _run_bucket(self, s: int, idxs: list[int], xs, results,
                    kind: str = "c2c") -> None:
        cfg = self.cfg
        n_live = len(idxs)
        bucket = bucket_size(n_live, cfg.max_batch)
        lat, mask = self._simulate_arrivals(n_live)
        self._account(lat, mask)
        self.stats.batches += 1

        xb = self._bucket_buffer(s, bucket, kind)
        for row, i in enumerate(idxs):
            x = np.asarray(xs[i])
            xb[row] = x.real if kind == "r2c" and np.iscomplexobj(x) else x
        # padded rows: every worker "responds" so decode stays well-posed
        masks = np.ones((bucket, cfg.n_workers), bool)
        masks[:n_live] = mask

        if self._kernel_path(s, kind):
            # per-request decode matrices from the LRU (host-side: the
            # masks are host data already, and repeats hit the cache) --
            # shared across every (s, kind) bucket, the generator only
            # depends on (N, m)
            cache = self._decode_cache_for()
            h0, m0 = cache.hits, cache.misses
            if ops.default_interpret():
                invs, subsets = cache.compact(masks)
                dplanes = np.stack([invs.real, invs.imag]).astype(np.float32)
                args = (jnp.asarray(xb), jnp.asarray(dplanes),
                        jnp.asarray(subsets))
            else:
                dmats = cache.matrices(masks)
                dplanes = np.stack([dmats.real, dmats.imag]).astype(np.float32)
                args = (jnp.asarray(xb), jnp.asarray(dplanes))
            # deltas, not lifetime cache totals: every other ServiceStats
            # field accumulates, so a stats reset must window these too
            self.stats.decode_cache_hits += cache.hits - h0
            self.stats.decode_cache_misses += cache.misses - m0
            out = self._runner_for(s, bucket, kind)(*args)
        else:
            out = self._runner_for(s, bucket, kind)(
                jnp.asarray(xb), jnp.asarray(masks))
        # ONE device->host transfer per bucket: per-request eager jax slices
        # would pay a python lax.slice dispatch per request instead, which
        # dominates the bucket at CPU latencies.  Results are host arrays
        # (views into the bucket transfer); they interop with jnp directly.
        out_rows = np.asarray(out)
        for row, i in enumerate(idxs):
            results[i] = out_rows[row]
