"""Shared request-batching helpers for the serving layer.

Both schedulers (the LM generation engine and the coded-FFT service) pad
variable request counts into fixed power-of-two buckets so the jitted
compute functions never retrace on partial batches; finished/padded rows
are masked rather than blocking the batch.

:class:`LatencyHistogram` is the per-request latency aggregate the
streaming front-end (``serving/streaming.py``) records into
``ServiceStats``: log-spaced bins so p50/p99 queries stay O(bins) without
keeping per-request samples alive.
"""

from __future__ import annotations

import math

__all__ = ["LatencyHistogram", "bucket_size", "pad_requests"]


class LatencyHistogram:
    """Log-spaced latency histogram with O(1) record and O(bins) quantiles.

    Bins cover ``LO``..``HI`` seconds at ``PER_DECADE`` bins per decade
    (~15% bin width -- one bin edge per 10^(1/16)x); out-of-range samples
    clamp to the edge bins.  Percentiles return the geometric midpoint of
    the winning bin, which is plenty for SLO reporting (p50/p99 good to a
    bin width) without the memory of a per-request sample list.  The TOP
    bin is the exception: samples past ``HI`` clamp into it, so its
    midpoint would silently underreport an outlier (a 2000 s stall as
    ~760 s); a percentile landing there reports the tracked ``max``
    instead.
    """

    LO = 1e-6          # 1 us
    HI = 1e3           # 1000 s
    PER_DECADE = 16

    def __init__(self):
        decades = int(round(math.log10(self.HI / self.LO)))
        self.counts = [0] * (decades * self.PER_DECADE + 1)
        self.n = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        s = max(float(seconds), 0.0)
        if s > 0.0:
            b = int((math.log10(s) - math.log10(self.LO)) * self.PER_DECADE)
            b = min(max(b, 0), len(self.counts) - 1)
        else:
            b = 0
        self.counts[b] += 1
        self.n += 1
        self.total += s
        self.max = max(self.max, s)

    def percentile(self, q: float) -> float:
        """The ``q``-th percentile (0..100) in seconds (NaN when empty)."""
        if self.n == 0:
            return float("nan")
        rank = max(1, math.ceil(q / 100.0 * self.n))
        seen = 0
        for b, cnt in enumerate(self.counts):
            seen += cnt
            if seen >= rank:
                if b == len(self.counts) - 1:
                    # clamp bin: anything >= HI lands here, so the bin
                    # midpoint is a lie -- report the true maximum
                    return self.max
                lo = self.LO * 10 ** (b / self.PER_DECADE)
                return lo * 10 ** (0.5 / self.PER_DECADE)
        return self.max

    def summary(self) -> dict:
        return {
            "count": self.n,
            "mean_s": self.total / self.n if self.n else float("nan"),
            "p50_s": self.percentile(50.0),
            "p99_s": self.percentile(99.0),
            "max_s": self.max,
        }


def bucket_size(n: int, cap: int) -> int:
    """Smallest power-of-two >= ``n``, clamped to ``cap``.

    Keeps the set of compiled batch shapes to O(log cap) per request shape.
    """
    if n <= 0:
        raise ValueError("need at least one request")
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


def pad_requests(requests: list, bucket: int, filler):
    """Pad ``requests`` to ``bucket`` entries with ``filler()`` copies.

    Returns ``(padded_list, n_live)``.  Raises if the bucket is too small.
    """
    n_live = len(requests)
    if n_live > bucket:
        raise ValueError(f"{n_live} requests exceed bucket size {bucket}")
    return list(requests) + [filler() for _ in range(bucket - n_live)], n_live
