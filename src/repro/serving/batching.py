"""Shared request-batching helpers for the serving layer.

Both schedulers (the LM generation engine and the coded-FFT service) pad
variable request counts into fixed power-of-two buckets so the jitted
compute functions never retrace on partial batches; finished/padded rows
are masked rather than blocking the batch.
"""

from __future__ import annotations

__all__ = ["bucket_size", "pad_requests"]


def bucket_size(n: int, cap: int) -> int:
    """Smallest power-of-two >= ``n``, clamped to ``cap``.

    Keeps the set of compiled batch shapes to O(log cap) per request shape.
    """
    if n <= 0:
        raise ValueError("need at least one request")
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


def pad_requests(requests: list, bucket: int, filler):
    """Pad ``requests`` to ``bucket`` entries with ``filler()`` copies.

    Returns ``(padded_list, n_live)``.  Raises if the bucket is too small.
    """
    n_live = len(requests)
    if n_live > bucket:
        raise ValueError(f"{n_live} requests exceed bucket size {bucket}")
    return list(requests) + [filler() for _ in range(bucket - n_live)], n_live
