"""Serving steps: jitted prefill + single-token decode for every family.

``make_serve_fns`` returns ``(prefill_fn, decode_fn)`` closed over a
``BuiltModel``; the launcher jits them with explicit shardings (decode_32k /
long_500k dry-run cells lower ``decode_fn``).  Sampling here is greedy /
temperature-categorical over the last-token logits -- the heavy machinery
(sharded logits, ring caches, int8 KV) lives in the model layer.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model_factory import BuiltModel

__all__ = ["make_serve_fns", "sample_token"]


def sample_token(logits: jax.Array, key: Optional[jax.Array],
                 temperature: float = 0.0) -> jax.Array:
    """(B, 1, V) logits -> (B, 1) int32 tokens."""
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.asarray(temperature, logits.dtype)
    flat = scaled.reshape(-1, scaled.shape[-1])
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks.reshape(logits.shape[:-1]).astype(jnp.int32)


def make_serve_fns(model: BuiltModel) -> tuple[Callable, Callable]:
    def prefill_fn(params, batch: dict, cache):
        logits, cache = model.prefill(params, batch, cache)
        return logits, cache

    def decode_fn(params, cache, tokens: jax.Array, step: jax.Array):
        logits, cache = model.decode_step(params, cache, {"tokens": tokens}, step)
        return logits, cache

    return prefill_fn, decode_fn
