"""Open-loop streaming front-end for the FFT service (DESIGN.md §11).

``FFTService.submit_batch`` is closed-loop: the caller hands over a
complete request list and blocks on one device fetch, so its throughput
number says nothing about latency under CONTINUOUS arrivals.
:class:`StreamingFFTService` turns the batched scheduler into a
continuously-batching service with an SLO story:

* **Async request queue** -- :meth:`submit` is non-blocking: it enqueues
  the request and returns a ``concurrent.futures.Future`` that resolves
  to the transform (with its measured ``latency_s`` attached).
* **Deadline-aware bucket formation** -- requests accumulate per
  ``(s, m, kind)`` bucket and dispatch when the bucket FILLS
  (``max_batch``) *or* when the OLDEST member's slack runs out,
  whichever comes first.  A partial bucket never waits on arrivals that
  may not come: the batch-rps knob and the p99 knob decouple.
* **Admission control / backpressure** -- the undispatched queue is
  bounded (``max_queue``); over capacity, :meth:`submit` raises a typed
  :class:`AdmissionError` with a machine-readable ``reason`` instead of
  letting queueing delay grow without bound (reject early, don't
  collapse late).
* **Double-buffered host->device staging** -- a dedicated staging
  thread packs bucket k+1's numpy buffers and launches its (async)
  device call while the sync thread is still blocked fetching bucket k.
  The host-side interleave/pack cost that ``submit_batch`` pays
  serially inside its dispatch loop is hidden behind device compute;
  ``ServiceStats.staging_overlap_s`` measures exactly the hidden share.

The pipeline is three threads around two depth-bounded queues::

    callers --submit()--> pending per (s, kind)   [admission bound]
        | scheduler: fill-or-deadline bucket formation
        v
    stage_q  (depth scfg.stage_depth)
        | stager: straggler sim + numpy pack + H2D + async launch
        v
    sync_q   (depth 1  ==  double buffer: bucket k+1 stages/computes
        |                   while bucket k is being fetched)
        v syncer: jax.device_get -> resolve futures -> latency histogram

Every ``FFTService`` internal (plan/runner caches, the staging numpy
work, ``stats.batches`` accounting) is touched ONLY by the staging
thread, so the service object itself never needs locks.  The bucket
executors are untouched: the streaming path launches the SAME jitted
one-launch/one-transfer runners as ``submit_batch`` (the jaxpr pins
hold by construction).

``fill_only=True`` + ``pipelined=False`` reproduce the naive baseline
the open-loop benchmark races against: dispatch only full buckets, and
stage synchronously on the scheduler thread.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from queue import Queue
from typing import Optional

import jax
import numpy as np

from repro.serving.fft_service import FFTService

__all__ = ["AdmissionError", "StreamConfig", "StreamingFFTService"]


class AdmissionError(RuntimeError):
    """Typed rejection from admission control.

    ``reason`` is machine-readable: ``"queue_full"`` (the undispatched
    queue is at ``max_queue``) or ``"closed"`` (submit after close).
    """

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"request rejected: {reason}"
                         + (f" ({detail})" if detail else ""))
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    slack_s: float = 0.010      # queueing slack before a PARTIAL bucket
    #                             dispatches (per-request override via
    #                             submit(..., slack_s=...))
    max_queue: int = 1024       # admission bound on undispatched requests
    stage_depth: int = 2        # bucket plans buffered ahead of the stager
    fill_only: bool = False     # naive baseline: dispatch only on full
    #                             buckets (plus the drain flush)
    pipelined: bool = True      # False = naive baseline: stage + launch +
    #                             sync inline on the scheduler thread


@dataclasses.dataclass
class _Request:
    x: object                   # the (host) request payload
    kind: str
    arrival: float              # perf_counter at submit
    deadline: float             # arrival + slack
    future: Future


@dataclasses.dataclass
class _BucketPlan:
    s: object                   # scalar length or n-D shape tuple
    kind: str
    reqs: list
    reason: str                 # "fill" | "deadline" | "drain"


class StreamingFFTService:
    """Deadline-aware continuous batching over one :class:`FFTService`.

    The wrapped service's ``stats`` object is extended in place (queue
    peak, dispatch reasons, staging overlap, the per-request latency
    histogram), so one ``ServiceStats.summary()`` tells the whole story.

    Warm up the wrapped service (``service.warmup()``) BEFORE offering
    traffic: the streaming scheduler dispatches every power-of-two
    bucket size up to ``max_batch``, and a cold compile inside a latency
    window is exactly the stall the front-end exists to avoid.
    """

    def __init__(self, service: FFTService,
                 scfg: StreamConfig = StreamConfig()):
        self.service = service
        self.scfg = scfg
        self.stats = service.stats       # extended in place
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: dict[tuple, list[_Request]] = {}
        self._depth = 0                  # undispatched requests
        self._outstanding = 0            # submitted, not yet resolved
        self._closed = False
        self._flush = False
        self._stage_q: Queue = Queue(maxsize=max(1, scfg.stage_depth))
        self._sync_q: Queue = Queue(maxsize=1)
        self._threads = [threading.Thread(
            target=self._scheduler, name="stream-scheduler", daemon=True)]
        if scfg.pipelined:
            self._threads.append(threading.Thread(
                target=self._stager, name="stream-stager", daemon=True))
            self._threads.append(threading.Thread(
                target=self._syncer, name="stream-syncer", daemon=True))
        for t in self._threads:
            t.start()

    # -- client surface -------------------------------------------------
    def submit(self, x, kind: str = "c2c",
               slack_s: Optional[float] = None) -> Future:
        """Enqueue one request; returns a Future resolving to the result.

        Non-blocking.  Raises :class:`AdmissionError` when the service is
        over capacity (``reason="queue_full"``) or closed.  The resolved
        future carries ``latency_s`` -- arrival-to-result wall time -- as
        an attribute.
        """
        x = np.asarray(x)
        s = self.service.bucket_key(x, kind)      # validates kind/shape
        now = time.perf_counter()
        slack = self.scfg.slack_s if slack_s is None else float(slack_s)
        req = _Request(x, kind, now, now + slack, Future())
        with self._cv:
            if self._closed:
                raise AdmissionError("closed")
            if self._depth >= self.scfg.max_queue:
                self.stats.rejected += 1
                raise AdmissionError(
                    "queue_full", f"max_queue={self.scfg.max_queue}")
            self._pending.setdefault((s, kind), []).append(req)
            self._depth += 1
            self._outstanding += 1
            self.stats.queue_peak = max(self.stats.queue_peak, self._depth)
            self._cv.notify_all()
        return req.future

    @property
    def queue_depth(self) -> int:
        """Undispatched requests right now (the admission-bounded gauge)."""
        with self._lock:
            return self._depth

    def flush(self) -> None:
        """Dispatch every pending partial bucket immediately (reason
        ``"drain"``), without waiting for fills or deadlines."""
        with self._cv:
            self._flush = True
            self._cv.notify_all()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Flush, then block until every submitted request has resolved.

        Returns False if ``timeout`` elapsed first.
        """
        with self._cv:
            self._flush = True
            self._cv.notify_all()
            return self._cv.wait_for(
                lambda: self._outstanding == 0, timeout)

    def close(self) -> None:
        """Drain outstanding work and stop the pipeline threads."""
        with self._cv:
            if self._closed:
                return
            self._flush = True
            self._closed = True
            self._cv.notify_all()
        for t in self._threads:
            t.join()

    def __enter__(self) -> "StreamingFFTService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- scheduler: fill-or-deadline bucket formation -------------------
    def _scheduler(self) -> None:
        cap = self.service.cfg.max_batch
        while True:
            with self._cv:
                plan = None
                while True:
                    plan = self._pop_ready_locked(cap)
                    if plan is not None or (self._closed
                                            and not self._pending):
                        break
                    self._cv.wait(self._timeout_locked())
            if plan is None:
                break                        # closed and fully dispatched
            with self._lock:
                field = f"{plan.reason}_dispatches"
                setattr(self.stats, field,
                        getattr(self.stats, field) + 1)
            if self.scfg.pipelined:
                self._stage_q.put(plan)      # backpressure: bounded depth
            else:
                self._stage_and_sync(plan)   # naive serial baseline
        self._stage_q.put(None)              # sentinel for the stager

    def _pop_ready_locked(self, cap: int) -> Optional[_BucketPlan]:
        """The first dispatchable bucket under the fill-or-deadline rule."""
        now = time.perf_counter()
        choice = reason = None
        for key, reqs in self._pending.items():
            if len(reqs) >= cap:
                choice, reason = key, "fill"
                break
            if self._flush or self._closed:
                choice, reason = key, "drain"
                break
            if not self.scfg.fill_only and reqs[0].deadline <= now:
                choice, reason = key, "deadline"
                break
        if choice is None:
            if self._flush and not self._pending:
                self._flush = False          # drain finished; disarm
            return None
        reqs = self._pending[choice]
        take, rest = reqs[:cap], reqs[cap:]
        if rest:
            self._pending[choice] = rest
        else:
            del self._pending[choice]
        self._depth -= len(take)
        return _BucketPlan(choice[0], choice[1], take, reason)

    def _timeout_locked(self) -> Optional[float]:
        """Sleep until the earliest slack expiry (None = wait for a fill
        notification -- the fill_only baseline never sets an alarm)."""
        if self.scfg.fill_only or not self._pending:
            return None
        expiry = min(reqs[0].deadline for reqs in self._pending.values())
        return max(expiry - time.perf_counter(), 0.0)

    # -- stager: numpy pack + H2D + async launch ------------------------
    def _stager(self) -> None:
        while True:
            plan = self._stage_q.get()
            if plan is None:
                break
            # overlapped iff a downstream bucket is still in flight when
            # this one starts staging (the double-buffer win, measured)
            overlapped = self._sync_q.unfinished_tasks > 0
            t0 = time.perf_counter()
            try:
                out = self._stage_and_launch(plan)
            except Exception as e:                # noqa: BLE001
                self._resolve(plan, error=e)
                continue
            dt = time.perf_counter() - t0
            with self._lock:
                self.stats.dispatch_s += dt
                if overlapped:
                    self.stats.staging_overlap_s += dt
            self._sync_q.put((plan, out))
        self._sync_q.put(None)                    # sentinel for the syncer

    def _stage_and_launch(self, plan: _BucketPlan):
        svc = self.service
        bucket, args = svc.stage_bucket(
            plan.s, plan.kind, [r.x for r in plan.reqs])
        return svc.launch_bucket(plan.s, bucket, plan.kind, args)

    # -- syncer: one device->host fetch per bucket ----------------------
    def _syncer(self) -> None:
        while True:
            item = self._sync_q.get()
            if item is None:
                self._sync_q.task_done()
                break
            plan, out = item
            t0 = time.perf_counter()
            try:
                rows = jax.device_get(out)
            except Exception as e:                # noqa: BLE001
                self._sync_q.task_done()
                self._resolve(plan, error=e)
                continue
            dt = time.perf_counter() - t0
            self._sync_q.task_done()
            with self._lock:
                self.stats.sync_s += dt
                self.stats.host_transfers += 1
            self._resolve(plan, rows=rows)

    def _stage_and_sync(self, plan: _BucketPlan) -> None:
        """The unpipelined baseline: stage, launch, and block, serially
        on the scheduler thread (no staging/compute overlap)."""
        t0 = time.perf_counter()
        try:
            out = self._stage_and_launch(plan)
        except Exception as e:                    # noqa: BLE001
            self._resolve(plan, error=e)
            return
        t1 = time.perf_counter()
        rows = jax.device_get(out)
        t2 = time.perf_counter()
        with self._lock:
            self.stats.dispatch_s += t1 - t0
            self.stats.sync_s += t2 - t1
            self.stats.host_transfers += 1
        self._resolve(plan, rows=rows)

    def _resolve(self, plan: _BucketPlan, rows=None,
                 error: Optional[Exception] = None) -> None:
        now = time.perf_counter()
        with self._cv:
            for req in plan.reqs:
                self.stats.latency.record(now - req.arrival)
            self._outstanding -= len(plan.reqs)
            self._cv.notify_all()
        # futures resolve OUTSIDE the lock: done-callbacks may re-enter
        # submit()
        for row, req in enumerate(plan.reqs):
            req.future.latency_s = now - req.arrival
            if error is not None:
                req.future.set_exception(error)
            else:
                req.future.set_result(rows[row])
