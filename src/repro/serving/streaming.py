"""Open-loop streaming front-end for the FFT service (DESIGN.md §11).

``FFTService.submit_batch`` is closed-loop: the caller hands over a
complete request list and blocks on one device fetch, so its throughput
number says nothing about latency under CONTINUOUS arrivals.
:class:`StreamingFFTService` turns the batched scheduler into a
continuously-batching service with an SLO story:

* **Async request queue** -- :meth:`submit` is non-blocking: it enqueues
  the request and returns a ``concurrent.futures.Future`` that resolves
  to the transform (with its measured ``latency_s`` attached).
* **Multi-tier EDF bucket formation** -- every request belongs to a
  named SLO tier (``StreamConfig.tiers``, e.g. ``interactive=2ms``,
  ``standard=10ms``, ``batch=100ms``) whose slack sets its deadline.
  Requests accumulate per ``(s, m, kind)`` bucket in
  earliest-deadline-first order; buckets dispatch when they FILL
  (``max_batch``) *or* when the earliest deadline across ALL bucket
  heads expires -- the scheduler scans a deadline-ordered heap of
  bucket heads, never dict insertion order, so a late-created bucket
  with an urgent head is served first.
* **Adaptive slack** -- an EWMA of the measured per-bucket-shape
  compute time (stage + launch + sync) is subtracted from each tier's
  nominal slack, so a tier's deadline budget covers QUEUEING only,
  not compute the scheduler can already predict.  Shrinks under load,
  grows back as the shape gets faster (``StreamConfig.adaptive``).
* **Admission control / backpressure** -- the undispatched queue is
  bounded (``max_queue``); over capacity, :meth:`submit` raises a typed
  :class:`AdmissionError` with a machine-readable ``reason`` instead of
  letting queueing delay grow without bound (reject early, don't
  collapse late).  Both reject reasons count into ``stats.rejected``.
* **Double-buffered host->device staging** -- a dedicated staging
  thread packs bucket k+1's numpy buffers and launches its (async)
  device call while the sync thread is still blocked fetching bucket k.
  ``ServiceStats.staging_overlap_s`` measures exactly the staging
  sub-interval that ran while a downstream bucket was in flight
  (explicit in-flight counter under the scheduler lock -- no unlocked
  queue-internals peeking).

The pipeline is three threads around two depth-bounded queues::

    callers --submit()--> per-(s, kind) EDF heaps   [admission bound]
        | scheduler: fill-or-earliest-deadline bucket formation
        v
    stage_q  (depth scfg.stage_depth)
        | stager: straggler sim + numpy pack + H2D + async launch
        v
    sync_q   (depth 1  ==  double buffer: bucket k+1 stages/computes
        |                   while bucket k is being fetched)
        v syncer: jax.device_get -> resolve futures -> latency histograms
                  (one histogram per tier + the global one)

Every ``FFTService`` internal (plan/runner caches, the staging numpy
work, ``stats.batches`` accounting) is touched ONLY by the staging
thread, so the service object itself never needs locks.  The bucket
executors are untouched: the streaming path launches the SAME jitted
one-launch/one-transfer runners as ``submit_batch`` (the jaxpr pins
hold by construction).

Scheduler invariants (pinned by tests/test_streaming_service.py):

* **EDF order** -- among dispatchable buckets the one with the
  earliest head deadline goes first, and rows inside a bucket are
  deadline-ordered, never FIFO.
* **Flush scoping** -- :meth:`flush` drains exactly the requests
  pending at flush time (a generation counter); requests submitted
  after ``flush()`` returns ride the normal fill/deadline rules.
* **Cancellation safety** -- a caller cancelling a pending future can
  never kill a pipeline thread: resolution claims the future with
  ``set_running_or_notify_cancel()`` and counts losses in
  ``stats.cancelled``.

``fill_only=True`` + ``pipelined=False`` reproduce the naive baseline
the open-loop benchmark races against: dispatch only full buckets, and
stage synchronously on the scheduler thread.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from concurrent.futures import Future
from queue import Queue
from typing import Mapping, Optional

import jax
import numpy as np

from repro.serving.batching import LatencyHistogram
from repro.serving.fft_service import FFTService

__all__ = ["AdmissionError", "StreamConfig", "StreamingFFTService"]


class AdmissionError(RuntimeError):
    """Typed rejection from admission control.

    ``reason`` is machine-readable: ``"queue_full"`` (the undispatched
    queue is at ``max_queue``) or ``"closed"`` (submit after close).
    Every rejection -- both reasons -- increments ``stats.rejected``.
    """

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"request rejected: {reason}"
                         + (f" ({detail})" if detail else ""))
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    slack_s: float = 0.010      # nominal slack of the DEFAULT tier (and
    #                             of any tier left unset in ``tiers``);
    #                             per-request override via
    #                             submit(..., slack_s=...)
    tiers: Optional[Mapping[str, float]] = None
    #                           # named SLO tiers -> nominal slack seconds.
    #                             None = {"interactive": 2ms,
    #                             "standard": slack_s, "batch": 100ms}
    default_tier: str = "standard"   # tier used when submit() names none
    adaptive: bool = True       # subtract the EWMA-predicted compute time
    #                             of the request's (s, kind) shape from the
    #                             tier slack, so the deadline budget covers
    #                             queueing only
    ewma_alpha: float = 0.25    # EWMA weight of the newest compute sample
    min_slack_frac: float = 0.1  # floor of the effective slack as a
    #                              fraction of the nominal tier slack
    max_queue: int = 1024       # admission bound on undispatched requests
    stage_depth: int = 2        # bucket plans buffered ahead of the stager
    fill_only: bool = False     # naive baseline: dispatch only on full
    #                             buckets (plus the drain flush)
    pipelined: bool = True      # False = naive baseline: stage + launch +
    #                             sync inline on the scheduler thread

    def resolved_tiers(self) -> dict[str, float]:
        """The tier table with defaults filled in (name -> slack seconds)."""
        if self.tiers is not None:
            return {str(k): float(v) for k, v in self.tiers.items()}
        return {"interactive": 0.002, "standard": self.slack_s,
                "batch": 0.100}


@dataclasses.dataclass
class _Request:
    x: object                   # the (host) request payload
    kind: str
    tier: str
    arrival: float              # perf_counter at submit
    deadline: float             # arrival + effective slack
    seq: int                    # submit order; EDF tie-break
    gen: int                    # flush generation at submit time
    future: Future

    def entry(self) -> tuple:
        """The per-bucket heap entry (EDF order, seq tie-break)."""
        return (self.deadline, self.seq, self)


@dataclasses.dataclass
class _BucketPlan:
    s: object                   # scalar length or n-D shape tuple
    kind: str
    reqs: list
    reason: str                 # "fill" | "deadline" | "drain"
    stage_s: float = 0.0        # filled by the stager; the syncer adds its
    #                             sync share and feeds the compute EWMA


class StreamingFFTService:
    """Multi-tier EDF continuous batching over one :class:`FFTService`.

    The wrapped service's ``stats`` object is extended in place (queue
    peak, dispatch reasons, staging overlap, cancellations, the global
    AND per-tier latency histograms), so one ``ServiceStats.summary()``
    tells the whole story.

    Warm up the wrapped service (``service.warmup()``) BEFORE offering
    traffic: the streaming scheduler dispatches every power-of-two
    bucket size up to ``max_batch``, and a cold compile inside a latency
    window is exactly the stall the front-end exists to avoid.
    """

    def __init__(self, service: FFTService,
                 scfg: StreamConfig = StreamConfig()):
        self.service = service
        self.scfg = scfg
        self.tiers = scfg.resolved_tiers()
        if scfg.default_tier not in self.tiers:
            raise ValueError(
                f"default_tier {scfg.default_tier!r} not in tiers "
                f"{sorted(self.tiers)}")
        self.stats = service.stats       # extended in place
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        # per-(s, kind) EDF heaps of (deadline, seq, request)
        self._pending: dict[tuple, list[tuple]] = {}
        # deadline-ordered heap of bucket HEADS: (deadline, seq, key).
        # Lazy invalidation: every time a request becomes the head of its
        # bucket an entry is pushed, so the true head of every pending
        # bucket always has an exact entry; stale entries are discarded
        # when they surface.
        self._heads: list[tuple] = []
        self._seq = 0                    # submit counter (EDF tie-break)
        self._gen = 0                    # flush generation counter
        self._flush_upto: Optional[int] = None   # drain gens <= this
        self._depth = 0                  # undispatched requests
        self._outstanding = 0            # submitted, not yet resolved
        self._closed = False
        # compute-time EWMA per (s, kind): stage + launch + sync seconds
        self._ewma: dict[tuple, float] = {}
        # launched-but-not-yet-fetched buckets, and the "busy clock" that
        # integrates the wall time with at least one bucket in flight --
        # the overlap accounting reads this under the lock instead of
        # racing on Queue.unfinished_tasks
        self._inflight = 0
        self._busy_total = 0.0
        self._busy_since: Optional[float] = None
        self._stage_q: Queue = Queue(maxsize=max(1, scfg.stage_depth))
        self._sync_q: Queue = Queue(maxsize=1)
        self._threads = [threading.Thread(
            target=self._scheduler, name="stream-scheduler", daemon=True)]
        if scfg.pipelined:
            self._threads.append(threading.Thread(
                target=self._stager, name="stream-stager", daemon=True))
            self._threads.append(threading.Thread(
                target=self._syncer, name="stream-syncer", daemon=True))
        for t in self._threads:
            t.start()

    # -- client surface -------------------------------------------------
    def submit(self, x, kind: str = "c2c", tier: Optional[str] = None,
               slack_s: Optional[float] = None) -> Future:
        """Enqueue one request; returns a Future resolving to the result.

        Non-blocking.  ``tier`` names an SLO class from
        ``StreamConfig.tiers`` (default ``scfg.default_tier``) whose
        slack -- shrunk by the predicted compute time of this request's
        bucket shape when ``scfg.adaptive`` -- sets the deadline;
        ``slack_s`` overrides the nominal slack outright (the tier still
        labels the latency accounting).  Raises :class:`AdmissionError`
        when the service is over capacity (``reason="queue_full"``) or
        closed.  The resolved future carries ``latency_s`` --
        arrival-to-result wall time -- as an attribute.
        """
        x = np.asarray(x)
        s = self.service.bucket_key(x, kind)      # validates kind/shape
        tier = self.scfg.default_tier if tier is None else tier
        if tier not in self.tiers:
            raise ValueError(
                f"unknown tier {tier!r}; configured: {sorted(self.tiers)}")
        base = self.tiers[tier] if slack_s is None else float(slack_s)
        now = time.perf_counter()
        with self._cv:
            if self._closed:
                self.stats.rejected += 1
                raise AdmissionError("closed")
            if self._depth >= self.scfg.max_queue:
                self.stats.rejected += 1
                raise AdmissionError(
                    "queue_full", f"max_queue={self.scfg.max_queue}")
            slack = self._effective_slack_locked((s, kind), base)
            self._seq += 1
            req = _Request(x, kind, tier, now, now + slack,
                           self._seq, self._gen, Future())
            heap = self._pending.setdefault((s, kind), [])
            heapq.heappush(heap, req.entry())
            if heap[0][2] is req:        # new bucket head -> index it
                heapq.heappush(self._heads,
                               (req.deadline, req.seq, (s, kind)))
            self._depth += 1
            self._outstanding += 1
            self.stats.queue_peak = max(self.stats.queue_peak, self._depth)
            self._cv.notify_all()
        return req.future

    def _effective_slack_locked(self, key: tuple, base: float) -> float:
        """The tier slack minus the EWMA-predicted compute time of this
        bucket shape (floored at ``min_slack_frac`` of nominal), so the
        remaining budget is pure queueing headroom."""
        if not self.scfg.adaptive:
            return base
        predicted = self._ewma.get(key)
        if predicted is None:
            return base
        return max(base - predicted, base * self.scfg.min_slack_frac)

    def _record_compute_locked(self, key: tuple, seconds: float) -> None:
        prev = self._ewma.get(key)
        a = self.scfg.ewma_alpha
        self._ewma[key] = (seconds if prev is None
                           else a * seconds + (1.0 - a) * prev)

    @property
    def compute_ewma(self) -> dict[tuple, float]:
        """Predicted compute seconds per (s, kind) bucket shape (a copy)."""
        with self._lock:
            return dict(self._ewma)

    @property
    def queue_depth(self) -> int:
        """Undispatched requests right now (the admission-bounded gauge)."""
        with self._lock:
            return self._depth

    def flush(self) -> None:
        """Dispatch every CURRENTLY pending partial bucket (reason
        ``"drain"``), without waiting for fills or deadlines.  Scoped by
        a generation counter: requests submitted after ``flush()``
        returns are NOT swept into drain buckets."""
        with self._cv:
            self._flush_upto = self._gen
            self._gen += 1
            self._cv.notify_all()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Flush, then block until every submitted request has resolved.

        Returns False if ``timeout`` elapsed first.
        """
        with self._cv:
            self._flush_upto = self._gen
            self._gen += 1
            self._cv.notify_all()
            return self._cv.wait_for(
                lambda: self._outstanding == 0, timeout)

    def close(self) -> None:
        """Drain outstanding work and stop the pipeline threads."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        for t in self._threads:
            t.join()

    def __enter__(self) -> "StreamingFFTService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- scheduler: fill-or-earliest-deadline bucket formation ----------
    def _scheduler(self) -> None:
        cap = self.service.cfg.max_batch
        while True:
            with self._cv:
                plan = None
                while True:
                    plan = self._pop_ready_locked(cap)
                    if plan is not None or (self._closed
                                            and not self._pending):
                        break
                    self._cv.wait(self._timeout_locked())
            if plan is None:
                break                        # closed and fully dispatched
            with self._lock:
                field = f"{plan.reason}_dispatches"
                setattr(self.stats, field,
                        getattr(self.stats, field) + 1)
            if self.scfg.pipelined:
                self._stage_q.put(plan)      # backpressure: bounded depth
            else:
                self._stage_and_sync(plan)   # naive serial baseline
        self._stage_q.put(None)              # sentinel for the stager

    def _head_key_locked(self) -> Optional[tuple]:
        """The pending bucket with the EARLIEST head deadline, via the
        lazy heap (stale entries discarded as they surface)."""
        while self._heads:
            deadline, seq, key = self._heads[0]
            heap = self._pending.get(key)
            if heap is not None and heap[0][:2] == (deadline, seq):
                return key
            heapq.heappop(self._heads)       # dispatched or superseded
        return None

    def _pop_ready_locked(self, cap: int) -> Optional[_BucketPlan]:
        """The EDF-ordered dispatch decision under the fill-or-deadline
        rule: fill first (a full bucket never waits), then drain when a
        flush/close is armed, then the earliest expired head."""
        now = time.perf_counter()
        choice = reason = None
        full = [key for key, heap in self._pending.items()
                if len(heap) >= cap]
        if full:
            # ties between simultaneously-full buckets break EDF too
            choice = min(full, key=lambda k: self._pending[k][0][0])
            reason = "fill"
        elif self._closed or self._flush_upto is not None:
            elig = [key for key, heap in self._pending.items()
                    if any(self._drains_locked(e[2]) for e in heap)]
            if elig:
                choice = min(elig, key=lambda k: self._pending[k][0][0])
                reason = "drain"
            elif self._flush_upto is not None and not self._closed:
                self._flush_upto = None      # drain scope finished; disarm
        if choice is None and not self.scfg.fill_only:
            key = self._head_key_locked()
            if key is not None and self._pending[key][0][0] <= now:
                choice, reason = key, "deadline"
        if choice is None:
            return None
        heap = self._pending[choice]
        if reason == "drain":
            # take only the requests inside the drain scope, EDF order
            keep, take = [], []
            while heap and len(take) < cap:
                entry = heapq.heappop(heap)
                (take if self._drains_locked(entry[2]) else keep).append(
                    entry)
            for entry in keep:
                heapq.heappush(heap, entry)
        else:
            take = [heapq.heappop(heap) for _ in range(min(cap, len(heap)))]
        if heap:
            # re-index the new bucket head in the deadline heap
            heapq.heappush(self._heads, (heap[0][0], heap[0][1], choice))
        else:
            del self._pending[choice]
        self._depth -= len(take)
        return _BucketPlan(choice[0], choice[1],
                           [entry[2] for entry in take], reason)

    def _drains_locked(self, req: _Request) -> bool:
        """Is this request inside the current drain scope?  close()
        drains everything; flush() only the generations it snapshotted."""
        if self._closed:
            return True
        return self._flush_upto is not None and req.gen <= self._flush_upto

    def _timeout_locked(self) -> Optional[float]:
        """Sleep until the earliest head deadline (None = wait for a fill
        notification -- the fill_only baseline never sets an alarm)."""
        if self.scfg.fill_only or not self._pending:
            return None
        key = self._head_key_locked()
        if key is None:                      # unreachable: pending != {}
            return None
        return max(self._pending[key][0][0] - time.perf_counter(), 0.0)

    # -- in-flight accounting (the staging-overlap clock) ---------------
    def _busy_clock_locked(self, now: float) -> float:
        """Total wall seconds, so far, with >= 1 launched-but-unfetched
        bucket; differences of this clock measure exactly the overlapped
        sub-interval of any window."""
        busy = self._busy_total
        if self._busy_since is not None:
            busy += now - self._busy_since
        return busy

    def _inflight_inc_locked(self, now: float) -> None:
        self._inflight += 1
        if self._inflight == 1:
            self._busy_since = now

    def _inflight_dec_locked(self, now: float) -> None:
        self._inflight -= 1
        if self._inflight == 0:
            self._busy_total += now - self._busy_since
            self._busy_since = None

    # -- stager: numpy pack + H2D + async launch ------------------------
    def _stager(self) -> None:
        while True:
            plan = self._stage_q.get()
            if plan is None:
                break
            t0 = time.perf_counter()
            with self._lock:
                busy0 = self._busy_clock_locked(t0)
            try:
                out = self._stage_and_launch(plan)
            except Exception as e:                # noqa: BLE001
                self._resolve(plan, error=e)
                continue
            t1 = time.perf_counter()
            dt = t1 - t0
            plan.stage_s = dt
            with self._lock:
                # the sub-interval of [t0, t1] during which a downstream
                # bucket was between launch and fetch-completion: the
                # double-buffer win, measured -- not inferred from a
                # point sample of queue internals
                overlap = min(self._busy_clock_locked(t1) - busy0, dt)
                self.stats.dispatch_s += dt
                self.stats.staging_overlap_s += max(overlap, 0.0)
                self._inflight_inc_locked(t1)
            self._sync_q.put((plan, out))
        self._sync_q.put(None)                    # sentinel for the syncer

    def _stage_and_launch(self, plan: _BucketPlan):
        svc = self.service
        bucket, args = svc.stage_bucket(
            plan.s, plan.kind, [r.x for r in plan.reqs])
        return svc.launch_bucket(plan.s, bucket, plan.kind, args)

    # -- syncer: one device->host fetch per bucket ----------------------
    def _syncer(self) -> None:
        while True:
            item = self._sync_q.get()
            if item is None:
                self._sync_q.task_done()
                break
            plan, out = item
            t0 = time.perf_counter()
            try:
                # fetch_bucket (not a bare device_get): the fault-tolerant
                # path returns host rows plus per-row ServiceErrors, which
                # must become per-request Future exceptions
                rows, row_errors = self.service.fetch_bucket(out)
            except Exception as e:                # noqa: BLE001
                self._sync_q.task_done()
                with self._lock:
                    self._inflight_dec_locked(time.perf_counter())
                self._resolve(plan, error=e)
                continue
            t1 = time.perf_counter()
            dt = t1 - t0
            self._sync_q.task_done()
            with self._lock:
                self._inflight_dec_locked(t1)
                self.stats.sync_s += dt
                self.stats.host_transfers += 1
                self._record_compute_locked(
                    (plan.s, plan.kind), plan.stage_s + dt)
            self._resolve(plan, rows=rows, row_errors=row_errors)

    def _stage_and_sync(self, plan: _BucketPlan) -> None:
        """The unpipelined baseline: stage, launch, and block, serially
        on the scheduler thread (no staging/compute overlap)."""
        t0 = time.perf_counter()
        try:
            out = self._stage_and_launch(plan)
        except Exception as e:                    # noqa: BLE001
            self._resolve(plan, error=e)
            return
        t1 = time.perf_counter()
        rows, row_errors = self.service.fetch_bucket(out)
        t2 = time.perf_counter()
        with self._lock:
            self.stats.dispatch_s += t1 - t0
            self.stats.sync_s += t2 - t1
            self.stats.host_transfers += 1
            self._record_compute_locked((plan.s, plan.kind), t2 - t0)
        self._resolve(plan, rows=rows, row_errors=row_errors)

    def _resolve(self, plan: _BucketPlan, rows=None,
                 error: Optional[Exception] = None,
                 row_errors: Optional[list] = None) -> None:
        now = time.perf_counter()
        with self._cv:
            for req in plan.reqs:
                self.stats.latency.record(now - req.arrival)
                self.stats.tier_latency.setdefault(
                    req.tier, LatencyHistogram()).record(now - req.arrival)
            self._outstanding -= len(plan.reqs)
            self._cv.notify_all()
        # futures resolve OUTSIDE the lock: done-callbacks may re-enter
        # submit()
        cancelled = 0
        for row, req in enumerate(plan.reqs):
            req.future.latency_s = now - req.arrival
            # claim the future first: a caller's .cancel() on a pending
            # future would otherwise make set_result/set_exception raise
            # InvalidStateError and kill this pipeline thread
            if not req.future.set_running_or_notify_cancel():
                cancelled += 1
                continue
            # a bucket-wide error beats per-row errors; a per-row
            # ServiceError (fault path) fails ONLY its own request --
            # the rest of the bucket resolves normally
            err = error if error is not None else (
                row_errors[row] if row_errors is not None else None)
            if err is not None:
                req.future.set_exception(err)
            else:
                req.future.set_result(rows[row])
        if cancelled:
            with self._lock:
                self.stats.cancelled += cancelled
