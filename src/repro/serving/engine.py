"""Batched generation engine: continuous batched prefill -> decode loop.

CPU-runnable with reduced configs (examples/serve_lm.py); the same engine
drives the full configs under the production mesh via launch/serve.py.
Requests are padded into fixed (batch, prompt_len) buckets so the jitted
prefill/decode never retrace; finished rows are masked, freed, and refilled
(continuous batching) rather than blocking the batch on its slowest member
-- the serving-side analogue of not waiting for stragglers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_factory import BuiltModel
from repro.serving.batching import pad_requests
from repro.serving.serve_step import make_serve_fns, sample_token

__all__ = ["EngineConfig", "GenerationEngine"]


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 4
    prompt_len: int = 32       # fixed prefill bucket
    max_new_tokens: int = 16
    cache_len: int = 128
    temperature: float = 0.0
    eos_id: Optional[int] = None
    seed: int = 0


class GenerationEngine:
    def __init__(self, model: BuiltModel, params, ecfg: EngineConfig):
        self.model = model
        self.params = params
        self.ecfg = ecfg
        prefill, decode = make_serve_fns(model)
        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def _pad_prompts(self, prompts: Sequence[Sequence[int]]) -> np.ndarray:
        e = self.ecfg
        out = np.zeros((len(prompts), e.prompt_len), np.int32)
        for i, p in enumerate(prompts):
            p = list(p)[-e.prompt_len:]
            out[i, e.prompt_len - len(p):] = p  # left-pad
        return out

    def generate(self, prompts: Sequence[Sequence[int]],
                 key: Optional[jax.Array] = None) -> list[list[int]]:
        """Greedy/temperature generation for a batch of prompts."""
        e = self.ecfg
        # pad request list to the fixed batch (no retrace on partial batches)
        prompts, n_live = pad_requests(list(prompts), e.batch_size, lambda: [0])
        tokens = jnp.asarray(self._pad_prompts(prompts))
        if key is None:
            key = jax.random.PRNGKey(e.seed)

        cache = self.model.init_cache(e.batch_size, e.cache_len)
        logits, cache = self._prefill(self.params, {"tokens": tokens}, cache)
        key, sub = jax.random.split(key)
        next_tok = sample_token(logits, sub, e.temperature)

        outs: list[list[int]] = [[] for _ in range(e.batch_size)]
        done = np.zeros(e.batch_size, bool)
        step0 = e.prompt_len
        # Fetch tokens ONE STEP BEHIND the decode launches: step t+1's
        # decode goes out (async dispatch) BEFORE token t crosses to the
        # host, so the blocking device_get and the per-token EOS/append
        # bookkeeping overlap the next step's device compute instead of
        # serializing with it.  An EOS discovered on the host simply
        # discards the already-launched speculative step -- wasted FLOPs
        # for one step, never wrong tokens (and one decode FEWER than the
        # old loop paid in the no-EOS case, which decoded past the last
        # fetched token).
        pending = next_tok
        for t in range(e.max_new_tokens):
            spec = None
            if t + 1 < e.max_new_tokens:
                logits, cache = self._decode(
                    self.params, cache, pending,
                    jnp.asarray(step0 + t, jnp.int32))
                key, sub = jax.random.split(key)
                spec = sample_token(logits, sub, e.temperature)
            toks = np.asarray(jax.device_get(pending)).reshape(-1)
            for i in range(n_live):
                if not done[i]:
                    outs[i].append(int(toks[i]))
                    if e.eos_id is not None and toks[i] == e.eos_id:
                        done[i] = True
            if done[:n_live].all() or spec is None:
                break
            pending = spec
        return [outs[i] for i in range(n_live)]
