"""LRU cache of per-straggler-mask MDS decode matrices (DESIGN.md §6).

Since DESIGN.md §8 this is the FALLBACK decode-matrix source: the default
service path builds per-request matrices inside the jitted bucket executor
via the closed-form Lagrange inversion (``mds.lagrange_inverse``), and the
LRU serves only ``m > mds.LAGRANGE_MAX_M`` (where adversarial-subset
conditioning exceeds what f32 planes carry and the complex128 host inverse
is the right tool) and explicitly pinned ``device_decode=False`` configs.

The batched service decodes every request in a bucket with ONE Pallas
batched matmul: each request contributes its own ``(m, N)`` *scatter decode
matrix* ``D`` with ``D[:, subset] = inv(G[subset, :])`` and zero columns
elsewhere, so that ``c_hat = D @ b`` recovers the message shards from the
full worker-result block without gathering responder rows first.

Straggler masks repeat heavily under any realistic latency model (the same
fast workers keep winning), so the ``O(m^3)`` subset inversion is cached
keyed by the mask byte-pattern.  Inverses are computed once in complex128
on the host and applied in f32 planes on device; a novel mask pays one
host inversion (the same cost the dense-solve decode pays per request) and
then hits the cache forever -- until evicted by churn, after which it is
simply recomputed, never answered wrongly.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np

__all__ = ["DecodeMatrixCache"]


class DecodeMatrixCache:
    """LRU of straggler-mask byte patterns -> ``(m, N)`` decode matrices.

    One cache per ``(N, m)`` GENERATOR -- the generator (hence every
    per-mask matrix) is independent of the transform length and of the
    bucket kind, so the service shares a single instance across all its
    ``(s, kind)`` buckets (c2c/r2c/c2r, DESIGN.md §7): a mask seen in any
    bucket is a hit in every other.  Keying is strictly by mask BYTE
    pattern: two masks equal as first-``m`` subsets but different as
    patterns occupy distinct entries (never aliased -- the tail responders
    differ even when the decode subset does not).  ``maxsize`` bounds host
    memory at ``maxsize * m * N * 8`` bytes.
    """

    def __init__(self, generator: np.ndarray, maxsize: int = 64):
        g = np.asarray(generator)
        self.generator = g.astype(np.complex128)
        self.n, self.m = g.shape
        self.maxsize = int(maxsize)
        if self.maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.hits = 0
        self.misses = 0
        # mask bytes -> (scatter (m, N), inv (m, m), subset (m,))
        self._store: OrderedDict[bytes, tuple] = OrderedDict()

    def __len__(self) -> int:
        return len(self._store)

    @staticmethod
    def subset_of(mask: np.ndarray, m: int) -> np.ndarray:
        """First ``m`` available workers (stable order) -- the host twin of
        ``mds.first_available``."""
        mask = np.asarray(mask, bool)
        order = np.argsort(~mask, kind="stable")
        return order[:m]

    def _entry(self, mask: np.ndarray) -> tuple:
        mask = np.asarray(mask, bool)
        if mask.shape != (self.n,):
            raise ValueError(f"mask must have shape ({self.n},), got {mask.shape}")
        key = mask.tobytes()
        cached = self._store.get(key)
        if cached is not None:
            self.hits += 1
            self._store.move_to_end(key)
            return cached
        self.misses += 1
        entry = self._compute(mask)
        self._store[key] = entry
        if len(self._store) > self.maxsize:
            self._store.popitem(last=False)
        return entry

    def matrix(self, mask: np.ndarray) -> np.ndarray:
        """The ``(m, N)`` complex64 scatter decode matrix for ``mask``."""
        return self._entry(mask)[0]

    def matrices(self, masks: np.ndarray) -> np.ndarray:
        """Stacked ``(B, m, N)`` scatter decode matrices for a bucket."""
        return np.stack([self.matrix(row) for row in np.asarray(masks, bool)])

    def compact(self, masks: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Stacked ``(B, m, m)`` compact inverses + ``(B, m)`` subsets.

        The gather-then-matmul decode form used by the direct (off-TPU)
        bucket executor; the scatter form feeds the Pallas kernel (no
        dynamic gathers on the MXU path)."""
        entries = [self._entry(row) for row in np.asarray(masks, bool)]
        return (np.stack([e[1] for e in entries]),
                np.stack([e[2] for e in entries]))

    def _compute(self, mask: np.ndarray) -> tuple:
        if int(mask.sum()) < self.m:
            raise ValueError(
                f"need >= m={self.m} responders, mask has {int(mask.sum())}")
        subset = self.subset_of(mask, self.m)
        inv = np.linalg.inv(self.generator[subset, :])
        d = np.zeros((self.m, self.n), np.complex128)
        d[:, subset] = inv
        return (d.astype(np.complex64), inv.astype(np.complex64),
                subset.astype(np.int32))
