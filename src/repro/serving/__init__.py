from repro.serving.batching import LatencyHistogram, bucket_size, pad_requests
from repro.serving.decode_cache import DecodeMatrixCache
from repro.serving.engine import EngineConfig, GenerationEngine
from repro.serving.fft_service import (
    FAILURE_REASONS,
    DegradedResult,
    FFTService,
    FFTServiceConfig,
    ServiceError,
    ServiceStats,
)
from repro.serving.serve_step import make_serve_fns, sample_token
from repro.serving.streaming import (
    AdmissionError,
    StreamConfig,
    StreamingFFTService,
)

__all__ = ["AdmissionError", "DecodeMatrixCache", "DegradedResult",
           "EngineConfig", "FAILURE_REASONS", "FFTService",
           "FFTServiceConfig", "GenerationEngine", "LatencyHistogram",
           "ServiceError", "ServiceStats", "StreamConfig",
           "StreamingFFTService", "bucket_size", "pad_requests",
           "make_serve_fns", "sample_token"]
