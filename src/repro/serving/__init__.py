from repro.serving.batching import bucket_size, pad_requests
from repro.serving.decode_cache import DecodeMatrixCache
from repro.serving.engine import EngineConfig, GenerationEngine
from repro.serving.fft_service import FFTService, FFTServiceConfig, ServiceStats
from repro.serving.serve_step import make_serve_fns, sample_token

__all__ = ["DecodeMatrixCache", "EngineConfig", "GenerationEngine",
           "FFTService", "FFTServiceConfig", "ServiceStats", "bucket_size",
           "pad_requests", "make_serve_fns", "sample_token"]
