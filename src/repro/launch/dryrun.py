import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device
count at first init, and the production meshes need 512 host placeholder
devices.  (Smoke tests and benchmarks never import this module, so they
see the real single CPU device.)

For each cell the driver:

  1. builds the full-size architecture config and its sharding plan
     (launch/shardings.py -- divisibility fallbacks recorded);
  2. lowers the right step (train_step / prefill / decode_step) against
     ShapeDtypeStruct inputs with explicit in/out shardings;
  3. compiles, then extracts ``memory_analysis()`` (does it fit?),
     ``cost_analysis()`` (FLOPs / bytes for the roofline), and the
     collective-bytes breakdown parsed from the partitioned HLO;
  4. writes ``experiments/dryrun/<cell>.json`` (idempotent: existing
     files are skipped unless --force).

``--all`` runs every cell in a subprocess (isolation: one cell's compile
cannot poison another's, and a crash leaves the other JSONs intact --
the same restartability story the trainer has).
"""

import argparse
import dataclasses
import gzip
import json
import re
import subprocess
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_runnable, get_config
from repro.distributed.sharding import use_rules
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.shardings import batch_pspecs, cache_pspecs, make_plan, to_named
from repro.models.model_factory import build_model
from repro.models.params import abstract_params, param_pspecs
from repro.optim.adamw import AdamWConfig, adamw
from repro.optim.schedules import cosine
from repro.training.train_state import abstract_train_state, train_state_pspecs
from repro.training.train_step import make_train_step

__all__ = ["run_cell", "collective_bytes_from_hlo"]

_QUANT_OPT_THRESHOLD = 5e10   # int8 optimizer state above 50B params

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+?\[[0-9,]*\]\S*)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result bytes of every collective in the partitioned module.

    Shapes in post-SPMD HLO are per-device, so totals here are
    bytes-per-chip.  ``-start`` ops are the async halves; their ``-done``
    twins carry no payload.  Methodology note: we count the collective's
    RESULT bytes -- for ring all-gather/reduce-scatter of result size R
    the wire traffic per chip is R*(k-1)/k ~= R, for all-reduce ~= 2R
    (reduce-scatter + all-gather); the report applies those factors.
    """
    per_op: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        b = _shape_bytes(shape_str)
        per_op[op] = per_op.get(op, 0) + b
        counts[op] = counts.get(op, 0) + 1
    wire_factor = {"all-gather": 1.0, "reduce-scatter": 1.0, "all-reduce": 2.0,
                   "all-to-all": 1.0, "collective-permute": 1.0}
    wire = sum(per_op.get(k, 0) * f for k, f in wire_factor.items())
    return {"result_bytes_per_op": per_op, "counts": counts,
            "wire_bytes_per_chip": int(wire)}


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover - backend-specific
        return {"error": f"memory_analysis unavailable: {e}"}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": f"cost_analysis unavailable: {e}"}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    keep = {}
    for k, v in ca.items():
        if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed")):
            keep[k] = float(v)
    return keep


# --------------------------------------------------------------------------
# per-cell lowering
# --------------------------------------------------------------------------
def _lower_cell(arch_id: str, shape_name: str, mesh_kind: str, *,
                n_micro: Optional[int] = None,
                remat_override: Optional[str] = None):
    cfg = get_config(arch_id)
    if remat_override is not None:
        cfg = dataclasses.replace(cfg, remat=remat_override)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    plan = make_plan(cfg, shape, mesh)
    model = build_model(cfg)
    rules = plan.rules

    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]

    ndev = lambda t: to_named(mesh, t)
    with use_rules(mesh, rules):
        if shape.kind == "train":
            quant = model.n_params > _QUANT_OPT_THRESHOLD
            opt = adamw(cosine(3e-4, 10_000, 500),
                        AdamWConfig(quantized_state=quant))
            if n_micro is None:
                b_local = max(shape.global_batch // dp, 1)
                n_micro = max(1, b_local // 2)   # 2 rows/device/microbatch
            step_fn = make_train_step(model, opt, n_micro=n_micro)
            state = abstract_train_state(model.specs, opt)
            state_sh = ndev(train_state_pspecs(model.specs, opt, rules, mesh))
            batch = model.input_specs(shape)
            batch_sh = ndev(batch_pspecs(cfg, shape, rules))
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, NamedSharding(mesh, P())),
            ).lower(state, batch)
            extra = {"n_micro": n_micro, "quantized_opt_state": quant}

        elif shape.kind == "prefill":
            params = abstract_params(model.specs)
            params_sh = ndev(param_pspecs(model.specs, rules))
            batch = model.input_specs(shape)
            batch_sh = ndev(batch_pspecs(cfg, shape, rules))
            cache = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sh = ndev(cache_pspecs(cfg, rules))
            logits_sh = NamedSharding(
                mesh, P(rules.get("batch"), None, rules.get("vocab")))
            fn = lambda p, b, c: model.prefill(p, b, c)
            lowered = jax.jit(
                fn,
                in_shardings=(params_sh, batch_sh, cache_sh),
                out_shardings=(logits_sh, cache_sh),
            ).lower(params, batch, cache)
            extra = {}

        else:  # decode
            quant_kv = cfg.kv_quant_decode
            params = abstract_params(model.specs)
            params_sh = ndev(param_pspecs(model.specs, rules))
            batch = model.input_specs(shape)
            batch_sh = ndev(batch_pspecs(cfg, shape, rules))
            cache = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         quant_kv))
            cache_sh = ndev(cache_pspecs(cfg, rules, quantized=quant_kv))
            logits_sh = NamedSharding(
                mesh, P(rules.get("batch"), None, rules.get("vocab")))
            step_idx = jax.ShapeDtypeStruct((), jnp.int32)
            fn = lambda p, c, b, i: model.decode_step(p, c, b, i)
            lowered = jax.jit(
                fn,
                in_shardings=(params_sh, cache_sh, batch_sh,
                              NamedSharding(mesh, P())),
                out_shardings=(logits_sh, cache_sh),
            ).lower(params, cache, batch, step_idx)
            extra = {"kv_quant": quant_kv}

    return lowered, plan, model, extra


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, variant: str = "baseline", save_hlo: bool = False,
             n_micro: Optional[int] = None,
             remat_override: Optional[str] = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    record: dict = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
    }
    ok, reason = cell_runnable(cfg, shape)
    if not ok:
        record["skipped"] = reason
        return record

    t0 = time.time()
    lowered, plan, model, extra = _lower_cell(
        arch_id, shape_name, mesh_kind, n_micro=n_micro,
        remat_override=remat_override)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    hlo = compiled.as_text()
    hlo_cost = analyze_hlo(hlo).as_dict()
    from repro.launch.roofline import useful_flops

    record.update(
        chips=mesh_chips(plan.mesh),
        n_params=model.n_params,
        n_active_params=model.n_active_params,
        sharding_fallbacks=plan.fallbacks,
        lower_seconds=round(t1 - t0, 2),
        compile_seconds=round(t2 - t1, 2),
        memory=_memory_dict(compiled),
        cost=_cost_dict(compiled),
        collectives=collective_bytes_from_hlo(hlo),
        hlo_cost=hlo_cost,
        model_flops=useful_flops(arch_id, shape_name),
        hlo_lines=hlo.count("\n"),
        **extra,
    )
    # the spec's required prints
    print(f"== {arch_id} x {shape_name} x {mesh_kind} [{variant}] ==")
    print("memory_analysis:", json.dumps(record["memory"]))
    print("cost_analysis:", json.dumps(record["cost"]))
    print("hlo_cost (trip-corrected, per chip): "
          f"flops={hlo_cost['flops']:.3e} bytes={hlo_cost['bytes_accessed']:.3e} "
          f"wire={hlo_cost['collective_wire_bytes']:.3e}")
    print("collectives:", json.dumps(hlo_cost["collective_counts"]))

    if save_hlo:
        with gzip.open(os.path.join(
                out_dir, _cell_name(arch_id, shape_name, mesh_kind, variant)
                + ".hlo.txt.gz"), "wt") as f:
            f.write(hlo)
    return record


def _cell_name(arch: str, shape: str, mesh: str, variant: str) -> str:
    safe = arch.replace(".", "_")
    return f"{safe}--{shape}--{mesh}--{variant}"


def _write(out_dir: str, record: dict) -> str:
    path = os.path.join(out_dir, _cell_name(
        record["arch"], record["shape"], record["mesh"],
        record.get("variant", "baseline")) + ".json")
    with open(path + ".tmp", "w") as f:
        json.dump(record, f, indent=1)
    os.replace(path + ".tmp", path)
    return path


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell in subprocesses")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--remat", choices=("full", "dots", "none"), default=None)
    args = ap.parse_args(argv)

    if args.all:
        failures = []
        for mesh_kind in ("single", "multi"):
            for arch_id in ARCH_IDS:
                for shape_name in SHAPES:
                    name = _cell_name(arch_id, shape_name, mesh_kind, args.variant)
                    path = os.path.join(args.out, name + ".json")
                    if os.path.exists(path) and not args.force:
                        print(f"skip (exists): {name}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch_id, "--shape", shape_name,
                           "--mesh", mesh_kind, "--out", args.out,
                           "--variant", args.variant]
                    if args.save_hlo:
                        cmd.append("--save-hlo")
                    print(">>", " ".join(cmd), flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append(name)
        if failures:
            print("FAILED cells:", failures)
            return 1
        print("all cells complete")
        return 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    record = run_cell(args.arch, args.shape, args.mesh, args.out,
                      variant=args.variant, save_hlo=args.save_hlo,
                      n_micro=args.n_micro, remat_override=args.remat)
    path = _write(args.out, record)
    print("wrote", path)
    return 0


if __name__ == "__main__":
    sys.exit(main())
