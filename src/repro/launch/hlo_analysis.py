"""Trip-count-aware roofline accounting over partitioned HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE, but our
models scan over layers / microbatches / attention chunks, so raw numbers
undercount by 1-3 orders of magnitude (verified: a length-7 scan of a
128x128 matmul reports exactly one matmul of FLOPs).  XLA does annotate
every while with ``backend_config={"known_trip_count":{"n":...}}`` in the
optimized module, so this analyzer re-derives roofline quantities from the
HLO text with multipliers propagated through (nested) loops:

  * FLOPs      -- dot / convolution ops only (the MXU terms; elementwise
                  work is on the VPU and belongs to the memory term);
  * HBM bytes  -- per op: operand + result bytes, skipping pure
                  bookkeeping (tuple/gte/parameter/bitcast).  Fusion ops
                  are costed at the call site (params + outputs), matching
                  how fused intermediates stay on-chip;
  * collective -- result bytes per collective, x wire factor (all-reduce
                  counts 2x: reduce-scatter + all-gather halves).

All quantities are PER CHIP: post-SPMD shapes are per-device.

Known approximations (documented in EXPERIMENTS.md §Roofline):
  * non-dot FLOPs ignored; conv counted with a simplified kernel model;
  * while condition computations ignored (trivial);
  * conditional branches counted as if all branches execute (upper bound);
  * bytes for reduce/scatter combiners counted at call site only.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

__all__ = ["HLOCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s*"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|update_computation|select|scatter)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS = {
    "lhs_contracting_dims": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "lhs_batch_dims": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
}
_WINDOW_SIZE_RE = re.compile(r"window=\{size=([0-9x]+)")

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "rng-bit-generator", "rng", "broadcast",
}

_COLLECTIVES = {
    "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}

# Fusion-normalized byte accounting: on TPU these elementwise ops fuse into
# their consumers/producers, so only their RESULT crosses HBM (and often not
# even that).  Counting operand bytes for them would model an unfused VPU
# pipeline that XLA:TPU never emits.
_ELEMENTWISE = {
    "convert", "multiply", "add", "subtract", "divide", "select", "maximum",
    "minimum", "compare", "exponential", "exponential-minus-one", "tanh",
    "negate", "and", "or", "xor", "not", "sqrt", "rsqrt", "power", "abs",
    "log", "log-plus-one", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "clamp", "cosine", "sine", "is-finite", "atan2",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "remainder",
    "stochastic-convert", "reduce-precision", "real", "imag", "complex",
}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def _dims_of(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    op: str
    rest: str


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0                 # dot+conv flops, trip-corrected, per chip
    bytes_accessed: float = 0.0        # HBM traffic proxy, per chip
    collective_result_bytes: dict = dataclasses.field(default_factory=dict)
    collective_wire_bytes: float = 0.0  # wire-factor weighted, per chip
    collective_counts: dict = dataclasses.field(default_factory=dict)
    while_summary: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_result_bytes": self.collective_result_bytes,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_counts": self.collective_counts,
            "while_summary": self.while_summary,
        }


def _parse_computations(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, list[_Op]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps, entry


def _dot_flops(op: _Op, shapes: dict[str, str]) -> float:
    result_elems, _ = _shape_elems_bytes(op.shape)
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    if not operands:
        return 0.0
    lhs_shape = shapes.get(operands[0])
    if lhs_shape is None:
        return 0.0
    lhs_dims = _dims_of(lhs_shape)
    m = _CDIMS["lhs_contracting_dims"].search(op.rest)
    cdims = [int(d) for d in m.group(1).split(",") if d] if m else []
    k = 1
    for d in cdims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return 2.0 * result_elems * max(k, 1)


def _conv_flops(op: _Op, shapes: dict[str, str]) -> float:
    result_elems, _ = _shape_elems_bytes(op.shape)
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    if len(operands) < 2:
        return 0.0
    ker_dims = _dims_of(shapes.get(operands[1], ""))
    m = _WINDOW_SIZE_RE.search(op.rest)
    spatial = 1
    if m:
        for d in m.group(1).split("x"):
            spatial *= int(d)
    # approximate: per output element, 2 * (kernel spatial extent) * in_feat;
    # in_feat inferred from kernel elems / spatial (over-counts grouped convs
    # by the group factor -- acceptable, convs are negligible in these nets)
    ker = math.prod(ker_dims) if ker_dims else spatial
    in_feat = max(ker // max(spatial, 1), 1)
    return 2.0 * result_elems * spatial * in_feat


def analyze_hlo(text: str) -> HLOCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda k: len(comps[k])) if comps else None
    cost = HLOCost()
    if entry is None:
        return cost

    # -- multipliers -------------------------------------------------------
    mult: dict[str, float] = {entry: 1.0}
    # fixpoint over nested whiles / branches (bounded depth)
    for _ in range(12):
        changed = False
        for cname, ops in comps.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for op in ops:
                if op.op == "while":
                    tm = _TRIP_RE.search(op.rest)
                    trips = int(tm.group(1)) if tm else 1
                    bm = _BODY_RE.search(op.rest)
                    if bm:
                        want = base * trips
                        if mult.get(bm.group(1), 0.0) < want:
                            mult[bm.group(1)] = want
                            changed = True
                elif op.op == "conditional":
                    for g in _BRANCH_RE.finditer(op.rest):
                        names = []
                        if g.group(1):
                            names += _OPERAND_RE.findall(g.group(1))
                        names += [x for x in (g.group(2), g.group(3)) if x]
                        for nm in names:
                            if mult.get(nm, 0.0) < base:
                                mult[nm] = base
                                changed = True
        if not changed:
            break

    # -- accumulate --------------------------------------------------------
    for cname, ops in comps.items():
        k = mult.get(cname, 0.0)
        if k == 0.0:
            continue
        shapes = {op.name: op.shape for op in ops}
        for op in ops:
            opn = op.op.replace("-start", "")
            if opn in _COLLECTIVES:
                _, b = _shape_elems_bytes(op.shape)
                cost.collective_result_bytes[opn] = (
                    cost.collective_result_bytes.get(opn, 0.0) + b * k)
                cost.collective_counts[opn] = (
                    cost.collective_counts.get(opn, 0) + int(k))
                cost.collective_wire_bytes += b * k * _COLLECTIVES[opn]
                cost.bytes_accessed += b * k  # collectives also touch HBM
                continue
            if opn in _SKIP_OPS or opn.endswith("-done"):
                if opn == "while":
                    tm = _TRIP_RE.search(op.rest)
                    _, b = _shape_elems_bytes(op.shape)
                    cost.while_summary.append({
                        "computation": cname,
                        "trips": int(tm.group(1)) if tm else 1,
                        "carry_bytes": b,
                    })
                continue
            # in-place slice ops: XLA aliases the big buffer (DUS updates in
            # place; DS reads only the window), so traffic is ~2x the slice,
            # NOT operand+result.  Counting the full buffer per loop
            # iteration inflated scan-heavy models by >10x (§Perf lesson).
            if opn == "dynamic-slice":
                _, rb = _shape_elems_bytes(op.shape)
                cost.bytes_accessed += 2 * rb * k
                continue
            if opn == "dynamic-update-slice":
                # update operand = smallest non-index operand
                depth = 0
                args = []
                for ch in op.rest:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth < 0:
                            break
                    args.append(ch)
                sizes = []
                for nm in _OPERAND_RE.findall("".join(args)):
                    if nm in shapes:
                        _, b2 = _shape_elems_bytes(shapes[nm])
                        if b2 > 8:
                            sizes.append(b2)
                upd = min(sizes) if len(sizes) >= 2 else 0
                cost.bytes_accessed += 2 * upd * k
                continue
            if opn == "dot":
                cost.flops += _dot_flops(op, shapes) * k
            elif opn == "convolution":
                cost.flops += _conv_flops(op, shapes) * k
            # bytes: result + operands (call-site accounting for fusions);
            # elementwise ops: result only (fusion-normalized, see header)
            _, rb = _shape_elems_bytes(op.shape)
            if opn in _ELEMENTWISE:
                cost.bytes_accessed += rb * k
                continue
            ob = 0
            depth = 0
            arg_str = []
            for ch in op.rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth < 0:
                        break
                arg_str.append(ch)
            for nm in _OPERAND_RE.findall("".join(arg_str)):
                if nm in shapes:
                    _, b = _shape_elems_bytes(shapes[nm])
                    ob += b
            cost.bytes_accessed += (rb + ob) * k
    return cost
