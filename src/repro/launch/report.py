"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the artifacts.

``python -m repro.launch.report`` writes experiments/dryrun_table.md and
experiments/roofline_table.md (both inlined into EXPERIMENTS.md).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES
from repro.launch.roofline import render_table

HBM_PER_CHIP = 16e9  # v5e


def dryrun_table(dryrun_dir: str, variant: str = "baseline") -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("variant", "baseline") != variant:
            continue
        rows.append(rec)
    order = {s: i for i, s in enumerate(SHAPES)}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    lines = [
        "| arch | shape | mesh | compile | state GB/chip | temp GB/chip | fits 16G | "
        "collectives (ag/ar/rs/a2a/cp) | fallbacks |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                         f"SKIP | — | {r['skipped']} |")
            continue
        mem = r.get("memory", {})
        arg = mem.get("argument_size_in_bytes", 0) / 1e9
        tmp = mem.get("temp_size_in_bytes", 0) / 1e9
        fits = "YES" if (arg + tmp) <= HBM_PER_CHIP / 1e9 else f"NO ({arg + tmp:.0f}G)"
        cc = r.get("hlo_cost", {}).get("collective_counts", {})
        coll = "/".join(str(cc.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        fb = len(r.get("sharding_fallbacks", []))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_seconds', 0):.0f}s | {arg:.2f} | {tmp:.2f} | "
            f"{fits} | {coll} | {fb} |")
    return "\n".join(lines)


def main() -> int:
    os.makedirs("experiments", exist_ok=True)
    dt = dryrun_table("experiments/dryrun")
    with open("experiments/dryrun_table.md", "w") as f:
        f.write(dt + "\n")
    rt = render_table("experiments/dryrun", "single", "baseline")
    with open("experiments/roofline_table.md", "w") as f:
        f.write(rt + "\n")
    print(dt)
    print()
    print(rt)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
