"""Training launcher.

Two modes:

* default -- actually trains on the local device(s): a reduced-family model
  (``--reduced``, the CPU path used by examples and CI) or any full config
  if the hardware can hold it.  Fault-tolerant: checkpoints land in
  ``--ckpt-dir`` and a restarted process resumes automatically.
* ``--lower-only`` -- production-mesh path: builds the (16,16) or
  (2,16,16) mesh, jits the train step with explicit shardings and stops
  after ``.lower().compile()`` (what a real pod launcher would do before
  burning accelerator hours; the dry-run drives this per cell).

Examples::

    python -m repro.launch.train --arch minicpm-2b --reduced --steps 200
    python -m repro.launch.train --arch dbrx-132b --lower-only --mesh multi
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.configs import ARCH_IDS, SHAPES, ShapeConfig, get_config, get_reduced_config


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=("cosine", "wsd"), default="cosine")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lower-only", action="store_true",
                    help="production mesh: lower+compile the train step, no run")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--shape", choices=tuple(SHAPES), default="train_4k")
    args = ap.parse_args(argv)

    if args.lower_only:
        # delegate to the dry-run cell runner (subprocess-safe XLA flags
        # only matter there; when invoked directly we assume the caller
        # set the device count)
        from repro.launch.dryrun import run_cell

        rec = run_cell(args.arch, args.shape, args.mesh, "experiments/dryrun",
                       variant="train-launcher")
        print("lower+compile OK" if not rec.get("skipped") else
              f"skipped: {rec['skipped']}")
        return 0

    from repro.data import make_pipeline
    from repro.models import build_model
    from repro.optim import adamw, cosine, wsd
    from repro.training import Trainer, TrainerConfig

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    pipe = make_pipeline(cfg, shape, seed=args.seed)
    sched = (wsd(args.lr, args.steps, max(args.steps // 20, 1))
             if args.schedule == "wsd"
             else cosine(args.lr, args.steps, max(args.steps // 20, 1)))
    opt = adamw(sched)
    print(f"[train] {cfg.name}: {model.n_params:,} params "
          f"({model.n_active_params:,} active), {args.steps} steps, "
          f"batch {args.global_batch} x seq {args.seq_len}")
    trainer = Trainer(model, opt, pipe, TrainerConfig(
        total_steps=args.steps, checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir, log_every=args.log_every,
        n_micro=args.n_micro, seed=args.seed))
    _, metrics = trainer.run()
    print(f"[train] done: {metrics}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
