"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state -- the dry-run sets ``XLA_FLAGS=--xla_force_host_platform_device_
count=512`` before its first jax import, and nothing here may run earlier.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods = 512
chips as (pod=2, data=16, model=16); the "pod" axis carries only
data-parallel gradient all-reduces (the slow inter-pod DCI hops), while
"model" stays inside the pod's ICI torus.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_chips"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_chips(mesh) -> int:
    return mesh.devices.size
