"""Launchers.  NOTE: never import ``repro.launch.dryrun`` from library code
-- it sets XLA_FLAGS for 512 placeholder devices at import time, which must
only happen in a dedicated dry-run process."""
