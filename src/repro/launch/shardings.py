"""Per-cell sharding plans: rules with divisibility fallbacks + state specs.

The production mesh is fixed at (data=16, model=16) [x pod=2], but not
every architecture dimension divides every axis (qwen1.5's 40 heads vs a
16-way model axis; whisper's 51865 vocab; long_500k's batch of 1).  GSPMD
refuses non-divisible dim shardings, so ``build_rules`` starts from the
global rules table and *falls back to replication* for any logical axis
whose dimension does not divide its mesh axis -- each fallback is recorded
and surfaced in the dry-run report (EXPERIMENTS.md documents the list).

Also here: PartitionSpec trees for every jit boundary (train state, batch,
KV/recurrent caches) so launch/dryrun.py and launch/train.py state their
in/out shardings explicitly rather than trusting propagation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, ShapeConfig
from repro.distributed.sharding import (
    MULTI_POD_RULES,
    SINGLE_POD_RULES,
    AxisRules,
    logical_spec,
)
from repro.models.attention import QuantKV

__all__ = ["ShardingPlan", "build_rules", "make_plan", "cache_pspecs",
           "batch_pspecs", "to_named"]


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        out = 1
        for a in entry:
            out *= mesh.shape[a]
        return out
    return mesh.shape[entry]


def build_rules(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh
                ) -> tuple[AxisRules, list[str]]:
    """Rules table specialised to (arch, shape, mesh) + fallback log."""
    base = MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    rules = dict(base)
    fallbacks: list[str] = []

    def require(axis: str, dim: int, what: str):
        size = _axis_size(mesh, rules.get(axis))
        if size > 1 and dim % size != 0:
            rules[axis] = None
            fallbacks.append(f"{axis}: {what}={dim} % {size} != 0 -> replicated")

    # batch: drop "pod" first, then all, if the global batch doesn't divide
    bsz = shape.global_batch
    if _axis_size(mesh, rules["batch"]) > 1 and bsz % _axis_size(mesh, rules["batch"]) != 0:
        if "pod" in mesh.axis_names and bsz % mesh.shape["data"] == 0:
            rules["batch"] = "data"
            fallbacks.append(f"batch: {bsz} not divisible by pod*data -> data only")
        else:
            rules["batch"] = None
            fallbacks.append(f"batch: {bsz} not divisible -> replicated")

    require("heads", cfg.n_heads, "n_heads")
    require("p_heads", cfg.n_heads, "n_heads")
    require("kv_heads", cfg.n_kv_heads, "n_kv_heads")
    require("p_kv", cfg.n_kv_heads, "n_kv_heads")
    require("vocab", cfg.vocab_size, "vocab")
    require("p_vocab", cfg.vocab_size, "vocab")
    mlp_dims = [cfg.d_ff]
    if cfg.moe is not None:
        mlp_dims.append(cfg.moe.d_ff_expert)
    if cfg.recurrent is not None:
        mlp_dims.append(cfg.recurrent.d_rnn)
    for dim in mlp_dims:
        require("mlp", dim, "ff/rnn width")
        require("p_mlp", dim, "ff/rnn width")
    if cfg.moe is not None:
        require("experts", cfg.moe.num_experts, "num_experts")
        require("p_experts", cfg.moe.num_experts, "num_experts")
    # FSDP axis shards d_model slices of params
    require("p_fsdp", cfg.d_model, "d_model")

    # KV-cache context parallelism: when kv_heads cannot occupy the model
    # axis (GQA kv < 16 or non-divisible), shard the cache's SEQUENCE axis
    # over "model" instead -- otherwise 32k-decode caches replicate 16x and
    # blow past HBM (qwen1.5-32b: 86 GB/chip replicated vs 5.4 GB sharded).
    if rules.get("kv_heads") is None and shape.kind in ("prefill", "decode"):
        cache_len = shape.seq_len if cfg.attn_window is None else min(
            shape.seq_len, cfg.attn_window)
        model_size = mesh.shape.get("model", 1)
        if model_size > 1 and cache_len % model_size == 0:
            rules["kv_seq"] = "model"
            fallbacks.append(
                f"kv_seq: cache seq axis -> model ({cache_len} % {model_size} == 0; "
                "context-parallel KV since kv_heads replicated)")

    # flattened token axis (MoE dispatch) follows the batch axis decision
    rules["tokens"] = rules["batch"]
    return rules, fallbacks


# --------------------------------------------------------------------------
# cache PartitionSpec trees (mirror each family's init_cache structure)
# --------------------------------------------------------------------------
def _kv_slot(rules: AxisRules, lead: tuple[str, ...], quantized: bool):
    axes = lead + ("batch", "kv_seq", "kv_heads", None)
    spec = logical_spec(axes, rules)
    if quantized:
        sc = logical_spec(axes, rules)  # scale: same layout, last dim 1
        return {"k": QuantKV(q=spec, scale=sc), "v": QuantKV(q=spec, scale=sc)}
    return {"k": spec, "v": spec}


def cache_pspecs(cfg: ArchConfig, rules: AxisRules, *, quantized: bool = False):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models.transformer import _block_structure

        pattern, _ = _block_structure(cfg)
        return [_kv_slot(rules, ("layers",), quantized) for _ in pattern]
    if fam == "ssm":
        return {
            "tm_last": logical_spec(("layers", "batch", None), rules),
            "cm_last": logical_spec(("layers", "batch", None), rules),
            "wkv": logical_spec(("layers", "batch", "heads", None, None), rules),
        }
    if fam == "hybrid":
        from repro.models.rglru import _pattern_counts

        pat, _, tail = _pattern_counts(cfg)

        def slot(kind, lead):
            if kind == "attn":
                return _kv_slot(rules, lead, quantized)
            return {
                "conv": logical_spec(lead + ("batch", None, "mlp"), rules),
                "h": logical_spec(lead + ("batch", "mlp"), rules),
            }

        return {
            "blocks": [slot(k, ("layers",)) for k in pat],
            "tail": [slot(k, ()) for k in tail],
        }
    if fam == "encdec":
        kv = _kv_slot(rules, ("layers",), quantized)
        return {
            "max_len": P(),
            "layers": {"self": dict(kv), "cross": dict(kv)},
        }
    raise ValueError(fam)


def batch_pspecs(cfg: ArchConfig, shape: ShapeConfig, rules: AxisRules) -> dict:
    b = logical_spec(("batch",), rules)[0]
    tok = P(b, None)
    emb = P(b, None, None)
    if shape.kind == "train":
        out = {"tokens": tok, "labels": tok}
        if cfg.family == "encdec":
            out["frames"] = emb
        if cfg.family == "vlm":
            out["patches"] = emb
        return out
    if shape.kind == "prefill":
        out = {"tokens": tok}
        if cfg.family == "encdec":
            out["frames"] = emb
        if cfg.family == "vlm":
            out["patches"] = emb
        return out
    return {"tokens": tok}


# --------------------------------------------------------------------------
# the full per-cell plan
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ShardingPlan:
    mesh: Mesh
    rules: AxisRules
    fallbacks: list[str]
    cfg: ArchConfig
    shape: ShapeConfig

    def named(self, spec_tree):
        return to_named(self.mesh, spec_tree)


def to_named(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (jit in/out_shardings)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def make_plan(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> ShardingPlan:
    rules, fallbacks = build_rules(cfg, shape, mesh)
    return ShardingPlan(mesh=mesh, rules=rules, fallbacks=fallbacks,
                        cfg=cfg, shape=shape)
