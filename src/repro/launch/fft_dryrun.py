import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production-mesh dry-run for the paper's own workload (§Perf cell C).

Coded FFT of a length-2^28 vector, m=256 (each worker holds 1/256), N=512
coded workers laid over the 256-chip pod (2 coded shards per chip -- the
paper's N > m redundancy).  Worker compute is the four-step matmul FFT
(what kernels/fourstep_fft.py does on the MXU, expressed in XLA dots so
the roofline analyzer sees the FLOPs).

Variants:
  baseline   -- paper-literal replicated master: all-gather all N results
                to every chip, decode everywhere.
  a2a-decode -- sharded-output decode: one all-to-all moves each worker's
                output columns to their consumer chip; decode + recombine
                happen on (m, L/P) blocks locally.

Napkin math (s=2^28, m=256, N=512, P=256 chips, c64):
  baseline  wire/chip ~= N x L x 8  = 512*2^20*8  = 4.3 GB  -> 86 ms ICI
  a2a       wire/chip ~= N x L/P x 8 x P/P ... = s/P x N/n_local... = 2.1 GB -> 43 ms
  worker FLOPs/chip ~= n_local x 3 x 2 x L x (A+B) = 2*6*2^20*2048 = 2.6e10 -> 0.13 ms
so the cell is collective-bound and halving wire should halve the step.
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.coded_fft import CodedFFT
from repro.core.recombine import dft_matrix
from repro.distributed.coded_runtime import DistributedCodedFFT
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS


def matmul_fft(x: jax.Array) -> jax.Array:
    """Four-step FFT as two DFT matmuls + twiddle (dot-counted, MXU-shaped).

    x: (n, L) complex, L = A*B.  Mirrors kernels/fourstep_fft.py.
    """
    n, ell = x.shape
    a = 1 << ((ell.bit_length() - 1) // 2)
    b = ell // a
    x3 = jnp.swapaxes(x.reshape(n, b, a), 1, 2)       # x3[a', b'] = x[a' + A b']
    fb = dft_matrix(b, x.dtype)
    fa = dft_matrix(a, x.dtype)
    y = jnp.einsum("nab,bk->nak", x3, fb)             # length-B DFTs
    tw = jnp.exp(-2j * jnp.pi
                 * jnp.outer(jnp.arange(a), jnp.arange(b)) / ell).astype(x.dtype)
    y = y * tw[None]
    z = jnp.einsum("qa,nak->nqk", fa, y)              # length-A DFTs
    return z.reshape(n, ell)                          # X[q*B + r]


def run_cell(s: int, m: int, n_workers: int, variant: str, out_dir: str) -> dict:
    mesh = jax.make_mesh((256,), ("workers",))
    plan = CodedFFT(s=s, m=m, n_workers=n_workers, worker_fn=matmul_fft)
    runtime = DistributedCodedFFT(plan, mesh)

    t0 = time.time()
    lowered = runtime.lower(sharded=variant.startswith("a2a"))
    compiled = lowered.compile()
    t1 = time.time()
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo).as_dict()
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes"):
            mem[k] = int(getattr(ma, k))
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    rec = {
        "arch": "coded-fft-service", "shape": f"s2^{s.bit_length()-1}_m{m}_N{n_workers}",
        "mesh": "single", "variant": variant, "chips": 256,
        "kind": "fft",
        "compile_seconds": round(t1 - t0, 2),
        "memory": mem,
        "hlo_cost": hc,
        # useful work: one length-s FFT, 5 s log2 s flops (complex radix-2)
        "model_flops": {"total": 5.0 * s * (s.bit_length() - 1)},
        "terms": {
            "compute_s": hc["flops"] / PEAK_FLOPS,
            "memory_s": hc["bytes_accessed"] / HBM_BW,
            "collective_s": hc["collective_wire_bytes"] / LINK_BW,
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"coded-fft--{rec['shape']}--{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    t = rec["terms"]
    print(f"[{variant:>10}] compile {rec['compile_seconds']}s | "
          f"compute {t['compute_s']*1e3:.2f}ms  memory {t['memory_s']*1e3:.2f}ms  "
          f"collective {t['collective_s']*1e3:.2f}ms | "
          f"colls {hc['collective_counts']}")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--s", type=int, default=1 << 28)
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--workers", type=int, default=512)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant",
                    choices=("baseline", "a2a-decode", "a2a-fused-encode", "both"),
                    default="both")
    args = ap.parse_args()
    variants = (["baseline", "a2a-decode"] if args.variant == "both"
                else [args.variant])
    for v in variants:
        run_cell(args.s, args.m, args.workers, v, args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
