"""Serving launcher: LM generation engine or the coded FFT service.

Examples::

    # batched LM generation with a reduced config (CPU-runnable)
    python -m repro.launch.serve --arch gemma-2b --reduced --prompts 4

    # the paper's application: straggler-tolerant FFT serving
    python -m repro.launch.serve --fft --s 4096 --m 4 --workers 8 --requests 20
"""

from __future__ import annotations

import argparse

import numpy as np


def _serve_lm(args) -> int:
    import jax

    from repro.configs import ARCH_IDS, get_config, get_reduced_config
    from repro.models import build_model
    from repro.serving import EngineConfig, GenerationEngine

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = GenerationEngine(model, params, EngineConfig(
        batch_size=args.prompts, prompt_len=args.prompt_len,
        max_new_tokens=args.new_tokens, cache_len=args.cache_len,
        temperature=args.temperature, seed=args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=args.prompt_len // 2))
               for _ in range(args.prompts)]
    outs = engine.generate(prompts)
    for i, o in enumerate(outs):
        print(f"[serve] request {i}: generated {len(o)} tokens: {o[:16]}...")
    return 0


def _serve_fft(args) -> int:
    import jax
    import jax.numpy as jnp

    from repro.distributed.straggler import StragglerModel
    from repro.serving import FFTService, FFTServiceConfig

    svc = FFTService(FFTServiceConfig(
        s=args.s, m=args.m, n_workers=args.workers,
        straggler=StragglerModel(t0=1.0, mu=args.mu), seed=args.seed))
    key = jax.random.PRNGKey(args.seed)
    worst = 0.0
    for i in range(args.requests):
        key, k1, k2 = jax.random.split(key, 3)
        x = (jax.random.normal(k1, (args.s,))
             + 1j * jax.random.normal(k2, (args.s,))).astype(jnp.complex64)
        y = svc.submit(x)
        err = float(jnp.max(jnp.abs(y - jnp.fft.fft(x))))
        worst = max(worst, err)
    stats = svc.stats.summary()
    print(f"[fft-service] {args.requests} requests, s={args.s} m={args.m} "
          f"N={args.workers}")
    print(f"[fft-service] mean latency: coded {stats['mean_coded_latency']:.3f} "
          f"vs uncoded {stats['mean_uncoded_latency']:.3f} "
          f"(speedup {stats['speedup']:.2f}x), "
          f"stragglers tolerated: {stats['stragglers_tolerated']}")
    print(f"[fft-service] worst abs error vs jnp.fft: {worst:.2e}")
    return 0


def main(argv=None) -> int:
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--fft", action="store_true", help="run the FFT service")
    # LM serving
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    # FFT service
    ap.add_argument("--s", type=int, default=4096)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--mu", type=float, default=1.0)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return _serve_fft(args) if args.fft else _serve_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
