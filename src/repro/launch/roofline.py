"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

Hardware model (TPU v5e, per the assignment):
    peak compute   197 TFLOP/s bf16 / chip
    HBM bandwidth  819 GB/s / chip
    ICI            ~50 GB/s / chip (link bandwidth, wire-factor weighted)

Terms (seconds per step, per chip -- post-SPMD HLO is per-chip):
    compute    = hlo_dot_flops / 197e12
    memory     = hlo_bytes     / 819e9
    collective = wire_bytes    / 50e9

``model_flops`` is the analytic useful work (6·N_active·D for training,
2·N_active·D prefill, 2·N_active·B per decoded token, plus the attention
term) -- the MODEL_FLOPS/HLO_FLOPs ratio exposes remat/redundancy waste.

``python -m repro.launch.roofline`` renders the markdown table that
EXPERIMENTS.md §Roofline embeds, reading ``experiments/dryrun/*.json``.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Optional

from repro.configs import SHAPES, get_config

__all__ = ["PEAK_FLOPS", "HBM_BW", "LINK_BW", "useful_flops", "terms",
           "render_table"]

PEAK_FLOPS = 197e12   # bf16 FLOP/s per chip
HBM_BW = 819e9        # bytes/s per chip
LINK_BW = 50e9        # bytes/s per chip (ICI)


def useful_flops(arch_id: str, shape_name: str) -> dict:
    """Analytic 'useful' FLOPs for one cell (GLOBAL, not per chip).

    * linear term: 6·N_active·D (train), 2·N_active·D (prefill),
      2·N_active·B (decode: D = B tokens, one per sequence).
    * attention term: 2 matmuls (QK^T, AV) x 2 flops, causal halving,
      window-clipped KV length; x3 for training (bwd = 2x fwd).
      Attention-free families have none; hybrids count their attn third.
    """
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    n = cfg.n_layers

    if shape.kind == "train":
        tokens = b * s
        mult = 3.0
    elif shape.kind == "prefill":
        tokens = b * s
        mult = 1.0
    else:
        tokens = b  # one new token per sequence
        mult = 1.0

    # param count: models/params is jax-free only via factory; compute lazily
    from repro.models.model_factory import build_model

    model = build_model(cfg)
    n_act = model.n_active_params
    per_tok = 6.0 if shape.kind == "train" else 2.0
    lin = per_tok * n_act * tokens

    # attention matmul flops
    attn = 0.0
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        attn_layers = n + (cfg.encoder_layers or 0)
        kv_len = s if cfg.attn_window is None else min(s, cfg.attn_window)
        if shape.kind == "decode":
            q_len = 1.0
            causal = 1.0
        else:
            q_len = float(s)
            causal = 0.5 if cfg.attn_window is None else 1.0
        attn = (4.0 * b * attn_layers * cfg.n_heads * cfg.head_dim
                * q_len * kv_len * causal) * mult
    elif cfg.family == "hybrid":
        pat = cfg.recurrent.block_pattern
        n_attn = round(n * pat.count("attn") / len(pat))
        kv_len = min(s, cfg.attn_window or s)
        q_len = 1.0 if shape.kind == "decode" else float(s)
        attn = (4.0 * b * n_attn * cfg.n_heads * cfg.head_dim
                * q_len * kv_len) * mult
        # RG-LRU recurrence is elementwise: no MXU term
    elif cfg.family == "ssm":
        # WKV state update: per token per head, O(hs^2) MACs (rank-1 update
        # + readout) -- counted as 4*d*hs per token
        hs = cfg.rwkv.head_size
        toks = b * (1.0 if shape.kind == "decode" else float(s))
        attn = 4.0 * cfg.n_layers * cfg.d_model * hs * toks * mult

    return {"linear": lin, "attention": attn, "total": lin + attn}


def terms(record: dict) -> Optional[dict]:
    """Roofline terms (seconds) for one dry-run JSON record."""
    if record.get("skipped"):
        return None
    hc = record.get("hlo_cost")
    if not hc:
        return None
    compute = hc["flops"] / PEAK_FLOPS
    memory = hc["bytes_accessed"] / HBM_BW
    collective = hc["collective_wire_bytes"] / LINK_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    bound = max(compute, memory, collective)
    mf = record.get("model_flops", {}).get("total")
    chips = record.get("chips", 1)
    out = {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        # fraction of the bound that is useful compute at peak
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / bound if mf and bound else None,
        "model_vs_hlo_flops": (mf / chips) / hc["flops"] if mf and hc["flops"] else None,
    }
    return out


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def render_table(dryrun_dir: str, mesh: str = "single",
                 variant: str = "baseline") -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh") != mesh or rec.get("variant", "baseline") != variant:
            continue
        t = terms(rec)
        if t is None:
            rows.append((rec["arch"], rec["shape"], None, rec.get("skipped", "?")))
            continue
        rows.append((rec["arch"], rec["shape"], t, rec))
    lines = [
        f"| arch | shape | compute | memory | collective | dominant | "
        f"roofline frac | model/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    order = {s: i for i, s in enumerate(SHAPES)}
    rows.sort(key=lambda r: (r[0], order.get(r[1], 9)))
    for arch, shape, t, rec in rows:
        if t is None:
            lines.append(f"| {arch} | {shape} | — | — | — | SKIP | — | {rec} |")
            continue
        rf = f"{t['roofline_fraction']:.1%}" if t["roofline_fraction"] else "—"
        mh = f"{t['model_vs_hlo_flops']:.2f}" if t["model_vs_hlo_flops"] else "—"
        lines.append(
            f"| {arch} | {shape} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"{t['dominant']} | {rf} | {mh} |")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    table = render_table(args.dir, args.mesh, args.variant)
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(table + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
