"""Pallas TPU kernel: fused twiddle + length-m DFT recombination.

The master's second decode stage (paper eq. 24) is

    X[i + j*(s/m)] = sum_k C[k, i] * omega_s^{ik} * omega_m^{jk}

= an elementwise twiddle ``T = C * W`` (VPU) fused with a dense length-m DFT
``F_m @ T`` (MXU), streaming the payload axis ``i`` through VMEM in blocks.
Fusing avoids materializing T in HBM -- the twiddle is applied in VMEM right
before the matmul consumes it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "recombine_body",
    "recombine_twiddle_dft",
    "recombine_batched_body",
    "recombine_twiddle_dft_batched",
]


def recombine_body(cr, ci, wr, wi, fr, fi):
    """One recombine block: twiddle in VMEM (never hits HBM) + m-DFT."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    tr = cr * wr - ci * wi
    ti = cr * wi + ci * wr
    return dot(fr, tr) - dot(fi, ti), dot(fr, ti) + dot(fi, tr)


def _kernel(cr_ref, ci_ref, wr_ref, wi_ref, fr_ref, fi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = recombine_body(
        cr_ref[...], ci_ref[...], wr_ref[...], wi_ref[...],
        fr_ref[...], fi_ref[...])


def recombine_twiddle_dft(
    cr, ci, wr, wi, fr, fi, *, block_l: int = 512, interpret: bool = False
):
    """Fused ``F @ (C * W)`` on planar (m, L) data, blocked over L."""
    m, ell = cr.shape
    assert wr.shape == (m, ell) and fr.shape == (m, m)
    block_l = min(block_l, ell)
    grid = (pl.cdiv(ell, block_l),)
    spec_c = pl.BlockSpec((m, block_l), lambda j: (0, j))
    spec_f = pl.BlockSpec((m, m), lambda j: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((m, ell), cr.dtype),
        jax.ShapeDtypeStruct((m, ell), cr.dtype),
    ]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec_c, spec_c, spec_c, spec_c, spec_f, spec_f],
        out_specs=[spec_c, spec_c],
        out_shape=out_shape,
        interpret=interpret,
        name="recombine_twiddle_dft",
    )(cr, ci, wr, wi, fr, fi)


def recombine_batched_body(cr, ci, wr, wi, fr, fi):
    """Batched recombine block: the twiddle/DFT planes are shared across
    the bucket, so the batch block folds into the matmul columns."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    bq, m, bl = cr.shape
    wr = wr[None]                              # (1, m, bl)
    wi = wi[None]
    tr = cr * wr - ci * wi
    ti = cr * wi + ci * wr
    tr = jnp.transpose(tr, (1, 0, 2)).reshape(m, bq * bl)
    ti = jnp.transpose(ti, (1, 0, 2)).reshape(m, bq * bl)
    outr = dot(fr, tr) - dot(fi, ti)
    outi = dot(fr, ti) + dot(fi, tr)
    return (jnp.transpose(outr.reshape(m, bq, bl), (1, 0, 2)),
            jnp.transpose(outi.reshape(m, bq, bl), (1, 0, 2)))


def _bkernel(cr_ref, ci_ref, wr_ref, wi_ref, fr_ref, fi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = recombine_batched_body(
        cr_ref[...], ci_ref[...], wr_ref[...], wi_ref[...],
        fr_ref[...], fi_ref[...])


def recombine_twiddle_dft_batched(
    cr, ci, wr, wi, fr, fi, *, block_q: int = 1, block_l: int = 512,
    interpret: bool = False
):
    """Batched fused ``F @ (C * W)`` on planar (q, m, L) data.

    ``wr/wi`` (m, L) and ``fr/fi`` (m, m) are shared across the bucket;
    blocked over the batch q and payload columns L (both collapsed in
    interpret mode by the ops layer).
    """
    q, m, ell = cr.shape
    assert wr.shape == (m, ell) and fr.shape == (m, m)
    block_l = min(block_l, ell)
    block_q = max(1, min(block_q, q))
    grid = (pl.cdiv(q, block_q), pl.cdiv(ell, block_l))
    spec_c = pl.BlockSpec((block_q, m, block_l), lambda i, j: (i, 0, j))
    spec_w = pl.BlockSpec((m, block_l), lambda i, j: (0, j))
    spec_f = pl.BlockSpec((m, m), lambda i, j: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((q, m, ell), cr.dtype),
        jax.ShapeDtypeStruct((q, m, ell), cr.dtype),
    ]
    return pl.pallas_call(
        _bkernel,
        grid=grid,
        in_specs=[spec_c, spec_c, spec_w, spec_w, spec_f, spec_f],
        out_specs=[spec_c, spec_c],
        out_shape=out_shape,
        interpret=interpret,
        name="recombine_twiddle_dft_batched",
    )(cr, ci, wr, wi, fr, fi)
