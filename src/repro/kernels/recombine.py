"""Pallas TPU kernel: fused twiddle + length-m DFT recombination.

The master's second decode stage (paper eq. 24) is

    X[i + j*(s/m)] = sum_k C[k, i] * omega_s^{ik} * omega_m^{jk}

= an elementwise twiddle ``T = C * W`` (VPU) fused with a dense length-m DFT
``F_m @ T`` (MXU), streaming the payload axis ``i`` through VMEM in blocks.
Fusing avoids materializing T in HBM -- the twiddle is applied in VMEM right
before the matmul consumes it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["recombine_twiddle_dft"]


def _kernel(cr_ref, ci_ref, wr_ref, wi_ref, fr_ref, fi_ref, or_ref, oi_ref):
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    cr, ci = cr_ref[...], ci_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    # twiddle in VMEM (never hits HBM)
    tr = cr * wr - ci * wi
    ti = cr * wi + ci * wr
    fr, fi = fr_ref[...], fi_ref[...]
    or_ref[...] = dot(fr, tr) - dot(fi, ti)
    oi_ref[...] = dot(fr, ti) + dot(fi, tr)


def recombine_twiddle_dft(
    cr, ci, wr, wi, fr, fi, *, block_l: int = 512, interpret: bool = False
):
    """Fused ``F @ (C * W)`` on planar (m, L) data, blocked over L."""
    m, ell = cr.shape
    assert wr.shape == (m, ell) and fr.shape == (m, m)
    block_l = min(block_l, ell)
    grid = (pl.cdiv(ell, block_l),)
    spec_c = pl.BlockSpec((m, block_l), lambda j: (0, j))
    spec_f = pl.BlockSpec((m, m), lambda j: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((m, ell), cr.dtype),
        jax.ShapeDtypeStruct((m, ell), cr.dtype),
    ]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec_c, spec_c, spec_c, spec_c, spec_f, spec_f],
        out_specs=[spec_c, spec_c],
        out_shape=out_shape,
        interpret=interpret,
        name="recombine_twiddle_dft",
    )(cr, ci, wr, wi, fr, fi)
