"""Pallas TPU kernels for the framework's hot spots.

Four kernels (see DESIGN.md §3 for the TPU adaptation rationale):

* ``fourstep_fft`` -- the per-worker DFT as two MXU matmuls + twiddle;
* ``cmatmul``      -- planar complex matmul for MDS encode/decode-apply;
* ``recombine``    -- fused twiddle + length-m DFT for the master;
* ``wkv``          -- RWKV-6 recurrence with the (K x V) state resident in
                      VMEM across the sequential time grid (the HBM-floor
                      answer to §Perf cell B's elementwise-bound knee).

``ops`` holds the jit'd complex-in/complex-out wrappers; ``ref`` the
pure-jnp oracles used by the allclose sweeps in tests/test_kernels.py
and tests/test_wkv_kernel.py.
"""

from repro.kernels.ops import (
    fft_fourstep,
    make_kernel_worker_fn,
    mds_apply,
    recombine_fused,
    split_factor,
)
from repro.kernels.wkv import wkv_pallas

__all__ = [
    "fft_fourstep",
    "make_kernel_worker_fn",
    "mds_apply",
    "recombine_fused",
    "split_factor",
    "wkv_pallas",
]
