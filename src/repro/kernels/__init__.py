"""Pallas TPU kernels for the framework's hot spots.

The kernel stack is the DEFAULT execution engine for complex64 MDS plans
(DESIGN.md §6); the jnp oracle path is the reference/escape hatch.

Kernels (see DESIGN.md §3/§6 for the TPU adaptation rationale):

* ``fourstep_fft``        -- the per-worker DFT as two MXU matmuls +
                             twiddle, batch-blocked;
* ``encode_fourstep_fused`` -- MDS encode folded into the four-step
                             stage-1 matmul: message shards transform in
                             VMEM and coded shards never round-trip HBM;
* ``cmatmul``/``bcmatmul`` -- planar complex matmul for MDS encode and
                             per-request decode-matrix apply;
* ``recombine``           -- fused twiddle + length-m DFT for the master,
                             single and bucket-batched;
* ``wkv``                 -- RWKV-6 recurrence with the (K x V) state
                             resident in VMEM across the time grid.

``ops`` is the backend-dispatch layer (complex/planar wrappers, block
policies, interpret-mode grid collapse); ``ref`` holds the pure-jnp
oracles used by the allclose sweeps in tests/test_kernels.py,
tests/test_kernel_pipeline.py and tests/test_wkv_kernel.py.
"""

from repro.kernels.ops import (
    decode_apply,
    encode_worker,
    fft_fourstep,
    fourstep_planar,
    kernel_backend_supported,
    make_kernel_fftn_fn,
    make_kernel_worker_fn,
    mds_apply,
    recombine_fused,
    recombine_planar,
    split_factor,
)
from repro.kernels.wkv import wkv_pallas

__all__ = [
    "decode_apply",
    "encode_worker",
    "fft_fourstep",
    "fourstep_planar",
    "kernel_backend_supported",
    "make_kernel_fftn_fn",
    "make_kernel_worker_fn",
    "mds_apply",
    "recombine_fused",
    "recombine_planar",
    "split_factor",
    "wkv_pallas",
]
