"""Pallas TPU kernel: the WHOLE coded-FFT bucket in one launch.

The batched service hot path (DESIGN.md §5/§6) is, per request,

    interleave -> MDS encode -> worker DFT -> MDS decode -> recombine

and every stage is either a (shared-matrix) matmul, a batched matmul
against per-request decode matrices, or an elementwise twiddle.  For
bucket shapes that fit VMEM there is no reason for ANY intermediate to
touch HBM: this kernel runs the full pipeline per batch block --

    c   = interleave(x)                       (pure relabeling, free)
    t   = ((F_A @ c) * W) @ F_B               (four-step worker DFT of the
                                               m MESSAGE shards)
    b   = G @ t                               (MDS encode; commutes with
                                               the DFT, N/m flop saving)
    c^  = D_q @ b                             (per-request scatter decode
                                               matrices, stragglers = zero
                                               columns)
    X   = F_m @ (c^ * W_s)                    (recombine butterfly)

-- six MXU contractions and two VPU twiddles per block, one HBM read of
the requests and one HBM write of the spectra.  Off-TPU the ops layer
collapses the batch into a single grid step, so the interpret-mode
lowering is one straight-line XLA program (this is what makes the fused
kernel the fastest CPU path as well, see BENCH_kernels.json).

Stage-level kernels (fourstep_fft.py, cmatmul.py, recombine.py) remain the
fallback for bucket shapes whose working set exceeds VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.cmatmul import bcmatmul_body, cmatmul_body
from repro.kernels.fourstep_fft import encode_fourstep_body

__all__ = ["bucket_body", "bucket_body_fftworker", "coded_fft_bucket"]


def bucket_body(xr, xi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                twr, twi, fmr, fmi):
    """The full pipeline on one (bq, s) block of requests.

    Shared between the Pallas kernel (one block per grid step, everything
    VMEM-resident) and the off-TPU direct path (full batch as straight
    XLA, DESIGN.md §6).  Stages 1-4 are :func:`encode_fourstep_body`.

    Layout note: the four-step DFT produces shard spectra in the scrambled
    order ``B_k[c + d*A] = out[k, c, d]``.  Decode only mixes the shard
    axis, so the scrambled payload order is carried THROUGH the decode and
    undone by the single output transpose at the end -- ``twr/twi`` must be
    the recombine twiddle pre-permuted to that order (``ops`` builds it),
    which saves the largest intermediate copy (the (bq, N, L) unscramble).
    """
    bq, s = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    ell = a * b
    # interleave: c_i[j] = x[i + j*m] -- a relabeling, stays in VMEM
    cr = jnp.transpose(xr.reshape(bq, ell, m), (0, 2, 1)).reshape(bq, m, a, b)
    ci = jnp.transpose(xi.reshape(bq, ell, m), (0, 2, 1)).reshape(bq, m, a, b)
    # stages 1-4: fused four-step DFT + MDS encode -> (bq, n, a, b)
    er, ei = encode_fourstep_body(
        cr, ci, gr, gi, far, fai, wr, wi, fbr, fbi)
    # stage 5: per-request decode matrices (batched contraction over N) --
    # payload stays in scrambled (c, d) order, decode never reads it
    hr, hi = bcmatmul_body(dr, di, er.reshape(bq, n, ell),
                           ei.reshape(bq, n, ell))
    # stage 6: recombine twiddle (pre-scrambled) + length-m DFT
    twr = twr[None]
    twi = twi[None]
    ur = hr * twr - hi * twi
    ui = hr * twi + hi * twr
    ur = jnp.transpose(ur, (1, 0, 2)).reshape(m, bq * ell)
    ui = jnp.transpose(ui, (1, 0, 2)).reshape(m, bq * ell)
    outr, outi = cmatmul_body(fmr, fmi, ur, ui)
    # output + unscramble in ONE transpose: X_q[j*L + c + d*A] lives at
    # out[j, q, c, d] -> (q, j, d, c)
    outr = outr.reshape(m, bq, a, b).transpose(1, 0, 3, 2).reshape(bq, s)
    outi = outi.reshape(m, bq, a, b).transpose(1, 0, 3, 2).reshape(bq, s)
    return outr, outi


def bucket_body_fftworker(xr, xi, dvr, dvi, subsets, gr, gi,
                          twr, twi, fmr, fmi):
    """Direct-mode (off-TPU) bucket pipeline.

    Identical stage structure to :func:`bucket_body` -- planar ingress,
    fused encode-after-transform on the m MESSAGE shards, per-request
    decode matrices, fused recombine -- with two platform-appropriate
    lowerings the Mosaic kernel cannot express:

    * the worker DFT runs on the host FFT (``jnp.fft``) instead of the
      four-step matmul factorization, which trades ~2x the flops for MXU
      shape on TPU but has no business on CPU scalar units;
    * decode gathers the m responder rows (``subsets``) and applies the
      COMPACT ``(m, m)`` inverses ``dvr/dvi`` -- dynamic gathers are cheap
      here and halve the decode contraction vs the scatter form.

    On TPU the Pallas bucket kernel above runs instead (DESIGN.md §6).
    """
    bq, s = xr.shape
    n, m = gr.shape
    ell = s // m
    # interleave on planes: c_i[j] = x[i + j*m]
    cr = jnp.transpose(xr.reshape(bq, ell, m), (0, 2, 1))
    ci = jnp.transpose(xi.reshape(bq, ell, m), (0, 2, 1))
    # worker DFT of the m message shards (linear -> commutes with encode)
    spec = jnp.fft.fft(cr + 1j * ci, axis=-1)
    sr = jnp.real(spec).astype(xr.dtype)
    si = jnp.imag(spec).astype(xr.dtype)
    # MDS encode: one shared matmul, batch folded into the columns
    tr = jnp.transpose(sr, (1, 0, 2)).reshape(m, bq * ell)
    ti = jnp.transpose(si, (1, 0, 2)).reshape(m, bq * ell)
    er, ei = cmatmul_body(gr, gi, tr, ti)
    er = jnp.transpose(er.reshape(n, bq, ell), (1, 0, 2))  # (bq, N, L)
    ei = jnp.transpose(ei.reshape(n, bq, ell), (1, 0, 2))
    # decode: gather each request's m responder rows, compact batched matmul
    idx = subsets[:, :, None]
    rr = jnp.take_along_axis(er, idx, axis=1)              # (bq, m, L)
    ri = jnp.take_along_axis(ei, idx, axis=1)
    hr, hi = bcmatmul_body(dvr, dvi, rr, ri)
    # recombine twiddle (natural order) + length-m DFT
    ur = hr * twr[None] - hi * twi[None]
    ui = hr * twi[None] + hi * twr[None]
    ur = jnp.transpose(ur, (1, 0, 2)).reshape(m, bq * ell)
    ui = jnp.transpose(ui, (1, 0, 2)).reshape(m, bq * ell)
    outr, outi = cmatmul_body(fmr, fmi, ur, ui)
    return (jnp.transpose(outr.reshape(m, bq, ell), (1, 0, 2)).reshape(bq, s),
            jnp.transpose(outi.reshape(m, bq, ell), (1, 0, 2)).reshape(bq, s))


def _bucket_kernel(xr_ref, xi_ref, dr_ref, di_ref, gr_ref, gi_ref,
                   far_ref, fai_ref, wr_ref, wi_ref, fbr_ref, fbi_ref,
                   twr_ref, twi_ref, fmr_ref, fmi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = bucket_body(
        xr_ref[...], xi_ref[...], dr_ref[...], di_ref[...],
        gr_ref[...], gi_ref[...], far_ref[...], fai_ref[...],
        wr_ref[...], wi_ref[...], fbr_ref[...], fbi_ref[...],
        twr_ref[...], twi_ref[...], fmr_ref[...], fmi_ref[...])


def coded_fft_bucket(xr, xi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                     twr, twi, fmr, fmi, *, block_q: int = 1,
                     interpret: bool = False):
    """Fused bucket pipeline: request planes -> output spectrum planes.

    ``xr, xi``: (q, s) request planes; ``dr, di``: (q, m, N) per-request
    scatter decode matrices; ``gr, gi``: (N, m) generator;
    ``far/wr/fbr``: four-step DFT/twiddle planes for L = s/m = A*B;
    ``twr``: (m, L) recombine twiddle; ``fmr``: (m, m) DFT.
    Returns (q, s) planes of ``fft(x, axis=-1)`` decoded from the masked
    worker subset each ``D_q`` encodes.
    """
    q, s = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    ell = a * b
    block_q = max(1, min(block_q, q))
    spec_x = pl.BlockSpec((block_q, s), lambda i: (i, 0))
    spec_d = pl.BlockSpec((block_q, m, n), lambda i: (i, 0, 0))
    spec_g = pl.BlockSpec((n, m), lambda i: (0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    spec_tw = pl.BlockSpec((m, ell), lambda i: (0, 0))
    spec_fm = pl.BlockSpec((m, m), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((q, s), xr.dtype),
        jax.ShapeDtypeStruct((q, s), xr.dtype),
    ]
    return pl.pallas_call(
        _bucket_kernel,
        grid=(pl.cdiv(q, block_q),),
        in_specs=[spec_x, spec_x, spec_d, spec_d, spec_g, spec_g,
                  spec_fa, spec_fa, spec_w, spec_w, spec_fb, spec_fb,
                  spec_tw, spec_tw, spec_fm, spec_fm],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="coded_fft_bucket",
    )(xr, xi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi, twr, twi, fmr, fmi)
