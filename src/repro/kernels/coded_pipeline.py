"""Pallas TPU kernel: the WHOLE coded-FFT bucket in one launch.

The batched service hot path (DESIGN.md §5/§6) is, per request,

    interleave -> MDS encode -> worker DFT -> MDS decode -> recombine

and every stage is either a (shared-matrix) matmul, a batched matmul
against per-request decode matrices, or an elementwise twiddle.  For
bucket shapes that fit VMEM there is no reason for ANY intermediate to
touch HBM: this kernel runs the full pipeline per batch block --

    c   = interleave(x)                       (pure relabeling, free)
    t   = ((F_A @ c) * W) @ F_B               (four-step worker DFT of the
                                               m MESSAGE shards)
    b   = G @ t                               (MDS encode; commutes with
                                               the DFT, N/m flop saving)
    c^  = D_q @ b                             (per-request scatter decode
                                               matrices, stragglers = zero
                                               columns)
    X   = F_m @ (c^ * W_s)                    (recombine butterfly)

-- six MXU contractions and two VPU twiddles per block, one HBM read of
the requests and one HBM write of the spectra.  Off-TPU the ops layer
collapses the batch into a single grid step, so the interpret-mode
lowering is one straight-line XLA program (this is what makes the fused
kernel the fastest CPU path as well, see BENCH_kernels.json).

Stage-level kernels (fourstep_fft.py, cmatmul.py, recombine.py) remain the
fallback for bucket shapes whose working set exceeds VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cmatmul import bcmatmul_body, cmatmul_body
from repro.kernels.fourstep_fft import _cmul_mm, encode_fourstep_body

__all__ = [
    "lagrange_planes_body",
    "subsets_from_masks_body",
    "bucket_body",
    "bucket_body_masked",
    "bucket_body_fftworker",
    "coded_fft_bucket",
    "coded_fft_bucket_masked",
    "coded_fft_bucket_streaming",
    "coded_fft_bucket_streaming_masked",
    "pack_real_planes",
    "half_postdecode_body",
    "rbucket_body",
    "rbucket_body_masked",
    "rbucket_body_fftworker",
    "coded_rfft_bucket",
    "coded_rfft_bucket_masked",
    "ir_message_body",
    "ir_unpack_body",
    "irbucket_body",
    "irbucket_body_masked",
    "irbucket_body_fftworker",
    "coded_irfft_bucket",
    "coded_irfft_bucket_masked",
]


# ================================== device-resident decode matrices (§8)
#
# The closed-form Lagrange inversion of core/mds.py restated on f32 planes
# with ONLY Mosaic-expressible ops -- broadcasted_iota, elementwise trig,
# static-shape matmuls, one static-unrolled m-step product -- so the bucket
# kernels can build every request's decode matrix IN VMEM from its
# responder subset.  No gathers: node powers come from the root-of-unity
# closed form, coefficient shifts from a static one-hot contraction, and
# the scatter from a subset-vs-iota one-hot matmul.


@functools.lru_cache(maxsize=None)
def _locator_perm(m: int) -> np.ndarray:
    # balanced (shuffled static) multiplication order keeps the locator's
    # partial products O(1) -- same argument as mds.lagrange_decode_coeffs
    return np.random.default_rng(0).permutation(m)


def lagrange_planes_body(subsets, n):
    """Per-request decode matrices from responder subsets, on planes.

    ``subsets``: ``(bq, m)`` int32 -- each request's first-m available
    workers.  Returns ``(ivr, ivi, dr, di)``: the compact ``(bq, m, m)``
    inverse planes (the gathered-decode form the direct executor wants) and
    the scatter ``(bq, m, n)`` planes with zero straggler columns (the MXU
    form the fused kernels contract against).  O(m^2) work per request;
    every op lowers inside a Mosaic kernel body.
    """
    bq, m = subsets.shape
    f32 = jnp.float32
    subsets = subsets.astype(jnp.int32)
    tau = 2.0 * np.pi / n
    # exact node powers P[b, j, d] = x_j^d = omega^(subset_j * d mod n)
    d_iota = jax.lax.broadcasted_iota(jnp.int32, (bq, m, m), 2)
    angp = (-tau) * ((subsets[:, :, None] * d_iota) % n).astype(f32)
    pr, pi_ = jnp.cos(angp), jnp.sin(angp)
    angn = (-tau) * (subsets % n).astype(f32)
    nr, ni = jnp.cos(angn), jnp.sin(angn)                   # nodes (bq, m)
    # locator A(z) = prod (z - x_j): m static-unrolled shift-multiply steps
    ar = jnp.concatenate([jnp.ones((bq, 1), f32), jnp.zeros((bq, m), f32)], 1)
    ai = jnp.zeros((bq, m + 1), f32)
    zero = jnp.zeros((bq, 1), f32)
    for i in _locator_perm(m):
        sr = jnp.concatenate([zero, ar[:, :m]], axis=1)     # z * A(z)
        si = jnp.concatenate([zero, ai[:, :m]], axis=1)
        xr_, xi_ = nr[:, i:i + 1], ni[:, i:i + 1]
        ar, ai = sr - (xr_ * ar - xi_ * ai), si - (xr_ * ai + xi_ * ar)
    # deflation in suffix form: T[i, d] = a[i+d+1] (0 past m); the selector
    # S[t, (i, d)] = [t == i+d+1] is built from iota IN the body -- a
    # pallas_call kernel may not capture host constants
    ii = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    dd = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    tsel = jax.lax.broadcasted_iota(jnp.int32, (m + 1, m, m), 0)
    sel = (tsel == (ii + dd + 1)[None]).astype(f32).reshape(m + 1, m * m)
    # q = T @ P^T: the coefficients of A(z)/(z - x_j) for every j at once
    tr = (ar @ sel).reshape(bq, m, m)
    ti = (ai @ sel).reshape(bq, m, m)
    prT = jnp.swapaxes(pr, 1, 2)
    piT = jnp.swapaxes(pi_, 1, 2)
    qr = tr @ prT - ti @ piT
    qi = tr @ piT + ti @ prT                                # (bq, i, j)
    # A'(x_j) = Q_j(x_j) = sum_i q[i, j] x_j^i  (diagonal contraction)
    qrT = jnp.swapaxes(qr, 1, 2)
    qiT = jnp.swapaxes(qi, 1, 2)                            # (bq, j, i)
    apr = jnp.sum(qrT * pr - qiT * pi_, axis=2)
    api = jnp.sum(qrT * pi_ + qiT * pr, axis=2)             # (bq, j)
    den = apr * apr + api * api
    cr = (apr / den)[:, None, :]
    ci = (-api / den)[:, None, :]                           # 1 / A'(x_j)
    ivr = qr * cr - qi * ci
    ivi = qr * ci + qi * cr                                 # inv (bq, m, m)
    # scatter inv columns to worker slots: D[:, subset] = inv, one-hot matmul
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (bq, m, n), 2)
    onehot = (subsets[:, :, None] == k_iota).astype(f32)    # (bq, m, n)
    return ivr, ivi, ivr @ onehot, ivi @ onehot


def subsets_from_masks_body(masks, m):
    """First-m-available responder subsets from raw masks, Mosaic-safe.

    ``masks``: ``(bq, n)`` availability planes (any dtype; nonzero =
    responded).  Returns ``(bq, m)`` int32 -- each request's first m
    available worker indices in ascending order, matching the host-side
    ``ops.mask_subsets`` (stable argsort).  No sort/cumsum primitives:
    the running count of available workers before slot k is one
    triangular-ones matmul, selection is a rank-vs-iota one-hot, and the
    index extraction a masked reduction -- every op lowers in a kernel
    body, so the host ships raw masks and ZERO decode metadata.
    Short rows (fewer than m available) mirror the argsort contract
    exactly: slots past the responder count fill with the FIRST
    non-responders in index order, keeping the Lagrange nodes distinct
    (the whole-bucket kernel computes every worker spectrum anyway, so
    such a row still decodes the true transform -- masks are simulated
    straggler metadata, not missing data).
    """
    bq, n = masks.shape
    f32 = jnp.float32
    mk = (masks.astype(f32) > 0.5).astype(f32)
    kp = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    kk = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    tri = (kp < kk).astype(f32)                  # strictly-lower ones
    rank = mk @ tri                              # (bq, n) availables before k
    rank_nr = (1.0 - mk) @ tri                   # ... and unavailables
    cnt = jnp.sum(mk, axis=1)[:, None, None]     # (bq, 1, 1) responder count
    jj = jax.lax.broadcasted_iota(jnp.int32, (bq, m, n), 1).astype(f32)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (bq, m, n), 2).astype(f32)
    sel = (rank[:, None, :] == jj).astype(f32) * mk[:, None, :]
    sel += ((rank_nr[:, None, :] == jj - cnt).astype(f32)
            * (1.0 - mk[:, None, :]))
    return jnp.sum(sel * kidx, axis=2).astype(jnp.int32)


def bucket_body(xr, xi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                twr, twi, fmr, fmi):
    """The full pipeline on one (bq, s) block of requests.

    Shared between the Pallas kernel (one block per grid step, everything
    VMEM-resident) and the off-TPU direct path (full batch as straight
    XLA, DESIGN.md §6).  Stages 1-4 are :func:`encode_fourstep_body`.

    Layout note: the four-step DFT produces shard spectra in the scrambled
    order ``B_k[c + d*A] = out[k, c, d]``.  Decode only mixes the shard
    axis, so the scrambled payload order is carried THROUGH the decode and
    undone by the single output transpose at the end -- ``twr/twi`` must be
    the recombine twiddle pre-permuted to that order (``ops`` builds it),
    which saves the largest intermediate copy (the (bq, N, L) unscramble).
    """
    bq, s = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    ell = a * b
    # interleave: c_i[j] = x[i + j*m] -- a relabeling, stays in VMEM
    cr = jnp.transpose(xr.reshape(bq, ell, m), (0, 2, 1)).reshape(bq, m, a, b)
    ci = jnp.transpose(xi.reshape(bq, ell, m), (0, 2, 1)).reshape(bq, m, a, b)
    # stages 1-4: fused four-step DFT + MDS encode -> (bq, n, a, b)
    er, ei = encode_fourstep_body(
        cr, ci, gr, gi, far, fai, wr, wi, fbr, fbi)
    # stage 5: per-request decode matrices (batched contraction over N) --
    # payload stays in scrambled (c, d) order, decode never reads it
    hr, hi = bcmatmul_body(dr, di, er.reshape(bq, n, ell),
                           ei.reshape(bq, n, ell))
    # stage 6: recombine twiddle (pre-scrambled) + length-m DFT
    twr = twr[None]
    twi = twi[None]
    ur = hr * twr - hi * twi
    ui = hr * twi + hi * twr
    ur = jnp.transpose(ur, (1, 0, 2)).reshape(m, bq * ell)
    ui = jnp.transpose(ui, (1, 0, 2)).reshape(m, bq * ell)
    outr, outi = cmatmul_body(fmr, fmi, ur, ui)
    # output + unscramble in ONE transpose: X_q[j*L + c + d*A] lives at
    # out[j, q, c, d] -> (q, j, d, c)
    outr = outr.reshape(m, bq, a, b).transpose(1, 0, 3, 2).reshape(bq, s)
    outi = outi.reshape(m, bq, a, b).transpose(1, 0, 3, 2).reshape(bq, s)
    return outr, outi


def bucket_body_masked(xr, xi, masks, gr, gi, far, fai, wr, wi, fbr, fbi,
                       twr, twi, fmr, fmi):
    """:func:`bucket_body` with the decode matrices built IN the body.

    Takes each request's raw ``(n,)`` responder mask instead of
    precomputed decode planes: the first-m subset is selected in-kernel
    (:func:`subsets_from_masks_body`) and the Lagrange weights formed in
    VMEM (DESIGN.md §8) and contracted immediately -- neither the subset
    indices nor the ``(bq, m, N)`` matrices exist outside the kernel's
    working set.
    """
    n, m = gr.shape
    subsets = subsets_from_masks_body(masks, m)
    _, _, dr, di = lagrange_planes_body(subsets, n)
    return bucket_body(xr, xi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                       twr, twi, fmr, fmi)


def bucket_body_fftworker(xr, xi, dvr, dvi, subsets, gr, gi,
                          twr, twi, fmr, fmi):
    """Direct-mode (off-TPU) bucket pipeline.

    Identical stage structure to :func:`bucket_body` -- planar ingress,
    fused encode-after-transform on the m MESSAGE shards, per-request
    decode matrices, fused recombine -- with two platform-appropriate
    lowerings the Mosaic kernel cannot express:

    * the worker DFT runs on the host FFT (``jnp.fft``) instead of the
      four-step matmul factorization, which trades ~2x the flops for MXU
      shape on TPU but has no business on CPU scalar units;
    * decode gathers the m responder rows (``subsets``) and applies the
      COMPACT ``(m, m)`` inverses ``dvr/dvi`` -- dynamic gathers are cheap
      here and halve the decode contraction vs the scatter form.

    On TPU the Pallas bucket kernel above runs instead (DESIGN.md §6).
    """
    bq, s = xr.shape
    n, m = gr.shape
    ell = s // m
    # interleave on planes: c_i[j] = x[i + j*m]
    cr = jnp.transpose(xr.reshape(bq, ell, m), (0, 2, 1))
    ci = jnp.transpose(xi.reshape(bq, ell, m), (0, 2, 1))
    # worker DFT of the m message shards (linear -> commutes with encode)
    spec = jnp.fft.fft(cr + 1j * ci, axis=-1)
    sr = jnp.real(spec).astype(xr.dtype)
    si = jnp.imag(spec).astype(xr.dtype)
    # MDS encode: one shared matmul, batch folded into the columns
    tr = jnp.transpose(sr, (1, 0, 2)).reshape(m, bq * ell)
    ti = jnp.transpose(si, (1, 0, 2)).reshape(m, bq * ell)
    er, ei = cmatmul_body(gr, gi, tr, ti)
    er = jnp.transpose(er.reshape(n, bq, ell), (1, 0, 2))  # (bq, N, L)
    ei = jnp.transpose(ei.reshape(n, bq, ell), (1, 0, 2))
    # decode: gather each request's m responder rows, compact batched matmul
    idx = subsets[:, :, None]
    rr = jnp.take_along_axis(er, idx, axis=1)              # (bq, m, L)
    ri = jnp.take_along_axis(ei, idx, axis=1)
    hr, hi = bcmatmul_body(dvr, dvi, rr, ri)
    # recombine twiddle (natural order) + length-m DFT
    ur = hr * twr[None] - hi * twi[None]
    ui = hr * twi[None] + hi * twr[None]
    ur = jnp.transpose(ur, (1, 0, 2)).reshape(m, bq * ell)
    ui = jnp.transpose(ui, (1, 0, 2)).reshape(m, bq * ell)
    outr, outi = cmatmul_body(fmr, fmi, ur, ui)
    return (jnp.transpose(outr.reshape(m, bq, ell), (1, 0, 2)).reshape(bq, s),
            jnp.transpose(outi.reshape(m, bq, ell), (1, 0, 2)).reshape(bq, s))


# ===================================================== real-input (r2c) path
#
# The r2c bucket (DESIGN.md §7) carries HALF-length payloads through the
# identical stage structure: the real request is relabeled into pair-packed
# message shards z_i[j] = x[i + 2jm] + 1j*x[i + (2j+1)m] (free on planes --
# the real input IS the plane), the fused encode+worker transforms L/2-point
# shards, decode is the same batched matmul, and the one NEW stage is the
# symmetry-aware postdecode: split each packed spectrum into the rfft of its
# real shard (conjugation = a sign flip on the imag plane, real-linear),
# Hermitian-extend, and recombine only the m//2+1 butterfly rows that feed
# the non-redundant bins X[0..s/2].


def pack_real_planes(xr, m):
    """Real request plane -> packed message planes, pure relabeling.

    ``(bq, s)`` real -> ``((bq, m, L/2), (bq, m, L/2))`` planes of
    ``z_i[j] = x[i + 2jm] + 1j*x[i + (2j+1)m]``.
    """
    bq, s = xr.shape
    if s < 2 * m or s % (2 * m) != 0:
        # same documented contract as core.rfft.require_even_shards (the
        # kernel layer never imports upward into repro.core) -- fail the
        # trace with the constraint instead of an opaque reshape error
        raise ValueError(
            f"real packing needs 2m | s (an even shard length s/m): "
            f"got s={s}, m={m}")
    n2 = s // m // 2
    x3 = xr.reshape(bq, n2, 2, m)
    zr = jnp.transpose(x3[:, :, 0, :], (0, 2, 1))
    zi = jnp.transpose(x3[:, :, 1, :], (0, 2, 1))
    return zr, zi


def half_postdecode_body(hr, hi, swr, swi, twr, twi, fhr, fhi, s):
    """Decoded packed spectra -> half-spectrum output planes.

    ``hr, hi``: ``(bq, m, L/2)`` NATURAL-order planes of ``fft(z_i)``;
    ``swr, swi``: ``(1, L/2+1)`` split twiddle ``omega_L^p``; ``twr, twi``:
    ``(m, L)`` recombine twiddle; ``fhr, fhi``: ``(m//2+1, m)`` DFT rows.
    Returns ``(bq, s//2+1)`` planes of ``rfft(x)``.  Conjugation is a sign
    flip on the imag plane, so every step is f32-plane-native.
    """
    bq, m, n2 = hr.shape
    ell = 2 * n2
    # split butterfly: Zext[p] = Z[p mod n2], Zrev[p] = conj(Zext[n2-p])
    hre = jnp.concatenate([hr, hr[..., :1]], axis=-1)
    hie = jnp.concatenate([hi, hi[..., :1]], axis=-1)
    rre = jnp.flip(hre, axis=-1)
    rie = -jnp.flip(hie, axis=-1)
    er = 0.5 * (hre + rre)
    ei = 0.5 * (hie + rie)
    our = 0.5 * (hie - rie)
    oui = -0.5 * (hre - rre)
    sw_r = swr[0][None, None, :]
    sw_i = swi[0][None, None, :]
    cr = er + our * sw_r - oui * sw_i            # C = E + O * omega_L^p
    ci = ei + our * sw_i + oui * sw_r            # (bq, m, n2+1)
    # Hermitian extension: C[L-p] = conj(C[p])
    cfr = jnp.concatenate([cr, jnp.flip(cr[..., 1:n2], axis=-1)], axis=-1)
    cfi = jnp.concatenate([ci, -jnp.flip(ci[..., 1:n2], axis=-1)], axis=-1)
    # recombine twiddle + the m//2+1 non-redundant DFT rows
    ur = cfr * twr[None] - cfi * twi[None]
    ui = cfr * twi[None] + cfi * twr[None]
    ur = jnp.transpose(ur, (1, 0, 2)).reshape(m, bq * ell)
    ui = jnp.transpose(ui, (1, 0, 2)).reshape(m, bq * ell)
    outr, outi = cmatmul_body(fhr, fhi, ur, ui)  # (m//2+1, bq*L)
    rows = m // 2 + 1
    sh = s // 2 + 1
    outr = outr.reshape(rows, bq, ell).transpose(1, 0, 2).reshape(bq, -1)
    outi = outi.reshape(rows, bq, ell).transpose(1, 0, 2).reshape(bq, -1)
    return outr[:, :sh], outi[:, :sh]


def rbucket_body(xr, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                 swr, swi, twr, twi, fhr, fhi, s):
    """The full r2c pipeline on one (bq, s) block of REAL requests.

    Identical structure to :func:`bucket_body` on half-length payloads
    (L/2 = A*B four-step planes), plus the symmetry postdecode.  Unlike the
    c2c bucket, the scrambled four-step order is undone BEFORE the
    butterfly -- the split needs natural reversed indexing -- which costs
    one (bq, m, L/2) transpose instead of the c2c path's pre-permuted
    twiddle trick.
    """
    bq, s_ = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    n2 = a * b
    zr, zi = pack_real_planes(xr, m)
    er, ei = encode_fourstep_body(
        zr.reshape(bq, m, a, b), zi.reshape(bq, m, a, b),
        gr, gi, far, fai, wr, wi, fbr, fbi)      # (bq, n, a, b) scrambled
    hr, hi = bcmatmul_body(dr, di, er.reshape(bq, n, n2),
                           ei.reshape(bq, n, n2))
    # unscramble: scr[c*B + d] holds B[c + d*A] -> natural flat index d*A + c
    hr = hr.reshape(bq, m, a, b).transpose(0, 1, 3, 2).reshape(bq, m, n2)
    hi = hi.reshape(bq, m, a, b).transpose(0, 1, 3, 2).reshape(bq, m, n2)
    return half_postdecode_body(hr, hi, swr, swi, twr, twi, fhr, fhi, s)


def rbucket_body_masked(xr, masks, gr, gi, far, fai, wr, wi, fbr, fbi,
                        swr, swi, twr, twi, fhr, fhi, s):
    """:func:`rbucket_body` with in-kernel subset selection + in-VMEM
    Lagrange decode matrices (cf. :func:`bucket_body_masked`)."""
    n, m = gr.shape
    subsets = subsets_from_masks_body(masks, m)
    _, _, dr, di = lagrange_planes_body(subsets, n)
    return rbucket_body(xr, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                        swr, swi, twr, twi, fhr, fhi, s)


def rbucket_body_fftworker(xr, dvr, dvi, subsets, gr, gi,
                           swr, swi, twr, twi, fhr, fhi, s):
    """Direct-mode (off-TPU) r2c bucket: platform-FFT worker on the packed
    half-length shards, gathered compact decode (cf.
    :func:`bucket_body_fftworker`), symmetry postdecode."""
    bq, s_ = xr.shape
    n, m = gr.shape
    n2 = s // m // 2
    zr, zi = pack_real_planes(xr, m)                   # (bq, m, n2)
    spec = jnp.fft.fft(zr + 1j * zi, axis=-1)
    sr = jnp.real(spec).astype(xr.dtype)
    si = jnp.imag(spec).astype(xr.dtype)
    tr = jnp.transpose(sr, (1, 0, 2)).reshape(m, bq * n2)
    ti = jnp.transpose(si, (1, 0, 2)).reshape(m, bq * n2)
    er, ei = cmatmul_body(gr, gi, tr, ti)
    er = jnp.transpose(er.reshape(n, bq, n2), (1, 0, 2))   # (bq, N, n2)
    ei = jnp.transpose(ei.reshape(n, bq, n2), (1, 0, 2))
    idx = subsets[:, :, None]
    rr = jnp.take_along_axis(er, idx, axis=1)
    ri = jnp.take_along_axis(ei, idx, axis=1)
    hr, hi = bcmatmul_body(dvr, dvi, rr, ri)
    return half_postdecode_body(hr, hi, swr, swi, twr, twi, fhr, fhi, s)


def _rbucket_kernel(s):
    def kernel(xr_ref, dr_ref, di_ref, gr_ref, gi_ref,
               far_ref, fai_ref, wr_ref, wi_ref, fbr_ref, fbi_ref,
               swr_ref, swi_ref, twr_ref, twi_ref, fhr_ref, fhi_ref,
               or_ref, oi_ref):
        or_ref[...], oi_ref[...] = rbucket_body(
            xr_ref[...], dr_ref[...], di_ref[...], gr_ref[...], gi_ref[...],
            far_ref[...], fai_ref[...], wr_ref[...], wi_ref[...],
            fbr_ref[...], fbi_ref[...], swr_ref[...], swi_ref[...],
            twr_ref[...], twi_ref[...], fhr_ref[...], fhi_ref[...], s)

    return kernel


def coded_rfft_bucket(xr, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                      swr, swi, twr, twi, fhr, fhi, s, *, block_q: int = 1,
                      interpret: bool = False):
    """Fused r2c bucket pipeline: real request planes -> half-spectrum
    planes, one Pallas launch per grid step.

    ``xr``: (q, s) REAL request plane (no imag plane exists); ``dr, di``:
    (q, m, N) scatter decode matrices; ``far/wr/fbr``: four-step planes for
    the HALF length L/2 = A*B; ``swr``: (1, L/2+1) split twiddle; ``twr``:
    (m, L) recombine twiddle; ``fhr``: (m//2+1, m) DFT rows.  Returns
    (q, s//2+1) planes of ``rfft(x, axis=-1)``.
    """
    q, s_ = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    n2 = a * b
    ell = 2 * n2
    sh = s // 2 + 1
    rows = m // 2 + 1
    block_q = max(1, min(block_q, q))
    spec_x = pl.BlockSpec((block_q, s), lambda i: (i, 0))
    spec_o = pl.BlockSpec((block_q, sh), lambda i: (i, 0))
    spec_d = pl.BlockSpec((block_q, m, n), lambda i: (i, 0, 0))
    spec_g = pl.BlockSpec((n, m), lambda i: (0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    spec_sw = pl.BlockSpec((1, n2 + 1), lambda i: (0, 0))
    spec_tw = pl.BlockSpec((m, ell), lambda i: (0, 0))
    spec_fh = pl.BlockSpec((rows, m), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((q, sh), xr.dtype),
        jax.ShapeDtypeStruct((q, sh), xr.dtype),
    ]
    return pl.pallas_call(
        _rbucket_kernel(s),
        grid=(pl.cdiv(q, block_q),),
        in_specs=[spec_x, spec_d, spec_d, spec_g, spec_g,
                  spec_fa, spec_fa, spec_w, spec_w, spec_fb, spec_fb,
                  spec_sw, spec_sw, spec_tw, spec_tw, spec_fh, spec_fh],
        out_specs=[spec_o, spec_o],
        out_shape=out_shape,
        interpret=interpret,
        name="coded_rfft_bucket",
    )(xr, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
      swr, swi, twr, twi, fhr, fhi)


def _rbucket_kernel_masked(s):
    def kernel(xr_ref, mk_ref, gr_ref, gi_ref,
               far_ref, fai_ref, wr_ref, wi_ref, fbr_ref, fbi_ref,
               swr_ref, swi_ref, twr_ref, twi_ref, fhr_ref, fhi_ref,
               or_ref, oi_ref):
        or_ref[...], oi_ref[...] = rbucket_body_masked(
            xr_ref[...], mk_ref[...], gr_ref[...], gi_ref[...],
            far_ref[...], fai_ref[...], wr_ref[...], wi_ref[...],
            fbr_ref[...], fbi_ref[...], swr_ref[...], swi_ref[...],
            twr_ref[...], twi_ref[...], fhr_ref[...], fhi_ref[...], s)

    return kernel


def coded_rfft_bucket_masked(xr, masks, gr, gi, far, fai, wr, wi, fbr, fbi,
                             swr, swi, twr, twi, fhr, fhi, s, *,
                             block_q: int = 1, interpret: bool = False):
    """:func:`coded_rfft_bucket` taking raw ``(q, N)`` responder masks in
    place of decode planes -- subset selection AND the Lagrange weights
    run in VMEM per grid step (DESIGN.md §8)."""
    q, s_ = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    n2 = a * b
    ell = 2 * n2
    sh = s // 2 + 1
    rows = m // 2 + 1
    block_q = max(1, min(block_q, q))
    masks = masks.astype(xr.dtype)
    spec_x = pl.BlockSpec((block_q, s), lambda i: (i, 0))
    spec_o = pl.BlockSpec((block_q, sh), lambda i: (i, 0))
    spec_mk = pl.BlockSpec((block_q, n), lambda i: (i, 0))
    spec_g = pl.BlockSpec((n, m), lambda i: (0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    spec_sw = pl.BlockSpec((1, n2 + 1), lambda i: (0, 0))
    spec_tw = pl.BlockSpec((m, ell), lambda i: (0, 0))
    spec_fh = pl.BlockSpec((rows, m), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((q, sh), xr.dtype),
        jax.ShapeDtypeStruct((q, sh), xr.dtype),
    ]
    return pl.pallas_call(
        _rbucket_kernel_masked(s),
        grid=(pl.cdiv(q, block_q),),
        in_specs=[spec_x, spec_mk, spec_g, spec_g,
                  spec_fa, spec_fa, spec_w, spec_w, spec_fb, spec_fb,
                  spec_sw, spec_sw, spec_tw, spec_tw, spec_fh, spec_fh],
        out_specs=[spec_o, spec_o],
        out_shape=out_shape,
        interpret=interpret,
        name="coded_rfft_bucket_masked",
    )(xr, masks, gr, gi, far, fai, wr, wi, fbr, fbi,
      swr, swi, twr, twi, fhr, fhi)


# ===================================================== real-output (c2r) path
def ir_message_body(yr, yi, fpr, fpi, ctwr, ctwi, pwr, pwi, s, m):
    """c2r message stage on planes (the ADJOINT of the r2c postdecode).

    ``yr, yi``: (bq, s//2+1) half-spectrum request planes.  Hermitian-
    extends (endpoint imag parts dropped, matching numpy.irfft), applies
    the adjoint recombine butterfly (``fpr``: (m, m) +sign DFT planes,
    ``ctwr``: (m, L) conjugate twiddle), and packs each per-shard Hermitian
    half spectrum (``pwr``: (1, L/2+1) pack twiddle ``omega_L^{-p}``
    conjugate) into the (bq, m, L/2) packed message planes workers ifft.
    """
    bq, h = yr.shape
    ell = s // m
    n2 = ell // 2
    zeros = jnp.zeros((bq, 1), yr.dtype)
    midr, midi = yr[:, 1:h - 1], yi[:, 1:h - 1]
    fullr = jnp.concatenate(
        [yr[:, :1], midr, yr[:, h - 1:], jnp.flip(midr, axis=-1)], axis=-1)
    fulli = jnp.concatenate(
        [zeros, midi, zeros, -jnp.flip(midi, axis=-1)], axis=-1)   # (bq, s)
    xr3 = jnp.transpose(fullr.reshape(bq, m, ell), (1, 0, 2)).reshape(m, -1)
    xi3 = jnp.transpose(fulli.reshape(bq, m, ell), (1, 0, 2)).reshape(m, -1)
    fr_, fi_ = cmatmul_body(fpr, fpi, xr3, xi3)            # +sign m-DFT
    foldr = jnp.transpose(fr_.reshape(m, bq, ell), (1, 0, 2))
    foldi = jnp.transpose(fi_.reshape(m, bq, ell), (1, 0, 2))
    tr = foldr * ctwr[None] - foldi * ctwi[None]
    ti = foldr * ctwi[None] + foldi * ctwr[None]           # (bq, m, L)
    # pack_half on planes: E + 1j * (0.5*(M - conj(M_rev)) * omega_L^{+p})
    mr, mi = tr[..., :n2 + 1], ti[..., :n2 + 1]
    rvr = jnp.flip(mr, axis=-1)
    rvi = -jnp.flip(mi, axis=-1)
    er = 0.5 * (mr + rvr)
    ei = 0.5 * (mi + rvi)
    dr_ = 0.5 * (mr - rvr)
    di_ = 0.5 * (mi - rvi)
    pw_r = pwr[0][None, None, :]
    pw_i = pwi[0][None, None, :]
    our = dr_ * pw_r - di_ * pw_i
    oui = dr_ * pw_i + di_ * pw_r
    zr = (er - oui)[..., :n2]
    zi = (ei + our)[..., :n2]
    return zr, zi                                          # (bq, m, L/2)


def ir_unpack_body(hr, hi):
    """Decoded packed interleave planes -> real output plane.

    ``hr, hi``: (bq, m, L/2) planes of ``ifft(z_i)`` where
    ``z_i[j] = o_i[2j] + 1j*o_i[2j+1]`` times ``m``.  Returns (bq, s).
    """
    bq, m, n2 = hr.shape
    ell = 2 * n2
    op = jnp.stack([hr, hi], axis=-1).reshape(bq, m, ell) / m
    return jnp.transpose(op, (0, 2, 1)).reshape(bq, m * ell)


def irbucket_body(yr, yi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                  fpr, fpi, ctwr, ctwi, pwr, pwi, s):
    """The full c2r pipeline on one (bq, s//2+1) block of half-spectrum
    requests -- the last of the four kinds to get a whole-bucket body
    (DESIGN.md §9; before this, c2r ran the stage path on TPU and the
    direct body off-TPU).

    Same stage skeleton as :func:`rbucket_body` run in reverse: adjoint
    message butterfly (:func:`ir_message_body`), fused encode + HALF-length
    ifft worker, batched scatter decode, relabel unpack.  The ifft worker
    rides the forward four-step planes via the conj trick on planes --
    ``ifft(G @ z) = conj(fft(conj(G) @ conj(z))) / (L/2)`` is two sign
    flips of imaginary planes around :func:`encode_fourstep_body` plus one
    rescale, so no inverse DFT planes exist anywhere.  The four-step's
    scrambled payload order is carried through decode (decode only mixes
    the shard axis) and undone just before the pair unpack, which needs
    natural order.  Returns ONE real (bq, s) plane.
    """
    bq = yr.shape[0]
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    n2 = a * b
    zr, zi = ir_message_body(yr, yi, fpr, fpi, ctwr, ctwi, pwr, pwi, s, m)
    er, ei = encode_fourstep_body(
        zr.reshape(bq, m, a, b), (-zi).reshape(bq, m, a, b), gr, -gi,
        far, fai, wr, wi, fbr, fbi)              # (bq, n, a, b) scrambled
    er = er.reshape(bq, n, n2) / n2
    ei = ei.reshape(bq, n, n2) / (-n2)           # conj + 1/(L/2): the ifft
    hr, hi = bcmatmul_body(dr, di, er, ei)
    # unscramble: scr[c*B + d] holds B[c + d*A] -> natural flat index d*A + c
    hr = hr.reshape(bq, m, a, b).transpose(0, 1, 3, 2).reshape(bq, m, n2)
    hi = hi.reshape(bq, m, a, b).transpose(0, 1, 3, 2).reshape(bq, m, n2)
    return ir_unpack_body(hr, hi)


def irbucket_body_masked(yr, yi, masks, gr, gi, far, fai, wr, wi, fbr, fbi,
                         fpr, fpi, ctwr, ctwi, pwr, pwi, s):
    """:func:`irbucket_body` with in-kernel subset selection + in-VMEM
    Lagrange decode matrices (cf. :func:`bucket_body_masked`)."""
    n, m = gr.shape
    subsets = subsets_from_masks_body(masks, m)
    _, _, dr, di = lagrange_planes_body(subsets, n)
    return irbucket_body(yr, yi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                         fpr, fpi, ctwr, ctwi, pwr, pwi, s)


def irbucket_body_fftworker(yr, yi, dvr, dvi, subsets, gr, gi,
                            fpr, fpi, ctwr, ctwi, pwr, pwi, s):
    """Direct-mode (off-TPU) c2r bucket: message stage on planes, platform
    ifft worker on packed half-length shards, gathered compact decode,
    relabel unpack.  Returns ONE real plane (bq, s)."""
    n, m = gr.shape
    n2 = s // m // 2
    bq = yr.shape[0]
    zr, zi = ir_message_body(yr, yi, fpr, fpi, ctwr, ctwi, pwr, pwi, s, m)
    tr = jnp.transpose(zr, (1, 0, 2)).reshape(m, bq * n2)
    ti = jnp.transpose(zi, (1, 0, 2)).reshape(m, bq * n2)
    ar_, ai_ = cmatmul_body(gr, gi, tr, ti)
    coded = (ar_ + 1j * ai_).reshape(n, bq, n2)
    spec = jnp.fft.ifft(coded, axis=-1)
    er = jnp.transpose(jnp.real(spec).astype(yr.dtype), (1, 0, 2))
    ei = jnp.transpose(jnp.imag(spec).astype(yr.dtype), (1, 0, 2))
    idx = subsets[:, :, None]
    rr = jnp.take_along_axis(er, idx, axis=1)
    ri = jnp.take_along_axis(ei, idx, axis=1)
    hr, hi = bcmatmul_body(dvr, dvi, rr, ri)
    return ir_unpack_body(hr, hi)


def _irbucket_kernel(s):
    def kernel(yr_ref, yi_ref, dr_ref, di_ref, gr_ref, gi_ref,
               far_ref, fai_ref, wr_ref, wi_ref, fbr_ref, fbi_ref,
               fpr_ref, fpi_ref, ctwr_ref, ctwi_ref, pwr_ref, pwi_ref,
               o_ref):
        o_ref[...] = irbucket_body(
            yr_ref[...], yi_ref[...], dr_ref[...], di_ref[...],
            gr_ref[...], gi_ref[...], far_ref[...], fai_ref[...],
            wr_ref[...], wi_ref[...], fbr_ref[...], fbi_ref[...],
            fpr_ref[...], fpi_ref[...], ctwr_ref[...], ctwi_ref[...],
            pwr_ref[...], pwi_ref[...], s)

    return kernel


def _irbucket_specs(s, m, n, a, b, block_q, masked: bool):
    ell = a * b * 2
    sh = s // 2 + 1
    spec_y = pl.BlockSpec((block_q, sh), lambda i: (i, 0))
    spec_o = pl.BlockSpec((block_q, s), lambda i: (i, 0))
    decode = ([pl.BlockSpec((block_q, n), lambda i: (i, 0))] if masked
              else [pl.BlockSpec((block_q, m, n), lambda i: (i, 0, 0))] * 2)
    shared = [
        pl.BlockSpec((n, m), lambda i: (0, 0)),       # gr
        pl.BlockSpec((n, m), lambda i: (0, 0)),       # gi
        pl.BlockSpec((a, a), lambda i: (0, 0)),       # far
        pl.BlockSpec((a, a), lambda i: (0, 0)),       # fai
        pl.BlockSpec((a, b), lambda i: (0, 0)),       # wr
        pl.BlockSpec((a, b), lambda i: (0, 0)),       # wi
        pl.BlockSpec((b, b), lambda i: (0, 0)),       # fbr
        pl.BlockSpec((b, b), lambda i: (0, 0)),       # fbi
        pl.BlockSpec((m, m), lambda i: (0, 0)),       # fpr
        pl.BlockSpec((m, m), lambda i: (0, 0)),       # fpi
        pl.BlockSpec((m, ell), lambda i: (0, 0)),     # ctwr
        pl.BlockSpec((m, ell), lambda i: (0, 0)),     # ctwi
        pl.BlockSpec((1, ell // 2 + 1), lambda i: (0, 0)),   # pwr
        pl.BlockSpec((1, ell // 2 + 1), lambda i: (0, 0)),   # pwi
    ]
    return [spec_y, spec_y, *decode, *shared], spec_o


def coded_irfft_bucket(yr, yi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                       fpr, fpi, ctwr, ctwi, pwr, pwi, s, *, block_q: int = 1,
                       interpret: bool = False):
    """Fused c2r bucket pipeline: half-spectrum request planes -> ONE real
    output plane, one Pallas launch per grid step (DESIGN.md §9).

    ``yr, yi``: (q, s//2+1) request planes; ``dr, di``: (q, m, N) scatter
    decode matrices; ``far/wr/fbr``: four-step planes for the HALF length
    L/2 = A*B; ``fpr``: (m, m) +sign DFT planes and ``ctwr``: (m, L)
    conjugate twiddle of the adjoint message butterfly; ``pwr``:
    (1, L/2+1) pack twiddle.  Returns the (q, s) real plane of
    ``irfft(y, n=s, axis=-1)`` decoded from the masked worker subset each
    ``D_q`` encodes.
    """
    q, _ = yr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    block_q = max(1, min(block_q, q))
    in_specs, spec_o = _irbucket_specs(s, m, n, a, b, block_q, masked=False)
    return pl.pallas_call(
        _irbucket_kernel(s),
        grid=(pl.cdiv(q, block_q),),
        in_specs=in_specs,
        out_specs=spec_o,
        out_shape=jax.ShapeDtypeStruct((q, s), yr.dtype),
        interpret=interpret,
        name="coded_irfft_bucket",
    )(yr, yi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
      fpr, fpi, ctwr, ctwi, pwr, pwi)


def _irbucket_kernel_masked(s):
    def kernel(yr_ref, yi_ref, mk_ref, gr_ref, gi_ref,
               far_ref, fai_ref, wr_ref, wi_ref, fbr_ref, fbi_ref,
               fpr_ref, fpi_ref, ctwr_ref, ctwi_ref, pwr_ref, pwi_ref,
               o_ref):
        o_ref[...] = irbucket_body_masked(
            yr_ref[...], yi_ref[...], mk_ref[...],
            gr_ref[...], gi_ref[...], far_ref[...], fai_ref[...],
            wr_ref[...], wi_ref[...], fbr_ref[...], fbi_ref[...],
            fpr_ref[...], fpi_ref[...], ctwr_ref[...], ctwi_ref[...],
            pwr_ref[...], pwi_ref[...], s)

    return kernel


def coded_irfft_bucket_masked(yr, yi, masks, gr, gi, far, fai, wr, wi,
                              fbr, fbi, fpr, fpi, ctwr, ctwi, pwr, pwi, s, *,
                              block_q: int = 1, interpret: bool = False):
    """:func:`coded_irfft_bucket` taking raw ``(q, N)`` responder masks in
    place of decode planes -- subset selection and the Lagrange weights are
    built in VMEM per grid step (DESIGN.md §8), completing the
    device-resident path for all four kinds."""
    q, _ = yr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    block_q = max(1, min(block_q, q))
    masks = masks.astype(yr.dtype)
    in_specs, spec_o = _irbucket_specs(s, m, n, a, b, block_q, masked=True)
    return pl.pallas_call(
        _irbucket_kernel_masked(s),
        grid=(pl.cdiv(q, block_q),),
        in_specs=in_specs,
        out_specs=spec_o,
        out_shape=jax.ShapeDtypeStruct((q, s), yr.dtype),
        interpret=interpret,
        name="coded_irfft_bucket_masked",
    )(yr, yi, masks, gr, gi, far, fai, wr, wi, fbr, fbi,
      fpr, fpi, ctwr, ctwi, pwr, pwi)


def _bucket_kernel(xr_ref, xi_ref, dr_ref, di_ref, gr_ref, gi_ref,
                   far_ref, fai_ref, wr_ref, wi_ref, fbr_ref, fbi_ref,
                   twr_ref, twi_ref, fmr_ref, fmi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = bucket_body(
        xr_ref[...], xi_ref[...], dr_ref[...], di_ref[...],
        gr_ref[...], gi_ref[...], far_ref[...], fai_ref[...],
        wr_ref[...], wi_ref[...], fbr_ref[...], fbi_ref[...],
        twr_ref[...], twi_ref[...], fmr_ref[...], fmi_ref[...])


def coded_fft_bucket(xr, xi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi,
                     twr, twi, fmr, fmi, *, block_q: int = 1,
                     interpret: bool = False):
    """Fused bucket pipeline: request planes -> output spectrum planes.

    ``xr, xi``: (q, s) request planes; ``dr, di``: (q, m, N) per-request
    scatter decode matrices; ``gr, gi``: (N, m) generator;
    ``far/wr/fbr``: four-step DFT/twiddle planes for L = s/m = A*B;
    ``twr``: (m, L) recombine twiddle; ``fmr``: (m, m) DFT.
    Returns (q, s) planes of ``fft(x, axis=-1)`` decoded from the masked
    worker subset each ``D_q`` encodes.
    """
    q, s = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    ell = a * b
    block_q = max(1, min(block_q, q))
    spec_x = pl.BlockSpec((block_q, s), lambda i: (i, 0))
    spec_d = pl.BlockSpec((block_q, m, n), lambda i: (i, 0, 0))
    spec_g = pl.BlockSpec((n, m), lambda i: (0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    spec_tw = pl.BlockSpec((m, ell), lambda i: (0, 0))
    spec_fm = pl.BlockSpec((m, m), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((q, s), xr.dtype),
        jax.ShapeDtypeStruct((q, s), xr.dtype),
    ]
    return pl.pallas_call(
        _bucket_kernel,
        grid=(pl.cdiv(q, block_q),),
        in_specs=[spec_x, spec_x, spec_d, spec_d, spec_g, spec_g,
                  spec_fa, spec_fa, spec_w, spec_w, spec_fb, spec_fb,
                  spec_tw, spec_tw, spec_fm, spec_fm],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="coded_fft_bucket",
    )(xr, xi, dr, di, gr, gi, far, fai, wr, wi, fbr, fbi, twr, twi, fmr, fmi)


def _bucket_kernel_masked(xr_ref, xi_ref, mk_ref, gr_ref, gi_ref,
                          far_ref, fai_ref, wr_ref, wi_ref, fbr_ref, fbi_ref,
                          twr_ref, twi_ref, fmr_ref, fmi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = bucket_body_masked(
        xr_ref[...], xi_ref[...], mk_ref[...],
        gr_ref[...], gi_ref[...], far_ref[...], fai_ref[...],
        wr_ref[...], wi_ref[...], fbr_ref[...], fbi_ref[...],
        twr_ref[...], twi_ref[...], fmr_ref[...], fmi_ref[...])


def coded_fft_bucket_masked(xr, xi, masks, gr, gi, far, fai, wr, wi,
                            fbr, fbi, twr, twi, fmr, fmi, *, block_q: int = 1,
                            interpret: bool = False):
    """:func:`coded_fft_bucket` taking raw ``(q, N)`` responder masks in
    place of the ``(q, m, N)`` decode planes.

    Subset selection (first-m-available) AND the per-request Lagrange
    decode matrices run INSIDE the kernel (VMEM-resident, DESIGN.md §8),
    so the host ships the availability bits it already has -- zero decode
    metadata, no host inversion or LRU at all.
    """
    q, s = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    ell = a * b
    block_q = max(1, min(block_q, q))
    masks = masks.astype(xr.dtype)
    spec_x = pl.BlockSpec((block_q, s), lambda i: (i, 0))
    spec_mk = pl.BlockSpec((block_q, n), lambda i: (i, 0))
    spec_g = pl.BlockSpec((n, m), lambda i: (0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    spec_tw = pl.BlockSpec((m, ell), lambda i: (0, 0))
    spec_fm = pl.BlockSpec((m, m), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((q, s), xr.dtype),
        jax.ShapeDtypeStruct((q, s), xr.dtype),
    ]
    return pl.pallas_call(
        _bucket_kernel_masked,
        grid=(pl.cdiv(q, block_q),),
        in_specs=[spec_x, spec_x, spec_mk, spec_g, spec_g,
                  spec_fa, spec_fa, spec_w, spec_w, spec_fb, spec_fb,
                  spec_tw, spec_tw, spec_fm, spec_fm],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="coded_fft_bucket_masked",
    )(xr, xi, masks, gr, gi, far, fai, wr, wi, fbr, fbi, twr, twi, fmr, fmi)


# ===================== streaming bucket: one launch beyond the VMEM budget
#
# The fused bucket kernel needs the whole (bq, s) working set VMEM-resident;
# past ~1M elements the ops layer used to FALL BACK to the multi-launch
# stage path.  The streaming kernel keeps the ONE-launch contract for
# arbitrarily large (s, m): payload and the inter-stage scratch live in HBM
# (ANY memory space) and the kernel hand-rolls double-buffered DMA over
# column tiles (stage 1+2, column-local) then row tiles (stage 3 + encode +
# decode + recombine, all row-local on the scrambled payload), staging tile
# k+1 while tile k computes.  The input is VIEWED as (q, A, B, m) -- the
# interleave relabeling composed with the four-step matrix view is still a
# free reshape of the flat request -- and the output is written NATURALLY
# ordered as (q, m, B, A) via an in-VMEM tile transpose, so no XLA
# pre/post-pass brackets the launch.  Only the c2c bucket streams: the r2c
# split butterfly pairs bin p with n2-p, which is not column-local, so the
# real kinds keep the stage fallback for over-budget shapes.


def _streaming_bucket_kernel(masked, nbt, nat, block_q, block_a, block_b,
                             *refs):
    xr_hbm, xi_hbm = refs[:2]
    rest = refs[2:]
    if masked:
        mk_ref = rest[0]
        rest = rest[1:]
    else:
        dr_ref, di_ref = rest[:2]
        rest = rest[2:]
    (gr_ref, gi_ref, far_ref, fai_ref, wr_ref, wi_ref, fbr_ref, fbi_ref,
     twr_ref, twi_ref, fmr_ref, fmi_ref) = rest[:12]
    (or_hbm, oi_hbm, t1r_hbm, t1i_hbm,
     abr, abi, t1s_r, t1s_i, bbr, bbi, obr, obi,
     sem_a, sem_t1, sem_b, sem_o) = rest[12:]

    n, m = gr_ref.shape
    a = far_ref.shape[0]
    b = fbr_ref.shape[0]
    bq = block_q
    q0 = pl.program_id(0) * block_q

    # per-request decode planes, once per batch block (tiny: (bq, m, n))
    if masked:
        subsets = subsets_from_masks_body(mk_ref[...], m)
        _, _, dr, di = lagrange_planes_body(subsets, n)
    else:
        dr, di = dr_ref[...], di_ref[...]

    # ---- phase A: stage 1 + twiddle over B-column tiles -> t1 HBM scratch
    def a_copies(j, slot):
        cols = pl.ds(j * block_b, block_b)
        return (
            pltpu.make_async_copy(
                xr_hbm.at[pl.ds(q0, bq), :, cols, :], abr.at[slot],
                sem_a.at[slot, 0]),
            pltpu.make_async_copy(
                xi_hbm.at[pl.ds(q0, bq), :, cols, :], abi.at[slot],
                sem_a.at[slot, 1]),
        )

    for c in a_copies(0, 0):
        c.start()
    far = far_ref[...]
    fai = fai_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]

    def phase_a(j, carry):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < nbt)
        def _():
            for c in a_copies(j + 1, jax.lax.rem(j + 1, 2)):
                c.start()

        for c in a_copies(j, slot):
            c.wait()
        # column DFT per message shard: contract A, (bq, b-tile, m) folded
        mr = abr[slot].transpose(1, 0, 2, 3).reshape(a, bq * block_b * m)
        mi = abi[slot].transpose(1, 0, 2, 3).reshape(a, bq * block_b * m)
        t1r, t1i = _cmul_mm(far, fai, mr, mi)
        t1r = t1r.reshape(a, bq, block_b, m)
        t1i = t1i.reshape(a, bq, block_b, m)
        w_r = jax.lax.dynamic_slice_in_dim(
            wr, j * block_b, block_b, 1)[:, None, :, None]
        w_i = jax.lax.dynamic_slice_in_dim(
            wi, j * block_b, block_b, 1)[:, None, :, None]
        t2r = t1r * w_r - t1i * w_i
        t2i = t1r * w_i + t1i * w_r
        t1s_r[...] = t2r.transpose(1, 0, 2, 3)
        t1s_i[...] = t2i.transpose(1, 0, 2, 3)
        cols = pl.ds(j * block_b, block_b)
        outs = (
            pltpu.make_async_copy(
                t1s_r, t1r_hbm.at[pl.ds(q0, bq), :, cols, :], sem_t1.at[0]),
            pltpu.make_async_copy(
                t1s_i, t1i_hbm.at[pl.ds(q0, bq), :, cols, :], sem_t1.at[1]),
        )
        for c in outs:
            c.start()
        for c in outs:
            c.wait()
        return carry

    jax.lax.fori_loop(0, nbt, phase_a, 0)

    # ---- phase B: stage 3 + encode + decode + recombine over A-row tiles
    def b_copies(i, slot):
        rows = pl.ds(i * block_a, block_a)
        return (
            pltpu.make_async_copy(
                t1r_hbm.at[pl.ds(q0, bq), rows, :, :], bbr.at[slot],
                sem_b.at[slot, 0]),
            pltpu.make_async_copy(
                t1i_hbm.at[pl.ds(q0, bq), rows, :, :], bbi.at[slot],
                sem_b.at[slot, 1]),
        )

    for c in b_copies(0, 0):
        c.start()
    gr = gr_ref[...]
    gi = gi_ref[...]
    fbr = fbr_ref[...]
    fbi = fbi_ref[...]
    twr = twr_ref[...]
    twi = twi_ref[...]
    fmr = fmr_ref[...]
    fmi = fmi_ref[...]
    tile = block_a * b

    def phase_b(i, carry):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < nat)
        def _():
            for c in b_copies(i + 1, jax.lax.rem(i + 1, 2)):
                c.start()

        for c in b_copies(i, slot):
            c.wait()
        # row DFT per shard: contract B with (bq, a-tile, m) folded in rows
        tr = bbr[slot].transpose(0, 1, 3, 2).reshape(bq * block_a * m, b)
        ti = bbi[slot].transpose(0, 1, 3, 2).reshape(bq * block_a * m, b)
        s3r, s3i = _cmul_mm(tr, ti, fbr, fbi)
        # MDS encode: contract the shard axis with G
        s3r = s3r.reshape(bq, block_a, m, b).transpose(2, 0, 1, 3).reshape(m, -1)
        s3i = s3i.reshape(bq, block_a, m, b).transpose(2, 0, 1, 3).reshape(m, -1)
        er, ei = _cmul_mm(gr, gi, s3r, s3i)
        er = er.reshape(n, bq, tile).transpose(1, 0, 2)
        ei = ei.reshape(n, bq, tile).transpose(1, 0, 2)
        # per-request decode (scrambled payload order carried through)
        hr, hi = bcmatmul_body(dr, di, er, ei)
        # recombine: the scrambled payload slice [c*B+d for c in tile i] is
        # CONTIGUOUS, so the pre-scrambled twiddle slices per tile
        tw_r = jax.lax.dynamic_slice_in_dim(twr, i * tile, tile, 1)[None]
        tw_i = jax.lax.dynamic_slice_in_dim(twi, i * tile, tile, 1)[None]
        ur = hr * tw_r - hi * tw_i
        ui = hr * tw_i + hi * tw_r
        ur = ur.transpose(1, 0, 2).reshape(m, bq * tile)
        ui = ui.transpose(1, 0, 2).reshape(m, bq * tile)
        outr, outi = _cmul_mm(fmr, fmi, ur, ui)
        # natural order: out[j, q, c, d] -> output[q, j, d, c-tile]
        obr[...] = outr.reshape(m, bq, block_a, b).transpose(1, 0, 3, 2)
        obi[...] = outi.reshape(m, bq, block_a, b).transpose(1, 0, 3, 2)
        cols = pl.ds(i * block_a, block_a)
        outs = (
            pltpu.make_async_copy(
                obr, or_hbm.at[pl.ds(q0, bq), :, :, cols], sem_o.at[0]),
            pltpu.make_async_copy(
                obi, oi_hbm.at[pl.ds(q0, bq), :, :, cols], sem_o.at[1]),
        )
        for c in outs:
            c.start()
        for c in outs:
            c.wait()
        return carry

    jax.lax.fori_loop(0, nat, phase_b, 0)


def _even_divisor(n: int, cap: int) -> int:
    d = max(1, min(cap, n))
    while n % d:
        d -= 1
    return d


def _streaming_bucket_call(masked, xr, xi, decode_args, gr, gi, far, fai,
                           wr, wi, fbr, fbi, twr, twi, fmr, fmi,
                           block_q, block_a, block_b, interpret, name):
    q, s = xr.shape
    n, m = gr.shape
    a = far.shape[0]
    b = fbr.shape[0]
    ell = a * b
    f32 = xr.dtype
    # interleave + matrix view in one free reshape: x4[q, a, b, i] = M_i[a, b]
    x4r = xr.reshape(q, a, b, m)
    x4i = xi.reshape(q, a, b, m)
    block_q = max(1, min(block_q, q))
    pad = (-q) % block_q
    if pad:  # DMA tile sizes are static: round the batch up
        x4r = jnp.concatenate([x4r, jnp.zeros((pad, a, b, m), f32)])
        x4i = jnp.concatenate([x4i, jnp.zeros((pad, a, b, m), f32)])
        if masked:  # all-available filler keeps the Lagrange nodes distinct
            decode_args = [jnp.concatenate(
                [decode_args[0], jnp.ones((pad, n), f32)])]
        else:
            decode_args = [
                jnp.concatenate([d, jnp.zeros((pad, m, n), f32)])
                for d in decode_args]
    qp = q + pad
    block_a = _even_divisor(a, block_a)
    block_b = _even_divisor(b, block_b)
    nat = a // block_a
    nbt = b // block_b

    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    def vspec(*shape):
        return pl.BlockSpec(shape, lambda i, r=len(shape): (0,) * r)

    if masked:
        decode_specs = [pl.BlockSpec((block_q, n), lambda i: (i, 0))]
    else:
        decode_specs = [
            pl.BlockSpec((block_q, m, n), lambda i: (i, 0, 0))] * 2
    in_specs = [any_spec, any_spec, *decode_specs,
                vspec(n, m), vspec(n, m), vspec(a, a), vspec(a, a),
                vspec(a, b), vspec(a, b), vspec(b, b), vspec(b, b),
                vspec(m, ell), vspec(m, ell), vspec(m, m), vspec(m, m)]
    out_shape = [
        jax.ShapeDtypeStruct((qp, m, b, a), f32),   # natural-order output
        jax.ShapeDtypeStruct((qp, m, b, a), f32),
        jax.ShapeDtypeStruct((qp, a, b, m), f32),   # t1 HBM scratch
        jax.ShapeDtypeStruct((qp, a, b, m), f32),
    ]
    scratch = [
        pltpu.VMEM((2, block_q, a, block_b, m), f32),   # phase A in (x2)
        pltpu.VMEM((2, block_q, a, block_b, m), f32),
        pltpu.VMEM((block_q, a, block_b, m), f32),      # phase A staging
        pltpu.VMEM((block_q, a, block_b, m), f32),
        pltpu.VMEM((2, block_q, block_a, b, m), f32),   # phase B in (x2)
        pltpu.VMEM((2, block_q, block_a, b, m), f32),
        pltpu.VMEM((block_q, m, b, block_a), f32),      # phase B staging
        pltpu.VMEM((block_q, m, b, block_a), f32),
        pltpu.SemaphoreType.DMA((2, 2)),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2, 2)),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    outs = pl.pallas_call(
        functools.partial(_streaming_bucket_kernel, masked, nbt, nat,
                          block_q, block_a, block_b),
        grid=(qp // block_q,),
        in_specs=in_specs,
        out_specs=[any_spec, any_spec, any_spec, any_spec],
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
        name=name,
    )(x4r, x4i, *decode_args, gr, gi, far, fai, wr, wi, fbr, fbi,
      twr, twi, fmr, fmi)
    return outs[0][:q].reshape(q, s), outs[1][:q].reshape(q, s)


def coded_fft_bucket_streaming(xr, xi, dr, di, gr, gi, far, fai, wr, wi,
                               fbr, fbi, twr, twi, fmr, fmi, *,
                               block_q: int = 1, block_a: int = 256,
                               block_b: int = 256, interpret: bool = False):
    """One-launch streaming c2c bucket for shapes beyond the VMEM budget.

    Same contract as :func:`coded_fft_bucket` (including the pre-scrambled
    ``twr/twi``) but only (block_q, A, block_b, m) / (block_q, block_a, B,
    m) tiles are VMEM-resident, double-buffered against HBM.
    """
    return _streaming_bucket_call(
        False, xr, xi, [dr, di], gr, gi, far, fai, wr, wi, fbr, fbi,
        twr, twi, fmr, fmi, block_q, block_a, block_b, interpret,
        "coded_fft_bucket_streaming")


def coded_fft_bucket_streaming_masked(xr, xi, masks, gr, gi, far, fai, wr, wi,
                                      fbr, fbi, twr, twi, fmr, fmi, *,
                                      block_q: int = 1, block_a: int = 256,
                                      block_b: int = 256,
                                      interpret: bool = False):
    """:func:`coded_fft_bucket_streaming` taking raw ``(q, N)`` responder
    masks: in-kernel subset selection + Lagrange decode (DESIGN.md §8), so
    the biggest buckets keep both the one-launch AND the zero-metadata
    contracts."""
    masks = masks.astype(xr.dtype)
    return _streaming_bucket_call(
        True, xr, xi, [masks], gr, gi, far, fai, wr, wi, fbr, fbi,
        twr, twi, fmr, fmi, block_q, block_a, block_b, interpret,
        "coded_fft_bucket_streaming_masked")
