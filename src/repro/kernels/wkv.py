"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked, factorized).

Why a kernel: the XLA-level chunked WKV (models/rwkv6.py) bottoms out at
~700 s/step of HBM traffic on rwkv6-3b train_4k because every per-chunk
intermediate (decay factors, scores, chunk outputs) round-trips HBM
(EXPERIMENTS.md §Perf cell B).  On TPU the whole chunk pipeline fits in
VMEM: r/k/v/w stream in once, the (K x V) state lives in a VMEM scratch
across the sequential time grid, and only o streams out -- a single
HBM read of the inputs and write of the output, the memory floor.

Layout / grid:
  inputs  r, k, v, logw : (BH, T, K) f32 planar (batch*heads flattened)
  bonus   u             : (BH, K)    f32 (pre-broadcast per head)
  state0                : (BH, K, K) f32
  grid = (BH, T // CT)  -- dim 0 parallel, dim 1 sequential ("arbitrary"),
  state scratch persists across the T iterations of one BH program.

Math per chunk (identical to models/rwkv6.wkv_chunked, mid-chunk
re-centered factorization; exponents bounded by (CT/2)*|logw|max):
  p      = cumsum(logw)                        (C, K)
  o_inter= (r * exp(pm1)) @ S
  scores = [(r*exp(pm1-c)) @ (k*exp(c-p))^T] * causal_mask
  o      = o_inter + scores @ v + (sum_k r*k*u) * v
  S      = S * exp(p_end) + (k * exp(p_end - p))^T @ v
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv_pallas"]

_CT = 8  # time tile; factor exponents <= 4*|logw|_max = 32, so even
#          fully-masked pair products stay <= e^64 (finite in f32;
#          same bound as models/rwkv6.py chunk=8 -- see its docstring)


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sout_ref,
                s_ref):
    """One (bh, t-tile) grid step.  s_ref: (K, V) f32 VMEM scratch."""
    t_idx = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t_idx == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    r = r_ref[0]                       # (CT, K)
    k = k_ref[0]
    v = v_ref[0]
    lw = lw_ref[0]
    u = u_ref[0]                       # (1, K) block
    s = s_ref[...]                     # (K, V)

    p = jnp.cumsum(lw, axis=0)                      # (CT, K)
    pm1 = p - lw                                    # exclusive cumsum
    c = p[_CT // 2]                                 # (K,) re-centering
    o_inter = jnp.dot(r * jnp.exp(pm1), s)          # (CT, V)
    r_dec = r * jnp.exp(pm1 - c[None])
    k_grow = k * jnp.exp(c[None] - p)
    scores = jnp.dot(r_dec, k_grow.T)               # (CT, CT)
    rows = jax.lax.broadcasted_iota(jnp.int32, (_CT, _CT), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (_CT, _CT), 1)
    scores = jnp.where(rows > cols, scores, 0.0)
    o_intra = jnp.dot(scores, v)
    coef = jnp.sum(r * k * u, axis=-1, keepdims=True)   # (CT, 1) diag bonus
    o_ref[0] = o_inter + o_intra + coef * v

    pe = p[-1]                                      # (K,)
    kdec = k * jnp.exp(pe[None] - p)
    s_ref[...] = s * jnp.exp(pe)[:, None] + jnp.dot(kdec.T, v)

    @pl.when(t_idx == nt - 1)
    def _emit_state():
        sout_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_pallas(r, k, v, logw, u, state, *, interpret: bool | None = None):
    """WKV over (BH, T, K) planar inputs.  Returns (o, final_state).

    ``u``: (BH, K); ``state``: (BH, K, K).  T must be a multiple of 8
    (pad upstream); K should be a multiple of 8 lanes (64 natively).
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    bh, t, kd = r.shape
    assert t % _CT == 0, (t, _CT)
    grid = (bh, t // _CT)
    blk = lambda: pl.BlockSpec((1, _CT, kd), lambda i, j: (i, j, 0))
    out_shape = (
        jax.ShapeDtypeStruct((bh, t, kd), jnp.float32),
        jax.ShapeDtypeStruct((bh, kd, kd), jnp.float32),
    )
    return pl.pallas_call(
        _wkv_kernel,
        grid=grid,
        in_specs=[
            blk(), blk(), blk(), blk(),
            pl.BlockSpec((1, kd), lambda i, j: (i, 0)),           # u
            pl.BlockSpec((1, kd, kd), lambda i, j: (i, 0, 0)),    # state0
        ],
        out_specs=[
            blk(),                                                # o
            pl.BlockSpec((1, kd, kd), lambda i, j: (i, 0, 0)),    # state out
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((kd, kd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state)
