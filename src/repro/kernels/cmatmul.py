"""Pallas TPU kernels: planar complex matmul (MDS encode / decode-apply).

MDS encoding is ``a = G @ c`` with tiny ``G`` (N x m, m <= 64) against a wide
payload ``c`` (m, L) -- and decode-apply is the same shape with the inverted
subset matrix.  The generator stays VMEM-resident while the payload streams
through in column blocks; each grid step does one (N, m) x (m, block_l)
complex matmul = 4 real MXU matmuls.

``bcmatmul`` is the per-request variant the batched service decode uses:
every request in a bucket carries its OWN (m, N) decode matrix (selected by
its straggler mask, DESIGN.md §6), so the contraction is a batched
``(q, m, N) @ (q, N, L)`` with the q axis blocked across the grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cmatmul", "cmatmul_body", "bcmatmul", "bcmatmul_body"]


def cmatmul_body(ar, ai, br, bi):
    """One complex matmul block: 4 real MXU matmuls, f32 accumulation."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def _kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref):
    cr_ref[...], ci_ref[...] = cmatmul_body(
        ar_ref[...], ai_ref[...], br_ref[...], bi_ref[...])


def cmatmul(ar, ai, br, bi, *, block_l: int = 512, interpret: bool = False):
    """Planar complex matmul: (M, K) @ (K, L) -> (M, L), blocked over L.

    Shapes follow the MDS-coding use case: M, K small (codes), L large
    (payload columns).  Returns (cr, ci).
    """
    m, k = ar.shape
    k2, ell = br.shape
    assert k == k2, (ar.shape, br.shape)
    block_l = min(block_l, ell)
    grid = (pl.cdiv(ell, block_l),)
    spec_a = pl.BlockSpec((m, k), lambda j: (0, 0))
    spec_b = pl.BlockSpec((k, block_l), lambda j: (0, j))
    spec_c = pl.BlockSpec((m, block_l), lambda j: (0, j))
    out_shape = [
        jax.ShapeDtypeStruct((m, ell), ar.dtype),
        jax.ShapeDtypeStruct((m, ell), ar.dtype),
    ]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec_a, spec_a, spec_b, spec_b],
        out_specs=[spec_c, spec_c],
        out_shape=out_shape,
        interpret=interpret,
        name="cmatmul",
    )(ar, ai, br, bi)


def bcmatmul_body(ar, ai, br, bi):
    """One batched complex matmul block: per-element left matrices."""
    dot = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def _bkernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref):
    cr_ref[...], ci_ref[...] = bcmatmul_body(
        ar_ref[...], ai_ref[...], br_ref[...], bi_ref[...])


def bcmatmul(ar, ai, br, bi, *, block_q: int = 1, block_l: int = 512,
             interpret: bool = False):
    """Batched planar complex matmul: (q, M, K) @ (q, K, L) -> (q, M, L).

    Per-element left matrices (the decode-matrix use case: one (m, N)
    scatter-inverse per request).  Blocked over the batch q and the payload
    columns L; the ops layer collapses both blocks in interpret mode.
    """
    q, m, k = ar.shape
    q2, k2, ell = br.shape
    assert (q, k) == (q2, k2), (ar.shape, br.shape)
    block_l = min(block_l, ell)
    block_q = max(1, min(block_q, q))
    grid = (pl.cdiv(q, block_q), pl.cdiv(ell, block_l))
    spec_a = pl.BlockSpec((block_q, m, k), lambda i, j: (i, 0, 0))
    spec_b = pl.BlockSpec((block_q, k, block_l), lambda i, j: (i, 0, j))
    spec_c = pl.BlockSpec((block_q, m, block_l), lambda i, j: (i, 0, j))
    out_shape = [
        jax.ShapeDtypeStruct((q, m, ell), ar.dtype),
        jax.ShapeDtypeStruct((q, m, ell), ar.dtype),
    ]
    return pl.pallas_call(
        _bkernel,
        grid=grid,
        in_specs=[spec_a, spec_a, spec_b, spec_b],
        out_specs=[spec_c, spec_c],
        out_shape=out_shape,
        interpret=interpret,
        name="bcmatmul",
    )(ar, ai, br, bi)
