"""Pallas TPU kernel: planar complex matmul (MDS encode / decode-apply).

MDS encoding is ``a = G @ c`` with tiny ``G`` (N x m, m <= 64) against a wide
payload ``c`` (m, L) -- and decode-apply is the same shape with the inverted
subset matrix.  The generator stays VMEM-resident while the payload streams
through in column blocks; each grid step does one (N, m) x (m, block_l)
complex matmul = 4 real MXU matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cmatmul"]


def _kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref):
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    cr_ref[...] = dot(ar, br) - dot(ai, bi)
    ci_ref[...] = dot(ar, bi) + dot(ai, br)


def cmatmul(ar, ai, br, bi, *, block_l: int = 512, interpret: bool = False):
    """Planar complex matmul: (M, K) @ (K, L) -> (M, L), blocked over L.

    Shapes follow the MDS-coding use case: M, K small (codes), L large
    (payload columns).  Returns (cr, ci).
    """
    m, k = ar.shape
    k2, ell = br.shape
    assert k == k2, (ar.shape, br.shape)
    block_l = min(block_l, ell)
    grid = (pl.cdiv(ell, block_l),)
    spec_a = pl.BlockSpec((m, k), lambda j: (0, 0))
    spec_b = pl.BlockSpec((k, block_l), lambda j: (0, j))
    spec_c = pl.BlockSpec((m, block_l), lambda j: (0, j))
    out_shape = [
        jax.ShapeDtypeStruct((m, ell), ar.dtype),
        jax.ShapeDtypeStruct((m, ell), ar.dtype),
    ]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec_a, spec_a, spec_b, spec_b],
        out_specs=[spec_c, spec_c],
        out_shape=out_shape,
        interpret=interpret,
        name="cmatmul",
    )(ar, ai, br, bi)
