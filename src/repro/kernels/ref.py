"""Pure-jnp oracles for every Pallas kernel in this package.

All kernels operate on *planar* complex data (separate real/imag f32 planes)
because Pallas TPU has no complex dtype; the oracles accept/return the same
planar layout so tests compare apples to apples.  Each oracle also has a
``*_complex`` twin in natural complex dtype used by the core library tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "fourstep_fft_ref",
    "fft_ref_complex",
    "cmatmul_ref",
    "bcmatmul_ref",
    "encode_worker_ref",
    "recombine_ref",
    "recombine_batched_ref",
    "planar",
    "unplanar",
]


def planar(z: jax.Array, dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
    return jnp.real(z).astype(dtype), jnp.imag(z).astype(dtype)


def unplanar(re: jax.Array, im: jax.Array) -> jax.Array:
    return re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64)


def fft_ref_complex(x: jax.Array) -> jax.Array:
    """Ground-truth FFT along the last axis."""
    return jnp.fft.fft(x, axis=-1)


def fourstep_fft_ref(
    xr: jax.Array, xi: jax.Array, a: int, b: int
) -> tuple[jax.Array, jax.Array]:
    """Four-step FFT oracle on planar data.

    ``xr, xi``: (batch, L) with L = a*b.  Returns planar (batch, L) FFT.
    Implemented with jnp.fft on the complexified input -- the oracle is the
    *mathematical answer*, independent of the four-step factorization.
    """
    z = unplanar(xr, xi)
    out = jnp.fft.fft(z, axis=-1)
    return planar(out, xr.dtype)


def cmatmul_ref(
    ar: jax.Array, ai: jax.Array, br: jax.Array, bi: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Planar complex matmul oracle: (M, K) @ (K, N)."""
    cr = ar @ br - ai @ bi
    ci = ar @ bi + ai @ br
    return cr, ci


def bcmatmul_ref(
    ar: jax.Array, ai: jax.Array, br: jax.Array, bi: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Batched planar complex matmul oracle: (q, M, K) @ (q, K, L)."""
    cr = jnp.einsum("qmk,qkl->qml", ar, br) - jnp.einsum("qmk,qkl->qml", ai, bi)
    ci = jnp.einsum("qmk,qkl->qml", ar, bi) + jnp.einsum("qmk,qkl->qml", ai, br)
    return cr, ci


def encode_worker_ref(
    cr: jax.Array, ci: jax.Array, g: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Fused encode+worker oracle: message planes -> coded worker spectra.

    ``cr, ci``: (q, m, L) planes; ``g``: (n, m) complex generator.  The
    mathematical answer -- encode with G then FFT each coded shard --
    computed in natural complex arithmetic, independent of the kernel's
    stage ordering and four-step factorization.
    """
    c = unplanar(cr, ci)
    a = jnp.einsum("nm,qml->qnl", g.astype(c.dtype), c)
    return planar(jnp.fft.fft(a, axis=-1), cr.dtype)


def recombine_ref(
    cr: jax.Array,
    ci: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    fr: jax.Array,
    fi: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Fused twiddle+DFT oracle: ``F @ (C * W)`` on planar (m, L) data."""
    tr = cr * wr - ci * wi
    ti = cr * wi + ci * wr
    outr = fr @ tr - fi @ ti
    outi = fr @ ti + fi @ tr
    return outr, outi


def recombine_batched_ref(
    cr: jax.Array,
    ci: jax.Array,
    wr: jax.Array,
    wi: jax.Array,
    fr: jax.Array,
    fi: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Batched twiddle+DFT oracle on planar (q, m, L) data."""
    tr = cr * wr[None] - ci * wi[None]
    ti = cr * wi[None] + ci * wr[None]
    outr = jnp.einsum("jm,qml->qjl", fr, tr) - jnp.einsum("jm,qml->qjl", fi, ti)
    outi = jnp.einsum("jm,qml->qjl", fr, ti) + jnp.einsum("jm,qml->qjl", fi, tr)
    return outr, outi
