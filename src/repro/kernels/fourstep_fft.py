"""Pallas TPU kernels: batched four-step (Bailey) FFT + fused MDS encode.

The per-worker hot loop of coded FFT is a length-L DFT of the worker's coded
shard (paper §III-B step 3).  On TPU we do NOT port a butterfly-network FFT
(a GPU/CPU idiom that starves the MXU); instead we factor ``L = A * B`` and
compute

    out[c, d] = ( (F_A @ M) * W ) @ F_B,     M[a, b] = x[a*B + b]
    X[c + d*A] = out[c, d]

i.e. two dense DFT-matrix matmuls (MXU work) plus one elementwise twiddle
(VPU work).  Complex arithmetic is planar: separate f32 real/imag planes,
4-real-matmul complex products with f32 accumulation.

Every kernel here blocks over the BATCH as well (``block_q`` elements per
grid step) with the batch block folded into the matmul row/column dims, so
one grid step issues the same two big MXU contractions regardless of
``block_q``.  Off-TPU (interpret mode) the ops-layer collapses the whole
batch into one grid step, which lowers to plain XLA matmuls with no
per-element loop — that is what makes the kernel path the *default* engine
rather than a TPU-only demo (DESIGN.md §6).

Kernels:

* ``fourstep_fused`` — whole (A, B) matrix per element resident in VMEM.
  VMEM footprint ~ 2*(bq*A*B + A*A + B*B + A*B) * 4 bytes.
* ``fourstep_stage1 / fourstep_stage2`` two-pass — stage 1 blocks over
  B-columns (column DFT + twiddle are column-local), stage 2 blocks over
  A-rows (row DFT is row-local); supports sizes whose full matrix would
  not fit VMEM.
* ``encode_fourstep_fused`` — the coded-FFT stage-1 fusion: the MDS encode
  ``a = G @ c`` is itself a (roots-of-unity) matmul across the shard axis
  and commutes with the per-shard DFT, so the kernel transforms the ``m``
  MESSAGE shards (not the ``N`` coded ones — an N/m flop saving) and
  applies the generator contraction in VMEM.  Coded shards never
  round-trip through HBM between encode and worker compute.
* ``multistep_fused`` — the mixed-radix generalization: ``L = f1 * ... * fk``
  with one dense-DFT matmul + twiddle per factor.  Flops per element scale
  with ``sum(f_i)`` instead of ``A + B = 2*sqrt(L)``, so deeper plans win at
  large L; the autotuner picks the plan per backend (autotune.py).
* ``fourstep_streaming`` — one-launch four-step for shapes whose full
  (A, B) matrix exceeds VMEM: the kernel keeps x/out/t1 in HBM (ANY memory
  space) and hand-rolls double-buffered DMA over column tiles (stage 1+2)
  then row tiles (stage 3), staging tile k+1 while tile k computes.  The
  output is written in NATURAL order (batch, B, A) via an in-VMEM tile
  transpose, so no XLA unscramble pass follows.

The jit wrappers with layout pack/unpack live in ops.py; the jnp oracles in
ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "fourstep_body",
    "fourstep_fused",
    "stage1_body",
    "stage2_body",
    "fourstep_stage1",
    "fourstep_stage2",
    "encode_fourstep_body",
    "encode_fourstep_fused",
    "multistep_body",
    "multistep_fused",
    "fourstep_streaming",
]


def _cmul_mm(ar, ai, br, bi):
    """Complex matmul on planes with f32 accumulation (4 real matmuls)."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def fourstep_body(xr, xi, far, fai, wr, wi, fbr, fbi):
    """The four-step math on one (bq, A, B) block: ((F_A @ M) * W) @ F_B.

    Shared between the Pallas kernel (one block per grid step) and the
    off-TPU direct path, which evaluates the body on the full batch as
    straight XLA (DESIGN.md §6).  The batch block is folded into the
    contraction dims (columns for stage 1, rows for stage 3), so the MXU
    sees two dense matmuls per call for any bq.
    """
    bq, a, b = xr.shape
    # step 1: column DFTs -- contract A with the batch folded into columns
    mr = jnp.transpose(xr, (1, 0, 2)).reshape(a, bq * b)
    mi = jnp.transpose(xi, (1, 0, 2)).reshape(a, bq * b)
    t1r, t1i = _cmul_mm(far, fai, mr, mi)
    t1r = t1r.reshape(a, bq, b)
    t1i = t1i.reshape(a, bq, b)
    # step 2: twiddle (elementwise, VPU), broadcast over the batch block
    wr = wr[:, None, :]
    wi = wi[:, None, :]
    t2r = t1r * wr - t1i * wi
    t2i = t1r * wi + t1i * wr
    # step 3: row DFTs -- contract B with the batch folded into rows
    rr = jnp.transpose(t2r, (1, 0, 2)).reshape(bq * a, b)
    ri = jnp.transpose(t2i, (1, 0, 2)).reshape(bq * a, b)
    t3r, t3i = _cmul_mm(rr, ri, fbr, fbi)
    return t3r.reshape(bq, a, b), t3i.reshape(bq, a, b)


def _fused_kernel(xr_ref, xi_ref, far_ref, fai_ref, wr_ref, wi_ref,
                  fbr_ref, fbi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = fourstep_body(
        xr_ref[...], xi_ref[...], far_ref[...], fai_ref[...],
        wr_ref[...], wi_ref[...], fbr_ref[...], fbi_ref[...])


def fourstep_fused(xr, xi, far, fai, wr, wi, fbr, fbi, *, block_q: int = 1,
                   interpret=False):
    """Batched fused four-step FFT.

    ``xr, xi``: (batch, A, B) planes of M[a,b] = x[a*B+b].
    Returns planes of out[c, d] with X[c + d*A] = out[c, d].
    ``block_q`` batch elements are processed per grid step (the ops layer
    collapses the grid entirely in interpret mode).
    """
    batch, a, b = xr.shape
    block_q = max(1, min(block_q, batch))
    spec_x = pl.BlockSpec((block_q, a, b), lambda i: (i, 0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
    ]
    return pl.pallas_call(
        _fused_kernel,
        grid=(pl.cdiv(batch, block_q),),
        in_specs=[spec_x, spec_x, spec_fa, spec_fa, spec_w, spec_w, spec_fb, spec_fb],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_fused",
    )(xr, xi, far, fai, wr, wi, fbr, fbi)


def encode_fourstep_body(cr, ci, gr, gi, far, fai, wr, wi, fbr, fbi):
    """Fused MDS-encode + four-step worker DFT on MESSAGE shards.

    ``c`` block: (bq, m, A, B) message planes; ``g``: (n, m) generator
    planes.  The DFT stages act per shard and the generator contraction
    acts across shards, so they commute: transforming the m message shards
    first saves an N/m factor of DFT flops, and the encode is one more
    (n, m) x (m, bq*A*B) MXU matmul on VMEM-resident data.
    """
    bq, m, a, b = cr.shape
    n = gr.shape[0]
    # stage 1: column DFTs of every message shard -- contract A
    mr = jnp.transpose(cr, (2, 0, 1, 3)).reshape(a, bq * m * b)
    mi = jnp.transpose(ci, (2, 0, 1, 3)).reshape(a, bq * m * b)
    t1r, t1i = _cmul_mm(far, fai, mr, mi)
    t1r = t1r.reshape(a, bq, m, b)
    t1i = t1i.reshape(a, bq, m, b)
    # stage 2: twiddle, shared across batch and shard index
    wr = wr[:, None, None, :]
    wi = wi[:, None, None, :]
    t2r = t1r * wr - t1i * wi
    t2i = t1r * wi + t1i * wr
    # stage 3: row DFTs -- contract B ((a, bq, m, b) rows are contiguous)
    t3r, t3i = _cmul_mm(t2r.reshape(-1, b), t2i.reshape(-1, b), fbr, fbi)
    # stage 4: MDS encode -- contract the shard axis m with G
    t3r = t3r.reshape(a, bq, m, b).transpose(2, 1, 0, 3).reshape(m, -1)
    t3i = t3i.reshape(a, bq, m, b).transpose(2, 1, 0, 3).reshape(m, -1)
    er, ei = _cmul_mm(gr, gi, t3r, t3i)
    return (er.reshape(n, bq, a, b).transpose(1, 0, 2, 3),
            ei.reshape(n, bq, a, b).transpose(1, 0, 2, 3))


def _encode_fused_kernel(cr_ref, ci_ref, gr_ref, gi_ref, far_ref, fai_ref,
                         wr_ref, wi_ref, fbr_ref, fbi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = encode_fourstep_body(
        cr_ref[...], ci_ref[...], gr_ref[...], gi_ref[...],
        far_ref[...], fai_ref[...], wr_ref[...], wi_ref[...],
        fbr_ref[...], fbi_ref[...])


def encode_fourstep_fused(cr, ci, gr, gi, far, fai, wr, wi, fbr, fbi, *,
                          block_q: int = 1, interpret=False):
    """Fused encode + worker DFT: message planes -> coded worker spectra.

    ``cr, ci``: (batch, m, A, B) planes of the m message shards,
    M_i[a, b] = c_i[a*B+b]; ``gr, gi``: (n, m) generator planes.
    Returns (batch, n, A, B) planes of out[k, c, d] with
    ``B_k[c + d*A] = out[k, c, d]`` -- the same scrambled four-step order
    as :func:`fourstep_fused`, unscrambled by the ops layer.
    """
    batch, m, a, b = cr.shape
    n = gr.shape[0]
    block_q = max(1, min(block_q, batch))
    spec_c = pl.BlockSpec((block_q, m, a, b), lambda i: (i, 0, 0, 0))
    spec_g = pl.BlockSpec((n, m), lambda i: (0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    spec_o = pl.BlockSpec((block_q, n, a, b), lambda i: (i, 0, 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((batch, n, a, b), cr.dtype),
        jax.ShapeDtypeStruct((batch, n, a, b), cr.dtype),
    ]
    return pl.pallas_call(
        _encode_fused_kernel,
        grid=(pl.cdiv(batch, block_q),),
        in_specs=[spec_c, spec_c, spec_g, spec_g, spec_fa, spec_fa,
                  spec_w, spec_w, spec_fb, spec_fb],
        out_specs=[spec_o, spec_o],
        out_shape=out_shape,
        interpret=interpret,
        name="encode_fourstep_fused",
    )(cr, ci, gr, gi, far, fai, wr, wi, fbr, fbi)


def stage1_body(xr, xi, far, fai, wr, wi):
    """Column-blocked: out = (F_A @ M_block) * W_block, batch folded in."""
    bq, a, bb = xr.shape
    mr = jnp.transpose(xr, (1, 0, 2)).reshape(a, bq * bb)
    mi = jnp.transpose(xi, (1, 0, 2)).reshape(a, bq * bb)
    t1r, t1i = _cmul_mm(far, fai, mr, mi)
    t1r = t1r.reshape(a, bq, bb)
    t1i = t1i.reshape(a, bq, bb)
    wr = wr[:, None, :]
    wi = wi[:, None, :]
    return (jnp.transpose(t1r * wr - t1i * wi, (1, 0, 2)),
            jnp.transpose(t1r * wi + t1i * wr, (1, 0, 2)))


def _stage1_kernel(xr_ref, xi_ref, far_ref, fai_ref, wr_ref, wi_ref,
                   or_ref, oi_ref):
    or_ref[...], oi_ref[...] = stage1_body(
        xr_ref[...], xi_ref[...], far_ref[...], fai_ref[...],
        wr_ref[...], wi_ref[...])


def fourstep_stage1(xr, xi, far, fai, wr, wi, *, block_q: int = 1,
                    block_b=256, interpret=False):
    """Stage 1+2 of the four-step FFT, blocked over columns of B."""
    batch, a, b = xr.shape
    block_b = min(block_b, b)
    block_q = max(1, min(block_q, batch))
    grid = (pl.cdiv(batch, block_q), pl.cdiv(b, block_b))
    spec_x = pl.BlockSpec((block_q, a, block_b), lambda i, j: (i, 0, j))
    spec_fa = pl.BlockSpec((a, a), lambda i, j: (0, 0))
    spec_w = pl.BlockSpec((a, block_b), lambda i, j: (0, j))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
    ]
    return pl.pallas_call(
        _stage1_kernel,
        grid=grid,
        in_specs=[spec_x, spec_x, spec_fa, spec_fa, spec_w, spec_w],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_stage1",
    )(xr, xi, far, fai, wr, wi)


def stage2_body(tr, ti, fbr, fbi):
    """Row-blocked: out = T_block @ F_B, batch folded into the rows."""
    bq, ba, b = tr.shape
    t3r, t3i = _cmul_mm(tr.reshape(bq * ba, b), ti.reshape(bq * ba, b),
                        fbr, fbi)
    return t3r.reshape(bq, ba, b), t3i.reshape(bq, ba, b)


def _stage2_kernel(tr_ref, ti_ref, fbr_ref, fbi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = stage2_body(
        tr_ref[...], ti_ref[...], fbr_ref[...], fbi_ref[...])


def fourstep_stage2(tr, ti, fbr, fbi, *, block_q: int = 1, block_a=256,
                    interpret=False):
    """Stage 3 of the four-step FFT, blocked over rows of A."""
    batch, a, b = tr.shape
    block_a = min(block_a, a)
    block_q = max(1, min(block_q, batch))
    grid = (pl.cdiv(batch, block_q), pl.cdiv(a, block_a))
    spec_t = pl.BlockSpec((block_q, block_a, b), lambda i, j: (i, j, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i, j: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), tr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), tr.dtype),
    ]
    return pl.pallas_call(
        _stage2_kernel,
        grid=grid,
        in_specs=[spec_t, spec_t, spec_fb, spec_fb],
        out_specs=[spec_t, spec_t],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_stage2",
    )(tr, ti, fbr, fbi)


# --------------------------------------------------------------------------
# mixed-radix (multistep) four-step
# --------------------------------------------------------------------------
def _parse_stage_planes(factors, planes):
    """Group the flat plane list into per-stage (fr, fi, twr, twi) tuples.

    The flat order is per stage: DFT planes (f, f), then — for every stage
    but the last, whose ``rest`` is 1 and whose twiddle is identically
    one — twiddle planes (f, rest).
    """
    stages = []
    idx = 0
    for i, _ in enumerate(factors):
        fr, fi = planes[idx], planes[idx + 1]
        idx += 2
        twr = twi = None
        if i + 1 < len(factors):
            twr, twi = planes[idx], planes[idx + 1]
            idx += 2
        stages.append((fr, fi, twr, twi))
    return stages


def multistep_body(xr, xi, stages):
    """Mixed-radix four-step on one (bq, L) block.

    ``stages``: per-factor (fr, fi, twr, twi) planes from
    :func:`_parse_stage_planes`; ``fr`` is the dense (f, f) DFT matrix and
    ``twr`` the (f, rest) twiddle (None on the last stage).  Each stage is
    the classic four-step stage 1 applied recursively: split the remaining
    length as ``f * rest``, contract ``f`` with one dense matmul (batch and
    already-processed digits folded into the columns), twiddle, and push the
    new digit onto the lead axis.  After all k stages the result is the
    scrambled spectrum with digit order (bq, c1, ..., ck) and
    ``X[c1 + f1*c2 + f1*f2*c3 + ...]`` — for two factors this is exactly
    :func:`fourstep_body`'s ``out[c, d] = X[c + d*A]``.  The ops layer
    unscrambles with one reversed-axes transpose.
    """
    bq, total = xr.shape
    lead = bq
    tr, ti = xr, xi
    for fr, fi, twr, twi in stages:
        f = fr.shape[0]
        rest = total // f
        mr = tr.reshape(lead, f, rest).transpose(1, 0, 2).reshape(f, lead * rest)
        mi = ti.reshape(lead, f, rest).transpose(1, 0, 2).reshape(f, lead * rest)
        t1r, t1i = _cmul_mm(fr, fi, mr, mi)
        t1r = t1r.reshape(f, lead, rest)
        t1i = t1i.reshape(f, lead, rest)
        if twr is not None:
            wr_ = twr[:, None, :]
            wi_ = twi[:, None, :]
            t1r, t1i = t1r * wr_ - t1i * wi_, t1r * wi_ + t1i * wr_
        tr = t1r.transpose(1, 0, 2).reshape(lead * f, rest)
        ti = t1i.transpose(1, 0, 2).reshape(lead * f, rest)
        lead *= f
        total = rest
    return tr.reshape(bq, -1), ti.reshape(bq, -1)


def _multistep_kernel(factors, *refs):
    n_planes = 4 * len(factors) - 2
    xr_ref, xi_ref = refs[:2]
    plane_refs = refs[2:2 + n_planes]
    or_ref, oi_ref = refs[2 + n_planes:]
    stages = _parse_stage_planes(factors, [r[...] for r in plane_refs])
    or_ref[...], oi_ref[...] = multistep_body(xr_ref[...], xi_ref[...], stages)


def multistep_fused(xr, xi, planes, factors, *, block_q: int = 1,
                    interpret=False):
    """Batched mixed-radix four-step FFT (one launch, k dense stages).

    ``xr, xi``: (batch, L) planes of x in natural order; ``planes``: flat
    per-stage DFT/twiddle planes (see :func:`_parse_stage_planes`);
    ``factors``: the radix plan with ``prod(factors) == L``.  Returns
    (batch, L) planes in the multistep scrambled digit order.
    """
    batch, ell = xr.shape
    block_q = max(1, min(block_q, batch))
    spec_x = pl.BlockSpec((block_q, ell), lambda i: (i, 0))
    in_specs = [spec_x, spec_x]
    for p in planes:
        in_specs.append(
            pl.BlockSpec(p.shape, lambda i, r=p.ndim: (0,) * r))
    out_shape = [
        jax.ShapeDtypeStruct((batch, ell), xr.dtype),
        jax.ShapeDtypeStruct((batch, ell), xr.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_multistep_kernel, tuple(factors)),
        grid=(pl.cdiv(batch, block_q),),
        in_specs=in_specs,
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_multistep",
    )(xr, xi, *planes)


# --------------------------------------------------------------------------
# streaming four-step: one launch with double-buffered HBM<->VMEM DMA
# --------------------------------------------------------------------------
def _streaming_kernel(nbt, nat, block_q, block_a, block_b,
                      xr_hbm, xi_hbm, far_ref, fai_ref, wr_ref, wi_ref,
                      fbr_ref, fbi_ref,
                      or_hbm, oi_hbm, t1r_hbm, t1i_hbm,
                      abr, abi, t1s_r, t1s_i, bbr, bbi, obr, obi,
                      sem_a, sem_t1, sem_b, sem_o):
    """Two sequential phases inside ONE kernel launch.

    Phase A walks B-column tiles (stage 1 + twiddle are column-local):
    DMA x tile in, compute, DMA the t1 tile out to an HBM scratch.  Phase B
    walks A-row tiles (stage 3 is row-local): DMA t1 tile in, contract F_B,
    transpose the tile in VMEM and DMA it to the NATURAL-order output
    (batch, B, A).  Input DMAs are double-buffered — tile k+1 streams while
    tile k computes; the (smaller) result write-backs block, which keeps a
    single staging buffer per phase and still hides the dominant read
    latency.  Phase B only starts after every phase-A write-back has waited,
    so the t1 scratch is consistent without an explicit barrier.
    """
    q0 = pl.program_id(0) * block_q

    def a_copies(j, slot):
        cols = pl.ds(j * block_b, block_b)
        return (
            pltpu.make_async_copy(
                xr_hbm.at[pl.ds(q0, block_q), :, cols], abr.at[slot],
                sem_a.at[slot, 0]),
            pltpu.make_async_copy(
                xi_hbm.at[pl.ds(q0, block_q), :, cols], abi.at[slot],
                sem_a.at[slot, 1]),
        )

    for c in a_copies(0, 0):
        c.start()
    far = far_ref[...]
    fai = fai_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]

    def phase_a(j, carry):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < nbt)
        def _():
            for c in a_copies(j + 1, jax.lax.rem(j + 1, 2)):
                c.start()

        for c in a_copies(j, slot):
            c.wait()
        tr, ti = stage1_body(
            abr[slot], abi[slot], far, fai,
            jax.lax.dynamic_slice_in_dim(wr, j * block_b, block_b, 1),
            jax.lax.dynamic_slice_in_dim(wi, j * block_b, block_b, 1))
        t1s_r[...] = tr
        t1s_i[...] = ti
        cols = pl.ds(j * block_b, block_b)
        outs = (
            pltpu.make_async_copy(
                t1s_r, t1r_hbm.at[pl.ds(q0, block_q), :, cols],
                sem_t1.at[0]),
            pltpu.make_async_copy(
                t1s_i, t1i_hbm.at[pl.ds(q0, block_q), :, cols],
                sem_t1.at[1]),
        )
        for c in outs:
            c.start()
        for c in outs:
            c.wait()
        return carry

    jax.lax.fori_loop(0, nbt, phase_a, 0)

    def b_copies(i, slot):
        rows = pl.ds(i * block_a, block_a)
        return (
            pltpu.make_async_copy(
                t1r_hbm.at[pl.ds(q0, block_q), rows, :], bbr.at[slot],
                sem_b.at[slot, 0]),
            pltpu.make_async_copy(
                t1i_hbm.at[pl.ds(q0, block_q), rows, :], bbi.at[slot],
                sem_b.at[slot, 1]),
        )

    for c in b_copies(0, 0):
        c.start()
    fbr = fbr_ref[...]
    fbi = fbi_ref[...]

    def phase_b(i, carry):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < nat)
        def _():
            for c in b_copies(i + 1, jax.lax.rem(i + 1, 2)):
                c.start()

        for c in b_copies(i, slot):
            c.wait()
        t3r, t3i = stage2_body(bbr[slot], bbi[slot], fbr, fbi)
        # out[c, d] = X[c + d*A]: tile rows are c's, so the transposed tile
        # lands at output[:, :, c-tile] of the natural (batch, B, A) layout.
        obr[...] = jnp.transpose(t3r, (0, 2, 1))
        obi[...] = jnp.transpose(t3i, (0, 2, 1))
        cols = pl.ds(i * block_a, block_a)
        outs = (
            pltpu.make_async_copy(
                obr, or_hbm.at[pl.ds(q0, block_q), :, cols], sem_o.at[0]),
            pltpu.make_async_copy(
                obi, oi_hbm.at[pl.ds(q0, block_q), :, cols], sem_o.at[1]),
        )
        for c in outs:
            c.start()
        for c in outs:
            c.wait()
        return carry

    jax.lax.fori_loop(0, nat, phase_b, 0)


def _even_divisor(n: int, cap: int) -> int:
    d = max(1, min(cap, n))
    while n % d:
        d -= 1
    return d


def fourstep_streaming(xr, xi, far, fai, wr, wi, fbr, fbi, *,
                       block_q: int = 1, block_a: int = 256,
                       block_b: int = 256, interpret=False):
    """One-launch four-step FFT for shapes exceeding the VMEM budget.

    Same plane inputs as :func:`fourstep_fused` but x/out/t1 stay in HBM;
    only (block_q, A, block_b) / (block_q, block_a, B) tiles are VMEM
    resident at a time (x2 for double buffering).  Returns (batch, B, A)
    planes in NATURAL order — ``out[:, d, c] = X[d*A + c]`` — so callers
    reshape (free) instead of transposing.
    """
    batch, a, b = xr.shape
    block_q = max(1, min(block_q, batch))
    pad = (-batch) % block_q
    if pad:  # DMA tile sizes are static: round the batch up
        z = jnp.zeros((pad, a, b), xr.dtype)
        xr = jnp.concatenate([xr, z])
        xi = jnp.concatenate([xi, z])
    batchp = batch + pad
    block_a = _even_divisor(a, block_a)
    block_b = _even_divisor(b, block_b)
    nat = a // block_a
    nbt = b // block_b
    f32 = xr.dtype

    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    def vspec(*shape):
        return pl.BlockSpec(shape, lambda i, r=len(shape): (0,) * r)

    out_shape = [
        jax.ShapeDtypeStruct((batchp, b, a), f32),   # natural-order output
        jax.ShapeDtypeStruct((batchp, b, a), f32),
        jax.ShapeDtypeStruct((batchp, a, b), f32),   # t1 HBM scratch
        jax.ShapeDtypeStruct((batchp, a, b), f32),
    ]
    scratch = [
        pltpu.VMEM((2, block_q, a, block_b), f32),   # phase A in (x2 slots)
        pltpu.VMEM((2, block_q, a, block_b), f32),
        pltpu.VMEM((block_q, a, block_b), f32),      # phase A out staging
        pltpu.VMEM((block_q, a, block_b), f32),
        pltpu.VMEM((2, block_q, block_a, b), f32),   # phase B in (x2 slots)
        pltpu.VMEM((2, block_q, block_a, b), f32),
        pltpu.VMEM((block_q, b, block_a), f32),      # phase B out staging
        pltpu.VMEM((block_q, b, block_a), f32),
        pltpu.SemaphoreType.DMA((2, 2)),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2, 2)),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    outs = pl.pallas_call(
        functools.partial(_streaming_kernel, nbt, nat, block_q, block_a,
                          block_b),
        grid=(batchp // block_q,),
        in_specs=[any_spec, any_spec, vspec(a, a), vspec(a, a),
                  vspec(a, b), vspec(a, b), vspec(b, b), vspec(b, b)],
        out_specs=[any_spec, any_spec, any_spec, any_spec],
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
        name="fourstep_fft_streaming",
    )(xr, xi, far, fai, wr, wi, fbr, fbi)
    return outs[0][:batch], outs[1][:batch]
