"""Pallas TPU kernels: batched four-step (Bailey) FFT + fused MDS encode.

The per-worker hot loop of coded FFT is a length-L DFT of the worker's coded
shard (paper §III-B step 3).  On TPU we do NOT port a butterfly-network FFT
(a GPU/CPU idiom that starves the MXU); instead we factor ``L = A * B`` and
compute

    out[c, d] = ( (F_A @ M) * W ) @ F_B,     M[a, b] = x[a*B + b]
    X[c + d*A] = out[c, d]

i.e. two dense DFT-matrix matmuls (MXU work) plus one elementwise twiddle
(VPU work).  Complex arithmetic is planar: separate f32 real/imag planes,
4-real-matmul complex products with f32 accumulation.

Every kernel here blocks over the BATCH as well (``block_q`` elements per
grid step) with the batch block folded into the matmul row/column dims, so
one grid step issues the same two big MXU contractions regardless of
``block_q``.  Off-TPU (interpret mode) the ops-layer collapses the whole
batch into one grid step, which lowers to plain XLA matmuls with no
per-element loop — that is what makes the kernel path the *default* engine
rather than a TPU-only demo (DESIGN.md §6).

Kernels:

* ``fourstep_fused`` — whole (A, B) matrix per element resident in VMEM.
  VMEM footprint ~ 2*(bq*A*B + A*A + B*B + A*B) * 4 bytes.
* ``fourstep_stage1 / fourstep_stage2`` two-pass — stage 1 blocks over
  B-columns (column DFT + twiddle are column-local), stage 2 blocks over
  A-rows (row DFT is row-local); supports sizes whose full matrix would
  not fit VMEM.
* ``encode_fourstep_fused`` — the coded-FFT stage-1 fusion: the MDS encode
  ``a = G @ c`` is itself a (roots-of-unity) matmul across the shard axis
  and commutes with the per-shard DFT, so the kernel transforms the ``m``
  MESSAGE shards (not the ``N`` coded ones — an N/m flop saving) and
  applies the generator contraction in VMEM.  Coded shards never
  round-trip through HBM between encode and worker compute.

The jit wrappers with layout pack/unpack live in ops.py; the jnp oracles in
ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "fourstep_body",
    "fourstep_fused",
    "stage1_body",
    "stage2_body",
    "fourstep_stage1",
    "fourstep_stage2",
    "encode_fourstep_body",
    "encode_fourstep_fused",
]


def _cmul_mm(ar, ai, br, bi):
    """Complex matmul on planes with f32 accumulation (4 real matmuls)."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def fourstep_body(xr, xi, far, fai, wr, wi, fbr, fbi):
    """The four-step math on one (bq, A, B) block: ((F_A @ M) * W) @ F_B.

    Shared between the Pallas kernel (one block per grid step) and the
    off-TPU direct path, which evaluates the body on the full batch as
    straight XLA (DESIGN.md §6).  The batch block is folded into the
    contraction dims (columns for stage 1, rows for stage 3), so the MXU
    sees two dense matmuls per call for any bq.
    """
    bq, a, b = xr.shape
    # step 1: column DFTs -- contract A with the batch folded into columns
    mr = jnp.transpose(xr, (1, 0, 2)).reshape(a, bq * b)
    mi = jnp.transpose(xi, (1, 0, 2)).reshape(a, bq * b)
    t1r, t1i = _cmul_mm(far, fai, mr, mi)
    t1r = t1r.reshape(a, bq, b)
    t1i = t1i.reshape(a, bq, b)
    # step 2: twiddle (elementwise, VPU), broadcast over the batch block
    wr = wr[:, None, :]
    wi = wi[:, None, :]
    t2r = t1r * wr - t1i * wi
    t2i = t1r * wi + t1i * wr
    # step 3: row DFTs -- contract B with the batch folded into rows
    rr = jnp.transpose(t2r, (1, 0, 2)).reshape(bq * a, b)
    ri = jnp.transpose(t2i, (1, 0, 2)).reshape(bq * a, b)
    t3r, t3i = _cmul_mm(rr, ri, fbr, fbi)
    return t3r.reshape(bq, a, b), t3i.reshape(bq, a, b)


def _fused_kernel(xr_ref, xi_ref, far_ref, fai_ref, wr_ref, wi_ref,
                  fbr_ref, fbi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = fourstep_body(
        xr_ref[...], xi_ref[...], far_ref[...], fai_ref[...],
        wr_ref[...], wi_ref[...], fbr_ref[...], fbi_ref[...])


def fourstep_fused(xr, xi, far, fai, wr, wi, fbr, fbi, *, block_q: int = 1,
                   interpret=False):
    """Batched fused four-step FFT.

    ``xr, xi``: (batch, A, B) planes of M[a,b] = x[a*B+b].
    Returns planes of out[c, d] with X[c + d*A] = out[c, d].
    ``block_q`` batch elements are processed per grid step (the ops layer
    collapses the grid entirely in interpret mode).
    """
    batch, a, b = xr.shape
    block_q = max(1, min(block_q, batch))
    spec_x = pl.BlockSpec((block_q, a, b), lambda i: (i, 0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
    ]
    return pl.pallas_call(
        _fused_kernel,
        grid=(pl.cdiv(batch, block_q),),
        in_specs=[spec_x, spec_x, spec_fa, spec_fa, spec_w, spec_w, spec_fb, spec_fb],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_fused",
    )(xr, xi, far, fai, wr, wi, fbr, fbi)


def encode_fourstep_body(cr, ci, gr, gi, far, fai, wr, wi, fbr, fbi):
    """Fused MDS-encode + four-step worker DFT on MESSAGE shards.

    ``c`` block: (bq, m, A, B) message planes; ``g``: (n, m) generator
    planes.  The DFT stages act per shard and the generator contraction
    acts across shards, so they commute: transforming the m message shards
    first saves an N/m factor of DFT flops, and the encode is one more
    (n, m) x (m, bq*A*B) MXU matmul on VMEM-resident data.
    """
    bq, m, a, b = cr.shape
    n = gr.shape[0]
    # stage 1: column DFTs of every message shard -- contract A
    mr = jnp.transpose(cr, (2, 0, 1, 3)).reshape(a, bq * m * b)
    mi = jnp.transpose(ci, (2, 0, 1, 3)).reshape(a, bq * m * b)
    t1r, t1i = _cmul_mm(far, fai, mr, mi)
    t1r = t1r.reshape(a, bq, m, b)
    t1i = t1i.reshape(a, bq, m, b)
    # stage 2: twiddle, shared across batch and shard index
    wr = wr[:, None, None, :]
    wi = wi[:, None, None, :]
    t2r = t1r * wr - t1i * wi
    t2i = t1r * wi + t1i * wr
    # stage 3: row DFTs -- contract B ((a, bq, m, b) rows are contiguous)
    t3r, t3i = _cmul_mm(t2r.reshape(-1, b), t2i.reshape(-1, b), fbr, fbi)
    # stage 4: MDS encode -- contract the shard axis m with G
    t3r = t3r.reshape(a, bq, m, b).transpose(2, 1, 0, 3).reshape(m, -1)
    t3i = t3i.reshape(a, bq, m, b).transpose(2, 1, 0, 3).reshape(m, -1)
    er, ei = _cmul_mm(gr, gi, t3r, t3i)
    return (er.reshape(n, bq, a, b).transpose(1, 0, 2, 3),
            ei.reshape(n, bq, a, b).transpose(1, 0, 2, 3))


def _encode_fused_kernel(cr_ref, ci_ref, gr_ref, gi_ref, far_ref, fai_ref,
                         wr_ref, wi_ref, fbr_ref, fbi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = encode_fourstep_body(
        cr_ref[...], ci_ref[...], gr_ref[...], gi_ref[...],
        far_ref[...], fai_ref[...], wr_ref[...], wi_ref[...],
        fbr_ref[...], fbi_ref[...])


def encode_fourstep_fused(cr, ci, gr, gi, far, fai, wr, wi, fbr, fbi, *,
                          block_q: int = 1, interpret=False):
    """Fused encode + worker DFT: message planes -> coded worker spectra.

    ``cr, ci``: (batch, m, A, B) planes of the m message shards,
    M_i[a, b] = c_i[a*B+b]; ``gr, gi``: (n, m) generator planes.
    Returns (batch, n, A, B) planes of out[k, c, d] with
    ``B_k[c + d*A] = out[k, c, d]`` -- the same scrambled four-step order
    as :func:`fourstep_fused`, unscrambled by the ops layer.
    """
    batch, m, a, b = cr.shape
    n = gr.shape[0]
    block_q = max(1, min(block_q, batch))
    spec_c = pl.BlockSpec((block_q, m, a, b), lambda i: (i, 0, 0, 0))
    spec_g = pl.BlockSpec((n, m), lambda i: (0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    spec_o = pl.BlockSpec((block_q, n, a, b), lambda i: (i, 0, 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((batch, n, a, b), cr.dtype),
        jax.ShapeDtypeStruct((batch, n, a, b), cr.dtype),
    ]
    return pl.pallas_call(
        _encode_fused_kernel,
        grid=(pl.cdiv(batch, block_q),),
        in_specs=[spec_c, spec_c, spec_g, spec_g, spec_fa, spec_fa,
                  spec_w, spec_w, spec_fb, spec_fb],
        out_specs=[spec_o, spec_o],
        out_shape=out_shape,
        interpret=interpret,
        name="encode_fourstep_fused",
    )(cr, ci, gr, gi, far, fai, wr, wi, fbr, fbi)


def stage1_body(xr, xi, far, fai, wr, wi):
    """Column-blocked: out = (F_A @ M_block) * W_block, batch folded in."""
    bq, a, bb = xr.shape
    mr = jnp.transpose(xr, (1, 0, 2)).reshape(a, bq * bb)
    mi = jnp.transpose(xi, (1, 0, 2)).reshape(a, bq * bb)
    t1r, t1i = _cmul_mm(far, fai, mr, mi)
    t1r = t1r.reshape(a, bq, bb)
    t1i = t1i.reshape(a, bq, bb)
    wr = wr[:, None, :]
    wi = wi[:, None, :]
    return (jnp.transpose(t1r * wr - t1i * wi, (1, 0, 2)),
            jnp.transpose(t1r * wi + t1i * wr, (1, 0, 2)))


def _stage1_kernel(xr_ref, xi_ref, far_ref, fai_ref, wr_ref, wi_ref,
                   or_ref, oi_ref):
    or_ref[...], oi_ref[...] = stage1_body(
        xr_ref[...], xi_ref[...], far_ref[...], fai_ref[...],
        wr_ref[...], wi_ref[...])


def fourstep_stage1(xr, xi, far, fai, wr, wi, *, block_q: int = 1,
                    block_b=256, interpret=False):
    """Stage 1+2 of the four-step FFT, blocked over columns of B."""
    batch, a, b = xr.shape
    block_b = min(block_b, b)
    block_q = max(1, min(block_q, batch))
    grid = (pl.cdiv(batch, block_q), pl.cdiv(b, block_b))
    spec_x = pl.BlockSpec((block_q, a, block_b), lambda i, j: (i, 0, j))
    spec_fa = pl.BlockSpec((a, a), lambda i, j: (0, 0))
    spec_w = pl.BlockSpec((a, block_b), lambda i, j: (0, j))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
    ]
    return pl.pallas_call(
        _stage1_kernel,
        grid=grid,
        in_specs=[spec_x, spec_x, spec_fa, spec_fa, spec_w, spec_w],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_stage1",
    )(xr, xi, far, fai, wr, wi)


def stage2_body(tr, ti, fbr, fbi):
    """Row-blocked: out = T_block @ F_B, batch folded into the rows."""
    bq, ba, b = tr.shape
    t3r, t3i = _cmul_mm(tr.reshape(bq * ba, b), ti.reshape(bq * ba, b),
                        fbr, fbi)
    return t3r.reshape(bq, ba, b), t3i.reshape(bq, ba, b)


def _stage2_kernel(tr_ref, ti_ref, fbr_ref, fbi_ref, or_ref, oi_ref):
    or_ref[...], oi_ref[...] = stage2_body(
        tr_ref[...], ti_ref[...], fbr_ref[...], fbi_ref[...])


def fourstep_stage2(tr, ti, fbr, fbi, *, block_q: int = 1, block_a=256,
                    interpret=False):
    """Stage 3 of the four-step FFT, blocked over rows of A."""
    batch, a, b = tr.shape
    block_a = min(block_a, a)
    block_q = max(1, min(block_q, batch))
    grid = (pl.cdiv(batch, block_q), pl.cdiv(a, block_a))
    spec_t = pl.BlockSpec((block_q, block_a, b), lambda i, j: (i, j, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i, j: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), tr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), tr.dtype),
    ]
    return pl.pallas_call(
        _stage2_kernel,
        grid=grid,
        in_specs=[spec_t, spec_t, spec_fb, spec_fb],
        out_specs=[spec_t, spec_t],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_stage2",
    )(tr, ti, fbr, fbi)
