"""Pallas TPU kernel: batched four-step (Bailey) FFT.

The per-worker hot loop of coded FFT is a length-L DFT of the worker's coded
shard (paper §III-B step 3).  On TPU we do NOT port a butterfly-network FFT
(a GPU/CPU idiom that starves the MXU); instead we factor ``L = A * B`` and
compute

    out[c, d] = ( (F_A @ M) * W ) @ F_B,     M[a, b] = x[a*B + b]
    X[c + d*A] = out[c, d]

i.e. two dense DFT-matrix matmuls (MXU work) plus one elementwise twiddle
(VPU work).  Complex arithmetic is planar: separate f32 real/imag planes,
4-real-matmul complex products with f32 accumulation.

Two variants:

* ``fourstep_fused_kernel`` -- one ``pallas_call``; per grid step the whole
  (A, B) matrix of one batch element lives in VMEM together with F_A, F_B
  and the twiddle.  VMEM footprint ~ 2*(A*B + A*A + B*B + A*B) * 4 bytes;
  good up to A = B = 512.
* ``stage1 / stage2`` two-pass -- stage 1 blocks over B-columns (column DFT
  + twiddle are column-local), stage 2 blocks over A-rows (row DFT is
  row-local); supports sizes whose full matrix would not fit VMEM.

The jit wrappers with layout pack/unpack live in ops.py; the jnp oracle in
ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "fourstep_fused",
    "fourstep_stage1",
    "fourstep_stage2",
]


def _cmul_mm(ar, ai, br, bi):
    """Complex matmul on planes with f32 accumulation (4 real matmuls)."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def _fused_kernel(xr_ref, xi_ref, far_ref, fai_ref, wr_ref, wi_ref,
                  fbr_ref, fbi_ref, or_ref, oi_ref):
    """One batch element per grid step: out = ((F_A @ M) * W) @ F_B."""
    xr = xr_ref[0]      # (A, B)
    xi = xi_ref[0]
    # step 1: column DFTs  (A, A) @ (A, B)
    t1r, t1i = _cmul_mm(far_ref[...], fai_ref[...], xr, xi)
    # step 2: twiddle (elementwise, VPU)
    wr = wr_ref[...]
    wi = wi_ref[...]
    t2r = t1r * wr - t1i * wi
    t2i = t1r * wi + t1i * wr
    # step 3: row DFTs  (A, B) @ (B, B)
    t3r, t3i = _cmul_mm(t2r, t2i, fbr_ref[...], fbi_ref[...])
    or_ref[0] = t3r
    oi_ref[0] = t3i


def fourstep_fused(xr, xi, far, fai, wr, wi, fbr, fbi, *, interpret=False):
    """Batched fused four-step FFT.

    ``xr, xi``: (batch, A, B) planes of M[a,b] = x[a*B+b].
    Returns planes of out[c, d] with X[c + d*A] = out[c, d].
    """
    batch, a, b = xr.shape
    spec_x = pl.BlockSpec((1, a, b), lambda i: (i, 0, 0))
    spec_fa = pl.BlockSpec((a, a), lambda i: (0, 0))
    spec_w = pl.BlockSpec((a, b), lambda i: (0, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
    ]
    return pl.pallas_call(
        _fused_kernel,
        grid=(batch,),
        in_specs=[spec_x, spec_x, spec_fa, spec_fa, spec_w, spec_w, spec_fb, spec_fb],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_fused",
    )(xr, xi, far, fai, wr, wi, fbr, fbi)


def _stage1_kernel(xr_ref, xi_ref, far_ref, fai_ref, wr_ref, wi_ref,
                   or_ref, oi_ref):
    """Column-blocked: out = (F_A @ M_block) * W_block."""
    t1r, t1i = _cmul_mm(far_ref[...], fai_ref[...], xr_ref[0], xi_ref[0])
    wr = wr_ref[...]
    wi = wi_ref[...]
    or_ref[0] = t1r * wr - t1i * wi
    oi_ref[0] = t1r * wi + t1i * wr


def fourstep_stage1(xr, xi, far, fai, wr, wi, *, block_b=256, interpret=False):
    """Stage 1+2 of the four-step FFT, blocked over columns of B."""
    batch, a, b = xr.shape
    block_b = min(block_b, b)
    grid = (batch, pl.cdiv(b, block_b))
    spec_x = pl.BlockSpec((1, a, block_b), lambda i, j: (i, 0, j))
    spec_fa = pl.BlockSpec((a, a), lambda i, j: (0, 0))
    spec_w = pl.BlockSpec((a, block_b), lambda i, j: (0, j))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), xr.dtype),
    ]
    return pl.pallas_call(
        _stage1_kernel,
        grid=grid,
        in_specs=[spec_x, spec_x, spec_fa, spec_fa, spec_w, spec_w],
        out_specs=[spec_x, spec_x],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_stage1",
    )(xr, xi, far, fai, wr, wi)


def _stage2_kernel(tr_ref, ti_ref, fbr_ref, fbi_ref, or_ref, oi_ref):
    """Row-blocked: out = T_block @ F_B."""
    t3r, t3i = _cmul_mm(tr_ref[0], ti_ref[0], fbr_ref[...], fbi_ref[...])
    or_ref[0] = t3r
    oi_ref[0] = t3i


def fourstep_stage2(tr, ti, fbr, fbi, *, block_a=256, interpret=False):
    """Stage 3 of the four-step FFT, blocked over rows of A."""
    batch, a, b = tr.shape
    block_a = min(block_a, a)
    grid = (batch, pl.cdiv(a, block_a))
    spec_t = pl.BlockSpec((1, block_a, b), lambda i, j: (i, j, 0))
    spec_fb = pl.BlockSpec((b, b), lambda i, j: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((batch, a, b), tr.dtype),
        jax.ShapeDtypeStruct((batch, a, b), tr.dtype),
    ]
    return pl.pallas_call(
        _stage2_kernel,
        grid=grid,
        in_specs=[spec_t, spec_t, spec_fb, spec_fb],
        out_specs=[spec_t, spec_t],
        out_shape=out_shape,
        interpret=interpret,
        name="fourstep_fft_stage2",
    )(tr, ti, fbr, fbi)
