"""Jit'd wrappers around the Pallas kernels (planar layout management).

These are the public entry points; they accept/return natural complex
arrays, handle the planar split, pick factorizations and block sizes, and
thread ``interpret=True`` on non-TPU backends so the same code validates on
CPU and runs compiled on TPU.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cmatmul import cmatmul
from repro.kernels.fourstep_fft import fourstep_fused, fourstep_stage1, fourstep_stage2
from repro.kernels.recombine import recombine_twiddle_dft

__all__ = [
    "default_interpret",
    "split_factor",
    "fft_fourstep",
    "mds_apply",
    "recombine_fused",
    "make_kernel_worker_fn",
]

# VMEM budget heuristic: fused kernel keeps ~4 (A,B) planes + 2 (A,A) +
# 2 (B,B) + 2 (A,B) twiddle planes resident; cap the fused path at the size
# where that stays under ~12 MB of the 16 MB VMEM.
_FUSED_MAX_ELEMS = 512 * 512


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except real TPU backends."""
    return jax.default_backend() != "tpu"


def split_factor(n: int) -> tuple[int, int]:
    """Factor ``n = a * b`` with a, b as close as possible (a <= b).

    MXU-friendliness: prefers multiples of 128 when available; for powers of
    two this returns (2^floor(k/2), 2^ceil(k/2)).
    """
    a = int(math.isqrt(n))
    while a > 1 and n % a != 0:
        a -= 1
    return a, n // a


def _dft_planes(n: int, dtype=jnp.float32):
    jk = jnp.outer(jnp.arange(n), jnp.arange(n))
    ang = -2.0 * jnp.pi * (jk % n) / n
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def _twiddle_planes(a: int, b: int, dtype=jnp.float32):
    # W[c, b] = omega_{a*b}^{c*b}
    cb = jnp.outer(jnp.arange(a), jnp.arange(b))
    ang = -2.0 * jnp.pi * (cb % (a * b)) / (a * b)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


@functools.partial(jax.jit, static_argnames=("a", "b", "interpret", "fused"))
def _fft_fourstep_impl(x, a, b, interpret, fused):
    batch = x.shape[0]
    ell = a * b
    xr, xi = ref.planar(x)
    xr = xr.reshape(batch, a, b)
    xi = xi.reshape(batch, a, b)
    far, fai = _dft_planes(a)
    fbr, fbi = _dft_planes(b)
    wr, wi = _twiddle_planes(a, b)
    if fused:
        outr, outi = fourstep_fused(
            xr, xi, far, fai, wr, wi, fbr, fbi, interpret=interpret
        )
    else:
        t1r, t1i = fourstep_stage1(xr, xi, far, fai, wr, wi, interpret=interpret)
        outr, outi = fourstep_stage2(t1r, t1i, fbr, fbi, interpret=interpret)
    # out[c, d] holds X[c + d*A]  ->  transpose to (d, c) then flatten
    z = ref.unplanar(outr, outi)
    return jnp.swapaxes(z, -1, -2).reshape(batch, ell)


def fft_fourstep(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Batched FFT along the last axis via the Pallas four-step kernel.

    ``x``: (..., L) complex; L is factored automatically.  Non-batched
    inputs are promoted.  Output matches ``jnp.fft.fft(x, axis=-1)`` up to
    f32 planar precision.
    """
    if interpret is None:
        interpret = default_interpret()
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    batch_shape = x.shape[:-1]
    ell = x.shape[-1]
    a, b = split_factor(ell)
    fused = (a * b) <= _FUSED_MAX_ELEMS
    out = _fft_fourstep_impl(
        x.reshape(-1, ell), a, b, interpret, fused
    ).reshape(batch_shape + (ell,))
    return out[0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("interpret",))
def _mds_apply_impl(g, c, interpret):
    gr, gi = ref.planar(g)
    payload = c.shape[1:]
    flat = c.reshape(c.shape[0], -1)
    cr, ci = ref.planar(flat)
    outr, outi = cmatmul(gr, gi, cr, ci, interpret=interpret)
    return ref.unplanar(outr, outi).reshape((g.shape[0],) + payload)


def mds_apply(g: jax.Array, c: jax.Array, *, interpret: bool | None = None):
    """Kernel-backed ``G @ c`` for MDS encode / decode-apply.

    ``g``: (n, m) complex code matrix; ``c``: (m, *payload).
    """
    if interpret is None:
        interpret = default_interpret()
    return _mds_apply_impl(g, c, interpret)


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def _recombine_impl(c_hat, s, interpret):
    m, ell = c_hat.shape
    cr, ci = ref.planar(c_hat)
    ki = jnp.outer(jnp.arange(m), jnp.arange(ell))
    ang = -2.0 * jnp.pi * (ki % s) / s
    wr, wi = jnp.cos(ang).astype(jnp.float32), jnp.sin(ang).astype(jnp.float32)
    fr, fi = _dft_planes(m)
    outr, outi = recombine_twiddle_dft(cr, ci, wr, wi, fr, fi, interpret=interpret)
    return ref.unplanar(outr, outi).reshape(s)


def recombine_fused(c_hat: jax.Array, s: int, *, interpret: bool | None = None):
    """Kernel-backed master recombination: (m, s/m) decoded C -> X (s,)."""
    if interpret is None:
        interpret = default_interpret()
    return _recombine_impl(c_hat, s, interpret)


def make_kernel_worker_fn(interpret: bool | None = None):
    """A ``CodedFFT.worker_fn`` that uses the Pallas four-step kernel.

    Satisfies the ``CodedPlan`` worker contract: transforms the LAST axis
    and maps over arbitrary leading axes.  All leading axes -- (workers,),
    (batch, workers) from the batched service scheduler, or (batch,
    n_local) under the distributed runtime -- are collapsed into the
    kernel's single grid dimension, so a bucket of requests costs one
    Pallas launch instead of one per request.
    """

    def worker_fn(a: jax.Array) -> jax.Array:
        lead, ell = a.shape[:-1], a.shape[-1]
        out = fft_fourstep(a.reshape(-1, ell), interpret=interpret)
        return out.reshape(lead + (ell,))

    return worker_fn
